"""Kernel-vs-reference correctness: the core L1 signal.

Sweeps shapes, block sizes, and value regimes (a hand-rolled
hypothesis-style sweep — network-free environment), asserting the Pallas
kernels in interpret mode match the pure-jnp oracles.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels import kalman, logpdf, ref


def _rand_spd(rng, n, dz, scale=1.0):
    """Batch of well-conditioned SPD matrices."""
    m = rng.standard_normal((n, dz, dz)).astype(np.float32) * scale
    return (m @ np.transpose(m, (0, 2, 1)) + np.eye(dz, dtype=np.float32)).astype(
        np.float32
    )


SHAPES = [128, 256, 512, 1024]
BLOCKS = [64, 128, 256]
SEEDS = [0, 1, 2]


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("block", BLOCKS)
def test_kalman_matches_ref_shapes(n, block):
    if n % block != 0:
        pytest.skip("block must divide n")
    rng = np.random.default_rng(7)
    means = rng.standard_normal((n, ref.DZ)).astype(np.float32)
    covs = _rand_spd(rng, n, ref.DZ)
    y = rng.standard_normal(n).astype(np.float32)
    got_m, got_p, got_ll = kalman.kalman3(means, covs, y, block_n=block)
    want_m, want_p, want_ll = ref.kalman3_ref(means, covs, y)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_ll, want_ll, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("scale", [0.1, 1.0, 5.0])
def test_kalman_value_regimes(seed, scale):
    rng = np.random.default_rng(seed)
    n = 256
    means = (rng.standard_normal((n, ref.DZ)) * scale).astype(np.float32)
    covs = _rand_spd(rng, n, ref.DZ, scale=scale)
    y = (rng.standard_normal(n) * scale).astype(np.float32)
    got_m, got_p, got_ll = kalman.kalman3(means, covs, y)
    want_m, want_p, want_ll = ref.kalman3_ref(means, covs, y)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_ll, want_ll, rtol=1e-4, atol=1e-4)


def test_kalman_reduces_uncertainty_and_moves_mean():
    # Semantic sanity on the kernel itself (not just agreement).
    n = 128
    means = np.zeros((n, ref.DZ), dtype=np.float32)
    covs = np.tile(np.eye(ref.DZ, dtype=np.float32) * 4.0, (n, 1, 1))
    y = np.full(n, 2.0, dtype=np.float32)
    got_m, got_p, got_ll = kalman.kalman3(means, covs, y, block_n=128)
    # Posterior mean moved toward the (positive) observation along C.
    assert np.all(np.asarray(got_m)[:, 0] > 0.0)
    # Trace shrank vs the predicted covariance trace.
    pred_tr = np.trace(ref.A @ covs[0] @ ref.A.T + ref.Q)
    post_tr = np.trace(np.asarray(got_p)[0])
    assert post_tr < pred_tr
    assert np.all(np.isfinite(np.asarray(got_ll)))


@pytest.mark.parametrize("n", [256, 512, 2048])
def test_logpdf_matches_ref(n):
    rng = np.random.default_rng(3)
    x = rng.standard_normal(n).astype(np.float32) * 3.0
    mean = rng.standard_normal(n).astype(np.float32)
    sd = (rng.random(n).astype(np.float32) + 0.1) * 2.0
    got = logpdf.logpdf(x, mean, sd)
    want = ref.logpdf_ref(x, mean, sd)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_logpdf_matches_scipy_style_closed_form():
    # Independent closed-form check (not via ref.py).
    x = np.array([0.0, 1.0, -2.0, 0.5] * 64, dtype=np.float32)
    got = np.asarray(logpdf.logpdf(x, np.zeros_like(x), np.ones_like(x)))
    want = -0.5 * x * x - 0.5 * np.log(2 * np.pi)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_kernels_jit_and_lower():
    # The L2 functions must trace and lower (what aot.py relies on).
    from compile import model

    n, dz = 256, ref.DZ
    lowered = jax.jit(model.rbpf_generation).lower(
        jax.ShapeDtypeStruct((n, dz), jnp.float32),
        jax.ShapeDtypeStruct((n, dz, dz), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    text = str(lowered.compiler_ir("stablehlo"))
    assert "func" in text

    lowered = jax.jit(model.weight_generation).lower(
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )
    assert "func" in str(lowered.compiler_ir("stablehlo"))


def test_vmem_budget():
    # The kernel's working set must fit comfortably in a 16 MiB VMEM.
    assert kalman.vmem_bytes(128) < 16 * 1024 * 1024
    assert kalman.vmem_bytes(1024) < 16 * 1024 * 1024


def test_constants_match_rust_side():
    """Guard the cross-language contract: these exact values are hardcoded
    in rust/src/runtime/kalman.rs::KalmanParams::rbpf_default()."""
    np.testing.assert_allclose(
        ref.A, [[0.8, 0.1, 0.0], [-0.1, 0.8, 0.1], [0.0, -0.1, 0.8]]
    )
    np.testing.assert_allclose(ref.Q, np.eye(3) * 0.1)
    np.testing.assert_allclose(ref.C, [1.0, 0.5, 0.25])
    assert ref.R == np.float32(0.5)
