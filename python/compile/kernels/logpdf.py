"""L1 Pallas kernel: batched diagonal-Gaussian log-density (weighting).

Used by the MOT observation weighting path and as the minimal smoke
artifact for the Rust runtime. Elementwise over the particle dimension,
tiled for VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_N = 256


def _kernel(x_ref, m_ref, sd_ref, o_ref):
    x = x_ref[...]
    m = m_ref[...]
    sd = sd_ref[...]
    z = (x - m) / sd
    o_ref[...] = -0.5 * z * z - jnp.log(sd) - 0.5 * ref.LN_2PI


def logpdf(x, mean, sd, block_n: int = BLOCK_N, interpret: bool = True):
    """Elementwise normal log-pdf as a Pallas call. Shapes: [N] each."""
    n = x.shape[0]
    assert n % block_n == 0, f"N={n} must be a multiple of block_n={block_n}"
    grid = (n // block_n,)
    spec = pl.BlockSpec((block_n,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(x, mean, sd)
