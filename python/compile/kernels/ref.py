"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: the pytest suite asserts the Pallas
kernels (interpret mode) match these to tight tolerance, and the Rust side
checks its f64 CPU oracle against the compiled artifacts.

Model constants MUST match `rust/src/runtime/kalman.rs::KalmanParams::
rbpf_default()`.
"""

import jax.numpy as jnp
import numpy as np

DZ = 3

# The RBPF linear-substate parameters (keep in sync with the Rust side).
A = np.array(
    [[0.8, 0.1, 0.0], [-0.1, 0.8, 0.1], [0.0, -0.1, 0.8]], dtype=np.float32
)
Q = np.eye(DZ, dtype=np.float32) * 0.1
C = np.array([1.0, 0.5, 0.25], dtype=np.float32)  # 1x3 observation row
R = np.float32(0.5)

LN_2PI = float(np.log(2.0 * np.pi))


def kalman3_ref(means, covs, y):
    """Batched predict + scalar-observation update + log-likelihood.

    means: [N, DZ], covs: [N, DZ, DZ], y: [N] (same observation broadcast
    by the caller). Returns (new_means, new_covs, ll).
    """
    a = jnp.asarray(A)
    q = jnp.asarray(Q)
    c = jnp.asarray(C)
    # Predict.
    mp = means @ a.T                                   # [N, DZ]
    pp = jnp.einsum("ij,njk,lk->nil", a, covs, a) + q  # [N, DZ, DZ]
    # Scalar-observation update.
    pct = pp @ c                                       # [N, DZ]
    s = pct @ c + R                                    # [N]
    k = pct / s[:, None]                               # [N, DZ]
    innov = y - mp @ c                                 # [N]
    new_means = mp + k * innov[:, None]
    new_covs = pp - s[:, None, None] * (k[:, :, None] * k[:, None, :])
    ll = -0.5 * (innov * innov / s + jnp.log(s) + LN_2PI)
    return new_means, new_covs, ll


def logpdf_ref(x, mean, sd):
    """Elementwise normal log-density."""
    z = (x - mean) / sd
    return -0.5 * z * z - jnp.log(sd) - 0.5 * LN_2PI
