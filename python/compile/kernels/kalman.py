"""L1 Pallas kernel: batched 3-D Kalman predict+update+weight.

The RBPF's per-generation numeric hot spot, tiled over the particle
dimension. Each grid step loads a (BLOCK_N, DZ) block of means and a
(BLOCK_N, DZ, DZ) block of covariances into VMEM, runs the full
predict → gain → update → log-likelihood chain in registers/VMEM, and
writes the three outputs — one HBM round trip per particle block.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): the per-particle
matrices are tiny (3×3), so the kernel batches them into (BLOCK_N, DZ*DZ)
panels where the arithmetic is pure VPU elementwise work with DZ-unrolled
contractions — the MXU is not the right unit at DZ=3; the win is VMEM
residency of the whole chain. `interpret=True` is required for CPU PJRT
execution (Mosaic custom-calls cannot run on the CPU plugin).

Must match `ref.kalman3_ref` exactly (same constants, same order of
operations up to float association).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DZ = ref.DZ
BLOCK_N = 128


def _kernel(m_ref, p_ref, y_ref, om_ref, op_ref, oll_ref):
    # Pallas kernels may not capture array constants; at DZ=3 the natural
    # formulation is the fully unrolled contraction with *scalar* model
    # constants (Python floats trace as immediates).
    a = [[float(ref.A[i, j]) for j in range(DZ)] for i in range(DZ)]
    q = [[float(ref.Q[i, j]) for j in range(DZ)] for i in range(DZ)]
    c = [float(ref.C[j]) for j in range(DZ)]
    r = float(ref.R)

    m = [m_ref[:, j] for j in range(DZ)]                  # DZ × [B]
    p = [[p_ref[:, i, j] for j in range(DZ)] for i in range(DZ)]
    y = y_ref[...]                                        # [B]

    # Predict: mp = A m ; pp = A P A^T + Q.
    mp = [sum(a[i][j] * m[j] for j in range(DZ)) for i in range(DZ)]
    ap = [
        [sum(a[i][j] * p[j][k] for j in range(DZ)) for k in range(DZ)]
        for i in range(DZ)
    ]
    pp = [
        [sum(ap[i][k] * a[l][k] for k in range(DZ)) + q[i][l] for l in range(DZ)]
        for i in range(DZ)
    ]

    # Scalar-observation update.
    pct = [sum(pp[i][j] * c[j] for j in range(DZ)) for i in range(DZ)]
    s = sum(pct[i] * c[i] for i in range(DZ)) + r         # [B]
    k = [pct[i] / s for i in range(DZ)]
    cm = sum(c[i] * mp[i] for i in range(DZ))
    innov = y - cm
    for i in range(DZ):
        om_ref[:, i] = mp[i] + k[i] * innov
        for l in range(DZ):
            op_ref[:, i, l] = pp[i][l] - s * k[i] * k[l]
    oll_ref[...] = -0.5 * (innov * innov / s + jnp.log(s) + ref.LN_2PI)


def kalman3(means, covs, y, block_n: int = BLOCK_N, interpret: bool = True):
    """Batched Kalman step as a Pallas call. Shapes: [N,DZ], [N,DZ,DZ], [N]."""
    n = means.shape[0]
    assert n % block_n == 0, f"N={n} must be a multiple of block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, DZ), lambda i: (i, 0)),
            pl.BlockSpec((block_n, DZ, DZ), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, DZ), lambda i: (i, 0)),
            pl.BlockSpec((block_n, DZ, DZ), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, DZ), jnp.float32),
            jax.ShapeDtypeStruct((n, DZ, DZ), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(means, covs, y)


def vmem_bytes(block_n: int = BLOCK_N) -> int:
    """Estimated VMEM footprint of one grid step (f32): in + out blocks."""
    per_particle = DZ + DZ * DZ + 1
    return 2 * block_n * per_particle * 4
