"""L2: the JAX numeric step functions lowered for the Rust coordinator.

These are the tensorizable portions of the paper's evaluation models: the
RBPF's batched linear-substate generation (calling the L1 Pallas Kalman
kernel) and the generic batched weighting density. The dynamic,
pointer-rich portions (state chains, stacks, ragged track arrays, delayed
sampling accumulators) live in the Rust heap; these functions see only the
flat numeric views the coordinator extracts per generation.

Lowered once by `aot.py`; never imported at inference time.
"""

import jax.numpy as jnp

from .kernels import kalman as kalman_kernel
from .kernels import logpdf as logpdf_kernel


def rbpf_generation(means, covs, y):
    """One RBPF generation over the particle batch: Kalman predict +
    scalar-observation update + marginal log-likelihood (the particle
    weight's linear-substate factor). `y` is the broadcast observation.

    means: [N, 3] f32; covs: [N, 3, 3] f32; y: [N] f32.
    Returns (new_means, new_covs, ll) — a stable output order for the
    Rust runtime.
    """
    new_means, new_covs, ll = kalman_kernel.kalman3(means, covs, y)
    return (new_means, new_covs, jnp.asarray(ll))


def weight_generation(x, mean, sd):
    """Batched diagonal-Gaussian weighting: [N] -> [N] log-densities."""
    return (logpdf_kernel.logpdf(x, mean, sd),)
