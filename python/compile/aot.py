"""AOT lowering: JAX/Pallas -> HLO text artifacts for the Rust runtime.

Run once at build time (`make artifacts`); the Rust binary is then
self-contained. The interchange format is HLO **text**, not serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly.

Artifacts (batch size BATCH must match `rust/src/runtime/mod.rs::BATCH`):
  kalman3.hlo.txt — batched RBPF Kalman generation (3-tuple output)
  logpdf.hlo.txt  — batched diagonal-Gaussian weighting (1-tuple output)
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

BATCH = 256  # keep in sync with rust/src/runtime/mod.rs
DZ = 3


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_kalman3() -> str:
    spec_m = jax.ShapeDtypeStruct((BATCH, DZ), jnp.float32)
    spec_p = jax.ShapeDtypeStruct((BATCH, DZ, DZ), jnp.float32)
    spec_y = jax.ShapeDtypeStruct((BATCH,), jnp.float32)
    lowered = jax.jit(model.rbpf_generation).lower(spec_m, spec_p, spec_y)
    return to_hlo_text(lowered)


def lower_logpdf() -> str:
    spec = jax.ShapeDtypeStruct((BATCH,), jnp.float32)
    lowered = jax.jit(model.weight_generation).lower(spec, spec, spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out-dir",
        default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
        help="artifact output directory",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, fn in [("kalman3", lower_kalman3), ("logpdf", lower_logpdf)]:
        text = fn()
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")


if __name__ == "__main__":
    main()
