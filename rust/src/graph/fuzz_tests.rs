//! Differential property tests: random object-graph programs executed on
//! the production [`Heap`] in all three copy modes must be observationally
//! equivalent to the eager [`Oracle`] after every step, and the heap's
//! reference counts must validate against a from-scratch recomputation.
//!
//! This is the machine-checked version of the paper's §4 validation
//! ("the output is expected to match regardless of the configuration").

use super::oracle::{OId, Oracle};
use crate::heap::{CopyMode, Heap, Lazy, RawLazy};
use crate::lazy_fields;
use crate::prop::{self, CaseResult, Gen};

#[derive(Clone, Default)]
struct SNode {
    value: i64,
    children: Vec<Lazy<SNode>>,
}
lazy_fields!(SNode: children);

/// One step of a generated object-graph program. Root indices refer to the
/// live-roots vector (removals use swap_remove, deterministically).
#[derive(Clone, Debug)]
enum Op {
    Alloc { value: i64 },
    DeepCopy { root: usize },
    Release { root: usize },
    WriteValue { root: usize, path: Vec<usize>, value: i64 },
    WriteValueAtRoot { root: usize, value: i64 },
    PushNew { root: usize, path: Vec<usize>, value: i64, in_context: bool },
    LinkExisting { root: usize, path: Vec<usize>, target: usize },
    PopChild { root: usize, path: Vec<usize> },
    /// Forced-eager deep copy (the particle-Gibbs reference pattern).
    EagerCopy { root: usize },
    /// Extra owning handle to the same object (clone_handle).
    Retain { root: usize },
}

/// Generate a script, using a shadow oracle to keep indices/paths valid
/// and to refuse cycle-creating links.
fn gen_script(g: &mut Gen) -> Vec<Op> {
    let mut ops = Vec::new();
    let mut oracle = Oracle::new();
    let mut roots: Vec<OId> = Vec::new();
    let n_ops = 8 + g.size * 2;
    for _ in 0..n_ops {
        if g.spent() {
            break;
        }
        if roots.is_empty() {
            let value = g.i64(-100, 100);
            roots.push(oracle.alloc(value));
            ops.push(Op::Alloc { value });
            continue;
        }
        let root = g.usize(0, roots.len() - 1);
        // Random descent path within the chosen root's tree.
        let mut path = Vec::new();
        {
            let mut v = roots[root];
            while oracle.n_children(v) > 0 && g.bool(0.6) {
                let i = g.usize(0, oracle.n_children(v) - 1);
                path.push(i);
                v = oracle.child(v, i);
            }
        }
        let choice = g.weighted(&[2.0, 3.0, 1.5, 4.0, 1.0, 3.5, 1.0, 1.5, 1.0, 0.7]);
        match choice {
            0 => {
                let value = g.i64(-100, 100);
                roots.push(oracle.alloc(value));
                ops.push(Op::Alloc { value });
            }
            1 => {
                roots.push(oracle.deep_copy(roots[root]));
                ops.push(Op::DeepCopy { root });
            }
            2 => {
                if roots.len() > 1 {
                    roots.swap_remove(root);
                    ops.push(Op::Release { root });
                }
            }
            3 => {
                let value = g.i64(-100, 100);
                let node = oracle.descend(roots[root], &path);
                oracle.set_value(node, value);
                ops.push(Op::WriteValue { root, path, value });
            }
            4 => {
                let value = g.i64(-100, 100);
                oracle.set_value(roots[root], value);
                ops.push(Op::WriteValueAtRoot { root, value });
            }
            5 => {
                let value = g.i64(-100, 100);
                let in_context = g.bool(0.5);
                let node = oracle.descend(roots[root], &path);
                let c = oracle.alloc(value);
                oracle.push_child(node, c);
                ops.push(Op::PushNew { root, path, value, in_context });
            }
            6 => {
                // Link an existing root as a child — cross references and
                // DAG sharing — unless it would create a cycle.
                let target = g.usize(0, roots.len() - 1);
                let node = oracle.descend(roots[root], &path);
                if !oracle.reachable(roots[target], node) {
                    oracle.push_child(node, roots[target]);
                    ops.push(Op::LinkExisting { root, path, target });
                }
            }
            8 => {
                roots.push(oracle.deep_copy(roots[root]));
                ops.push(Op::EagerCopy { root });
            }
            9 => {
                // Retained handles alias the same object: subsequent
                // writes through either must stay visible to both.
                roots.push(roots[root]);
                ops.push(Op::Retain { root });
            }
            _ => {
                let node = oracle.descend(roots[root], &path);
                if oracle.n_children(node) > 0 && path.last() != Some(&(usize::MAX)) {
                    // Only pop children that are not on the descent path of
                    // any *other* pending op — safe since ops replay
                    // sequentially against the same evolving structure.
                    oracle.pop_child(node);
                    ops.push(Op::PopChild { root, path });
                }
            }
        }
    }
    ops
}


/// Descend a path for *writing*: get-chain from the root (the Table 1
/// discipline), updating each stored edge in place. Updates the root
/// handle too.
fn descend_write(heap: &mut Heap, root: &mut Lazy<SNode>, path: &[usize]) -> Lazy<SNode> {
    heap.mutate_root(root, |_| {});
    let mut cur = *root;
    for &i in path {
        cur = heap.get_field(&cur, move |n| &mut n.children[i]);
    }
    cur
}

/// Structural comparison of a heap tree vs the oracle tree.
fn compare(
    heap: &mut Heap,
    p: &Lazy<SNode>,
    oracle: &Oracle,
    o: OId,
    where_: &str,
) -> Result<(), String> {
    let mut cur = *p;
    let (v, n) = heap.read(&mut cur, |s| (s.value, s.children.len()));
    if v != oracle.value(o) {
        return Err(format!(
            "{where_}: value mismatch heap={v} oracle={}",
            oracle.value(o)
        ));
    }
    if n != oracle.n_children(o) {
        return Err(format!(
            "{where_}: child count mismatch heap={n} oracle={}",
            oracle.n_children(o)
        ));
    }
    for i in 0..n {
        let c = heap.read_ptr(&mut cur, |s| s.children[i]);
        compare(heap, &c, oracle, oracle.child(o, i), where_)?;
    }
    Ok(())
}

/// Replay a script on a fresh heap (given mode) + fresh oracle, comparing
/// observable state after every op and validating reference counts.
fn replay(mode: CopyMode, ops: &[Op]) -> Result<(), String> {
    let mut heap = Heap::new(mode);
    let mut oracle = Oracle::new();
    let mut h_roots: Vec<Lazy<SNode>> = Vec::new();
    let mut o_roots: Vec<OId> = Vec::new();

    for (step, op) in ops.iter().enumerate() {
        match op {
            Op::Alloc { value } => {
                h_roots.push(heap.alloc(SNode {
                    value: *value,
                    children: Vec::new(),
                }));
                o_roots.push(oracle.alloc(*value));
            }
            Op::DeepCopy { root } => {
                let c = heap.deep_copy(&h_roots[*root]);
                h_roots.push(c);
                o_roots.push(oracle.deep_copy(o_roots[*root]));
            }
            Op::Release { root } => {
                let h = h_roots.swap_remove(*root);
                heap.release(h);
                o_roots.swap_remove(*root);
            }
            Op::WriteValue { root, path, value } => {
                let mut h = h_roots[*root];
                let mut node = descend_write(&mut heap, &mut h, path);
                h_roots[*root] = h;
                heap.mutate(&mut node, |n| n.value = *value);
                let o = oracle.descend(o_roots[*root], path);
                oracle.set_value(o, *value);
            }
            Op::WriteValueAtRoot { root, value } => {
                // Owning mutate: exercises thaw + single-reference paths.
                let mut h = h_roots[*root];
                heap.mutate_root(&mut h, |n| n.value = *value);
                h_roots[*root] = h;
                oracle.set_value(o_roots[*root], *value);
            }
            Op::PushNew {
                root,
                path,
                value,
                in_context,
            } => {
                let mut h = h_roots[*root];
                let mut node = descend_write(&mut heap, &mut h, path);
                h_roots[*root] = h;
                let child = if *in_context {
                    let l = node.label();
                    heap.with_context(l, |h| {
                        h.alloc(SNode {
                            value: *value,
                            children: Vec::new(),
                        })
                    })
                } else {
                    heap.alloc(SNode {
                        value: *value,
                        children: Vec::new(),
                    })
                };
                heap.mutate(&mut node, |n| n.children.push(child));
                heap.release(child); // the stored edge owns its own count
                let o = oracle.descend(o_roots[*root], path);
                let c = oracle.alloc(*value);
                oracle.push_child(o, c);
            }
            Op::LinkExisting { root, path, target } => {
                let mut h = h_roots[*root];
                let mut node = descend_write(&mut heap, &mut h, path);
                h_roots[*root] = h;
                let t = h_roots[*target];
                heap.mutate(&mut node, |n| n.children.push(t));
                let o = oracle.descend(o_roots[*root], path);
                oracle.push_child(o, o_roots[*target]);
            }
            Op::PopChild { root, path } => {
                let mut h = h_roots[*root];
                let mut node = descend_write(&mut heap, &mut h, path);
                h_roots[*root] = h;
                heap.mutate(&mut node, |n| {
                    n.children.pop();
                });
                let o = oracle.descend(o_roots[*root], path);
                oracle.pop_child(o);
            }
            Op::EagerCopy { root } => {
                let c = heap.deep_copy_eager(&h_roots[*root]);
                h_roots.push(c);
                o_roots.push(oracle.deep_copy(o_roots[*root]));
            }
            Op::Retain { root } => {
                let c = heap.clone_handle(&h_roots[*root]);
                h_roots.push(c);
                o_roots.push(o_roots[*root]); // aliases in the oracle too
            }
        }
        // Full observational comparison from every root.
        for (i, (h, o)) in h_roots.iter().zip(&o_roots).enumerate() {
            compare(
                &mut heap,
                h,
                &oracle,
                *o,
                &format!("{:?} step {step} root {i} op {op:?}", mode),
            )?;
        }
        // Reference-count invariants.
        let raws: Vec<RawLazy> = h_roots.iter().map(|h| h.raw()).collect();
        heap.validate(&raws);
    }

    // Teardown: everything must be reclaimed (after the precise sweep —
    // the paper's cheap criterion tolerates memo-cycle leftovers, which
    // deep_sweep collects).
    for h in h_roots {
        heap.release(h);
    }
    heap.sweep_memos();
    heap.deep_sweep(&[]);
    if heap.live_objects() != 0 {
        return Err(format!(
            "{mode:?}: {} objects leaked after full release; script: {ops:?}\n{}",
            heap.live_objects(),
            heap.dump_live()
        ));
    }
    Ok(())
}

#[test]
fn heap_matches_eager_oracle_all_modes() {
    prop::check(120, |g| -> CaseResult {
        let ops = gen_script(g);
        for mode in CopyMode::ALL {
            if let Err(e) = replay(mode, &ops) {
                return CaseResult::Fail(e);
            }
        }
        CaseResult::Pass
    });
}

#[test]
fn all_modes_agree_with_each_other() {
    // Beyond matching the oracle, the three modes must produce identical
    // final structures for the same script (the paper's output check).
    prop::check(60, |g| -> CaseResult {
        let ops = gen_script(g);
        // Collect final value-trees per mode.
        let mut dumps: Vec<String> = Vec::new();
        for mode in CopyMode::ALL {
            let mut heap = Heap::new(mode);
            let mut oracle = Oracle::new();
            let mut h_roots: Vec<Lazy<SNode>> = Vec::new();
            let mut o_roots: Vec<OId> = Vec::new();
            for op in &ops {
                apply_silent(&mut heap, &mut oracle, &mut h_roots, &mut o_roots, op);
            }
            let mut dump = String::new();
            for h in &h_roots {
                dump_tree(&mut heap, h, &mut dump);
                dump.push('|');
            }
            dumps.push(dump);
        }
        if dumps.windows(2).all(|w| w[0] == w[1]) {
            CaseResult::Pass
        } else {
            CaseResult::Fail(format!("mode dumps diverge: {dumps:?}"))
        }
    });
}

fn dump_tree(heap: &mut Heap, p: &Lazy<SNode>, out: &mut String) {
    let mut cur = *p;
    let (v, n) = heap.read(&mut cur, |s| (s.value, s.children.len()));
    out.push_str(&format!("{v}("));
    for i in 0..n {
        let c = heap.read_ptr(&mut cur, |s| s.children[i]);
        dump_tree(heap, &c, out);
    }
    out.push(')');
}

fn apply_silent(
    heap: &mut Heap,
    oracle: &mut Oracle,
    h_roots: &mut Vec<Lazy<SNode>>,
    o_roots: &mut Vec<OId>,
    op: &Op,
) {
    match op {
        Op::Alloc { value } => {
            h_roots.push(heap.alloc(SNode {
                value: *value,
                children: Vec::new(),
            }));
            o_roots.push(oracle.alloc(*value));
        }
        Op::DeepCopy { root } => {
            let c = heap.deep_copy(&h_roots[*root]);
            h_roots.push(c);
            o_roots.push(oracle.deep_copy(o_roots[*root]));
        }
        Op::Release { root } => {
            let h = h_roots.swap_remove(*root);
            heap.release(h);
            o_roots.swap_remove(*root);
        }
        Op::WriteValue { root, path, value } => {
            let mut h = h_roots[*root];
            let mut node = descend_write(heap, &mut h, path);
            h_roots[*root] = h;
            heap.mutate(&mut node, |n| n.value = *value);
            oracle.set_value(oracle.descend(o_roots[*root], path), *value);
        }
        Op::WriteValueAtRoot { root, value } => {
            let mut h = h_roots[*root];
            heap.mutate_root(&mut h, |n| n.value = *value);
            h_roots[*root] = h;
            oracle.set_value(o_roots[*root], *value);
        }
        Op::PushNew {
            root, path, value, ..
        } => {
            let mut h = h_roots[*root];
            let mut node = descend_write(heap, &mut h, path);
            h_roots[*root] = h;
            let child = heap.alloc(SNode {
                value: *value,
                children: Vec::new(),
            });
            heap.mutate(&mut node, |n| n.children.push(child));
            heap.release(child);
            let o = oracle.descend(o_roots[*root], path);
            let c = oracle.alloc(*value);
            oracle.push_child(o, c);
        }
        Op::LinkExisting { root, path, target } => {
            let mut h = h_roots[*root];
            let mut node = descend_write(heap, &mut h, path);
            h_roots[*root] = h;
            let t = h_roots[*target];
            heap.mutate(&mut node, |n| n.children.push(t));
            let o = oracle.descend(o_roots[*root], path);
            oracle.push_child(o, o_roots[*target]);
        }
        Op::PopChild { root, path } => {
            let mut h = h_roots[*root];
            let mut node = descend_write(heap, &mut h, path);
            h_roots[*root] = h;
            heap.mutate(&mut node, |n| {
                n.children.pop();
            });
            oracle.pop_child(oracle.descend(o_roots[*root], path));
        }
        Op::EagerCopy { root } => {
            let c = heap.deep_copy_eager(&h_roots[*root]);
            h_roots.push(c);
            o_roots.push(oracle.deep_copy(o_roots[*root]));
        }
        Op::Retain { root } => {
            let c = heap.clone_handle(&h_roots[*root]);
            h_roots.push(c);
            o_roots.push(o_roots[*root]);
        }
    }
}

#[test]
fn retain_after_freeze_clears_sro_flag() {
    // Regression (fuzzer-found): clone_handle created a second in-edge
    // with the same label without clearing the Remark 1 flag; a later
    // owning write skipped the memo and stranded the retained handle on
    // the stale original.
    let ops = vec![
        Op::Alloc { value: 43 },
        Op::DeepCopy { root: 0 },
        Op::Retain { root: 1 },
        Op::PushNew {
            root: 1,
            path: vec![],
            value: -90,
            in_context: true,
        },
    ];
    for mode in CopyMode::ALL {
        if let Err(e) = replay(mode, &ops) {
            panic!("{e}");
        }
    }
}

#[test]
fn leak_regression_linkexisting_deepcopy() {
    // Shrunk from fuzz seed 0x2e2ac13ef828273c: link + deep copies + release
    // left objects behind.
    let ops = vec![
        Op::Alloc { value: 63 },
        Op::WriteValueAtRoot { root: 0, value: -78 },
        Op::DeepCopy { root: 0 },
        Op::LinkExisting { root: 0, path: vec![], target: 1 },
        Op::DeepCopy { root: 1 },
        Op::PushNew { root: 1, path: vec![], value: -36, in_context: true },
        Op::WriteValue { root: 2, path: vec![], value: 8 },
        Op::WriteValue { root: 1, path: vec![], value: -22 },
        Op::Release { root: 2 },
    ];
    if let Err(e) = replay(CopyMode::Lazy, &ops) {
        panic!("{e}");
    }
}
