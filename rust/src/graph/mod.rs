//! The paper's §2 formalism, executable: labeled directed multigraphs
//! F (Definition 1), G (Definition 2, Algorithm 1) and H (Definition 3,
//! Algorithm 2), plus an eager-copy [`oracle`] used as the reference
//! semantics in differential property tests against the production
//! [`Heap`](crate::heap::Heap).

pub mod formal;
pub mod oracle;

#[cfg(test)]
mod fuzz_tests;
