//! Eager-copy reference semantics (the F-graph view of `deep_copy`).
//!
//! The oracle implements object graphs with *immediate* recursive deep
//! copies — the semantics the lazy platform must be observationally
//! equivalent to (the paper validates its implementation the same way:
//! "the output is expected to match regardless of the configuration").
//! Nodes are never reclaimed (test-only structure), which keeps ids stable
//! for differential comparison.

use std::collections::HashMap;

/// Oracle node id (stable; nodes are never reclaimed).
pub type OId = usize;

#[derive(Clone, Default)]
struct ONode {
    value: i64,
    children: Vec<OId>,
}

/// Reference object graph with integer payloads and child lists.
#[derive(Clone, Default)]
pub struct Oracle {
    nodes: Vec<ONode>,
}

impl Oracle {
    /// An empty oracle graph.
    pub fn new() -> Self {
        Oracle::default()
    }

    /// Allocate a node with the given payload and no children.
    pub fn alloc(&mut self, value: i64) -> OId {
        self.nodes.push(ONode {
            value,
            children: Vec::new(),
        });
        self.nodes.len() - 1
    }

    /// The node's payload.
    pub fn value(&self, id: OId) -> i64 {
        self.nodes[id].value
    }

    /// Overwrite the node's payload.
    pub fn set_value(&mut self, id: OId, v: i64) {
        self.nodes[id].value = v;
    }

    /// The node's child list.
    pub fn children(&self, id: OId) -> &[OId] {
        &self.nodes[id].children
    }

    /// Number of children.
    pub fn n_children(&self, id: OId) -> usize {
        self.nodes[id].children.len()
    }

    /// The `i`-th child.
    pub fn child(&self, id: OId, i: usize) -> OId {
        self.nodes[id].children[i]
    }

    /// Append a child edge.
    pub fn push_child(&mut self, id: OId, c: OId) {
        self.nodes[id].children.push(c);
    }

    /// Remove and return the last child edge.
    pub fn pop_child(&mut self, id: OId) -> Option<OId> {
        self.nodes[id].children.pop()
    }

    /// Recursive deep copy preserving internal sharing (each reachable node
    /// copied exactly once — the paper's §2.1 caveat).
    pub fn deep_copy(&mut self, root: OId) -> OId {
        let mut memo: HashMap<OId, OId> = HashMap::new();
        self.copy_rec(root, &mut memo)
    }

    fn copy_rec(&mut self, v: OId, memo: &mut HashMap<OId, OId>) -> OId {
        if let Some(&u) = memo.get(&v) {
            return u;
        }
        let u = self.alloc(self.nodes[v].value);
        memo.insert(v, u);
        let kids = self.nodes[v].children.clone();
        let copied: Vec<OId> = kids.into_iter().map(|c| self.copy_rec(c, memo)).collect();
        self.nodes[u].children = copied;
        u
    }

    /// Is `needle` reachable from `from`? (Used by fuzzers to avoid
    /// creating reference cycles, which reference counting cannot collect
    /// and the evaluation models do not create.)
    pub fn reachable(&self, from: OId, needle: OId) -> bool {
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![from];
        while let Some(v) = stack.pop() {
            if v == needle {
                return true;
            }
            if seen[v] {
                continue;
            }
            seen[v] = true;
            stack.extend_from_slice(&self.nodes[v].children);
        }
        false
    }

    /// Descend a child-index path from a root.
    pub fn descend(&self, root: OId, path: &[usize]) -> OId {
        let mut v = root;
        for &i in path {
            v = self.nodes[v].children[i];
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_mutate() {
        let mut o = Oracle::new();
        let a = o.alloc(1);
        let b = o.alloc(2);
        o.push_child(a, b);
        o.set_value(b, 20);
        assert_eq!(o.value(o.child(a, 0)), 20);
    }

    #[test]
    fn deep_copy_is_independent() {
        let mut o = Oracle::new();
        let a = o.alloc(1);
        let b = o.alloc(2);
        o.push_child(a, b);
        let c = o.deep_copy(a);
        o.set_value(o.child(c, 0), 99);
        assert_eq!(o.value(o.child(a, 0)), 2, "original untouched");
        assert_eq!(o.value(o.child(c, 0)), 99);
    }

    #[test]
    fn deep_copy_preserves_sharing() {
        let mut o = Oracle::new();
        let root = o.alloc(0);
        let shared = o.alloc(7);
        o.push_child(root, shared);
        o.push_child(root, shared);
        let c = o.deep_copy(root);
        assert_eq!(o.child(c, 0), o.child(c, 1), "diamond stays a diamond");
        assert_ne!(o.child(c, 0), shared);
    }

    #[test]
    fn reachability() {
        let mut o = Oracle::new();
        let a = o.alloc(0);
        let b = o.alloc(1);
        let c = o.alloc(2);
        o.push_child(a, b);
        o.push_child(b, c);
        assert!(o.reachable(a, c));
        assert!(!o.reachable(c, a));
        assert_eq!(o.descend(a, &[0, 0]), c);
    }
}
