//! Executable transliteration of the paper's §2 definitions.
//!
//! These small-graph structures exist to make the paper's semantics
//! testable in isolation: Algorithm 1 restores F from G (expanding lazy
//! copies on demand), Algorithm 2 restores G from H (expanding single edge
//! labels into label lists via the label tree `a`). The unit tests replay
//! Figure 4 and the Table 2 label-list argument.

use std::collections::HashMap;

/// Vertex id of the small formal graphs.
pub type V = usize;
/// Label id of the small formal graphs.
pub type L = usize;

/// An edge of G: source, target, and the label list `g(e)` — the deep-copy
/// operations the target is yet to be propagated through (Def. 2).
#[derive(Clone, Debug, PartialEq)]
pub struct GEdge {
    /// Source vertex `s(e)`.
    pub src: V,
    /// Target vertex `t(e)`.
    pub tgt: V,
    /// Label list `g(e)`, outermost copy first.
    pub labels: Vec<L>,
}

/// The labeled multigraph G = (V, E, s, t, b, R, L, m, f, g) (Def. 2),
/// with integer payloads standing in for `b`.
#[derive(Clone, Default)]
pub struct G {
    /// Payload data b(v).
    pub b: Vec<i64>,
    /// Read-only set R (indexed by vertex).
    pub read_only: Vec<bool>,
    /// Creating label f(v).
    pub f: Vec<L>,
    /// Edges (vertex 0 is the root; its out-edges are the global scope).
    pub edges: Vec<GEdge>,
    /// Memo m : V × L → V.
    pub memo: HashMap<(V, L), V>,
    /// Number of labels minted.
    pub n_labels: usize,
}

impl G {
    /// A graph with just the root vertex and root label.
    pub fn new() -> Self {
        let mut g = G::default();
        g.b.push(0); // root vertex
        g.read_only.push(false);
        g.f.push(0);
        g.n_labels = 1; // root label
        g
    }

    /// Add a vertex with payload `b(v)` and creating label `f(v)`.
    pub fn add_vertex(&mut self, payload: i64, label: L) -> V {
        self.b.push(payload);
        self.read_only.push(false);
        self.f.push(label);
        self.b.len() - 1
    }

    /// Add an edge with label list `g(e)`; returns its index.
    pub fn add_edge(&mut self, src: V, tgt: V, labels: Vec<L>) -> usize {
        self.edges.push(GEdge { src, tgt, labels });
        self.edges.len() - 1
    }

    /// Condition 1: every memoized vertex is read-only.
    pub fn check_condition1(&self) -> bool {
        self.memo.keys().all(|(v, _)| self.read_only[*v])
    }

    /// One step of **Algorithm 1** applied to edge `e`: let `v = t(e)` and
    /// `l = head g(e)`; redirect through the memo or copy `v`, then drop
    /// the head label. Returns the vertex the edge now targets.
    ///
    /// Precondition (checked by the caller in tests): `e` is reachable from
    /// the root through label-free edges.
    pub fn expand_edge(&mut self, e: usize) -> V {
        assert!(!self.edges[e].labels.is_empty(), "no labels to expand");
        let v = self.edges[e].tgt;
        let l = self.edges[e].labels[0];
        let u = if let Some(&u) = self.memo.get(&(v, l)) {
            u
        } else {
            // Copy v: payload and out-edges (a shallow copy in F terms).
            let u = self.add_vertex(self.b[v], l);
            let out: Vec<GEdge> = self
                .edges
                .iter()
                .filter(|d| d.src == v)
                .cloned()
                .collect();
            for mut d in out {
                d.src = u;
                self.edges.push(d);
            }
            self.memo.insert((v, l), u);
            self.read_only[v] = true; // Condition 1
            u
        };
        self.edges[e].tgt = u;
        self.edges[e].labels.remove(0);
        u
    }

    /// Apply Algorithm 1 until edge `e` has an empty label list (Condition
    /// 2: the target is then readable/writable).
    pub fn expand_fully(&mut self, e: usize) -> V {
        while !self.edges[e].labels.is_empty() {
            self.expand_edge(e);
        }
        self.edges[e].tgt
    }
}

/// An edge of H: a single label `h(e)` (Def. 3).
#[derive(Clone, Debug, PartialEq)]
pub struct HEdge {
    /// Source vertex `s(e)`.
    pub src: V,
    /// Target vertex `t(e)`.
    pub tgt: V,
    /// The single label `h(e)`.
    pub label: L,
}

/// The labeled multigraph H = (V, E, s, t, b, R, L, m, f, h, a) (Def. 3).
#[derive(Clone, Default)]
pub struct H {
    /// Payload data b(v).
    pub b: Vec<i64>,
    /// Read-only set R (indexed by vertex).
    pub read_only: Vec<bool>,
    /// Creating label f(v).
    pub f: Vec<L>,
    /// Edges (vertex 0 is the root).
    pub edges: Vec<HEdge>,
    /// Label tree: a(l) = parent of l (Def. 3); a[0] is the root label,
    /// represented as its own parent.
    pub a: Vec<L>,
}

impl H {
    /// A graph with just the root vertex and root label.
    pub fn new() -> Self {
        let mut h = H::default();
        h.b.push(0);
        h.read_only.push(false);
        h.f.push(0);
        h.a.push(0); // root label
        h
    }

    /// Add a vertex with payload `b(v)` and creating label `f(v)`.
    pub fn add_vertex(&mut self, payload: i64, label: L) -> V {
        self.b.push(payload);
        self.read_only.push(false);
        self.f.push(label);
        self.b.len() - 1
    }

    /// Mint a fresh label as a child of `parent` in the label tree `a`.
    pub fn new_label(&mut self, parent: L) -> L {
        self.a.push(parent);
        self.a.len() - 1
    }

    /// Add an edge with single label `h(e)`.
    pub fn add_edge(&mut self, src: V, tgt: V, label: L) {
        self.edges.push(HEdge { src, tgt, label });
    }

    /// Condition 3: for every edge there exists n ≥ 0 with
    /// aⁿ(h(e)) = f(t(e)).
    pub fn check_condition3(&self) -> bool {
        self.edges.iter().all(|e| self.label_chain(e).is_some())
    }

    /// The chain [aⁿ⁻¹(h(e)), …, a(h(e)), h(e)] of **Algorithm 2**, or
    /// `None` if Condition 3 fails (a cross reference not yet finished).
    pub fn label_chain(&self, e: &HEdge) -> Option<Vec<L>> {
        let target_label = self.f[e.tgt];
        let mut chain = Vec::new();
        let mut l = e.label;
        loop {
            if l == target_label {
                chain.reverse();
                return Some(chain);
            }
            chain.push(l);
            let parent = self.a[l];
            if parent == l {
                return None; // hit the root without matching
            }
            l = parent;
        }
    }

    /// **Algorithm 2**: restore G from H by expanding every single edge
    /// label into its label list.
    pub fn to_g(&self) -> G {
        let mut g = G::new();
        // Copy vertices 1.. (vertex 0 is the shared root convention).
        for v in 1..self.b.len() {
            let nv = g.add_vertex(self.b[v], self.f[v]);
            debug_assert_eq!(nv, v);
            g.read_only[v] = self.read_only[v];
        }
        g.n_labels = self.a.len();
        for e in &self.edges {
            let labels = self
                .label_chain(e)
                .expect("Condition 3 violated: unfinished cross reference");
            g.add_edge(e.src, e.tgt, labels);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Figure 4 (left series): a root -> x edge carrying labels [1] over a
    /// two-vertex chain; expansion copies x once and reuses the memo.
    #[test]
    fn algorithm1_expands_and_memoizes() {
        let mut g = G::new();
        let x = g.add_vertex(10, 0);
        let y = g.add_vertex(20, 0);
        g.add_edge(0, x, vec![1]); // root -> x, pending copy 1
        g.add_edge(x, y, vec![]); // x -> y within original
        g.n_labels = 2;

        let e0 = 0;
        let u = g.expand_fully(e0);
        assert_ne!(u, x, "x was copied");
        assert_eq!(g.b[u], 10);
        assert!(g.read_only[x], "original frozen (Condition 1)");
        assert!(g.check_condition1());
        // The copy's out-edge still targets y (shallow copy).
        let copied_edge = g.edges.iter().find(|d| d.src == u).unwrap();
        assert_eq!(copied_edge.tgt, y);

        // A second edge with the same pending label reuses the memo.
        let e2 = g.add_edge(0, x, vec![1]);
        let u2 = g.expand_fully(e2);
        assert_eq!(u2, u, "memo m(x, 1) reused");
    }

    /// Nested labels: an edge with list [1, 2] expands through two copies.
    #[test]
    fn algorithm1_nested_labels() {
        let mut g = G::new();
        let x = g.add_vertex(5, 0);
        let e = g.add_edge(0, x, vec![1, 2]);
        g.n_labels = 3;
        let u = g.expand_fully(e);
        // Two successive copies: x -> m(x,1) -> m(m(x,1),2).
        let u1 = g.memo[&(x, 1)];
        let u2 = g.memo[&(u1, 2)];
        assert_eq!(u, u2);
        assert_eq!(g.b[u], 5);
    }

    /// Algorithm 2 on a tree of labels: root label 0, children 1 and 2,
    /// grandchild 3 under 1.
    #[test]
    fn algorithm2_restores_label_lists() {
        let mut h = H::new();
        let l1 = h.new_label(0);
        let l2 = h.new_label(0);
        let l3 = h.new_label(l1);
        assert_eq!((l1, l2, l3), (1, 2, 3));

        let x = h.add_vertex(7, 0); // created under root label
        h.add_edge(0, x, l3); // edge label 3: chain 0 -> 1 -> 3
        h.add_edge(0, x, l2); // edge label 2: chain 0 -> 2
        h.add_edge(0, x, 0); // plain edge

        assert!(h.check_condition3());
        let g = h.to_g();
        assert_eq!(g.edges[0].labels, vec![l1, l3]);
        assert_eq!(g.edges[1].labels, vec![l2]);
        assert_eq!(g.edges[2].labels, Vec::<L>::new());
    }

    /// The Table 2 counterfactual: the edge x3 -> x1 with label 3 would
    /// imply list [2, 3] under G (wrong view); Condition 3 detects that the
    /// *correct* single-label encoding for the intended [3] view does not
    /// exist, which is why Algorithm 6 must finish cross references eagerly.
    #[test]
    fn table2_label_list_argument() {
        let mut h = H::new();
        let l2 = h.new_label(0);
        let l3 = h.new_label(l2);
        let x1 = h.add_vertex(1, 0);
        // x3 is the copy of x2 under label 3 (f = 3), its next field
        // pointing at x1 with edge label 3:
        let x3 = h.add_vertex(3, l3);
        h.add_edge(x3, x1, l3);
        let e = h.edges.last().unwrap();
        // Chain from label 3 back to f(x1) = 0 passes through 2: the list
        // is [2, 3], i.e. the x1 target would be propagated through copy 2
        // *then* 3 — the incorrect behaviour shown in Table 2's last row.
        assert_eq!(h.label_chain(e), Some(vec![l2, l3]));
        // The correct view required list [3] alone, which no single-label
        // edge can encode when a(3) = 2: hence the eager Finish.
        let g = h.to_g();
        assert_eq!(g.edges[0].labels, vec![l2, l3]);
    }

    /// Condition 3 violation: a cross reference whose label chain cannot
    /// reach f(t(e)).
    #[test]
    fn condition3_detects_unfinished_cross_reference() {
        let mut h = H::new();
        let l1 = h.new_label(0);
        let l2 = h.new_label(0); // sibling of l1, not ancestor
        let x = h.add_vertex(1, l1);
        h.add_edge(0, x, l2);
        assert!(!h.check_condition3());
    }
}
