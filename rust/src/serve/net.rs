//! TCP front-end for the serve engine: accept loop, connection workers,
//! and graceful drain.
//!
//! Thread shape: one non-blocking acceptor thread queues connections; a
//! small worker pool owns the per-connection line framing; the *engine
//! stays on the caller's thread*, consuming one request at a time from a
//! channel. That keeps the engine single-threaded (exact telemetry
//! attribution, no locks around the heap) while many clients stay
//! connected — a client's line is answered before the next queued line
//! from any client runs, and replies go only to the issuing connection.
//!
//! Shutdown: SIGTERM/SIGINT (or any client's `finish-all`) flips a
//! process-wide flag. Every loop polls it: the acceptor stops accepting,
//! workers tell their clients `err server draining` and hang up, and the
//! engine finishes every open session — reporting each final estimate on
//! the server's stdout — before `serve_tcp` returns. The listener socket
//! is closed on return, so a drained server can be restarted on the same
//! address immediately.

use super::engine::{error_reason, verb_label, ServeEngine, Verdict};
use super::metrics_http::MetricsHub;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Process-wide drain flag: set by the signal handlers and by
/// `finish-all`, polled by every loop. Reset at each `serve_tcp` entry
/// so a drained server can be restarted in-process.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Connection-handler threads. Each owns one connection at a time;
/// further connections wait in the accept queue. Engine work is serial
/// regardless, so more workers would only add idle connections.
const WORKERS: usize = 4;

/// Poll cadence for the accept loop and the shutdown checks.
const POLL: Duration = Duration::from_millis(50);

/// One protocol line from a connection, with the channel its reply lines
/// go back on.
struct Request {
    line: String,
    reply: Sender<Vec<String>>,
}

/// Flip [`SHUTDOWN`] on SIGTERM/SIGINT so every loop drains gracefully.
/// Raw `signal(2)` FFI — the crate is dependency-free — with a handler
/// that only performs an atomic store (async-signal-safe).
#[cfg(unix)]
fn install_signal_handlers() {
    type SigHandler = extern "C" fn(i32);
    extern "C" {
        fn signal(signum: i32, handler: SigHandler) -> isize;
    }
    extern "C" fn on_signal(_sig: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }
    unsafe {
        let _ = signal(2, on_signal); // SIGINT
        let _ = signal(15, on_signal); // SIGTERM
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// Serve the engine over TCP at `addr` (`host:port`). Blocks until a
/// client sends `finish-all` or the process receives SIGTERM/SIGINT,
/// then drains: every open session is finished and reported on stdout,
/// all threads join, and the listener closes (the address is immediately
/// reusable). Sessions are server-owned — a client disconnecting leaves
/// its sessions open for the next connection to pick up by name.
pub fn serve_tcp(engine: ServeEngine, addr: &str, hub: Arc<MetricsHub>) -> Result<(), String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
    serve_on(engine, listener, hub)
}

/// [`serve_tcp`] over an already-bound listener — bind to port 0 first
/// to serve on an OS-assigned port (the in-process route the tests
/// take). One serve loop at a time per process: the drain flag is
/// process-wide.
///
/// The `hub` is the observability side-channel: connections, executed
/// lines (verb/latency/error labels), and the draining gauge go in, and
/// the engine's metrics render is re-snapshotted after every executed
/// line so a concurrent `/metrics` scrape sees the latest completed
/// state. The hub is always maintained; whether a scrape responder is
/// actually listening is the caller's business (`--metrics-addr`).
pub fn serve_on(
    mut engine: ServeEngine,
    listener: TcpListener,
    hub: Arc<MetricsHub>,
) -> Result<(), String> {
    SHUTDOWN.store(false, Ordering::SeqCst);
    install_signal_handlers();
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("set_nonblocking: {e}"))?;
    let local = listener.local_addr().map_err(|e| e.to_string())?;
    println!("# listening on {local}");
    let banner = engine.banner();
    // Seed the scrape snapshot so a scrape before the first protocol
    // line sees the (empty-session, all-shard) baseline, not nothing.
    hub.set_engine_snapshot(engine.render_metrics());

    let (conn_tx, conn_rx) = channel::<TcpStream>();
    let conn_rx = Arc::new(Mutex::new(conn_rx));
    let (req_tx, req_rx) = channel::<Request>();

    let acceptor = std::thread::spawn(move || loop {
        if SHUTDOWN.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                if conn_tx.send(stream).is_err() {
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(POLL),
            Err(_) => std::thread::sleep(POLL),
        }
    });

    let mut workers = Vec::with_capacity(WORKERS);
    for _ in 0..WORKERS {
        let rx = Arc::clone(&conn_rx);
        let tx = req_tx.clone();
        let banner = banner.clone();
        let hub = Arc::clone(&hub);
        workers.push(std::thread::spawn(move || loop {
            // Take the lock only to wait for a connection, not while
            // serving one, so idle workers don't starve the busy ones.
            let conn = rx
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .recv_timeout(POLL);
            match conn {
                Ok(stream) => {
                    hub.note_connection();
                    handle_conn(stream, &tx, &banner, &hub)
                }
                Err(RecvTimeoutError::Timeout) => {
                    if SHUTDOWN.load(Ordering::SeqCst) {
                        return;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }));
    }
    // The engine loop must see Disconnected once every worker exits.
    drop(req_tx);

    let mut drained = false;
    loop {
        match req_rx.recv_timeout(POLL) {
            Ok(req) => {
                let verb = verb_label(&req.line);
                let t0 = Instant::now();
                let (lines, drain) = match engine.execute(&req.line) {
                    Verdict::Silent => (Vec::new(), false),
                    Verdict::Reply(l) => (l, false),
                    Verdict::Drain(l) => (l, true),
                };
                if verb != "comment" {
                    let reason = lines.last().and_then(|l| error_reason(l));
                    hub.note_request(verb, t0.elapsed().as_secs_f64(), reason);
                }
                hub.set_engine_snapshot(engine.render_metrics());
                // A send failure means the client hung up mid-reply;
                // the engine's state change stands either way.
                let _ = req.reply.send(lines);
                if drain {
                    SHUTDOWN.store(true, Ordering::SeqCst);
                    drained = true;
                    break;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if SHUTDOWN.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    SHUTDOWN.store(true, Ordering::SeqCst);
    hub.set_draining(true);
    // Unprocessed queued requests drop here; their reply channels close
    // and the owning workers answer `err server draining`.
    drop(req_rx);
    if !drained {
        // Signal-initiated (or accept-loop failure) drain: finish every
        // session on the server console.
        for line in engine.finish_all() {
            println!("{line}");
        }
    }
    // Final snapshot: a scrape during teardown sees zero sessions.
    hub.set_engine_snapshot(engine.render_metrics());
    println!("heap: {}", engine.heap_summary());
    for w in workers {
        let _ = w.join();
    }
    let _ = acceptor.join();
    Ok(())
}

/// Per-connection framing: read protocol lines, round-trip each through
/// the engine channel, write the reply lines back. Read timeouts poll
/// the shutdown flag; partial bytes accumulated before a timeout stay in
/// the buffer (`read_line` appends), so slow writers are never
/// corrupted. EOF just closes the connection — sessions are
/// server-owned and survive for the next connection to address by name.
fn handle_conn(stream: TcpStream, req_tx: &Sender<Request>, banner: &str, hub: &MetricsHub) {
    let mut writer = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    if stream.set_read_timeout(Some(POLL)).is_err() {
        return;
    }
    if writeln!(writer, "{banner}").is_err() {
        return;
    }
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    loop {
        match reader.read_line(&mut buf) {
            // EOF: drop any partial trailing command (no newline ever
            // arrived for it) and close.
            Ok(0) => return,
            Ok(_) => {
                // `read_line` returns Ok without a trailing newline only
                // at EOF: the client hung up mid-command, so the partial
                // line is dropped, never executed.
                if !buf.ends_with('\n') {
                    return;
                }
                let line = std::mem::take(&mut buf);
                let (tx, rx) = channel();
                let sent = req_tx.send(Request {
                    line: line.trim().to_string(),
                    reply: tx,
                });
                if sent.is_err() {
                    hub.note_error("draining");
                    let _ = writeln!(writer, "err server draining");
                    return;
                }
                match rx.recv() {
                    Ok(lines) => {
                        for l in lines {
                            if writeln!(writer, "{l}").is_err() {
                                return;
                            }
                        }
                    }
                    Err(_) => {
                        hub.note_error("draining");
                        let _ = writeln!(writer, "err server draining");
                        return;
                    }
                }
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted =>
            {
                // Timeout poll: partial bytes stay in `buf`.
                if SHUTDOWN.load(Ordering::SeqCst) {
                    hub.note_error("draining");
                    let _ = writeln!(writer, "err server draining");
                    return;
                }
            }
            Err(_) => return,
        }
    }
}
