//! The transport-agnostic serve core: named sessions over one shared
//! sharded heap, one protocol line in → reply lines out.
//!
//! A [`ServeEngine`] is single-threaded by construction: whichever
//! front-end drives it (the stdin loop or the TCP request loop) calls
//! [`execute`](ServeEngine::execute) one line at a time, so sessions on
//! the shared shards run serially and the per-session telemetry
//! attribution stays exact (see [`crate::telemetry`]). Parallelism lives
//! *inside* a step — the engine's thread pool propagates shards
//! concurrently — not across protocol lines.
//!
//! Error handling is the protocol's, not the process's: every malformed
//! or unknown line becomes an `err ...` reply and the engine stays
//! consistent (a failed `open` opens nothing, a failed `obs` leaves the
//! session exactly as it was — observations are validated before any
//! state changes).

use crate::config::{Model, RunConfig, Task};
use crate::heap::{Heap, HeapMetrics, ShardedHeap};
use crate::models::{Crbd, ListModel, Mot, Pcfg, Rbpf, Vbd};
use crate::pool::ThreadPool;
use crate::runtime::BatchKalman;
use crate::smc::{FilterResult, FilterSession, Method, SmcModel, StepCtx};
use crate::telemetry::{self, Registry};
use std::collections::BTreeMap;

/// The filter method each model is served with — the same pairing the
/// batch dispatcher ([`run_model`](crate::models::run_model)) uses for
/// §4: auxiliary for PCFG, alive for CRBD, bootstrap elsewhere. VBD is
/// served with the forward bootstrap filter: particle Gibbs is an
/// offline multi-pass scheme, and the streaming surface is the filter.
pub fn serve_method(model: Model) -> Method {
    match model {
        Model::Pcfg => Method::Auxiliary,
        Model::Crbd => Method::Alive,
        _ => Method::Bootstrap,
    }
}

fn method_name(m: Method) -> &'static str {
    match m {
        Method::Bootstrap => "bootstrap",
        Method::Auxiliary => "auxiliary",
        Method::Alive => "alive",
    }
}

/// Outcome of executing one protocol line.
pub enum Verdict {
    /// Blank line or `#` comment: nothing to send.
    Silent,
    /// Reply lines for the issuing client (the last one always starts
    /// with `ok ` or `err `).
    Reply(Vec<String>),
    /// `finish-all`: reply lines, after which the front-end should stop
    /// accepting input and shut down.
    Drain(Vec<String>),
}

fn err(msg: impl Into<String>) -> Verdict {
    Verdict::Reply(vec![format!("err {}", msg.into())])
}

/// One `obs` ingest: the generation stepped and the running estimates.
struct ObsReport {
    t: usize,
    ess: f64,
    log_evidence: f64,
    posterior_mean: f64,
}

/// Object-safe adapter erasing the model type of one named session, so
/// the engine can hold sessions over different models in one map. Each
/// method mirrors a protocol verb.
trait Servable {
    fn model_name(&self) -> &'static str;
    /// Generations completed so far.
    fn generations(&self) -> usize;
    /// Ingest one observation (already tokenized) and step a generation.
    /// Tokens are validated before the session or model mutates.
    fn obs(
        &mut self,
        shards: &mut [Heap],
        ctx: &StepCtx,
        tokens: &[&str],
    ) -> Result<ObsReport, String>;
    /// Speculative query: clone the model, stage all observation groups
    /// (validated before anything forks), fork the population lazily,
    /// step it through the groups, finish the fork. The live session is
    /// untouched.
    fn whatif(
        &mut self,
        shards: &mut [Heap],
        ctx: &StepCtx,
        groups: &[Vec<&str>],
    ) -> Result<(usize, FilterResult), String>;
    /// Fork into an independent named session over the same shards.
    fn fork(&mut self, shards: &mut [Heap]) -> Box<dyn Servable>;
    /// Render the session's telemetry registry.
    fn telemetry(&self) -> String;
    /// Borrow the session's telemetry registry — the `/metrics` scrape
    /// merges it under `{session,model}` labels.
    fn registry(&self) -> &Registry;
    /// Final reduction; releases the population.
    fn finish(self: Box<Self>, shards: &mut [Heap]) -> FilterResult;
    /// Abandon without a result; releases the population.
    fn close(self: Box<Self>, shards: &mut [Heap]);
}

/// The one generic impl behind every servable model: the model value
/// (owning the growing observation stream) plus its filter session.
struct ModelSession<M: SmcModel> {
    model: M,
    session: FilterSession<M::State>,
}

impl<M> Servable for ModelSession<M>
where
    M: SmcModel + Clone + Sync + 'static,
{
    fn model_name(&self) -> &'static str {
        self.model.name()
    }

    fn generations(&self) -> usize {
        self.session.next_generation() - 1
    }

    fn obs(
        &mut self,
        shards: &mut [Heap],
        ctx: &StepCtx,
        tokens: &[&str],
    ) -> Result<ObsReport, String> {
        // stream_observation validates every token before mutating, so a
        // rejected line leaves model and session untouched.
        self.model.stream_observation(tokens)?;
        let m = self.session.step(&self.model, shards, ctx);
        Ok(ObsReport {
            t: m.t,
            ess: m.ess,
            log_evidence: self.session.evidence_estimate(),
            posterior_mean: self.session.posterior_estimate(&self.model, shards),
        })
    }

    fn whatif(
        &mut self,
        shards: &mut [Heap],
        ctx: &StepCtx,
        groups: &[Vec<&str>],
    ) -> Result<(usize, FilterResult), String> {
        let mut what_model = self.model.clone();
        // Stage (and validate) every group before forking: a bad token
        // costs nothing, not an abandoned fork.
        for g in groups {
            what_model.stream_observation(g)?;
        }
        let mut fork = self.session.fork(shards);
        for _ in 0..groups.len() {
            fork.step(&what_model, shards, ctx);
        }
        let r = fork.finish(&what_model, shards);
        Ok((groups.len(), r))
    }

    fn fork(&mut self, shards: &mut [Heap]) -> Box<dyn Servable> {
        Box::new(ModelSession {
            model: self.model.clone(),
            session: self.session.fork(shards),
        })
    }

    fn telemetry(&self) -> String {
        self.session.telemetry().render()
    }

    fn registry(&self) -> &Registry {
        self.session.telemetry()
    }

    fn finish(self: Box<Self>, shards: &mut [Heap]) -> FilterResult {
        let ModelSession { model, session } = *self;
        session.finish(&model, shards)
    }

    fn close(self: Box<Self>, shards: &mut [Heap]) {
        let ModelSession { session, .. } = *self;
        session.abandon(shards);
    }
}

/// Open a streaming session for `model`: the model's empty streaming
/// constructor paired with its serve method. The session's trace spans
/// (if `--trace` is live in the template config) are labeled with the
/// protocol `name` so one JSONL file disentangles interleaved sessions.
fn open_session(
    name: &str,
    model: Model,
    cfg: &RunConfig,
    shards: &mut [Heap],
    ctx: &StepCtx,
) -> Box<dyn Servable> {
    fn boxed<M>(
        name: &str,
        model: M,
        cfg: &RunConfig,
        shards: &mut [Heap],
        ctx: &StepCtx,
        m: Method,
    ) -> Box<dyn Servable>
    where
        M: SmcModel + Clone + Sync + 'static,
    {
        let mut session = FilterSession::begin(&model, cfg, shards, ctx, m);
        session.trace_label(name);
        Box::new(ModelSession { model, session })
    }
    let m = serve_method(model);
    match model {
        Model::Rbpf => boxed(name, Rbpf::streaming(), cfg, shards, ctx, m),
        Model::Pcfg => boxed(name, Pcfg::streaming(), cfg, shards, ctx, m),
        Model::Vbd => boxed(name, Vbd::streaming(), cfg, shards, ctx, m),
        Model::Mot => boxed(name, Mot::streaming(), cfg, shards, ctx, m),
        Model::Crbd => boxed(name, Crbd::streaming(), cfg, shards, ctx, m),
        Model::List => boxed(name, ListModel::streaming(), cfg, shards, ctx, m),
    }
}

/// Format a wall-clock duration as the stable `wall=<s>` reply token.
///
/// Every serve reply that reports elapsed time goes through this one
/// helper and keeps the token last on its line, so CI strips the only
/// nondeterministic field with a single `sed 's/ wall=[^ ]*//'`.
pub fn fmt_wall(s: f64) -> String {
    format!("wall={s:.3}")
}

fn finish_line(name: &str, model: &'static str, r: &FilterResult) -> String {
    format!(
        "ok finish {name} model={model} steps={} log_evidence={:.4} posterior_mean={:.4} {}",
        r.series.len(),
        r.log_evidence,
        r.posterior_mean,
        fmt_wall(r.wall_s)
    )
}

/// The `{verb=..}` label for [`telemetry::SERVE_REQUESTS_TOTAL`]: the
/// line's first token when it is a known protocol verb, `"other"`
/// otherwise — label cardinality is bounded by this fixed list, never by
/// client input. Blank and `#` comment lines map to `"comment"` (the
/// front-ends do not count them).
pub fn verb_label(line: &str) -> &'static str {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return "comment";
    }
    match line.split_whitespace().next().unwrap_or("") {
        "open" => "open",
        "obs" => "obs",
        "whatif" => "whatif",
        "fork" => "fork",
        "telemetry" => "telemetry",
        "finish" => "finish",
        "close" => "close",
        "finish-all" => "finish-all",
        _ => "other",
    }
}

/// Classify a reply line into the `{reason=..}` label for
/// [`telemetry::SERVE_ERRORS_TOTAL`], or `None` for non-error replies.
/// Reasons come from this fixed list (bounded cardinality); anything
/// unrecognized is `"bad-input"`, the catch-all for model/option
/// validation errors.
pub fn error_reason(reply: &str) -> Option<&'static str> {
    let msg = reply.strip_prefix("err ")?;
    Some(if msg.starts_with("unknown command") {
        "unknown-verb"
    } else if msg.starts_with("no open session") {
        "no-session"
    } else if msg.starts_with("session '") {
        "name-taken"
    } else if msg.starts_with("usage:") {
        "usage"
    } else if msg.starts_with("server draining") {
        "draining"
    } else {
        "bad-input"
    })
}

/// The serve core: one shared [`ShardedHeap`], one thread pool, and a
/// map of named sessions, driven one protocol line at a time by a
/// front-end (stdin loop or TCP request loop).
///
/// The heap's shard count is fixed at construction from the template
/// config (`--shards 0` matches the worker threads) and shared by every
/// session; per-session `open` options may override particles, seed, and
/// the ESS threshold, everything else (mode, allocator, rebalance
/// policy, ...) comes from the template.
pub struct ServeEngine {
    template: RunConfig,
    pool: ThreadPool,
    kalman: Option<BatchKalman>,
    heap: ShardedHeap,
    sessions: BTreeMap<String, Box<dyn Servable>>,
}

impl ServeEngine {
    /// Build an engine from the launch configuration plus the numeric
    /// backend (thread pool and optional compiled Kalman kernel).
    pub fn new(template: RunConfig, pool: ThreadPool, kalman: Option<BatchKalman>) -> Self {
        let k = template.resolved_shards(pool.n_threads());
        let heap = ShardedHeap::with_allocator(template.mode, k, template.allocator);
        ServeEngine {
            template,
            pool,
            kalman,
            heap,
            sessions: BTreeMap::new(),
        }
    }

    /// The greeting line a front-end prints/sends on startup: the shared
    /// engine parameters and a verb cheat-sheet.
    pub fn banner(&self) -> String {
        format!(
            "# lazycow serve K={} mode={} allocator={} — open <name> <model> [particles=N \
             seed=S ess=X] | obs <name> <tokens> | whatif <name> <tokens>[; <tokens>] | \
             fork <name> <new> | telemetry <name> | finish <name> | close <name> | finish-all",
            self.heap.k(),
            self.template.mode.name(),
            self.template.allocator.name()
        )
    }

    /// Execute one protocol line. Never panics on input: malformed or
    /// unknown lines produce an `err ...` reply and leave every session
    /// untouched.
    pub fn execute(&mut self, line: &str) -> Verdict {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Verdict::Silent;
        }
        let (verb, rest) = match line.split_once(char::is_whitespace) {
            Some((v, r)) => (v, r.trim()),
            None => (line, ""),
        };
        match verb {
            "open" => self.cmd_open(rest),
            "obs" => self.cmd_obs(rest),
            "whatif" => self.cmd_whatif(rest),
            "fork" => self.cmd_fork(rest),
            "telemetry" => self.cmd_telemetry(rest),
            "finish" => self.cmd_finish(rest),
            "close" => self.cmd_close(rest),
            "finish-all" => Verdict::Drain(self.finish_all()),
            _ => err(format!(
                "unknown command '{verb}' (open|obs|whatif|fork|telemetry|finish|close|finish-all)"
            )),
        }
    }

    fn ctx<'a>(pool: &'a ThreadPool, kalman: Option<&'a BatchKalman>) -> StepCtx<'a> {
        StepCtx {
            pool,
            kalman,
            batch: true,
        }
    }

    fn cmd_open(&mut self, rest: &str) -> Verdict {
        let mut it = rest.split_whitespace();
        let (Some(name), Some(model_s)) = (it.next(), it.next()) else {
            return err("usage: open <name> <model> [particles=N] [seed=S] [ess=X]");
        };
        if self.sessions.contains_key(name) {
            return err(format!("session '{name}' already open"));
        }
        let Some(model) = Model::parse(model_s) else {
            return err(format!("unknown model '{model_s}' (rbpf|pcfg|vbd|mot|crbd|list)"));
        };
        let mut cfg = self.template.clone();
        cfg.model = model;
        cfg.task = Task::Inference;
        cfg.shards = self.heap.k();
        for opt in it {
            let Some((key, value)) = opt.split_once('=') else {
                return err(format!("bad open option '{opt}' (expected key=value)"));
            };
            if !matches!(key, "particles" | "n" | "seed" | "ess") {
                return err(format!("unknown open option '{key}' (particles|seed|ess)"));
            }
            if let Err(e) = cfg.apply(key, value) {
                return err(e);
            }
        }
        if cfg.n_particles == 0 {
            return err("particles must be >= 1");
        }
        let ctx = Self::ctx(&self.pool, self.kalman.as_ref());
        let sess = open_session(name, model, &cfg, self.heap.shards_mut(), &ctx);
        let reply = format!(
            "ok open {name} model={} method={} n={} seed={}",
            model.name(),
            method_name(serve_method(model)),
            cfg.n_particles,
            cfg.seed
        );
        self.sessions.insert(name.to_string(), sess);
        Verdict::Reply(vec![reply])
    }

    fn cmd_obs(&mut self, rest: &str) -> Verdict {
        let mut it = rest.split_whitespace();
        let Some(name) = it.next() else {
            return err("usage: obs <name> <tokens...>");
        };
        let tokens: Vec<&str> = it.collect();
        let Some(sess) = self.sessions.get_mut(name) else {
            return err(format!("no open session '{name}'"));
        };
        let ctx = Self::ctx(&self.pool, self.kalman.as_ref());
        match sess.obs(self.heap.shards_mut(), &ctx, &tokens) {
            Ok(r) => Verdict::Reply(vec![format!(
                "ok obs {name} t={} ess={:.1} log_evidence={:.4} posterior_mean={:.4}",
                r.t, r.ess, r.log_evidence, r.posterior_mean
            )]),
            Err(e) => err(e),
        }
    }

    fn cmd_whatif(&mut self, rest: &str) -> Verdict {
        let (name, spec) = match rest.split_once(char::is_whitespace) {
            Some((n, s)) => (n, s.trim()),
            None => (rest, ""),
        };
        if name.is_empty() || spec.is_empty() {
            return err("usage: whatif <name> <tokens>[; <tokens>...]");
        }
        let groups: Vec<Vec<&str>> = spec
            .split(';')
            .map(|g| g.split_whitespace().collect())
            .collect();
        let Some(sess) = self.sessions.get_mut(name) else {
            return err(format!("no open session '{name}'"));
        };
        let ctx = Self::ctx(&self.pool, self.kalman.as_ref());
        match sess.whatif(self.heap.shards_mut(), &ctx, &groups) {
            Ok((h, r)) => Verdict::Reply(vec![format!(
                "ok whatif {name} horizon=+{h} log_evidence={:.4} posterior_mean={:.4}",
                r.log_evidence, r.posterior_mean
            )]),
            Err(e) => err(e),
        }
    }

    fn cmd_fork(&mut self, rest: &str) -> Verdict {
        let mut it = rest.split_whitespace();
        let (Some(name), Some(new), None) = (it.next(), it.next(), it.next()) else {
            return err("usage: fork <name> <newname>");
        };
        if self.sessions.contains_key(new) {
            return err(format!("session '{new}' already open"));
        }
        let Some(sess) = self.sessions.get_mut(name) else {
            return err(format!("no open session '{name}'"));
        };
        let forked = sess.fork(self.heap.shards_mut());
        let reply = format!(
            "ok fork {name} {new} model={} t={}",
            forked.model_name(),
            forked.generations()
        );
        self.sessions.insert(new.to_string(), forked);
        Verdict::Reply(vec![reply])
    }

    fn cmd_telemetry(&mut self, rest: &str) -> Verdict {
        let name = rest.trim();
        if name.is_empty() || name.contains(char::is_whitespace) {
            return err("usage: telemetry <name>");
        }
        let Some(sess) = self.sessions.get(name) else {
            return err(format!("no open session '{name}'"));
        };
        let mut lines: Vec<String> = sess.telemetry().lines().map(str::to_string).collect();
        lines.push(format!("ok telemetry {name}"));
        Verdict::Reply(lines)
    }

    fn cmd_finish(&mut self, rest: &str) -> Verdict {
        let name = rest.trim();
        if name.is_empty() || name.contains(char::is_whitespace) {
            return err("usage: finish <name>");
        }
        let Some(sess) = self.sessions.remove(name) else {
            return err(format!("no open session '{name}'"));
        };
        let model = sess.model_name();
        let r = sess.finish(self.heap.shards_mut());
        Verdict::Reply(vec![finish_line(name, model, &r)])
    }

    fn cmd_close(&mut self, rest: &str) -> Verdict {
        let name = rest.trim();
        if name.is_empty() || name.contains(char::is_whitespace) {
            return err("usage: close <name>");
        }
        let Some(sess) = self.sessions.remove(name) else {
            return err(format!("no open session '{name}'"));
        };
        sess.close(self.heap.shards_mut());
        Verdict::Reply(vec![format!("ok close {name}")])
    }

    /// Finish every open session in name order, reporting each final
    /// estimate — the `finish-all` verb, and the drain path every
    /// front-end runs on EOF or SIGTERM/SIGINT.
    pub fn finish_all(&mut self) -> Vec<String> {
        let sessions = std::mem::take(&mut self.sessions);
        let n = sessions.len();
        let mut out = Vec::with_capacity(n + 1);
        for (name, sess) in sessions {
            let model = sess.model_name();
            let r = sess.finish(self.heap.shards_mut());
            out.push(finish_line(&name, model, &r));
        }
        out.push(format!("ok finish-all sessions={n}"));
        out
    }

    /// Open sessions right now.
    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// Shards in the shared heap.
    pub fn shard_count(&self) -> usize {
        self.heap.k()
    }

    /// Live objects across the shared shards (0 once every session is
    /// finished or closed).
    pub fn live_objects(&self) -> usize {
        self.heap.live_objects()
    }

    /// Aggregate metrics of the shared shards.
    pub fn heap_metrics(&self) -> HeapMetrics {
        self.heap.metrics()
    }

    /// One-line aggregate heap summary (the front-ends print it on
    /// shutdown).
    pub fn heap_summary(&self) -> String {
        self.heap.metrics().summary()
    }

    /// Render the engine's fragment of the `/metrics` exposition: every
    /// open session's registry merged under `{session,model}` labels
    /// (sessions iterate in `BTreeMap` name order, so renders are
    /// deterministic for a given engine state) plus per-shard residency
    /// gauges labeled `{shard="k"}` from the shared heap.
    ///
    /// A fresh [`Registry`] is rebuilt per call — sessions keep sole
    /// ownership of their live registries, and a session that finishes
    /// or closes simply stops appearing in the next render.
    pub fn render_metrics(&self) -> String {
        let mut reg = Registry::new();
        for (name, sess) in &self.sessions {
            reg.merge_labeled(
                sess.registry(),
                &[("session", name.as_str()), ("model", sess.model_name())],
            );
        }
        for (s, shard) in self.heap.shards().iter().enumerate() {
            let idx = s.to_string();
            let labels: [(&'static str, &str); 1] = [("shard", idx.as_str())];
            let m = &shard.metrics;
            reg.set_gauge_with(telemetry::SHARD_LIVE_BYTES, &labels, m.live_bytes as f64);
            reg.set_gauge_with(telemetry::SHARD_LIVE_OBJECTS, &labels, m.live_objects as f64);
            reg.set_gauge_with(
                telemetry::SHARD_COMMITTED_BYTES,
                &labels,
                m.slab_committed_bytes as f64,
            );
        }
        reg.render()
    }
}
