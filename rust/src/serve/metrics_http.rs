//! Prometheus scrape endpoint for the serve front-ends
//! (`--metrics-addr host:port`): a dependency-free HTTP/1.1 responder
//! answering `GET /metrics` with the text exposition format 0.0.4.
//!
//! The split mirrors the protocol front-end in [`super::net`]: a
//! [`MetricsHub`] is the shared state, the responder thread is a
//! non-blocking accept loop polling a stop flag. The hub holds two
//! halves of the exposition:
//!
//! - the **serve-level registry** (connections, requests by verb, error
//!   replies by reason, request latency histogram, draining gauge),
//!   updated by the front-end threads through `note_*` calls; and
//! - the **engine snapshot**: the engine loop re-renders
//!   [`ServeEngine::render_metrics`](super::ServeEngine::render_metrics)
//!   after every executed protocol line and stores the string here, so a
//!   scrape never touches the engine (no lock around the heap, no
//!   blocking behind a long `obs` step — a scrape returns the state as
//!   of the last completed line, which is the only consistent state a
//!   single-threaded engine has to offer).
//!
//! The two halves render disjoint metric families (`serve_*` vs the
//! session/heap/shard names), so concatenating them is a spec-valid
//! exposition with one `# HELP`/`# TYPE` header per family. Scrape
//! connections are deliberately *not* counted in
//! `serve_connections_total` — that counter tracks protocol clients, and
//! a monitoring fleet polling `/metrics` every few seconds would drown
//! the signal.

use crate::telemetry::{self, Registry};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Poll cadence for the responder's non-blocking accept loop and its
/// stop checks (matches the protocol front-end's).
const POLL: Duration = Duration::from_millis(50);

/// Shared observability state between the protocol front-ends, the
/// engine loop, and the `/metrics` responder thread. Cheap to share
/// (`Arc`), internally locked; every lock section is a few metric
/// updates or a snapshot swap — never engine work.
pub struct MetricsHub {
    /// Serve-level metrics owned by the front-ends.
    serve: Mutex<Registry>,
    /// Latest engine render (sessions + shard gauges).
    engine: Mutex<String>,
    /// Tells the responder thread to exit its accept loop.
    stop: AtomicBool,
}

impl MetricsHub {
    /// A fresh hub with the draining gauge pre-registered at 0, so the
    /// gauge is present from the very first scrape.
    pub fn new() -> Arc<MetricsHub> {
        let mut serve = Registry::new();
        serve.set_gauge(telemetry::SERVE_DRAINING, 0.0);
        Arc::new(MetricsHub {
            serve: Mutex::new(serve),
            engine: Mutex::new(String::new()),
            stop: AtomicBool::new(false),
        })
    }

    fn serve_reg(&self) -> std::sync::MutexGuard<'_, Registry> {
        self.serve.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Count one accepted protocol (line-protocol, not scrape) connection.
    pub fn note_connection(&self) {
        self.serve_reg().inc(telemetry::SERVE_CONNECTIONS_TOTAL, 1);
    }

    /// Count one executed protocol line: the verb-labeled request
    /// counter, the latency histogram, and — when the reply was an
    /// error — the reason-labeled error counter. `verb` and `reason`
    /// come from [`verb_label`](super::verb_label) /
    /// [`error_reason`](super::error_reason), so label cardinality stays
    /// bounded.
    pub fn note_request(&self, verb: &'static str, dur_s: f64, reason: Option<&'static str>) {
        let mut reg = self.serve_reg();
        reg.inc_with(telemetry::SERVE_REQUESTS_TOTAL, &[("verb", verb)], 1);
        reg.observe(telemetry::SERVE_REQUEST_SECONDS, dur_s);
        if let Some(reason) = reason {
            reg.inc_with(telemetry::SERVE_ERRORS_TOTAL, &[("reason", reason)], 1);
        }
    }

    /// Count one error reply issued outside the engine — the connection
    /// workers' `err server draining` hang-up lines, which never pass
    /// through [`note_request`](MetricsHub::note_request).
    pub fn note_error(&self, reason: &'static str) {
        self.serve_reg()
            .inc_with(telemetry::SERVE_ERRORS_TOTAL, &[("reason", reason)], 1);
    }

    /// Flip the `serve_draining` gauge (1 while sessions are being
    /// finished after `finish-all`/SIGTERM/SIGINT).
    pub fn set_draining(&self, on: bool) {
        self.serve_reg()
            .set_gauge(telemetry::SERVE_DRAINING, if on { 1.0 } else { 0.0 });
    }

    /// Store the engine's latest exposition fragment (called by the
    /// engine loop after each executed line).
    pub fn set_engine_snapshot(&self, rendered: String) {
        *self.engine.lock().unwrap_or_else(|e| e.into_inner()) = rendered;
    }

    /// The full `/metrics` body: serve-level registry render followed by
    /// the engine snapshot. The two halves use disjoint family names, so
    /// the concatenation keeps one header per family.
    pub fn scrape(&self) -> String {
        let mut out = self.serve_reg().render();
        out.push_str(&self.engine.lock().unwrap_or_else(|e| e.into_inner()));
        out
    }

    /// Ask the responder thread to exit (join its handle afterwards).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

/// Bind `addr` and spawn the `/metrics` responder thread over `hub`.
/// Returns the join handle; the thread exits after
/// [`MetricsHub::shutdown`]. Binding errors are reported here, before
/// any thread exists, so a bad `--metrics-addr` fails fast at startup.
pub fn spawn_metrics(hub: Arc<MetricsHub>, addr: &str) -> Result<JoinHandle<()>, String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("bind metrics {addr}: {e}"))?;
    serve_metrics_on(hub, listener)
}

/// [`spawn_metrics`] over an already-bound listener (bind port 0 first
/// for an OS-assigned port — the route the tests take). Prints the
/// resolved address as a `# metrics on ...` console line.
pub fn serve_metrics_on(
    hub: Arc<MetricsHub>,
    listener: TcpListener,
) -> Result<JoinHandle<()>, String> {
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("metrics set_nonblocking: {e}"))?;
    let local = listener.local_addr().map_err(|e| e.to_string())?;
    println!("# metrics on http://{local}/metrics");
    Ok(std::thread::spawn(move || loop {
        if hub.stopped() {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => handle_scrape(stream, &hub),
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(POLL),
            Err(_) => std::thread::sleep(POLL),
        }
    }))
}

/// Answer one HTTP connection: parse the request line, serve
/// `GET /metrics` (200, `text/plain; version=0.0.4`), 404 any other
/// path, 405 any other method. Always `Connection: close` — scrapers
/// reconnect per poll, and one-shot connections keep the responder a
/// single accept loop with no keep-alive bookkeeping.
fn handle_scrape(stream: TcpStream, hub: &MetricsHub) {
    // A stuck scraper must not wedge the responder thread.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
    let mut reader = BufReader::new(stream);
    let mut request = String::new();
    if reader.read_line(&mut request).is_err() {
        return;
    }
    // Drain the header block so the peer never sees a reset while still
    // sending; tolerate EOF/timeout mid-headers.
    let mut header = String::new();
    loop {
        header.clear();
        match reader.read_line(&mut header) {
            Ok(0) => break,
            Ok(_) if header == "\r\n" || header == "\n" => break,
            Ok(_) => continue,
            Err(_) => break,
        }
    }
    let mut stream = reader.into_inner();
    let mut parts = request.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let (status, body) = if method != "GET" {
        ("405 Method Not Allowed", String::from("method not allowed\n"))
    } else if path != "/metrics" {
        ("404 Not Found", String::from("not found; try /metrics\n"))
    } else {
        ("200 OK", hub.scrape())
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.flush();
}
