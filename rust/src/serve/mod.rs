//! The inference server: many named [`FilterSession`]s multiplexed over
//! one shared [`ShardedHeap`], driven by a line protocol.
//!
//! The paper's pitch for the lazy-copy platform is *serving*: a
//! long-running population that ingests observations as they arrive and
//! answers speculative what-if queries by forking itself in O(particles)
//! (Murray 2020, §5). This module is that serving surface, split into
//! two layers:
//!
//! - [`engine`] — the transport-agnostic core. A [`ServeEngine`] owns
//!   the shared sharded heap, the worker thread pool, and a name →
//!   session map; [`ServeEngine::execute`] runs one protocol line
//!   (`open` / `obs` / `whatif` / `fork` / `telemetry` / `finish` /
//!   `close` / `finish-all`) and returns the reply lines. Malformed and
//!   unknown input produces structured `err ...` replies — a protocol
//!   line can never panic or kill the server.
//! - [`net`] — the TCP front-end (`--listen addr:port`): a non-blocking
//!   accept loop feeding a small worker pool, per-connection line
//!   framing, and a graceful drain on SIGTERM/SIGINT or a client's
//!   `finish-all` (every open session is finished and reported before
//!   exit). The stdin front-end lives in the binary and drives the same
//!   engine, so both transports speak byte-identical protocol.
//! - [`metrics_http`] — the Prometheus scrape endpoint
//!   (`--metrics-addr host:port`): a [`MetricsHub`] shared by the
//!   front-ends collects serve-level counters (connections, requests by
//!   verb, errors by reason, request latency, draining) and caches the
//!   engine's per-session/per-shard exposition fragment after every
//!   executed line; a dependency-free HTTP/1.1 responder thread answers
//!   `GET /metrics` from the hub without ever touching the engine.
//!
//! Every model is servable: `open <name> <model>` pairs the model's
//! empty streaming constructor with its §4 filter method (auxiliary for
//! PCFG, alive for CRBD, bootstrap elsewhere), and each `obs` line feeds
//! [`SmcModel::stream_observation`](crate::smc::SmcModel::stream_observation)
//! before stepping one generation. Because every random draw is keyed by
//! `(seed, generation, global index)`, a session's replies are
//! bit-identical to the equivalent batch run no matter how sessions
//! interleave on the shared heap — the contract the `serve` tests and CI
//! smoke pin.
//!
//! Protocol reference: `DESIGN.md` ("Serving: the network protocol").
//!
//! [`FilterSession`]: crate::smc::FilterSession
//! [`ShardedHeap`]: crate::heap::ShardedHeap

pub mod engine;
pub mod metrics_http;
pub mod net;

pub use engine::{error_reason, fmt_wall, serve_method, verb_label, ServeEngine, Verdict};
pub use metrics_http::{serve_metrics_on, spawn_metrics, MetricsHub};
pub use net::{serve_on, serve_tcp};
