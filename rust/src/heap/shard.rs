//! Sharded lazy-copy heap: K independent [`Heap`]s with a contiguous
//! particle partition.
//!
//! The single-heap platform serializes all heap mutation behind `&mut
//! Heap`. Sharding removes that bottleneck without introducing locks or
//! atomics: each worker thread receives `&mut` to exactly one shard, so
//! the allocate/copy/mutate hot path of particle propagation runs fully
//! parallel. The only cross-shard traffic is the lineage transplant at
//! resampling ([`Heap::extract_into`]), performed serially by the
//! coordinator, and it is the *exception*: systematic resampling keeps
//! most offspring on their ancestor's shard, where the O(1) lazy
//! [`Heap::deep_copy`](Heap::deep_copy) applies unchanged.
//!
//! Partitioning is contiguous and balanced: with `n` particles over `k`
//! shards, the first `n % k` shards hold `n/k + 1` particles and the rest
//! hold `n/k`. With `k = 1` everything degenerates to the single-heap
//! platform, which the seeded-equivalence tests pin down bit-for-bit.

use super::metrics::HeapMetrics;
use super::{AllocatorKind, CopyMode, Heap};
use std::ops::Range;

/// Contiguous balanced partition of `0..n` into `k` ranges (some possibly
/// empty when `k > n`).
pub fn shard_ranges(n: usize, k: usize) -> Vec<Range<usize>> {
    assert!(k > 0, "at least one shard");
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for s in 0..k {
        let len = base + usize::from(s < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Shard owning global particle index `i` under the [`shard_ranges`]
/// partition of `n` particles over `k` shards.
pub fn shard_of(n: usize, k: usize, i: usize) -> usize {
    debug_assert!(i < n, "particle index {i} out of range 0..{n}");
    let base = n / k;
    let rem = n % k;
    let cut = rem * (base + 1);
    if i < cut {
        i / (base + 1)
    } else {
        rem + (i - cut) / base.max(1)
    }
}

/// Aggregate heap counters over any shard slice — shared by
/// [`ShardedHeap::metrics`] and the SMC engine's per-generation
/// snapshots (see [`HeapMetrics::merge`] for the peak-bytes caveat).
pub fn aggregate_metrics(shards: &[Heap]) -> HeapMetrics {
    let mut m = HeapMetrics::default();
    for h in shards {
        m.merge(&h.metrics);
    }
    m
}

/// Decommit barrier over a shard slice (see [`Heap::trim`]): return
/// fully-empty slab chunks beyond `keep` per size class, per shard, to
/// the system allocator. The SMC engine calls this at generation
/// barriers when `RunConfig::decommit_watermark` is set; outputs are
/// bit-identical whether it runs or not.
pub fn trim_shards(shards: &mut [Heap], keep: usize) {
    for h in shards {
        h.trim(keep);
    }
}

/// Evacuation pass over a shard slice (see [`Heap::evacuate`]): per
/// shard, placement-move the survivors of sparse chunks into same-class
/// bump space and decommit the emptied chunks. The SMC engine calls this
/// at generation barriers (before the trim pass, so evacuation-emptied
/// chunks never linger) when `RunConfig::evacuate_threshold` is set;
/// outputs are bit-identical whether it runs or not. Returns the total
/// number of payloads relocated.
pub fn evacuate_shards(shards: &mut [Heap], threshold: f64) -> usize {
    shards.iter_mut().map(|h| h.evacuate(threshold)).sum()
}

/// Barrier sample for the exact global peak: sum the *current* footprint
/// of every shard at this instant and fold the sum into the running
/// `global_peak_bytes` (recorded on shard 0; [`HeapMetrics::merge`]
/// carries the max into aggregates). Called by the SMC coordinator at
/// generation barriers — after initialization, at the resampling spike
/// (offspring and parents both live), and after each propagation — where
/// all shards are quiescent, so the summed gauges refer to the same
/// moment. Returns the sampled sum.
pub fn sample_global_peak(shards: &mut [Heap]) -> usize {
    let now: usize = shards.iter().map(|h| h.metrics.current_bytes()).sum();
    if let Some(first) = shards.first_mut() {
        let m = &mut first.metrics;
        if now > m.global_peak_bytes {
            m.global_peak_bytes = now;
        }
    }
    now
}

/// K independent object heaps plus aggregated instrumentation. The
/// coordinator owns it; propagation phases borrow the shard slice via
/// [`ShardedHeap::shards_mut`] and fan it out one-`&mut`-per-worker.
pub struct ShardedHeap {
    shards: Vec<Heap>,
    mode: CopyMode,
}

impl ShardedHeap {
    /// Create `k` independent heaps (`k >= 1`) in the given copy mode, on
    /// the default payload allocator ([`AllocatorKind::Slab`]).
    pub fn new(mode: CopyMode, k: usize) -> Self {
        ShardedHeap::with_allocator(mode, k, AllocatorKind::Slab)
    }

    /// Create `k` independent heaps whose payload storage uses the given
    /// backend (`--allocator system|slab`). Scratch heaps spawned from
    /// any shard inherit the backend.
    pub fn with_allocator(mode: CopyMode, k: usize, kind: AllocatorKind) -> Self {
        assert!(k > 0, "at least one shard");
        ShardedHeap {
            shards: (0..k).map(|_| Heap::with_allocator(mode, kind)).collect(),
            mode,
        }
    }

    /// Number of shards K.
    #[inline]
    pub fn k(&self) -> usize {
        self.shards.len()
    }

    /// Payload-storage backend of the shards.
    #[inline]
    pub fn allocator_kind(&self) -> AllocatorKind {
        self.shards[0].allocator_kind()
    }

    /// Copy mode shared by every shard.
    #[inline]
    pub fn mode(&self) -> CopyMode {
        self.mode
    }

    /// Borrow the shard slice.
    #[inline]
    pub fn shards(&self) -> &[Heap] {
        &self.shards
    }

    /// Borrow the shard slice mutably (what propagation fans out over).
    #[inline]
    pub fn shards_mut(&mut self) -> &mut [Heap] {
        &mut self.shards
    }

    /// Borrow one shard.
    #[inline]
    pub fn shard(&self, s: usize) -> &Heap {
        &self.shards[s]
    }

    /// Borrow one shard mutably.
    #[inline]
    pub fn shard_mut(&mut self, s: usize) -> &mut Heap {
        &mut self.shards[s]
    }

    /// Aggregated counters across all shards (see
    /// [`HeapMetrics::merge`] for the peak-bytes caveat).
    pub fn metrics(&self) -> HeapMetrics {
        aggregate_metrics(&self.shards)
    }

    /// Total live objects across shards.
    pub fn live_objects(&self) -> usize {
        self.shards.iter().map(|h| h.live_objects()).sum()
    }

    /// Sweep every shard's memo tables.
    pub fn sweep_memos(&mut self) {
        for h in &mut self.shards {
            h.sweep_memos();
        }
    }

    /// Barrier-sample the summed footprint into the running global peak
    /// (see [`sample_global_peak`]).
    pub fn sample_global_peak(&mut self) -> usize {
        sample_global_peak(&mut self.shards)
    }

    /// Decommit barrier over every shard (see [`Heap::trim`]): return
    /// fully-empty slab chunks beyond `keep` per size class to the
    /// system allocator. Long-running servers call this at quiescent
    /// points to bound committed residency.
    pub fn trim_all(&mut self, keep: usize) {
        trim_shards(&mut self.shards, keep);
    }

    /// Evacuation pass over every shard (see [`Heap::evacuate`]).
    /// Returns the total number of payloads relocated.
    pub fn evacuate_all(&mut self, threshold: f64) -> usize {
        evacuate_shards(&mut self.shards, threshold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heap::Lazy;
    use crate::lazy_fields;

    #[derive(Clone)]
    struct Node {
        value: i64,
        next: Lazy<Node>,
    }
    lazy_fields!(Node: next);

    fn build_chain(heap: &mut Heap, len: usize) -> Lazy<Node> {
        let mut head = heap.alloc(Node {
            value: 0,
            next: Lazy::NULL,
        });
        for i in 1..len {
            let new = heap.alloc(Node {
                value: i as i64,
                next: head,
            });
            heap.release(head);
            head = new;
        }
        head
    }

    fn chain_values(heap: &mut Heap, head: Lazy<Node>) -> Vec<i64> {
        let mut out = Vec::new();
        let mut cur = head;
        while !cur.is_null() {
            out.push(heap.read(&mut cur, |n| n.value));
            cur = heap.read_ptr(&mut cur, |n| n.next);
        }
        out
    }

    #[test]
    fn partition_covers_and_balances() {
        for n in [0usize, 1, 5, 7, 64, 97] {
            for k in [1usize, 2, 3, 4, 9, 130] {
                let ranges = shard_ranges(n, k);
                assert_eq!(ranges.len(), k);
                // Contiguous cover of 0..n.
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next);
                    next = r.end;
                }
                assert_eq!(next, n);
                // Balance: sizes differ by at most one.
                let sizes: Vec<usize> = ranges.iter().map(|r| r.end - r.start).collect();
                let (mn, mx) = (
                    *sizes.iter().min().unwrap(),
                    *sizes.iter().max().unwrap(),
                );
                assert!(mx - mn <= 1, "n={n} k={k}: sizes {sizes:?}");
                // shard_of agrees with the ranges.
                for i in 0..n {
                    let s = shard_of(n, k, i);
                    assert!(
                        ranges[s].contains(&i),
                        "n={n} k={k} i={i}: shard_of says {s}, ranges {ranges:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn single_shard_is_degenerate() {
        assert_eq!(shard_ranges(10, 1), vec![0..10]);
        for i in 0..10 {
            assert_eq!(shard_of(10, 1, i), 0);
        }
    }

    #[test]
    fn transplant_chain_all_modes() {
        for mode in CopyMode::ALL {
            let mut src = Heap::new(mode);
            let mut dst = Heap::new(mode);
            let head = build_chain(&mut src, 20);
            let want = chain_values(&mut src, head);

            let moved = src.extract_into(&head, &mut dst);
            assert_eq!(dst.metrics.transplants, 1);
            assert_eq!(
                chain_values(&mut dst, moved),
                want,
                "{mode:?}: transplanted values differ"
            );
            // Source untouched and still readable.
            assert_eq!(chain_values(&mut src, head), want);

            // The transplanted lineage participates in dst's lazy
            // machinery: deep-copy it there and mutate the copy.
            let mut copy = dst.deep_copy(&moved);
            dst.mutate_root(&mut copy, |n| n.value = -1);
            let mut expect = want.clone();
            expect[0] = -1;
            assert_eq!(chain_values(&mut dst, copy), expect);
            assert_eq!(chain_values(&mut dst, moved), want, "original intact");

            dst.release(copy);
            dst.release(moved);
            src.release(head);
            src.sweep_memos();
            dst.sweep_memos();
            assert_eq!(src.live_objects(), 0, "{mode:?}: src leaked");
            assert_eq!(dst.live_objects(), 0, "{mode:?}: dst leaked");
            // Alloc/free balance on both sides of the transplant.
            for h in [&src, &dst] {
                assert_eq!(
                    h.metrics.total_allocs,
                    h.metrics.total_frees + h.metrics.live_objects
                );
            }
        }
    }

    #[test]
    fn transplant_resolves_pending_lazy_copies() {
        // Mutate a lazy copy so the source label's memo holds
        // redirections, then transplant the *copy*: the pulled view (with
        // the mutation) must land in dst.
        let mut src = Heap::new(CopyMode::LazySro);
        let mut dst = Heap::new(CopyMode::LazySro);
        let head = build_chain(&mut src, 10);
        let mut copy = src.deep_copy(&head);
        src.mutate_root(&mut copy, |n| n.value = 100);
        // Descend one node so a memo redirection exists mid-chain.
        let mut second = src.get_field(&copy, |n| &mut n.next);
        src.mutate(&mut second, |n| n.value = 200);

        let mut want = chain_values(&mut src, copy);
        assert_eq!(want[0], 100);
        assert_eq!(want[1], 200);

        let moved = src.extract_into(&copy, &mut dst);
        assert_eq!(chain_values(&mut dst, moved), want);

        // Mutating the transplant does not touch the source.
        let mut dst_head = moved;
        dst.mutate_root(&mut dst_head, |n| n.value = -5);
        want[0] = -5;
        assert_eq!(chain_values(&mut dst, dst_head), want);
        want[0] = 100;
        assert_eq!(chain_values(&mut src, copy), want);

        dst.release(dst_head);
        src.release(copy);
        src.release(head);
        src.sweep_memos();
        dst.sweep_memos();
        assert_eq!(src.live_objects(), 0);
        assert_eq!(dst.live_objects(), 0);
    }

    #[test]
    fn transplant_preserves_internal_sharing() {
        // A diamond: two fields of the root alias the same tail node. The
        // transplant must keep one tail object, not duplicate it.
        #[derive(Clone)]
        struct Pair {
            a: Lazy<Node>,
            b: Lazy<Node>,
        }
        lazy_fields!(Pair: a, b);

        let mut src = Heap::new(CopyMode::Eager);
        let mut dst = Heap::new(CopyMode::Eager);
        let tail = src.alloc(Node {
            value: 7,
            next: Lazy::NULL,
        });
        let tail2 = src.clone_handle(&tail);
        let root = src.alloc(Pair { a: tail, b: tail2 });
        // The stored edges own their counts; drop the stack handles.
        src.release(tail);
        src.release(tail2);
        assert_eq!(src.live_objects(), 2);

        let moved = src.extract_into(&root, &mut dst);
        assert_eq!(dst.live_objects(), 2, "shared tail must stay shared");
        dst.release(moved);
        src.release(root);
        assert_eq!(src.live_objects(), 0);
        assert_eq!(dst.live_objects(), 0);
    }

    #[test]
    fn global_peak_is_barrier_sampled_sum() {
        let mut sh = ShardedHeap::new(CopyMode::LazySro, 2);
        let a = build_chain(sh.shard_mut(0), 8);
        let sum1 = sh.sample_global_peak();
        assert_eq!(
            sum1,
            sh.shard(0).metrics.current_bytes() + sh.shard(1).metrics.current_bytes()
        );
        let b = build_chain(sh.shard_mut(1), 8);
        let sum2 = sh.sample_global_peak();
        assert!(sum2 > sum1);
        assert_eq!(sh.metrics().global_peak_bytes, sum2);
        // Releasing shard 0's chain lowers the current sum but not the peak.
        sh.shard_mut(0).release(a);
        sh.shard_mut(0).sweep_memos();
        let sum3 = sh.sample_global_peak();
        assert!(sum3 < sum2);
        assert_eq!(sh.metrics().global_peak_bytes, sum2);
        // The barrier-sampled global peak never exceeds the sum of
        // per-shard continuous peaks (the documented upper bound).
        let m = sh.metrics();
        assert!(m.global_peak_bytes <= m.peak_bytes);
        sh.shard_mut(1).release(b);
    }

    /// The work-stealing scratch-heap round trip: home → scratch (victim
    /// donation), propagate-like mutation in the scratch, scratch → home
    /// (transplant-back), then counter absorption. Values survive, the
    /// home shard's alloc/free balance holds, and the scratch's op work is
    /// not lost from the accounting.
    #[test]
    fn scratch_roundtrip_preserves_values_and_balance() {
        for mode in CopyMode::ALL {
            let mut home = Heap::new(mode);
            let head = build_chain(&mut home, 12);
            let want = chain_values(&mut home, head);

            // Victim side: extract the particle into a scratch heap and
            // release the home handle (the particle now lives elsewhere).
            let mut scratch = home.scratch();
            assert_eq!(scratch.mode(), mode);
            let mut stolen = home.extract_into(&head, &mut scratch);
            home.release(head);
            home.sweep_memos();

            // Thief side: mutate in the scratch heap (a propagation step).
            scratch.mutate_root(&mut stolen, |n| n.value += 1000);
            let mut want_after = want.clone();
            want_after[0] += 1000;
            assert_eq!(chain_values(&mut scratch, stolen), want_after);

            // Transplant back, drain and absorb the scratch.
            let back = scratch.extract_into(&stolen, &mut home);
            scratch.release(stolen);
            scratch.sweep_memos();
            assert_eq!(scratch.live_objects(), 0, "{mode:?}: scratch not drained");
            let scratch_allocs = scratch.metrics.total_allocs;
            assert!(scratch_allocs > 0);
            let before = home.metrics.total_allocs;
            home.absorb_counters(&scratch);
            assert_eq!(
                home.metrics.total_allocs,
                before + scratch_allocs,
                "{mode:?}: scratch op work lost from the accounting"
            );

            assert_eq!(chain_values(&mut home, back), want_after);
            home.release(back);
            home.sweep_memos();
            assert_eq!(home.live_objects(), 0, "{mode:?}: home leaked");
            assert_eq!(
                home.metrics.total_allocs,
                home.metrics.total_frees + home.metrics.live_objects,
                "{mode:?}: home balance broken after absorption"
            );
        }
    }

    #[test]
    fn sharded_heap_aggregates_metrics() {
        let mut sh = ShardedHeap::new(CopyMode::LazySro, 3);
        assert_eq!(sh.k(), 3);
        let a = build_chain(sh.shard_mut(0), 4);
        let b = build_chain(sh.shard_mut(2), 6);
        let m = sh.metrics();
        assert_eq!(m.live_objects, 10);
        assert_eq!(m.total_allocs, 10);
        assert_eq!(m.total_allocs, m.total_frees + m.live_objects);
        assert_eq!(sh.live_objects(), 10);
        sh.shard_mut(0).release(a);
        sh.shard_mut(2).release(b);
        sh.sweep_memos();
        let m = sh.metrics();
        assert_eq!(m.live_objects, 0);
        assert_eq!(m.total_allocs, m.total_frees);
    }
}
