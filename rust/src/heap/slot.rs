//! Object slots: the vertex records of the heap slab.

use super::alloc::PBox;
use super::ids::LabelId;

/// Per-object record. Holds the payload `b(v)` (a [`PBox`] handle into
/// the heap's slab allocator — the vtable rides in the handle's fat
/// pointer, the bytes live in a size-class slab), the creating label
/// `f(v)` (§2.2 Def. 2), the read-only flag (`v ∈ R`), the three
/// reference counts of §3 (shared / weak / memo), and the
/// single-reference-optimization bookkeeping of Remark 1.
pub(crate) struct Slot {
    /// Payload `b(v)`; `None` once destroyed (shared count reached zero).
    /// Destruction must return the handle through the owning heap's
    /// allocator (`Heap::destroy` → `SlabAlloc::dealloc`) so the block
    /// re-enters its free list; a bare drop (heap teardown) is safe but
    /// unaccounted.
    pub payload: Option<PBox>,
    /// Creating label `f(v)`. Does not hold a reference count on the label
    /// (the paper's cycle-breaking rule, §3).
    pub label: LabelId,
    /// `v ∈ R`: read-only (frozen by a deep copy).
    pub frozen: bool,
    /// Remark 1 flag: at freeze time the in-degree was 1 and `v ∉ ran m`,
    /// so copies of this object may skip the memo update.
    pub single_ref: bool,
    /// `v ∈ ran m` (ever): this object is the value of some memo entry, so
    /// its apparent in-degree under-counts expanded-graph in-edges and the
    /// single-reference optimization must not apply (Remark 1, cond. 1).
    pub in_memo_ran: bool,
    /// The object has been shallow-copied at least once.
    pub copied_once: bool,
    /// Label under which a copy skipped the memo update (single-reference
    /// optimization). Used to detect the paper's "identical in-edge"
    /// violation: if a new in-edge with this label appears later, it must be
    /// eagerly `Get`-ed to keep views consistent.
    pub skipped_label: LabelId,
    /// More than one label has skipped the memo for this object; treat any
    /// new in-edge conservatively.
    pub skipped_many: bool,

    /// Shared count: owning edges (object fields + root handles) + memo
    /// values. Destroy payload at zero.
    pub shared: u32,
    /// Weak count (starts at 1 for self; decremented on destroy).
    pub weak: u32,
    /// Memo count: memo table *keys* naming this slot. The slot index is not
    /// recycled until this reaches zero.
    pub memo: u32,
    /// Generation tag: incremented when the slot is recycled.
    pub gen: u32,
    /// Cached payload size for metrics (bytes).
    pub bytes: u32,
}

impl Slot {
    pub fn vacant(gen: u32) -> Self {
        Slot {
            payload: None,
            label: LabelId::NULL,
            frozen: false,
            single_ref: false,
            in_memo_ran: false,
            copied_once: false,
            skipped_label: LabelId::NULL,
            skipped_many: false,
            shared: 0,
            weak: 0,
            memo: 0,
            gen,
            bytes: 0,
        }
    }

    /// Payload destroyed (but slot possibly still reserved by memo keys)?
    #[inline]
    pub fn destroyed(&self) -> bool {
        self.payload.is_none()
    }
}

/// Per-object overhead in bytes, reported in memory metrics alongside the
/// payload size. The paper reports 12 extra bytes per object for lazy-copy
/// support; our slot record is the analogous bookkeeping.
pub(crate) const OBJ_OVERHEAD: usize = 48;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vacant_slot_is_destroyed() {
        let s = Slot::vacant(3);
        assert!(s.destroyed());
        assert_eq!(s.gen, 3);
        assert_eq!(s.shared, 0);
        assert!(!s.frozen);
    }
}
