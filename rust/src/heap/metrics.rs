//! Heap instrumentation: allocation, copy, and memo counters.
//!
//! These counters drive the paper's evaluation: peak memory (Figures 5–6),
//! per-generation memory series (Figure 7), and the copy/sharing behaviour
//! that explains them (eager vs lazy vs lazy+SRO).

/// Counters maintained by the [`Heap`](super::Heap). All sizes are in bytes.
#[derive(Clone, Copy, Debug, Default)]
pub struct HeapMetrics {
    /// Objects currently live (payload not yet destroyed).
    pub live_objects: usize,
    /// Bytes in live payloads + per-object overhead.
    pub live_bytes: usize,
    /// High-water mark of `live_bytes` (+ label/memo bytes).
    pub peak_bytes: usize,
    /// Labels currently live.
    pub live_labels: usize,
    /// Bytes in live memo tables.
    pub memo_bytes: usize,

    /// Total objects ever allocated.
    pub total_allocs: usize,
    /// Total objects ever destroyed. Invariant (checked by the sharded-heap
    /// tests): `total_allocs == total_frees + live_objects`.
    pub total_frees: usize,
    /// Shallow copies performed by `Copy` (Algorithm 6) — the lazy platform's
    /// actual object copies.
    pub lazy_copies: usize,
    /// Objects copied by eager deep copies (eager mode, or `Finish` of cross
    /// references in lazy mode).
    pub eager_copies: usize,
    /// `deep_copy` invocations.
    pub deep_copies: usize,
    /// Copies avoided by the in-place thaw optimization (sole-reference
    /// recycling at copy time, §3).
    pub thaws: usize,
    /// Memo insertions skipped by the single-reference optimization
    /// (Remark 1).
    pub sro_skips: usize,

    /// Memo lookups that hit / missed.
    pub memo_hits: usize,
    pub memo_misses: usize,
    /// Entries removed by memo sweeps.
    pub memo_swept: usize,

    /// `Pull` / `Get` operation counts.
    pub pulls: usize,
    pub gets: usize,
    /// Objects frozen by `Freeze` traversals.
    pub freezes: usize,
    /// Cross references encountered (edges outside the tree pattern).
    pub cross_refs: usize,
    /// Cross-shard lineage transplants received (`Heap::extract_into`
    /// calls that materialized a subgraph in this heap).
    pub transplants: usize,

    /// Barrier-sampled *global* peak: the maximum over generation
    /// barriers of the summed footprint of all shards at that instant
    /// (see [`sample_global_peak`](super::sample_global_peak)). Unlike
    /// the sum of per-shard `peak_bytes` — an upper bound, since shards
    /// need not peak at the same moment — this is an exact simultaneous
    /// figure at barrier resolution. The coordinator records it into
    /// shard 0; [`merge`](HeapMetrics::merge) takes the max so the
    /// aggregate carries it. Zero until the first sample.
    pub global_peak_bytes: usize,
}

impl HeapMetrics {
    #[inline]
    pub(crate) fn note_peak(&mut self) {
        let now = self.live_bytes + self.memo_bytes;
        if now > self.peak_bytes {
            self.peak_bytes = now;
        }
    }

    /// Current footprint (live payloads + memo tables).
    pub fn current_bytes(&self) -> usize {
        self.live_bytes + self.memo_bytes
    }

    /// Reset the peak to the current footprint (for per-phase measurement).
    pub fn reset_peak(&mut self) {
        self.peak_bytes = self.current_bytes();
    }

    /// Accumulate another heap's counters into this one — the aggregation
    /// used by [`ShardedHeap`](super::ShardedHeap). All counters (including
    /// the live gauges) add; `peak_bytes` also adds, so the aggregate peak
    /// is an upper bound on the true simultaneous global peak (per-shard
    /// peaks need not coincide in time).
    pub fn merge(&mut self, o: &HeapMetrics) {
        // Exhaustive destructuring (no `..` rest pattern): adding a field
        // to HeapMetrics without aggregating it here is a compile error.
        let HeapMetrics {
            live_objects,
            live_bytes,
            peak_bytes,
            live_labels,
            memo_bytes,
            total_allocs,
            total_frees,
            lazy_copies,
            eager_copies,
            deep_copies,
            thaws,
            sro_skips,
            memo_hits,
            memo_misses,
            memo_swept,
            pulls,
            gets,
            freezes,
            cross_refs,
            transplants,
            global_peak_bytes,
        } = *o;
        self.live_objects += live_objects;
        self.live_bytes += live_bytes;
        self.peak_bytes += peak_bytes;
        self.live_labels += live_labels;
        self.memo_bytes += memo_bytes;
        self.total_allocs += total_allocs;
        self.total_frees += total_frees;
        self.lazy_copies += lazy_copies;
        self.eager_copies += eager_copies;
        self.deep_copies += deep_copies;
        self.thaws += thaws;
        self.sro_skips += sro_skips;
        self.memo_hits += memo_hits;
        self.memo_misses += memo_misses;
        self.memo_swept += memo_swept;
        self.pulls += pulls;
        self.gets += gets;
        self.freezes += freezes;
        self.cross_refs += cross_refs;
        self.transplants += transplants;
        // Barrier samples are global figures, not per-shard counters: the
        // aggregate carries the largest sample seen anywhere.
        self.global_peak_bytes = self.global_peak_bytes.max(global_peak_bytes);
    }

    /// Fold the *monotone operation counters* of a drained scratch heap
    /// into this heap's metrics — the bookkeeping half of the scratch-heap
    /// transplant-back path (work stealing): a stolen particle's allocs,
    /// copies, and pulls happened in a transient scratch heap that is about
    /// to be dropped, and would otherwise vanish from the op accounting the
    /// rebalancer's cost model feeds on. Gauges (live objects/bytes, peaks,
    /// labels, memo bytes) are deliberately left untouched: the scratch
    /// heap is fully drained when reclaimed (allocs == frees there, so the
    /// alloc/free/live balance of the absorbing shard survives), and its
    /// transient footprint is not part of this shard's footprint history.
    pub fn merge_counters(&mut self, o: &HeapMetrics) {
        // Exhaustive destructuring, as in `merge`: a new field must be
        // explicitly classified counter-vs-gauge here or this fails to
        // compile.
        let HeapMetrics {
            live_objects: _,
            live_bytes: _,
            peak_bytes: _,
            live_labels: _,
            memo_bytes: _,
            total_allocs,
            total_frees,
            lazy_copies,
            eager_copies,
            deep_copies,
            thaws,
            sro_skips,
            memo_hits,
            memo_misses,
            memo_swept,
            pulls,
            gets,
            freezes,
            cross_refs,
            transplants,
            global_peak_bytes: _,
        } = *o;
        self.total_allocs += total_allocs;
        self.total_frees += total_frees;
        self.lazy_copies += lazy_copies;
        self.eager_copies += eager_copies;
        self.deep_copies += deep_copies;
        self.thaws += thaws;
        self.sro_skips += sro_skips;
        self.memo_hits += memo_hits;
        self.memo_misses += memo_misses;
        self.memo_swept += memo_swept;
        self.pulls += pulls;
        self.gets += gets;
        self.freezes += freezes;
        self.cross_refs += cross_refs;
        self.transplants += transplants;
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "live={} objs / {} B (peak {} B), labels={}, copies: lazy={} eager={} thaw={} sro_skips={}, memo: hits={} misses={} swept={}, cross_refs={}, transplants={}",
            self.live_objects,
            self.live_bytes,
            self.peak_bytes,
            self.live_labels,
            self.lazy_copies,
            self.eager_copies,
            self.thaws,
            self.sro_skips,
            self.memo_hits,
            self.memo_misses,
            self.memo_swept,
            self.cross_refs,
            self.transplants,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water() {
        let mut m = HeapMetrics::default();
        m.live_bytes = 100;
        m.note_peak();
        assert_eq!(m.peak_bytes, 100);
        m.live_bytes = 50;
        m.note_peak();
        assert_eq!(m.peak_bytes, 100);
        m.memo_bytes = 80;
        m.note_peak();
        assert_eq!(m.peak_bytes, 130);
        m.reset_peak();
        assert_eq!(m.peak_bytes, 130);
        m.live_bytes = 0;
        m.memo_bytes = 0;
        m.reset_peak();
        assert_eq!(m.peak_bytes, 0);
    }

    #[test]
    fn summary_contains_counts() {
        let mut m = HeapMetrics::default();
        m.lazy_copies = 3;
        assert!(m.summary().contains("lazy=3"));
    }

    #[test]
    fn merge_counters_skips_gauges_and_keeps_balance() {
        let mut shard = HeapMetrics {
            live_objects: 4,
            live_bytes: 400,
            peak_bytes: 500,
            total_allocs: 10,
            total_frees: 6,
            pulls: 3,
            ..Default::default()
        };
        // A drained scratch heap: everything allocated was freed.
        let scratch = HeapMetrics {
            live_objects: 0,
            live_bytes: 0,
            peak_bytes: 999,
            total_allocs: 7,
            total_frees: 7,
            lazy_copies: 2,
            eager_copies: 5,
            pulls: 4,
            transplants: 1,
            ..Default::default()
        };
        shard.merge_counters(&scratch);
        assert_eq!(shard.total_allocs, 17);
        assert_eq!(shard.total_frees, 13);
        assert_eq!(shard.lazy_copies, 2);
        assert_eq!(shard.eager_copies, 5);
        assert_eq!(shard.pulls, 7);
        assert_eq!(shard.transplants, 1);
        // Gauges untouched.
        assert_eq!(shard.live_objects, 4);
        assert_eq!(shard.live_bytes, 400);
        assert_eq!(shard.peak_bytes, 500);
        // The per-shard invariant survives absorption.
        assert_eq!(shard.total_allocs, shard.total_frees + shard.live_objects);
    }

    #[test]
    fn merge_adds_counters() {
        let mut a = HeapMetrics {
            live_objects: 2,
            total_allocs: 5,
            total_frees: 3,
            peak_bytes: 100,
            transplants: 1,
            ..Default::default()
        };
        let b = HeapMetrics {
            live_objects: 1,
            total_allocs: 4,
            total_frees: 3,
            peak_bytes: 50,
            transplants: 2,
            ..Default::default()
        };
        a.global_peak_bytes = 90;
        a.merge(&b);
        assert_eq!(a.live_objects, 3);
        assert_eq!(a.total_allocs, 9);
        assert_eq!(a.total_frees, 6);
        assert_eq!(a.peak_bytes, 150);
        assert_eq!(a.transplants, 3);
        // Global barrier samples max, not add.
        assert_eq!(a.global_peak_bytes, 90);
        // The alloc/free/live balance survives aggregation.
        assert_eq!(a.total_allocs, a.total_frees + a.live_objects);
    }
}
