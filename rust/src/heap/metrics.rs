//! Heap instrumentation: allocation, copy, and memo counters.
//!
//! These counters drive the paper's evaluation: peak memory (Figures 5–6),
//! per-generation memory series (Figure 7), and the copy/sharing behaviour
//! that explains them (eager vs lazy vs lazy+SRO) — plus the slab
//! allocator's storage gauges (payload blocks, raw memo/label blocks,
//! committed and decommitted chunks) that make long-run residency
//! observable.

use super::alloc::{AllocReceipt, FreeReceipt};

/// Counters maintained by the [`Heap`](super::Heap). All sizes are in bytes.
#[derive(Clone, Copy, Debug, Default)]
pub struct HeapMetrics {
    /// Objects currently live (payload not yet destroyed).
    pub live_objects: usize,
    /// Bytes in live payloads + per-object overhead.
    pub live_bytes: usize,
    /// High-water mark of `live_bytes` (+ label/memo bytes).
    pub peak_bytes: usize,
    /// Labels currently live.
    pub live_labels: usize,
    /// Bytes in live memo tables.
    pub memo_bytes: usize,

    /// Total objects ever allocated.
    pub total_allocs: usize,
    /// Total objects ever destroyed. Invariant (checked by the sharded-heap
    /// tests): `total_allocs == total_frees + live_objects`.
    pub total_frees: usize,
    /// Shallow copies performed by `Copy` (Algorithm 6) — the lazy platform's
    /// actual object copies.
    pub lazy_copies: usize,
    /// Objects copied by eager deep copies (eager mode, or `Finish` of cross
    /// references in lazy mode).
    pub eager_copies: usize,
    /// `deep_copy` invocations.
    pub deep_copies: usize,
    /// Copies avoided by the in-place thaw optimization (sole-reference
    /// recycling at copy time, §3).
    pub thaws: usize,
    /// Memo insertions skipped by the single-reference optimization
    /// (Remark 1).
    pub sro_skips: usize,

    /// Memo lookups that found a redirection.
    pub memo_hits: usize,
    /// Memo lookups that found none (the probe ended the pull chase).
    pub memo_misses: usize,
    /// Entries removed by memo sweeps.
    pub memo_swept: usize,

    /// `Pull` operations (Algorithm 4).
    pub pulls: usize,
    /// `Get` operations (Algorithm 5).
    pub gets: usize,
    /// Objects frozen by `Freeze` traversals.
    pub freezes: usize,
    /// Cross references encountered (edges outside the tree pattern).
    pub cross_refs: usize,
    /// Cross-shard lineage transplants received (`Heap::extract_into`
    /// calls that materialized a subgraph in this heap).
    pub transplants: usize,

    /// Barrier-sampled *global* peak: the maximum over generation
    /// barriers of the summed footprint of all shards at that instant
    /// (see [`sample_global_peak`](super::sample_global_peak)). Unlike
    /// the sum of per-shard `peak_bytes` — an upper bound, since shards
    /// need not peak at the same moment — this is an exact simultaneous
    /// figure at barrier resolution. The coordinator records it into
    /// shard 0; [`merge`](HeapMetrics::merge) takes the max so the
    /// aggregate carries it. Zero until the first sample.
    pub global_peak_bytes: usize,

    /// Work-stealing scratch residency: the maximum over generations of
    /// the summed per-scratch-heap peaks of that generation's donation
    /// batches. Each scratch measures its own peak exactly; the
    /// per-generation sum bounds the transient bytes that live in *no*
    /// shard's `peak_bytes` between donation and reclaim, so
    /// `peak_bytes + scratch_peak_bytes` bounds the true steal-on peak.
    /// Recorded on shard 0 at the reclaim barrier
    /// ([`note_scratch_peak`](HeapMetrics::note_scratch_peak));
    /// [`merge`](HeapMetrics::merge) carries the max. Zero with stealing
    /// off — which is what makes steal-on vs steal-off peak comparisons
    /// exact.
    pub scratch_peak_bytes: usize,

    // --- Slab-allocator gauges and counters (see `heap::alloc`). ---
    /// Slab chunks committed (gauge).
    pub slab_chunks: usize,
    /// Bytes committed in slab chunks (gauge; `slab_chunks` ×
    /// [`CHUNK_BYTES`](super::CHUNK_BYTES)). Lowered by decommit
    /// barriers ([`Heap::trim`](super::Heap::trim)).
    pub slab_committed_bytes: usize,
    /// High-water mark of `slab_committed_bytes` (gauge). Unlike the
    /// current committed gauge this never drops at decommit, which is
    /// what keeps the fragmentation figure well-defined on trimming
    /// heaps.
    pub slab_committed_peak_bytes: usize,
    /// Bytes in slab blocks currently handed out, at block granularity
    /// (gauge). Occupancy = this / `slab_committed_bytes`.
    pub slab_live_block_bytes: usize,
    /// High-water mark of `slab_live_block_bytes + slab_raw_bytes` —
    /// payload *and* memo/label blocks (gauge). Fragmentation at the
    /// allocator's fullest moment =
    /// `1 - slab_block_peak_bytes / slab_committed_peak_bytes`.
    pub slab_block_peak_bytes: usize,
    /// Payload allocations served from a class free list — reuse, the
    /// slab's whole point on resampling churn (counter).
    pub slab_freelist_hits: usize,
    /// Payload allocations served by bumping fresh chunk space (counter).
    pub slab_fresh_bumps: usize,
    /// Payload allocations on the exact-layout path: payloads too large
    /// or over-aligned for any class, and *every* allocation under the
    /// `system` backend (counter).
    pub slab_large_allocs: usize,

    /// Raw-path allocations (memo bucket arrays, label slot storage)
    /// served by the allocator — every per-heap dynamic structure routes
    /// through here, on any backend (counter).
    pub slab_raw_allocs: usize,
    /// Raw-path blocks returned (memo rehashes/sweeps, label-vector
    /// growth; counter).
    pub slab_raw_frees: usize,
    /// Bytes in live raw (memo/label) slab blocks, at block granularity
    /// (gauge). Zero under the `system` backend and in scratch heaps,
    /// whose raw allocations take the exact-layout path.
    pub slab_raw_bytes: usize,

    /// Slab chunks returned to the system allocator by decommit barriers
    /// ([`Heap::trim`](super::Heap::trim); counter). Zero with decommit
    /// off — which is what makes the long-run `alloc` bench contrast
    /// (bounded vs monotone committed bytes) checkable.
    pub decommitted_chunks: usize,
    /// Bytes returned by decommit (`decommitted_chunks` ×
    /// [`CHUNK_BYTES`](super::CHUNK_BYTES); counter).
    pub decommitted_bytes: usize,

    // --- Large-object space (see `heap::alloc`'s module docs). ---
    /// Large-object-space blocks handed out (payload or raw; counter).
    /// Zero under the `system` backend, whose large allocations stay on
    /// the exact-layout path.
    pub los_allocs: usize,
    /// Large-object-space blocks returned to the LOS free list (counter).
    pub los_frees: usize,
    /// LOS allocations served by reusing a free block instead of a fresh
    /// system allocation (counter; a subset of `los_allocs`).
    pub los_reuses: usize,
    /// Bytes in live LOS blocks, headers included (gauge).
    pub los_live_bytes: usize,
    /// Bytes parked on the LOS free list, headers included (gauge).
    /// Lowered when [`Heap::trim`](super::Heap::trim) returns free LOS
    /// blocks to the system allocator.
    pub los_free_bytes: usize,
    /// LOS bytes returned to the system allocator by trim barriers
    /// (counter; accounted apart from `decommitted_bytes`, which stays
    /// chunk-granular).
    pub los_decommitted_bytes: usize,

    // --- Evacuation (opportunistic defrag; `--evacuate-threshold`). ---
    /// Payloads placement-moved out of sparse chunks at evacuation
    /// barriers (counter). Zero with evacuation off.
    pub evacuated_objects: usize,
    /// Slab block bytes those moves relocated (counter).
    pub evacuated_bytes: usize,
    /// Chunks emptied and decommitted by evacuation (counter; accounted
    /// apart from `decommitted_chunks`, which counts only watermark-trim
    /// decommits).
    pub evacuated_chunks: usize,
}

impl HeapMetrics {
    #[inline]
    pub(crate) fn note_peak(&mut self) {
        let now = self.live_bytes + self.memo_bytes;
        if now > self.peak_bytes {
            self.peak_bytes = now;
        }
    }

    /// Current footprint (live payloads + memo tables).
    pub fn current_bytes(&self) -> usize {
        self.live_bytes + self.memo_bytes
    }

    /// Reset the peak to the current footprint (for per-phase measurement).
    pub fn reset_peak(&mut self) {
        self.peak_bytes = self.current_bytes();
    }

    /// The rebalancer's operation charge for a metrics delta: allocations
    /// + actual object copies + memo-chase pulls, the lazy platform's
    /// hot-path operations.
    pub fn op_charge(&self) -> usize {
        self.total_allocs + self.lazy_copies + self.eager_copies + self.pulls
    }

    /// Fold one generation's summed scratch-heap residency into the
    /// running `scratch_peak_bytes` high-water mark (the work-stealing
    /// reclaim barrier calls this on shard 0).
    pub fn note_scratch_peak(&mut self, bytes: usize) {
        if bytes > self.scratch_peak_bytes {
            self.scratch_peak_bytes = bytes;
        }
    }

    /// Mirror one raw-path (memo/label storage) allocation receipt into
    /// the gauges. Raw allocations are counted apart from payload
    /// allocations so `slab_freelist_hits + slab_fresh_bumps +
    /// slab_large_allocs == total_allocs` stays a payload-only invariant.
    pub(crate) fn note_raw_alloc(&mut self, r: &AllocReceipt) {
        self.slab_raw_allocs += 1;
        if r.new_chunk {
            self.slab_chunks += 1;
            self.slab_committed_bytes += super::CHUNK_BYTES;
            if self.slab_committed_bytes > self.slab_committed_peak_bytes {
                self.slab_committed_peak_bytes = self.slab_committed_bytes;
            }
        }
        self.slab_raw_bytes += r.block_bytes;
        let all = self.slab_live_block_bytes + self.slab_raw_bytes;
        if all > self.slab_block_peak_bytes {
            self.slab_block_peak_bytes = all;
        }
        self.note_los_alloc(r);
    }

    /// Mirror one raw-path free receipt into the gauges.
    pub(crate) fn note_raw_free(&mut self, r: &FreeReceipt) {
        self.slab_raw_frees += 1;
        self.slab_raw_bytes -= r.block_bytes;
        self.note_los_free(r);
    }

    /// Mirror the LOS half of an allocation receipt (payload or raw) into
    /// the `los_*` counters and gauges. No-op off the LOS path.
    pub(crate) fn note_los_alloc(&mut self, r: &AllocReceipt) {
        if r.los_bytes == 0 {
            return;
        }
        self.los_allocs += 1;
        self.los_live_bytes += r.los_bytes;
        if r.reused {
            self.los_reuses += 1;
            self.los_free_bytes -= r.los_bytes;
        }
    }

    /// Mirror the LOS half of a free receipt into the `los_*` counters
    /// and gauges. No-op off the LOS path.
    pub(crate) fn note_los_free(&mut self, r: &FreeReceipt) {
        if r.los_bytes == 0 {
            return;
        }
        self.los_frees += 1;
        self.los_live_bytes -= r.los_bytes;
        self.los_free_bytes += r.los_bytes;
    }

    /// Exact delta since `earlier` (a [`MetricsScope`] snapshot of the
    /// same heap): monotone counters subtract; gauges (live/peak/memo
    /// footprints, slab occupancy, barrier samples) carry their *current*
    /// values, since a point-in-time gauge has no meaningful difference.
    pub fn delta_since(&self, earlier: &HeapMetrics) -> HeapMetrics {
        // Exhaustive destructuring, as in `merge`: adding a field without
        // classifying it counter-vs-gauge here is a compile error.
        let HeapMetrics {
            live_objects,
            live_bytes,
            peak_bytes,
            live_labels,
            memo_bytes,
            total_allocs,
            total_frees,
            lazy_copies,
            eager_copies,
            deep_copies,
            thaws,
            sro_skips,
            memo_hits,
            memo_misses,
            memo_swept,
            pulls,
            gets,
            freezes,
            cross_refs,
            transplants,
            global_peak_bytes,
            scratch_peak_bytes,
            slab_chunks,
            slab_committed_bytes,
            slab_committed_peak_bytes,
            slab_live_block_bytes,
            slab_block_peak_bytes,
            slab_freelist_hits,
            slab_fresh_bumps,
            slab_large_allocs,
            slab_raw_allocs,
            slab_raw_frees,
            slab_raw_bytes,
            decommitted_chunks,
            decommitted_bytes,
            los_allocs,
            los_frees,
            los_reuses,
            los_live_bytes,
            los_free_bytes,
            los_decommitted_bytes,
            evacuated_objects,
            evacuated_bytes,
            evacuated_chunks,
        } = *self;
        HeapMetrics {
            // Gauges: current values.
            live_objects,
            live_bytes,
            peak_bytes,
            live_labels,
            memo_bytes,
            global_peak_bytes,
            scratch_peak_bytes,
            slab_chunks,
            slab_committed_bytes,
            slab_committed_peak_bytes,
            slab_live_block_bytes,
            slab_block_peak_bytes,
            slab_raw_bytes,
            los_live_bytes,
            los_free_bytes,
            // Counters: exact in-scope deltas.
            total_allocs: total_allocs - earlier.total_allocs,
            total_frees: total_frees - earlier.total_frees,
            lazy_copies: lazy_copies - earlier.lazy_copies,
            eager_copies: eager_copies - earlier.eager_copies,
            deep_copies: deep_copies - earlier.deep_copies,
            thaws: thaws - earlier.thaws,
            sro_skips: sro_skips - earlier.sro_skips,
            memo_hits: memo_hits - earlier.memo_hits,
            memo_misses: memo_misses - earlier.memo_misses,
            memo_swept: memo_swept - earlier.memo_swept,
            pulls: pulls - earlier.pulls,
            gets: gets - earlier.gets,
            freezes: freezes - earlier.freezes,
            cross_refs: cross_refs - earlier.cross_refs,
            transplants: transplants - earlier.transplants,
            slab_freelist_hits: slab_freelist_hits - earlier.slab_freelist_hits,
            slab_fresh_bumps: slab_fresh_bumps - earlier.slab_fresh_bumps,
            slab_large_allocs: slab_large_allocs - earlier.slab_large_allocs,
            slab_raw_allocs: slab_raw_allocs - earlier.slab_raw_allocs,
            slab_raw_frees: slab_raw_frees - earlier.slab_raw_frees,
            decommitted_chunks: decommitted_chunks - earlier.decommitted_chunks,
            decommitted_bytes: decommitted_bytes - earlier.decommitted_bytes,
            los_allocs: los_allocs - earlier.los_allocs,
            los_frees: los_frees - earlier.los_frees,
            los_reuses: los_reuses - earlier.los_reuses,
            los_decommitted_bytes: los_decommitted_bytes - earlier.los_decommitted_bytes,
            evacuated_objects: evacuated_objects - earlier.evacuated_objects,
            evacuated_bytes: evacuated_bytes - earlier.evacuated_bytes,
            evacuated_chunks: evacuated_chunks - earlier.evacuated_chunks,
        }
    }

    /// Accumulate another heap's counters into this one — the aggregation
    /// used by [`ShardedHeap`](super::ShardedHeap). All counters (including
    /// the live gauges) add; `peak_bytes` also adds, so the aggregate peak
    /// is an upper bound on the true simultaneous global peak (per-shard
    /// peaks need not coincide in time).
    pub fn merge(&mut self, o: &HeapMetrics) {
        // Exhaustive destructuring (no `..` rest pattern): adding a field
        // to HeapMetrics without aggregating it here is a compile error.
        let HeapMetrics {
            live_objects,
            live_bytes,
            peak_bytes,
            live_labels,
            memo_bytes,
            total_allocs,
            total_frees,
            lazy_copies,
            eager_copies,
            deep_copies,
            thaws,
            sro_skips,
            memo_hits,
            memo_misses,
            memo_swept,
            pulls,
            gets,
            freezes,
            cross_refs,
            transplants,
            global_peak_bytes,
            scratch_peak_bytes,
            slab_chunks,
            slab_committed_bytes,
            slab_committed_peak_bytes,
            slab_live_block_bytes,
            slab_block_peak_bytes,
            slab_freelist_hits,
            slab_fresh_bumps,
            slab_large_allocs,
            slab_raw_allocs,
            slab_raw_frees,
            slab_raw_bytes,
            decommitted_chunks,
            decommitted_bytes,
            los_allocs,
            los_frees,
            los_reuses,
            los_live_bytes,
            los_free_bytes,
            los_decommitted_bytes,
            evacuated_objects,
            evacuated_bytes,
            evacuated_chunks,
        } = *o;
        self.live_objects += live_objects;
        self.live_bytes += live_bytes;
        self.peak_bytes += peak_bytes;
        self.live_labels += live_labels;
        self.memo_bytes += memo_bytes;
        self.total_allocs += total_allocs;
        self.total_frees += total_frees;
        self.lazy_copies += lazy_copies;
        self.eager_copies += eager_copies;
        self.deep_copies += deep_copies;
        self.thaws += thaws;
        self.sro_skips += sro_skips;
        self.memo_hits += memo_hits;
        self.memo_misses += memo_misses;
        self.memo_swept += memo_swept;
        self.pulls += pulls;
        self.gets += gets;
        self.freezes += freezes;
        self.cross_refs += cross_refs;
        self.transplants += transplants;
        self.slab_chunks += slab_chunks;
        self.slab_committed_bytes += slab_committed_bytes;
        self.slab_committed_peak_bytes += slab_committed_peak_bytes;
        self.slab_live_block_bytes += slab_live_block_bytes;
        self.slab_block_peak_bytes += slab_block_peak_bytes;
        self.slab_freelist_hits += slab_freelist_hits;
        self.slab_fresh_bumps += slab_fresh_bumps;
        self.slab_large_allocs += slab_large_allocs;
        self.slab_raw_allocs += slab_raw_allocs;
        self.slab_raw_frees += slab_raw_frees;
        self.slab_raw_bytes += slab_raw_bytes;
        self.decommitted_chunks += decommitted_chunks;
        self.decommitted_bytes += decommitted_bytes;
        self.los_allocs += los_allocs;
        self.los_frees += los_frees;
        self.los_reuses += los_reuses;
        self.los_live_bytes += los_live_bytes;
        self.los_free_bytes += los_free_bytes;
        self.los_decommitted_bytes += los_decommitted_bytes;
        self.evacuated_objects += evacuated_objects;
        self.evacuated_bytes += evacuated_bytes;
        self.evacuated_chunks += evacuated_chunks;
        // Barrier samples are global figures, not per-shard counters: the
        // aggregate carries the largest sample seen anywhere.
        self.global_peak_bytes = self.global_peak_bytes.max(global_peak_bytes);
        self.scratch_peak_bytes = self.scratch_peak_bytes.max(scratch_peak_bytes);
    }

    /// Fold the *monotone operation counters* of a drained scratch heap
    /// into this heap's metrics — the bookkeeping half of the scratch-heap
    /// transplant-back path (work stealing): a stolen particle's allocs,
    /// copies, and pulls happened in a transient scratch heap that is about
    /// to be dropped, and would otherwise vanish from the op accounting the
    /// rebalancer's cost model feeds on. Gauges (live objects/bytes, peaks,
    /// labels, memo bytes) are deliberately left untouched: the scratch
    /// heap is fully drained when reclaimed (allocs == frees there, so the
    /// alloc/free/live balance of the absorbing shard survives), and its
    /// transient footprint is not part of this shard's footprint history.
    pub fn merge_counters(&mut self, o: &HeapMetrics) {
        // Exhaustive destructuring, as in `merge`: a new field must be
        // explicitly classified counter-vs-gauge here or this fails to
        // compile.
        let HeapMetrics {
            live_objects: _,
            live_bytes: _,
            peak_bytes: _,
            live_labels: _,
            memo_bytes: _,
            total_allocs,
            total_frees,
            lazy_copies,
            eager_copies,
            deep_copies,
            thaws,
            sro_skips,
            memo_hits,
            memo_misses,
            memo_swept,
            pulls,
            gets,
            freezes,
            cross_refs,
            transplants,
            global_peak_bytes: _,
            scratch_peak_bytes: _,
            // Slab gauges die with the scratch heap's own storage; its
            // residency is accounted by `scratch_peak_bytes` instead.
            slab_chunks: _,
            slab_committed_bytes: _,
            slab_committed_peak_bytes: _,
            slab_live_block_bytes: _,
            slab_block_peak_bytes: _,
            slab_raw_bytes: _,
            slab_freelist_hits,
            slab_fresh_bumps,
            slab_large_allocs,
            slab_raw_allocs,
            slab_raw_frees,
            // Scratch heaps never decommit (retain-everything pooling),
            // but the fields are monotone counters: classify them as
            // such so a future absorb of a trimming heap stays correct.
            decommitted_chunks,
            decommitted_bytes,
            los_allocs,
            los_frees,
            los_reuses,
            // LOS storage gauges die with the scratch heap's own LOS,
            // like the slab gauges above.
            los_live_bytes: _,
            los_free_bytes: _,
            los_decommitted_bytes,
            // Scratch heaps never evacuate (bump-only), but these are
            // monotone counters: classify them as such.
            evacuated_objects,
            evacuated_bytes,
            evacuated_chunks,
        } = *o;
        self.total_allocs += total_allocs;
        self.total_frees += total_frees;
        self.lazy_copies += lazy_copies;
        self.eager_copies += eager_copies;
        self.deep_copies += deep_copies;
        self.thaws += thaws;
        self.sro_skips += sro_skips;
        self.memo_hits += memo_hits;
        self.memo_misses += memo_misses;
        self.memo_swept += memo_swept;
        self.pulls += pulls;
        self.gets += gets;
        self.freezes += freezes;
        self.cross_refs += cross_refs;
        self.transplants += transplants;
        self.slab_freelist_hits += slab_freelist_hits;
        self.slab_fresh_bumps += slab_fresh_bumps;
        self.slab_large_allocs += slab_large_allocs;
        self.slab_raw_allocs += slab_raw_allocs;
        self.slab_raw_frees += slab_raw_frees;
        self.decommitted_chunks += decommitted_chunks;
        self.decommitted_bytes += decommitted_bytes;
        self.los_allocs += los_allocs;
        self.los_frees += los_frees;
        self.los_reuses += los_reuses;
        self.los_decommitted_bytes += los_decommitted_bytes;
        self.evacuated_objects += evacuated_objects;
        self.evacuated_bytes += evacuated_bytes;
        self.evacuated_chunks += evacuated_chunks;
    }

    /// Free-list hit rate of the slab allocator (0.0 when no slab
    /// allocation happened — e.g. the `system` backend).
    pub fn slab_hit_rate(&self) -> f64 {
        let tried = self.slab_freelist_hits + self.slab_fresh_bumps;
        if tried == 0 {
            0.0
        } else {
            self.slab_freelist_hits as f64 / tried as f64
        }
    }

    /// Unused committed-slab fraction at the allocator's fullest moment
    /// (0.0 when nothing was committed). Both terms are high-water marks
    /// — the committed *peak*, not the current (possibly decommitted)
    /// gauge — so the figure stays in [0, 1] on trimming heaps.
    pub fn slab_fragmentation(&self) -> f64 {
        if self.slab_committed_peak_bytes == 0 {
            0.0
        } else {
            1.0 - self.slab_block_peak_bytes as f64 / self.slab_committed_peak_bytes as f64
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "live={} objs / {} B (peak {} B), labels={}, copies: lazy={} eager={} thaw={} sro_skips={}, memo: hits={} misses={} swept={}, cross_refs={}, transplants={}, slab: chunks={} hits={} bumps={} large={} raw={}/{} decommitted={}",
            self.live_objects,
            self.live_bytes,
            self.peak_bytes,
            self.live_labels,
            self.lazy_copies,
            self.eager_copies,
            self.thaws,
            self.sro_skips,
            self.memo_hits,
            self.memo_misses,
            self.memo_swept,
            self.cross_refs,
            self.transplants,
            self.slab_chunks,
            self.slab_freelist_hits,
            self.slab_fresh_bumps,
            self.slab_large_allocs,
            self.slab_raw_allocs,
            self.slab_raw_frees,
            self.decommitted_chunks,
        )
    }
}

/// An open metrics scope (see [`Heap::begin_scope`](super::Heap::begin_scope)):
/// the snapshot against which [`HeapMetrics::delta_since`] computes the
/// exact operation delta of a bracketed region. One-shot by construction
/// (closing consumes it); scopes on the same heap may nest freely, since
/// each holds an independent snapshot.
pub struct MetricsScope {
    start: HeapMetrics,
}

impl MetricsScope {
    #[inline]
    pub(crate) fn open(at: &HeapMetrics) -> MetricsScope {
        MetricsScope { start: *at }
    }

    #[inline]
    pub(crate) fn close(self, now: &HeapMetrics) -> HeapMetrics {
        now.delta_since(&self.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water() {
        let mut m = HeapMetrics::default();
        m.live_bytes = 100;
        m.note_peak();
        assert_eq!(m.peak_bytes, 100);
        m.live_bytes = 50;
        m.note_peak();
        assert_eq!(m.peak_bytes, 100);
        m.memo_bytes = 80;
        m.note_peak();
        assert_eq!(m.peak_bytes, 130);
        m.reset_peak();
        assert_eq!(m.peak_bytes, 130);
        m.live_bytes = 0;
        m.memo_bytes = 0;
        m.reset_peak();
        assert_eq!(m.peak_bytes, 0);
    }

    #[test]
    fn summary_contains_counts() {
        let mut m = HeapMetrics::default();
        m.lazy_copies = 3;
        assert!(m.summary().contains("lazy=3"));
    }

    #[test]
    fn merge_counters_skips_gauges_and_keeps_balance() {
        let mut shard = HeapMetrics {
            live_objects: 4,
            live_bytes: 400,
            peak_bytes: 500,
            total_allocs: 10,
            total_frees: 6,
            pulls: 3,
            ..Default::default()
        };
        // A drained scratch heap: everything allocated was freed.
        let scratch = HeapMetrics {
            live_objects: 0,
            live_bytes: 0,
            peak_bytes: 999,
            total_allocs: 7,
            total_frees: 7,
            lazy_copies: 2,
            eager_copies: 5,
            pulls: 4,
            transplants: 1,
            ..Default::default()
        };
        shard.merge_counters(&scratch);
        assert_eq!(shard.total_allocs, 17);
        assert_eq!(shard.total_frees, 13);
        assert_eq!(shard.lazy_copies, 2);
        assert_eq!(shard.eager_copies, 5);
        assert_eq!(shard.pulls, 7);
        assert_eq!(shard.transplants, 1);
        // Gauges untouched.
        assert_eq!(shard.live_objects, 4);
        assert_eq!(shard.live_bytes, 400);
        assert_eq!(shard.peak_bytes, 500);
        // The per-shard invariant survives absorption.
        assert_eq!(shard.total_allocs, shard.total_frees + shard.live_objects);
    }

    #[test]
    fn delta_since_subtracts_counters_and_carries_gauges() {
        let mut m = HeapMetrics {
            total_allocs: 10,
            pulls: 4,
            lazy_copies: 2,
            live_objects: 5,
            live_bytes: 500,
            slab_freelist_hits: 3,
            ..Default::default()
        };
        let scope = MetricsScope::open(&m);
        m.total_allocs += 7;
        m.pulls += 2;
        m.eager_copies += 1;
        m.slab_freelist_hits += 4;
        m.live_objects = 9;
        let d = scope.close(&m);
        assert_eq!(d.total_allocs, 7);
        assert_eq!(d.pulls, 2);
        assert_eq!(d.eager_copies, 1);
        assert_eq!(d.lazy_copies, 0);
        assert_eq!(d.slab_freelist_hits, 4);
        // op_charge over the delta = allocs + copies + pulls in scope.
        assert_eq!(d.op_charge(), 7 + 0 + 1 + 2);
        // Gauges carry the current values.
        assert_eq!(d.live_objects, 9);
        assert_eq!(d.live_bytes, 500);
    }

    #[test]
    fn scratch_peak_folds_as_max_and_merges_as_max() {
        let mut m = HeapMetrics::default();
        m.note_scratch_peak(100);
        m.note_scratch_peak(60);
        assert_eq!(m.scratch_peak_bytes, 100);
        let mut a = HeapMetrics::default();
        a.merge(&m);
        assert_eq!(a.scratch_peak_bytes, 100);
        // merge_counters treats it as a gauge (skipped).
        let mut b = HeapMetrics::default();
        b.merge_counters(&m);
        assert_eq!(b.scratch_peak_bytes, 0);
    }

    #[test]
    fn slab_rates() {
        let m = HeapMetrics {
            slab_freelist_hits: 30,
            slab_fresh_bumps: 10,
            slab_committed_bytes: 400,
            slab_committed_peak_bytes: 1000,
            slab_block_peak_bytes: 600,
            ..Default::default()
        };
        assert!((m.slab_hit_rate() - 0.75).abs() < 1e-12);
        // Fragmentation divides by the committed *peak*, so a decommitted
        // heap (committed < peak) still reports a sane [0, 1] figure.
        assert!((m.slab_fragmentation() - 0.4).abs() < 1e-12);
        let z = HeapMetrics::default();
        assert_eq!(z.slab_hit_rate(), 0.0);
        assert_eq!(z.slab_fragmentation(), 0.0);
    }

    #[test]
    fn merge_adds_slab_counters_and_gauges() {
        let mut a = HeapMetrics {
            slab_chunks: 1,
            slab_committed_bytes: 100,
            slab_freelist_hits: 2,
            ..Default::default()
        };
        let b = HeapMetrics {
            slab_chunks: 2,
            slab_committed_bytes: 200,
            slab_freelist_hits: 3,
            slab_large_allocs: 1,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.slab_chunks, 3);
        assert_eq!(a.slab_committed_bytes, 300);
        assert_eq!(a.slab_freelist_hits, 5);
        assert_eq!(a.slab_large_allocs, 1);
        // merge_counters folds the counters but not the storage gauges.
        let mut c = HeapMetrics::default();
        c.merge_counters(&b);
        assert_eq!(c.slab_freelist_hits, 3);
        assert_eq!(c.slab_large_allocs, 1);
        assert_eq!(c.slab_chunks, 0);
        assert_eq!(c.slab_committed_bytes, 0);
    }

    #[test]
    fn raw_and_decommit_fields_classified() {
        // merge adds everything; merge_counters adds the raw/decommit
        // counters but skips the raw gauge; delta subtracts counters and
        // carries the gauge.
        let src = HeapMetrics {
            slab_raw_allocs: 5,
            slab_raw_frees: 3,
            slab_raw_bytes: 256,
            decommitted_chunks: 2,
            decommitted_bytes: 2 * 65536,
            ..Default::default()
        };
        let mut a = HeapMetrics::default();
        a.merge(&src);
        assert_eq!(a.slab_raw_allocs, 5);
        assert_eq!(a.slab_raw_frees, 3);
        assert_eq!(a.slab_raw_bytes, 256);
        assert_eq!(a.decommitted_chunks, 2);
        assert_eq!(a.decommitted_bytes, 2 * 65536);
        let mut b = HeapMetrics::default();
        b.merge_counters(&src);
        assert_eq!(b.slab_raw_allocs, 5);
        assert_eq!(b.slab_raw_frees, 3);
        assert_eq!(b.slab_raw_bytes, 0, "raw gauge dies with the scratch");
        assert_eq!(b.decommitted_chunks, 2);
        let scope = MetricsScope::open(&src);
        let mut later = src;
        later.slab_raw_allocs += 4;
        later.decommitted_chunks += 1;
        later.slab_raw_bytes = 512;
        let d = scope.close(&later);
        assert_eq!(d.slab_raw_allocs, 4);
        assert_eq!(d.decommitted_chunks, 1);
        assert_eq!(d.slab_raw_bytes, 512, "gauges carry current values");
    }

    #[test]
    fn note_raw_alloc_free_track_gauges() {
        let mut m = HeapMetrics::default();
        m.note_raw_alloc(&AllocReceipt {
            reused: false,
            large: false,
            block_bytes: 128,
            new_chunk: true,
            los_bytes: 0,
        });
        assert_eq!(m.slab_raw_allocs, 1);
        assert_eq!(m.slab_raw_bytes, 128);
        assert_eq!(m.slab_chunks, 1);
        assert_eq!(m.slab_committed_bytes, super::super::CHUNK_BYTES);
        assert_eq!(m.slab_committed_peak_bytes, super::super::CHUNK_BYTES);
        assert_eq!(m.slab_block_peak_bytes, 128, "raw bytes count in the peak");
        assert_eq!(m.los_allocs, 0, "slab raw path leaves LOS untouched");
        m.note_raw_free(&FreeReceipt {
            block_bytes: 128,
            los_bytes: 0,
        });
        assert_eq!(m.slab_raw_frees, 1);
        assert_eq!(m.slab_raw_bytes, 0);
        assert_eq!(m.slab_block_peak_bytes, 128, "peak is a high-water mark");
    }

    #[test]
    fn note_los_receipts_track_live_and_free_gauges() {
        let mut m = HeapMetrics::default();
        // Fresh LOS alloc (raw path, e.g. a 4 KiB memo bucket array).
        m.note_raw_alloc(&AllocReceipt {
            reused: false,
            large: true,
            block_bytes: 0,
            new_chunk: false,
            los_bytes: 4096 + 32,
        });
        assert_eq!(m.los_allocs, 1);
        assert_eq!(m.los_reuses, 0);
        assert_eq!(m.los_live_bytes, 4096 + 32);
        assert_eq!(m.los_free_bytes, 0);
        assert_eq!(m.slab_raw_allocs, 1, "LOS raw allocs still count as raw");
        assert_eq!(m.slab_raw_bytes, 0, "but not as slab block bytes");
        // Free it: live → free list.
        m.note_raw_free(&FreeReceipt {
            block_bytes: 0,
            los_bytes: 4096 + 32,
        });
        assert_eq!(m.los_frees, 1);
        assert_eq!(m.los_live_bytes, 0);
        assert_eq!(m.los_free_bytes, 4096 + 32);
        // Reuse it: free list → live, counted as a reuse.
        m.note_los_alloc(&AllocReceipt {
            reused: true,
            large: true,
            block_bytes: 0,
            new_chunk: false,
            los_bytes: 4096 + 32,
        });
        assert_eq!(m.los_allocs, 2);
        assert_eq!(m.los_reuses, 1);
        assert_eq!(m.los_live_bytes, 4096 + 32);
        assert_eq!(m.los_free_bytes, 0);
    }

    #[test]
    fn los_and_evacuation_fields_classified() {
        // merge adds everything; merge_counters adds the counters but
        // skips the storage gauges; delta subtracts counters and carries
        // the gauges.
        let src = HeapMetrics {
            los_allocs: 6,
            los_frees: 4,
            los_reuses: 2,
            los_live_bytes: 8192,
            los_free_bytes: 4096,
            los_decommitted_bytes: 2048,
            evacuated_objects: 10,
            evacuated_bytes: 640,
            evacuated_chunks: 1,
            ..Default::default()
        };
        let mut a = HeapMetrics::default();
        a.merge(&src);
        assert_eq!(a.los_allocs, 6);
        assert_eq!(a.los_frees, 4);
        assert_eq!(a.los_reuses, 2);
        assert_eq!(a.los_live_bytes, 8192);
        assert_eq!(a.los_free_bytes, 4096);
        assert_eq!(a.los_decommitted_bytes, 2048);
        assert_eq!(a.evacuated_objects, 10);
        assert_eq!(a.evacuated_bytes, 640);
        assert_eq!(a.evacuated_chunks, 1);
        let mut b = HeapMetrics::default();
        b.merge_counters(&src);
        assert_eq!(b.los_allocs, 6);
        assert_eq!(b.los_frees, 4);
        assert_eq!(b.los_reuses, 2);
        assert_eq!(b.los_live_bytes, 0, "LOS gauges die with the scratch");
        assert_eq!(b.los_free_bytes, 0, "LOS gauges die with the scratch");
        assert_eq!(b.los_decommitted_bytes, 2048);
        assert_eq!(b.evacuated_objects, 10);
        let scope = MetricsScope::open(&src);
        let mut later = src;
        later.los_allocs += 3;
        later.evacuated_objects += 5;
        later.los_live_bytes = 16384;
        let d = scope.close(&later);
        assert_eq!(d.los_allocs, 3);
        assert_eq!(d.evacuated_objects, 5);
        assert_eq!(d.los_live_bytes, 16384, "gauges carry current values");
        assert_eq!(d.los_free_bytes, 4096, "gauges carry current values");
    }

    #[test]
    fn merge_adds_counters() {
        let mut a = HeapMetrics {
            live_objects: 2,
            total_allocs: 5,
            total_frees: 3,
            peak_bytes: 100,
            transplants: 1,
            ..Default::default()
        };
        let b = HeapMetrics {
            live_objects: 1,
            total_allocs: 4,
            total_frees: 3,
            peak_bytes: 50,
            transplants: 2,
            ..Default::default()
        };
        a.global_peak_bytes = 90;
        a.merge(&b);
        assert_eq!(a.live_objects, 3);
        assert_eq!(a.total_allocs, 9);
        assert_eq!(a.total_frees, 6);
        assert_eq!(a.peak_bytes, 150);
        assert_eq!(a.transplants, 3);
        // Global barrier samples max, not add.
        assert_eq!(a.global_peak_bytes, 90);
        // The alloc/free/live balance survives aggregation.
        assert_eq!(a.total_allocs, a.total_frees + a.live_objects);
    }
}
