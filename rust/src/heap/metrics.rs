//! Heap instrumentation: allocation, copy, and memo counters.
//!
//! These counters drive the paper's evaluation: peak memory (Figures 5–6),
//! per-generation memory series (Figure 7), and the copy/sharing behaviour
//! that explains them (eager vs lazy vs lazy+SRO).

/// Counters maintained by the [`Heap`](super::Heap). All sizes are in bytes.
#[derive(Clone, Debug, Default)]
pub struct HeapMetrics {
    /// Objects currently live (payload not yet destroyed).
    pub live_objects: usize,
    /// Bytes in live payloads + per-object overhead.
    pub live_bytes: usize,
    /// High-water mark of `live_bytes` (+ label/memo bytes).
    pub peak_bytes: usize,
    /// Labels currently live.
    pub live_labels: usize,
    /// Bytes in live memo tables.
    pub memo_bytes: usize,

    /// Total objects ever allocated.
    pub total_allocs: usize,
    /// Shallow copies performed by `Copy` (Algorithm 6) — the lazy platform's
    /// actual object copies.
    pub lazy_copies: usize,
    /// Objects copied by eager deep copies (eager mode, or `Finish` of cross
    /// references in lazy mode).
    pub eager_copies: usize,
    /// `deep_copy` invocations.
    pub deep_copies: usize,
    /// Copies avoided by the in-place thaw optimization (sole-reference
    /// recycling at copy time, §3).
    pub thaws: usize,
    /// Memo insertions skipped by the single-reference optimization
    /// (Remark 1).
    pub sro_skips: usize,

    /// Memo lookups that hit / missed.
    pub memo_hits: usize,
    pub memo_misses: usize,
    /// Entries removed by memo sweeps.
    pub memo_swept: usize,

    /// `Pull` / `Get` operation counts.
    pub pulls: usize,
    pub gets: usize,
    /// Objects frozen by `Freeze` traversals.
    pub freezes: usize,
    /// Cross references encountered (edges outside the tree pattern).
    pub cross_refs: usize,
}

impl HeapMetrics {
    #[inline]
    pub(crate) fn note_peak(&mut self) {
        let now = self.live_bytes + self.memo_bytes;
        if now > self.peak_bytes {
            self.peak_bytes = now;
        }
    }

    /// Current footprint (live payloads + memo tables).
    pub fn current_bytes(&self) -> usize {
        self.live_bytes + self.memo_bytes
    }

    /// Reset the peak to the current footprint (for per-phase measurement).
    pub fn reset_peak(&mut self) {
        self.peak_bytes = self.current_bytes();
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "live={} objs / {} B (peak {} B), labels={}, copies: lazy={} eager={} thaw={} sro_skips={}, memo: hits={} misses={} swept={}, cross_refs={}",
            self.live_objects,
            self.live_bytes,
            self.peak_bytes,
            self.live_labels,
            self.lazy_copies,
            self.eager_copies,
            self.thaws,
            self.sro_skips,
            self.memo_hits,
            self.memo_misses,
            self.memo_swept,
            self.cross_refs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water() {
        let mut m = HeapMetrics::default();
        m.live_bytes = 100;
        m.note_peak();
        assert_eq!(m.peak_bytes, 100);
        m.live_bytes = 50;
        m.note_peak();
        assert_eq!(m.peak_bytes, 100);
        m.memo_bytes = 80;
        m.note_peak();
        assert_eq!(m.peak_bytes, 130);
        m.reset_peak();
        assert_eq!(m.peak_bytes, 130);
        m.live_bytes = 0;
        m.memo_bytes = 0;
        m.reset_peak();
        assert_eq!(m.peak_bytes, 0);
    }

    #[test]
    fn summary_contains_counts() {
        let mut m = HeapMetrics::default();
        m.lazy_copies = 3;
        assert!(m.summary().contains("lazy=3"));
    }
}
