//! Heap unit tests, including literal replays of the paper's Table 1
//! (tree-pattern lazy copies) and Table 2 (cross-reference eager fallback).

use super::*;
use crate::lazy_fields;

/// The paper's `class Node { value:Integer; next:Node; }`.
#[derive(Clone)]
struct Node {
    value: i64,
    next: Lazy<Node>,
}
lazy_fields!(Node: next);

fn node(heap: &mut Heap, value: i64) -> Lazy<Node> {
    heap.alloc(Node {
        value,
        next: Lazy::NULL,
    })
}

/// Build the list x1 -> y1 -> z1 with values (1, 2, 3); returns the head
/// handle (interior handles are stored, then released).
fn build_list(heap: &mut Heap) -> Lazy<Node> {
    let z1 = node(heap, 3);
    let y1 = node(heap, 2);
    let x1 = node(heap, 1);
    let mut x = x1;
    heap.mutate_root(&mut x, |n| n.next = y1);
    let mut y = y1;
    heap.mutate_root(&mut y, |n| n.next = z1);
    // Stored edges now own them; release the stack handles.
    heap.release(y1);
    heap.release(z1);
    x
}

fn list_values(heap: &mut Heap, head: &Lazy<Node>) -> Vec<i64> {
    let mut out = Vec::new();
    let mut cur = *head;
    while !cur.is_null() {
        out.push(heap.read(&mut cur, |n| n.value));
        cur = heap.read_ptr(&mut cur, |n| n.next);
    }
    out
}

fn for_each_mode(f: impl Fn(CopyMode)) {
    for mode in CopyMode::ALL {
        f(mode);
    }
}

#[test]
fn alloc_read_release() {
    for_each_mode(|mode| {
        let mut heap = Heap::new(mode);
        let mut x = node(&mut heap, 42);
        assert_eq!(heap.read(&mut x, |n| n.value), 42);
        assert_eq!(heap.live_objects(), 1);
        heap.validate(&[x.raw()]);
        heap.release(x);
        assert_eq!(heap.live_objects(), 0, "mode {mode:?}");
        heap.validate(&[]);
    });
}

#[test]
fn list_teardown_cascades() {
    for_each_mode(|mode| {
        let mut heap = Heap::new(mode);
        let head = build_list(&mut heap);
        assert_eq!(heap.live_objects(), 3);
        heap.validate(&[head.raw()]);
        heap.release(head);
        assert_eq!(heap.live_objects(), 0, "mode {mode:?}");
    });
}

#[test]
fn table1_trace_lazy() {
    // The paper's Table 1, replayed against the lazy heap. Assertions on
    // object counts verify the exact copy/share structure at each row.
    for mode in [CopyMode::Lazy, CopyMode::LazySro] {
        let mut heap = Heap::new(mode);
        let x1 = build_list(&mut heap);
        assert_eq!(heap.live_objects(), 3);

        // x2:Node <- deep_copy(x1): a new label and edge, no new vertex.
        let mut x2 = heap.deep_copy(&x1);
        assert_eq!(heap.live_objects(), 3, "deep copy allocates no objects");
        assert_ne!(x2.label(), x1.label());
        assert_eq!(x2.obj(), x1.obj(), "the handle shares the original");
        assert!(heap.is_frozen(x1.obj()));

        // value <- x2.value: read-only access, copy not required.
        let v = heap.read(&mut x2, |n| n.value);
        assert_eq!(v, 1);
        assert_eq!(heap.live_objects(), 3, "reads never copy");

        // x2.value <- value: write access, copy required (head only).
        heap.mutate_root(&mut x2, |n| n.value = 10);
        assert_eq!(heap.live_objects(), 4, "only the head was copied");
        assert_ne!(x2.obj(), x1.obj());

        // y2 <- x2.next; z2 <- y2.next: traversal with write access copies
        // each node along the way (get-chain, per the Table 1 commentary).
        let read_y2 = heap.read_ptr(&mut x2, |n| n.next);
        assert_eq!(
            read_y2.label(),
            x2.label(),
            "tree-pattern field adopts reader label"
        );
        let mut y2 = heap.get_field(&x2, |n| &mut n.next);
        heap.mutate(&mut y2, |n| n.value = 20);
        assert_eq!(heap.live_objects(), 5);

        // value <- z2.value: read-only, no copy.
        let mut z2r = heap.read_ptr(&mut y2, |n| n.next);
        assert_eq!(heap.read(&mut z2r, |n| n.value), 3);
        assert_eq!(heap.live_objects(), 5);

        // z2.value <- value: copy required.
        let mut z2 = heap.get_field(&y2, |n| &mut n.next);
        heap.mutate(&mut z2, |n| n.value = 30);
        assert_eq!(heap.live_objects(), 6);

        // Both lists observe their own values; the original is intact.
        let mut x1m = x1;
        assert_eq!(list_values(&mut heap, &x1m), vec![1, 2, 3]);
        assert_eq!(list_values(&mut heap, &x2), vec![10, 20, 30]);
        let _ = &mut x1m;

        heap.validate(&[x1.raw(), x2.raw()]);

        // Releasing the copy frees exactly the copied nodes.
        heap.release(x2);
        assert_eq!(heap.live_objects(), 3, "mode {mode:?}");
        assert_eq!(list_values(&mut heap, &x1), vec![1, 2, 3]);
        heap.release(x1);
        assert_eq!(heap.live_objects(), 0);
        assert_eq!(heap.live_labels(), 1, "only the root label remains");
    }
}

#[test]
fn table1_trace_eager_equivalent() {
    // Same program under eager copies: identical observable values,
    // maximal object count.
    let mut heap = Heap::new(CopyMode::Eager);
    let x1 = build_list(&mut heap);
    let mut x2 = heap.deep_copy(&x1);
    assert_eq!(heap.live_objects(), 6, "eager deep copy copies everything");
    heap.mutate_root(&mut x2, |n| n.value = 10);
    let mut y2 = heap.read_ptr(&mut x2, |n| n.next);
    heap.mutate(&mut y2, |n| n.value = 20);
    let mut z2 = heap.read_ptr(&mut y2, |n| n.next);
    heap.mutate(&mut z2, |n| n.value = 30);
    assert_eq!(list_values(&mut heap, &x1), vec![1, 2, 3]);
    assert_eq!(list_values(&mut heap, &x2), vec![10, 20, 30]);
    heap.validate(&[x1.raw(), x2.raw()]);
    heap.release(x1);
    heap.release(x2);
    assert_eq!(heap.live_objects(), 0);
}

#[test]
fn table2_cross_reference() {
    // The paper's Table 2: an assignment creates a cross reference; the
    // eager Finish in Copy (Algorithm 6) preserves correctness. The final
    // read must print 1 (the paper's "correct" row), not 2 (the
    // counterfactual produced without cross-reference handling).
    for mode in [CopyMode::Eager, CopyMode::Lazy, CopyMode::LazySro] {
        let mut heap = Heap::new(mode);
        let x1 = node(&mut heap, 1);

        let mut x2 = heap.deep_copy(&x1);
        heap.mutate_root(&mut x2, |n| n.value = 2);
        assert_ne!(x2.obj(), x1.obj());

        // x2.next <- x1: establishes a cross reference (lazy modes: the
        // stored edge's label differs from f(x2)). Storing the pointer adds
        // its own count; the stack handle x1 keeps its own.
        heap.mutate_root(&mut x2, |n| n.next = x1);

        let mut x3 = heap.deep_copy(&x2);
        heap.mutate_root(&mut x3, |n| n.value = 3);

        // y3 <- x3.next; print(y3.value): must print 1.
        let mut y3 = heap.read_ptr(&mut x3, |n| n.next);
        let printed = heap.read(&mut y3, |n| n.value);
        assert_eq!(printed, 1, "mode {mode:?}: cross reference mishandled");

        // And the x2 view is unperturbed.
        let mut y2 = heap.read_ptr(&mut x2, |n| n.next);
        assert_eq!(heap.read(&mut y2, |n| n.value), 1);
        assert_eq!(heap.read(&mut x2, |n| n.value), 2);
        assert_eq!(heap.read(&mut x3, |n| n.value), 3);

        heap.validate(&[x1.raw(), x2.raw(), x3.raw()]);
        heap.release(x3);
        heap.release(x2);
        heap.release(x1);
        assert_eq!(heap.live_objects(), 0, "mode {mode:?}");
    }
}

#[test]
fn mutation_after_copy_is_private() {
    for mode in [CopyMode::Lazy, CopyMode::LazySro] {
        let mut heap = Heap::new(mode);
        let x1 = build_list(&mut heap);
        let mut a = heap.deep_copy(&x1);
        let mut b = heap.deep_copy(&x1);
        heap.mutate_root(&mut a, |n| n.value = 100);
        heap.mutate_root(&mut b, |n| n.value = 200);
        assert_eq!(list_values(&mut heap, &a), vec![100, 2, 3]);
        assert_eq!(list_values(&mut heap, &b), vec![200, 2, 3]);
        assert_eq!(list_values(&mut heap, &x1), vec![1, 2, 3]);
        // Tails are shared: 3 originals + 2 copied heads.
        assert_eq!(heap.live_objects(), 5);
        heap.validate(&[x1.raw(), a.raw(), b.raw()]);
        heap.release(a);
        heap.release(b);
        heap.release(x1);
        assert_eq!(heap.live_objects(), 0);
    }
}

#[test]
fn chained_deep_copies_pull_through_memo_chain() {
    // x -> copy under l2 (written) -> copy under l3 (written): pulls must
    // chase the memo chain v <- m_l(v) repeatedly (Algorithm 4's while).
    for mode in [CopyMode::Lazy, CopyMode::LazySro] {
        let mut heap = Heap::new(mode);
        let g1 = node(&mut heap, 1);
        let mut g2 = heap.deep_copy(&g1);
        heap.mutate_root(&mut g2, |n| n.value = 2);
        let mut g3 = heap.deep_copy(&g2);
        heap.mutate_root(&mut g3, |n| n.value = 3);
        let mut g4 = heap.deep_copy(&g3);
        heap.mutate_root(&mut g4, |n| n.value = 4);
        assert_eq!(heap.read(&mut g1.clone(), |n| n.value), 1);
        assert_eq!(heap.read(&mut g2, |n| n.value), 2);
        assert_eq!(heap.read(&mut g3, |n| n.value), 3);
        assert_eq!(heap.read(&mut g4, |n| n.value), 4);
        heap.validate(&[g1.raw(), g2.raw(), g3.raw(), g4.raw()]);
        for h in [g1, g2, g3, g4] {
            heap.release(h);
        }
        assert_eq!(heap.live_objects(), 0, "mode {mode:?}");
    }
}

#[test]
fn resampling_pattern_shares_ancestors() {
    // The motivating pattern (Figure 2): at each generation, deep-copy a
    // surviving particle and extend it. The ancestry chain is shared, so
    // live objects grow O(T + survivors), not O(N*T).
    for mode in [CopyMode::Lazy, CopyMode::LazySro] {
        let mut heap = Heap::new(mode);
        let n = 8usize;
        let t_max = 20usize;
        // Each particle: a cons-list of states, newest first.
        let mut particles: Vec<Lazy<Node>> = (0..n).map(|i| node(&mut heap, i as i64)).collect();
        for t in 1..t_max {
            // "Resample": all offspring from parent 0 (worst-case sharing).
            let parent = particles[0];
            let mut next: Vec<Lazy<Node>> = Vec::new();
            for i in 0..n {
                let child = heap.deep_copy(&parent);
                // Extend with a new head node (the new state at time t),
                // allocated *in the child's context* (Condition 4) so the
                // tail edge is tree-pattern, not a cross reference. The
                // stored edge owns its count; the stack handle is released.
                let head = heap.with_context(child.label(), |h| {
                    h.alloc(Node {
                        value: (t * n + i) as i64,
                        next: child,
                    })
                });
                heap.release(child);
                next.push(head);
            }
            for p in particles {
                heap.release(p);
            }
            particles = next;
        }
        // Chain depth t_max; N distinct heads per generation but shared
        // tails: far fewer than n * t_max live objects.
        assert!(
            heap.live_objects() < n * t_max / 2,
            "mode {mode:?}: {} live objects, expected sharing",
            heap.live_objects()
        );
        let roots: Vec<RawLazy> = particles.iter().map(|p| p.raw()).collect();
        heap.validate(&roots);
        for p in particles {
            heap.release(p);
        }
        assert_eq!(heap.live_objects(), 0);
    }
}

#[test]
fn sro_reduces_memo_traffic() {
    // The single-reference optimization must produce identical reads with
    // fewer memo insertions.
    let run = |mode: CopyMode| -> (Vec<i64>, usize) {
        let mut heap = Heap::new(mode);
        let x1 = build_list(&mut heap);
        let mut x2 = heap.deep_copy(&x1);
        heap.mutate_root(&mut x2, |n| n.value = 10);
        let mut y2 = heap.get_field(&x2, |n| &mut n.next);
        heap.mutate(&mut y2, |n| n.value = 20);
        let vals = list_values(&mut heap, &x2);
        let skips = heap.metrics.sro_skips;
        heap.release(x1);
        heap.release(x2);
        (vals, skips)
    };
    let (lazy_vals, lazy_skips) = run(CopyMode::Lazy);
    let (sro_vals, sro_skips) = run(CopyMode::LazySro);
    assert_eq!(lazy_vals, sro_vals);
    assert_eq!(lazy_skips, 0);
    assert!(sro_skips > 0, "SRO should skip at least the head copy memo");
}

#[test]
fn thaw_recycles_sole_reference() {
    // deep_copy then immediately drop the copy: writing through the
    // original handle thaws in place instead of copying.
    for mode in [CopyMode::Lazy, CopyMode::LazySro] {
        let mut heap = Heap::new(mode);
        let mut x = node(&mut heap, 1);
        let c = heap.deep_copy(&x);
        heap.release(c); // label dies; x frozen with sole reference
        heap.mutate_root(&mut x, |n| n.value = 2);
        assert_eq!(heap.metrics.thaws, 1, "mode {mode:?}");
        assert_eq!(heap.metrics.lazy_copies, 0);
        assert_eq!(heap.live_objects(), 1);
        assert_eq!(heap.read(&mut x, |n| n.value), 2);
        assert!(!heap.is_frozen(x.obj()));
        heap.release(x);
        assert_eq!(heap.live_objects(), 0);
    }
}

#[test]
fn label_death_reclaims_private_copies() {
    // Copies made under a label die with the label when nothing else
    // references them (memo values hold the only count).
    for mode in [CopyMode::Lazy, CopyMode::LazySro] {
        let mut heap = Heap::new(mode);
        let x1 = build_list(&mut heap);
        let mut x2 = heap.deep_copy(&x1);
        heap.mutate_root(&mut x2, |n| n.value = 10);
        let mut y2 = heap.get_field(&x2, |n| &mut n.next);
        heap.mutate(&mut y2, |n| n.value = 20);
        assert_eq!(heap.live_objects(), 5);
        heap.release(x2);
        assert_eq!(
            heap.live_objects(),
            3,
            "mode {mode:?}: label death should free private copies"
        );
        assert_eq!(list_values(&mut heap, &x1), vec![1, 2, 3]);
        heap.validate(&[x1.raw()]);
        heap.release(x1);
        assert_eq!(heap.live_objects(), 0);
    }
}

#[test]
fn deep_copy_of_dag_preserves_sharing_eagerly() {
    // A diamond: root -> (a, b) -> shared leaf. Eager deep copy must copy
    // the leaf exactly once (the paper's Fig. 3 deep copy caveat).
    #[derive(Clone)]
    struct Pair {
        a: Lazy<Node>,
        b: Lazy<Node>,
    }
    lazy_fields!(Pair: a, b);

    let mut heap = Heap::new(CopyMode::Eager);
    let leaf = node(&mut heap, 7);
    // Storing `leaf` into a payload adds an owning edge count each time;
    // the stack handle keeps its own count until released.
    let a = heap.alloc(Node {
        value: 1,
        next: leaf,
    });
    let b = heap.alloc(Node {
        value: 2,
        next: leaf,
    });
    heap.release(leaf);
    let root = heap.alloc(Pair { a, b });
    heap.release(a);
    heap.release(b);
    assert_eq!(heap.live_objects(), 4);

    let copy = heap.deep_copy(&root);
    assert_eq!(heap.live_objects(), 8, "diamond copied with sharing intact");
    let mut ca = heap.read_ptr(&mut copy.clone(), |p| p.a);
    let mut cb = heap.read_ptr(&mut copy.clone(), |p| p.b);
    let la = heap.read_ptr(&mut ca, |n| n.next);
    let lb = heap.read_ptr(&mut cb, |n| n.next);
    assert_eq!(la.obj(), lb.obj(), "shared leaf stays shared in the copy");
    heap.validate(&[root.raw(), copy.raw()]);
    heap.release(root);
    heap.release(copy);
    assert_eq!(heap.live_objects(), 0);
}

#[test]
fn ragged_array_payloads() {
    // Vec<Lazy<_>> fields: growth and shrinkage through mutate keeps
    // reference counts exact.
    #[derive(Clone, Default)]
    struct Bag {
        items: Vec<Lazy<Node>>,
    }
    lazy_fields!(Bag: items);

    for_each_mode(|mode| {
        let mut heap = Heap::new(mode);
        let mut bag = heap.alloc(Bag::default());
        for i in 0..10 {
            let item = node(&mut heap, i);
            heap.mutate_root(&mut bag, |b| b.items.push(item));
            heap.release(item);
        }
        assert_eq!(heap.live_objects(), 11);
        // Drop half the items.
        heap.mutate_root(&mut bag, |b| {
            b.items.retain(|p| {
                // keep even-indexed items by address parity of value: the
                // closure has no heap access, so filter by position instead
                true && !p.is_null()
            });
            b.items.truncate(5);
        });
        assert_eq!(heap.live_objects(), 6, "mode {mode:?}");
        heap.validate(&[bag.raw()]);
        // Deep copy the bag and mutate one branch.
        let mut copy = heap.deep_copy(&bag);
        heap.mutate_root(&mut copy, |b| b.items.truncate(2));
        let n_bag = heap.read(&mut bag.clone(), |b| b.items.len());
        let n_copy = heap.read(&mut copy, |b| b.items.len());
        assert_eq!((n_bag, n_copy), (5, 2));
        heap.validate(&[bag.raw(), copy.raw()]);
        heap.release(copy);
        heap.release(bag);
        assert_eq!(heap.live_objects(), 0);
    });
}

#[test]
fn deep_copy_null_is_null() {
    let mut heap = Heap::new(CopyMode::Lazy);
    let p: Lazy<Node> = Lazy::NULL;
    let q = heap.deep_copy(&p);
    assert!(q.is_null());
}

#[test]
fn metrics_track_copies() {
    let mut heap = Heap::new(CopyMode::Lazy);
    let x1 = build_list(&mut heap);
    let mut x2 = heap.deep_copy(&x1);
    assert_eq!(heap.metrics.deep_copies, 1);
    assert_eq!(heap.metrics.lazy_copies, 0);
    heap.mutate_root(&mut x2, |n| n.value = 9);
    assert_eq!(heap.metrics.lazy_copies, 1);
    assert!(heap.metrics.peak_bytes > 0);
    assert!(heap.metrics.summary().contains("lazy=1"));
    heap.release(x1);
    heap.release(x2);
}
