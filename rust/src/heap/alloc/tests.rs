//! Allocator unit + property tests: class math, free-list reuse, the
//! large-object space (round-trips, first-fit reuse, the 2× waste bound,
//! scratch reset immunity), the raw (memo/label) path, scratch
//! bump/reset, the decommit watermark, evacuation (victim selection,
//! pinning, value preservation), and fuzz runs proving random
//! alloc/free/copy/transplant sequences balance to zero live storage
//! with gauges consistent, on both backends and with decommit on. The
//! chunk-liveness oracle fuzz keeps a ground-truth shadow recount of
//! every per-chunk counter and cross-checks it after every single
//! operation; `LAZYCOW_FUZZ_ITERS` elevates the iteration count (the
//! CI heap-stress job does).

use super::*;
use crate::heap::{CopyMode, Heap, HeapMetrics, Lazy, MemoTable, ObjId};
use crate::lazy_fields;
use crate::rng::Pcg64;
use std::collections::HashMap;

/// Fuzz iteration budget: the default, unless `LAZYCOW_FUZZ_ITERS` asks
/// for a longer run (the CI heap-stress job sets it).
fn fuzz_iters(default: usize) -> usize {
    std::env::var("LAZYCOW_FUZZ_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[derive(Clone)]
struct Small {
    a: u64,
}
lazy_fields!(Small);

#[derive(Clone)]
struct Mid {
    a: [u64; 12],
}
lazy_fields!(Mid);

#[derive(Clone)]
struct Huge {
    a: [u64; 300], // 2400 B > largest class: exact-layout path
}
lazy_fields!(Huge);

#[derive(Clone)]
#[repr(align(64))]
struct Aligned {
    a: [u64; 8], // fits the 64 B class by size, but over-aligned: LOS
}
lazy_fields!(Aligned);

#[derive(Clone)]
struct Unit;
lazy_fields!(Unit);

#[test]
fn class_for_rounds_up_and_rejects_misfits() {
    let l = |s: usize, a: usize| Layout::from_size_align(s, a).unwrap();
    assert_eq!(class_for(l(1, 1)), Some(0));
    assert_eq!(class_for(l(16, 8)), Some(0));
    assert_eq!(class_for(l(17, 8)), Some(1));
    assert_eq!(class_for(l(96, 16)), Some(4));
    assert_eq!(class_for(l(2048, 16)), Some(SIZE_CLASSES.len() - 1));
    assert_eq!(class_for(l(2049, 16)), None, "over the largest class");
    assert_eq!(class_for(l(64, 32)), None, "over-aligned");
    for (i, b) in SIZE_CLASSES.iter().enumerate() {
        assert_eq!(b % BLOCK_ALIGN, 0, "class {i} not block-aligned");
        assert_eq!(class_for(l(*b, BLOCK_ALIGN)), Some(i));
    }
}

#[test]
fn freelist_reuses_the_freed_block() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let (p1, r1) = a.alloc_value(Small { a: 7 });
    assert!(!r1.reused && !r1.large && r1.new_chunk);
    assert_eq!(r1.block_bytes, 16);
    let addr1 = &*p1 as *const dyn Payload as *const u8 as usize;
    let fr = a.dealloc(p1);
    assert_eq!(fr.block_bytes, 16);
    assert_eq!(a.live_blocks(), 0);
    // Same class: the freed block comes straight back.
    let (p2, r2) = a.alloc_value(Small { a: 8 });
    assert!(r2.reused && !r2.new_chunk);
    let addr2 = &*p2 as *const dyn Payload as *const u8 as usize;
    assert_eq!(addr1, addr2, "free list must hand the block back");
    // A different class bumps fresh storage instead.
    let (p3, r3) = a.alloc_value(Mid { a: [0; 12] });
    assert!(!r3.reused && r3.new_chunk, "first Mid alloc opens its class");
    assert_eq!(r3.block_bytes, 96);
    a.dealloc(p2);
    a.dealloc(p3);
    assert_eq!(a.live_blocks(), 0);
}

#[test]
fn bump_fills_chunks_then_grows() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let per_chunk = CHUNK_BYTES / 16;
    let mut held = Vec::new();
    let mut chunks = 0;
    for i in 0..per_chunk + 1 {
        let (p, r) = a.alloc_value(Small { a: i as u64 });
        assert!(!r.reused);
        chunks += usize::from(r.new_chunk);
        held.push(p);
    }
    assert_eq!(chunks, 2, "one chunk filled exactly, a second opened");
    for p in held {
        a.dealloc(p);
    }
    assert_eq!(a.live_blocks(), 0);
}

#[test]
fn large_payloads_take_the_off_slab_path() {
    // Large payloads bypass the slabs on both backends — the LOS under
    // `Slab`, exact layout under `System` (which sends everything that
    // way and owns no LOS).
    for kind in AllocatorKind::ALL {
        let mut a = SlabAlloc::new(kind);
        let (h, rh) = a.alloc_value(Huge { a: [1; 300] });
        assert!(rh.large && !rh.reused && rh.block_bytes == 0);
        assert_eq!(rh.los_bytes > 2400, kind == AllocatorKind::Slab);
        let (s, rs) = a.alloc_value(Small { a: 2 });
        assert_eq!(rs.large, kind == AllocatorKind::System);
        assert_eq!(rs.los_bytes, 0, "small payloads never touch the LOS");
        let fh = a.dealloc(h);
        assert_eq!(fh.block_bytes, 0);
        assert_eq!(fh.los_bytes, rh.los_bytes, "LOS free returns the full block");
        let fs = a.dealloc(s);
        assert_eq!(fs.block_bytes != 0, kind == AllocatorKind::Slab);
        assert_eq!(a.live_blocks(), 0);
        a.validate_counters();
    }
}

#[test]
fn zero_sized_payloads_own_no_storage() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let (p, r) = a.alloc_value(Unit);
    assert!(!r.reused && !r.large && r.block_bytes == 0 && !r.new_chunk);
    assert_eq!(a.live_blocks(), 0);
    assert_eq!(a.dealloc(p).block_bytes, 0);
}

#[test]
fn clone_and_adopt_preserve_values() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let (orig, _) = a.alloc_value(Mid { a: [3; 12] });
    let (copy, _) = a.alloc_clone(&*orig);
    let got = copy.as_any().downcast_ref::<Mid>().unwrap().a;
    assert_eq!(got, [3; 12]);
    let boxed: Box<dyn Payload> = Box::new(Small { a: 99 });
    let (adopted, r) = a.adopt_box(boxed);
    assert!(!r.large);
    assert_eq!(adopted.as_any().downcast_ref::<Small>().unwrap().a, 99);
    a.dealloc(orig);
    a.dealloc(copy);
    a.dealloc(adopted);
    assert_eq!(a.live_blocks(), 0);
}

#[test]
fn scratch_is_bump_only_and_resets_keeping_chunks() {
    let mut a = SlabAlloc::scratch(AllocatorKind::Slab);
    assert!(a.is_bump_only());
    let mut grew = 0;
    for round in 0..3 {
        let mut held = Vec::new();
        for i in 0..100u64 {
            let (p, r) = a.alloc_value(Mid { a: [i; 12] });
            assert!(!r.reused, "bump-only never builds a free list");
            grew += usize::from(r.new_chunk);
            held.push(p);
        }
        for p in held {
            assert_eq!(a.dealloc(p).block_bytes, 96);
        }
        assert_eq!(a.live_blocks(), 0);
        a.reset();
        assert_eq!(grew, 1, "round {round}: reset must retain the chunk");
    }
}

#[test]
#[should_panic(expected = "reset with live slab blocks")]
fn reset_rejects_live_blocks() {
    let mut a = SlabAlloc::scratch(AllocatorKind::Slab);
    let (_p, _) = a.alloc_value(Small { a: 1 });
    a.reset();
}

#[test]
fn alloc_raw_class_math_and_reuse() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    // Class rounding matches the payload path: 100 B → the 128 B class.
    let l128 = Layout::from_size_align(100, 8).unwrap();
    let (p1, loc1, r1) = a.alloc_raw(l128);
    assert!(!r1.reused && !r1.large && r1.new_chunk);
    assert_eq!(r1.block_bytes, 128);
    assert_eq!(a.live_blocks(), 1);
    // Free and re-allocate the same class: the block comes straight back.
    let f = a.free_raw(p1, l128, loc1);
    assert_eq!(f.block_bytes, 128);
    assert_eq!(a.live_blocks(), 0);
    let (p2, loc2, r2) = a.alloc_raw(Layout::from_size_align(128, 8).unwrap());
    assert!(r2.reused && !r2.new_chunk);
    assert_eq!(p1, p2, "raw free list must hand the block back");
    a.free_raw(p2, l128, loc2);
    // Over the largest class: the large-object space takes it.
    let big = Layout::from_size_align(4096, 8).unwrap();
    let (pb, locb, rb) = a.alloc_raw(big);
    assert!(rb.large && rb.block_bytes == 0 && !rb.new_chunk);
    assert!(matches!(locb, BlockLoc::Los) && rb.los_bytes > 4096);
    a.free_raw(pb, big, locb);
    assert_eq!(a.live_blocks(), 0);
}

#[test]
fn raw_path_is_off_slab_for_scratch_and_system() {
    // Bump-only (scratch) allocators must keep raw blocks out of the
    // rewindable chunks — every scratch raw request goes to the LOS; the
    // System backend has no chunks at all and takes exact layout.
    for mut a in [
        SlabAlloc::scratch(AllocatorKind::Slab),
        SlabAlloc::new(AllocatorKind::System),
    ] {
        let l = Layout::from_size_align(64, 8).unwrap();
        let (p, loc, r) = a.alloc_raw(l);
        assert!(r.large && r.block_bytes == 0 && !r.new_chunk);
        assert!(!matches!(loc, BlockLoc::Slab { .. }));
        assert_eq!(a.live_blocks(), 0, "off-slab raw blocks are not slab-live");
        a.free_raw(p, l, loc);
        if a.is_bump_only() {
            a.reset(); // raw storage must survive the rewind contract
        }
    }
}

#[test]
fn scratch_raw_storage_lives_in_los_and_survives_reset() {
    let mut a = SlabAlloc::scratch(AllocatorKind::Slab);
    let l = Layout::from_size_align(64, 8).unwrap();
    let (p, loc, r) = a.alloc_raw(l);
    assert!(matches!(loc, BlockLoc::Los), "scratch raw storage must be reset-immune");
    assert!(r.los_bytes > 64, "header accounted");
    a.free_raw(p, l, loc);
    a.reset();
    // The freed block sat out the rewind on the LOS free list; a
    // recycled scratch gets it straight back.
    let (p2, loc2, r2) = a.alloc_raw(l);
    assert!(r2.reused, "recycled scratch must reuse its old LOS block");
    assert_eq!(p, p2, "first fit must return the previously freed block");
    a.free_raw(p2, l, loc2);
    a.validate_counters();
}

#[test]
fn memo_rehash_reuses_freed_buckets() {
    // Growing a memo table frees its outgrown bucket blocks into the
    // class free lists; the next same-class raw allocation — a rehash of
    // any other table — reuses them instead of bumping fresh storage.
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let mut m = HeapMetrics::default();
    let mut t = MemoTable::new();
    {
        let mut ctx = RawCtx {
            alloc: &mut a,
            metrics: &mut m,
        };
        for i in 0..100u32 {
            t.insert(&mut ctx, ObjId::new(i, 0), ObjId::new(i + 1000, 0));
        }
    }
    assert!(m.slab_raw_frees > 0, "rehashes must free outgrown blocks");
    // The table grew 8 → 16 → ... → 256 buckets; the outgrown blocks
    // (128 B ... 2 KiB) are all back on their free lists. A fresh table
    // growing through the same sizes reuses every one of them.
    let chunks_before = m.slab_chunks;
    let mut t2 = MemoTable::new();
    {
        let mut ctx = RawCtx {
            alloc: &mut a,
            metrics: &mut m,
        };
        for i in 0..100u32 {
            t2.insert(&mut ctx, ObjId::new(i, 0), ObjId::new(i + 1000, 0));
        }
        assert_eq!(
            ctx.metrics.slab_chunks, chunks_before,
            "second table must reuse the first table's freed buckets"
        );
        t.drain_all(&mut ctx);
        t2.drain_all(&mut ctx);
    }
    assert_eq!(m.slab_raw_bytes, 0);
    assert_eq!(a.live_blocks(), 0);
}

#[test]
fn slab_vec_grows_through_raw_path_and_keeps_values() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let mut m = HeapMetrics::default();
    let mut v: SlabVec<u64> = SlabVec::new();
    {
        let mut ctx = RawCtx {
            alloc: &mut a,
            metrics: &mut m,
        };
        for i in 0..100u64 {
            v.push(&mut ctx, i * 3);
        }
    }
    assert_eq!(v.len(), 100);
    for (i, x) in v.iter().enumerate() {
        assert_eq!(*x, i as u64 * 3);
    }
    v[7] = 99;
    assert_eq!(v[7], 99);
    assert!(m.slab_raw_allocs > 1, "growth reallocates");
    assert_eq!(m.slab_raw_frees, m.slab_raw_allocs - 1, "old stores freed");
    assert!(m.slab_raw_bytes > 0, "backing store is slab-live");
}

#[test]
fn trim_decommits_empty_chunks_past_watermark() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let per_chunk = CHUNK_BYTES / 16;
    // Fill three chunks of the 16 B class, then free everything.
    let mut held = Vec::new();
    for i in 0..per_chunk * 3 {
        held.push(a.alloc_value(Small { a: i as u64 }).0);
    }
    for p in held {
        a.dealloc(p);
    }
    assert_eq!(a.live_blocks(), 0);
    // keep=1: two of the three fully-empty chunks go back to the OS.
    let stats = a.trim(1);
    assert_eq!(stats.chunks, 2);
    assert_eq!(stats.bytes, 2 * CHUNK_BYTES);
    // Idempotent at the watermark.
    let stats = a.trim(1);
    assert_eq!(stats.chunks, 0);
    // The retained chunk still serves allocations (free list survived).
    let (p, r) = a.alloc_value(Small { a: 7 });
    assert!(r.reused && !r.new_chunk, "retained chunk's free list must survive trim");
    a.dealloc(p);
    // keep=0: everything goes.
    let stats = a.trim(0);
    assert_eq!(stats.chunks, 1);
    // And the class still works from scratch afterwards.
    let (p, r) = a.alloc_value(Small { a: 8 });
    assert!(!r.reused && r.new_chunk);
    a.dealloc(p);
}

#[test]
fn trim_never_touches_chunks_with_live_blocks() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let per_chunk = CHUNK_BYTES / 16;
    // Two chunks; keep one block live in the first chunk.
    let mut held = Vec::new();
    for i in 0..per_chunk + 10 {
        held.push(a.alloc_value(Small { a: i as u64 }));
    }
    let (keep_alive, _) = held.remove(0);
    let addr = &*keep_alive as *const dyn Payload as *const u8 as usize;
    for (p, _) in held {
        a.dealloc(p);
    }
    // Chunk 0 has a live block; only chunk 1 is empty.
    let stats = a.trim(0);
    assert_eq!(stats.chunks, 1, "only the fully-empty chunk may go");
    // The live block is untouched and still frees cleanly.
    let got = &*keep_alive as *const dyn Payload as *const u8 as usize;
    assert_eq!(addr, got);
    assert_eq!(
        keep_alive.as_any().downcast_ref::<Small>().unwrap().a,
        0,
        "live payload intact after decommit"
    );
    a.dealloc(keep_alive);
    assert_eq!(a.live_blocks(), 0);
    assert_eq!(a.trim(0).chunks, 1);
}

#[test]
fn heap_trim_updates_gauges_and_counters() {
    let mut heap = Heap::new(CopyMode::LazySro);
    // Churn enough payload to commit several chunks, then drain.
    let mut roots = Vec::new();
    for i in 0..2000i64 {
        roots.push(build_chain(&mut heap, 4, i));
    }
    for r in roots {
        heap.release(r);
    }
    heap.sweep_memos();
    let before = heap.metrics;
    assert!(before.slab_chunks > 2, "churn should commit several chunks");
    heap.trim(1);
    let after = heap.metrics;
    assert!(after.decommitted_chunks > 0, "trim must return spike chunks");
    assert_eq!(
        after.slab_chunks + after.decommitted_chunks,
        before.slab_chunks + before.decommitted_chunks
    );
    assert_eq!(after.slab_committed_bytes, after.slab_chunks * CHUNK_BYTES);
    assert_eq!(after.decommitted_bytes, after.decommitted_chunks * CHUNK_BYTES);
    // The heap still works after decommit.
    let r = build_chain(&mut heap, 8, 1);
    let vals = chain_values(&mut heap, r);
    assert_eq!(vals.len(), 8);
    heap.release(r);
    heap.sweep_memos();
}

#[derive(Clone)]
struct Node {
    value: i64,
    pad: [u64; 6],
    next: Lazy<Node>,
}
lazy_fields!(Node: next);

fn build_chain(heap: &mut Heap, len: usize, tag: i64) -> Lazy<Node> {
    let mut head = heap.alloc(Node {
        value: tag,
        pad: [tag as u64; 6],
        next: Lazy::NULL,
    });
    for i in 1..len {
        let new = heap.alloc(Node {
            value: tag + i as i64,
            pad: [0; 6],
            next: head,
        });
        heap.release(head);
        head = new;
    }
    head
}

fn chain_values(heap: &mut Heap, head: Lazy<Node>) -> Vec<i64> {
    let mut out = Vec::new();
    let mut cur = head;
    while !cur.is_null() {
        out.push(heap.read(&mut cur, |n| n.value));
        cur = heap.read_ptr(&mut cur, |n| n.next);
    }
    out
}

/// The slab-gauge consistency contract every balanced heap must satisfy.
fn assert_gauges_balanced(h: &Heap, label: &str) {
    let m = &h.metrics;
    assert_eq!(
        m.slab_freelist_hits + m.slab_fresh_bumps + m.slab_large_allocs,
        m.total_allocs,
        "{label}: every payload alloc takes exactly one source"
    );
    if m.live_objects == 0 {
        assert_eq!(m.slab_live_block_bytes, 0, "{label}: blocks outlive objects");
    }
    assert!(m.slab_live_block_bytes <= m.slab_committed_bytes, "{label}");
    assert_eq!(m.slab_committed_bytes, m.slab_chunks * CHUNK_BYTES, "{label}");
    assert!(
        m.slab_committed_peak_bytes >= m.slab_committed_bytes,
        "{label}: committed peak below the current gauge"
    );
    let frag = m.slab_fragmentation();
    assert!((0.0..=1.0).contains(&frag), "{label}: fragmentation {frag} out of [0, 1]");
    assert!(m.los_reuses <= m.los_allocs, "{label}: LOS reuses outnumber allocs");
    assert!(m.los_frees <= m.los_allocs, "{label}: LOS frees outnumber allocs");
    if m.los_allocs == m.los_frees {
        assert_eq!(m.los_live_bytes, 0, "{label}: LOS gauge drift at balance");
    }
    // And the allocator's own invariant sweep: free-list recounts,
    // per-chunk liveness, avail-stack membership.
    h.validate_storage();
}

/// Random alloc/release/deep-copy/mutate/transplant churn on both
/// backends: values identical, everything balances to zero live bytes,
/// gauges consistent, and the slab backend demonstrably reuses blocks.
#[test]
fn fuzz_churn_balances_on_both_backends() {
    for kind in AllocatorKind::ALL {
        for seed in 0..6u64 {
            let mode = CopyMode::ALL[(seed % 3) as usize];
            let mut heap = Heap::with_allocator(mode, kind);
            let mut other = Heap::with_allocator(mode, kind);
            let mut rng = Pcg64::new(0xA110C ^ seed);
            let mut roots: Vec<Lazy<Node>> = Vec::new();
            let mut trace = 0i64;
            for step in 0..200i64 {
                match rng.below(6) {
                    0 | 1 => roots.push(build_chain(&mut heap, 1 + rng.below(8) as usize, step)),
                    2 => {
                        if let Some(i) = pick(&mut rng, roots.len()) {
                            let c = heap.deep_copy(&roots[i]);
                            roots.push(c);
                        }
                    }
                    3 => {
                        if let Some(i) = pick(&mut rng, roots.len()) {
                            heap.mutate_root(&mut roots[i], |n| n.value += 1000);
                        }
                    }
                    4 => {
                        if let Some(i) = pick(&mut rng, roots.len()) {
                            let moved = heap.extract_into(&roots[i], &mut other);
                            trace += chain_values(&mut other, moved).iter().sum::<i64>();
                            other.release(moved);
                            other.sweep_memos();
                        }
                    }
                    _ => {
                        if let Some(i) = pick(&mut rng, roots.len()) {
                            let r = roots.swap_remove(i);
                            trace += chain_values(&mut heap, r).iter().sum::<i64>();
                            heap.release(r);
                        }
                    }
                }
            }
            for r in roots.drain(..) {
                trace += chain_values(&mut heap, r).iter().sum::<i64>();
                heap.release(r);
            }
            heap.sweep_memos();
            for (h, label) in [(&heap, "home"), (&other, "other")] {
                assert_eq!(h.live_objects(), 0, "{kind:?}/{label}: leaked");
                assert_eq!(
                    h.metrics.total_allocs,
                    h.metrics.total_frees + h.metrics.live_objects,
                    "{kind:?}/{label}: alloc/free balance"
                );
                assert_gauges_balanced(h, &format!("{kind:?}/{label}"));
            }
            match kind {
                AllocatorKind::Slab => assert!(
                    heap.metrics.slab_freelist_hits > 0,
                    "churn must reuse freed blocks"
                ),
                AllocatorKind::System => {
                    assert_eq!(heap.metrics.slab_freelist_hits, 0);
                    assert_eq!(heap.metrics.slab_chunks, 0);
                }
            }
            std::hint::black_box(trace);
        }
    }
    // Cross-backend value identity: identical sequences, identical sums.
    let run = |kind: AllocatorKind| -> i64 {
        let mut heap = Heap::with_allocator(CopyMode::LazySro, kind);
        let mut rng = Pcg64::new(77);
        let mut sum = 0i64;
        let mut roots = Vec::new();
        for step in 0..120i64 {
            if rng.below(2) == 0 || roots.is_empty() {
                roots.push(build_chain(&mut heap, 1 + rng.below(6) as usize, step));
            } else {
                let i = rng.below(roots.len() as u64) as usize;
                let mut c = heap.deep_copy(&roots[i]);
                heap.mutate_root(&mut c, |n| n.value *= 3);
                sum += chain_values(&mut heap, c).iter().sum::<i64>();
                heap.release(c);
            }
        }
        for r in roots {
            sum += chain_values(&mut heap, r).iter().sum::<i64>();
            heap.release(r);
        }
        sum
    };
    assert_eq!(
        run(AllocatorKind::System),
        run(AllocatorKind::Slab),
        "backend changed computed values"
    );
}

fn pick(rng: &mut Pcg64, len: usize) -> Option<usize> {
    if len == 0 {
        None
    } else {
        Some(rng.below(len as u64) as usize)
    }
}

/// The decommit differential cell: spiky alloc/copy/mutate churn with
/// periodic trim barriers computes bit-identical values to the same
/// churn without them, decommits chunks on the spikes' way down, and
/// ends with less committed residency than the monotone (off) run.
#[test]
fn churn_with_decommit_is_value_identical_and_bounded() {
    let run = |watermark: Option<usize>| -> (i64, usize, usize) {
        let mut heap = Heap::new(CopyMode::LazySro);
        let mut rng = Pcg64::new(0xDEC0);
        let mut sum = 0i64;
        for round in 0..8i64 {
            let mut roots = Vec::new();
            let spike = if round % 4 == 0 { 600 } else { 30 };
            for i in 0..spike {
                let len = 1 + rng.below(8) as usize;
                roots.push(build_chain(&mut heap, len, round * 1000 + i));
            }
            for _ in 0..10 {
                let i = rng.below(roots.len() as u64) as usize;
                let mut c = heap.deep_copy(&roots[i]);
                heap.mutate_root(&mut c, |n| n.value += 7);
                sum += chain_values(&mut heap, c).iter().sum::<i64>();
                heap.release(c);
            }
            for r in roots {
                sum += chain_values(&mut heap, r).iter().sum::<i64>();
                heap.release(r);
            }
            heap.sweep_memos();
            if let Some(w) = watermark {
                heap.trim(w);
            }
        }
        assert_eq!(heap.live_objects(), 0);
        assert_gauges_balanced(&heap, "churn");
        (
            sum,
            heap.metrics.slab_committed_bytes,
            heap.metrics.decommitted_chunks,
        )
    };
    let (sum_off, committed_off, dec_off) = run(None);
    let (sum_on, committed_on, dec_on) = run(Some(1));
    assert_eq!(sum_off, sum_on, "decommit changed computed values");
    assert_eq!(dec_off, 0, "no trim, no decommit");
    assert!(dec_on > 0, "spiky churn past the watermark must decommit");
    assert!(
        committed_on < committed_off,
        "decommit must shrink committed residency ({committed_on} vs {committed_off})"
    );
}

/// The scratch-heap contract end-to-end, following the engine's pooling
/// protocol: a `Heap::scratch` uses a bump-only allocator; each donation
/// round trip drains it, absorbs its counters into the home shard, and
/// `recycle_scratch` rewinds it (keeping chunks and zeroing per-use
/// metrics) for the next round. Both sides stay balanced with
/// consistent gauges and no fresh chunk after the first round.
#[test]
fn scratch_heap_roundtrip_with_recycling() {
    let mut home = Heap::new(CopyMode::LazySro);
    let mut scratch = home.scratch();
    assert!(scratch.allocator_is_bump_only());
    for round in 0..3i64 {
        let head = build_chain(&mut home, 10, round);
        let moved = home.extract_into(&head, &mut scratch);
        home.release(head);
        let want: Vec<i64> = (0..10).map(|i| round + 9 - i).collect();
        assert_eq!(chain_values(&mut scratch, moved), want);
        let back = scratch.extract_into(&moved, &mut home);
        scratch.release(moved);
        scratch.sweep_memos();
        assert_eq!(scratch.live_objects(), 0);
        assert!(scratch.metrics.peak_bytes > 0, "per-use peak measured");
        home.absorb_counters(&scratch);
        scratch.recycle_scratch();
        assert_eq!(scratch.metrics.peak_bytes, 0, "per-use metrics zeroed");
        assert_eq!(scratch.metrics.total_allocs, 0);
        assert_eq!(chain_values(&mut home, back), want);
        home.release(back);
        home.sweep_memos();
        assert_gauges_balanced(&home, "home");
    }
    assert_eq!(home.live_objects(), 0);
    assert!(
        scratch.metrics.slab_chunks <= 1,
        "recycling must retain (not re-commit) the scratch chunk"
    );
    assert_eq!(
        home.metrics.slab_freelist_hits + home.metrics.slab_fresh_bumps
            + home.metrics.slab_large_allocs,
        home.metrics.total_allocs,
        "absorbed per-use counters keep the source invariant"
    );
}

// --- Large-object space ---------------------------------------------------

#[test]
fn los_round_trips_large_and_overaligned_payloads() {
    for kind in AllocatorKind::ALL {
        let mut a = SlabAlloc::new(kind);
        let (h, rh) = a.alloc_value(Huge { a: [9; 300] });
        let (al, ra) = a.alloc_value(Aligned { a: [7; 8] });
        let pa = &*al as *const dyn Payload as *const u8 as usize;
        assert_eq!(pa % 64, 0, "over-aligned payload must honour its alignment");
        if kind == AllocatorKind::Slab {
            assert!(rh.los_bytes > 2400, "header + payload accounted");
            assert!(ra.los_bytes >= 64 + 64, "aligned header slot + payload");
            assert!(ra.large, "over-aligned payloads are LOS misfits");
        } else {
            assert_eq!(rh.los_bytes + ra.los_bytes, 0, "system backend has no LOS");
        }
        assert_eq!(h.as_any().downcast_ref::<Huge>().unwrap().a, [9; 300]);
        assert_eq!(al.as_any().downcast_ref::<Aligned>().unwrap().a, [7; 8]);
        let fh = a.dealloc(h);
        assert_eq!(fh.los_bytes, rh.los_bytes);
        let fa = a.dealloc(al);
        assert_eq!(fa.los_bytes, ra.los_bytes);
        assert_eq!(a.live_blocks(), 0);
        a.validate_counters();
    }
}

#[test]
fn los_first_fit_reuse_respects_the_waste_bound() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let big = Layout::from_size_align(8192, 8).unwrap();
    let (p1, loc1, r1) = a.alloc_raw(big);
    assert!(r1.large && !r1.reused && r1.los_bytes > 8192);
    a.free_raw(p1, big, loc1);
    // A far smaller request must not squat in the 8 KiB block (the 2×
    // waste bound rejects it) — fresh storage instead.
    let small = Layout::from_size_align(3000, 8).unwrap();
    let (p2, loc2, r2) = a.alloc_raw(small);
    assert!(!r2.reused, "2x waste bound must reject the oversized free block");
    // A near-size request gets the freed block straight back.
    let near = Layout::from_size_align(8000, 8).unwrap();
    let (p3, loc3, r3) = a.alloc_raw(near);
    assert!(r3.reused, "first fit must reuse the freed block");
    assert_eq!(p1, p3, "reuse must return the previously freed block");
    assert_eq!(r3.los_bytes, r1.los_bytes, "a reused block keeps its total size");
    a.free_raw(p2, small, loc2);
    a.free_raw(p3, near, loc3);
    // Trim keeps the warmest `keep` free blocks and decommits the rest.
    let stats = a.trim(1);
    assert_eq!(stats.los_blocks, 1);
    assert!(stats.los_bytes > 0);
    let stats = a.trim(0);
    assert_eq!(stats.los_blocks, 1);
    assert_eq!(a.trim(0).los_blocks, 0, "LOS trim is idempotent when drained");
    a.validate_counters();
}

#[test]
fn scratch_heap_los_blocks_survive_recycling() {
    // Heap-level recycle_scratch interaction: a scratch heap's large
    // payload storage is LOS, so its freed block and the LOS gauges must
    // both survive the bump rewind, and the next incarnation reuses it.
    let mut home = Heap::new(CopyMode::LazySro);
    let mut scratch = home.scratch();
    for round in 0..3u64 {
        let mut h = scratch.alloc(Huge { a: [round; 300] });
        assert_eq!(scratch.read(&mut h, |p| p.a[7]), round);
        scratch.release(h);
        scratch.sweep_memos();
        assert_eq!(scratch.live_objects(), 0);
        if round > 0 {
            assert!(
                scratch.metrics.los_reuses >= 1,
                "round {round}: recycled scratch must reuse its freed LOS block"
            );
        }
        assert!(scratch.metrics.los_free_bytes > 0, "freed block parked for reuse");
        home.absorb_counters(&scratch);
        scratch.recycle_scratch();
        assert!(
            scratch.metrics.los_free_bytes > 0,
            "LOS gauge must be carried across the rewind"
        );
        assert_eq!(scratch.metrics.los_allocs, 0, "per-use counters zeroed");
        scratch.validate_storage();
    }
    assert!(home.metrics.los_allocs >= 3, "absorbed counters keep the LOS history");
}

#[test]
fn los_cross_backend_value_identity_under_churn() {
    let run = |kind: AllocatorKind| -> u64 {
        let mut heap = Heap::with_allocator(CopyMode::LazySro, kind);
        let mut rng = Pcg64::new(0x105);
        let mut roots: Vec<Lazy<Huge>> = Vec::new();
        let mut sum = 0u64;
        for step in 0..120u64 {
            if rng.below(2) == 0 || roots.is_empty() {
                let mut v = [0u64; 300];
                v[0] = step;
                v[299] = step.wrapping_mul(7);
                roots.push(heap.alloc(Huge { a: v }));
            } else {
                let i = rng.below(roots.len() as u64) as usize;
                let mut r = roots.swap_remove(i);
                sum = sum.wrapping_add(heap.read(&mut r, |p| p.a[0] + p.a[299]));
                heap.release(r);
            }
        }
        for mut r in roots {
            sum = sum.wrapping_add(heap.read(&mut r, |p| p.a[0] + p.a[299]));
            heap.release(r);
        }
        heap.sweep_memos();
        assert_eq!(heap.live_objects(), 0);
        if kind == AllocatorKind::Slab {
            assert!(heap.metrics.los_allocs > 0, "Huge churn must exercise the LOS");
            assert!(heap.metrics.los_frees > 0);
        }
        assert_gauges_balanced(&heap, "los churn");
        sum
    };
    assert_eq!(
        run(AllocatorKind::System),
        run(AllocatorKind::Slab),
        "the LOS changed computed values"
    );
}

// --- Evacuation -----------------------------------------------------------

#[test]
fn evacuation_compacts_sparse_chunks_preserving_values() {
    let mut heap = Heap::new(CopyMode::LazySro);
    // ~3 chunks of the 64 B Node class, then free all but every 100th:
    // each chunk keeps a thin scatter of survivors.
    let mut kept = Vec::new();
    for i in 0..3000i64 {
        let r = build_chain(&mut heap, 1, i);
        if i % 100 == 0 {
            kept.push(r);
        } else {
            heap.release(r);
        }
    }
    heap.sweep_memos();
    let before: Vec<i64> = kept.iter().map(|&r| chain_values(&mut heap, r)[0]).collect();
    let chunks_before = heap.metrics.slab_chunks;
    assert!(chunks_before >= 3, "churn should commit several chunks");
    // Threshold 0 never selects a victim (a victim needs live > 0).
    assert_eq!(heap.evacuate(0.0), 0);
    assert_eq!(heap.metrics.slab_chunks, chunks_before);
    assert_eq!(heap.metrics.evacuated_objects, 0);
    let moved = heap.evacuate(0.5);
    assert!(moved > 0, "sparse chunks must evacuate");
    assert_eq!(heap.metrics.evacuated_objects, moved);
    assert!(heap.metrics.evacuated_chunks >= 1, "an emptied victim must decommit");
    assert!(
        heap.metrics.slab_chunks < chunks_before,
        "evacuation must shrink committed residency"
    );
    assert_eq!(heap.metrics.slab_committed_bytes, heap.metrics.slab_chunks * CHUNK_BYTES);
    assert!(heap.metrics.evacuated_bytes >= moved * 64, "block bytes recorded");
    heap.validate_storage();
    // The absolute contract: relocation changes storage, never a value.
    let after: Vec<i64> = kept.iter().map(|&r| chain_values(&mut heap, r)[0]).collect();
    assert_eq!(before, after, "evacuation must not change one value");
    for r in kept {
        heap.release(r);
    }
    heap.sweep_memos();
    assert_eq!(heap.live_objects(), 0);
    assert_gauges_balanced(&heap, "evacuate");
}

#[test]
fn evacuation_skips_raw_pinned_and_bump_chunks() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let l96 = Layout::from_size_align(96, 8).unwrap();
    // A raw block takes the first slot of chunk 0 (96 B class)...
    let (p, loc, _) = a.alloc_raw(l96);
    // ...payloads fill the rest of chunk 0 and spill into chunk 1.
    let per_chunk = CHUNK_BYTES / 96;
    let mut held = Vec::new();
    for i in 0..per_chunk as u64 {
        held.push(a.alloc_value(Mid { a: [i; 12] }).0);
    }
    let spill = held.pop().expect("spill block in chunk 1");
    for pb in held.drain(..) {
        a.dealloc(pb);
    }
    a.validate_counters();
    // Chunk 0 is maximally sparse but raw-pinned; chunk 1 is the bump
    // chunk. Even at threshold 1.0 neither is a victim.
    assert!(
        !a.begin_evacuation(1.0),
        "raw-pinned and bump chunks are never victims"
    );
    a.validate_counters();
    a.free_raw(p, l96, loc);
    a.dealloc(spill);
    assert_eq!(a.live_blocks(), 0);
    a.validate_counters();
}

// --- Chunk-liveness fuzz oracle -------------------------------------------

/// Ground-truth shadow of the per-chunk liveness counters, keyed on the
/// `BlockLoc` every allocation returns. `check` cross-checks the
/// allocator's own counters (and full invariant sweep) against it.
#[derive(Default)]
struct ShadowCounts {
    counts: HashMap<(u8, u32), (u32, u32)>, // (class, chunk) -> (live, live_raw)
}

impl ShadowCounts {
    fn alloc(&mut self, loc: BlockLoc, raw: bool) {
        if let BlockLoc::Slab { class, chunk } = loc {
            let e = self.counts.entry((class, chunk)).or_insert((0, 0));
            e.0 += 1;
            e.1 += u32::from(raw);
        }
    }

    fn free(&mut self, loc: BlockLoc, raw: bool) {
        if let BlockLoc::Slab { class, chunk } = loc {
            let e = self
                .counts
                .get_mut(&(class, chunk))
                .expect("free of a block the shadow never saw");
            e.0 -= 1;
            e.1 -= u32::from(raw);
        }
    }

    fn check(&self, a: &SlabAlloc) {
        a.validate_counters();
        let mut seen = 0usize;
        for (ci, chunks) in a.chunk_live_counts().iter().enumerate() {
            for &(j, live, live_raw) in chunks {
                let &(want, want_raw) = self.counts.get(&(ci as u8, j)).unwrap_or(&(0, 0));
                assert_eq!(live, want, "class {ci} chunk {j}: live counter drift");
                assert_eq!(live_raw, want_raw, "class {ci} chunk {j}: live_raw drift");
                seen += usize::from(live > 0);
            }
        }
        let nonzero = self.counts.values().filter(|&&(l, _)| l > 0).count();
        assert_eq!(seen, nonzero, "a live block sits in a decommitted chunk");
    }
}

/// The tentpole's pin: random payload/raw churn with interleaved trim
/// and evacuation barriers, where after *every single operation* each
/// chunk's live counters must equal a ground-truth recount, and every
/// trim must free exactly the counter-empty chunks beyond its watermark.
#[test]
fn fuzz_chunk_liveness_oracle() {
    const RAW_LAYOUTS: [(usize, usize); 5] = [(16, 8), (100, 8), (256, 16), (1024, 8), (3000, 8)];
    let iters = fuzz_iters(400);
    for kind in AllocatorKind::ALL {
        for seed in 0..2u64 {
            let mut a = SlabAlloc::new(kind);
            let mut shadow = ShadowCounts::default();
            let mut rng = Pcg64::new(0x11FE ^ seed);
            let mut payloads: Vec<PBox> = Vec::new();
            let mut raws: Vec<(*mut u8, Layout, BlockLoc)> = Vec::new();
            for _ in 0..iters {
                match rng.below(8) {
                    0 | 1 | 2 => {
                        let (pb, _) = match rng.below(3) {
                            0 => a.alloc_value(Small { a: 1 }),
                            1 => a.alloc_value(Mid { a: [2; 12] }),
                            _ => a.alloc_value(Huge { a: [3; 300] }),
                        };
                        shadow.alloc(pb.loc, false);
                        payloads.push(pb);
                    }
                    3 => {
                        let (s, al) = RAW_LAYOUTS[rng.below(RAW_LAYOUTS.len() as u64) as usize];
                        let l = Layout::from_size_align(s, al).unwrap();
                        let (p, loc, _) = a.alloc_raw(l);
                        shadow.alloc(loc, true);
                        raws.push((p, l, loc));
                    }
                    4 => {
                        if let Some(i) = pick(&mut rng, payloads.len()) {
                            let pb = payloads.swap_remove(i);
                            let loc = pb.loc;
                            a.dealloc(pb);
                            shadow.free(loc, false);
                        }
                    }
                    5 => {
                        if let Some(i) = pick(&mut rng, raws.len()) {
                            let (p, l, loc) = raws.swap_remove(i);
                            a.free_raw(p, l, loc);
                            shadow.free(loc, true);
                        }
                    }
                    6 => {
                        // Trim barrier: predict the exact chunk count it
                        // frees from the liveness counters alone.
                        let keep = rng.below(3) as usize;
                        let predicted: usize = a
                            .chunk_live_counts()
                            .iter()
                            .map(|chunks| {
                                let empties =
                                    chunks.iter().filter(|&&(_, live, _)| live == 0).count();
                                empties.saturating_sub(keep)
                            })
                            .sum();
                        let stats = a.trim(keep);
                        assert_eq!(
                            stats.chunks, predicted,
                            "trim must free exactly the counter-empty chunks past keep={keep}"
                        );
                    }
                    _ => {
                        // Evacuation barrier: walk the held payloads as
                        // the heap's slot walk would, shadowing each
                        // relocation as free(old) + alloc(new).
                        if a.begin_evacuation(0.5) {
                            for pb in payloads.iter_mut() {
                                let old = pb.loc;
                                if a.evacuate_block(pb).is_some() {
                                    shadow.free(old, false);
                                    shadow.alloc(pb.loc, false);
                                }
                            }
                            a.finish_evacuation();
                        }
                    }
                }
                shadow.check(&a);
            }
            // Drain everything: the counters must come back to zero and
            // trim(0) must then decommit every remaining chunk.
            for pb in payloads.drain(..) {
                let loc = pb.loc;
                a.dealloc(pb);
                shadow.free(loc, false);
            }
            for (p, l, loc) in raws.drain(..) {
                a.free_raw(p, l, loc);
                shadow.free(loc, true);
            }
            shadow.check(&a);
            assert_eq!(a.live_blocks(), 0, "{kind:?}/{seed}: leaked slab blocks");
            assert!(
                a.chunk_live_counts().iter().flatten().all(|&(_, live, _)| live == 0),
                "{kind:?}/{seed}: drained allocator with a live counter"
            );
            a.trim(0);
            assert!(
                a.chunk_live_counts().iter().all(|c| c.is_empty()),
                "{kind:?}/{seed}: trim(0) must decommit every empty chunk"
            );
            a.validate_counters();
        }
    }
}
