//! Allocator unit + property tests: class math, free-list reuse, the
//! exact-layout fallback, scratch bump/reset, and heap-level fuzz runs
//! proving random alloc/free/copy/transplant sequences balance to zero
//! live storage with gauges consistent, on both backends.

use super::*;
use crate::heap::{CopyMode, Heap, Lazy};
use crate::lazy_fields;
use crate::rng::Pcg64;

#[derive(Clone)]
struct Small {
    a: u64,
}
lazy_fields!(Small);

#[derive(Clone)]
struct Mid {
    a: [u64; 12],
}
lazy_fields!(Mid);

#[derive(Clone)]
struct Huge {
    a: [u64; 300], // 2400 B > largest class: exact-layout path
}
lazy_fields!(Huge);

#[derive(Clone)]
struct Unit;
lazy_fields!(Unit);

#[test]
fn class_for_rounds_up_and_rejects_misfits() {
    let l = |s: usize, a: usize| Layout::from_size_align(s, a).unwrap();
    assert_eq!(class_for(l(1, 1)), Some(0));
    assert_eq!(class_for(l(16, 8)), Some(0));
    assert_eq!(class_for(l(17, 8)), Some(1));
    assert_eq!(class_for(l(96, 16)), Some(4));
    assert_eq!(class_for(l(2048, 16)), Some(SIZE_CLASSES.len() - 1));
    assert_eq!(class_for(l(2049, 16)), None, "over the largest class");
    assert_eq!(class_for(l(64, 32)), None, "over-aligned");
    for (i, b) in SIZE_CLASSES.iter().enumerate() {
        assert_eq!(b % BLOCK_ALIGN, 0, "class {i} not block-aligned");
        assert_eq!(class_for(l(*b, BLOCK_ALIGN)), Some(i));
    }
}

#[test]
fn freelist_reuses_the_freed_block() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let (p1, r1) = a.alloc_value(Small { a: 7 });
    assert!(!r1.reused && !r1.large && r1.new_chunk);
    assert_eq!(r1.block_bytes, 16);
    let addr1 = &*p1 as *const dyn Payload as *const u8 as usize;
    let fr = a.dealloc(p1);
    assert_eq!(fr.block_bytes, 16);
    assert_eq!(a.live_blocks(), 0);
    // Same class: the freed block comes straight back.
    let (p2, r2) = a.alloc_value(Small { a: 8 });
    assert!(r2.reused && !r2.new_chunk);
    let addr2 = &*p2 as *const dyn Payload as *const u8 as usize;
    assert_eq!(addr1, addr2, "free list must hand the block back");
    // A different class bumps fresh storage instead.
    let (p3, r3) = a.alloc_value(Mid { a: [0; 12] });
    assert!(!r3.reused && r3.new_chunk, "first Mid alloc opens its class");
    assert_eq!(r3.block_bytes, 96);
    a.dealloc(p2);
    a.dealloc(p3);
    assert_eq!(a.live_blocks(), 0);
}

#[test]
fn bump_fills_chunks_then_grows() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let per_chunk = CHUNK_BYTES / 16;
    let mut held = Vec::new();
    let mut chunks = 0;
    for i in 0..per_chunk + 1 {
        let (p, r) = a.alloc_value(Small { a: i as u64 });
        assert!(!r.reused);
        chunks += usize::from(r.new_chunk);
        held.push(p);
    }
    assert_eq!(chunks, 2, "one chunk filled exactly, a second opened");
    for p in held {
        a.dealloc(p);
    }
    assert_eq!(a.live_blocks(), 0);
}

#[test]
fn exact_layout_paths() {
    // Large payloads bypass the slabs on both backends; the System
    // backend sends everything that way.
    for kind in AllocatorKind::ALL {
        let mut a = SlabAlloc::new(kind);
        let (h, rh) = a.alloc_value(Huge { a: [1; 300] });
        assert!(rh.large && !rh.reused && rh.block_bytes == 0);
        let (s, rs) = a.alloc_value(Small { a: 2 });
        assert_eq!(rs.large, kind == AllocatorKind::System);
        assert_eq!(a.dealloc(h).block_bytes, 0);
        let fs = a.dealloc(s);
        assert_eq!(fs.block_bytes != 0, kind == AllocatorKind::Slab);
        assert_eq!(a.live_blocks(), 0);
    }
}

#[test]
fn zero_sized_payloads_own_no_storage() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let (p, r) = a.alloc_value(Unit);
    assert!(!r.reused && !r.large && r.block_bytes == 0 && !r.new_chunk);
    assert_eq!(a.live_blocks(), 0);
    assert_eq!(a.dealloc(p).block_bytes, 0);
}

#[test]
fn clone_and_adopt_preserve_values() {
    let mut a = SlabAlloc::new(AllocatorKind::Slab);
    let (orig, _) = a.alloc_value(Mid { a: [3; 12] });
    let (copy, _) = a.alloc_clone(&*orig);
    let got = copy.as_any().downcast_ref::<Mid>().unwrap().a;
    assert_eq!(got, [3; 12]);
    let boxed: Box<dyn Payload> = Box::new(Small { a: 99 });
    let (adopted, r) = a.adopt_box(boxed);
    assert!(!r.large);
    assert_eq!(adopted.as_any().downcast_ref::<Small>().unwrap().a, 99);
    a.dealloc(orig);
    a.dealloc(copy);
    a.dealloc(adopted);
    assert_eq!(a.live_blocks(), 0);
}

#[test]
fn scratch_is_bump_only_and_resets_keeping_chunks() {
    let mut a = SlabAlloc::scratch(AllocatorKind::Slab);
    assert!(a.is_bump_only());
    let mut grew = 0;
    for round in 0..3 {
        let mut held = Vec::new();
        for i in 0..100u64 {
            let (p, r) = a.alloc_value(Mid { a: [i; 12] });
            assert!(!r.reused, "bump-only never builds a free list");
            grew += usize::from(r.new_chunk);
            held.push(p);
        }
        for p in held {
            assert_eq!(a.dealloc(p).block_bytes, 96);
        }
        assert_eq!(a.live_blocks(), 0);
        a.reset();
        assert_eq!(grew, 1, "round {round}: reset must retain the chunk");
    }
}

#[test]
#[should_panic(expected = "reset with live slab blocks")]
fn reset_rejects_live_blocks() {
    let mut a = SlabAlloc::scratch(AllocatorKind::Slab);
    let (_p, _) = a.alloc_value(Small { a: 1 });
    a.reset();
}

#[derive(Clone)]
struct Node {
    value: i64,
    pad: [u64; 6],
    next: Lazy<Node>,
}
lazy_fields!(Node: next);

fn build_chain(heap: &mut Heap, len: usize, tag: i64) -> Lazy<Node> {
    let mut head = heap.alloc(Node {
        value: tag,
        pad: [tag as u64; 6],
        next: Lazy::NULL,
    });
    for i in 1..len {
        let new = heap.alloc(Node {
            value: tag + i as i64,
            pad: [0; 6],
            next: head,
        });
        heap.release(head);
        head = new;
    }
    head
}

fn chain_values(heap: &mut Heap, head: Lazy<Node>) -> Vec<i64> {
    let mut out = Vec::new();
    let mut cur = head;
    while !cur.is_null() {
        out.push(heap.read(&mut cur, |n| n.value));
        cur = heap.read_ptr(&mut cur, |n| n.next);
    }
    out
}

/// The slab-gauge consistency contract every balanced heap must satisfy.
fn assert_gauges_balanced(h: &Heap, label: &str) {
    let m = &h.metrics;
    assert_eq!(
        m.slab_freelist_hits + m.slab_fresh_bumps + m.slab_large_allocs,
        m.total_allocs,
        "{label}: every payload alloc takes exactly one source"
    );
    if m.live_objects == 0 {
        assert_eq!(m.slab_live_block_bytes, 0, "{label}: blocks outlive objects");
    }
    assert!(m.slab_live_block_bytes <= m.slab_committed_bytes, "{label}");
    assert_eq!(m.slab_committed_bytes, m.slab_chunks * CHUNK_BYTES, "{label}");
}

/// Random alloc/release/deep-copy/mutate/transplant churn on both
/// backends: values identical, everything balances to zero live bytes,
/// gauges consistent, and the slab backend demonstrably reuses blocks.
#[test]
fn fuzz_churn_balances_on_both_backends() {
    for kind in AllocatorKind::ALL {
        for seed in 0..6u64 {
            let mode = CopyMode::ALL[(seed % 3) as usize];
            let mut heap = Heap::with_allocator(mode, kind);
            let mut other = Heap::with_allocator(mode, kind);
            let mut rng = Pcg64::new(0xA110C ^ seed);
            let mut roots: Vec<Lazy<Node>> = Vec::new();
            let mut trace = 0i64;
            for step in 0..200i64 {
                match rng.below(6) {
                    0 | 1 => roots.push(build_chain(&mut heap, 1 + rng.below(8) as usize, step)),
                    2 => {
                        if let Some(i) = pick(&mut rng, roots.len()) {
                            let c = heap.deep_copy(&roots[i]);
                            roots.push(c);
                        }
                    }
                    3 => {
                        if let Some(i) = pick(&mut rng, roots.len()) {
                            heap.mutate_root(&mut roots[i], |n| n.value += 1000);
                        }
                    }
                    4 => {
                        if let Some(i) = pick(&mut rng, roots.len()) {
                            let moved = heap.extract_into(&roots[i], &mut other);
                            trace += chain_values(&mut other, moved).iter().sum::<i64>();
                            other.release(moved);
                            other.sweep_memos();
                        }
                    }
                    _ => {
                        if let Some(i) = pick(&mut rng, roots.len()) {
                            let r = roots.swap_remove(i);
                            trace += chain_values(&mut heap, r).iter().sum::<i64>();
                            heap.release(r);
                        }
                    }
                }
            }
            for r in roots.drain(..) {
                trace += chain_values(&mut heap, r).iter().sum::<i64>();
                heap.release(r);
            }
            heap.sweep_memos();
            for (h, label) in [(&heap, "home"), (&other, "other")] {
                assert_eq!(h.live_objects(), 0, "{kind:?}/{label}: leaked");
                assert_eq!(
                    h.metrics.total_allocs,
                    h.metrics.total_frees + h.metrics.live_objects,
                    "{kind:?}/{label}: alloc/free balance"
                );
                assert_gauges_balanced(h, &format!("{kind:?}/{label}"));
            }
            match kind {
                AllocatorKind::Slab => assert!(
                    heap.metrics.slab_freelist_hits > 0,
                    "churn must reuse freed blocks"
                ),
                AllocatorKind::System => {
                    assert_eq!(heap.metrics.slab_freelist_hits, 0);
                    assert_eq!(heap.metrics.slab_chunks, 0);
                }
            }
            std::hint::black_box(trace);
        }
    }
    // Cross-backend value identity: identical sequences, identical sums.
    let run = |kind: AllocatorKind| -> i64 {
        let mut heap = Heap::with_allocator(CopyMode::LazySro, kind);
        let mut rng = Pcg64::new(77);
        let mut sum = 0i64;
        let mut roots = Vec::new();
        for step in 0..120i64 {
            if rng.below(2) == 0 || roots.is_empty() {
                roots.push(build_chain(&mut heap, 1 + rng.below(6) as usize, step));
            } else {
                let i = rng.below(roots.len() as u64) as usize;
                let mut c = heap.deep_copy(&roots[i]);
                heap.mutate_root(&mut c, |n| n.value *= 3);
                sum += chain_values(&mut heap, c).iter().sum::<i64>();
                heap.release(c);
            }
        }
        for r in roots {
            sum += chain_values(&mut heap, r).iter().sum::<i64>();
            heap.release(r);
        }
        sum
    };
    assert_eq!(
        run(AllocatorKind::System),
        run(AllocatorKind::Slab),
        "backend changed computed values"
    );
}

fn pick(rng: &mut Pcg64, len: usize) -> Option<usize> {
    if len == 0 {
        None
    } else {
        Some(rng.below(len as u64) as usize)
    }
}

/// The scratch-heap contract end-to-end, following the engine's pooling
/// protocol: a `Heap::scratch` uses a bump-only allocator; each donation
/// round trip drains it, absorbs its counters into the home shard, and
/// `recycle_scratch` rewinds it (keeping chunks and zeroing per-use
/// metrics) for the next round. Both sides stay balanced with
/// consistent gauges and no fresh chunk after the first round.
#[test]
fn scratch_heap_roundtrip_with_recycling() {
    let mut home = Heap::new(CopyMode::LazySro);
    let mut scratch = home.scratch();
    assert!(scratch.allocator_is_bump_only());
    for round in 0..3i64 {
        let head = build_chain(&mut home, 10, round);
        let moved = home.extract_into(&head, &mut scratch);
        home.release(head);
        let want: Vec<i64> = (0..10).map(|i| round + 9 - i).collect();
        assert_eq!(chain_values(&mut scratch, moved), want);
        let back = scratch.extract_into(&moved, &mut home);
        scratch.release(moved);
        scratch.sweep_memos();
        assert_eq!(scratch.live_objects(), 0);
        assert!(scratch.metrics.peak_bytes > 0, "per-use peak measured");
        home.absorb_counters(&scratch);
        scratch.recycle_scratch();
        assert_eq!(scratch.metrics.peak_bytes, 0, "per-use metrics zeroed");
        assert_eq!(scratch.metrics.total_allocs, 0);
        assert_eq!(chain_values(&mut home, back), want);
        home.release(back);
        home.sweep_memos();
        assert_gauges_balanced(&home, "home");
    }
    assert_eq!(home.live_objects(), 0);
    assert!(
        scratch.metrics.slab_chunks <= 1,
        "recycling must retain (not re-commit) the scratch chunk"
    );
    assert_eq!(
        home.metrics.slab_freelist_hits + home.metrics.slab_fresh_bumps
            + home.metrics.slab_large_allocs,
        home.metrics.total_allocs,
        "absorbed per-use counters keep the source invariant"
    );
}
