//! Payload storage: the size-class slab allocator behind every
//! [`Heap`](super::Heap).
//!
//! The paper's contribution is dynamic memory management for the
//! allocate/copy/mutate/free churn of particle populations, yet a naive
//! heap pays one system-allocator round trip per object payload — the
//! hottest allocation path in the platform. Resampling makes that churn
//! pathological in a very exploitable way: every generation frees and
//! reallocates objects of the *same few size classes* (each model has one
//! or two payload structs), so freed blocks are immediately reusable at
//! exactly the size the next generation asks for.
//!
//! [`SlabAlloc`] exploits that: payload storage is segregated into size
//! classes; each class bump-allocates out of fixed 64 KiB chunks and
//! recycles freed blocks through an intrusive free list (the freed block's
//! first word is the list link, so free blocks cost no side storage).
//! Payloads whose layout does not fit a class (over 2 KiB, or
//! over-aligned) fall back to the system allocator with their exact
//! layout. The `System` backend ([`AllocatorKind::System`]) bypasses the
//! slabs entirely — every payload takes the exact-layout path — which is
//! the differential baseline: the allocator must never change what is
//! computed, only where payload bytes live.
//!
//! **Ownership.** A payload lives in slab (or system) memory as its
//! concrete type, reached through a [`PBox`]: a fat `*mut dyn Payload`
//! plus the block's location tag. The heap's `Slot` stores `Option<PBox>`
//! where it used to store `Option<Box<dyn Payload>>`; the vtable travels
//! in the fat pointer, slot metadata is unchanged. All allocation goes
//! through the owning heap's `SlabAlloc` (placement-clone, placement-move
//! from a `Box`, or direct placement-write of a typed value — see the
//! [`Payload`] trait's placement methods), and all deallocation returns
//! through `SlabAlloc::dealloc`, which runs the payload's destructor in
//! place and pushes the block onto its class's free list. Dropping a
//! `PBox` outside the allocator (heap teardown) still runs the destructor
//! and frees exact-layout memory; a slab block simply stays with its
//! chunk, which the allocator frees wholesale on drop.
//!
//! **Raw (metadata) storage.** Payloads are not the only per-heap
//! structures that churn every generation: memo-table bucket arrays
//! rehash on growth and are freed wholesale on label death, and the label
//! slot vector grows with the lineage population. `SlabAlloc::alloc_raw`
//! / `SlabAlloc::free_raw` serve plain byte blocks from the *same* size
//! classes (exact-layout fallback for buckets over the largest class), and
//! `SlabVec` plus the memo module's bucket store route those structures
//! through them — so a memo rehash frees a 1 KiB block and the next 1 KiB
//! rehash anywhere in the heap reuses it, closing the last per-generation
//! system-allocator traffic. Raw allocations are accounted separately from
//! payload allocations (see the `slab_raw_*` fields of
//! [`HeapMetrics`](super::HeapMetrics)), through the crate-internal
//! `RawCtx` handle that pairs the allocator with the owning heap's
//! metrics.
//!
//! **Scratch heaps** (work-stealing donations) get a *bump-only*
//! allocator ([`SlabAlloc::scratch`]): they drain completely at every
//! generation barrier, so maintaining free lists for blocks that are
//! about to be released en masse is wasted work — frees only run the
//! destructor, and the storage is reclaimed in bulk when the scratch heap
//! drops (or recycled with [`SlabAlloc::reset`], which rewinds every
//! class's bump cursor while keeping the chunks). Raw allocations in a
//! bump-only allocator take the exact-layout path regardless of size:
//! metadata blocks must survive `reset` (which rewinds every bump
//! cursor), so they cannot live in the rewindable chunks.
//!
//! **Decommit.** A reuse-mode allocator never shrinks on its own: chunks
//! committed for one load spike stay committed for the life of the heap.
//! `SlabAlloc::trim` (surfaced as [`Heap::trim`](super::Heap::trim)) is
//! the watermark decommit pass for long-running
//! servers: at a generation barrier it finds fully-empty chunks (every
//! handed-out block returned to the free list) per size class and returns
//! the ones beyond a configurable watermark to the system allocator,
//! rebuilding the class free list without the dropped chunks' blocks.
//! Live blocks pin their chunk by definition, so decommit never moves or
//! invalidates storage — outputs are bit-identical with decommit on or
//! off.

use std::alloc::Layout;
use std::ops::{Deref, DerefMut};

use super::metrics::HeapMetrics;
use super::payload::Payload;

#[cfg(test)]
mod tests;

/// Payload-storage backend selector (`--allocator`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AllocatorKind {
    /// Every payload through the system allocator with its exact layout
    /// (the pre-slab behaviour; the differential baseline).
    System,
    /// Size-class slabs with free-list reuse (the default).
    Slab,
}

impl AllocatorKind {
    /// Parse a backend name as accepted by `--allocator`.
    pub fn parse(s: &str) -> Option<AllocatorKind> {
        match s.to_ascii_lowercase().as_str() {
            "system" | "sys" | "malloc" => Some(AllocatorKind::System),
            "slab" => Some(AllocatorKind::Slab),
            _ => None,
        }
    }

    /// Canonical name (CLI/bench labels).
    pub fn name(self) -> &'static str {
        match self {
            AllocatorKind::System => "system",
            AllocatorKind::Slab => "slab",
        }
    }

    /// Both backends (test sweeps).
    pub const ALL: [AllocatorKind; 2] = [AllocatorKind::System, AllocatorKind::Slab];
}

/// Block sizes served from slabs. Multiples of [`BLOCK_ALIGN`]; requests
/// above the last class (or over-aligned) take the exact-layout path.
/// The classes are dense at the bottom — every evaluation model's payload
/// struct lands in 16..384 — and quarter-spaced above.
pub(crate) const SIZE_CLASSES: [usize; 14] = [
    16, 32, 48, 64, 96, 128, 192, 256, 384, 512, 768, 1024, 1536, 2048,
];

/// Alignment of every slab block (and chunk). Payloads needing more fall
/// back to the exact-layout path.
pub(crate) const BLOCK_ALIGN: usize = 16;

/// Bytes per slab chunk. Small enough that a scratch heap costs little,
/// large enough that the smallest class amortizes 4096 blocks per system
/// allocation.
pub const CHUNK_BYTES: usize = 64 * 1024;

/// Default decommit watermark: fully-empty chunks kept per size class at
/// a [`Heap::trim`](super::Heap::trim) barrier before the rest are
/// returned to the system allocator (`--decommit-watermark`, config key
/// `decommit_watermark`). Two chunks absorb the steady-state churn of a
/// generation without re-committing, while anything beyond is spike
/// residue worth returning.
pub const DEFAULT_DECOMMIT_WATERMARK: usize = 2;

/// Smallest class index whose block fits `size`, or `None` for the
/// exact-layout path.
#[inline]
fn class_for(layout: Layout) -> Option<usize> {
    if layout.align() > BLOCK_ALIGN || layout.size() > SIZE_CLASSES[SIZE_CLASSES.len() - 1] {
        return None;
    }
    // Linear scan: 14 entries, branch-predicted cold tail (real payloads
    // hit within the first few classes).
    SIZE_CLASSES.iter().position(|&b| layout.size() <= b)
}

fn chunk_layout() -> Layout {
    Layout::from_size_align(CHUNK_BYTES, BLOCK_ALIGN).expect("chunk layout")
}

/// One 64 KiB slab chunk: raw memory so block pointers have plain
/// provenance (no `Box` aliasing contract to violate while `PBox`es point
/// into the chunk long-term).
struct Chunk {
    ptr: *mut u8,
}

impl Chunk {
    fn new() -> Chunk {
        let l = chunk_layout();
        // SAFETY: `l` has nonzero size.
        let ptr = unsafe { std::alloc::alloc(l) };
        if ptr.is_null() {
            std::alloc::handle_alloc_error(l);
        }
        Chunk { ptr }
    }
}

impl Drop for Chunk {
    fn drop(&mut self) {
        // SAFETY: allocated in `Chunk::new` with the same layout.
        unsafe { std::alloc::dealloc(self.ptr, chunk_layout()) };
    }
}

/// Where a block came from — what [`SlabAlloc::dealloc`] /
/// [`SlabAlloc::free_raw`] (or a teardown `Drop`) must do with the
/// memory. Carried by [`PBox`] for payloads and by the slab-resident
/// containers ([`SlabVec`], the memo bucket store) for raw blocks.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum BlockLoc {
    /// A slab block of the given size class.
    Slab(u8),
    /// Exact-layout system allocation (large/over-aligned payloads, and
    /// everything under the `System` backend).
    Sys,
    /// Zero-sized payload: no storage at all.
    Zst,
}

/// Owning handle to a payload stored in a [`SlabAlloc`] (or system
/// memory). Behaves like `Box<dyn Payload>` for access (`Deref`), but
/// deallocation belongs to the allocator: return it through
/// `SlabAlloc::dealloc` so the block re-enters its free list. Dropping
/// a `PBox` directly (heap teardown, unwind paths) is safe — the payload
/// destructor runs and exact-layout memory is freed — but a slab block
/// then stays with its chunk until the allocator drops.
pub struct PBox {
    ptr: *mut dyn Payload,
    loc: BlockLoc,
}

// SAFETY: a PBox uniquely owns its payload (`Payload: Send` is a
// supertrait), and it only ever moves between threads together with the
// Heap that owns both the slot holding it and the SlabAlloc holding its
// storage — the same whole-heap transfer discipline the old
// `Box<dyn Payload>` payloads relied on.
unsafe impl Send for PBox {}

impl PBox {
    /// Disassemble without running `Drop` (the allocator's dealloc path).
    fn into_parts(self) -> (*mut dyn Payload, BlockLoc) {
        let m = std::mem::ManuallyDrop::new(self);
        (m.ptr, m.loc)
    }
}

impl Deref for PBox {
    type Target = dyn Payload;
    #[inline]
    fn deref(&self) -> &dyn Payload {
        // SAFETY: `ptr` points at a live payload owned by this PBox.
        unsafe { &*self.ptr }
    }
}

impl DerefMut for PBox {
    #[inline]
    fn deref_mut(&mut self) -> &mut dyn Payload {
        // SAFETY: as above; `&mut self` gives exclusive access.
        unsafe { &mut *self.ptr }
    }
}

impl Drop for PBox {
    fn drop(&mut self) {
        // Teardown fallback only: the accounted path is
        // `SlabAlloc::dealloc`. SAFETY: the payload is live and uniquely
        // owned; the layout is read from the vtable before the value is
        // destroyed.
        unsafe {
            let layout = Layout::for_value(&*self.ptr);
            std::ptr::drop_in_place(self.ptr);
            if self.loc == BlockLoc::Sys && layout.size() > 0 {
                std::alloc::dealloc(self.ptr as *mut u8, layout);
            }
            // Slab blocks stay with their chunk (freed when the
            // SlabAlloc drops); Zst owns no memory.
        }
    }
}

/// What one allocation did — the heap mirrors this into `HeapMetrics`.
#[derive(Clone, Copy, Debug)]
pub(crate) struct AllocReceipt {
    /// Served from a class free list (reuse — the whole point).
    pub reused: bool,
    /// Exact-layout path (large/over-aligned payload or System backend).
    pub large: bool,
    /// Slab block size handed out (0 on the exact-layout/ZST paths).
    pub block_bytes: usize,
    /// The allocation grew the slab by one chunk.
    pub new_chunk: bool,
}

/// What one deallocation returned.
#[derive(Clone, Copy, Debug)]
pub(crate) struct FreeReceipt {
    /// Slab block size returned (0 on the exact-layout/ZST paths).
    pub block_bytes: usize,
}

/// Per-size-class state: chunks, a bump cursor, and the intrusive free
/// list.
struct ClassState {
    block: usize,
    chunks: Vec<Chunk>,
    /// Chunk currently being bumped (`== chunks.len()` only when empty).
    cur: usize,
    /// Bump offset within `chunks[cur]`.
    offset: usize,
    /// Intrusive free-list head (null = empty). Each free block's first
    /// word links to the next free block of the class.
    free: *mut u8,
}

impl ClassState {
    fn new(block: usize) -> ClassState {
        ClassState {
            block,
            chunks: Vec::new(),
            cur: 0,
            offset: 0,
            free: std::ptr::null_mut(),
        }
    }
}

/// The size-class slab allocator owning one heap's payload storage. See
/// the module docs for the design; see `HeapMetrics`' `slab_*` fields for
/// the gauges the owning heap maintains from the receipts.
pub struct SlabAlloc {
    kind: AllocatorKind,
    /// Scratch mode: frees run destructors but build no free lists; the
    /// storage is reclaimed in bulk by [`SlabAlloc::reset`] or drop.
    bump_only: bool,
    classes: Vec<ClassState>,
    /// Slab blocks currently handed out (the reset-safety gauge).
    live_blocks: usize,
}

// SAFETY: the raw free-list pointers and chunk pointers all point into
// memory owned by this SlabAlloc; it is only ever used through `&mut`
// from the single thread that owns the enclosing Heap.
unsafe impl Send for SlabAlloc {}

impl SlabAlloc {
    /// A reuse-mode allocator (the shard-heap default).
    pub fn new(kind: AllocatorKind) -> SlabAlloc {
        SlabAlloc {
            kind,
            bump_only: false,
            classes: SIZE_CLASSES.iter().map(|&b| ClassState::new(b)).collect(),
            live_blocks: 0,
        }
    }

    /// A bump-only allocator for scratch heaps: pure bump allocation, no
    /// free-list maintenance, bulk [`SlabAlloc::reset`]. (Inert under the
    /// `System` backend, which has no slab storage to bump.)
    pub fn scratch(kind: AllocatorKind) -> SlabAlloc {
        SlabAlloc {
            bump_only: true,
            ..SlabAlloc::new(kind)
        }
    }

    /// The backend this allocator serves payloads with.
    #[inline]
    pub fn kind(&self) -> AllocatorKind {
        self.kind
    }

    /// Whether this is the scratch-heap bump-only variant.
    #[inline]
    pub fn is_bump_only(&self) -> bool {
        self.bump_only
    }

    /// Slab blocks currently handed out.
    #[inline]
    pub fn live_blocks(&self) -> usize {
        self.live_blocks
    }

    /// Rewind every class to empty — the scratch heap's bulk reclaim.
    /// Chunks are kept, so a recycled scratch allocates without touching
    /// the system allocator at all. Every block must have been freed
    /// (destructors run on free in bump-only mode too); resetting with
    /// live blocks would hand their storage out again.
    pub fn reset(&mut self) {
        assert_eq!(self.live_blocks, 0, "reset with live slab blocks");
        for c in &mut self.classes {
            c.cur = 0;
            c.offset = 0;
            c.free = std::ptr::null_mut();
        }
    }

    /// Place `value` (placement-write; the typed hot path — no `Box`).
    pub(crate) fn alloc_value<T: Payload>(&mut self, value: T) -> (PBox, AllocReceipt) {
        let (mem, loc, r) = self.alloc_block(Layout::new::<T>());
        // SAFETY: `mem` has the size/align of `T` and is uniquely ours.
        let ptr = unsafe {
            std::ptr::write(mem as *mut T, value);
            mem as *mut T as *mut dyn Payload
        };
        (PBox { ptr, loc }, r)
    }

    /// Placement-clone `src` (the `Copy`/transplant hot path — no
    /// intermediate `Box`).
    pub(crate) fn alloc_clone(&mut self, src: &dyn Payload) -> (PBox, AllocReceipt) {
        let (mem, loc, r) = self.alloc_block(src.layout());
        // SAFETY: `mem` matches `src.layout()` and is uniquely ours.
        let ptr = unsafe { src.clone_into(mem) };
        (PBox { ptr, loc }, r)
    }

    /// Move a boxed payload into owned storage, freeing the box's
    /// allocation without running the destructor.
    pub(crate) fn adopt_box(&mut self, payload: Box<dyn Payload>) -> (PBox, AllocReceipt) {
        let (mem, loc, r) = self.alloc_block(Layout::for_value(&*payload));
        // SAFETY: `mem` matches the payload's concrete layout.
        let ptr = unsafe { payload.move_into(mem) };
        (PBox { ptr, loc }, r)
    }

    /// Destroy a payload and return its block: destructor in place, then
    /// the block re-enters its class free list (reuse mode) or merely
    /// stops counting as live (bump-only mode); exact-layout memory goes
    /// back to the system allocator.
    pub(crate) fn dealloc(&mut self, payload: PBox) -> FreeReceipt {
        let (ptr, loc) = payload.into_parts();
        // SAFETY: live uniquely-owned payload; layout read before drop.
        let layout = unsafe { Layout::for_value(&*ptr) };
        unsafe { std::ptr::drop_in_place(ptr) };
        self.free_raw(ptr as *mut u8, layout, loc)
    }

    /// Raw-bytes allocation over the same size classes as payloads — the
    /// storage path of memo bucket arrays and label slot vectors. Three
    /// deviations from the payload path: bump-only (scratch) allocators
    /// route *every* raw request through the exact-layout path, because
    /// metadata must survive [`SlabAlloc::reset`]'s bump rewind; the
    /// `System` backend likewise takes exact layout (its contract — no
    /// slab storage at all); oversized/over-aligned requests fall back to
    /// exact layout just like large payloads. Callers go through
    /// [`RawCtx`] so the receipt lands in the owning heap's metrics.
    pub(crate) fn alloc_raw(&mut self, layout: Layout) -> (*mut u8, BlockLoc, AllocReceipt) {
        if self.bump_only {
            return Self::alloc_exact(layout);
        }
        self.alloc_block(layout)
    }

    /// Return a raw block obtained from [`SlabAlloc::alloc_raw`]. No
    /// destructor runs — the caller owns the contents; slab blocks
    /// re-enter their class free list, exact-layout memory goes back to
    /// the system allocator.
    pub(crate) fn free_raw(&mut self, ptr: *mut u8, layout: Layout, loc: BlockLoc) -> FreeReceipt {
        match loc {
            BlockLoc::Zst => FreeReceipt { block_bytes: 0 },
            BlockLoc::Sys => {
                debug_assert!(layout.size() > 0);
                // SAFETY: allocated by the exact-layout path with this
                // layout.
                unsafe { std::alloc::dealloc(ptr, layout) };
                FreeReceipt { block_bytes: 0 }
            }
            BlockLoc::Slab(ci) => {
                self.live_blocks -= 1;
                let c = &mut self.classes[ci as usize];
                if !self.bump_only {
                    // SAFETY: the block is ≥ 16 bytes, 16-aligned, and
                    // dead — its first word becomes the free-list link.
                    unsafe { *(ptr as *mut *mut u8) = c.free };
                    c.free = ptr;
                }
                FreeReceipt {
                    block_bytes: c.block,
                }
            }
        }
    }

    /// The exact-layout path shared by large payloads, the `System`
    /// backend, and bump-only raw allocations.
    fn alloc_exact(layout: Layout) -> (*mut u8, BlockLoc, AllocReceipt) {
        if layout.size() == 0 {
            return (
                layout.align() as *mut u8,
                BlockLoc::Zst,
                AllocReceipt {
                    reused: false,
                    large: false,
                    block_bytes: 0,
                    new_chunk: false,
                },
            );
        }
        // SAFETY: nonzero size.
        let p = unsafe { std::alloc::alloc(layout) };
        if p.is_null() {
            std::alloc::handle_alloc_error(layout);
        }
        (
            p,
            BlockLoc::Sys,
            AllocReceipt {
                reused: false,
                large: true,
                block_bytes: 0,
                new_chunk: false,
            },
        )
    }

    fn alloc_block(&mut self, layout: Layout) -> (*mut u8, BlockLoc, AllocReceipt) {
        if layout.size() == 0 {
            return Self::alloc_exact(layout);
        }
        let class = if self.kind == AllocatorKind::Slab {
            class_for(layout)
        } else {
            None
        };
        let Some(ci) = class else {
            return Self::alloc_exact(layout);
        };
        let c = &mut self.classes[ci];
        self.live_blocks += 1;
        if !c.free.is_null() {
            let p = c.free;
            // SAFETY: `p` is a free block whose first word is the link.
            c.free = unsafe { *(p as *const *mut u8) };
            return (
                p,
                BlockLoc::Slab(ci as u8),
                AllocReceipt {
                    reused: true,
                    large: false,
                    block_bytes: c.block,
                    new_chunk: false,
                },
            );
        }
        // Bump, advancing through retained chunks (a reset scratch walks
        // its old chunks again) and growing by one chunk when all are
        // full.
        let mut new_chunk = false;
        let p = loop {
            if c.cur < c.chunks.len() && c.offset + c.block <= CHUNK_BYTES {
                // SAFETY: offset + block ≤ CHUNK_BYTES keeps the pointer
                // inside the chunk allocation.
                let p = unsafe { c.chunks[c.cur].ptr.add(c.offset) };
                c.offset += c.block;
                break p;
            }
            if c.cur + 1 < c.chunks.len() {
                c.cur += 1;
                c.offset = 0;
                continue;
            }
            c.chunks.push(Chunk::new());
            new_chunk = true;
            c.cur = c.chunks.len() - 1;
            c.offset = 0;
        };
        (
            p,
            BlockLoc::Slab(ci as u8),
            AllocReceipt {
                reused: false,
                large: false,
                block_bytes: c.block,
                new_chunk,
            },
        )
    }

    /// Watermark decommit pass (`Heap::trim` calls this at generation
    /// barriers): per size class, find *fully-empty* chunks — every block
    /// ever bumped out of the chunk is back on the free list — and return
    /// the ones beyond `keep` to the system allocator, rebuilding the
    /// free list without their blocks. Chunks holding any live block are
    /// never touched, so no pointer is invalidated. The current bump
    /// chunk is kept preferentially (it holds the class's only virgin
    /// space). O(free blocks + chunks·log chunks) — a cold barrier pass,
    /// not hot-path work. No-op for bump-only (scratch) allocators, whose
    /// retain-everything pooling contract this deliberately preserves,
    /// and for the `System` backend (no chunks exist).
    pub(crate) fn trim(&mut self, keep: usize) -> TrimStats {
        let mut stats = TrimStats {
            chunks: 0,
            bytes: 0,
        };
        if self.bump_only || self.kind != AllocatorKind::Slab {
            return stats;
        }
        for c in &mut self.classes {
            // Fewer chunks than the watermark keeps: nothing can be
            // freed, so skip the free-list walk entirely — this is what
            // keeps the per-generation barrier cheap in steady state.
            if c.chunks.len() <= keep {
                continue;
            }
            let blocks_per_chunk = CHUNK_BYTES / c.block;
            // Locate each free block's chunk by address (chunks are not
            // address-ordered, so sort the bases once).
            let mut bases: Vec<(usize, usize)> = c
                .chunks
                .iter()
                .enumerate()
                .map(|(j, ch)| (ch.ptr as usize, j))
                .collect();
            bases.sort_unstable();
            let chunk_of = |addr: usize| -> usize {
                let i = match bases.binary_search_by(|&(b, _)| b.cmp(&addr)) {
                    Ok(i) => i,
                    Err(i) => i - 1,
                };
                debug_assert!(addr >= bases[i].0 && addr - bases[i].0 < CHUNK_BYTES);
                bases[i].1
            };
            let mut free_in = vec![0usize; c.chunks.len()];
            let mut p = c.free;
            while !p.is_null() {
                free_in[chunk_of(p as usize)] += 1;
                // SAFETY: `p` is a free block; its first word is the link.
                p = unsafe { *(p as *const *mut u8) };
            }
            // Blocks ever bumped out of chunk j. Reuse mode keeps `cur`
            // at the last chunk: earlier chunks are fully bumped, later
            // ones do not exist.
            debug_assert_eq!(c.cur, c.chunks.len() - 1, "reuse-mode bump invariant");
            let bumped = |j: usize| {
                if j < c.cur {
                    blocks_per_chunk
                } else {
                    c.offset / c.block
                }
            };
            let empty: Vec<bool> = (0..c.chunks.len())
                .map(|j| free_in[j] == bumped(j))
                .collect();
            let n_empty = empty.iter().filter(|e| **e).count();
            if n_empty <= keep {
                continue;
            }
            // Choose victims: lowest-index empties first, the bump chunk
            // last (its virgin space is the cheapest storage the class
            // has).
            let mut to_free = n_empty - keep;
            let mut dropf = vec![false; c.chunks.len()];
            for j in 0..c.chunks.len() {
                if to_free == 0 {
                    break;
                }
                if empty[j] && j != c.cur {
                    dropf[j] = true;
                    to_free -= 1;
                }
            }
            if to_free > 0 && empty[c.cur] {
                dropf[c.cur] = true;
                to_free -= 1;
            }
            debug_assert_eq!(to_free, 0);
            // Rebuild the free list without blocks in dropped chunks
            // (order preserved — decommit must not perturb reuse order).
            let mut head: *mut u8 = std::ptr::null_mut();
            let mut tail: *mut u8 = std::ptr::null_mut();
            let mut p = c.free;
            while !p.is_null() {
                // SAFETY: free-list walk as above.
                let next = unsafe { *(p as *const *mut u8) };
                if !dropf[chunk_of(p as usize)] {
                    if head.is_null() {
                        head = p;
                    } else {
                        // SAFETY: `tail` is a retained free block.
                        unsafe { *(tail as *mut *mut u8) = p };
                    }
                    tail = p;
                }
                p = next;
            }
            if !tail.is_null() {
                // SAFETY: as above.
                unsafe { *(tail as *mut *mut u8) = std::ptr::null_mut() };
            }
            c.free = head;
            // Drop the victim chunks (their `Drop` returns the 64 KiB to
            // the system allocator) and re-point the bump cursor.
            let cur_dropped = dropf[c.cur];
            let old_cur = c.cur;
            let old = std::mem::take(&mut c.chunks);
            let mut new_cur = 0usize;
            for (j, ch) in old.into_iter().enumerate() {
                if dropf[j] {
                    stats.chunks += 1;
                    stats.bytes += CHUNK_BYTES;
                    drop(ch);
                } else {
                    if j == old_cur {
                        new_cur = c.chunks.len();
                    }
                    c.chunks.push(ch);
                }
            }
            if cur_dropped {
                // Every survivor is fully bumped (their free blocks stay
                // on the list): mark the cursor exhausted so the next
                // free-list miss opens a fresh chunk.
                if c.chunks.is_empty() {
                    c.cur = 0;
                    c.offset = 0;
                } else {
                    c.cur = c.chunks.len() - 1;
                    c.offset = blocks_per_chunk * c.block;
                }
            } else {
                c.cur = new_cur;
            }
        }
        stats
    }
}

/// What one [`SlabAlloc::trim`] pass returned to the system allocator;
/// the owning heap folds it into `decommitted_chunks` /
/// `decommitted_bytes` and lowers the committed gauges.
#[derive(Clone, Copy, Debug)]
pub(crate) struct TrimStats {
    /// Chunks returned to the system allocator.
    pub chunks: usize,
    /// Bytes returned (`chunks` × [`CHUNK_BYTES`]).
    pub bytes: usize,
}

/// Accounted raw-bytes allocation context: the slab allocator paired with
/// the owning heap's metrics, so every memo/label storage operation lands
/// in the `slab_raw_*` gauges. Built on the fly from `Heap`'s disjoint
/// fields wherever a slab-resident container needs to grow or free.
pub(crate) struct RawCtx<'a> {
    /// The heap's allocator.
    pub alloc: &'a mut SlabAlloc,
    /// The heap's metrics, receiving the receipts.
    pub metrics: &'a mut HeapMetrics,
}

impl RawCtx<'_> {
    /// Allocate a raw block, recording the receipt.
    pub(crate) fn alloc_raw(&mut self, layout: Layout) -> (*mut u8, BlockLoc) {
        let (p, loc, r) = self.alloc.alloc_raw(layout);
        self.metrics.note_raw_alloc(&r);
        (p, loc)
    }

    /// Free a raw block, recording the receipt.
    pub(crate) fn free_raw(&mut self, ptr: *mut u8, layout: Layout, loc: BlockLoc) {
        let r = self.alloc.free_raw(ptr, layout, loc);
        self.metrics.note_raw_free(&r);
    }
}

/// A minimal `Vec<T>` whose backing store lives in the owning heap's
/// slab allocator (raw path) — the label slot vector's storage. Growth
/// and explicit teardown go through a [`RawCtx`] so freed backing blocks
/// re-enter their size-class free list; a plain `Drop` (heap teardown)
/// runs the element destructors and frees exact-layout memory, while a
/// slab-resident block stays with its chunk exactly like a dropped
/// [`PBox`].
pub(crate) struct SlabVec<T> {
    ptr: *mut T,
    cap: usize,
    len: usize,
    loc: BlockLoc,
}

// SAFETY: SlabVec uniquely owns its elements and storage; it only moves
// between threads together with the Heap that owns both it and the
// SlabAlloc holding its storage (the PBox discipline).
unsafe impl<T: Send> Send for SlabVec<T> {}

impl<T> SlabVec<T> {
    /// An empty vector owning no storage.
    pub(crate) const fn new() -> SlabVec<T> {
        SlabVec {
            ptr: std::ptr::NonNull::dangling().as_ptr(),
            cap: 0,
            len: 0,
            loc: BlockLoc::Zst,
        }
    }

    #[inline]
    pub(crate) fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub(crate) fn as_slice(&self) -> &[T] {
        // SAFETY: `ptr` is dangling-aligned when cap == 0 and points at
        // `len` initialized elements otherwise.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    #[inline]
    pub(crate) fn as_mut_slice(&mut self) -> &mut [T] {
        // SAFETY: as above; `&mut self` gives exclusive access.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    #[inline]
    pub(crate) fn iter(&self) -> std::slice::Iter<'_, T> {
        self.as_slice().iter()
    }

    /// Append, growing through the raw slab path when full.
    pub(crate) fn push(&mut self, ctx: &mut RawCtx<'_>, value: T) {
        if self.len == self.cap {
            self.grow(ctx);
        }
        // SAFETY: `len < cap` after grow; the slot is uninitialized.
        unsafe { self.ptr.add(self.len).write(value) };
        self.len += 1;
    }

    fn grow(&mut self, ctx: &mut RawCtx<'_>) {
        let new_cap = (self.cap * 2).max(8);
        let layout = Layout::array::<T>(new_cap).expect("slab vec layout");
        let (p, loc) = ctx.alloc_raw(layout);
        let p = p as *mut T;
        if self.cap > 0 {
            // SAFETY: old and new blocks are disjoint; `len` elements are
            // initialized; the bitwise copy is a move (old storage is
            // freed without running destructors).
            unsafe { std::ptr::copy_nonoverlapping(self.ptr, p, self.len) };
            let old_layout = Layout::array::<T>(self.cap).expect("slab vec layout");
            ctx.free_raw(self.ptr as *mut u8, old_layout, self.loc);
        }
        self.ptr = p;
        self.cap = new_cap;
        self.loc = loc;
    }
}

impl<T> std::ops::Index<usize> for SlabVec<T> {
    type Output = T;
    #[inline]
    fn index(&self, i: usize) -> &T {
        &self.as_slice()[i]
    }
}

impl<T> std::ops::IndexMut<usize> for SlabVec<T> {
    #[inline]
    fn index_mut(&mut self, i: usize) -> &mut T {
        &mut self.as_mut_slice()[i]
    }
}

impl<T> Drop for SlabVec<T> {
    fn drop(&mut self) {
        // Teardown fallback (heap drop): run element destructors; free
        // exact-layout storage; a slab block stays with its chunk, which
        // the allocator frees wholesale right after (field order in
        // `Heap`).
        // SAFETY: `len` initialized elements, uniquely owned.
        unsafe { std::ptr::drop_in_place(std::ptr::slice_from_raw_parts_mut(self.ptr, self.len)) };
        if self.loc == BlockLoc::Sys && self.cap > 0 {
            let layout = Layout::array::<T>(self.cap).expect("slab vec layout");
            // SAFETY: allocated by the exact-layout path with this layout.
            unsafe { std::alloc::dealloc(self.ptr as *mut u8, layout) };
        }
    }
}
