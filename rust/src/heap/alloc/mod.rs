//! Payload storage: the size-class slab allocator behind every [`Heap`].
//!
//! The paper's contribution is dynamic memory management for the
//! allocate/copy/mutate/free churn of particle populations, yet a naive
//! heap pays one system-allocator round trip per object payload — the
//! hottest allocation path in the platform. Resampling makes that churn
//! pathological in a very exploitable way: every generation frees and
//! reallocates objects of the *same few size classes* (each model has one
//! or two payload structs), so freed blocks are immediately reusable at
//! exactly the size the next generation asks for.
//!
//! [`SlabAlloc`] exploits that: payload storage is segregated into size
//! classes; each class bump-allocates out of fixed 64 KiB chunks and
//! recycles freed blocks through an intrusive free list (the freed block's
//! first word is the list link, so free blocks cost no side storage).
//! Payloads whose layout does not fit a class (over 2 KiB, or
//! over-aligned) fall back to the system allocator with their exact
//! layout. The `System` backend ([`AllocatorKind::System`]) bypasses the
//! slabs entirely — every payload takes the exact-layout path — which is
//! the differential baseline: the allocator must never change what is
//! computed, only where payload bytes live.
//!
//! **Ownership.** A payload lives in slab (or system) memory as its
//! concrete type, reached through a [`PBox`]: a fat `*mut dyn Payload`
//! plus the block's location tag. The heap's `Slot` stores `Option<PBox>`
//! where it used to store `Option<Box<dyn Payload>>`; the vtable travels
//! in the fat pointer, slot metadata is unchanged. All allocation goes
//! through the owning heap's `SlabAlloc` (placement-clone, placement-move
//! from a `Box`, or direct placement-write of a typed value — see the
//! [`Payload`] trait's placement methods), and all deallocation returns
//! through [`SlabAlloc::dealloc`], which runs the payload's destructor in
//! place and pushes the block onto its class's free list. Dropping a
//! `PBox` outside the allocator (heap teardown) still runs the destructor
//! and frees exact-layout memory; a slab block simply stays with its
//! chunk, which the allocator frees wholesale on drop.
//!
//! **Scratch heaps** (work-stealing donations) get a *bump-only*
//! allocator ([`SlabAlloc::scratch`]): they drain completely at every
//! generation barrier, so maintaining free lists for blocks that are
//! about to be released en masse is wasted work — frees only run the
//! destructor, and the storage is reclaimed in bulk when the scratch heap
//! drops (or recycled with [`SlabAlloc::reset`], which rewinds every
//! class's bump cursor while keeping the chunks).

use std::alloc::Layout;
use std::ops::{Deref, DerefMut};

use super::payload::Payload;

#[cfg(test)]
mod tests;

/// Payload-storage backend selector (`--allocator`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AllocatorKind {
    /// Every payload through the system allocator with its exact layout
    /// (the pre-slab behaviour; the differential baseline).
    System,
    /// Size-class slabs with free-list reuse (the default).
    Slab,
}

impl AllocatorKind {
    pub fn parse(s: &str) -> Option<AllocatorKind> {
        match s.to_ascii_lowercase().as_str() {
            "system" | "sys" | "malloc" => Some(AllocatorKind::System),
            "slab" => Some(AllocatorKind::Slab),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AllocatorKind::System => "system",
            AllocatorKind::Slab => "slab",
        }
    }

    pub const ALL: [AllocatorKind; 2] = [AllocatorKind::System, AllocatorKind::Slab];
}

/// Block sizes served from slabs. Multiples of [`BLOCK_ALIGN`]; requests
/// above the last class (or over-aligned) take the exact-layout path.
/// The classes are dense at the bottom — every evaluation model's payload
/// struct lands in 16..384 — and quarter-spaced above.
pub(crate) const SIZE_CLASSES: [usize; 14] = [
    16, 32, 48, 64, 96, 128, 192, 256, 384, 512, 768, 1024, 1536, 2048,
];

/// Alignment of every slab block (and chunk). Payloads needing more fall
/// back to the exact-layout path.
pub(crate) const BLOCK_ALIGN: usize = 16;

/// Bytes per slab chunk. Small enough that a scratch heap costs little,
/// large enough that the smallest class amortizes 4096 blocks per system
/// allocation.
pub const CHUNK_BYTES: usize = 64 * 1024;

/// Smallest class index whose block fits `size`, or `None` for the
/// exact-layout path.
#[inline]
fn class_for(layout: Layout) -> Option<usize> {
    if layout.align() > BLOCK_ALIGN || layout.size() > SIZE_CLASSES[SIZE_CLASSES.len() - 1] {
        return None;
    }
    // Linear scan: 14 entries, branch-predicted cold tail (real payloads
    // hit within the first few classes).
    SIZE_CLASSES.iter().position(|&b| layout.size() <= b)
}

fn chunk_layout() -> Layout {
    Layout::from_size_align(CHUNK_BYTES, BLOCK_ALIGN).expect("chunk layout")
}

/// One 64 KiB slab chunk: raw memory so block pointers have plain
/// provenance (no `Box` aliasing contract to violate while `PBox`es point
/// into the chunk long-term).
struct Chunk {
    ptr: *mut u8,
}

impl Chunk {
    fn new() -> Chunk {
        let l = chunk_layout();
        // SAFETY: `l` has nonzero size.
        let ptr = unsafe { std::alloc::alloc(l) };
        if ptr.is_null() {
            std::alloc::handle_alloc_error(l);
        }
        Chunk { ptr }
    }
}

impl Drop for Chunk {
    fn drop(&mut self) {
        // SAFETY: allocated in `Chunk::new` with the same layout.
        unsafe { std::alloc::dealloc(self.ptr, chunk_layout()) };
    }
}

/// Where a payload's block came from — what [`SlabAlloc::dealloc`] (or a
/// teardown `Drop`) must do with the memory.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum BlockLoc {
    /// A slab block of the given size class.
    Slab(u8),
    /// Exact-layout system allocation (large/over-aligned payloads, and
    /// everything under the `System` backend).
    Sys,
    /// Zero-sized payload: no storage at all.
    Zst,
}

/// Owning handle to a payload stored in a [`SlabAlloc`] (or system
/// memory). Behaves like `Box<dyn Payload>` for access (`Deref`), but
/// deallocation belongs to the allocator: return it through
/// [`SlabAlloc::dealloc`] so the block re-enters its free list. Dropping
/// a `PBox` directly (heap teardown, unwind paths) is safe — the payload
/// destructor runs and exact-layout memory is freed — but a slab block
/// then stays with its chunk until the allocator drops.
pub struct PBox {
    ptr: *mut dyn Payload,
    loc: BlockLoc,
}

// SAFETY: a PBox uniquely owns its payload (`Payload: Send` is a
// supertrait), and it only ever moves between threads together with the
// Heap that owns both the slot holding it and the SlabAlloc holding its
// storage — the same whole-heap transfer discipline the old
// `Box<dyn Payload>` payloads relied on.
unsafe impl Send for PBox {}

impl PBox {
    /// Disassemble without running `Drop` (the allocator's dealloc path).
    fn into_parts(self) -> (*mut dyn Payload, BlockLoc) {
        let m = std::mem::ManuallyDrop::new(self);
        (m.ptr, m.loc)
    }
}

impl Deref for PBox {
    type Target = dyn Payload;
    #[inline]
    fn deref(&self) -> &dyn Payload {
        // SAFETY: `ptr` points at a live payload owned by this PBox.
        unsafe { &*self.ptr }
    }
}

impl DerefMut for PBox {
    #[inline]
    fn deref_mut(&mut self) -> &mut dyn Payload {
        // SAFETY: as above; `&mut self` gives exclusive access.
        unsafe { &mut *self.ptr }
    }
}

impl Drop for PBox {
    fn drop(&mut self) {
        // Teardown fallback only: the accounted path is
        // `SlabAlloc::dealloc`. SAFETY: the payload is live and uniquely
        // owned; the layout is read from the vtable before the value is
        // destroyed.
        unsafe {
            let layout = Layout::for_value(&*self.ptr);
            std::ptr::drop_in_place(self.ptr);
            if self.loc == BlockLoc::Sys && layout.size() > 0 {
                std::alloc::dealloc(self.ptr as *mut u8, layout);
            }
            // Slab blocks stay with their chunk (freed when the
            // SlabAlloc drops); Zst owns no memory.
        }
    }
}

/// What one allocation did — the heap mirrors this into `HeapMetrics`.
#[derive(Clone, Copy, Debug)]
pub(crate) struct AllocReceipt {
    /// Served from a class free list (reuse — the whole point).
    pub reused: bool,
    /// Exact-layout path (large/over-aligned payload or System backend).
    pub large: bool,
    /// Slab block size handed out (0 on the exact-layout/ZST paths).
    pub block_bytes: usize,
    /// The allocation grew the slab by one chunk.
    pub new_chunk: bool,
}

/// What one deallocation returned.
#[derive(Clone, Copy, Debug)]
pub(crate) struct FreeReceipt {
    /// Slab block size returned (0 on the exact-layout/ZST paths).
    pub block_bytes: usize,
}

/// Per-size-class state: chunks, a bump cursor, and the intrusive free
/// list.
struct ClassState {
    block: usize,
    chunks: Vec<Chunk>,
    /// Chunk currently being bumped (`== chunks.len()` only when empty).
    cur: usize,
    /// Bump offset within `chunks[cur]`.
    offset: usize,
    /// Intrusive free-list head (null = empty). Each free block's first
    /// word links to the next free block of the class.
    free: *mut u8,
}

impl ClassState {
    fn new(block: usize) -> ClassState {
        ClassState {
            block,
            chunks: Vec::new(),
            cur: 0,
            offset: 0,
            free: std::ptr::null_mut(),
        }
    }
}

/// The size-class slab allocator owning one heap's payload storage. See
/// the module docs for the design; see `HeapMetrics`' `slab_*` fields for
/// the gauges the owning heap maintains from the receipts.
pub struct SlabAlloc {
    kind: AllocatorKind,
    /// Scratch mode: frees run destructors but build no free lists; the
    /// storage is reclaimed in bulk by [`SlabAlloc::reset`] or drop.
    bump_only: bool,
    classes: Vec<ClassState>,
    /// Slab blocks currently handed out (the reset-safety gauge).
    live_blocks: usize,
}

// SAFETY: the raw free-list pointers and chunk pointers all point into
// memory owned by this SlabAlloc; it is only ever used through `&mut`
// from the single thread that owns the enclosing Heap.
unsafe impl Send for SlabAlloc {}

impl SlabAlloc {
    /// A reuse-mode allocator (the shard-heap default).
    pub fn new(kind: AllocatorKind) -> SlabAlloc {
        SlabAlloc {
            kind,
            bump_only: false,
            classes: SIZE_CLASSES.iter().map(|&b| ClassState::new(b)).collect(),
            live_blocks: 0,
        }
    }

    /// A bump-only allocator for scratch heaps: pure bump allocation, no
    /// free-list maintenance, bulk [`SlabAlloc::reset`]. (Inert under the
    /// `System` backend, which has no slab storage to bump.)
    pub fn scratch(kind: AllocatorKind) -> SlabAlloc {
        SlabAlloc {
            bump_only: true,
            ..SlabAlloc::new(kind)
        }
    }

    #[inline]
    pub fn kind(&self) -> AllocatorKind {
        self.kind
    }

    #[inline]
    pub fn is_bump_only(&self) -> bool {
        self.bump_only
    }

    /// Slab blocks currently handed out.
    #[inline]
    pub fn live_blocks(&self) -> usize {
        self.live_blocks
    }

    /// Rewind every class to empty — the scratch heap's bulk reclaim.
    /// Chunks are kept, so a recycled scratch allocates without touching
    /// the system allocator at all. Every block must have been freed
    /// (destructors run on free in bump-only mode too); resetting with
    /// live blocks would hand their storage out again.
    pub fn reset(&mut self) {
        assert_eq!(self.live_blocks, 0, "reset with live slab blocks");
        for c in &mut self.classes {
            c.cur = 0;
            c.offset = 0;
            c.free = std::ptr::null_mut();
        }
    }

    /// Place `value` (placement-write; the typed hot path — no `Box`).
    pub(crate) fn alloc_value<T: Payload>(&mut self, value: T) -> (PBox, AllocReceipt) {
        let (mem, loc, r) = self.alloc_block(Layout::new::<T>());
        // SAFETY: `mem` has the size/align of `T` and is uniquely ours.
        let ptr = unsafe {
            std::ptr::write(mem as *mut T, value);
            mem as *mut T as *mut dyn Payload
        };
        (PBox { ptr, loc }, r)
    }

    /// Placement-clone `src` (the `Copy`/transplant hot path — no
    /// intermediate `Box`).
    pub(crate) fn alloc_clone(&mut self, src: &dyn Payload) -> (PBox, AllocReceipt) {
        let (mem, loc, r) = self.alloc_block(src.layout());
        // SAFETY: `mem` matches `src.layout()` and is uniquely ours.
        let ptr = unsafe { src.clone_into(mem) };
        (PBox { ptr, loc }, r)
    }

    /// Move a boxed payload into owned storage, freeing the box's
    /// allocation without running the destructor.
    pub(crate) fn adopt_box(&mut self, payload: Box<dyn Payload>) -> (PBox, AllocReceipt) {
        let (mem, loc, r) = self.alloc_block(Layout::for_value(&*payload));
        // SAFETY: `mem` matches the payload's concrete layout.
        let ptr = unsafe { payload.move_into(mem) };
        (PBox { ptr, loc }, r)
    }

    /// Destroy a payload and return its block: destructor in place, then
    /// the block re-enters its class free list (reuse mode) or merely
    /// stops counting as live (bump-only mode); exact-layout memory goes
    /// back to the system allocator.
    pub(crate) fn dealloc(&mut self, payload: PBox) -> FreeReceipt {
        let (ptr, loc) = payload.into_parts();
        // SAFETY: live uniquely-owned payload; layout read before drop.
        let layout = unsafe { Layout::for_value(&*ptr) };
        unsafe { std::ptr::drop_in_place(ptr) };
        match loc {
            BlockLoc::Zst => FreeReceipt { block_bytes: 0 },
            BlockLoc::Sys => {
                // SAFETY: allocated by `alloc_block`'s exact-layout path
                // with this layout.
                unsafe { std::alloc::dealloc(ptr as *mut u8, layout) };
                FreeReceipt { block_bytes: 0 }
            }
            BlockLoc::Slab(ci) => {
                self.live_blocks -= 1;
                let c = &mut self.classes[ci as usize];
                if !self.bump_only {
                    let p = ptr as *mut u8;
                    // SAFETY: the block is ≥ 16 bytes, 16-aligned, and
                    // dead — its first word becomes the free-list link.
                    unsafe { *(p as *mut *mut u8) = c.free };
                    c.free = p;
                }
                FreeReceipt {
                    block_bytes: c.block,
                }
            }
        }
    }

    fn alloc_block(&mut self, layout: Layout) -> (*mut u8, BlockLoc, AllocReceipt) {
        if layout.size() == 0 {
            return (
                layout.align() as *mut u8,
                BlockLoc::Zst,
                AllocReceipt {
                    reused: false,
                    large: false,
                    block_bytes: 0,
                    new_chunk: false,
                },
            );
        }
        let class = if self.kind == AllocatorKind::Slab {
            class_for(layout)
        } else {
            None
        };
        let Some(ci) = class else {
            // SAFETY: nonzero size.
            let p = unsafe { std::alloc::alloc(layout) };
            if p.is_null() {
                std::alloc::handle_alloc_error(layout);
            }
            return (
                p,
                BlockLoc::Sys,
                AllocReceipt {
                    reused: false,
                    large: true,
                    block_bytes: 0,
                    new_chunk: false,
                },
            );
        };
        let c = &mut self.classes[ci];
        self.live_blocks += 1;
        if !c.free.is_null() {
            let p = c.free;
            // SAFETY: `p` is a free block whose first word is the link.
            c.free = unsafe { *(p as *const *mut u8) };
            return (
                p,
                BlockLoc::Slab(ci as u8),
                AllocReceipt {
                    reused: true,
                    large: false,
                    block_bytes: c.block,
                    new_chunk: false,
                },
            );
        }
        // Bump, advancing through retained chunks (a reset scratch walks
        // its old chunks again) and growing by one chunk when all are
        // full.
        let mut new_chunk = false;
        let p = loop {
            if c.cur < c.chunks.len() && c.offset + c.block <= CHUNK_BYTES {
                // SAFETY: offset + block ≤ CHUNK_BYTES keeps the pointer
                // inside the chunk allocation.
                let p = unsafe { c.chunks[c.cur].ptr.add(c.offset) };
                c.offset += c.block;
                break p;
            }
            if c.cur + 1 < c.chunks.len() {
                c.cur += 1;
                c.offset = 0;
                continue;
            }
            c.chunks.push(Chunk::new());
            new_chunk = true;
            c.cur = c.chunks.len() - 1;
            c.offset = 0;
        };
        (
            p,
            BlockLoc::Slab(ci as u8),
            AllocReceipt {
                reused: false,
                large: false,
                block_bytes: c.block,
                new_chunk,
            },
        )
    }
}
