//! Payload storage: the size-class slab allocator (plus large-object
//! space) behind every [`Heap`](super::Heap).
//!
//! The paper's contribution is dynamic memory management for the
//! allocate/copy/mutate/free churn of particle populations, yet a naive
//! heap pays one system-allocator round trip per object payload — the
//! hottest allocation path in the platform. Resampling makes that churn
//! pathological in a very exploitable way: every generation frees and
//! reallocates objects of the *same few size classes* (each model has one
//! or two payload structs), so freed blocks are immediately reusable at
//! exactly the size the next generation asks for.
//!
//! [`SlabAlloc`] exploits that: payload storage is segregated into size
//! classes; each class bump-allocates out of fixed 64 KiB chunks and
//! recycles freed blocks through *per-chunk* intrusive free lists (the
//! freed block's first word is the list link, so free blocks cost no side
//! storage). Payloads whose layout does not fit a class (over 2 KiB, or
//! over-aligned) go to the [large-object space](#large-object-space). The
//! `System` backend ([`AllocatorKind::System`]) bypasses both entirely —
//! every payload takes the exact-layout system path — which is the
//! differential baseline: the allocator must never change what is
//! computed, only where payload bytes live.
//!
//! **Per-chunk liveness.** Every chunk carries live/free/bump counters
//! maintained on every alloc, free and `free_raw` (the block's
//! [`BlockLoc`] names its chunk, so the free-time update is O(1)). The
//! counters buy three things: the empty-chunk scan behind
//! [`SlabAlloc::trim`] is O(chunks) instead of O(free blocks), so decommit
//! can run at *every* generation barrier on huge heaps for free; sparsity
//! is known per chunk, which is what evacuation victims are selected by;
//! and the whole structure is checkable — [`SlabAlloc::validate_counters`]
//! recounts every free list and cross-checks every counter, and the fuzz
//! battery in `tests.rs` runs it after every operation.
//!
//! **Ownership.** A payload lives in slab (or LOS/system) memory as its
//! concrete type, reached through a [`PBox`]: a fat `*mut dyn Payload`
//! plus the block's location tag. The heap's `Slot` stores `Option<PBox>`
//! where it used to store `Option<Box<dyn Payload>>`; the vtable travels
//! in the fat pointer, slot metadata is unchanged. All allocation goes
//! through the owning heap's `SlabAlloc` (placement-clone, placement-move
//! from a `Box`, or direct placement-write of a typed value — see the
//! [`Payload`] trait's placement methods), and all deallocation returns
//! through `SlabAlloc::dealloc`, which runs the payload's destructor in
//! place and pushes the block onto its chunk's free list. Dropping a
//! `PBox` outside the allocator (heap teardown) still runs the destructor
//! and frees exact-layout and LOS memory; a slab block simply stays with
//! its chunk, which the allocator frees wholesale on drop.
//!
//! **Raw (metadata) storage.** Payloads are not the only per-heap
//! structures that churn every generation: memo-table bucket arrays
//! rehash on growth and are freed wholesale on label death, and the label
//! slot vector grows with the lineage population. `SlabAlloc::alloc_raw`
//! / `SlabAlloc::free_raw` serve plain byte blocks from the *same* size
//! classes (LOS for buckets over the largest class), and `SlabVec` plus
//! the memo module's bucket store route those structures through them —
//! so a memo rehash frees a 1 KiB block and the next 1 KiB rehash
//! anywhere in the heap reuses it, closing the last per-generation
//! system-allocator traffic. Raw allocations are accounted separately
//! from payload allocations (see the `slab_raw_*` fields of
//! [`HeapMetrics`](super::HeapMetrics)), through the crate-internal
//! `RawCtx` handle that pairs the allocator with the owning heap's
//! metrics. Chunks holding raw blocks are *pinned* against evacuation
//! (`live_raw` counter): raw blocks are reachable only from their owning
//! containers, not from heap slots, so the evacuation slot-walk cannot
//! move them.
//!
//! **Large-object space.** Requests that fit no size class (payload or
//! raw, over 2 KiB or over-aligned on the `Slab` backend) are served by
//! [`Los`]: each block is a single system allocation with a small header
//! (total size, alignment, free-list link) in front of the payload.
//! Freed blocks go on a LIFO free list and are reused first-fit with a
//! 2× waste bound, so the memo table's largest bucket arrays and big
//! model payloads stop round-tripping through the system allocator on
//! every churn cycle. [`SlabAlloc::trim`] returns free LOS blocks beyond
//! the watermark; the owning heap accounts the space through the
//! `los_*` fields of [`HeapMetrics`](super::HeapMetrics).
//!
//! **Scratch heaps** (work-stealing donations) get a *bump-only*
//! allocator ([`SlabAlloc::scratch`]): they drain completely at every
//! generation barrier, so maintaining free lists for blocks that are
//! about to be released en masse is wasted work — frees only run the
//! destructor, and the storage is reclaimed in bulk when the scratch heap
//! drops (or recycled with [`SlabAlloc::reset`], which rewinds every
//! chunk's bump cursor while keeping the chunks). Raw allocations in a
//! bump-only allocator go to the LOS regardless of size: metadata blocks
//! must survive `reset` (which rewinds every bump cursor), so they cannot
//! live in the rewindable chunks — and the LOS free list means a recycled
//! scratch heap reuses its old metadata blocks instead of paying fresh
//! system allocations.
//!
//! **Decommit.** A reuse-mode allocator never shrinks on its own: chunks
//! committed for one load spike stay committed for the life of the heap.
//! `SlabAlloc::trim` (surfaced as [`Heap::trim`](super::Heap::trim)) is
//! the watermark decommit pass for long-running servers: at a generation
//! barrier it finds fully-empty chunks — the per-chunk live counter is
//! zero — per size class and returns the ones beyond a configurable
//! watermark to the system allocator, discarding their free lists
//! wholesale (no rebuild: each chunk owns its own list). Live blocks pin
//! their chunk by definition, so decommit never moves or invalidates
//! storage — outputs are bit-identical with decommit on or off.
//!
//! **Evacuation.** Decommit only helps when churn happens to empty a
//! chunk completely; resampling instead scatters survivors thinly across
//! many chunks. [`SlabAlloc::begin_evacuation`] marks chunks whose live
//! bytes fall below a sparsity threshold (and which hold no raw blocks
//! and are not the bump chunk) as victims and detaches their free lists;
//! the owning heap then walks its slots and placement-moves every
//! surviving payload out of a victim with [`SlabAlloc::evacuate_block`]
//! (a bitwise [`Payload::relocate`] into a fresh block of the same
//! class); [`SlabAlloc::finish_evacuation`] decommits the now-empty
//! victims. `Lazy` handles and memo entries are index-based — only the
//! slot's `PBox` fat pointer is re-pointed — so evacuation relocates
//! storage without changing a single output bit. Opt-in via
//! `--evacuate-threshold`.

use std::alloc::Layout;
use std::ops::{Deref, DerefMut};

use super::metrics::HeapMetrics;
use super::payload::Payload;

#[cfg(test)]
mod tests;

/// Payload-storage backend selector (`--allocator`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AllocatorKind {
    /// Every payload through the system allocator with its exact layout
    /// (the pre-slab behaviour; the differential baseline).
    System,
    /// Size-class slabs with free-list reuse plus the large-object space
    /// (the default).
    Slab,
}

impl AllocatorKind {
    /// Parse a backend name as accepted by `--allocator`.
    pub fn parse(s: &str) -> Option<AllocatorKind> {
        match s.to_ascii_lowercase().as_str() {
            "system" | "sys" | "malloc" => Some(AllocatorKind::System),
            "slab" => Some(AllocatorKind::Slab),
            _ => None,
        }
    }

    /// Canonical name (CLI/bench labels).
    pub fn name(self) -> &'static str {
        match self {
            AllocatorKind::System => "system",
            AllocatorKind::Slab => "slab",
        }
    }

    /// Both backends (test sweeps).
    pub const ALL: [AllocatorKind; 2] = [AllocatorKind::System, AllocatorKind::Slab];
}

/// Block sizes served from slabs. Multiples of [`BLOCK_ALIGN`]; requests
/// above the last class (or over-aligned) take the large-object space.
/// The classes are dense at the bottom — every evaluation model's payload
/// struct lands in 16..384 — and quarter-spaced above.
pub(crate) const SIZE_CLASSES: [usize; 14] = [
    16, 32, 48, 64, 96, 128, 192, 256, 384, 512, 768, 1024, 1536, 2048,
];

/// Alignment of every slab block (and chunk). Payloads needing more go to
/// the large-object space.
pub(crate) const BLOCK_ALIGN: usize = 16;

/// Bytes per slab chunk. Small enough that a scratch heap costs little,
/// large enough that the smallest class amortizes 4096 blocks per system
/// allocation.
pub const CHUNK_BYTES: usize = 64 * 1024;

/// Default decommit watermark: fully-empty chunks kept per size class at
/// a [`Heap::trim`](super::Heap::trim) barrier before the rest are
/// returned to the system allocator (`--decommit-watermark`, config key
/// `decommit_watermark`). Two chunks absorb the steady-state churn of a
/// generation without re-committing, while anything beyond is spike
/// residue worth returning.
pub const DEFAULT_DECOMMIT_WATERMARK: usize = 2;

/// Smallest class index whose block fits `size`, or `None` for the
/// large-object space.
#[inline]
fn class_for(layout: Layout) -> Option<usize> {
    if layout.align() > BLOCK_ALIGN || layout.size() > SIZE_CLASSES[SIZE_CLASSES.len() - 1] {
        return None;
    }
    // Linear scan: 14 entries, branch-predicted cold tail (real payloads
    // hit within the first few classes).
    SIZE_CLASSES.iter().position(|&b| layout.size() <= b)
}

fn chunk_layout() -> Layout {
    Layout::from_size_align(CHUNK_BYTES, BLOCK_ALIGN).expect("chunk layout")
}

/// One 64 KiB slab chunk: raw memory so block pointers have plain
/// provenance (no `Box` aliasing contract to violate while `PBox`es point
/// into the chunk long-term).
struct Chunk {
    ptr: *mut u8,
}

impl Chunk {
    fn new() -> Chunk {
        let l = chunk_layout();
        // SAFETY: `l` has nonzero size.
        let ptr = unsafe { std::alloc::alloc(l) };
        if ptr.is_null() {
            std::alloc::handle_alloc_error(l);
        }
        Chunk { ptr }
    }
}

impl Drop for Chunk {
    fn drop(&mut self) {
        // SAFETY: allocated in `Chunk::new` with the same layout.
        unsafe { std::alloc::dealloc(self.ptr, chunk_layout()) };
    }
}

/// Where a block came from — what [`SlabAlloc::dealloc`] /
/// [`SlabAlloc::free_raw`] (or a teardown `Drop`) must do with the
/// memory. Carried by [`PBox`] for payloads and by the slab-resident
/// containers ([`SlabVec`], the memo bucket store) for raw blocks.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum BlockLoc {
    /// A slab block: size class plus the owning chunk's slot index, so
    /// the free-time counter update is O(1).
    Slab {
        /// Size-class index into [`SIZE_CLASSES`].
        class: u8,
        /// Chunk slot index within the class (stable across trim —
        /// vacated slots are recycled, never compacted away).
        chunk: u32,
    },
    /// A large-object-space block (over 2 KiB or over-aligned on the
    /// `Slab` backend).
    Los,
    /// Exact-layout system allocation (everything under the `System`
    /// backend).
    Sys,
    /// Zero-sized payload: no storage at all.
    Zst,
}

/// Owning handle to a payload stored in a [`SlabAlloc`] (or LOS/system
/// memory). Behaves like `Box<dyn Payload>` for access (`Deref`), but
/// deallocation belongs to the allocator: return it through
/// `SlabAlloc::dealloc` so the block re-enters its chunk's free list.
/// Dropping a `PBox` directly (heap teardown, unwind paths) is safe — the
/// payload destructor runs and exact-layout/LOS memory is freed — but a
/// slab block then stays with its chunk until the allocator drops.
pub struct PBox {
    ptr: *mut dyn Payload,
    loc: BlockLoc,
}

// SAFETY: a PBox uniquely owns its payload (`Payload: Send` is a
// supertrait), and it only ever moves between threads together with the
// Heap that owns both the slot holding it and the SlabAlloc holding its
// storage — the same whole-heap transfer discipline the old
// `Box<dyn Payload>` payloads relied on.
unsafe impl Send for PBox {}

impl PBox {
    /// Disassemble without running `Drop` (the allocator's dealloc path).
    fn into_parts(self) -> (*mut dyn Payload, BlockLoc) {
        let m = std::mem::ManuallyDrop::new(self);
        (m.ptr, m.loc)
    }
}

impl Deref for PBox {
    type Target = dyn Payload;
    #[inline]
    fn deref(&self) -> &dyn Payload {
        // SAFETY: `ptr` points at a live payload owned by this PBox.
        unsafe { &*self.ptr }
    }
}

impl DerefMut for PBox {
    #[inline]
    fn deref_mut(&mut self) -> &mut dyn Payload {
        // SAFETY: as above; `&mut self` gives exclusive access.
        unsafe { &mut *self.ptr }
    }
}

impl Drop for PBox {
    fn drop(&mut self) {
        // Teardown fallback only: the accounted path is
        // `SlabAlloc::dealloc`. SAFETY: the payload is live and uniquely
        // owned; the layout is read from the vtable before the value is
        // destroyed.
        unsafe {
            let layout = Layout::for_value(&*self.ptr);
            std::ptr::drop_in_place(self.ptr);
            match self.loc {
                BlockLoc::Sys if layout.size() > 0 => {
                    std::alloc::dealloc(self.ptr as *mut u8, layout);
                }
                BlockLoc::Los => los_teardown_free(self.ptr as *mut u8, layout),
                // Slab blocks stay with their chunk (freed when the
                // SlabAlloc drops); Zst owns no memory.
                _ => {}
            }
        }
    }
}

/// What one allocation did — the heap mirrors this into `HeapMetrics`.
#[derive(Clone, Copy, Debug)]
pub(crate) struct AllocReceipt {
    /// Served from a free list (slab chunk or LOS — reuse, the whole
    /// point).
    pub reused: bool,
    /// Off-slab path (LOS block or System-backend exact layout).
    pub large: bool,
    /// Slab block size handed out (0 on the LOS/exact-layout/ZST paths).
    pub block_bytes: usize,
    /// The allocation grew the slab by one chunk.
    pub new_chunk: bool,
    /// Total LOS bytes of the block handed out, header included (0 off
    /// the LOS path).
    pub los_bytes: usize,
}

/// What one deallocation returned.
#[derive(Clone, Copy, Debug)]
pub(crate) struct FreeReceipt {
    /// Slab block size returned (0 on the LOS/exact-layout/ZST paths).
    pub block_bytes: usize,
    /// Total LOS bytes returned to the LOS free list (0 off the LOS
    /// path).
    pub los_bytes: usize,
}

/// One chunk slot of a [`ClassState`]: the committed memory (if any) plus
/// the per-chunk liveness counters and intrusive free list. Slots are
/// stable — a decommitted chunk leaves its slot behind (`chunk: None`,
/// recorded in the class's vacant list) so every outstanding
/// [`BlockLoc::Slab`] index stays valid.
struct ChunkState {
    /// The 64 KiB allocation, `None` while the slot is vacant.
    chunk: Option<Chunk>,
    /// This chunk's intrusive free-list head (null = empty). Each free
    /// block's first word links to the next free block *of this chunk*.
    free: *mut u8,
    /// Blocks on `free` (kept exact so `trim` never walks a list).
    free_count: u32,
    /// Blocks handed out and not yet freed — the liveness counter.
    live: u32,
    /// Live blocks that are raw (metadata) allocations. Raw blocks are
    /// unreachable from heap slots, so `live_raw > 0` pins the chunk
    /// against evacuation.
    live_raw: u32,
    /// Blocks ever bumped out of this chunk since commit/reset.
    bumped: u32,
    /// Whether this chunk is on the class's avail stack (has free
    /// blocks to pop). Kept in lockstep with membership.
    in_avail: bool,
    /// Marked as an evacuation victim between `begin_evacuation` and
    /// `finish_evacuation`.
    evacuating: bool,
}

impl ChunkState {
    /// A freshly committed chunk (one new 64 KiB system allocation).
    fn committed() -> ChunkState {
        ChunkState {
            chunk: Some(Chunk::new()),
            free: std::ptr::null_mut(),
            free_count: 0,
            live: 0,
            live_raw: 0,
            bumped: 0,
            in_avail: false,
            evacuating: false,
        }
    }
}

/// Per-size-class state: chunk slots, the avail stack of chunks with
/// free blocks, the vacant slot list, and the current bump chunk.
struct ClassState {
    /// Block size of this class.
    block: usize,
    /// Chunk slots; indices are stable (see [`ChunkState`]).
    chunks: Vec<ChunkState>,
    /// Slot indices with `chunk: None`, reusable by the next commit.
    vacant: Vec<u32>,
    /// LIFO stack of chunk slots with non-empty free lists. Invariant:
    /// a committed, non-evacuating chunk is on the stack iff
    /// `free_count > 0` (and `in_avail` mirrors membership).
    avail: Vec<u32>,
    /// Chunk currently being bump-allocated, if any.
    bump: Option<u32>,
}

impl ClassState {
    fn new(block: usize) -> ClassState {
        ClassState {
            block,
            chunks: Vec::new(),
            vacant: Vec::new(),
            avail: Vec::new(),
            bump: None,
        }
    }
}

/// The size-class slab allocator (plus large-object space) owning one
/// heap's payload storage. See the module docs for the design; see
/// `HeapMetrics`' `slab_*` and `los_*` fields for the gauges the owning
/// heap maintains from the receipts.
pub struct SlabAlloc {
    kind: AllocatorKind,
    /// Scratch mode: frees run destructors but build no free lists; the
    /// storage is reclaimed in bulk by [`SlabAlloc::reset`] or drop.
    bump_only: bool,
    classes: Vec<ClassState>,
    /// Slab blocks currently handed out (the reset-safety gauge; LOS
    /// blocks are tracked inside [`Los`]).
    live_blocks: usize,
    /// The large-object space shared by all classes' misfits.
    los: Los,
}

// SAFETY: the raw free-list pointers and chunk pointers all point into
// memory owned by this SlabAlloc; it is only ever used through `&mut`
// from the single thread that owns the enclosing Heap.
unsafe impl Send for SlabAlloc {}

impl SlabAlloc {
    /// A reuse-mode allocator (the shard-heap default).
    pub fn new(kind: AllocatorKind) -> SlabAlloc {
        SlabAlloc {
            kind,
            bump_only: false,
            classes: SIZE_CLASSES.iter().map(|&b| ClassState::new(b)).collect(),
            live_blocks: 0,
            los: Los::new(),
        }
    }

    /// A bump-only allocator for scratch heaps: pure bump allocation, no
    /// free-list maintenance, bulk [`SlabAlloc::reset`]. (Inert under the
    /// `System` backend, which has no slab storage to bump.)
    pub fn scratch(kind: AllocatorKind) -> SlabAlloc {
        SlabAlloc {
            bump_only: true,
            ..SlabAlloc::new(kind)
        }
    }

    /// The backend this allocator serves payloads with.
    #[inline]
    pub fn kind(&self) -> AllocatorKind {
        self.kind
    }

    /// Whether this is the scratch-heap bump-only variant.
    #[inline]
    pub fn is_bump_only(&self) -> bool {
        self.bump_only
    }

    /// Slab blocks currently handed out.
    #[inline]
    pub fn live_blocks(&self) -> usize {
        self.live_blocks
    }

    /// Rewind every class to empty — the scratch heap's bulk reclaim.
    /// Chunks are kept, so a recycled scratch allocates without touching
    /// the system allocator at all (the next bump pass finds the retained
    /// chunks virgin again). Every block must have been freed
    /// (destructors run on free in bump-only mode too); resetting with
    /// live blocks would hand their storage out again. The LOS is
    /// deliberately untouched: its blocks (scratch metadata) survive
    /// reset on the free list for the next incarnation to reuse.
    pub fn reset(&mut self) {
        assert_eq!(self.live_blocks, 0, "reset with live slab blocks");
        for c in &mut self.classes {
            c.avail.clear();
            c.bump = None;
            for ch in &mut c.chunks {
                debug_assert!(!ch.evacuating, "reset during evacuation");
                ch.free = std::ptr::null_mut();
                ch.free_count = 0;
                ch.live = 0;
                ch.live_raw = 0;
                ch.bumped = 0;
                ch.in_avail = false;
            }
        }
    }

    /// Place `value` (placement-write; the typed hot path — no `Box`).
    pub(crate) fn alloc_value<T: Payload>(&mut self, value: T) -> (PBox, AllocReceipt) {
        let (mem, loc, r) = self.alloc_block(Layout::new::<T>(), false);
        // SAFETY: `mem` has the size/align of `T` and is uniquely ours.
        let ptr = unsafe {
            std::ptr::write(mem as *mut T, value);
            mem as *mut T as *mut dyn Payload
        };
        (PBox { ptr, loc }, r)
    }

    /// Placement-clone `src` (the `Copy`/transplant hot path — no
    /// intermediate `Box`).
    pub(crate) fn alloc_clone(&mut self, src: &dyn Payload) -> (PBox, AllocReceipt) {
        let (mem, loc, r) = self.alloc_block(src.layout(), false);
        // SAFETY: `mem` matches `src.layout()` and is uniquely ours.
        let ptr = unsafe { src.clone_into(mem) };
        (PBox { ptr, loc }, r)
    }

    /// Move a boxed payload into owned storage, freeing the box's
    /// allocation without running the destructor.
    pub(crate) fn adopt_box(&mut self, payload: Box<dyn Payload>) -> (PBox, AllocReceipt) {
        let (mem, loc, r) = self.alloc_block(Layout::for_value(&*payload), false);
        // SAFETY: `mem` matches the payload's concrete layout.
        let ptr = unsafe { payload.move_into(mem) };
        (PBox { ptr, loc }, r)
    }

    /// Destroy a payload and return its block: destructor in place, then
    /// the block re-enters its chunk's free list (reuse mode) or merely
    /// stops counting as live (bump-only mode); LOS blocks go on the LOS
    /// free list, exact-layout memory back to the system allocator.
    pub(crate) fn dealloc(&mut self, payload: PBox) -> FreeReceipt {
        let (ptr, loc) = payload.into_parts();
        // SAFETY: live uniquely-owned payload; layout read before drop.
        let layout = unsafe { Layout::for_value(&*ptr) };
        unsafe { std::ptr::drop_in_place(ptr) };
        self.release(ptr as *mut u8, layout, loc, false)
    }

    /// Raw-bytes allocation over the same size classes as payloads — the
    /// storage path of memo bucket arrays and label slot vectors. Three
    /// deviations from the payload path: bump-only (scratch) allocators
    /// route *every* raw request through the LOS, because metadata must
    /// survive [`SlabAlloc::reset`]'s bump rewind (and the LOS free list
    /// lets a recycled scratch reuse its old blocks); the `System`
    /// backend takes exact layout (its contract — no slab storage at
    /// all); oversized/over-aligned requests go to the LOS just like
    /// large payloads. Callers go through [`RawCtx`] so the receipt lands
    /// in the owning heap's metrics.
    pub(crate) fn alloc_raw(&mut self, layout: Layout) -> (*mut u8, BlockLoc, AllocReceipt) {
        if layout.size() == 0 || self.kind != AllocatorKind::Slab {
            return Self::alloc_exact(layout);
        }
        if self.bump_only {
            return self.los.alloc(layout);
        }
        self.alloc_block(layout, true)
    }

    /// Return a raw block obtained from [`SlabAlloc::alloc_raw`]. No
    /// destructor runs — the caller owns the contents; slab blocks
    /// re-enter their chunk's free list, LOS blocks the LOS free list,
    /// exact-layout memory goes back to the system allocator.
    pub(crate) fn free_raw(&mut self, ptr: *mut u8, layout: Layout, loc: BlockLoc) -> FreeReceipt {
        self.release(ptr, layout, loc, true)
    }

    /// The shared free path behind [`SlabAlloc::dealloc`] (`raw: false`)
    /// and [`SlabAlloc::free_raw`] (`raw: true`): route the block back to
    /// wherever it came from and keep the per-chunk counters exact.
    fn release(&mut self, ptr: *mut u8, layout: Layout, loc: BlockLoc, raw: bool) -> FreeReceipt {
        match loc {
            BlockLoc::Zst => FreeReceipt {
                block_bytes: 0,
                los_bytes: 0,
            },
            BlockLoc::Sys => {
                debug_assert!(layout.size() > 0);
                // SAFETY: allocated by the exact-layout path with this
                // layout.
                unsafe { std::alloc::dealloc(ptr, layout) };
                FreeReceipt {
                    block_bytes: 0,
                    los_bytes: 0,
                }
            }
            BlockLoc::Los => self.los.free(ptr, layout),
            BlockLoc::Slab { class, chunk } => {
                self.live_blocks -= 1;
                let c = &mut self.classes[class as usize];
                let ch = &mut c.chunks[chunk as usize];
                debug_assert!(ch.chunk.is_some(), "free into a vacant chunk slot");
                debug_assert!(!ch.evacuating, "free into an evacuating chunk");
                ch.live -= 1;
                if raw {
                    ch.live_raw -= 1;
                }
                if !self.bump_only {
                    // SAFETY: the block is ≥ 16 bytes, 16-aligned, and
                    // dead — its first word becomes the free-list link.
                    unsafe { *(ptr as *mut *mut u8) = ch.free };
                    ch.free = ptr;
                    ch.free_count += 1;
                    if !ch.in_avail {
                        ch.in_avail = true;
                        c.avail.push(chunk);
                    }
                }
                FreeReceipt {
                    block_bytes: c.block,
                    los_bytes: 0,
                }
            }
        }
    }

    /// The exact-layout path: ZSTs, and everything under the `System`
    /// backend.
    fn alloc_exact(layout: Layout) -> (*mut u8, BlockLoc, AllocReceipt) {
        if layout.size() == 0 {
            return (
                layout.align() as *mut u8,
                BlockLoc::Zst,
                AllocReceipt {
                    reused: false,
                    large: false,
                    block_bytes: 0,
                    new_chunk: false,
                    los_bytes: 0,
                },
            );
        }
        // SAFETY: nonzero size.
        let p = unsafe { std::alloc::alloc(layout) };
        if p.is_null() {
            std::alloc::handle_alloc_error(layout);
        }
        (
            p,
            BlockLoc::Sys,
            AllocReceipt {
                reused: false,
                large: true,
                block_bytes: 0,
                new_chunk: false,
                los_bytes: 0,
            },
        )
    }

    /// The block allocation path shared by payloads (`raw: false`) and
    /// reuse-mode raw requests (`raw: true`): pop from the avail stack's
    /// top chunk, else bump — advancing through retained virgin chunks (a
    /// reset scratch walks its old chunks again) and committing a fresh
    /// chunk (into a vacant slot if one exists) when all are full.
    fn alloc_block(&mut self, layout: Layout, raw: bool) -> (*mut u8, BlockLoc, AllocReceipt) {
        if layout.size() == 0 || self.kind != AllocatorKind::Slab {
            return Self::alloc_exact(layout);
        }
        let Some(ci) = class_for(layout) else {
            return self.los.alloc(layout);
        };
        let c = &mut self.classes[ci];
        self.live_blocks += 1;
        if let Some(&j) = c.avail.last() {
            let block = c.block;
            let ch = &mut c.chunks[j as usize];
            let p = ch.free;
            debug_assert!(!p.is_null(), "avail chunk with empty free list");
            // SAFETY: `p` is a free block whose first word is the link.
            ch.free = unsafe { *(p as *const *mut u8) };
            ch.free_count -= 1;
            ch.live += 1;
            ch.live_raw += u32::from(raw);
            if ch.free_count == 0 {
                ch.in_avail = false;
                c.avail.pop();
            }
            return (
                p,
                BlockLoc::Slab {
                    class: ci as u8,
                    chunk: j,
                },
                AllocReceipt {
                    reused: true,
                    large: false,
                    block_bytes: block,
                    new_chunk: false,
                    los_bytes: 0,
                },
            );
        }
        // Bump path.
        let mut new_chunk = false;
        let j = loop {
            if let Some(j) = c.bump {
                if (c.chunks[j as usize].bumped as usize + 1) * c.block <= CHUNK_BYTES {
                    break j;
                }
                c.bump = None;
            }
            // A retained virgin chunk (reset scratch, or decommit-spared
            // spare)? O(chunks), and runs at most once per chunk-fill.
            if let Some(v) = c
                .chunks
                .iter()
                .position(|ch| ch.chunk.is_some() && ch.bumped == 0)
            {
                c.bump = Some(v as u32);
                continue;
            }
            // Commit a fresh chunk, reusing a vacant slot if any (keeps
            // outstanding BlockLoc chunk indices stable and the slot
            // vector from growing without bound under trim churn).
            let j = if let Some(j) = c.vacant.pop() {
                c.chunks[j as usize].chunk = Some(Chunk::new());
                j
            } else {
                c.chunks.push(ChunkState::committed());
                (c.chunks.len() - 1) as u32
            };
            new_chunk = true;
            c.bump = Some(j);
            break j;
        };
        let block = c.block;
        let ch = &mut c.chunks[j as usize];
        let off = ch.bumped as usize * block;
        // SAFETY: `(bumped + 1) * block <= CHUNK_BYTES` (checked above;
        // trivially true for a fresh chunk) keeps the pointer inside the
        // chunk allocation.
        let p = unsafe { ch.chunk.as_ref().expect("bump chunk committed").ptr.add(off) };
        ch.bumped += 1;
        ch.live += 1;
        ch.live_raw += u32::from(raw);
        (
            p,
            BlockLoc::Slab {
                class: ci as u8,
                chunk: j,
            },
            AllocReceipt {
                reused: false,
                large: false,
                block_bytes: block,
                new_chunk,
                los_bytes: 0,
            },
        )
    }

    /// Watermark decommit pass (`Heap::trim` calls this at generation
    /// barriers): per size class, find *fully-empty* chunks — the live
    /// counter is zero — and return the ones beyond `keep` to the system
    /// allocator, discarding their free lists wholesale. O(chunks): the
    /// per-chunk counters make the scan independent of how many free
    /// blocks exist, which is what lets trim run at every barrier on huge
    /// heaps. Chunks holding any live block are never touched, so no
    /// pointer is invalidated; the current bump chunk is kept
    /// preferentially (it holds the class's only virgin space). Also
    /// trims the LOS free list beyond `keep` blocks. No-op for bump-only
    /// (scratch) allocators, whose retain-everything pooling contract
    /// this deliberately preserves, and for the `System` backend (no
    /// chunks exist).
    pub(crate) fn trim(&mut self, keep: usize) -> TrimStats {
        let mut stats = TrimStats {
            chunks: 0,
            bytes: 0,
            los_blocks: 0,
            los_bytes: 0,
        };
        if self.bump_only || self.kind != AllocatorKind::Slab {
            return stats;
        }
        for c in &mut self.classes {
            let mut empties: Vec<u32> = Vec::new();
            for (j, ch) in c.chunks.iter().enumerate() {
                if ch.chunk.is_some() && ch.live == 0 {
                    debug_assert!(!ch.evacuating);
                    empties.push(j as u32);
                }
            }
            if empties.len() <= keep {
                continue;
            }
            // Keep the bump chunk preferentially — its virgin space is
            // the cheapest storage the class has. Moving it to the back
            // puts it among the survivors (the last `keep` entries).
            if let Some(b) = c.bump {
                if let Some(pos) = empties.iter().position(|&j| j == b) {
                    empties.remove(pos);
                    empties.push(b);
                }
            }
            let n_drop = empties.len() - keep;
            for &j in &empties[..n_drop] {
                let ch = &mut c.chunks[j as usize];
                // Dropping the Option's Chunk returns the 64 KiB to the
                // system allocator; the free list dies with it (each
                // chunk owns its own list — nothing to rebuild).
                ch.chunk = None;
                ch.free = std::ptr::null_mut();
                ch.free_count = 0;
                ch.bumped = 0;
                ch.in_avail = false;
                c.vacant.push(j);
                if c.bump == Some(j) {
                    c.bump = None;
                }
                stats.chunks += 1;
                stats.bytes += CHUNK_BYTES;
            }
            let chunks = &c.chunks;
            c.avail.retain(|&j| chunks[j as usize].in_avail);
        }
        let (lb, lbytes) = self.los.trim(keep);
        stats.los_blocks = lb;
        stats.los_bytes = lbytes;
        stats
    }

    /// Mark evacuation victims: committed chunks whose live payload bytes
    /// are at or below `threshold × CHUNK_BYTES`, hold at least one live
    /// block (fully-empty chunks are `trim`'s business), hold *no* raw
    /// blocks (raw blocks are unreachable from heap slots, so they pin
    /// the chunk), and are not the current bump chunk. Victims leave the
    /// avail stack and their free lists are discarded — the survivors are
    /// about to be moved out and the chunk decommitted by
    /// [`SlabAlloc::finish_evacuation`]. Returns whether any victim was
    /// marked; `false` (bump-only or `System` backend, or nothing sparse
    /// enough) means the heap can skip the slot walk. A threshold of 0.0
    /// never selects (a victim needs `live > 0`); 1.0 compacts every
    /// non-pinned chunk.
    pub(crate) fn begin_evacuation(&mut self, threshold: f64) -> bool {
        if self.bump_only || self.kind != AllocatorKind::Slab {
            return false;
        }
        let mut any = false;
        for c in &mut self.classes {
            let limit = threshold * CHUNK_BYTES as f64;
            let bump = c.bump;
            let block = c.block;
            let mut marked = false;
            for (j, ch) in c.chunks.iter_mut().enumerate() {
                if ch.chunk.is_none() || ch.live == 0 || ch.live_raw > 0 {
                    continue;
                }
                if bump == Some(j as u32) {
                    continue;
                }
                if (ch.live as usize * block) as f64 > limit {
                    continue;
                }
                ch.evacuating = true;
                ch.free = std::ptr::null_mut();
                ch.free_count = 0;
                ch.in_avail = false;
                marked = true;
            }
            if marked {
                let chunks = &c.chunks;
                c.avail.retain(|&j| !chunks[j as usize].evacuating);
                any = true;
            }
        }
        any
    }

    /// Placement-move one payload out of an evacuating chunk: allocate a
    /// fresh block of the same class (victims are detached from the avail
    /// stack and never the bump chunk, so the destination is always a
    /// non-victim), bitwise-relocate the payload, and re-point the `PBox`
    /// in place. Returns `None` if the payload is not in an evacuating
    /// chunk (the common case on the slot walk). The vacated block is
    /// simply forgotten — its chunk is decommitted wholesale by
    /// [`SlabAlloc::finish_evacuation`].
    pub(crate) fn evacuate_block(&mut self, pb: &mut PBox) -> Option<EvacMove> {
        let BlockLoc::Slab { class, chunk } = pb.loc else {
            return None;
        };
        if !self.classes[class as usize].chunks[chunk as usize].evacuating {
            return None;
        }
        // SAFETY: live payload; layout read from the vtable.
        let layout = unsafe { Layout::for_value(&*pb.ptr) };
        let (mem, loc, r) = self.alloc_block(layout, false);
        debug_assert!(
            matches!(loc, BlockLoc::Slab { .. }),
            "evacuation destination off-slab"
        );
        // SAFETY: `mem` matches the payload's layout and is a fresh
        // disjoint block; the source is treated as moved-out (its chunk
        // is dropped without running destructors).
        let new_ptr = unsafe { pb.relocate(mem) };
        let c = &mut self.classes[class as usize];
        let ch = &mut c.chunks[chunk as usize];
        ch.live -= 1;
        // Net zero with the destination alloc above: evacuation moves a
        // block, it does not create one.
        self.live_blocks -= 1;
        pb.ptr = new_ptr;
        pb.loc = loc;
        Some(EvacMove {
            bytes: c.block,
            new_chunk: r.new_chunk,
        })
    }

    /// Decommit the (now empty) evacuation victims and clear the marks.
    /// Call after the owning heap has walked every slot through
    /// [`SlabAlloc::evacuate_block`]. Returns the freed chunks as
    /// [`TrimStats`] (LOS fields zero) for the heap's committed gauges.
    pub(crate) fn finish_evacuation(&mut self) -> TrimStats {
        let mut stats = TrimStats {
            chunks: 0,
            bytes: 0,
            los_blocks: 0,
            los_bytes: 0,
        };
        for c in &mut self.classes {
            for (j, ch) in c.chunks.iter_mut().enumerate() {
                if !ch.evacuating {
                    continue;
                }
                debug_assert_eq!(ch.live, 0, "evacuation left a live block behind");
                ch.evacuating = false;
                if ch.live > 0 {
                    // Defensive (unreachable by construction: every
                    // payload block is reachable from a slot, and raw
                    // blocks pin their chunk): keep the chunk committed
                    // rather than free storage under a live pointer. Its
                    // discarded free blocks leak until the chunk empties.
                    continue;
                }
                ch.chunk = None;
                ch.free = std::ptr::null_mut();
                ch.free_count = 0;
                ch.live_raw = 0;
                ch.bumped = 0;
                ch.in_avail = false;
                c.vacant.push(j as u32);
                stats.chunks += 1;
                stats.bytes += CHUNK_BYTES;
            }
        }
        stats
    }

    /// Recount-and-cross-check every per-chunk counter against ground
    /// truth — the heap-invariant oracle behind the fuzz battery (and
    /// the differential suite's post-run sweep). Walks each chunk's free
    /// list and asserts: the recount equals `free_count`; every link
    /// stays inside its chunk on a block boundary; reuse-mode chunks
    /// satisfy `live + free_count == bumped` (bump-only chunks build no
    /// free lists, so only `live <= bumped`); `live_raw <= live`;
    /// `in_avail` mirrors avail-stack membership exactly (no duplicates)
    /// and holds iff `free_count > 0`; vacant slots are truly vacant; and
    /// the per-chunk live counters sum to [`SlabAlloc::live_blocks`].
    /// O(blocks) — test/debug only, never on a hot path.
    pub fn validate_counters(&self) {
        let mut live_sum = 0usize;
        for (ci, c) in self.classes.iter().enumerate() {
            let mut avail_set = vec![false; c.chunks.len()];
            for &j in &c.avail {
                let j = j as usize;
                assert!(j < c.chunks.len(), "class {ci}: avail index {j} out of range");
                assert!(!avail_set[j], "class {ci}: duplicate avail entry {j}");
                avail_set[j] = true;
            }
            for &j in &c.vacant {
                assert!(
                    c.chunks[j as usize].chunk.is_none(),
                    "class {ci}: vacant slot {j} still committed"
                );
            }
            for (j, ch) in c.chunks.iter().enumerate() {
                let Some(chunk) = &ch.chunk else {
                    assert_eq!(ch.free_count, 0, "class {ci} slot {j}: vacant with free blocks");
                    assert_eq!(ch.live, 0, "class {ci} slot {j}: vacant with live blocks");
                    assert_eq!(ch.live_raw, 0, "class {ci} slot {j}: vacant with raw blocks");
                    assert_eq!(ch.bumped, 0, "class {ci} slot {j}: vacant with bumped blocks");
                    assert!(!ch.in_avail && !avail_set[j], "class {ci} slot {j}: vacant on avail");
                    assert!(!ch.evacuating, "class {ci} slot {j}: vacant evacuating");
                    continue;
                };
                let base = chunk.ptr as usize;
                assert!(
                    ch.bumped as usize * c.block <= CHUNK_BYTES,
                    "class {ci} chunk {j}: bumped past chunk end"
                );
                let mut n = 0u32;
                let mut p = ch.free;
                while !p.is_null() {
                    let addr = p as usize;
                    assert!(
                        addr >= base && addr < base + CHUNK_BYTES,
                        "class {ci} chunk {j}: free link outside chunk"
                    );
                    assert_eq!(
                        (addr - base) % c.block,
                        0,
                        "class {ci} chunk {j}: misaligned free link"
                    );
                    n += 1;
                    assert!(
                        n <= ch.bumped,
                        "class {ci} chunk {j}: free list longer than bumped blocks"
                    );
                    // SAFETY: `p` is a free block; its first word is the
                    // link.
                    p = unsafe { *(p as *const *mut u8) };
                }
                assert_eq!(n, ch.free_count, "class {ci} chunk {j}: free_count drift");
                assert!(
                    ch.live_raw <= ch.live,
                    "class {ci} chunk {j}: live_raw exceeds live"
                );
                if self.bump_only {
                    assert_eq!(ch.free_count, 0, "class {ci} chunk {j}: scratch free list");
                    assert!(!ch.in_avail, "class {ci} chunk {j}: scratch on avail");
                    assert!(ch.live <= ch.bumped, "class {ci} chunk {j}: live past bumped");
                } else if ch.evacuating {
                    assert_eq!(ch.free_count, 0, "class {ci} chunk {j}: victim free list");
                    assert!(!ch.in_avail, "class {ci} chunk {j}: victim on avail");
                } else {
                    assert_eq!(
                        ch.live + ch.free_count,
                        ch.bumped,
                        "class {ci} chunk {j}: liveness drift"
                    );
                    assert_eq!(
                        ch.in_avail,
                        ch.free_count > 0,
                        "class {ci} chunk {j}: avail membership drift"
                    );
                }
                assert_eq!(
                    ch.in_avail, avail_set[j],
                    "class {ci} chunk {j}: in_avail / avail stack mismatch"
                );
                live_sum += ch.live as usize;
            }
        }
        assert_eq!(live_sum, self.live_blocks, "live_blocks drift");
    }

    /// Per-class snapshot of every committed chunk's
    /// `(slot index, live, live_raw)` counters — the fuzz oracle compares
    /// this against its ground-truth shadow recount and predicts exactly
    /// which chunks `trim` will free.
    pub fn chunk_live_counts(&self) -> Vec<Vec<(u32, u32, u32)>> {
        self.classes
            .iter()
            .map(|c| {
                c.chunks
                    .iter()
                    .enumerate()
                    .filter(|(_, ch)| ch.chunk.is_some())
                    .map(|(j, ch)| (j as u32, ch.live, ch.live_raw))
                    .collect()
            })
            .collect()
    }
}

/// What one payload move during evacuation did — the heap folds these
/// into the `evacuated_*` metrics.
#[derive(Clone, Copy, Debug)]
pub(crate) struct EvacMove {
    /// Slab block size of the moved payload.
    pub bytes: usize,
    /// The destination allocation committed a fresh chunk.
    pub new_chunk: bool,
}

/// What one [`SlabAlloc::trim`] (or [`SlabAlloc::finish_evacuation`])
/// pass returned to the system allocator; the owning heap folds it into
/// the decommit/evacuation counters and lowers the committed gauges.
#[derive(Clone, Copy, Debug)]
pub(crate) struct TrimStats {
    /// Chunks returned to the system allocator.
    pub chunks: usize,
    /// Chunk bytes returned (`chunks` × [`CHUNK_BYTES`]).
    pub bytes: usize,
    /// LOS free blocks returned to the system allocator.
    pub los_blocks: usize,
    /// LOS bytes returned (headers included).
    pub los_bytes: usize,
}

/// Header in front of every large-object-space block: the free-list
/// link, the block's total size (header + padding + payload capacity),
/// and the alignment it was allocated with (needed to rebuild the
/// `Layout` at dealloc).
#[repr(C)]
struct LosHeader {
    next: *mut LosHeader,
    total: usize,
    align: usize,
}

/// Payload offset and effective alignment for a LOS block serving
/// `align`-aligned data: the header is at the block base, the payload at
/// the next `max(align, BLOCK_ALIGN)` boundary past it. Deterministic in
/// the request layout alone, so the free path recovers the header without
/// any side table.
#[inline]
fn los_offset(align: usize) -> (usize, usize) {
    let eff = align.max(BLOCK_ALIGN);
    let off = (std::mem::size_of::<LosHeader>() + eff - 1) & !(eff - 1);
    (off, eff)
}

/// Free a LOS block outside the allocator — the teardown path of `PBox`,
/// `SlabVec`, and the memo bucket store `Drop` impls (heap teardown,
/// where no `&mut SlabAlloc` is reachable). The block leaves no trace in
/// any free list, so this is safe while the owning [`Los`] still exists.
///
/// # Safety
/// `ptr`/`layout` must be the pointer and request layout of a live block
/// obtained from [`Los::alloc`] (directly or via the allocator), and the
/// block must not be freed again.
pub(crate) unsafe fn los_teardown_free(ptr: *mut u8, layout: Layout) {
    let (off, _) = los_offset(layout.align());
    let h = ptr.sub(off) as *mut LosHeader;
    let l = Layout::from_size_align((*h).total, (*h).align).expect("los layout");
    std::alloc::dealloc(h as *mut u8, l);
}

/// The large-object space: one system allocation per block, fronted by a
/// [`LosHeader`], with a LIFO free list reused first-fit under a 2×
/// waste bound. See the module docs.
struct Los {
    /// Free-list head (most recently freed first).
    free: *mut LosHeader,
    /// Blocks on the free list.
    free_blocks: usize,
    /// Total bytes on the free list (headers included).
    free_bytes: usize,
}

impl Los {
    fn new() -> Los {
        Los {
            free: std::ptr::null_mut(),
            free_blocks: 0,
            free_bytes: 0,
        }
    }

    /// Serve `layout`: first fit from the free list if a block's total
    /// size covers the need without more than 2× waste and its alignment
    /// suffices; otherwise one fresh system allocation.
    fn alloc(&mut self, layout: Layout) -> (*mut u8, BlockLoc, AllocReceipt) {
        let (off, eff) = los_offset(layout.align());
        let need = off + layout.size();
        // SAFETY: the free list links only blocks this Los owns; headers
        // stay initialized while listed.
        unsafe {
            let mut prev: *mut *mut LosHeader = &mut self.free;
            let mut h = self.free;
            while !h.is_null() {
                let total = (*h).total;
                if total >= need && total <= 2 * need && (*h).align >= eff {
                    *prev = (*h).next;
                    (*h).next = std::ptr::null_mut();
                    self.free_blocks -= 1;
                    self.free_bytes -= total;
                    return (
                        (h as *mut u8).add(off),
                        BlockLoc::Los,
                        AllocReceipt {
                            reused: true,
                            large: true,
                            block_bytes: 0,
                            new_chunk: false,
                            los_bytes: total,
                        },
                    );
                }
                prev = &mut (*h).next;
                h = *prev;
            }
        }
        let bl = Layout::from_size_align(need, eff).expect("los layout");
        // SAFETY: nonzero size (`need` includes the header).
        let base = unsafe { std::alloc::alloc(bl) };
        if base.is_null() {
            std::alloc::handle_alloc_error(bl);
        }
        let h = base as *mut LosHeader;
        // SAFETY: `base` is a fresh block large enough for the header.
        unsafe {
            h.write(LosHeader {
                next: std::ptr::null_mut(),
                total: need,
                align: eff,
            });
        }
        (
            // SAFETY: `off < need` keeps the pointer in bounds.
            unsafe { base.add(off) },
            BlockLoc::Los,
            AllocReceipt {
                reused: false,
                large: true,
                block_bytes: 0,
                new_chunk: false,
                los_bytes: need,
            },
        )
    }

    /// Push a block back on the free list. `layout` must be the request
    /// layout the block was allocated with (the header offset is
    /// recomputed from it).
    fn free(&mut self, ptr: *mut u8, layout: Layout) -> FreeReceipt {
        let (off, _) = los_offset(layout.align());
        // SAFETY: `ptr` came from `Los::alloc` with this layout, so the
        // header sits `off` bytes below it.
        unsafe {
            let h = ptr.sub(off) as *mut LosHeader;
            let total = (*h).total;
            (*h).next = self.free;
            self.free = h;
            self.free_blocks += 1;
            self.free_bytes += total;
            FreeReceipt {
                block_bytes: 0,
                los_bytes: total,
            }
        }
    }

    /// Return every free block beyond the first `keep` (most recently
    /// freed — the warmest) to the system allocator. Returns
    /// `(blocks, bytes)` freed.
    fn trim(&mut self, keep: usize) -> (usize, usize) {
        let mut blocks = 0usize;
        let mut bytes = 0usize;
        // SAFETY: free-list walk over owned blocks, as in `alloc`.
        unsafe {
            let mut prev: *mut *mut LosHeader = &mut self.free;
            let mut h = self.free;
            let mut kept = 0usize;
            while !h.is_null() && kept < keep {
                prev = &mut (*h).next;
                h = *prev;
                kept += 1;
            }
            *prev = std::ptr::null_mut();
            while !h.is_null() {
                let next = (*h).next;
                let total = (*h).total;
                let l = Layout::from_size_align(total, (*h).align).expect("los layout");
                std::alloc::dealloc(h as *mut u8, l);
                blocks += 1;
                bytes += total;
                h = next;
            }
        }
        self.free_blocks -= blocks;
        self.free_bytes -= bytes;
        (blocks, bytes)
    }
}

impl Drop for Los {
    fn drop(&mut self) {
        self.trim(0);
    }
}

/// Accounted raw-bytes allocation context: the slab allocator paired with
/// the owning heap's metrics, so every memo/label storage operation lands
/// in the `slab_raw_*` (and `los_*`) gauges. Built on the fly from
/// `Heap`'s disjoint fields wherever a slab-resident container needs to
/// grow or free.
pub(crate) struct RawCtx<'a> {
    /// The heap's allocator.
    pub alloc: &'a mut SlabAlloc,
    /// The heap's metrics, receiving the receipts.
    pub metrics: &'a mut HeapMetrics,
}

impl RawCtx<'_> {
    /// Allocate a raw block, recording the receipt.
    pub(crate) fn alloc_raw(&mut self, layout: Layout) -> (*mut u8, BlockLoc) {
        let (p, loc, r) = self.alloc.alloc_raw(layout);
        self.metrics.note_raw_alloc(&r);
        (p, loc)
    }

    /// Free a raw block, recording the receipt.
    pub(crate) fn free_raw(&mut self, ptr: *mut u8, layout: Layout, loc: BlockLoc) {
        let r = self.alloc.free_raw(ptr, layout, loc);
        self.metrics.note_raw_free(&r);
    }
}

/// A minimal `Vec<T>` whose backing store lives in the owning heap's
/// slab allocator (raw path) — the label slot vector's storage. Growth
/// and explicit teardown go through a [`RawCtx`] so freed backing blocks
/// re-enter their size-class (or LOS) free list; a plain `Drop` (heap
/// teardown) runs the element destructors and frees exact-layout/LOS
/// memory, while a slab-resident block stays with its chunk exactly like
/// a dropped [`PBox`].
pub(crate) struct SlabVec<T> {
    ptr: *mut T,
    cap: usize,
    len: usize,
    loc: BlockLoc,
}

// SAFETY: SlabVec uniquely owns its elements and storage; it only moves
// between threads together with the Heap that owns both it and the
// SlabAlloc holding its storage (the PBox discipline).
unsafe impl<T: Send> Send for SlabVec<T> {}

impl<T> SlabVec<T> {
    /// An empty vector owning no storage.
    pub(crate) const fn new() -> SlabVec<T> {
        SlabVec {
            ptr: std::ptr::NonNull::dangling().as_ptr(),
            cap: 0,
            len: 0,
            loc: BlockLoc::Zst,
        }
    }

    #[inline]
    pub(crate) fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub(crate) fn as_slice(&self) -> &[T] {
        // SAFETY: `ptr` is dangling-aligned when cap == 0 and points at
        // `len` initialized elements otherwise.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    #[inline]
    pub(crate) fn as_mut_slice(&mut self) -> &mut [T] {
        // SAFETY: as above; `&mut self` gives exclusive access.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }

    #[inline]
    pub(crate) fn iter(&self) -> std::slice::Iter<'_, T> {
        self.as_slice().iter()
    }

    /// Append, growing through the raw slab path when full.
    pub(crate) fn push(&mut self, ctx: &mut RawCtx<'_>, value: T) {
        if self.len == self.cap {
            self.grow(ctx);
        }
        // SAFETY: `len < cap` after grow; the slot is uninitialized.
        unsafe { self.ptr.add(self.len).write(value) };
        self.len += 1;
    }

    fn grow(&mut self, ctx: &mut RawCtx<'_>) {
        let new_cap = (self.cap * 2).max(8);
        let layout = Layout::array::<T>(new_cap).expect("slab vec layout");
        let (p, loc) = ctx.alloc_raw(layout);
        let p = p as *mut T;
        if self.cap > 0 {
            // SAFETY: old and new blocks are disjoint; `len` elements are
            // initialized; the bitwise copy is a move (old storage is
            // freed without running destructors).
            unsafe { std::ptr::copy_nonoverlapping(self.ptr, p, self.len) };
            let old_layout = Layout::array::<T>(self.cap).expect("slab vec layout");
            ctx.free_raw(self.ptr as *mut u8, old_layout, self.loc);
        }
        self.ptr = p;
        self.cap = new_cap;
        self.loc = loc;
    }
}

impl<T> std::ops::Index<usize> for SlabVec<T> {
    type Output = T;
    #[inline]
    fn index(&self, i: usize) -> &T {
        &self.as_slice()[i]
    }
}

impl<T> std::ops::IndexMut<usize> for SlabVec<T> {
    #[inline]
    fn index_mut(&mut self, i: usize) -> &mut T {
        &mut self.as_mut_slice()[i]
    }
}

impl<T> Drop for SlabVec<T> {
    fn drop(&mut self) {
        // Teardown fallback (heap drop): run element destructors; free
        // exact-layout/LOS storage; a slab block stays with its chunk,
        // which the allocator frees wholesale right after (field order in
        // `Heap`).
        // SAFETY: `len` initialized elements, uniquely owned.
        unsafe { std::ptr::drop_in_place(std::ptr::slice_from_raw_parts_mut(self.ptr, self.len)) };
        if self.cap > 0 {
            let layout = Layout::array::<T>(self.cap).expect("slab vec layout");
            match self.loc {
                // SAFETY: allocated by the exact-layout path with this
                // layout.
                BlockLoc::Sys => unsafe { std::alloc::dealloc(self.ptr as *mut u8, layout) },
                // SAFETY: allocated by the LOS with this request layout.
                BlockLoc::Los => unsafe { los_teardown_free(self.ptr as *mut u8, layout) },
                _ => {}
            }
        }
    }
}
