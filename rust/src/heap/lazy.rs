//! Lazy pointers: the edge representation of the labeled multigraph H (§2.3).
//!
//! An edge `e` is a pair `(t(e), h(e))`: the target object and a single label
//! identifying the deep-copy operation the target is yet to be propagated
//! through. In the paper's C++ implementation this is a pair of smart
//! pointers; here it is a pair of generation-tagged ids, with reference
//! counts maintained explicitly by the [`Heap`](super::Heap) (which mediates
//! every mutation).

use std::marker::PhantomData;

use super::ids::{LabelId, ObjId};

/// Untyped lazy pointer: `(t(e), h(e))`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct RawLazy {
    /// Target object `t(e)`.
    pub obj: ObjId,
    /// Single label `h(e)` (§2.3 Definition 3).
    pub label: LabelId,
}

impl RawLazy {
    /// The null edge (no target, no label).
    pub const NULL: RawLazy = RawLazy {
        obj: ObjId::NULL,
        label: LabelId::NULL,
    };

    /// Whether this edge points nowhere.
    #[inline]
    pub fn is_null(self) -> bool {
        self.obj.is_null()
    }
}

impl Default for RawLazy {
    fn default() -> Self {
        RawLazy::NULL
    }
}

/// Typed lazy pointer to a payload of type `T`.
///
/// `Lazy<T>` is a pair of plain ids, so it is `Send + Sync` regardless of
/// `T` (the phantom uses a function-pointer position): shard workers move
/// per-shard handle vectors across threads, while every dereference still
/// requires `&mut Heap` of the owning shard.
///
/// `Lazy<T>` is `Copy`: it does not own a reference count by itself. The
/// ownership discipline is:
///
/// * handles returned by [`Heap::alloc`](super::Heap::alloc) and
///   [`Heap::deep_copy`](super::Heap::deep_copy) are *owning* (shared count
///   +1) and must be released with [`Heap::release`](super::Heap::release)
///   (or stored into an object field, which transfers the count bookkeeping
///   to the edge-diff machinery in `mutate`);
/// * pointers read out of object fields are *borrowed* and must not outlive
///   the owning edge. Generation tags turn violations into panics.
///
/// ```
/// use lazycow::heap::{CopyMode, Heap, Lazy};
/// use lazycow::lazy_fields;
///
/// #[derive(Clone)]
/// struct Cell { value: i64, next: Lazy<Cell> }
/// lazy_fields!(Cell: next);
///
/// // A null pointer is inert until a heap gives it a target.
/// let p: Lazy<Cell> = Lazy::NULL;
/// assert!(p.is_null());
///
/// let mut heap = Heap::new(CopyMode::Lazy);
/// let a = heap.alloc(Cell { value: 7, next: Lazy::NULL });
/// // `deep_copy` mints a new label: same object, O(1), copy-on-write.
/// let mut b = heap.deep_copy(&a);
/// assert_eq!(b.obj(), a.obj(), "no bytes copied yet");
/// assert_ne!(b.label(), a.label(), "distinct lineages");
/// heap.mutate_root(&mut b, |c| c.value = 8);
/// assert_ne!(b.obj(), a.obj(), "write forced the copy");
/// heap.release(a);
/// heap.release(b);
/// ```
pub struct Lazy<T> {
    pub(crate) raw: RawLazy,
    pub(crate) _ph: PhantomData<fn() -> T>,
}

impl<T> Lazy<T> {
    /// The null pointer.
    pub const NULL: Lazy<T> = Lazy {
        raw: RawLazy::NULL,
        _ph: PhantomData,
    };

    /// Wrap an untyped edge (caller asserts the payload type).
    #[inline]
    pub fn from_raw(raw: RawLazy) -> Self {
        Lazy {
            raw,
            _ph: PhantomData,
        }
    }

    /// The untyped `(object, label)` pair.
    #[inline]
    pub fn raw(&self) -> RawLazy {
        self.raw
    }

    /// Whether this pointer is null.
    #[inline]
    pub fn is_null(&self) -> bool {
        self.raw.is_null()
    }

    /// Target object id `t(e)`.
    #[inline]
    pub fn obj(&self) -> ObjId {
        self.raw.obj
    }

    /// Label id `h(e)`.
    #[inline]
    pub fn label(&self) -> LabelId {
        self.raw.label
    }
}

// Manual impls: `derive` would put bounds on `T`.
impl<T> Clone for Lazy<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for Lazy<T> {}
impl<T> PartialEq for Lazy<T> {
    fn eq(&self, other: &Self) -> bool {
        self.raw == other.raw
    }
}
impl<T> Eq for Lazy<T> {}
impl<T> std::fmt::Debug for Lazy<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Lazy({:?}, {:?})", self.raw.obj, self.raw.label)
    }
}
impl<T> Default for Lazy<T> {
    fn default() -> Self {
        Lazy::NULL
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Foo;

    #[test]
    fn null_typed_pointer() {
        let p: Lazy<Foo> = Lazy::NULL;
        assert!(p.is_null());
        assert!(p.raw().is_null());
        let q = p; // Copy
        assert_eq!(p, q);
    }

    #[test]
    fn lazy_pointer_is_two_ids() {
        // The paper reports 8 extra bytes per pointer for the label.
        assert_eq!(std::mem::size_of::<RawLazy>(), 16);
        assert_eq!(std::mem::size_of::<Lazy<Foo>>(), 16);
    }
}
