//! Memo tables: the per-label partial functions `m_l : V -> V` (§2.2 Def. 2,
//! flattened per §2.4 Def. 5).
//!
//! A memo records which frozen object was copied to which fresh object under
//! a given label, so that later `Pull`s through the same label are redirected
//! to the copy. The paper implements these as hash tables and motivates the
//! by-label partition with cache locality (§3): successive queries share the
//! label with high probability, so a per-label open-addressing table keeps
//! the probed region hot.
//!
//! This implementation is a linear-probing open-addressing table keyed by
//! slot index (the memo reference count guarantees a keyed slot is not
//! recycled, and generation tags catch violations), sized to a power of two,
//! with Fibonacci hashing. There are no tombstones: deletion happens only
//! wholesale during sweeps (rebuild) or when the label dies (drop).
//!
//! **Storage.** The bucket arrays live in the owning heap's slab allocator
//! (the raw path, [`RawCtx`]): one [`SlabBuckets`] block holds the three
//! parallel arrays (keys, key generations, values) contiguously, so a
//! rehash frees a single size-class block that the next same-size rehash —
//! of *any* label in the heap — reuses from the free list. Buckets above
//! the largest size class take the allocator's exact-layout fallback.
//! Every operation that can allocate or free (insert, sweep, drain) takes
//! a [`RawCtx`]; read paths (`get`, `iter`) need none.

use std::alloc::Layout;

use super::alloc::{BlockLoc, RawCtx};
use super::ids::ObjId;

const EMPTY: u32 = u32::MAX;

/// Bytes per bucket: key (u32) + key generation (u32) + value (`ObjId`).
const BUCKET_BYTES: usize = 4 + 4 + std::mem::size_of::<ObjId>();

/// One slab block holding a memo table's three parallel bucket arrays:
/// `cap` keys (u32), then `cap` key generations (u32), then `cap` values
/// (`ObjId`). Explicit teardown goes through [`SlabBuckets::free`] so the
/// block re-enters its size-class free list; a plain `Drop` (heap
/// teardown) frees exact-layout memory and leaves slab blocks to their
/// chunk, like a dropped `PBox`.
struct SlabBuckets {
    ptr: *mut u8,
    cap: usize,
    loc: BlockLoc,
}

// SAFETY: SlabBuckets uniquely owns its storage and only moves between
// threads together with the Heap owning both it and the allocator.
unsafe impl Send for SlabBuckets {}

impl SlabBuckets {
    const fn empty() -> SlabBuckets {
        SlabBuckets {
            ptr: std::ptr::NonNull::dangling().as_ptr(),
            cap: 0,
            loc: BlockLoc::Zst,
        }
    }

    fn layout(cap: usize) -> Layout {
        Layout::from_size_align(cap * BUCKET_BYTES, 8).expect("memo bucket layout")
    }

    /// Allocate `cap` buckets (power of two), all marked empty.
    fn alloc(ctx: &mut RawCtx<'_>, cap: usize) -> SlabBuckets {
        debug_assert!(cap.is_power_of_two());
        let (ptr, loc) = ctx.alloc_raw(Self::layout(cap));
        // All-ones everywhere: keys become EMPTY (u32::MAX); generations
        // and values of empty buckets are never read before being
        // written.
        // SAFETY: the block spans `cap * BUCKET_BYTES` writable bytes.
        unsafe { std::ptr::write_bytes(ptr, 0xFF, cap * BUCKET_BYTES) };
        SlabBuckets { ptr, cap, loc }
    }

    /// Return the block to the allocator (the accounted path).
    fn free(self, ctx: &mut RawCtx<'_>) {
        if self.cap > 0 {
            ctx.free_raw(self.ptr, Self::layout(self.cap), self.loc);
        }
        std::mem::forget(self);
    }

    #[inline]
    fn keys(&self) -> &[u32] {
        if self.cap == 0 {
            return &[];
        }
        // SAFETY: `cap` initialized u32s at the block base.
        unsafe { std::slice::from_raw_parts(self.ptr as *const u32, self.cap) }
    }

    #[inline]
    fn gens(&self) -> &[u32] {
        if self.cap == 0 {
            return &[];
        }
        // SAFETY: `cap` u32s at offset 4·cap.
        unsafe { std::slice::from_raw_parts((self.ptr as *const u32).add(self.cap), self.cap) }
    }

    #[inline]
    fn vals(&self) -> &[ObjId] {
        if self.cap == 0 {
            return &[];
        }
        // SAFETY: `cap` ObjIds at offset 8·cap (8-aligned base keeps the
        // ObjId alignment).
        unsafe { std::slice::from_raw_parts(self.ptr.add(self.cap * 8) as *const ObjId, self.cap) }
    }

    #[inline]
    fn set(&mut self, i: usize, key: u32, gen: u32, val: ObjId) {
        debug_assert!(i < self.cap);
        // SAFETY: `i < cap`; the three arrays are disjoint regions of the
        // uniquely-owned block.
        unsafe {
            *(self.ptr as *mut u32).add(i) = key;
            *(self.ptr as *mut u32).add(self.cap + i) = gen;
            *(self.ptr.add(self.cap * 8) as *mut ObjId).add(i) = val;
        }
    }

    #[inline]
    fn set_val(&mut self, i: usize, gen: u32, val: ObjId) {
        debug_assert!(i < self.cap);
        // SAFETY: as in `set`.
        unsafe {
            *(self.ptr as *mut u32).add(self.cap + i) = gen;
            *(self.ptr.add(self.cap * 8) as *mut ObjId).add(i) = val;
        }
    }
}

impl Drop for SlabBuckets {
    fn drop(&mut self) {
        // Teardown fallback: exact-layout and LOS storage go back to the
        // system allocator; slab blocks stay with their chunk (freed when
        // the owning SlabAlloc drops).
        if self.cap > 0 {
            match self.loc {
                // SAFETY: allocated by the exact-layout path with this
                // layout.
                BlockLoc::Sys => unsafe { std::alloc::dealloc(self.ptr, Self::layout(self.cap)) },
                // SAFETY: allocated by the LOS with this request layout.
                BlockLoc::Los => unsafe {
                    super::alloc::los_teardown_free(self.ptr, Self::layout(self.cap))
                },
                _ => {}
            }
        }
    }
}

/// Open-addressing hash map `ObjId -> ObjId` specialised for memo use,
/// with slab-resident bucket storage (see the module docs). Mutating
/// operations take a crate-internal `RawCtx` so bucket blocks are
/// allocated and freed through the owning heap's slab allocator.
#[derive(Default)]
pub struct MemoTable {
    buckets: SlabBuckets,
    len: usize,
    mask: usize,
}

impl Default for SlabBuckets {
    fn default() -> Self {
        SlabBuckets::empty()
    }
}

#[inline]
fn hash(key: u32, mask: usize) -> usize {
    // Fibonacci hashing: multiply by 2^32/phi, take high bits via mask after
    // mixing. Good dispersion for sequential slot indices.
    let h = key.wrapping_mul(0x9E37_79B9);
    (h >> 16 ^ h) as usize & mask
}

impl MemoTable {
    /// An empty table owning no bucket storage.
    pub fn new() -> Self {
        MemoTable {
            buckets: SlabBuckets::empty(),
            len: 0,
            mask: 0,
        }
    }

    /// Number of entries.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// `true` when the table holds no entries.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Capacity in buckets (0 if unallocated).
    pub fn capacity(&self) -> usize {
        self.buckets.cap
    }

    /// Approximate heap bytes used by this table.
    pub fn size_bytes(&self) -> usize {
        self.buckets.cap * BUCKET_BYTES
    }

    /// Look up `m_l(v)`.
    #[inline]
    pub fn get(&self, key: ObjId) -> Option<ObjId> {
        if self.len == 0 {
            return None;
        }
        let keys = self.buckets.keys();
        let mut i = hash(key.key(), self.mask);
        loop {
            let k = keys[i];
            if k == EMPTY {
                return None;
            }
            if k == key.key() {
                debug_assert_eq!(
                    self.buckets.gens()[i],
                    key.gen,
                    "memo key generation mismatch: slot recycled while keyed"
                );
                return Some(self.buckets.vals()[i]);
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Insert `m_l(key) <- val`, replacing any existing entry.
    /// Returns the previous value if the key was present.
    pub(crate) fn insert(&mut self, ctx: &mut RawCtx<'_>, key: ObjId, val: ObjId) -> Option<ObjId> {
        debug_assert!(!key.is_null() && !val.is_null());
        if self.buckets.cap == 0 || self.len * 4 >= self.buckets.cap * 3 {
            self.grow(ctx);
        }
        self.insert_no_grow(key, val)
    }

    /// The probe loop, on buckets guaranteed to have a free slot.
    fn insert_no_grow(&mut self, key: ObjId, val: ObjId) -> Option<ObjId> {
        let mut i = hash(key.key(), self.mask);
        loop {
            let k = self.buckets.keys()[i];
            if k == EMPTY {
                self.buckets.set(i, key.key(), key.gen, val);
                self.len += 1;
                return None;
            }
            if k == key.key() {
                let old = self.buckets.vals()[i];
                self.buckets.set_val(i, key.gen, val);
                return Some(old);
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Double the bucket block (rehash): the freed old block re-enters
    /// its size-class free list for the next rehash anywhere in the heap.
    fn grow(&mut self, ctx: &mut RawCtx<'_>) {
        let new_cap = (self.buckets.cap * 2).max(8);
        let old = std::mem::replace(&mut self.buckets, SlabBuckets::alloc(ctx, new_cap));
        self.mask = new_cap - 1;
        self.len = 0;
        for (j, k) in old.keys().iter().enumerate() {
            if *k != EMPTY {
                self.insert_no_grow(ObjId::new(*k, old.gens()[j]), old.vals()[j]);
            }
        }
        old.free(ctx);
    }

    /// Iterate over `(key, value)` entries.
    pub fn iter(&self) -> impl Iterator<Item = (ObjId, ObjId)> + '_ {
        self.buckets
            .keys()
            .iter()
            .enumerate()
            .filter(|(_, k)| **k != EMPTY)
            .map(move |(i, k)| {
                (
                    ObjId::new(*k, self.buckets.gens()[i]),
                    self.buckets.vals()[i],
                )
            })
    }

    /// Rebuild the table keeping only entries for which `keep(key)` holds.
    /// This is the paper's sweep: entries whose key object has zero shared
    /// and weak counts can never be pulled again and are dropped. Returns
    /// the removed `(key, value)` pairs so the caller can adjust reference
    /// counts. The old bucket block is freed through `ctx`; a fresh
    /// (smaller, if many entries died) block is allocated on demand.
    pub(crate) fn sweep(
        &mut self,
        ctx: &mut RawCtx<'_>,
        mut keep: impl FnMut(ObjId) -> bool,
    ) -> Vec<(ObjId, ObjId)> {
        let mut removed = Vec::new();
        if self.len == 0 {
            return removed;
        }
        let old = std::mem::replace(&mut self.buckets, SlabBuckets::empty());
        self.len = 0;
        self.mask = 0;
        for (j, k) in old.keys().iter().enumerate() {
            if *k != EMPTY {
                let key = ObjId::new(*k, old.gens()[j]);
                if keep(key) {
                    self.insert(ctx, key, old.vals()[j]);
                } else {
                    removed.push((key, old.vals()[j]));
                }
            }
        }
        old.free(ctx);
        removed
    }

    /// Drain all entries, leaving the table empty and its bucket block
    /// back on the allocator's free list.
    pub(crate) fn drain_all(&mut self, ctx: &mut RawCtx<'_>) -> Vec<(ObjId, ObjId)> {
        let out: Vec<_> = self.iter().collect();
        let old = std::mem::replace(&mut self.buckets, SlabBuckets::empty());
        old.free(ctx);
        self.len = 0;
        self.mask = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::super::alloc::{AllocatorKind, SlabAlloc};
    use super::super::metrics::HeapMetrics;
    use super::*;

    fn o(i: u32) -> ObjId {
        ObjId::new(i, 0)
    }

    /// Allocator + metrics backing one test's tables.
    struct Arena {
        alloc: SlabAlloc,
        metrics: HeapMetrics,
    }

    impl Arena {
        fn new() -> Arena {
            Arena {
                alloc: SlabAlloc::new(AllocatorKind::Slab),
                metrics: HeapMetrics::default(),
            }
        }

        fn ctx(&mut self) -> RawCtx<'_> {
            RawCtx {
                alloc: &mut self.alloc,
                metrics: &mut self.metrics,
            }
        }
    }

    #[test]
    fn empty_lookup() {
        let t = MemoTable::new();
        assert_eq!(t.get(o(3)), None);
        assert!(t.is_empty());
        assert_eq!(t.size_bytes(), 0);
    }

    #[test]
    fn insert_get_replace() {
        let mut a = Arena::new();
        let mut t = MemoTable::new();
        assert_eq!(t.insert(&mut a.ctx(), o(1), o(10)), None);
        assert_eq!(t.insert(&mut a.ctx(), o(2), o(20)), None);
        assert_eq!(t.get(o(1)), Some(o(10)));
        assert_eq!(t.get(o(2)), Some(o(20)));
        assert_eq!(t.get(o(3)), None);
        assert_eq!(t.insert(&mut a.ctx(), o(1), o(11)), Some(o(10)));
        assert_eq!(t.get(o(1)), Some(o(11)));
        assert_eq!(t.len(), 2);
        t.drain_all(&mut a.ctx());
    }

    #[test]
    fn many_inserts_grow() {
        let mut a = Arena::new();
        let mut t = MemoTable::new();
        for i in 0..1000 {
            t.insert(&mut a.ctx(), o(i), o(i + 100_000));
        }
        assert_eq!(t.len(), 1000);
        for i in 0..1000 {
            assert_eq!(t.get(o(i)), Some(o(i + 100_000)), "key {i}");
        }
        assert_eq!(t.get(o(5000)), None);
        // Growth went through the raw slab path and freed every
        // outgrown block.
        assert!(a.metrics.slab_raw_allocs > 1);
        assert_eq!(a.metrics.slab_raw_frees, a.metrics.slab_raw_allocs - 1);
        t.drain_all(&mut a.ctx());
        assert_eq!(a.metrics.slab_raw_frees, a.metrics.slab_raw_allocs);
    }

    #[test]
    fn sweep_removes_dead_keys() {
        let mut a = Arena::new();
        let mut t = MemoTable::new();
        for i in 0..100 {
            t.insert(&mut a.ctx(), o(i), o(i + 100));
        }
        let removed = t.sweep(&mut a.ctx(), |k| k.idx % 2 == 0);
        assert_eq!(removed.len(), 50);
        assert_eq!(t.len(), 50);
        assert_eq!(t.get(o(2)), Some(o(102)));
        assert_eq!(t.get(o(3)), None);
        t.drain_all(&mut a.ctx());
    }

    #[test]
    fn rebuilt_table_matches_source() {
        // (The old `Clone` contract, now via explicit rebuild: memo
        // cloning in `deep_copy` iterates + reinserts through a ctx.)
        let mut a = Arena::new();
        let mut t = MemoTable::new();
        for i in 0..37 {
            t.insert(&mut a.ctx(), o(i * 3), o(i));
        }
        let mut u = MemoTable::new();
        for (k, v) in t.iter().collect::<Vec<_>>() {
            u.insert(&mut a.ctx(), k, v);
        }
        for i in 0..37 {
            assert_eq!(u.get(o(i * 3)), Some(o(i)));
        }
        t.drain_all(&mut a.ctx());
        u.drain_all(&mut a.ctx());
    }

    #[test]
    fn drain_all_empties_and_frees() {
        let mut a = Arena::new();
        let mut t = MemoTable::new();
        t.insert(&mut a.ctx(), o(1), o(2));
        t.insert(&mut a.ctx(), o(3), o(4));
        let all = t.drain_all(&mut a.ctx());
        assert_eq!(all.len(), 2);
        assert!(t.is_empty());
        assert_eq!(t.get(o(1)), None);
        assert_eq!(t.capacity(), 0, "drain returns the bucket block");
        assert_eq!(a.metrics.slab_raw_bytes, 0);
        assert_eq!(a.alloc.live_blocks(), 0);
    }

    #[test]
    fn colliding_keys_probe() {
        // Keys engineered to collide under the initial mask are still found.
        let mut a = Arena::new();
        let mut t = MemoTable::new();
        for i in 0..8u32 {
            t.insert(&mut a.ctx(), o(i * 8), o(i));
        }
        for i in 0..8u32 {
            assert_eq!(t.get(o(i * 8)), Some(o(i)));
        }
        t.drain_all(&mut a.ctx());
    }
}
