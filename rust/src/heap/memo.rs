//! Memo tables: the per-label partial functions `m_l : V -> V` (§2.2 Def. 2,
//! flattened per §2.4 Def. 5).
//!
//! A memo records which frozen object was copied to which fresh object under
//! a given label, so that later `Pull`s through the same label are redirected
//! to the copy. The paper implements these as hash tables and motivates the
//! by-label partition with cache locality (§3): successive queries share the
//! label with high probability, so a per-label open-addressing table keeps
//! the probed region hot.
//!
//! This implementation is a linear-probing open-addressing table keyed by
//! slot index (the memo reference count guarantees a keyed slot is not
//! recycled, and generation tags catch violations), sized to a power of two,
//! with Fibonacci hashing. There are no tombstones: deletion happens only
//! wholesale during sweeps (rebuild) or when the label dies (drop).

use super::ids::ObjId;

const EMPTY: u32 = u32::MAX;

/// Open-addressing hash map `ObjId -> ObjId` specialised for memo use.
#[derive(Clone, Default)]
pub struct MemoTable {
    /// Parallel arrays: `keys[i] == EMPTY` marks an empty bucket.
    keys: Vec<u32>,
    key_gens: Vec<u32>,
    vals: Vec<ObjId>,
    len: usize,
    mask: usize,
}

#[inline]
fn hash(key: u32, mask: usize) -> usize {
    // Fibonacci hashing: multiply by 2^32/phi, take high bits via mask after
    // mixing. Good dispersion for sequential slot indices.
    let h = key.wrapping_mul(0x9E37_79B9);
    (h >> 16 ^ h) as usize & mask
}

impl MemoTable {
    pub fn new() -> Self {
        MemoTable {
            keys: Vec::new(),
            key_gens: Vec::new(),
            vals: Vec::new(),
            len: 0,
            mask: 0,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Capacity in buckets (0 if unallocated).
    pub fn capacity(&self) -> usize {
        self.keys.len()
    }

    /// Approximate heap bytes used by this table.
    pub fn size_bytes(&self) -> usize {
        self.keys.len() * (4 + 4 + std::mem::size_of::<ObjId>())
    }

    /// Look up `m_l(v)`.
    #[inline]
    pub fn get(&self, key: ObjId) -> Option<ObjId> {
        if self.len == 0 {
            return None;
        }
        let mut i = hash(key.key(), self.mask);
        loop {
            let k = self.keys[i];
            if k == EMPTY {
                return None;
            }
            if k == key.key() {
                debug_assert_eq!(
                    self.key_gens[i],
                    key.gen,
                    "memo key generation mismatch: slot recycled while keyed"
                );
                return Some(self.vals[i]);
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Insert `m_l(key) <- val`, replacing any existing entry.
    /// Returns the previous value if the key was present.
    pub fn insert(&mut self, key: ObjId, val: ObjId) -> Option<ObjId> {
        debug_assert!(!key.is_null() && !val.is_null());
        if self.keys.is_empty() || self.len * 4 >= self.keys.len() * 3 {
            self.grow();
        }
        let mut i = hash(key.key(), self.mask);
        loop {
            let k = self.keys[i];
            if k == EMPTY {
                self.keys[i] = key.key();
                self.key_gens[i] = key.gen;
                self.vals[i] = val;
                self.len += 1;
                return None;
            }
            if k == key.key() {
                let old = self.vals[i];
                self.vals[i] = val;
                self.key_gens[i] = key.gen;
                return Some(old);
            }
            i = (i + 1) & self.mask;
        }
    }

    fn grow(&mut self) {
        let new_cap = (self.keys.len() * 2).max(8);
        let old_keys = std::mem::replace(&mut self.keys, vec![EMPTY; new_cap]);
        let old_gens = std::mem::replace(&mut self.key_gens, vec![0; new_cap]);
        let old_vals = std::mem::replace(&mut self.vals, vec![ObjId::NULL; new_cap]);
        self.mask = new_cap - 1;
        self.len = 0;
        for (j, k) in old_keys.iter().enumerate() {
            if *k != EMPTY {
                self.insert(ObjId::new(*k, old_gens[j]), old_vals[j]);
            }
        }
    }

    /// Iterate over `(key, value)` entries.
    pub fn iter(&self) -> impl Iterator<Item = (ObjId, ObjId)> + '_ {
        self.keys
            .iter()
            .enumerate()
            .filter(|(_, k)| **k != EMPTY)
            .map(move |(i, k)| (ObjId::new(*k, self.key_gens[i]), self.vals[i]))
    }

    /// Rebuild the table keeping only entries for which `keep(key)` holds.
    /// This is the paper's sweep: entries whose key object has zero shared
    /// and weak counts can never be pulled again and are dropped. Returns
    /// the removed `(key, value)` pairs so the caller can adjust reference
    /// counts.
    pub fn sweep(&mut self, mut keep: impl FnMut(ObjId) -> bool) -> Vec<(ObjId, ObjId)> {
        let mut removed = Vec::new();
        if self.len == 0 {
            return removed;
        }
        let old_keys = std::mem::take(&mut self.keys);
        let old_gens = std::mem::take(&mut self.key_gens);
        let old_vals = std::mem::take(&mut self.vals);
        self.len = 0;
        self.mask = 0;
        for (j, k) in old_keys.iter().enumerate() {
            if *k != EMPTY {
                let key = ObjId::new(*k, old_gens[j]);
                if keep(key) {
                    self.insert(key, old_vals[j]);
                } else {
                    removed.push((key, old_vals[j]));
                }
            }
        }
        removed
    }

    /// Drain all entries, leaving the table empty.
    pub fn drain_all(&mut self) -> Vec<(ObjId, ObjId)> {
        let out: Vec<_> = self.iter().collect();
        self.keys.clear();
        self.key_gens.clear();
        self.vals.clear();
        self.len = 0;
        self.mask = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn o(i: u32) -> ObjId {
        ObjId::new(i, 0)
    }

    #[test]
    fn empty_lookup() {
        let t = MemoTable::new();
        assert_eq!(t.get(o(3)), None);
        assert!(t.is_empty());
    }

    #[test]
    fn insert_get_replace() {
        let mut t = MemoTable::new();
        assert_eq!(t.insert(o(1), o(10)), None);
        assert_eq!(t.insert(o(2), o(20)), None);
        assert_eq!(t.get(o(1)), Some(o(10)));
        assert_eq!(t.get(o(2)), Some(o(20)));
        assert_eq!(t.get(o(3)), None);
        assert_eq!(t.insert(o(1), o(11)), Some(o(10)));
        assert_eq!(t.get(o(1)), Some(o(11)));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn many_inserts_grow() {
        let mut t = MemoTable::new();
        for i in 0..1000 {
            t.insert(o(i), o(i + 100_000));
        }
        assert_eq!(t.len(), 1000);
        for i in 0..1000 {
            assert_eq!(t.get(o(i)), Some(o(i + 100_000)), "key {i}");
        }
        assert_eq!(t.get(o(5000)), None);
    }

    #[test]
    fn sweep_removes_dead_keys() {
        let mut t = MemoTable::new();
        for i in 0..100 {
            t.insert(o(i), o(i + 100));
        }
        let removed = t.sweep(|k| k.idx % 2 == 0);
        assert_eq!(removed.len(), 50);
        assert_eq!(t.len(), 50);
        assert_eq!(t.get(o(2)), Some(o(102)));
        assert_eq!(t.get(o(3)), None);
    }

    #[test]
    fn clone_preserves_entries() {
        let mut t = MemoTable::new();
        for i in 0..37 {
            t.insert(o(i * 3), o(i));
        }
        let u = t.clone();
        for i in 0..37 {
            assert_eq!(u.get(o(i * 3)), Some(o(i)));
        }
    }

    #[test]
    fn drain_all_empties() {
        let mut t = MemoTable::new();
        t.insert(o(1), o(2));
        t.insert(o(3), o(4));
        let all = t.drain_all();
        assert_eq!(all.len(), 2);
        assert!(t.is_empty());
        assert_eq!(t.get(o(1)), None);
    }

    #[test]
    fn colliding_keys_probe() {
        // Keys engineered to collide under the initial mask are still found.
        let mut t = MemoTable::new();
        for i in 0..8u32 {
            t.insert(o(i * 8), o(i));
        }
        for i in 0..8u32 {
            assert_eq!(t.get(o(i * 8)), Some(o(i)));
        }
    }
}
