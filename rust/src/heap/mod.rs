//! The lazy object copy-on-write platform — the paper's core contribution.
//!
//! Objects live in a slab [`Heap`]. Pointers between them are *lazy
//! pointers* ([`Lazy`]): a pair of (object id, label id), the edge
//! representation of the labeled multigraph H (§2.3). A
//! [`deep_copy`](Heap::deep_copy) is O(1)+memo-clone: it freezes the
//! reachable subgraph (Algorithm 7) and mints a new label whose memo is a
//! (swept) clone of the source label's memo (Algorithm 3 + Definition 5).
//! Objects are copied only when first written through a given label
//! (Algorithms 4–6), with cross references — edges outside the
//! tree-structured copy pattern — handled by eager `Finish` (Algorithm 8).
//!
//! Three run-time configurations mirror the paper's §4 compile-time ones:
//! [`CopyMode::Eager`] (deep copies materialize immediately),
//! [`CopyMode::Lazy`], and [`CopyMode::LazySro`] (lazy + the
//! single-reference optimization of Remark 1).
//!
//! Threading: heap operations take `&mut Heap`, so a single heap is
//! serialized by construction — Rust ownership replaces the paper's
//! "judicious atomics". Scaling across cores comes from *sharding* instead
//! of locking: a [`ShardedHeap`] holds K independent `Heap`s, particles are
//! partitioned contiguously across shards, and per-generation propagation
//! runs shard-parallel with each worker holding `&mut` to exactly one
//! shard (no locks, no atomics on the allocate/copy/mutate hot path).
//! When resampling assigns an offspring to a different shard than its
//! ancestor, [`Heap::extract_into`] performs a cross-shard lineage
//! transplant: it walks the frozen reachable subgraph (the Algorithm 7
//! machinery) and materializes the pulled view in the destination shard,
//! where it participates in that shard's lazy machinery from then on.
//! See DESIGN.md for the full threading model.

pub mod alloc;
mod ids;
mod lazy;
mod memo;
mod metrics;
mod payload;
mod shard;
mod slot;

pub use self::alloc::{
    AllocatorKind, PBox, SlabAlloc, CHUNK_BYTES, DEFAULT_DECOMMIT_WATERMARK,
};
pub use ids::{LabelId, ObjId};
pub use lazy::{Lazy, RawLazy};
pub use memo::MemoTable;
pub use metrics::{HeapMetrics, MetricsScope};
pub use payload::{EdgeSlot, Payload};
pub use shard::{
    aggregate_metrics, evacuate_shards, sample_global_peak, shard_of, shard_ranges, trim_shards,
    ShardedHeap,
};

use self::alloc::{AllocReceipt, FreeReceipt, RawCtx, SlabVec};
use slot::{Slot, OBJ_OVERHEAD};

/// Copy strategy, corresponding to the paper's three evaluation
/// configurations (§4).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CopyMode {
    /// `deep_copy` performs an immediate recursive copy (the baseline).
    Eager,
    /// Lazy copy-on-write (labels + memos), without Remark 1.
    Lazy,
    /// Lazy copy-on-write with the single-reference optimization.
    LazySro,
}

impl CopyMode {
    /// Whether deep copies defer object copying (either lazy mode).
    pub fn is_lazy(self) -> bool {
        !matches!(self, CopyMode::Eager)
    }

    /// Parse a mode name as accepted by `--mode`.
    pub fn parse(s: &str) -> Option<CopyMode> {
        match s {
            "eager" => Some(CopyMode::Eager),
            "lazy" => Some(CopyMode::Lazy),
            "lazy-sro" | "lazy_sro" | "sro" => Some(CopyMode::LazySro),
            _ => None,
        }
    }

    /// Canonical name (CLI/bench labels).
    pub fn name(self) -> &'static str {
        match self {
            CopyMode::Eager => "eager",
            CopyMode::Lazy => "lazy",
            CopyMode::LazySro => "lazy-sro",
        }
    }

    /// Every mode, in the paper's presentation order (test sweeps).
    pub const ALL: [CopyMode; 3] = [CopyMode::Eager, CopyMode::Lazy, CopyMode::LazySro];
}

/// Per-label record: the memo `m_l` plus the label's shared count and
/// generation tag. Lives in the slab-resident label vector.
struct LabelSlot {
    memo: MemoTable,
    shared: u32,
    gen: u32,
    alive: bool,
}

/// The object heap: slab of objects, slab of labels, context stack, and
/// reference-count machinery.
pub struct Heap {
    slots: Vec<Slot>,
    free_slots: Vec<u32>,
    /// Label records. Slab-resident ([`SlabVec`]): growth allocates
    /// through the raw path of `alloc`, so label-population churn reuses
    /// freed same-class blocks. Declared before `alloc` so teardown
    /// drops the records (and their memo bucket blocks) while the chunks
    /// they point into are still allocated.
    labels: SlabVec<LabelSlot>,
    free_labels: Vec<u32>,
    mode: CopyMode,
    context: Vec<LabelId>,
    /// Live instrumentation (see [`HeapMetrics`]); maintained eagerly by
    /// every operation.
    pub metrics: HeapMetrics,
    // Deferred reference-count release queues (drained iteratively to avoid
    // unbounded recursion on long death cascades).
    obj_dec: Vec<ObjId>,
    label_dec: Vec<LabelId>,
    draining: bool,
    // Reusable edge-diff scratch buffers (mutate hot path).
    scratch_before: Vec<RawLazy>,
    scratch_after: Vec<RawLazy>,
    /// Live stored cross-reference edges. When zero (the tree-pattern fast
    /// path — all five evaluation models), `deep_copy` skips the
    /// cross-reference scan entirely.
    live_cross_edges: usize,
    /// Payload storage: every payload block is handed out and reclaimed
    /// here. Declared *after* `slots` on purpose — fields drop in
    /// declaration order, so at teardown the slots' [`PBox`] handles run
    /// their payload destructors while the slab chunks they point into
    /// are still allocated.
    alloc: SlabAlloc,
}

/// The pinned root label (root context, §2.4 Def. 4).
pub const ROOT_LABEL: LabelId = LabelId { idx: 0, gen: 0 };

impl Heap {
    /// A heap on the default payload allocator
    /// ([`AllocatorKind::Slab`]).
    pub fn new(mode: CopyMode) -> Self {
        Heap::with_allocator(mode, AllocatorKind::Slab)
    }

    /// A heap whose payload storage uses the given backend
    /// (`--allocator system|slab`).
    pub fn with_allocator(mode: CopyMode, kind: AllocatorKind) -> Self {
        Heap::build(mode, SlabAlloc::new(kind))
    }

    fn build(mode: CopyMode, alloc: SlabAlloc) -> Self {
        let mut h = Heap {
            slots: Vec::new(),
            free_slots: Vec::new(),
            labels: SlabVec::new(),
            free_labels: Vec::new(),
            mode,
            context: vec![ROOT_LABEL],
            metrics: HeapMetrics::default(),
            obj_dec: Vec::new(),
            label_dec: Vec::new(),
            draining: false,
            scratch_before: Vec::new(),
            scratch_after: Vec::new(),
            live_cross_edges: 0,
            alloc,
        };
        // Pinned root label (never collected). The push routes the label
        // vector's first backing block through the slab raw path.
        let mut ctx = RawCtx {
            alloc: &mut h.alloc,
            metrics: &mut h.metrics,
        };
        h.labels.push(
            &mut ctx,
            LabelSlot {
                memo: MemoTable::new(),
                shared: 1,
                gen: 0,
                alive: true,
            },
        );
        h.metrics.live_labels = 1;
        h
    }

    /// This heap's copy mode.
    #[inline]
    pub fn mode(&self) -> CopyMode {
        self.mode
    }

    /// Payload-storage backend this heap was built with.
    #[inline]
    pub fn allocator_kind(&self) -> AllocatorKind {
        self.alloc.kind()
    }

    /// Whether the payload allocator is the scratch-heap bump-only
    /// variant (no free lists; bulk reset/drop reclaim).
    #[inline]
    pub fn allocator_is_bump_only(&self) -> bool {
        self.alloc.is_bump_only()
    }

    /// Rewind a *drained* scratch heap's payload storage so its chunks
    /// can be reused without touching the system allocator. Requires
    /// zero live objects and a bump-only (scratch) allocator: in a
    /// reuse-mode heap the label vector and memo buckets live in the
    /// slabs, so a bump rewind would hand their storage out again.
    /// (Scratch raw allocations take the exact-layout path precisely so
    /// this reset stays sound.)
    pub fn reset_storage(&mut self) {
        assert!(
            self.alloc.is_bump_only(),
            "reset_storage is the scratch-heap bulk reclaim"
        );
        assert_eq!(
            self.metrics.live_objects, 0,
            "reset_storage on a heap with live objects"
        );
        self.alloc.reset();
    }

    /// Prepare a drained scratch heap for its next donation: rewind the
    /// payload storage (keeping the chunks — a pooled scratch's next use
    /// costs no system-allocator traffic) and zero the metrics history,
    /// so the next use's `peak_bytes` and op counters describe that use
    /// alone. Call *after* [`Heap::absorb_counters`] (recycling discards
    /// the counters) and after the scratch's own peak has been folded
    /// into the scratch-residency gauge. Slot and label slabs keep their
    /// capacity, which the next use reuses too.
    pub fn recycle_scratch(&mut self) {
        debug_assert_eq!(
            self.metrics.live_labels, 1,
            "recycle of a scratch heap with live non-root labels"
        );
        self.reset_storage();
        self.metrics = HeapMetrics {
            live_labels: 1,
            // Retained storage carries over; everything else starts over.
            // (The LOS gauges too: scratch metadata — the label vector's
            // backing store and any retained free blocks — lives in the
            // LOS precisely so it survives the bump rewind.)
            slab_chunks: self.metrics.slab_chunks,
            slab_committed_bytes: self.metrics.slab_committed_bytes,
            slab_committed_peak_bytes: self.metrics.slab_committed_peak_bytes,
            slab_raw_bytes: self.metrics.slab_raw_bytes,
            los_live_bytes: self.metrics.los_live_bytes,
            los_free_bytes: self.metrics.los_free_bytes,
            ..HeapMetrics::default()
        };
    }

    /// Decommit barrier: return fully-empty slab
    /// chunks beyond `keep` per size class to the system allocator,
    /// folding the result into the `decommitted_*` counters and lowering
    /// the committed gauges. Call at generation barriers (the SMC engine
    /// does, via [`trim_shards`], when `decommit_watermark` is set);
    /// outputs are bit-identical whether and how often this runs. No-op
    /// for scratch heaps (retain-everything pooling) and the `system`
    /// backend.
    pub fn trim(&mut self, keep: usize) {
        let stats = self.alloc.trim(keep);
        let m = &mut self.metrics;
        if stats.chunks > 0 {
            m.slab_chunks -= stats.chunks;
            m.slab_committed_bytes -= stats.bytes;
            m.decommitted_chunks += stats.chunks;
            m.decommitted_bytes += stats.bytes;
        }
        if stats.los_bytes > 0 {
            // LOS blocks are not chunks: account them separately so the
            // chunk-granularity invariant
            // `decommitted_bytes == decommitted_chunks * CHUNK_BYTES` holds.
            m.los_free_bytes -= stats.los_bytes;
            m.los_decommitted_bytes += stats.los_bytes;
        }
    }

    // ------------------------------------------------------------------
    // Metrics scopes: exact operation deltas for a bracketed region
    // ------------------------------------------------------------------

    /// Open a metrics scope: a snapshot against which
    /// [`Heap::end_scope`] computes the *exact* operation delta of
    /// everything this heap did in between. The engine brackets one
    /// particle's propagation this way to feed the rebalancer exact
    /// per-particle costs (no `cost_hint` apportioning).
    #[inline]
    pub fn begin_scope(&self) -> MetricsScope {
        MetricsScope::open(&self.metrics)
    }

    /// Close a scope: monotone counters in the result are the exact
    /// in-scope deltas; gauges carry their current values (see
    /// [`HeapMetrics::delta_since`]).
    #[inline]
    pub fn end_scope(&self, scope: MetricsScope) -> HeapMetrics {
        scope.close(&self.metrics)
    }

    /// Mirror one allocation receipt into the slab gauges/counters.
    #[inline]
    fn note_alloc(&mut self, r: AllocReceipt) {
        let m = &mut self.metrics;
        if r.large {
            m.slab_large_allocs += 1;
        } else if r.reused {
            m.slab_freelist_hits += 1;
        } else {
            m.slab_fresh_bumps += 1;
            if r.new_chunk {
                m.slab_chunks += 1;
                m.slab_committed_bytes += CHUNK_BYTES;
                if m.slab_committed_bytes > m.slab_committed_peak_bytes {
                    m.slab_committed_peak_bytes = m.slab_committed_bytes;
                }
            }
        }
        m.slab_live_block_bytes += r.block_bytes;
        let all = m.slab_live_block_bytes + m.slab_raw_bytes;
        if all > m.slab_block_peak_bytes {
            m.slab_block_peak_bytes = all;
        }
        m.note_los_alloc(&r);
    }

    #[inline]
    fn note_free(&mut self, r: FreeReceipt) {
        self.metrics.slab_live_block_bytes -= r.block_bytes;
        self.metrics.note_los_free(&r);
    }

    /// Current context label (top of the context stack, Def. 4).
    #[inline]
    pub fn context(&self) -> LabelId {
        *self.context.last().expect("context stack never empty")
    }

    /// Push a context label (prefer [`Heap::with_context`]).
    pub fn push_context(&mut self, l: LabelId) {
        self.context.push(l);
    }

    /// Pop the top context label (must not pop the root context).
    pub fn pop_context(&mut self) {
        assert!(self.context.len() > 1, "cannot pop the root context");
        self.context.pop();
    }

    /// Run `f` with `l` as the current context (Condition 4: objects
    /// allocated inside get `f(v) = l`).
    pub fn with_context<R>(&mut self, l: LabelId, f: impl FnOnce(&mut Heap) -> R) -> R {
        self.push_context(l);
        let r = f(self);
        self.pop_context();
        r
    }

    // ------------------------------------------------------------------
    // Slot / label plumbing
    // ------------------------------------------------------------------

    #[inline]
    fn slot(&self, o: ObjId) -> &Slot {
        let s = &self.slots[o.idx as usize];
        debug_assert_eq!(s.gen, o.gen, "stale ObjId: slot recycled");
        s
    }

    #[inline]
    fn slot_mut(&mut self, o: ObjId) -> &mut Slot {
        let s = &mut self.slots[o.idx as usize];
        debug_assert_eq!(s.gen, o.gen, "stale ObjId: slot recycled");
        s
    }

    #[inline]
    fn label_alive(&self, l: LabelId) -> bool {
        let s = &self.labels[l.idx as usize];
        s.alive && s.gen == l.gen
    }

    fn new_slot(&mut self, payload: PBox, label: LabelId, shared: u32) -> ObjId {
        let bytes = payload.size_bytes() as u32;
        let idx = if let Some(idx) = self.free_slots.pop() {
            let s = &mut self.slots[idx as usize];
            debug_assert!(s.destroyed() && s.memo == 0);
            let gen = s.gen;
            *s = Slot::vacant(gen);
            s.payload = Some(payload);
            s.label = label;
            s.shared = shared;
            s.weak = 1;
            s.memo = 1;
            s.bytes = bytes;
            idx
        } else {
            let mut s = Slot::vacant(0);
            s.payload = Some(payload);
            s.label = label;
            s.shared = shared;
            s.weak = 1;
            s.memo = 1;
            s.bytes = bytes;
            self.slots.push(s);
            (self.slots.len() - 1) as u32
        };
        let gen = self.slots[idx as usize].gen;
        self.metrics.total_allocs += 1;
        self.metrics.live_objects += 1;
        self.metrics.live_bytes += bytes as usize + OBJ_OVERHEAD;
        self.metrics.note_peak();
        ObjId::new(idx, gen)
    }

    fn new_label(&mut self, memo: MemoTable) -> LabelId {
        self.metrics.memo_bytes += memo.size_bytes();
        self.metrics.live_labels += 1;
        let id = if let Some(idx) = self.free_labels.pop() {
            let s = &mut self.labels[idx as usize];
            debug_assert!(!s.alive);
            debug_assert_eq!(s.memo.capacity(), 0, "dead label kept bucket storage");
            s.memo = memo;
            s.shared = 0;
            s.alive = true;
            LabelId::new(idx, s.gen)
        } else {
            let mut ctx = RawCtx {
                alloc: &mut self.alloc,
                metrics: &mut self.metrics,
            };
            self.labels.push(
                &mut ctx,
                LabelSlot {
                    memo,
                    shared: 0,
                    gen: 0,
                    alive: true,
                },
            );
            LabelId::new((self.labels.len() - 1) as u32, 0)
        };
        self.metrics.note_peak();
        id
    }

    /// Placement-clone the live payload of `v` into *this* heap's slab
    /// (the same-heap eager-copy path). Split-borrow helper: the source
    /// bytes are read out of `slots` while `alloc` hands out storage.
    fn clone_payload_of(&mut self, v: ObjId) -> PBox {
        let slots = &self.slots;
        let s = &slots[v.idx as usize];
        debug_assert_eq!(s.gen, v.gen, "stale ObjId: slot recycled");
        let src = s.payload.as_deref().expect("deep copy of destroyed object");
        let (clone, receipt) = self.alloc.alloc_clone(src);
        self.note_alloc(receipt);
        clone
    }

    /// Placement-clone a *foreign* payload into this heap and install it
    /// in a fresh slot (the transplant path: `src` lives in another
    /// heap's storage).
    fn new_slot_cloned(&mut self, src: &dyn Payload, label: LabelId, shared: u32) -> ObjId {
        let (clone, receipt) = self.alloc.alloc_clone(src);
        self.note_alloc(receipt);
        self.new_slot(clone, label, shared)
    }

    // ------------------------------------------------------------------
    // Reference counting (three counts: shared / weak / memo, §3)
    // ------------------------------------------------------------------

    #[inline]
    fn inc_shared(&mut self, o: ObjId) {
        self.slot_mut(o).shared += 1;
    }

    #[inline]
    fn inc_label(&mut self, l: LabelId) {
        let s = &mut self.labels[l.idx as usize];
        debug_assert!(s.alive && s.gen == l.gen, "stale LabelId");
        s.shared += 1;
    }

    fn dec_shared(&mut self, o: ObjId) {
        self.obj_dec.push(o);
        self.drain_rc();
    }

    fn dec_label(&mut self, l: LabelId) {
        self.label_dec.push(l);
        self.drain_rc();
    }

    /// Drain the deferred release queues. Destroying an object decrements
    /// its out-edge targets (and cross-reference labels); killing a label
    /// decrements its memo values — all cascades are processed iteratively.
    fn drain_rc(&mut self) {
        if self.draining {
            return; // an outer drain_rc call will finish the queues
        }
        self.draining = true;
        loop {
            if let Some(o) = self.obj_dec.pop() {
                let s = self.slot_mut(o);
                debug_assert!(s.shared > 0, "shared count underflow");
                s.shared -= 1;
                if s.shared == 0 && !s.destroyed() {
                    self.destroy(o);
                }
            } else if let Some(l) = self.label_dec.pop() {
                let s = &mut self.labels[l.idx as usize];
                debug_assert!(s.alive && s.gen == l.gen, "stale LabelId");
                debug_assert!(s.shared > 0, "label count underflow");
                s.shared -= 1;
                if s.shared == 0 {
                    self.kill_label(l);
                }
            } else {
                break;
            }
        }
        self.draining = false;
    }

    /// Destroy an object: drop the payload, release out-edges. The slot is
    /// freed only when the memo count also reaches zero (§3: memo keys keep
    /// the slot reserved so ids cannot alias).
    fn destroy(&mut self, o: ObjId) {
        let slot = self.slot_mut(o);
        let payload = slot.payload.take().expect("destroy of destroyed object");
        let f_v = slot.label;
        let bytes = slot.bytes as usize;
        let mut edges = Vec::new();
        payload.edges(&mut edges);
        // Return the payload block to the slab (destructor runs there;
        // the block re-enters its class free list for the next
        // generation's allocations).
        let freed = self.alloc.dealloc(payload);
        self.note_free(freed);
        self.metrics.live_objects -= 1;
        self.metrics.total_frees += 1;
        self.metrics.live_bytes -= bytes + OBJ_OVERHEAD;
        for d in edges {
            if d.label != f_v && self.mode.is_lazy() {
                self.live_cross_edges -= 1;
                self.label_dec.push(d.label); // cross reference held its label
            }
            self.obj_dec.push(d.obj);
        }
        // weak self-count drops with the payload; memo self-count drops with
        // the weak count.
        let slot = self.slot_mut(o);
        slot.weak -= 1;
        if slot.weak == 0 {
            slot.memo -= 1;
            if slot.memo == 0 {
                self.free_slot(o);
            }
        }
    }

    #[inline]
    fn dec_memo_count(&mut self, o: ObjId) {
        let s = &mut self.slots[o.idx as usize];
        debug_assert!(s.memo > 0, "memo count underflow");
        s.memo -= 1;
        if s.memo == 0 {
            debug_assert!(s.destroyed() && s.weak == 0);
            self.free_slot(o);
        }
    }

    fn free_slot(&mut self, o: ObjId) {
        let s = &mut self.slots[o.idx as usize];
        debug_assert!(s.destroyed());
        let gen = s.gen.wrapping_add(1);
        *s = Slot::vacant(gen);
        self.free_slots.push(o.idx);
    }

    fn kill_label(&mut self, l: LabelId) {
        self.metrics.live_labels -= 1;
        self.metrics.memo_bytes -= self.labels[l.idx as usize].memo.size_bytes();
        let entries = {
            let s = &mut self.labels[l.idx as usize];
            s.alive = false;
            s.gen = s.gen.wrapping_add(1);
            let mut ctx = RawCtx {
                alloc: &mut self.alloc,
                metrics: &mut self.metrics,
            };
            s.memo.drain_all(&mut ctx)
        };
        self.free_labels.push(l.idx);
        for (k, v) in entries {
            self.dec_memo_count(k);
            self.obj_dec.push(v);
        }
    }

    // ------------------------------------------------------------------
    // Allocation and root handles
    // ------------------------------------------------------------------

    /// Allocate a new object under the current context. Returns an *owning*
    /// handle (release with [`Heap::release`] or store into a field). The
    /// value is placement-written straight into the slab — the typed hot
    /// path never touches the system allocator once its size class is
    /// warm.
    ///
    /// ```
    /// use lazycow::heap::{CopyMode, Heap, Lazy};
    /// use lazycow::lazy_fields;
    ///
    /// #[derive(Clone)]
    /// struct Node { value: i64, next: Lazy<Node> }
    /// lazy_fields!(Node: next);
    ///
    /// let mut heap = Heap::new(CopyMode::LazySro);
    /// let tail = heap.alloc(Node { value: 2, next: Lazy::NULL });
    /// let mut head = heap.alloc(Node { value: 1, next: tail });
    /// // The stored edge now owns a reference; drop the stack handle.
    /// heap.release(tail);
    /// assert_eq!(heap.read(&mut head, |n| n.value), 1);
    /// let mut next = heap.read_ptr(&mut head, |n| n.next);
    /// assert_eq!(heap.read(&mut next, |n| n.value), 2);
    /// heap.release(head);
    /// heap.sweep_memos();
    /// assert_eq!(heap.live_objects(), 0);
    /// ```
    pub fn alloc<T: Payload>(&mut self, value: T) -> Lazy<T> {
        let (payload, receipt) = self.alloc.alloc_value(value);
        self.note_alloc(receipt);
        Lazy::from_raw(self.install_new(payload))
    }

    /// Allocate from an already-boxed payload (the untyped entry point):
    /// the value moves into slab storage and the box allocation is
    /// released without running the destructor.
    pub fn alloc_raw(&mut self, payload: Box<dyn Payload>) -> RawLazy {
        let (payload, receipt) = self.alloc.adopt_box(payload);
        self.note_alloc(receipt);
        self.install_new(payload)
    }

    /// Shared tail of the allocation paths: slot bookkeeping for a
    /// freshly placed payload.
    fn install_new(&mut self, payload: PBox) -> RawLazy {
        let ctx = if self.mode.is_lazy() {
            self.context()
        } else {
            ROOT_LABEL
        };
        // Edges already inside the payload become owning stored edges.
        let mut edges = Vec::new();
        payload.edges(&mut edges);
        let o = self.new_slot(payload, ctx, 1);
        for d in edges {
            self.on_edge_added(d, ctx);
        }
        if self.mode.is_lazy() {
            self.inc_label(ctx);
        }
        RawLazy { obj: o, label: ctx }
    }

    /// Account for a new owning stored edge `d` inside an object whose
    /// creating label is `f_owner` (Condition 4 bookkeeping + the paper's
    /// cross-reference label counting).
    fn on_edge_added(&mut self, d: RawLazy, f_owner: LabelId) {
        self.inc_shared(d.obj);
        if self.mode.is_lazy() && d.label != f_owner {
            self.metrics.cross_refs += 1;
            self.live_cross_edges += 1;
            self.inc_label(d.label);
        }
        // Remark 1, condition 2: a new in-edge may duplicate an existing
        // in-edge's label, so the flag (set at freeze time) no longer
        // guarantees distinct labels at copy time.
        let s = self.slot_mut(d.obj);
        if s.frozen && s.single_ref {
            s.single_ref = false;
        }
    }

    fn on_edge_removed(&mut self, d: RawLazy, f_owner: LabelId) {
        if self.mode.is_lazy() && d.label != f_owner {
            self.live_cross_edges -= 1;
            self.label_dec.push(d.label);
        }
        self.obj_dec.push(d.obj);
        self.drain_rc();
    }

    /// Does adding edge `d` require an eager Get to preserve correctness?
    /// True when the target already skipped a memo update under `d.label`
    /// (§3: "In this situation GET is triggered on the edge").
    fn sro_hazard(&self, d: RawLazy) -> bool {
        if !self.mode.is_lazy() || d.obj.is_null() {
            return false;
        }
        let s = self.slot(d.obj);
        s.frozen && s.copied_once && (s.skipped_many || s.skipped_label == d.label)
    }

    /// Retain an extra owning handle to the same object (shared +1).
    pub fn clone_handle<T>(&mut self, e: &Lazy<T>) -> Lazy<T> {
        if e.is_null() {
            return Lazy::NULL;
        }
        self.inc_shared(e.raw.obj);
        if self.mode.is_lazy() {
            self.inc_label(e.raw.label);
            // Remark 1, condition 2: the retained handle duplicates an
            // in-edge label, so the single-reference flag (set at freeze
            // with in-degree 1) no longer guarantees distinct labels at
            // copy time — a skip now would strand this handle on the
            // stale original.
            let s = self.slot_mut(e.raw.obj);
            if s.frozen && s.single_ref {
                s.single_ref = false;
            }
        }
        *e
    }

    /// Release an owning handle.
    pub fn release<T>(&mut self, e: Lazy<T>) {
        self.release_raw(e.raw);
    }

    /// Release an owning handle by its untyped edge.
    pub fn release_raw(&mut self, e: RawLazy) {
        if e.is_null() {
            return;
        }
        if self.mode.is_lazy() {
            self.label_dec.push(e.label);
        }
        self.dec_shared(e.obj);
    }

    // ------------------------------------------------------------------
    // The lazy operations: Pull, Get, Copy, Freeze, Finish, DeepCopy
    // ------------------------------------------------------------------

    /// `Pull` (Algorithm 4): chase memo redirections so `t(e)` is correct
    /// for reading. `owning` edges transfer their shared count to the new
    /// target; borrowed locals do not.
    fn pull_raw(&mut self, e: &mut RawLazy, owning: bool) {
        if e.obj.is_null() || !self.mode.is_lazy() {
            return;
        }
        self.metrics.pulls += 1;
        if !self.label_alive(e.label) {
            // The deep-copy lineage identified by this label has no owning
            // references left; its memo (and private copies) are gone and no
            // redirection applies.
            return;
        }
        loop {
            let memo = &self.labels[e.label.idx as usize].memo;
            match memo.get(e.obj) {
                Some(u) => {
                    self.metrics.memo_hits += 1;
                    if owning {
                        self.inc_shared(u);
                        let old = e.obj;
                        e.obj = u;
                        self.dec_shared(old);
                    } else {
                        e.obj = u;
                    }
                }
                None => {
                    self.metrics.memo_misses += 1;
                    break;
                }
            }
        }
    }

    /// `Get` (Algorithm 5): pull, then copy-on-write if the target is
    /// frozen. After this, `t(e)` is safe to mutate.
    fn get_raw(&mut self, e: &mut RawLazy, owning: bool) {
        self.pull_raw(e, owning);
        if e.obj.is_null() || !self.mode.is_lazy() {
            return;
        }
        self.metrics.gets += 1;
        let v = e.obj;
        if !self.slot(v).frozen {
            return;
        }
        let l = e.label;
        // Copy elimination (§3): a frozen object whose only reference is
        // this edge can be thawed and reused in place.
        {
            let s = self.slot(v);
            if owning && s.shared == 1 && !s.in_memo_ran && s.memo == 1 {
                self.thaw(v, l);
                return;
            }
        }
        // A dead label (lineage with no owning references left) has no memo
        // to record into and no other live edge that could pull through it:
        // copy without a memo entry, like the single-reference optimization.
        let flagged = (owning && self.mode == CopyMode::LazySro && self.slot(v).single_ref)
            || !self.label_alive(l);
        let u = self.copy_object(v, l);
        {
            let s = self.slot_mut(v);
            s.copied_once = true;
            if flagged {
                if !s.skipped_label.is_null() && s.skipped_label != l {
                    s.skipped_many = true;
                }
                s.skipped_label = l;
            }
        }
        if flagged {
            // Remark 1: single in-edge at freeze time, distinct labels at
            // copy time — the memo will never be queried for v under l.
            self.metrics.sro_skips += 1;
        } else {
            self.memo_insert(l, v, u);
        }
        // t(e) <- u
        if owning {
            self.inc_shared(u);
            let old = e.obj;
            e.obj = u;
            self.dec_shared(old);
        } else {
            debug_assert!(
                self.slot(u).shared > 0,
                "borrowed get produced an unowned copy"
            );
            e.obj = u;
        }
    }

    fn memo_insert(&mut self, l: LabelId, v: ObjId, u: ObjId) {
        debug_assert!(self.label_alive(l));
        let before = self.labels[l.idx as usize].memo.size_bytes();
        let prev = {
            let memo = &mut self.labels[l.idx as usize].memo;
            let mut ctx = RawCtx {
                alloc: &mut self.alloc,
                metrics: &mut self.metrics,
            };
            memo.insert(&mut ctx, v, u)
        };
        debug_assert!(prev.is_none(), "double copy of {v:?} under {l:?}");
        let after = self.labels[l.idx as usize].memo.size_bytes();
        self.metrics.memo_bytes += after - before;
        self.slot_mut(v).memo += 1; // key: memo count
        self.inc_shared(u); // value: shared count
        self.slot_mut(u).in_memo_ran = true;
        self.metrics.note_peak();
    }

    /// `Copy` (Algorithm 6): shallow-copy the frozen object `v` for label
    /// `l`. Cross references in `v` (edges whose label differs from `f(v)`)
    /// are outside the tree pattern: they are eagerly `Finish`ed and frozen
    /// first, then shared by the clone. Tree-pattern edges in the clone are
    /// relabeled to `l`, enrolling the shared targets in the new lazy copy.
    fn copy_object(&mut self, v: ObjId, l: LabelId) -> ObjId {
        let f_v = self.slot(v).label;
        // Phase 1: resolve cross references on the original.
        let mut payload = self
            .slot_mut(v)
            .payload
            .take()
            .expect("copy of destroyed object");
        let mut probe = std::mem::take(&mut self.scratch_before);
        probe.clear();
        payload.edges(&mut probe);
        let has_cross = probe.iter().any(|d| d.label != f_v);
        self.scratch_before = probe;
        if has_cross {
            self.metrics.cross_refs += 1;
            payload.edges_mut(&mut |d: &mut RawLazy| {
                if !d.is_null() && d.label != f_v {
                    // Owning stored edge: Finish + Freeze (bookkeeping
                    // writes on a read-only object are permitted).
                    self.finish_edge(d);
                    self.freeze_raw(*d);
                }
            });
        }
        // Phase 2: placement-clone into the slab and fix up the clone's
        // edges.
        let (mut clone, receipt) = self.alloc.alloc_clone(&*payload);
        self.slot_mut(v).payload = Some(payload);
        self.note_alloc(receipt);
        let mut incs: Vec<RawLazy> = Vec::new();
        clone.edges_mut(&mut |d: &mut RawLazy| {
            if d.is_null() {
                return;
            }
            if d.label == f_v {
                d.label = l; // adopt the new label (tree pattern)
            }
            incs.push(*d);
        });
        for d in &incs {
            self.inc_shared(d.obj);
            if d.label != l {
                self.live_cross_edges += 1;
                self.inc_label(d.label); // cross reference in the clone
            }
        }
        self.metrics.lazy_copies += 1;
        self.new_slot(clone, l, 0)
    }

    /// In-place copy elimination (§3): thaw a frozen object whose sole
    /// reference is the writing edge, relabeling it to `l`.
    fn thaw(&mut self, v: ObjId, l: LabelId) {
        let f_v = self.slot(v).label;
        self.metrics.thaws += 1;
        let mut payload = self
            .slot_mut(v)
            .payload
            .take()
            .expect("thaw of destroyed object");
        let mut label_decs: Vec<LabelId> = Vec::new();
        payload.edges_mut(&mut |d: &mut RawLazy| {
            if d.is_null() {
                return;
            }
            if d.label == f_v {
                // Tree-pattern edge: relabel. It was uncounted (non-cross)
                // and stays uncounted iff the new label equals l.
                d.label = l;
            } else {
                self.finish_edge(d);
                self.freeze_raw(*d);
                if d.label == l {
                    // Was cross (counted), now non-cross: drop the count.
                    self.live_cross_edges -= 1;
                    label_decs.push(d.label);
                }
            }
        });
        let s = self.slot_mut(v);
        s.payload = Some(payload);
        s.frozen = false;
        s.single_ref = false;
        s.label = l;
        for d in label_decs {
            self.dec_label(d);
        }
    }

    /// `Freeze` (Algorithm 7): mark the subgraph reachable from `e`
    /// read-only; record the Remark 1 flag where it applies.
    fn freeze_raw(&mut self, e: RawLazy) {
        if e.obj.is_null() || !self.mode.is_lazy() {
            return;
        }
        let sro = self.mode == CopyMode::LazySro;
        let mut work = vec![e.obj];
        let mut edges = Vec::new();
        while let Some(v) = work.pop() {
            let s = self.slot_mut(v);
            if s.frozen || s.destroyed() {
                continue;
            }
            s.frozen = true;
            if sro && s.shared == 1 && !s.in_memo_ran {
                s.single_ref = true;
            }
            self.metrics.freezes += 1;
            edges.clear();
            if let Some(p) = &self.slot(v).payload {
                p.edges(&mut edges);
            }
            for d in &edges {
                work.push(d.obj);
            }
        }
    }

    /// `Finish` (Algorithm 8): complete all pending lazy copies in the
    /// subgraph reachable from `e` (eager deep copy of the out-of-tree
    /// region). Mutates stored edges in place.
    fn finish_edge(&mut self, e: &mut RawLazy) {
        if e.obj.is_null() || !self.mode.is_lazy() {
            return;
        }
        // Finish this edge: if its label has not propagated to the target,
        // Get it (copying as needed).
        self.pull_raw(e, true);
        let needs = {
            let s = self.slot(e.obj);
            !s.destroyed() && e.label != s.label
        };
        if needs {
            self.metrics.eager_copies += 1;
            self.get_raw(e, true);
        }
        // Recurse into the target's stored edges.
        let v = e.obj;
        let mut payload = match self.slot_mut(v).payload.take() {
            Some(p) => p,
            None => return, // cycle back into an object being finished
        };
        payload.edges_mut(&mut |d: &mut RawLazy| {
            if !d.is_null() {
                self.finish_edge(d);
            }
        });
        self.slot_mut(v).payload = Some(payload);
    }

    /// `DeepCopy` (Algorithm 3). In lazy modes: freeze the reachable
    /// subgraph, mint a new label whose memo is a swept clone of the source
    /// label's memo (flattened memos, Definition 5), and return a new
    /// owning handle — O(reachable) only on first copy (freeze), O(memo)
    /// afterwards, and no object payload is copied at all.
    /// In eager mode: a full recursive copy, preserving internal sharing.
    pub fn deep_copy<T>(&mut self, e: &Lazy<T>) -> Lazy<T> {
        Lazy::from_raw(self.deep_copy_raw(e.raw))
    }

    /// Untyped [`Heap::deep_copy`].
    pub fn deep_copy_raw(&mut self, e: RawLazy) -> RawLazy {
        if e.obj.is_null() {
            return RawLazy::NULL;
        }
        self.metrics.deep_copies += 1;
        if !self.mode.is_lazy() {
            return self.eager_deep_copy(e);
        }
        // §2.3: the single-label scheme is exact only for tree-structured
        // copies. If the reachable *view* contains a cross reference —
        // mutable aliasing with another lineage — "forego the lazy copy and
        // trigger an eager deep copy". The global counter makes this check
        // free for pure tree-pattern workloads.
        if self.live_cross_edges > 0 && self.view_has_cross(e) {
            return self.eager_fallback(e);
        }
        let mut e = e;
        self.pull_raw(&mut e, false);
        self.freeze_raw(e);
        // Clone the source label's memo, sweeping entries whose key can no
        // longer be pulled (zero shared count).
        let memo = if self.label_alive(e.label) {
            let src = &self.labels[e.label.idx as usize].memo;
            let mut cloned = MemoTable::new();
            let mut keep: Vec<(ObjId, ObjId)> = Vec::new();
            let mut swept = 0usize;
            for (k, v) in src.iter() {
                if self.slots[k.idx as usize].shared > 0 {
                    keep.push((k, v));
                } else {
                    swept += 1;
                }
            }
            self.metrics.memo_swept += swept;
            {
                let mut ctx = RawCtx {
                    alloc: &mut self.alloc,
                    metrics: &mut self.metrics,
                };
                for (k, v) in &keep {
                    cloned.insert(&mut ctx, *k, *v);
                }
            }
            for (k, v) in keep {
                self.slot_mut(k).memo += 1;
                self.inc_shared(v);
                // The value may be an unfrozen lineage-private copy that is
                // only memo-reachable; the new label's reader can pull to
                // it, so it must be frozen with the rest of the view.
                self.freeze_raw(RawLazy {
                    obj: v,
                    label: e.label,
                });
            }
            cloned
        } else {
            MemoTable::new()
        };
        let l = self.new_label(memo);
        self.inc_label(l); // returned handle owns the label
        self.inc_shared(e.obj);
        RawLazy { obj: e.obj, label: l }
    }

    /// Walk the *pulled view* reachable from `e` (applying the label
    /// propagation rule per edge, as reads would) looking for any cross
    /// reference. Only called when `live_cross_edges > 0`.
    fn view_has_cross(&mut self, e: RawLazy) -> bool {
        use std::collections::HashSet;
        let mut seen: HashSet<(ObjId, LabelId)> = HashSet::new();
        let mut work: Vec<RawLazy> = vec![e];
        let mut edges = Vec::new();
        while let Some(mut cur) = work.pop() {
            self.pull_raw(&mut cur, false);
            if !seen.insert((cur.obj, cur.label)) {
                continue;
            }
            let s = self.slot(cur.obj);
            let f_v = s.label;
            edges.clear();
            if let Some(p) = &s.payload {
                p.edges(&mut edges);
            }
            for d in &edges {
                if d.label != f_v {
                    return true; // cross reference in the view
                }
                // Tree-pattern edge: viewed under the reader's label.
                work.push(RawLazy {
                    obj: d.obj,
                    label: cur.label,
                });
            }
        }
        false
    }

    /// Eager deep copy of a *lazy-mode* subgraph: copies the pulled view
    /// (resolving memo redirections per edge), preserving internal sharing.
    /// The result is a fresh private structure under a new label.
    fn eager_fallback(&mut self, root: RawLazy) -> RawLazy {
        use std::collections::HashMap;
        let l = self.new_label(MemoTable::new());
        // Map (viewed object, view label) -> clone.
        let mut map: HashMap<(ObjId, LabelId), ObjId> = HashMap::new();
        let mut order: Vec<(ObjId, LabelId, ObjId)> = Vec::new();
        let mut work: Vec<RawLazy> = vec![root];
        let mut edges = Vec::new();
        while let Some(mut cur) = work.pop() {
            self.pull_raw(&mut cur, false);
            if map.contains_key(&(cur.obj, cur.label)) {
                continue;
            }
            let clone = self.clone_payload_of(cur.obj);
            let u = self.new_slot(clone, l, 0);
            self.metrics.eager_copies += 1;
            map.insert((cur.obj, cur.label), u);
            order.push((cur.obj, cur.label, u));
            let f_v = self.slot(cur.obj).label;
            edges.clear();
            self.slot(cur.obj).payload.as_ref().unwrap().edges(&mut edges);
            for d in &edges {
                let view = if d.label == f_v { cur.label } else { d.label };
                work.push(RawLazy {
                    obj: d.obj,
                    label: view,
                });
            }
        }
        // Rewire the clones' edges to the corresponding clones.
        for (v, view, u) in order {
            let f_v = self.slot(v).label;
            let mut payload = self.slot_mut(u).payload.take().unwrap();
            let mut incs: Vec<ObjId> = Vec::new();
            payload.edges_mut(&mut |d: &mut RawLazy| {
                if d.is_null() {
                    return;
                }
                let child_view = if d.label == f_v { view } else { d.label };
                // Resolve the edge the way the walk did.
                let mut resolved = RawLazy {
                    obj: d.obj,
                    label: child_view,
                };
                self.pull_raw(&mut resolved, false);
                let key = (resolved.obj, resolved.label);
                d.obj = map[&key];
                d.label = l; // fresh private structure: all tree-pattern
                incs.push(d.obj);
            });
            self.slot_mut(u).payload = Some(payload);
            for t in incs {
                self.inc_shared(t);
            }
        }
        let mut start = root;
        self.pull_raw(&mut start, false);
        let u = map[&(start.obj, start.label)];
        self.inc_shared(u);
        self.inc_label(l);
        RawLazy { obj: u, label: l }
    }

    /// Force an *eager* deep copy regardless of mode — the paper's §4 VBD
    /// note: "a deep copy of a single particle between iterations that must
    /// be completed eagerly, as it is outside the tree pattern" (particle
    /// Gibbs reference trajectories).
    pub fn deep_copy_eager<T>(&mut self, e: &Lazy<T>) -> Lazy<T> {
        if e.is_null() {
            return Lazy::NULL;
        }
        self.metrics.deep_copies += 1;
        if self.mode.is_lazy() {
            Lazy::from_raw(self.eager_fallback(e.raw))
        } else {
            Lazy::from_raw(self.eager_deep_copy(e.raw))
        }
    }

    fn eager_deep_copy(&mut self, root: RawLazy) -> RawLazy {
        use std::collections::HashMap;
        let mut map: HashMap<ObjId, ObjId> = HashMap::new();
        let mut order: Vec<ObjId> = Vec::new();
        let mut work = vec![root.obj];
        let mut edges = Vec::new();
        // Discover the reachable subgraph, cloning payloads.
        while let Some(v) = work.pop() {
            if map.contains_key(&v) {
                continue;
            }
            let clone = self.clone_payload_of(v);
            let u = self.new_slot(clone, ROOT_LABEL, 0);
            self.metrics.eager_copies += 1;
            map.insert(v, u);
            order.push(v);
            edges.clear();
            self.slot(v).payload.as_ref().unwrap().edges(&mut edges);
            for d in &edges {
                work.push(d.obj);
            }
        }
        // Rewire each clone's edges to the corresponding copies.
        for v in order {
            let u = map[&v];
            let mut payload = self.slot_mut(u).payload.take().unwrap();
            let mut incs: Vec<ObjId> = Vec::new();
            payload.edges_mut(&mut |d: &mut RawLazy| {
                if !d.is_null() {
                    d.obj = map[&d.obj];
                    d.label = ROOT_LABEL;
                    incs.push(d.obj);
                }
            });
            self.slot_mut(u).payload = Some(payload);
            for t in incs {
                self.inc_shared(t);
            }
        }
        let u = map[&root.obj];
        self.inc_shared(u); // returned handle
        RawLazy {
            obj: u,
            label: ROOT_LABEL,
        }
    }

    // ------------------------------------------------------------------
    // Freeze / extract: the cross-shard transplant APIs
    // ------------------------------------------------------------------

    /// Public `Freeze` entry (Algorithm 7): pull `e` up to date and mark
    /// the subgraph reachable from it read-only. No-op in eager mode.
    pub fn freeze_handle<T>(&mut self, e: &Lazy<T>) {
        let mut raw = e.raw;
        self.pull_raw(&mut raw, false);
        self.freeze_raw(raw);
    }

    /// A fresh empty heap in the same copy mode — the scratch heap a work-
    /// stealing thief propagates stolen particles in. The scratch heap is
    /// a full peer: lineages are moved in and out with
    /// [`Heap::extract_into`] and its op counters are folded back into the
    /// home shard with [`Heap::absorb_counters`] when it is reclaimed.
    ///
    /// Its payload allocator is the *bump-only* variant
    /// ([`SlabAlloc::scratch`]): a scratch drains completely at the
    /// generation barrier, so frees skip free-list maintenance and the
    /// storage is reclaimed in bulk when the scratch drops — or reused:
    /// the steal path pools drained scratches per shard via
    /// [`Heap::recycle_scratch`], so repeat donations recycle chunks
    /// instead of allocating fresh ones.
    pub fn scratch(&self) -> Heap {
        Heap::build(self.mode, SlabAlloc::scratch(self.alloc.kind()))
    }

    /// Fold a drained scratch heap's monotone op counters into this heap's
    /// metrics (see [`HeapMetrics::merge_counters`]). Call after every
    /// lineage has been transplanted back and released, so the scratch is
    /// empty and the alloc/free/live balance of this shard is preserved.
    pub fn absorb_counters(&mut self, scratch: &Heap) {
        debug_assert_eq!(
            scratch.metrics.live_objects, 0,
            "absorb_counters on a scratch heap that is not drained"
        );
        self.metrics.merge_counters(&scratch.metrics);
    }

    /// Cross-shard lineage transplant: materialize the subgraph reachable
    /// from `e` (which lives in `self`) inside the independent heap `dst`,
    /// returning a new owning handle valid in `dst`.
    ///
    /// In lazy modes the source view is first frozen (Algorithm 7 — so
    /// later same-shard `deep_copy`s of the ancestor stay O(1)) and the
    /// *pulled view* is walked, resolving memo redirections per edge
    /// exactly as reads would; the copy lands in `dst` under a fresh label
    /// with all tree-pattern edges, so it participates in `dst`'s lazy
    /// copy-on-write machinery from then on. In eager mode this is a plain
    /// cross-heap deep copy. Either way the transplant is completed
    /// eagerly: the two heaps share no objects afterwards, which is what
    /// makes shard workers lock-free.
    pub fn extract_into<T>(&mut self, e: &Lazy<T>, dst: &mut Heap) -> Lazy<T> {
        Lazy::from_raw(self.extract_into_raw(e.raw, dst))
    }

    /// Untyped [`Heap::extract_into`].
    pub fn extract_into_raw(&mut self, root: RawLazy, dst: &mut Heap) -> RawLazy {
        use std::collections::HashMap;
        if root.is_null() {
            return RawLazy::NULL;
        }
        // Hard assert (pub API): a mode mismatch would corrupt label
        // reference counting in the destination.
        assert_eq!(
            self.mode, dst.mode,
            "transplant between heaps of different copy modes"
        );
        dst.metrics.transplants += 1;
        if !self.mode.is_lazy() {
            // Eager mode: the eager_deep_copy walk, allocating into dst.
            let mut map: HashMap<ObjId, ObjId> = HashMap::new();
            let mut order: Vec<ObjId> = Vec::new();
            let mut work = vec![root.obj];
            let mut edges = Vec::new();
            while let Some(v) = work.pop() {
                if map.contains_key(&v) {
                    continue;
                }
                let src = self
                    .slot(v)
                    .payload
                    .as_deref()
                    .expect("transplant of destroyed object");
                let u = dst.new_slot_cloned(src, ROOT_LABEL, 0);
                dst.metrics.eager_copies += 1;
                map.insert(v, u);
                order.push(v);
                edges.clear();
                self.slot(v).payload.as_ref().unwrap().edges(&mut edges);
                for d in &edges {
                    work.push(d.obj);
                }
            }
            for v in order {
                let u = map[&v];
                let mut payload = dst.slot_mut(u).payload.take().unwrap();
                let mut incs: Vec<ObjId> = Vec::new();
                payload.edges_mut(&mut |d: &mut RawLazy| {
                    if !d.is_null() {
                        d.obj = map[&d.obj];
                        d.label = ROOT_LABEL;
                        incs.push(d.obj);
                    }
                });
                dst.slot_mut(u).payload = Some(payload);
                for t in incs {
                    dst.inc_shared(t);
                }
            }
            let u = map[&root.obj];
            dst.inc_shared(u);
            return RawLazy {
                obj: u,
                label: ROOT_LABEL,
            };
        }
        // Lazy modes: freeze the source view, then walk the pulled view
        // (label propagation rule per edge, as in the eager fallback) and
        // materialize it in dst under a fresh label.
        let mut e = root;
        self.pull_raw(&mut e, false);
        self.freeze_raw(e);
        let l = dst.new_label(MemoTable::new());
        let mut map: HashMap<(ObjId, LabelId), ObjId> = HashMap::new();
        let mut order: Vec<(ObjId, LabelId, ObjId)> = Vec::new();
        let mut work: Vec<RawLazy> = vec![e];
        let mut edges = Vec::new();
        while let Some(mut cur) = work.pop() {
            self.pull_raw(&mut cur, false);
            if map.contains_key(&(cur.obj, cur.label)) {
                continue;
            }
            let src = self
                .slot(cur.obj)
                .payload
                .as_deref()
                .expect("transplant of destroyed object");
            let u = dst.new_slot_cloned(src, l, 0);
            dst.metrics.eager_copies += 1;
            map.insert((cur.obj, cur.label), u);
            order.push((cur.obj, cur.label, u));
            let f_v = self.slot(cur.obj).label;
            edges.clear();
            self.slot(cur.obj).payload.as_ref().unwrap().edges(&mut edges);
            for d in &edges {
                let view = if d.label == f_v { cur.label } else { d.label };
                work.push(RawLazy {
                    obj: d.obj,
                    label: view,
                });
            }
        }
        // Rewire the destination clones' edges to the corresponding
        // clones; everything is tree-pattern under the fresh label.
        for (v, view, u) in order {
            let f_v = self.slot(v).label;
            let mut payload = dst.slot_mut(u).payload.take().unwrap();
            let mut incs: Vec<ObjId> = Vec::new();
            payload.edges_mut(&mut |d: &mut RawLazy| {
                if d.is_null() {
                    return;
                }
                let child_view = if d.label == f_v { view } else { d.label };
                let mut resolved = RawLazy {
                    obj: d.obj,
                    label: child_view,
                };
                self.pull_raw(&mut resolved, false);
                d.obj = map[&(resolved.obj, resolved.label)];
                d.label = l;
                incs.push(d.obj);
            });
            dst.slot_mut(u).payload = Some(payload);
            for t in incs {
                dst.inc_shared(t);
            }
        }
        let mut start = e;
        self.pull_raw(&mut start, false);
        let u = map[&(start.obj, start.label)];
        dst.inc_shared(u);
        dst.inc_label(l);
        RawLazy { obj: u, label: l }
    }

    // ------------------------------------------------------------------
    // Typed access
    // ------------------------------------------------------------------

    /// Read the target of `e` (pulls a borrowed local; `e` itself is
    /// updated so later accesses skip the memo chase).
    pub fn read<T: Payload, R>(&mut self, e: &mut Lazy<T>, f: impl FnOnce(&T) -> R) -> R {
        self.pull_raw(&mut e.raw, false);
        let s = self.slot(e.raw.obj);
        let p = s
            .payload
            .as_ref()
            .expect("read of destroyed object")
            .as_any()
            .downcast_ref::<T>()
            .expect("payload type mismatch");
        f(p)
    }

    /// Read a pointer field out of `parent`, applying the label propagation
    /// rule: a tree-pattern field (stored label == `f(owner)`) is viewed
    /// under the *reader's* label, so pulls deep inside shared frozen
    /// regions consult the reader's flattened memo (Definition 5); a
    /// cross-reference field keeps its own (finished) label.
    pub fn read_ptr<P: Payload, T>(
        &mut self,
        parent: &mut Lazy<P>,
        get: impl FnOnce(&P) -> Lazy<T>,
    ) -> Lazy<T> {
        self.pull_raw(&mut parent.raw, false);
        let owner = self.slot(parent.raw.obj);
        let f_owner = owner.label;
        let p = owner
            .payload
            .as_ref()
            .expect("read of destroyed object")
            .as_any()
            .downcast_ref::<P>()
            .expect("payload type mismatch");
        let mut child = get(p);
        if !child.is_null() && child.raw.label == f_owner {
            child.raw.label = parent.raw.label;
        }
        child
    }

    /// Make the target of a stored pointer field writable, updating the
    /// stored edge in place (`t(e) ← u`, Algorithm 5 on an owning edge).
    /// This is how writes descend into a structure — the paper's Table 1
    /// pattern: "as each node in the list is accessed it must be copied, as
    /// write access is potentially required". Requires a *writable* parent
    /// (obtained from [`Heap::mutate_root`] or a previous `get_field`), so
    /// stored edges along written paths never go stale and `Freeze` can
    /// soundly early-exit on frozen subgraphs.
    pub fn get_field<P: Payload, T>(
        &mut self,
        parent: &Lazy<P>,
        sel: impl Fn(&mut P) -> &mut Lazy<T>,
    ) -> Lazy<T> {
        let v = parent.raw.obj;
        debug_assert!(
            !self.slot(v).frozen,
            "get_field requires a writable parent (use mutate_root / get_field chain)"
        );
        let mut payload = self
            .slot_mut(v)
            .payload
            .take()
            .expect("get_field on destroyed object");
        let p = payload
            .as_any_mut()
            .downcast_mut::<P>()
            .expect("payload type mismatch");
        let mut raw = sel(p).raw;
        self.get_raw(&mut raw, true);
        let p = payload
            .as_any_mut()
            .downcast_mut::<P>()
            .expect("payload type mismatch");
        sel(p).raw = raw;
        self.slot_mut(v).payload = Some(payload);
        Lazy::from_raw(raw)
    }

    /// Mutate through a pointer whose target is already writable (returned
    /// by [`Heap::get_field`], or freshly allocated). Mutating a *frozen*
    /// target through a borrowed pointer is rejected: it would memoize a
    /// copy without updating the owning stored edge, leaving a stale edge
    /// that a later `Freeze` traversal cannot see through.
    pub fn mutate<T: Payload, R>(&mut self, e: &mut Lazy<T>, f: impl FnOnce(&mut T) -> R) -> R {
        self.pull_raw(&mut e.raw, false);
        assert!(
            !self.mode.is_lazy() || !self.slot(e.raw.obj).frozen,
            "mutate through a borrowed pointer to a frozen object; \
             descend with get_field instead"
        );
        self.mutate_impl(&mut e.raw, false, f)
    }

    /// Mutate through an *owning* handle (root handles held by the
    /// coordinator, or stored edges). Enables the single-reference and
    /// thaw optimizations.
    pub fn mutate_root<T: Payload, R>(
        &mut self,
        e: &mut Lazy<T>,
        f: impl FnOnce(&mut T) -> R,
    ) -> R {
        self.mutate_impl(&mut e.raw, true, f)
    }

    fn mutate_impl<T: Payload, R>(
        &mut self,
        e: &mut RawLazy,
        owning: bool,
        f: impl FnOnce(&mut T) -> R,
    ) -> R {
        self.get_raw(e, owning);
        let v = e.obj;
        let f_owner = self.slot(v).label;
        let mut payload = self
            .slot_mut(v)
            .payload
            .take()
            .expect("mutate of destroyed object");
        let mut before = std::mem::take(&mut self.scratch_before);
        before.clear();
        payload.edges(&mut before);
        let r = f(payload
            .as_any_mut()
            .downcast_mut::<T>()
            .expect("payload type mismatch"));
        let mut after = std::mem::take(&mut self.scratch_after);
        after.clear();
        payload.edges(&mut after);
        // Update the size estimate (payloads with Vec fields grow/shrink).
        let old_bytes = self.slot(v).bytes as usize;
        let new_bytes = payload.size_bytes();
        self.slot_mut(v).payload = Some(payload);
        if new_bytes != old_bytes {
            self.slot_mut(v).bytes = new_bytes as u32;
            self.metrics.live_bytes = self.metrics.live_bytes + new_bytes - old_bytes;
            self.metrics.note_peak();
        }
        self.edge_diff(v, f_owner, &before, &after);
        self.scratch_before = before;
        self.scratch_after = after;
        r
    }

    /// Multiset diff of stored edges around a mutation; maintains shared
    /// and cross-reference label counts, and repairs single-reference
    /// hazards with an eager Get.
    fn edge_diff(&mut self, v: ObjId, f_owner: LabelId, before: &[RawLazy], after: &[RawLazy]) {
        if before == after {
            return;
        }
        let mut removed: Vec<RawLazy> = Vec::new();
        let mut added: Vec<Option<RawLazy>> = after.iter().copied().map(Some).collect();
        'outer: for b in before {
            for a in added.iter_mut() {
                if *a == Some(*b) {
                    *a = None;
                    continue 'outer;
                }
            }
            removed.push(*b);
        }
        let mut hazards: Vec<RawLazy> = Vec::new();
        for a in added.into_iter().flatten() {
            if self.sro_hazard(a) {
                hazards.push(a);
            }
            self.on_edge_added(a, f_owner);
        }
        for b in removed {
            self.on_edge_removed(b, f_owner);
        }
        // Repair hazards: eagerly Get the new edges in place (§3).
        if !hazards.is_empty() {
            let mut payload = self.slot_mut(v).payload.take().unwrap();
            payload.edges_mut(&mut |d: &mut RawLazy| {
                if hazards.contains(d) {
                    self.get_raw(d, true);
                }
            });
            self.slot_mut(v).payload = Some(payload);
        }
    }

    /// Pull an owning root handle up to date (path shortening).
    pub fn pull_root<T>(&mut self, e: &mut Lazy<T>) {
        // The handle owns its label count; only the object count transfers.
        self.pull_raw(&mut e.raw, true);
    }

    /// Sweep all live memo tables, removing entries whose key object has a
    /// zero shared count (§3: "a sweep of a table can be performed at any
    /// point to remove entries with zero shared and weak count, but nonzero
    /// memo count"). Such keys can never be pulled again — a pull requires
    /// a live edge targeting the key. Iterates to a fixpoint, since
    /// releasing a value may kill further keys. The coordinator calls this
    /// once per generation; it also runs implicitly when labels die and
    /// when memos are cloned by `deep_copy`.
    pub fn sweep_memos(&mut self) {
        loop {
            let mut removed_any = false;
            for i in 0..self.labels.len() {
                if !self.labels[i].alive || self.labels[i].memo.is_empty() {
                    continue;
                }
                let before = self.labels[i].memo.size_bytes();
                // Collect liveness of keys first (cannot borrow slots while
                // sweeping the table in place).
                let dead: Vec<(ObjId, ObjId)> = {
                    let Heap {
                        labels,
                        slots,
                        alloc,
                        metrics,
                        ..
                    } = self;
                    let mut ctx = RawCtx { alloc, metrics };
                    labels[i]
                        .memo
                        .sweep(&mut ctx, |k| slots[k.idx as usize].shared > 0)
                };
                let after = self.labels[i].memo.size_bytes();
                self.metrics.memo_bytes = self.metrics.memo_bytes + after - before;
                if !dead.is_empty() {
                    removed_any = true;
                    self.metrics.memo_swept += dead.len();
                    for (k, v) in dead {
                        self.dec_memo_count(k);
                        self.obj_dec.push(v);
                    }
                    self.drain_rc();
                }
            }
            if !removed_any {
                break;
            }
        }
    }

    // ------------------------------------------------------------------
    // Introspection (tests, metrics, invariant checking)
    // ------------------------------------------------------------------

    /// Whether the object is read-only (`v ∈ R`).
    pub fn is_frozen(&self, o: ObjId) -> bool {
        self.slot(o).frozen
    }

    /// The object's shared (owning-reference) count.
    pub fn shared_count(&self, o: ObjId) -> u32 {
        self.slot(o).shared
    }

    /// The object's creating label `f(v)`.
    pub fn creator_label(&self, o: ObjId) -> LabelId {
        self.slot(o).label
    }

    /// Objects currently live.
    pub fn live_objects(&self) -> usize {
        self.metrics.live_objects
    }

    /// Labels currently live (including the pinned root label).
    pub fn live_labels(&self) -> usize {
        self.metrics.live_labels
    }

    /// Number of *distinct* objects reachable from the given handles — the
    /// quantity bounded by Jacob et al. (2015) for particle ancestry trees.
    pub fn reachable_objects(&self, roots: &[RawLazy]) -> usize {
        let mut seen = std::collections::HashSet::new();
        let mut work: Vec<ObjId> = roots
            .iter()
            .filter(|r| !r.is_null())
            .map(|r| r.obj)
            .collect();
        let mut edges = Vec::new();
        while let Some(v) = work.pop() {
            if !seen.insert(v) {
                continue;
            }
            if let Some(p) = &self.slot(v).payload {
                edges.clear();
                p.edges(&mut edges);
                for d in &edges {
                    work.push(d.obj);
                }
            }
        }
        seen.len()
    }

    /// Precise memo sweep by trial deletion: a memo entry `m_l(k) = v` is
    /// only useful if some live edge *labeled l* targets `k` (pulls query
    /// the edge's own label's table). The paper's cheap criterion (key has
    /// zero shared and weak counts) cannot collect self-sustaining cycles
    /// where the entry's value holds the only edge keeping the key alive —
    /// e.g. a cross reference stored in a copy made under the pinned root
    /// label. This pass computes the live (label, target) set from stored
    /// edges plus the caller's `handles` and removes unpullable entries,
    /// iterating to a fixpoint. O(heap) — an explicit GC pass, not part of
    /// the hot path.
    pub fn deep_sweep(&mut self, handles: &[RawLazy]) {
        use std::collections::HashSet;
        loop {
            let mut live: HashSet<(u32, u32)> = HashSet::new();
            for h in handles {
                if !h.is_null() {
                    live.insert((h.label.idx, h.obj.idx));
                }
            }
            let mut edges = Vec::new();
            for s in &self.slots {
                if let Some(p) = &s.payload {
                    edges.clear();
                    p.edges(&mut edges);
                    for d in &edges {
                        live.insert((d.label.idx, d.obj.idx));
                    }
                }
            }
            // Close the live set under memo chains: a pull of (k, l) hops
            // k -> m_l(k) -> m_l(m_l(k)) ... so each kept entry makes its
            // value pullable under the same label.
            loop {
                let mut changed = false;
                for (i, l) in self.labels.iter().enumerate() {
                    if !l.alive {
                        continue;
                    }
                    for (k, v) in l.memo.iter() {
                        if live.contains(&(i as u32, k.idx)) && live.insert((i as u32, v.idx)) {
                            changed = true;
                        }
                    }
                }
                if !changed {
                    break;
                }
            }
            let mut removed_any = false;
            for i in 0..self.labels.len() {
                if !self.labels[i].alive || self.labels[i].memo.is_empty() {
                    continue;
                }
                let before = self.labels[i].memo.size_bytes();
                let dead: Vec<(ObjId, ObjId)> = {
                    let Heap {
                        labels,
                        alloc,
                        metrics,
                        ..
                    } = self;
                    let mut ctx = RawCtx { alloc, metrics };
                    labels[i]
                        .memo
                        .sweep(&mut ctx, |k| live.contains(&(i as u32, k.idx)))
                };
                let after = self.labels[i].memo.size_bytes();
                self.metrics.memo_bytes = self.metrics.memo_bytes + after - before;
                if !dead.is_empty() {
                    removed_any = true;
                    self.metrics.memo_swept += dead.len();
                    for (k, v) in dead {
                        self.dec_memo_count(k);
                        self.obj_dec.push(v);
                    }
                    self.drain_rc();
                }
            }
            if !removed_any {
                break;
            }
        }
    }

    /// Debug description of all live objects and labels (tests/diagnosis).
    pub fn dump_live(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        for (i, slot) in self.slots.iter().enumerate() {
            if slot.destroyed() {
                continue;
            }
            let mut edges = Vec::new();
            slot.payload.as_ref().unwrap().edges(&mut edges);
            let _ = writeln!(
                s,
                "obj {i} gen={} f={:?} frozen={} sro={} shared={} weak={} memo={} edges={:?}",
                slot.gen, slot.label, slot.frozen, slot.single_ref, slot.shared, slot.weak,
                slot.memo, edges
            );
        }
        for (i, l) in self.labels.iter().enumerate() {
            if !l.alive {
                continue;
            }
            let entries: Vec<_> = l.memo.iter().collect();
            let _ = writeln!(s, "label {i} gen={} shared={} memo={entries:?}", l.gen, l.shared);
        }
        s
    }

    /// Recompute all reference counts from scratch and compare with the
    /// maintained ones. `handles` lists every owning handle held by the
    /// caller. Panics (with a description) on the first inconsistency.
    /// Used by the property-based tests.
    pub fn validate(&self, handles: &[RawLazy]) {
        use std::collections::HashMap;
        let mut shared: HashMap<u32, u32> = HashMap::new();
        let mut label_shared: HashMap<u32, u32> = HashMap::new();
        for h in handles {
            if h.is_null() {
                continue;
            }
            *shared.entry(h.obj.idx).or_default() += 1;
            if self.mode.is_lazy() {
                *label_shared.entry(h.label.idx).or_default() += 1;
            }
        }
        let mut edges = Vec::new();
        for (i, s) in self.slots.iter().enumerate() {
            let Some(p) = &s.payload else { continue };
            edges.clear();
            p.edges(&mut edges);
            for d in &edges {
                *shared.entry(d.obj.idx).or_default() += 1;
                if self.mode.is_lazy() && d.label != s.label {
                    *label_shared.entry(d.label.idx).or_default() += 1;
                }
                // Frozen-subgraph invariant: out-targets of a frozen object
                // are frozen.
                if s.frozen {
                    assert!(
                        self.slots[d.obj.idx as usize].frozen,
                        "frozen object {i} has unfrozen target {}",
                        d.obj.idx
                    );
                }
            }
        }
        for l in self.labels.iter() {
            if !l.alive {
                continue;
            }
            for (_k, v) in l.memo.iter() {
                *shared.entry(v.idx).or_default() += 1;
            }
        }
        for (i, s) in self.slots.iter().enumerate() {
            if s.destroyed() {
                continue;
            }
            let expect = shared.get(&(i as u32)).copied().unwrap_or(0);
            assert_eq!(
                s.shared, expect,
                "slot {i}: maintained shared={} recomputed={}",
                s.shared, expect
            );
        }
        for (i, l) in self.labels.iter().enumerate() {
            if i == 0 || !l.alive {
                continue; // root label is pinned
            }
            let expect = label_shared.get(&(i as u32)).copied().unwrap_or(0);
            assert_eq!(
                l.shared, expect,
                "label {i}: maintained shared={} recomputed={}",
                l.shared, expect
            );
        }
    }

    /// Cross-check every per-chunk liveness counter of the payload
    /// allocator against a ground-truth recount (free-list walks, avail
    /// membership, `live + free == bumped` per chunk — see
    /// [`SlabAlloc::validate_counters`]). Panics on the first drift.
    /// O(blocks); used by the differential suite's post-run sweep and the
    /// fuzz battery, never on a hot path.
    pub fn validate_storage(&self) {
        self.alloc.validate_counters();
    }

    /// The payload allocator (tests: chunk-liveness snapshots).
    pub fn allocator(&self) -> &SlabAlloc {
        &self.alloc
    }

    /// Opportunistic evacuation pass — Immix-style defragmentation at a
    /// generation barrier (opt-in via `--evacuate-threshold`). Chunks
    /// whose live payload bytes are at or below `threshold × CHUNK_BYTES`
    /// (and which hold no raw metadata blocks and are not the bump chunk)
    /// are victims: every surviving payload is placement-moved
    /// ([`Payload::relocate`]) into same-class bump/free space, its
    /// slot's `PBox` re-pointed in place, and the emptied chunks are
    /// decommitted. `Lazy` handles and memo entries address objects by
    /// slot index, not by address, so no handle or memo repointing is
    /// needed and outputs are bit-identical with evacuation on or off —
    /// only the `evacuated_*` metrics and committed-space gauges move.
    /// Returns the number of payloads relocated.
    pub fn evacuate(&mut self, threshold: f64) -> usize {
        if !self.alloc.begin_evacuation(threshold) {
            return 0;
        }
        let mut objects = 0usize;
        let mut bytes = 0usize;
        let mut new_chunks = 0usize;
        {
            let Heap { slots, alloc, .. } = self;
            for s in slots.iter_mut() {
                if let Some(pb) = s.payload.as_mut() {
                    if let Some(mv) = alloc.evacuate_block(pb) {
                        objects += 1;
                        bytes += mv.bytes;
                        new_chunks += usize::from(mv.new_chunk);
                    }
                }
            }
        }
        let freed = self.alloc.finish_evacuation();
        let m = &mut self.metrics;
        // Destination chunks committed during the walk coexisted with the
        // still-committed victims, so fold them in (and take the peak)
        // before subtracting the decommits. Evacuation moves blocks, it
        // does not allocate or free payloads: the payload alloc/free
        // counters and `decommitted_*` (reserved for `trim`) stay put.
        if new_chunks > 0 {
            m.slab_chunks += new_chunks;
            m.slab_committed_bytes += new_chunks * CHUNK_BYTES;
            if m.slab_committed_bytes > m.slab_committed_peak_bytes {
                m.slab_committed_peak_bytes = m.slab_committed_bytes;
            }
        }
        m.slab_chunks -= freed.chunks;
        m.slab_committed_bytes -= freed.bytes;
        m.evacuated_objects += objects;
        m.evacuated_bytes += bytes;
        m.evacuated_chunks += freed.chunks;
        objects
    }
}

#[cfg(test)]
mod tests;

#[cfg(test)]
mod transplant_tests;
