//! Generation-tagged identifiers for objects and labels.
//!
//! The paper's implementation (§3) uses raw C++ pointers; slot reuse there is
//! guarded by the memo reference count, which keeps a slot reserved while any
//! memo key still names it. We additionally tag every id with a generation
//! counter so that a stale id can never silently alias a recycled slot — a
//! use-after-free becomes a deterministic panic instead of memory corruption.

/// Identifier of an object (vertex) in the [`Heap`](super::Heap) slab.
///
/// 8 bytes: slot index + generation. This matches the paper's reported
/// overhead of "an extra 8 bytes per pointer" for the label half of a lazy
/// pointer; the object half is the price of any pointer.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct ObjId {
    pub(crate) idx: u32,
    pub(crate) gen: u32,
}

impl ObjId {
    /// Sentinel for "no object" (a null lazy pointer).
    pub const NULL: ObjId = ObjId {
        idx: u32::MAX,
        gen: u32::MAX,
    };

    /// Whether this is the null sentinel.
    #[inline]
    pub fn is_null(self) -> bool {
        self.idx == u32::MAX
    }

    #[inline]
    pub(crate) fn new(idx: u32, gen: u32) -> Self {
        ObjId { idx, gen }
    }

    /// Stable integer key for hashing / memo tables (slot index only; the
    /// memo count guarantees a keyed slot is not recycled while keyed).
    #[inline]
    pub(crate) fn key(self) -> u32 {
        self.idx
    }
}

/// Identifier of a label (a distinct deep-copy operation, §2.2 Definition 2).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct LabelId {
    pub(crate) idx: u32,
    pub(crate) gen: u32,
}

impl LabelId {
    /// Sentinel for "no label" (the label half of a null lazy pointer).
    pub const NULL: LabelId = LabelId {
        idx: u32::MAX,
        gen: u32::MAX,
    };

    /// Whether this is the null sentinel.
    #[inline]
    pub fn is_null(self) -> bool {
        self.idx == u32::MAX
    }

    #[inline]
    pub(crate) fn new(idx: u32, gen: u32) -> Self {
        LabelId { idx, gen }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_ids() {
        assert!(ObjId::NULL.is_null());
        assert!(LabelId::NULL.is_null());
        assert!(!ObjId::new(0, 0).is_null());
        assert!(!LabelId::new(3, 1).is_null());
    }

    #[test]
    fn distinct_generations_differ() {
        assert_ne!(ObjId::new(1, 0), ObjId::new(1, 1));
        assert_eq!(ObjId::new(1, 0).key(), ObjId::new(1, 1).key());
    }

    #[test]
    fn id_size_is_8_bytes() {
        assert_eq!(std::mem::size_of::<ObjId>(), 8);
        assert_eq!(std::mem::size_of::<LabelId>(), 8);
    }
}
