//! Property/fuzz tests for [`Heap::extract_into`] round trips — the
//! foundation both the cross-shard migration path and the work-stealing
//! scratch-heap path stand on.
//!
//! Random object graphs (chain "stacks", ragged fan-out arrays, shared
//! substructure via back-edges, optional pending lazy copies with memo
//! redirections) are transplanted src → scratch → back. The result must be
//! *isomorphic* to the source view — same canonical signature as a direct
//! eager deep copy, with internal sharing preserved — and every heap
//! involved must end balanced (allocs == frees + live) with validated
//! reference counts.

use super::ids::{LabelId, ObjId};
use super::{CopyMode, Heap, Lazy};
use crate::lazy_fields;
use crate::rng::Pcg64;
use std::collections::HashMap;

#[derive(Clone)]
struct FuzzNode {
    tag: i64,
    kids: Vec<Lazy<FuzzNode>>,
    extra: Option<Lazy<FuzzNode>>,
}
lazy_fields!(FuzzNode: kids, extra);

/// Build a random DAG rooted at the returned handle: a chain backbone
/// (every node links its predecessor, so the root reaches everything)
/// decorated with random fan-out edges and an optional `extra` back-edge —
/// ragged arrays and shared substructure in one shape family. Interior
/// stack handles are released; stored edges own the structure.
fn build_graph(heap: &mut Heap, rng: &mut Pcg64, max_nodes: usize) -> Lazy<FuzzNode> {
    let n = 2 + rng.below(max_nodes as u64 - 1) as usize;
    let mut handles: Vec<Lazy<FuzzNode>> = Vec::with_capacity(n);
    for idx in 0..n {
        let mut kids = Vec::new();
        if let Some(&prev) = handles.last() {
            kids.push(prev); // chain backbone
            let fan = rng.below(3) as usize;
            for _ in 0..fan {
                kids.push(handles[rng.below(handles.len() as u64) as usize]);
            }
        }
        let extra = if !handles.is_empty() && rng.below(4) == 0 {
            Some(handles[rng.below(handles.len() as u64) as usize])
        } else {
            None
        };
        let node = heap.alloc(FuzzNode {
            tag: idx as i64 * 17 + 1,
            kids,
            extra,
        });
        handles.push(node);
    }
    let root = handles.pop().expect("at least two nodes");
    for h in handles {
        heap.release(h);
    }
    root
}

/// Canonical form of the *view* reachable from `root`: DFS preorder ids,
/// each node recorded as (tag, kid ids, extra id, -1 for none). Nodes are
/// identified by their pulled (object, label) pair, so internal sharing
/// shows up as repeated ids and the form is isomorphism-invariant across
/// heaps.
type Sig = Vec<(i64, Vec<isize>, isize)>;

fn signature(heap: &mut Heap, root: Lazy<FuzzNode>) -> Sig {
    fn walk(
        heap: &mut Heap,
        mut cur: Lazy<FuzzNode>,
        seen: &mut HashMap<(ObjId, LabelId), usize>,
        order: &mut Sig,
    ) -> usize {
        // `read` pulls `cur` in place, so its raw pair is the resolved
        // view identity.
        let tag = heap.read(&mut cur, |n| n.tag);
        let key = (cur.raw().obj, cur.raw().label);
        if let Some(&id) = seen.get(&key) {
            return id;
        }
        let id = order.len();
        order.push((tag, Vec::new(), -1));
        seen.insert(key, id);
        let kid_count = heap.read(&mut cur, |n| n.kids.len());
        let mut kid_ids = Vec::with_capacity(kid_count);
        for j in 0..kid_count {
            let kid = heap.read_ptr(&mut cur, |n| n.kids[j]);
            kid_ids.push(if kid.is_null() {
                -1
            } else {
                walk(heap, kid, seen, order) as isize
            });
        }
        let extra = heap.read_ptr(&mut cur, |n| n.extra.unwrap_or(Lazy::NULL));
        let extra_id = if extra.is_null() {
            -1
        } else {
            walk(heap, extra, seen, order) as isize
        };
        order[id].1 = kid_ids;
        order[id].2 = extra_id;
        id
    }
    let mut order = Sig::new();
    let mut seen = HashMap::new();
    walk(heap, root, &mut seen, &mut order);
    order
}

/// One round-trip property case.
fn roundtrip_case(seed: u64, mode: CopyMode) {
    let mut rng = Pcg64::new(seed);
    let mut src = Heap::new(mode);
    let root = build_graph(&mut src, &mut rng, 24);

    // Half the lazy cases transplant a *mutated lazy copy* instead of the
    // original, so the source label's memo holds redirections mid-graph
    // and the transplant must materialize the pulled view, not the stale
    // objects.
    let mut copies: Vec<Lazy<FuzzNode>> = Vec::new();
    let target = if mode.is_lazy() && rng.below(2) == 0 {
        let mut c = src.deep_copy(&root);
        src.mutate_root(&mut c, |n| n.tag += 100_000);
        let has_kid = src.read(&mut c, |n| !n.kids.is_empty());
        if has_kid {
            // Descend one stored edge: a memo entry below the root.
            let mut k = src.get_field(&c, |n| &mut n.kids[0]);
            src.mutate(&mut k, |n| n.tag += 500_000);
        }
        copies.push(c);
        c
    } else {
        root
    };
    let want = signature(&mut src, target);
    assert!(want.len() >= 2, "degenerate graph");

    // src → scratch.
    let mut scratch = src.scratch();
    let moved = src.extract_into(&target, &mut scratch);
    assert_eq!(
        signature(&mut scratch, moved),
        want,
        "seed {seed} {mode:?}: scratch view differs from source"
    );
    // A transplant materializes the pulled view, so the stored graph in
    // the scratch must have exactly one object per distinct view node —
    // shared substructure stays shared, nothing is duplicated.
    assert_eq!(
        scratch.reachable_objects(&[moved.raw()]),
        want.len(),
        "seed {seed} {mode:?}: sharing not preserved in scratch"
    );

    // scratch → back, then drain the scratch completely.
    let back = scratch.extract_into(&moved, &mut src);
    scratch.release(moved);
    scratch.sweep_memos();
    assert_eq!(scratch.live_objects(), 0, "seed {seed} {mode:?}: scratch leaked");
    assert_eq!(
        scratch.metrics.total_allocs, scratch.metrics.total_frees,
        "seed {seed} {mode:?}: scratch alloc/free balance broken"
    );
    scratch.validate(&[]);

    assert_eq!(
        signature(&mut src, back),
        want,
        "seed {seed} {mode:?}: round trip not isomorphic to the source view"
    );
    assert_eq!(
        src.reachable_objects(&[back.raw()]),
        want.len(),
        "seed {seed} {mode:?}: sharing not preserved through the round trip"
    );

    // The round trip is isomorphic to a *direct* eager deep copy, and the
    // source view itself is untouched.
    let direct = src.deep_copy_eager(&target);
    assert_eq!(
        signature(&mut src, direct),
        want,
        "seed {seed} {mode:?}: direct deep copy disagrees"
    );
    assert_eq!(signature(&mut src, target), want, "source view disturbed");

    // Cleanup: everything released, per-heap balance restored.
    src.release(back);
    src.release(direct);
    for c in copies {
        src.release(c);
    }
    src.release(root);
    src.sweep_memos();
    assert_eq!(src.live_objects(), 0, "seed {seed} {mode:?}: src leaked");
    assert_eq!(
        src.metrics.total_allocs,
        src.metrics.total_frees + src.metrics.live_objects,
        "seed {seed} {mode:?}: src alloc/free/live balance broken"
    );
    src.validate(&[]);
}

#[test]
fn extract_into_roundtrip_fuzz() {
    for mode in CopyMode::ALL {
        for seed in 0..30u64 {
            roundtrip_case(seed ^ 0xF022, mode);
        }
    }
}

/// A directed shape case the fuzz loop hits only occasionally: a deep
/// chain ("stack") plus a wide ragged node sharing a tail — transplanted
/// twice over, with the second hop into a heap that already holds other
/// structure (offsets all ids, catching absolute-id assumptions).
#[test]
fn extract_into_roundtrip_with_preexisting_structure() {
    for mode in CopyMode::ALL {
        let mut rng = Pcg64::new(99);
        let mut src = Heap::new(mode);
        let root = build_graph(&mut src, &mut rng, 20);
        let want = signature(&mut src, root);

        let mut dst = Heap::new(mode);
        // Pre-populate the destination so transplanted ids don't align.
        let resident = build_graph(&mut dst, &mut rng, 10);
        let resident_sig = signature(&mut dst, resident);

        let mut scratch = src.scratch();
        let moved = src.extract_into(&root, &mut scratch);
        let landed = scratch.extract_into(&moved, &mut dst);
        scratch.release(moved);
        scratch.sweep_memos();
        assert_eq!(scratch.live_objects(), 0);

        assert_eq!(signature(&mut dst, landed), want, "{mode:?}: landed view differs");
        assert_eq!(
            signature(&mut dst, resident),
            resident_sig,
            "{mode:?}: transplant disturbed resident structure"
        );

        dst.release(landed);
        dst.release(resident);
        src.release(root);
        src.sweep_memos();
        dst.sweep_memos();
        assert_eq!(src.live_objects(), 0);
        assert_eq!(dst.live_objects(), 0);
        for h in [&src, &dst] {
            assert_eq!(h.metrics.total_allocs, h.metrics.total_frees + h.metrics.live_objects);
        }
    }
}
