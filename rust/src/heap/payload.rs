//! Payload trait: what the heap requires of object data `b(v)` (§2.1 Def. 1).
//!
//! The heap needs three capabilities from a payload: (1) clone it bitwise
//! (for the `Copy` operation, Algorithm 6), (2) enumerate its out-edges (for
//! `Freeze`/`Finish` traversals and reference-count bookkeeping), and (3)
//! rewrite its out-edge labels in place (the clone rule of Algorithm 6:
//! non-cross edges in a fresh copy adopt the new label). Everything else
//! about the payload is opaque.
//!
//! Payload *storage* belongs to the owning heap's slab allocator
//! ([`SlabAlloc`](super::alloc::SlabAlloc)), so cloning is *placement*
//! cloning: the allocator hands out a block of [`Payload::layout`] bytes
//! and [`Payload::clone_into`] / [`Payload::move_into`] construct the
//! concrete value there, returning the fat pointer the allocator wraps in
//! a [`PBox`](super::alloc::PBox). The [`crate::lazy_fields!`] macro
//! derives all of this; the placement methods exist because a trait
//! object cannot otherwise be cloned or moved into caller-provided
//! storage (the vtable knows the concrete type; stable Rust offers no way
//! to re-point a fat pointer at new storage from outside).

use std::alloc::Layout;
use std::any::Any;

use super::lazy::RawLazy;

/// Object payload data. Implement via [`crate::lazy_fields!`] for structs
/// with a fixed set of lazy-pointer fields (each a [`Lazy<T>`](super::Lazy),
/// `Vec<Lazy<T>>`, or `Option<Lazy<T>>` — anything implementing
/// [`EdgeSlot`]).
///
/// `Send` is a supertrait so that whole [`Heap`](super::Heap) shards can be
/// handed to worker threads (one `&mut Heap` per worker, no sharing).
pub trait Payload: Any + Send {
    /// Append all (non-null) out-edges to `out`.
    fn edges(&self, out: &mut Vec<RawLazy>);

    /// Visit every out-edge slot mutably (including null slots is allowed
    /// but not required; the heap skips nulls).
    fn edges_mut(&mut self, f: &mut dyn FnMut(&mut RawLazy));

    /// Approximate heap size of the payload in bytes, for memory metrics.
    fn size_bytes(&self) -> usize;

    /// Size/alignment of the *concrete* payload type — what the slab
    /// allocator must reserve for a clone.
    fn layout(&self) -> Layout;

    /// Placement-clone: construct a clone of `self` at `dst` and return
    /// the fat pointer to it (shallow: lazy-pointer fields copy bitwise).
    ///
    /// # Safety
    /// `dst` must be valid for writes of [`Payload::layout`] bytes at
    /// that layout's alignment, and must not overlap `self`.
    unsafe fn clone_into(&self, dst: *mut u8) -> *mut dyn Payload;

    /// Placement-move: move the boxed value to `dst` (bitwise), free the
    /// box's allocation *without* running the destructor, and return the
    /// fat pointer to the moved value.
    ///
    /// # Safety
    /// `dst` must be valid for writes of [`Payload::layout`] bytes at
    /// that layout's alignment.
    unsafe fn move_into(self: Box<Self>, dst: *mut u8) -> *mut dyn Payload;

    /// Placement-relocate: bitwise-copy `self` into `dst` and return the
    /// fat pointer to the copy — the evacuation move. Unlike
    /// [`Payload::move_into`], the source storage is not freed here (the
    /// allocator decommits the whole evacuated chunk afterwards).
    ///
    /// # Safety
    /// `dst` must be valid for writes of [`Payload::layout`] bytes at
    /// that layout's alignment and must not overlap `self`. The copy is a
    /// *move*: the caller must treat the source as moved-out afterwards —
    /// never read it, drop it, or run its destructor again.
    unsafe fn relocate(&self, dst: *mut u8) -> *mut dyn Payload;

    /// Upcast for typed reads ([`Heap::read`](super::Heap::read)).
    fn as_any(&self) -> &dyn Any;
    /// Upcast for typed mutation ([`Heap::mutate`](super::Heap::mutate)).
    fn as_any_mut(&mut self) -> &mut dyn Any;
}

/// Implement [`Payload`] for a struct, listing the fields that hold lazy
/// pointers (each of type [`Lazy<T>`](super::Lazy), a `Vec<Lazy<T>>`, or an
/// `Option<Lazy<T>>` — anything implementing [`EdgeSlot`]).
///
/// ```ignore
/// struct Node { value: i64, next: Lazy<Node> }
/// lazy_fields!(Node: next);
/// ```
#[macro_export]
macro_rules! lazy_fields {
    ($ty:ty) => {
        $crate::lazy_fields!($ty:);
    };
    ($ty:ty : $($field:ident),* $(,)?) => {
        impl $crate::heap::Payload for $ty
        where
            $ty: Clone + 'static,
        {
            fn edges(&self, out: &mut Vec<$crate::heap::RawLazy>) {
                $( $crate::heap::EdgeSlot::collect(&self.$field, out); )*
                let _ = out;
            }
            fn edges_mut(
                &mut self,
                f: &mut dyn FnMut(&mut $crate::heap::RawLazy),
            ) {
                $( $crate::heap::EdgeSlot::visit_mut(&mut self.$field, f); )*
                let _ = f;
            }
            fn size_bytes(&self) -> usize {
                std::mem::size_of::<$ty>()
            }
            fn layout(&self) -> std::alloc::Layout {
                std::alloc::Layout::new::<$ty>()
            }
            unsafe fn clone_into(&self, dst: *mut u8) -> *mut dyn $crate::heap::Payload {
                let value: $ty = self.clone();
                // SAFETY: caller provides `layout()`-sized, -aligned,
                // non-overlapping storage.
                unsafe { std::ptr::write(dst as *mut $ty, value) };
                dst as *mut $ty as *mut dyn $crate::heap::Payload
            }
            unsafe fn move_into(
                self: Box<Self>,
                dst: *mut u8,
            ) -> *mut dyn $crate::heap::Payload {
                let src = Box::into_raw(self);
                // SAFETY: `src` is a live box of `$ty`; `dst` has its
                // layout; the bitwise move transfers ownership, so the
                // box allocation is released without dropping the value.
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        src as *const u8,
                        dst,
                        std::mem::size_of::<$ty>(),
                    );
                    if std::mem::size_of::<$ty>() != 0 {
                        std::alloc::dealloc(
                            src as *mut u8,
                            std::alloc::Layout::new::<$ty>(),
                        );
                    }
                }
                dst as *mut $ty as *mut dyn $crate::heap::Payload
            }
            unsafe fn relocate(&self, dst: *mut u8) -> *mut dyn $crate::heap::Payload {
                // SAFETY: caller provides `layout()`-sized, -aligned,
                // non-overlapping storage and treats the source as
                // moved-out (no destructor runs on it).
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        self as *const $ty as *const u8,
                        dst,
                        std::mem::size_of::<$ty>(),
                    );
                }
                dst as *mut $ty as *mut dyn $crate::heap::Payload
            }
            fn as_any(&self) -> &dyn std::any::Any { self }
            fn as_any_mut(&mut self) -> &mut dyn std::any::Any { self }
        }
    };
}

/// A field that stores zero or more lazy pointers.
pub trait EdgeSlot {
    /// Append the slot's non-null edges to `out`.
    fn collect(&self, out: &mut Vec<RawLazy>);
    /// Visit every edge slot mutably (null slots included).
    fn visit_mut(&mut self, f: &mut dyn FnMut(&mut RawLazy));
}

impl<T: 'static> EdgeSlot for super::Lazy<T> {
    fn collect(&self, out: &mut Vec<RawLazy>) {
        if !self.is_null() {
            out.push(self.raw);
        }
    }
    fn visit_mut(&mut self, f: &mut dyn FnMut(&mut RawLazy)) {
        f(&mut self.raw);
    }
}

impl<T: 'static> EdgeSlot for Option<super::Lazy<T>> {
    fn collect(&self, out: &mut Vec<RawLazy>) {
        if let Some(p) = self {
            EdgeSlot::collect(p, out);
        }
    }
    fn visit_mut(&mut self, f: &mut dyn FnMut(&mut RawLazy)) {
        if let Some(p) = self {
            EdgeSlot::visit_mut(p, f);
        }
    }
}

impl<S: EdgeSlot> EdgeSlot for Vec<S> {
    fn collect(&self, out: &mut Vec<RawLazy>) {
        for s in self {
            s.collect(out);
        }
    }
    fn visit_mut(&mut self, f: &mut dyn FnMut(&mut RawLazy)) {
        for s in self {
            s.visit_mut(f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Lazy, RawLazy};
    use super::*;
    use crate::heap::ids::{LabelId, ObjId};

    #[derive(Clone)]
    struct Node {
        #[allow(dead_code)]
        value: i64,
        next: Lazy<Node>,
    }
    lazy_fields!(Node: next);

    #[derive(Clone)]
    struct Ragged {
        items: Vec<Lazy<Node>>,
        opt: Option<Lazy<Node>>,
    }
    lazy_fields!(Ragged: items, opt);

    #[derive(Clone)]
    struct Leaf {
        #[allow(dead_code)]
        x: f64,
    }
    lazy_fields!(Leaf);

    fn ptr(i: u32) -> Lazy<Node> {
        Lazy::from_raw(RawLazy {
            obj: ObjId::new(i, 0),
            label: LabelId::new(0, 0),
        })
    }

    #[test]
    fn collects_non_null_edges() {
        let n = Node {
            value: 1,
            next: Lazy::NULL,
        };
        let mut out = Vec::new();
        n.edges(&mut out);
        assert!(out.is_empty());

        let n = Node {
            value: 1,
            next: ptr(7),
        };
        n.edges(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].obj.idx, 7);
    }

    #[test]
    fn ragged_and_optional_slots() {
        let r = Ragged {
            items: vec![ptr(1), Lazy::NULL, ptr(2)],
            opt: Some(ptr(3)),
        };
        let mut out = Vec::new();
        r.edges(&mut out);
        assert_eq!(out.len(), 3); // nulls skipped
        let mut r = r;
        let mut count = 0;
        r.edges_mut(&mut |_| count += 1);
        assert_eq!(count, 4); // mutable visit includes the null slot
    }

    #[test]
    fn leaf_has_no_edges() {
        let l = Leaf { x: 0.0 };
        let mut out = Vec::new();
        l.edges(&mut out);
        assert!(out.is_empty());
        assert_eq!(l.size_bytes(), std::mem::size_of::<Leaf>());
    }
}
