//! lazycow launcher: run experiment cells and regenerate the paper's
//! figures from the command line.
//!
//! ```text
//! lazycow run   --model rbpf --task inference --mode lazy-sro --particles 256 --steps 150
//! lazycow serve [--listen 127.0.0.1:7878] [--metrics-addr 127.0.0.1:9100]
//!               # multi-session inference server (+ Prometheus /metrics)
//! lazycow fig5  [--reps 5] [--scale paper]     # §4 Figure 5 (inference)
//! lazycow fig6  [--reps 5]                     # §4 Figure 6 (simulation)
//! lazycow fig7  --model rbpf                   # §4 Figure 7 (series over t)
//! lazycow tree-bound                           # Jacob et al. (2015) bound
//! ```
//!
//! `serve` multiplexes named [`FilterSession`](lazycow::smc::FilterSession)s
//! — any model, any mix — over one shared sharded heap, driven by a line
//! protocol on stdin/`--input` or, with `--listen addr:port`, over TCP:
//! `open <name> <model>` starts a session, `obs <name> <tokens>` ingests
//! one observation and steps a generation, `whatif` answers speculative
//! queries on a lazily forked population, `fork` branches a session,
//! `telemetry` dumps the stable-name registry, `finish`/`close` end one
//! session and `finish-all` (or EOF/SIGTERM) drains them all. See
//! `DESIGN.md` for the protocol spec.

use lazycow::bench::{human_bytes, CellResult};
use lazycow::cli::{Cli, CliError};
use lazycow::config::{parse_config_text, Model, RunConfig, Task};
use lazycow::heap::{AllocatorKind, CopyMode, Heap, ShardedHeap};
use lazycow::models::run_model;
use lazycow::pool::ThreadPool;
use lazycow::runtime::{BatchKalman, XlaRuntime};
use lazycow::smc::StepCtx;

fn cli() -> Cli {
    Cli::new(
        "lazycow",
        "lazy object copy-on-write platform for population-based probabilistic programming",
    )
    .command("run", "run one (model, task, mode) cell")
    .command(
        "serve",
        "incremental inference server: ingest observations, fork for what-ifs",
    )
    .command("fig5", "regenerate Figure 5 (inference: time + peak memory)")
    .command("fig6", "regenerate Figure 6 (simulation: overhead isolation)")
    .command("fig7", "regenerate Figure 7 (time/memory series over t)")
    .command("tree-bound", "ancestry-tree reachability vs the Jacob et al. bound")
    .flag("model", "rbpf", "model: rbpf|pcfg|vbd|mot|crbd|list")
    .flag("task", "inference", "task: inference|simulation")
    .flag("mode", "lazy-sro", "copy mode: eager|lazy|lazy-sro")
    .flag("particles", "", "particle count N (default: model preset)")
    .flag("steps", "", "generations T (default: model preset)")
    .flag("seed", "20200401", "PRNG seed")
    .flag("threads", "0", "worker threads (0 = all cores)")
    .flag("shards", "0", "heap shards K for parallel propagation (0 = match threads)")
    .flag("rebalance", "", "offspring rebalancing at K>1: off|greedy|budget (default greedy)")
    .flag(
        "rebalance-threshold",
        "",
        "imbalance fraction of mean shard load that triggers migration (default 0.25)",
    )
    .flag(
        "steal",
        "",
        "intra-generation work stealing at K>1: on|off (default on; output identical either way)",
    )
    .flag(
        "steal-threshold",
        "",
        "min pending particles before a busy shard donates its tail (default 4)",
    )
    .flag(
        "allocator",
        "",
        "payload storage backend: system|slab (default slab; output identical either way)",
    )
    .flag(
        "batch",
        "",
        "batched SoA numeric path: on|off (default on; output identical either way)",
    )
    .flag(
        "decommit-watermark",
        "",
        "empty slab chunks kept per size class before decommitting to the OS at generation \
         barriers (integer, or off to disable; default 2; output identical either way)",
    )
    .flag(
        "evacuate-threshold",
        "",
        "evacuate slab chunks whose live fraction is at or below this value at generation \
         barriers (fraction in [0,1], or off; default off; output identical either way)",
    )
    .flag(
        "input",
        "",
        "serve: observation/command file replayed through the line protocol (default: stdin)",
    )
    .flag(
        "listen",
        "",
        "serve: TCP listen address (addr:port); default is the stdin line protocol",
    )
    .flag(
        "metrics-addr",
        "",
        "serve: Prometheus scrape address (host:port) answering GET /metrics; default off",
    )
    .flag(
        "trace",
        "",
        "append per-phase span records (JSONL) to this path; default off (output identical \
         either way)",
    )
    .flag("reps", "5", "benchmark repetitions")
    .flag("scale", "default", "scale preset: default|paper")
    .flag("config", "", "config file (key = value lines)")
    .flag("artifacts", "artifacts", "AOT artifact directory")
    .bool_flag("no-xla", "disable the PJRT artifact path")
    .bool_flag("series", "print the per-generation series")
}

/// `--allocator` value, when one was given (shared by `run` and the
/// figure commands).
fn parse_allocator(args: &lazycow::cli::Args) -> Result<Option<AllocatorKind>, String> {
    match args.get("allocator") {
        Some(a) if !a.is_empty() => Ok(Some(
            AllocatorKind::parse(a).ok_or("bad --allocator (system|slab)")?,
        )),
        _ => Ok(None),
    }
}

fn build_config(args: &lazycow::cli::Args) -> Result<RunConfig, String> {
    let model = Model::parse(args.get_or("model", "rbpf")).ok_or("bad --model")?;
    let task = Task::parse(args.get_or("task", "inference")).ok_or("bad --task")?;
    let mode = CopyMode::parse(args.get_or("mode", "lazy-sro")).ok_or("bad --mode")?;
    let mut cfg = RunConfig::for_model(model, task, mode);
    if args.get_or("scale", "default") == "paper" {
        let (n, t_inf, t_sim) = model.paper_scale();
        cfg.n_particles = n;
        cfg.n_steps = if task == Task::Inference { t_inf } else { t_sim };
    }
    if let Some(f) = args.get("config") {
        if !f.is_empty() {
            let text = std::fs::read_to_string(f).map_err(|e| e.to_string())?;
            for (k, v) in parse_config_text(&text)? {
                cfg.apply(&k, &v)?;
            }
        }
    }
    if let Some(n) = args.get_usize("particles") {
        cfg.n_particles = n;
    }
    if let Some(t) = args.get_usize("steps") {
        cfg.n_steps = t;
    }
    if let Some(s) = args.get_u64("seed") {
        cfg.seed = s;
    }
    if let Some(t) = args.get_usize("threads") {
        cfg.threads = t;
    }
    if let Some(s) = args.get_usize("shards") {
        cfg.shards = s;
    }
    if let Some(p) = args.get("rebalance") {
        if !p.is_empty() {
            cfg.rebalance = lazycow::smc::RebalancePolicy::parse(p)
                .ok_or("bad --rebalance (off|greedy|budget)")?;
        }
    }
    if let Some(t) = args.get_f64("rebalance-threshold") {
        cfg.rebalance_threshold = t;
    }
    if let Some(s) = args.get("steal") {
        if !s.is_empty() {
            cfg.apply("steal", s)?;
        }
    }
    if let Some(m) = args.get_usize("steal-threshold") {
        cfg.steal_min = m;
    }
    if let Some(kind) = parse_allocator(args)? {
        cfg.allocator = kind;
    }
    if let Some(w) = args.get("decommit-watermark") {
        if !w.is_empty() {
            cfg.apply("decommit-watermark", w)?;
        }
    }
    if let Some(e) = args.get("evacuate-threshold") {
        if !e.is_empty() {
            cfg.apply("evacuate-threshold", e)?;
        }
    }
    if let Some(b) = args.get("batch") {
        if !b.is_empty() {
            cfg.apply("batch", b)?;
        }
    }
    if let Some(a) = args.get("listen") {
        if !a.is_empty() {
            cfg.apply("listen", a)?;
        }
    }
    if let Some(a) = args.get("metrics-addr") {
        if !a.is_empty() {
            cfg.apply("metrics-addr", a)?;
        }
    }
    if let Some(p) = args.get("trace") {
        if !p.is_empty() {
            cfg.apply("trace", p)?;
        }
    }
    cfg.use_xla = !args.get_bool("no-xla");
    cfg.series = args.get_bool("series");
    Ok(cfg)
}

struct Backend {
    pool: ThreadPool,
    kalman: Option<BatchKalman>,
}

impl Backend {
    fn new(threads: usize, use_xla: bool, artifacts: &str) -> Self {
        let kalman = if use_xla {
            match XlaRuntime::cpu(artifacts) {
                Ok(rt) if rt.has_artifact("kalman3") => match BatchKalman::load(&rt) {
                    Ok(bk) => {
                        eprintln!("[lazycow] PJRT {} + kalman3 artifact", rt.platform());
                        Some(bk)
                    }
                    Err(e) => {
                        eprintln!("[lazycow] artifact load failed ({e}); CPU fallback");
                        None
                    }
                },
                _ => {
                    eprintln!("[lazycow] artifacts missing; CPU fallback (run `make artifacts`)");
                    None
                }
            }
        } else {
            None
        };
        Backend {
            pool: ThreadPool::new(threads),
            kalman,
        }
    }

    fn ctx(&self) -> StepCtx<'_> {
        StepCtx {
            pool: &self.pool,
            kalman: self.kalman.as_ref(),
            batch: true,
        }
    }

    /// Resolve the shard count K (`--shards 0` matches the worker thread
    /// count). The runtime dispatch is shard-aware — each shard-local run
    /// takes the batched step against the compiled artifact or the CPU
    /// oracle — so no K is pinned to keep an artifact active.
    fn choose_shards(&self, cfg: &RunConfig) -> usize {
        cfg.resolved_shards(self.pool.n_threads())
    }
}

fn cmd_run(args: &lazycow::cli::Args) -> Result<(), String> {
    let cfg = build_config(args)?;
    let backend = Backend::new(cfg.threads, cfg.use_xla, args.get_or("artifacts", "artifacts"));
    let k = backend.choose_shards(&cfg);
    let mut heap = ShardedHeap::with_allocator(cfg.mode, k, cfg.allocator);
    println!(
        "# {} K={k} rebalance={} steal={} allocator={} batch={}",
        cfg.label(),
        if k > 1 { cfg.rebalance.name() } else { "off" },
        if k > 1 && cfg.steal { "on" } else { "off" },
        cfg.allocator.name(),
        if cfg.batch { "on" } else { "off" }
    );
    let r = run_model(&cfg, &mut heap, &backend.ctx());
    println!(
        "log_evidence={:.4} posterior_mean={:.4} wall={:.3}s peak={} global_peak={} \
         scratch_peak={} migrations={} steals={} attempts={}",
        r.log_evidence,
        r.posterior_mean,
        r.wall_s,
        human_bytes(r.peak_bytes as f64),
        human_bytes(r.global_peak_bytes as f64),
        human_bytes(r.scratch_peak_bytes as f64),
        r.migrations,
        r.steals,
        r.attempts
    );
    let m = heap.metrics();
    println!("heap: {}", m.summary());
    if cfg.allocator == AllocatorKind::Slab {
        println!(
            "slab: hit_rate={:.3} fragmentation={:.3} committed={} decommitted={} ({} chunks, watermark {})",
            m.slab_hit_rate(),
            m.slab_fragmentation(),
            human_bytes(m.slab_committed_bytes as f64),
            human_bytes(m.decommitted_bytes as f64),
            m.decommitted_chunks,
            cfg.decommit_watermark
                .map(|w| w.to_string())
                .unwrap_or_else(|| "off".to_string()),
        );
        println!(
            "slab: los_live={} los_free={} evacuated={} objects ({} chunks, threshold {})",
            human_bytes(m.los_live_bytes as f64),
            human_bytes(m.los_free_bytes as f64),
            m.evacuated_objects,
            m.evacuated_chunks,
            cfg.evacuate_threshold
                .map(|t| format!("{t}"))
                .unwrap_or_else(|| "off".to_string()),
        );
    }
    if cfg.series {
        println!("t\telapsed_s\tlive_bytes\tpeak_bytes\tlive_objects\tess");
        for s in &r.series {
            println!(
                "{}\t{:.4}\t{}\t{}\t{}\t{:.1}",
                s.t, s.elapsed_s, s.live_bytes, s.peak_bytes, s.live_objects, s.ess
            );
        }
    }
    Ok(())
}

/// `serve`: many named [`FilterSession`]s — any model, any mix — over
/// one shared sharded heap, fed by the line protocol.
///
/// Verbs: `open <name> <model> [particles=N seed=S ess=X]`, `obs <name>
/// <tokens>`, `whatif <name> <tokens>[; <tokens>...]`, `fork <name>
/// <new>`, `telemetry <name>`, `finish <name>`, `close <name>`,
/// `finish-all`; `#`-comments and blanks are skipped, and every
/// malformed or unknown line gets a structured `err ...` reply instead
/// of killing the server. With `--listen addr:port` the same protocol
/// runs over TCP ([`lazycow::serve::serve_tcp`]); otherwise lines come
/// from stdin or `--input`, and EOF drains every open session like
/// `finish-all`. With `--metrics-addr host:port` a scrape responder
/// answers `GET /metrics` in the Prometheus exposition format for
/// either front-end. Protocol spec: `DESIGN.md`.
///
/// [`FilterSession`]: lazycow::smc::FilterSession
fn cmd_serve(args: &lazycow::cli::Args) -> Result<(), String> {
    use lazycow::serve::{serve_tcp, spawn_metrics, MetricsHub, ServeEngine};
    use std::sync::Arc;

    let cfg = build_config(args)?;
    let Backend { pool, kalman } =
        Backend::new(cfg.threads, cfg.use_xla, args.get_or("artifacts", "artifacts"));
    let listen = cfg.listen.clone();
    let metrics_addr = cfg.metrics_addr.clone();
    let engine = ServeEngine::new(cfg, pool, kalman);
    let hub = MetricsHub::new();
    // Bind the scrape responder before serving so a bad --metrics-addr
    // fails fast, not after sessions have opened.
    let responder = match metrics_addr.as_deref() {
        Some(addr) => Some(spawn_metrics(Arc::clone(&hub), addr)?),
        None => None,
    };
    let input = args.get("input").filter(|f| !f.is_empty());
    let result = match listen {
        Some(addr) => serve_tcp(engine, &addr, Arc::clone(&hub)),
        None => serve_stdin(engine, input, &hub),
    };
    hub.shutdown();
    if let Some(h) = responder {
        let _ = h.join();
    }
    result
}

/// The stdin/`--input` front-end: the same protocol loop as the TCP
/// server, one line in → reply lines on stdout, feeding the metrics hub
/// identically (request counters, latency, engine snapshot refresh) so
/// `/metrics` works over either transport.
fn serve_stdin(
    mut engine: lazycow::serve::ServeEngine,
    input: Option<&str>,
    hub: &lazycow::serve::MetricsHub,
) -> Result<(), String> {
    use lazycow::serve::{error_reason, verb_label, Verdict};
    use std::io::BufRead;

    println!("{}", engine.banner());
    hub.set_engine_snapshot(engine.render_metrics());
    let reader: Box<dyn BufRead> = match input {
        Some(f) => Box::new(std::io::BufReader::new(
            std::fs::File::open(f).map_err(|e| format!("--input {f}: {e}"))?,
        )),
        None => Box::new(std::io::BufReader::new(std::io::stdin())),
    };
    let mut drained = false;
    for line in reader.lines() {
        let line = line.map_err(|e| e.to_string())?;
        let verb = verb_label(&line);
        let t0 = std::time::Instant::now();
        let (lines, drain) = match engine.execute(&line) {
            Verdict::Silent => (Vec::new(), false),
            Verdict::Reply(l) => (l, false),
            Verdict::Drain(l) => (l, true),
        };
        if verb != "comment" {
            let reason = lines.last().and_then(|l| error_reason(l));
            hub.note_request(verb, t0.elapsed().as_secs_f64(), reason);
        }
        hub.set_engine_snapshot(engine.render_metrics());
        for l in lines {
            println!("{l}");
        }
        if drain {
            drained = true;
            break;
        }
    }
    hub.set_draining(true);
    if !drained {
        // EOF without finish-all: drain every open session anyway.
        for l in engine.finish_all() {
            println!("{l}");
        }
    }
    hub.set_engine_snapshot(engine.render_metrics());
    println!("heap: {}", engine.heap_summary());
    Ok(())
}

fn figure_cells(task: Task, args: &lazycow::cli::Args) -> Result<Vec<CellResult>, String> {
    let reps = args.get_usize("reps").unwrap_or(5);
    let backend = Backend::new(
        args.get_usize("threads").unwrap_or(0),
        !args.get_bool("no-xla"),
        args.get_or("artifacts", "artifacts"),
    );
    let paper = args.get_or("scale", "default") == "paper";
    let base_seed = args.get_u64("seed").unwrap_or(20200401);
    let mut cells = Vec::new();
    for model in Model::EVAL {
        for mode in CopyMode::ALL {
            let mut cfg = RunConfig::for_model(model, task, mode);
            if paper {
                let (n, t_inf, t_sim) = model.paper_scale();
                cfg.n_particles = n;
                cfg.n_steps = if task == Task::Inference { t_inf } else { t_sim };
            }
            cfg.seed = base_seed;
            // Figures reproduce the paper's single-heap baselines, whose
            // peak-memory numbers are exact only at K = 1 (the K > 1
            // aggregate is a sum of per-shard peaks and would vary with
            // the core count). An explicit --shards K opts in.
            cfg.shards = args.get_usize("shards").unwrap_or(0);
            if let Some(kind) = parse_allocator(args)? {
                cfg.allocator = kind;
            }
            let k = if cfg.shards == 0 { 1 } else { cfg.shards };
            let name = format!("{}/{}", model.name(), mode.name());
            let backend_ref = &backend;
            let cell = lazycow::bench::run_cell(&name, reps, |rep| {
                let mut c = cfg.clone();
                c.seed = base_seed.wrapping_add(rep as u64); // one seed per rep (§4)
                let mut heap = ShardedHeap::with_allocator(c.mode, k, c.allocator);
                let r = run_model(&c, &mut heap, &backend_ref.ctx());
                Some(r.peak_bytes as f64)
            });
            eprintln!("{}", cell.pretty_row());
            cells.push(cell);
        }
    }
    Ok(cells)
}

fn cmd_figure(task: Task, args: &lazycow::cli::Args) -> Result<(), String> {
    let which = if task == Task::Inference { 5 } else { 6 };
    println!(
        "# Figure {which}: {} task — median [Q1, Q3] over reps",
        task.name()
    );
    let cells = figure_cells(task, args)?;
    println!("{}", CellResult::tsv_header());
    for c in &cells {
        println!("{}", c.tsv_row());
    }
    Ok(())
}

fn cmd_fig7(args: &lazycow::cli::Args) -> Result<(), String> {
    let backend = Backend::new(
        args.get_usize("threads").unwrap_or(0),
        !args.get_bool("no-xla"),
        args.get_or("artifacts", "artifacts"),
    );
    let models: Vec<Model> = match args.get("model") {
        Some(m) if !m.is_empty() => vec![Model::parse(m).ok_or("bad --model")?],
        _ => Model::EVAL.to_vec(),
    };
    println!("# Figure 7: elapsed time and memory across t=1..T (inference)");
    println!("model\tmode\tt\telapsed_s\tlive_bytes\tpeak_bytes\tlive_objects");
    for model in models {
        for mode in CopyMode::ALL {
            let mut cfg = RunConfig::for_model(model, Task::Inference, mode);
            if args.get_or("scale", "default") == "paper" {
                let (n, t_inf, _) = model.paper_scale();
                cfg.n_particles = n;
                cfg.n_steps = t_inf;
            }
            // Single-heap baseline by default (exact peak memory); an
            // explicit --shards K opts in to the sharded engine.
            cfg.shards = args.get_usize("shards").unwrap_or(0);
            if let Some(kind) = parse_allocator(args)? {
                cfg.allocator = kind;
            }
            let k = if cfg.shards == 0 { 1 } else { cfg.shards };
            let mut heap = ShardedHeap::with_allocator(mode, k, cfg.allocator);
            let r = run_model(&cfg, &mut heap, &backend.ctx());
            for s in &r.series {
                println!(
                    "{}\t{}\t{}\t{:.4}\t{}\t{}\t{}",
                    model.name(),
                    mode.name(),
                    s.t,
                    s.elapsed_s,
                    s.live_bytes,
                    s.peak_bytes,
                    s.live_objects
                );
            }
        }
    }
    Ok(())
}

/// Figure 2 / Jacob et al. (2015): reachable ancestry objects stay below
/// t + c·N·log N.
fn cmd_tree_bound(args: &lazycow::cli::Args) -> Result<(), String> {
    use lazycow::models::ListModel;
    use lazycow::smc::{run_filter, Method};
    let n = args.get_usize("particles").unwrap_or(256);
    let t_max = args.get_usize("steps").unwrap_or(200);
    let backend = Backend::new(1, false, "artifacts");
    let model = ListModel::synthetic(t_max, lazycow::models::DATA_SEED);
    let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
    cfg.n_particles = n;
    cfg.n_steps = t_max;
    let mut heap = Heap::new(CopyMode::LazySro);
    let r = run_filter(&model, &cfg, &mut heap, &backend.ctx(), Method::Bootstrap);
    let bound = |t: f64| t + 2.0 * (n as f64) * (n as f64).ln();
    println!("# reachable live objects vs t + 2·N·ln N (N={n})");
    println!("t\tlive_objects\tbound");
    for s in r.series.iter().step_by((t_max / 20).max(1)) {
        println!("{}\t{}\t{:.0}", s.t, s.live_objects, bound(s.t as f64));
    }
    let Some(last) = r.series.last() else {
        return Err(
            "tree-bound ran zero generations (--steps 0): nothing to bound; pass --steps >= 1"
                .into(),
        );
    };
    println!(
        "# final: {} live objects, bound {:.0}, dense would be {}",
        last.live_objects,
        bound(t_max as f64),
        n * t_max
    );
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested) => {
            print!("{}", cli.help_text());
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{}", cli.help_text());
            std::process::exit(2);
        }
    };
    let r = match args.command.as_deref() {
        Some("run") | None => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("fig5") => cmd_figure(Task::Inference, &args),
        Some("fig6") => cmd_figure(Task::Simulation, &args),
        Some("fig7") => cmd_fig7(&args),
        Some("tree-bound") => cmd_tree_bound(&args),
        Some(c) => Err(format!("unknown command {c}")),
    };
    if let Err(e) = r {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
