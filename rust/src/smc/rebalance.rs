//! Cost-driven particle rebalancing over heap shards.
//!
//! The paper's motivating workloads carry populations of objects of
//! *random, possibly unbounded* size (derivation stacks, track arrays,
//! trees), so per-particle propagation cost is heavy-tailed and a static
//! contiguous partition of particles over shards leaves some shards idle
//! while others grind. This module closes that gap with three pieces:
//!
//! 1. **Cost accounting** ([`CostTracker`]): a per-particle EWMA cost
//!    estimate fed by *exact* per-particle measurements — each particle's
//!    propagation is bracketed in a heap metrics scope
//!    ([`Heap::begin_scope`](crate::heap::Heap::begin_scope)), yielding
//!    its wall time plus a charge per heap operation (allocs, copies,
//!    pulls) from the exact
//!    [`HeapMetrics`](crate::heap::HeapMetrics) delta. Where only a
//!    batch-granular measurement exists (a thief's stolen batch), the
//!    cost is apportioned within the batch by the model's
//!    [`cost_hint`](crate::smc::SmcModel::cost_hint) (e.g. PCFG stack
//!    depth, MOT track count) — the hint fallback. Offspring inherit
//!    their ancestor's estimate at resampling.
//! 2. **Planning** ([`plan_offspring`]): at each resampling step a greedy
//!    longest-processing-time pass assigns offspring to shards, biased to
//!    keep offspring on their ancestor's shard and migrating only when
//!    the predicted imbalance exceeds a configurable threshold. The
//!    `Budget` policy additionally requires the predicted gain to exceed
//!    a migration cost modeled from the ancestor's reachable-subgraph
//!    size (the same subgraph `extract_into` traverses).
//! 3. **Execution** (in `smc::filter`): the plan groups all cross-shard
//!    offspring of one ancestor per destination into a single transplant,
//!    and pairwise-disjoint (src, dst) transplants run concurrently via
//!    [`ThreadPool::for_pairs`](crate::pool::ThreadPool::for_pairs).
//!
//! **Determinism.** Rebalancing only moves *where* heap work runs, never
//! what is computed: RNG streams are keyed by global particle index and
//! all weight reductions run in global index order, so the filter output
//! is bit-identical for every shard count and every policy — including
//! `Off`, which reproduces the static contiguous partition exactly.

use crate::heap::shard_of;
use std::collections::{BTreeSet, HashMap};

/// Estimated seconds charged per heap operation (alloc / copy / pull) on
/// top of the measured wall time, so op-heavy generations register even
/// when the clock resolution is coarse.
pub const OP_COST_S: f64 = 2e-8;

/// Estimated seconds per transplanted object (the per-object cost of the
/// `extract_into` walk + allocation in the destination shard), used by
/// the `Budget` policy's migration-cost model.
pub const TRANSPLANT_COST_S: f64 = 2e-7;

/// Floor applied to model cost hints wherever a measured cost is
/// apportioned among particles, so zero/negative hints cannot zero a
/// denominator or erase a particle's share. One constant, shared by every
/// apportionment site (tracker update, steal-path scatter, alive rounds).
pub const HINT_FLOOR: f64 = 1e-12;

/// Offspring-to-shard assignment policy applied at each resampling step.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RebalancePolicy {
    /// Static contiguous partition (the pre-rebalancing behaviour).
    Off,
    /// Greedy LPT with ancestor-shard stickiness: migrate whenever the
    /// predicted imbalance exceeds the threshold.
    Greedy,
    /// Greedy LPT that additionally charges each new transplant its
    /// modeled migration cost: migrate only when the predicted gain
    /// exceeds the cost of moving the ancestor's reachable subgraph.
    Budget,
}

impl RebalancePolicy {
    /// Parse a policy name as accepted by `--rebalance`.
    pub fn parse(s: &str) -> Option<RebalancePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "static" | "none" => Some(RebalancePolicy::Off),
            "greedy" => Some(RebalancePolicy::Greedy),
            "budget" => Some(RebalancePolicy::Budget),
            _ => None,
        }
    }

    /// Canonical name (CLI/bench labels).
    pub fn name(self) -> &'static str {
        match self {
            RebalancePolicy::Off => "off",
            RebalancePolicy::Greedy => "greedy",
            RebalancePolicy::Budget => "budget",
        }
    }

    /// Every policy (test sweeps).
    pub const ALL: [RebalancePolicy; 3] = [
        RebalancePolicy::Off,
        RebalancePolicy::Greedy,
        RebalancePolicy::Budget,
    ];
}

/// EWMA weight applied to a particle's fresh measurement when the particle
/// was stolen this generation: a steal is direct evidence the planner's
/// estimate for it (or its shard) was off, so the tracker adapts faster.
pub const STEAL_ALPHA: f64 = 0.8;

/// Per-particle propagation-cost estimates (EWMA over generations).
///
/// Costs start at zero, so the first resampling step plans the static
/// sticky assignment; estimates sharpen as measured generations arrive.
///
/// The tracker also learns from the work-stealing executor: particles
/// flagged stolen ([`CostTracker::note_stolen`]) fold their next
/// measurement in with the boosted [`STEAL_ALPHA`] — a steal means the
/// current estimate under-predicted the particle's (or its shard's) load,
/// so the fresh, thief-measured cost should dominate the stale prior.
///
/// `Clone` supports session forking ([`crate::smc::FilterSession::fork`]):
/// a forked population inherits the parent's learned cost estimates, so
/// its first resampling barrier plans from the same evidence the parent
/// would have used.
#[derive(Clone)]
pub struct CostTracker {
    costs: Vec<f64>,
    stolen: Vec<bool>,
    alpha: f64,
    steal_events: usize,
}

impl CostTracker {
    /// A tracker for `n` particle slots with zeroed estimates.
    pub fn new(n: usize) -> Self {
        CostTracker {
            costs: vec![0.0; n],
            stolen: vec![false; n],
            alpha: 0.5,
            steal_events: 0,
        }
    }

    /// Current per-particle cost estimates (indexed by global particle
    /// slot).
    pub fn costs(&self) -> &[f64] {
        &self.costs
    }

    /// Total particles stolen over the tracker's lifetime (one event per
    /// stolen particle per generation).
    pub fn steal_events(&self) -> usize {
        self.steal_events
    }

    /// Resampling: offspring slot `i` inherits ancestor `anc[i]`'s cost.
    /// Steal flags are per-generation signals and reset.
    pub fn inherit(&mut self, anc: &[usize]) {
        let new: Vec<f64> = anc.iter().map(|&a| self.costs[a]).collect();
        for (c, v) in self.costs.iter_mut().zip(new) {
            *c = v;
        }
        self.stolen.iter_mut().for_each(|s| *s = false);
    }

    /// Record that slot `i` was stolen this generation: its next folded
    /// measurement uses [`STEAL_ALPHA`].
    pub fn note_stolen(&mut self, i: usize) {
        self.stolen[i] = true;
        self.steal_events += 1;
    }

    /// Fold direct per-particle cost measurements (the work-stealing
    /// executor's output: home-shard costs apportioned over the particles
    /// the home worker actually processed, thief-measured costs for stolen
    /// batches). Non-finite or negative entries mean "no measurement for
    /// this slot" and leave the estimate untouched. Consumes (and clears)
    /// the stolen flags.
    pub fn fold(&mut self, raw: &[f64]) {
        debug_assert!(raw.len() <= self.costs.len());
        for (i, &r) in raw.iter().enumerate() {
            if !r.is_finite() || r < 0.0 {
                continue;
            }
            let a = if self.stolen[i] { STEAL_ALPHA } else { self.alpha };
            self.costs[i] = (1.0 - a) * self.costs[i] + a * r;
            self.stolen[i] = false;
        }
    }

    /// Fold one measured generation back into the estimates by
    /// *hint apportioning* — the fallback cost feed for callers that only
    /// have shard-granular measurements. (The engine's propagation paths
    /// now measure per particle with heap metrics scopes and use
    /// [`CostTracker::fold`] directly; hint apportioning remains for
    /// batch-granular measurements such as stolen batches, and for
    /// external callers without scopes.) `assign[i]` is particle `i`'s
    /// shard, `shard_cost[s]` the measured cost of shard `s`'s generation
    /// (seconds + op charge), and `hints[i]` the model's relative
    /// per-particle weight used to apportion a shard's cost among its
    /// particles. Slices may cover a prefix of the population (particle
    /// Gibbs pins the last slot); untouched slots keep their previous
    /// estimate.
    pub fn update(&mut self, assign: &[usize], shard_cost: &[f64], hints: &[f64]) {
        debug_assert_eq!(assign.len(), hints.len());
        let k = shard_cost.len();
        let mut hint_sum = vec![0.0f64; k];
        for (i, &s) in assign.iter().enumerate() {
            hint_sum[s] += hints[i].max(HINT_FLOOR);
        }
        for (i, &s) in assign.iter().enumerate() {
            if hint_sum[s] <= 0.0 || !shard_cost[s].is_finite() {
                continue;
            }
            let raw = shard_cost[s] * hints[i].max(HINT_FLOOR) / hint_sum[s];
            self.costs[i] = (1.0 - self.alpha) * self.costs[i] + self.alpha * raw;
        }
    }
}

/// Result of [`plan_offspring`]: the shard each offspring lands on, and
/// the number of distinct (ancestor, destination) transplants the plan
/// requires beyond the static stickiness baseline.
pub struct OffspringPlan {
    /// Destination shard per offspring slot.
    pub assign: Vec<usize>,
    /// Distinct (ancestor, destination) transplants the plan adds.
    pub transplant_pairs: usize,
}

/// Plan the offspring → shard assignment for one resampling step.
///
/// `anc[i]` is offspring `i`'s ancestor, `parent_shard[a]` the shard the
/// ancestor currently lives on, `cost[a]` the predicted cost of one of
/// its offspring (the ancestor's [`CostTracker`] estimate), and
/// `migration_cost(a)` the modeled one-off cost of transplanting the
/// ancestor's lineage to a new shard (consulted lazily, `Budget` only).
///
/// The pass walks offspring in descending predicted cost (LPT) and
/// assigns each to its ancestor's shard unless the load gap to the
/// least-loaded shard exceeds `threshold` × mean shard load — in which
/// case it migrates (for `Budget`, only if the gap also exceeds the
/// migration cost, unless a transplant of the same ancestor to the same
/// destination is already planned and the marginal cost is zero). Fully
/// deterministic given its inputs: ties break on the lowest shard index
/// and the stable offspring order.
pub fn plan_offspring(
    policy: RebalancePolicy,
    threshold: f64,
    anc: &[usize],
    parent_shard: &[usize],
    cost: &[f64],
    k: usize,
    mut migration_cost: impl FnMut(usize) -> f64,
) -> OffspringPlan {
    let n = anc.len();
    if k <= 1 || policy == RebalancePolicy::Off {
        return OffspringPlan {
            assign: (0..n).map(|i| shard_of(n, k, i)).collect(),
            transplant_pairs: 0,
        };
    }
    // LPT order: offspring by descending predicted cost, stable on index.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        cost[anc[b]]
            .partial_cmp(&cost[anc[a]])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let total: f64 = anc.iter().map(|&a| cost[a].max(0.0)).sum();
    let mean_load = total / k as f64;
    let mut loads = vec![0.0f64; k];
    let mut assign = vec![0usize; n];
    let mut planned: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut mig_cache: HashMap<usize, f64> = HashMap::new();
    for i in order {
        let a = anc[i];
        let home = parent_shard[a];
        let c = cost[a].max(0.0);
        let best = (0..k)
            .min_by(|&x, &y| {
                loads[x]
                    .partial_cmp(&loads[y])
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap_or(0);
        let gap = loads[home] - loads[best];
        let migrate = best != home
            && gap > threshold * mean_load
            && match policy {
                RebalancePolicy::Greedy => true,
                RebalancePolicy::Budget => {
                    // A transplant already planned for (a, best) makes this
                    // offspring's migration marginally free (it reuses the
                    // transplanted lineage with an O(1) lazy copy).
                    planned.contains(&(a, best)) || {
                        let mc = *mig_cache
                            .entry(a)
                            .or_insert_with(|| migration_cost(a));
                        gap > mc
                    }
                }
                RebalancePolicy::Off => unreachable!(),
            };
        let dst = if migrate { best } else { home };
        if dst != home {
            planned.insert((a, dst));
        }
        assign[i] = dst;
        loads[dst] += c;
    }
    OffspringPlan {
        transplant_pairs: planned.len(),
        assign,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrip() {
        for p in RebalancePolicy::ALL {
            assert_eq!(RebalancePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(RebalancePolicy::parse("static"), Some(RebalancePolicy::Off));
        assert_eq!(RebalancePolicy::parse("nope"), None);
    }

    #[test]
    fn off_policy_is_static_partition() {
        let anc = vec![0usize, 0, 1, 2, 3, 3];
        let parent_shard = vec![0usize, 0, 0, 1, 1, 1];
        let cost = vec![1.0; 6];
        let plan = plan_offspring(
            RebalancePolicy::Off,
            0.25,
            &anc,
            &parent_shard,
            &cost,
            2,
            |_| 0.0,
        );
        assert_eq!(plan.transplant_pairs, 0);
        for (i, &s) in plan.assign.iter().enumerate() {
            assert_eq!(s, shard_of(6, 2, i));
        }
    }

    #[test]
    fn zero_costs_stay_sticky() {
        // Before any measurement (all costs zero) nothing migrates.
        let anc = vec![0usize, 0, 0, 0, 3, 3];
        let parent_shard = vec![0usize, 0, 0, 1, 1, 1];
        let cost = vec![0.0; 6];
        let plan = plan_offspring(
            RebalancePolicy::Greedy,
            0.25,
            &anc,
            &parent_shard,
            &cost,
            2,
            |_| 0.0,
        );
        assert_eq!(plan.transplant_pairs, 0);
        assert!(plan.assign.iter().take(4).all(|&s| s == 0));
        assert!(plan.assign.iter().skip(4).all(|&s| s == 1));
    }

    #[test]
    fn greedy_migrates_under_skew() {
        // One heavy ancestor on shard 0 spawns every offspring; greedy
        // must spread the load across both shards.
        let n = 8;
        let anc = vec![0usize; n];
        let parent_shard = vec![0usize; n];
        let mut cost = vec![0.0; n];
        cost[0] = 1.0;
        let plan = plan_offspring(
            RebalancePolicy::Greedy,
            0.1,
            &anc,
            &parent_shard,
            &cost,
            2,
            |_| 0.0,
        );
        let on0 = plan.assign.iter().filter(|&&s| s == 0).count();
        let on1 = n - on0;
        assert_eq!(on0, on1, "load must split evenly: {:?}", plan.assign);
        assert_eq!(plan.transplant_pairs, 1, "one (ancestor, dst) pair");
    }

    #[test]
    fn budget_blocks_expensive_migrations() {
        let n = 8;
        let anc = vec![0usize; n];
        let parent_shard = vec![0usize; n];
        let mut cost = vec![0.0; n];
        cost[0] = 1.0;
        // Migration cost dwarfs any gap: everything stays home.
        let plan = plan_offspring(
            RebalancePolicy::Budget,
            0.1,
            &anc,
            &parent_shard,
            &cost,
            2,
            |_| 1e9,
        );
        assert!(plan.assign.iter().all(|&s| s == 0));
        assert_eq!(plan.transplant_pairs, 0);
        // Free migration behaves like greedy.
        let plan = plan_offspring(
            RebalancePolicy::Budget,
            0.1,
            &anc,
            &parent_shard,
            &cost,
            2,
            |_| 0.0,
        );
        assert_eq!(plan.transplant_pairs, 1);
    }

    #[test]
    fn tracker_inherits_and_updates() {
        let mut t = CostTracker::new(4);
        t.update(&[0, 0, 1, 1], &[4.0, 8.0], &[1.0, 3.0, 1.0, 1.0]);
        // Shard 0's cost 4.0 splits 1:3; shard 1's cost 8.0 splits 1:1.
        let c = t.costs().to_vec();
        assert!((c[0] - 0.5).abs() < 1e-12, "{c:?}");
        assert!((c[1] - 1.5).abs() < 1e-12, "{c:?}");
        assert!((c[2] - 2.0).abs() < 1e-12, "{c:?}");
        assert!((c[3] - 2.0).abs() < 1e-12, "{c:?}");
        // Offspring of particle 1 everywhere.
        t.inherit(&[1, 1, 1, 1]);
        assert!(t.costs().iter().all(|&x| (x - 1.5).abs() < 1e-12));
    }

    #[test]
    fn tracker_ignores_non_finite_measurements() {
        let mut t = CostTracker::new(2);
        t.update(&[0, 1], &[f64::NAN, 2.0], &[1.0, 1.0]);
        assert_eq!(t.costs()[0], 0.0);
        assert!((t.costs()[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fold_applies_direct_measurements_and_skips_unmeasured() {
        let mut t = CostTracker::new(3);
        t.fold(&[2.0, f64::NAN, -1.0]);
        assert!((t.costs()[0] - 1.0).abs() < 1e-12, "alpha 0.5 of 2.0");
        assert_eq!(t.costs()[1], 0.0, "NAN = no measurement");
        assert_eq!(t.costs()[2], 0.0, "negative = no measurement");
        // A shorter (prefix) slice is allowed — particle Gibbs measures
        // only the free slots.
        t.fold(&[2.0]);
        assert!((t.costs()[0] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn stolen_particles_adapt_faster_then_reset() {
        let mut t = CostTracker::new(2);
        t.note_stolen(0);
        assert_eq!(t.steal_events(), 1);
        t.fold(&[1.0, 1.0]);
        // Slot 0 folded with STEAL_ALPHA, slot 1 with the default alpha.
        assert!((t.costs()[0] - STEAL_ALPHA).abs() < 1e-12, "{:?}", t.costs());
        assert!((t.costs()[1] - 0.5).abs() < 1e-12);
        // The flag is consumed: a second fold uses the default alpha again.
        let c0 = t.costs()[0];
        t.fold(&[c0, f64::NAN]);
        assert!((t.costs()[0] - c0).abs() < 1e-12, "steady state at default alpha");
        // inherit clears pending flags too.
        t.note_stolen(1);
        t.inherit(&[0, 0]);
        t.fold(&[f64::NAN, 1.0]);
        assert!(
            (t.costs()[1] - (0.5 * c0 + 0.5)).abs() < 1e-12,
            "flag cleared by inherit: default alpha applies"
        );
    }
}
