//! The population coordinator: particle filters over the sharded lazy heap.
//!
//! Implements the paper's §1 bootstrap filter plus the method variants its
//! evaluation uses — auxiliary PF (PCFG), alive PF (CRBD), and particle
//! Gibbs with a reference trajectory (VBD). Resampling performs one
//! `deep_copy` per offspring (O(1) in lazy modes, O(history) in eager mode
//! — the paper's Figure 7 quadratic/linear time contrast), releases dead
//! lineages, and sweeps memos once per generation.
//!
//! **Sharded execution.** The engine operates on `&mut [Heap]` — K
//! independent heap shards with particles partitioned contiguously
//! ([`shard_ranges`]). Per-generation propagation runs shard-parallel on
//! the thread pool: each worker holds `&mut` to exactly one shard, so the
//! allocate/copy/mutate hot path needs no locks and no atomics. At
//! resampling, offspring whose ancestor lives on the same shard take the
//! O(1) lazy [`Heap::deep_copy`]; offspring assigned across shards take a
//! cross-shard lineage transplant ([`Heap::extract_into`]). All RNG
//! streams are keyed by *global* particle index and all weight reductions
//! run in global index order, so the numeric output (`log_evidence`,
//! `posterior_mean`) is bit-identical for every K — and K = 1 reproduces
//! the pre-sharding single-heap engine exactly.
//!
//! The alive PF remains coordinator-serial (its retry RNG stream depends
//! on the cumulative attempt count across particles); since sharding
//! would buy it no parallelism while making the O(history) transplant
//! the common case on retries, its population is collapsed onto shard 0.
//! With K > 1 the per-shard `step_population` runs with a serial pool and
//! without the XLA batch artifact (the batched runtime is not
//! shard-aware yet); K = 1 keeps the full batched path.

use super::model::{particle_rng, resample_rng, SmcModel, StepCtx};
use super::resample::Resampler;
use crate::config::{RunConfig, Task};
use crate::heap::{aggregate_metrics, shard_of, shard_ranges, Heap, Lazy};
use crate::pool::ThreadPool;
use crate::stats::{ess, log_sum_exp, normalize_log_weights};
use std::time::Instant;

/// Per-generation metrics snapshot (Figure 7 series), aggregated across
/// shards.
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub t: usize,
    /// Cumulative wall time since filter start (seconds).
    pub elapsed_s: f64,
    /// Heap footprint after this generation (bytes; exact — summed
    /// per-shard gauges refer to the same instant).
    pub live_bytes: usize,
    /// High-water mark so far (bytes). With K > 1 shards this is the sum
    /// of per-shard peaks — a conservative upper bound on the true
    /// simultaneous peak, since shards need not peak at the same moment
    /// (snapshot-based maxima would instead *miss* the intra-generation
    /// resampling spikes that dominate eager-mode peaks). K = 1 — all
    /// figure baselines — is exact.
    pub peak_bytes: usize,
    pub live_objects: usize,
    pub lazy_copies: usize,
    pub eager_copies: usize,
    pub ess: f64,
}

/// Filter output: evidence estimate, posterior summary, and metrics.
#[derive(Clone, Debug)]
pub struct FilterResult {
    pub log_evidence: f64,
    /// Weighted posterior mean of the model summary at the final
    /// generation (the cross-configuration output check).
    pub posterior_mean: f64,
    pub wall_s: f64,
    /// Peak heap bytes; with K > 1 an upper bound (sum of per-shard
    /// peaks — see [`StepMetrics::peak_bytes`]), exact at K = 1.
    pub peak_bytes: usize,
    pub series: Vec<StepMetrics>,
    /// Alive PF: total propagation attempts (N·T when every particle
    /// survives immediately).
    pub attempts: usize,
}

/// Inference method, per §4.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Method {
    Bootstrap,
    Auxiliary,
    Alive,
}

/// One shard's slice of the propagation work: the heap, the shard's
/// contiguous particle chunk, its log-weight chunk, and the global index
/// of the chunk's first particle.
struct ShardTask<'a, S> {
    heap: &'a mut Heap,
    states: &'a mut [Lazy<S>],
    lw: &'a mut [f64],
    base: usize,
}

/// Split (shards, states, lw) into per-shard [`ShardTask`]s following
/// `ranges`. `ranges` must be contiguous from 0 and sum to the slice
/// lengths.
fn make_tasks<'a, S>(
    shards: &'a mut [Heap],
    states: &'a mut [Lazy<S>],
    lw: &'a mut [f64],
    ranges: &[std::ops::Range<usize>],
) -> Vec<ShardTask<'a, S>> {
    let mut tasks = Vec::with_capacity(ranges.len());
    let mut shards = shards;
    let mut states = states;
    let mut lw = lw;
    for r in ranges {
        let (heap, shard_tail) = std::mem::take(&mut shards)
            .split_first_mut()
            .expect("more ranges than shards");
        shards = shard_tail;
        let len = r.end - r.start;
        let (s_chunk, s_tail) = std::mem::take(&mut states).split_at_mut(len);
        states = s_tail;
        let (w_chunk, w_tail) = std::mem::take(&mut lw).split_at_mut(len);
        lw = w_tail;
        tasks.push(ShardTask {
            heap,
            states: s_chunk,
            lw: w_chunk,
            base: r.start,
        });
    }
    tasks
}

fn step_snapshot(shards: &[Heap], t: usize, start: &Instant, w: &[f64]) -> StepMetrics {
    let agg = aggregate_metrics(shards);
    StepMetrics {
        t,
        elapsed_s: start.elapsed().as_secs_f64(),
        live_bytes: agg.current_bytes(),
        peak_bytes: agg.peak_bytes,
        live_objects: agg.live_objects,
        lazy_copies: agg.lazy_copies,
        eager_copies: agg.eager_copies,
        ess: ess(w),
    }
}

/// Draw the initial population, shard-parallel (per-particle RNG streams
/// make the draw order immaterial).
fn init_population<M: SmcModel + Sync>(
    model: &M,
    shards: &mut [Heap],
    pool: &ThreadPool,
    n: usize,
    seed: u64,
) -> Vec<Lazy<M::State>> {
    let mut states: Vec<Lazy<M::State>> = vec![Lazy::NULL; n];
    let mut scratch = vec![0.0f64; n];
    let ranges = shard_ranges(n, shards.len());
    let mut tasks = make_tasks(shards, &mut states, &mut scratch, &ranges);
    pool.for_shards(&mut tasks, |_, task| {
        for (j, slot) in task.states.iter_mut().enumerate() {
            let mut rng = particle_rng(seed, 0, task.base + j);
            *slot = model.init(task.heap, &mut rng);
        }
    });
    drop(tasks);
    states
}

/// Propagate + weight a prefix (`states.len() <= full_n`) of the
/// population, shard-parallel. Weight increments are added into `lw` in
/// place. `full_n` fixes the partition so prefix propagation (particle
/// Gibbs pins the last slot) stays shard-aligned.
#[allow(clippy::too_many_arguments)]
fn propagate_prefix<M: SmcModel + Sync>(
    model: &M,
    shards: &mut [Heap],
    states: &mut [Lazy<M::State>],
    lw: &mut [f64],
    full_n: usize,
    t: usize,
    seed: u64,
    observe: bool,
    ctx: &StepCtx,
) {
    debug_assert_eq!(states.len(), lw.len());
    if shards.len() == 1 {
        // Single shard: the pre-sharding path, with the full batched
        // context (XLA artifact + intra-generation numeric parallelism).
        let winc = model.step_population(&mut shards[0], states, t, seed, observe, 0, ctx);
        for (w, d) in lw.iter_mut().zip(winc) {
            *w += d;
        }
        return;
    }
    let m = states.len();
    let k = shards.len();
    let ranges: Vec<std::ops::Range<usize>> = shard_ranges(full_n, k)
        .into_iter()
        .map(|r| r.start.min(m)..r.end.min(m))
        .collect();
    // Split the worker budget across shards so a shard count below the
    // thread count does not shrink total numeric-phase parallelism
    // (models like RBPF fan their numeric phase out on the given pool;
    // per-particle RNG streams keep results invariant to the chunking).
    let per_shard_threads = (ctx.pool.n_threads() / k).max(1);
    let mut tasks = make_tasks(shards, states, lw, &ranges);
    ctx.pool.for_shards(&mut tasks, |_, task| {
        if task.states.is_empty() {
            return;
        }
        // Each worker owns one shard outright; the shard's numeric phase
        // gets its slice of the thread budget and runs on the CPU oracle
        // path (the batched XLA runtime is not shard-aware).
        let local = ThreadPool::new(per_shard_threads);
        let shard_ctx = StepCtx {
            pool: &local,
            kalman: None,
        };
        let winc = model.step_population(
            task.heap,
            task.states,
            t,
            seed,
            observe,
            task.base,
            &shard_ctx,
        );
        for (w, d) in task.lw.iter_mut().zip(winc) {
            *w += d;
        }
    });
}

/// Disjoint `&mut` access to two different shards.
fn pair_mut<T>(xs: &mut [T], a: usize, b: usize) -> (&mut T, &mut T) {
    debug_assert_ne!(a, b);
    if a < b {
        let (lo, hi) = xs.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = xs.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}

/// Replace the population by the offspring given by `anc` (one O(1)
/// `deep_copy` per same-shard offspring, one transplant per *distinct*
/// (ancestor, destination-shard) pair), release the parent generation,
/// and sweep memos.
fn resample_population<S: crate::heap::Payload>(
    shards: &mut [Heap],
    states: &mut Vec<Lazy<S>>,
    anc: &[usize],
) {
    let n = states.len();
    let k = shards.len();
    debug_assert_eq!(anc.len(), n);
    // Systematic resampling hands out *runs* of duplicate offspring; an
    // ancestor crossing a shard boundary is transplanted once per
    // destination shard and the remaining duplicates take lazy O(1)
    // copies of that transplant (sharing structure within the
    // destination). BTreeMap keeps the release order deterministic.
    let mut transplanted: std::collections::BTreeMap<(usize, usize), Lazy<S>> =
        std::collections::BTreeMap::new();
    let mut new_states: Vec<Lazy<S>> = Vec::with_capacity(n);
    for (i, &a) in anc.iter().enumerate() {
        let si = shard_of(n, k, i);
        let sa = shard_of(n, k, a);
        let child = if si == sa {
            let parent = states[a];
            shards[si].deep_copy(&parent)
        } else if let Some(first) = transplanted.get(&(a, si)).copied() {
            shards[si].deep_copy(&first)
        } else {
            let parent = states[a];
            let (src, dst) = pair_mut(shards, sa, si);
            let moved = src.extract_into(&parent, dst);
            let child = dst.deep_copy(&moved);
            transplanted.insert((a, si), moved);
            child
        };
        new_states.push(child);
    }
    for ((_, si), h) in transplanted {
        shards[si].release(h);
    }
    let old = std::mem::replace(states, new_states);
    for (i, s) in old.into_iter().enumerate() {
        shards[shard_of(n, k, i)].release(s);
    }
    for h in shards.iter_mut() {
        h.sweep_memos();
    }
}

/// Run a particle filter (or forward simulation) for `cfg` over `model`
/// on a single heap — the K = 1 specialization of
/// [`run_filter_shards`].
pub fn run_filter<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    heap: &mut Heap,
    ctx: &StepCtx,
    method: Method,
) -> FilterResult {
    run_filter_shards(model, cfg, std::slice::from_mut(heap), ctx, method)
}

/// Run a particle filter (or forward simulation) over `shards.len()`
/// heap shards. Output is seed-deterministic and identical for every
/// shard count.
pub fn run_filter_shards<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    shards: &mut [Heap],
    ctx: &StepCtx,
    method: Method,
) -> FilterResult {
    assert!(!shards.is_empty(), "at least one heap shard");
    // The alive PF is coordinator-serial (its retry RNG stream depends on
    // the cumulative attempt count), so sharding buys no parallelism there
    // — and a sharded layout would make the O(history) cross-shard
    // transplant the common case on retries (each retry draws a uniform
    // ancestor, so (K-1)/K of draws would cross), reintroducing the eager
    // copying cost the lazy platform exists to avoid. Keep its population
    // on shard 0; outputs are K-invariant either way.
    let shards = if method == Method::Alive {
        &mut shards[..1]
    } else {
        shards
    };
    let n = cfg.n_particles;
    let k = shards.len();
    let t_max = cfg.n_steps.min(model.horizon());
    let observe = cfg.task == Task::Inference;
    let resampler = Resampler::Systematic;
    let start = Instant::now();

    // Initialize.
    let mut states = init_population(model, shards, ctx.pool, n, cfg.seed);
    let mut lw = vec![0.0f64; n];
    let mut log_z = 0.0f64;
    let mut series = Vec::new();
    let mut w = Vec::with_capacity(n);
    let mut attempts = 0usize;

    for t in 1..=t_max {
        // --- Resample (inference only; simulation performs no copies). ---
        if observe {
            normalize_log_weights(&lw, &mut w);
            let cur_ess = ess(&w);
            if cur_ess < cfg.ess_threshold * n as f64 {
                let mut rrng = resample_rng(cfg.seed, t);
                // Auxiliary stage: bias resampling by lookahead scores.
                let ancestors = if method == Method::Auxiliary {
                    let mut aux = vec![0.0f64; n];
                    let mut any = false;
                    for (i, aux_i) in aux.iter_mut().enumerate() {
                        let si = shard_of(n, k, i);
                        let mut s = states[i];
                        if let Some(la) = model.lookahead(&mut shards[si], &mut s, t) {
                            *aux_i = la;
                            any = true;
                        }
                        states[i] = s;
                    }
                    if any {
                        let alw: Vec<f64> =
                            lw.iter().zip(&aux).map(|(a, b)| a + b).collect();
                        let mut aw = Vec::new();
                        normalize_log_weights(&alw, &mut aw);
                        let anc = resampler.ancestors(&mut rrng, &aw, n);
                        // First-stage correction: w ∝ 1 / lookahead(a).
                        log_z += log_sum_exp(&alw) - (n as f64).ln();
                        resample_population(shards, &mut states, &anc);
                        for (i, &a) in anc.iter().enumerate() {
                            lw[i] = -aux[a];
                        }
                        None
                    } else {
                        Some(resampler.ancestors(&mut rrng, &w, n))
                    }
                } else {
                    Some(resampler.ancestors(&mut rrng, &w, n))
                };
                if let Some(anc) = ancestors {
                    log_z += log_sum_exp(&lw) - (n as f64).ln();
                    resample_population(shards, &mut states, &anc);
                    lw.iter_mut().for_each(|x| *x = 0.0);
                }
            }
        }

        // --- Propagate + weight. ---
        match method {
            Method::Alive if observe => {
                // Alive PF: re-propose each slot until it survives, drawing
                // a fresh ancestor per attempt (Del Moral et al. 2015).
                // Resampling above has already equalized weights. The
                // whole population lives on shard 0 (see the collapse at
                // function entry), so every retry is an O(1) lazy copy.
                debug_assert_eq!(k, 1);
                let heap = &mut shards[0];
                let parents = std::mem::take(&mut states);
                let mut survivors = Vec::with_capacity(n);
                for i in 0..n {
                    let mut attempt = 0usize;
                    loop {
                        let mut rng = particle_rng(
                            cfg.seed,
                            t,
                            i + attempt * n + attempts, // fresh stream per retry
                        );
                        let a = if attempt == 0 {
                            i
                        } else {
                            rng.below(n as u64) as usize
                        };
                        let mut child = heap.deep_copy(&parents[a]);
                        let label = child.label();
                        let winc = heap.with_context(label, |h| {
                            model.step(h, &mut child, t, &mut rng, true)
                        });
                        attempt += 1;
                        if model.alive(winc) {
                            lw[i] += winc;
                            survivors.push(child);
                            break;
                        }
                        heap.release(child);
                        assert!(
                            attempt < 10_000,
                            "alive PF: no surviving particle after 10k attempts at t={t}"
                        );
                    }
                    attempts += attempt;
                }
                states = survivors;
                for p in parents {
                    heap.release(p);
                }
                heap.sweep_memos();
            }
            _ => {
                propagate_prefix(
                    model, shards, &mut states, &mut lw, n, t, cfg.seed, observe, ctx,
                );
                attempts += n;
            }
        }

        // --- Metrics snapshot (Figure 7). ---
        normalize_log_weights(&lw, &mut w);
        series.push(step_snapshot(shards, t, &start, &w));
    }

    // Final-generation evidence contribution and posterior summary.
    log_z += log_sum_exp(&lw) - (n as f64).ln();
    normalize_log_weights(&lw, &mut w);
    let mut post = 0.0;
    for i in 0..n {
        let si = shard_of(n, k, i);
        let mut s = states[i];
        post += w[i] * model.summary(&mut shards[si], &mut s);
        states[i] = s;
    }

    let agg = aggregate_metrics(shards);
    let result = FilterResult {
        log_evidence: if observe { log_z } else { f64::NAN },
        posterior_mean: post,
        wall_s: start.elapsed().as_secs_f64(),
        peak_bytes: agg.peak_bytes,
        series,
        attempts,
    };

    for (i, s) in states.into_iter().enumerate() {
        shards[shard_of(n, k, i)].release(s);
    }
    for h in shards.iter_mut() {
        h.sweep_memos();
    }
    result
}

/// Particle Gibbs with reference trajectory (conditional SMC) on a single
/// heap — the K = 1 specialization of [`run_particle_gibbs_shards`].
pub fn run_particle_gibbs<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    heap: &mut Heap,
    ctx: &StepCtx,
) -> Vec<FilterResult> {
    run_particle_gibbs_shards(model, cfg, std::slice::from_mut(heap), ctx)
}

/// Particle Gibbs with reference trajectory (conditional SMC), VBD's
/// method (Wigren et al. 2019, marginalized parameters live inside the
/// state's sufficient-statistic accumulators). Returns per-iteration
/// filter results. The inter-iteration single-particle copy is eager, per
/// the paper's §4 note; the reference trajectory lives on the shard that
/// owns the conditional slot `n - 1`, and a winner from another shard is
/// transplanted there (the transplant is itself an eager copy).
pub fn run_particle_gibbs_shards<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    shards: &mut [Heap],
    ctx: &StepCtx,
) -> Vec<FilterResult> {
    assert!(!shards.is_empty(), "at least one heap shard");
    let n = cfg.n_particles;
    let k = shards.len();
    let t_max = cfg.n_steps.min(model.horizon());
    let resampler = Resampler::Systematic;
    let mut results = Vec::new();
    // Shard holding the conditional slot — and the reference trajectory.
    let s_ref = shard_of(n, k, n - 1);
    // Reference trajectory: handles for generations 0..=T (oldest first),
    // all owned by shard `s_ref`.
    let mut reference: Option<Vec<Lazy<M::State>>> = None;

    for iter in 0..cfg.pg_iterations {
        let seed = cfg.seed.wrapping_add(iter as u64 * 0x9E37);
        let start = Instant::now();
        let mut states = init_population(model, shards, ctx.pool, n, seed);
        // Conditional slot n-1 follows the reference when present.
        if let Some(r) = &reference {
            shards[s_ref].release(states[n - 1]);
            states[n - 1] = shards[s_ref].clone_handle(&r[0]);
        }
        let mut lw = vec![0.0f64; n];
        let mut log_z = 0.0;
        let mut w = Vec::new();
        let mut series = Vec::new();

        for t in 1..=t_max {
            // Resample all but the conditional slot.
            normalize_log_weights(&lw, &mut w);
            let mut rrng = resample_rng(seed, t);
            let mut anc = resampler.ancestors(&mut rrng, &w, n);
            if reference.is_some() {
                anc[n - 1] = n - 1;
            }
            log_z += log_sum_exp(&lw) - (n as f64).ln();
            resample_population(shards, &mut states, &anc);
            lw.iter_mut().for_each(|x| *x = 0.0);

            // Propagate free particles; pin + score the conditional one.
            let split = if reference.is_some() { n - 1 } else { n };
            propagate_prefix(
                model,
                shards,
                &mut states[..split],
                &mut lw[..split],
                n,
                t,
                seed,
                true,
                ctx,
            );
            if let Some(r) = &reference {
                shards[s_ref].release(states[n - 1]);
                states[n - 1] = shards[s_ref].clone_handle(&r[t.min(r.len() - 1)]);
                let mut pinned = states[n - 1];
                lw[n - 1] += model.ref_weight(&mut shards[s_ref], &mut pinned, t);
                states[n - 1] = pinned;
            }

            normalize_log_weights(&lw, &mut w);
            series.push(step_snapshot(shards, t, &start, &w));
        }
        log_z += log_sum_exp(&lw) - (n as f64).ln();

        // Select the next reference trajectory and copy it out EAGERLY
        // (outside the tree pattern — the paper's §4 VBD note). A winner
        // on a foreign shard is transplanted to the reference shard,
        // which is equally eager.
        normalize_log_weights(&lw, &mut w);
        let mut srng = resample_rng(seed, t_max + 1);
        let winner = srng.categorical(&w);
        let s_win = shard_of(n, k, winner);
        let eager_ref = if s_win == s_ref {
            shards[s_ref].deep_copy_eager(&states[winner])
        } else {
            let (src, dst) = pair_mut(shards, s_win, s_ref);
            src.extract_into(&states[winner], dst)
        };
        let mut chain = model.chain(&mut shards[s_ref], &eager_ref);
        shards[s_ref].release(eager_ref);
        chain.reverse(); // oldest first
        if let Some(old) = reference.take() {
            for h in old {
                shards[s_ref].release(h);
            }
        }
        reference = Some(chain);

        let mut post = 0.0;
        for i in 0..n {
            let si = shard_of(n, k, i);
            let mut s = states[i];
            post += w[i] * model.summary(&mut shards[si], &mut s);
            states[i] = s;
        }
        for (i, s) in states.into_iter().enumerate() {
            shards[shard_of(n, k, i)].release(s);
        }
        for h in shards.iter_mut() {
            h.sweep_memos();
        }

        let agg = aggregate_metrics(shards);
        results.push(FilterResult {
            log_evidence: log_z,
            posterior_mean: post,
            wall_s: start.elapsed().as_secs_f64(),
            peak_bytes: agg.peak_bytes,
            series,
            attempts: n * t_max,
        });
    }
    if let Some(old) = reference.take() {
        for h in old {
            shards[s_ref].release(h);
        }
    }
    for h in shards.iter_mut() {
        h.sweep_memos();
    }
    results
}
