//! The population coordinator: particle filters over the sharded lazy heap.
//!
//! Implements the paper's §1 bootstrap filter plus the method variants its
//! evaluation uses — auxiliary PF (PCFG), alive PF (CRBD), and particle
//! Gibbs with a reference trajectory (VBD). Resampling performs one
//! `deep_copy` per offspring (O(1) in lazy modes, O(history) in eager mode
//! — the paper's Figure 7 quadratic/linear time contrast), releases dead
//! lineages, and sweeps memos once per generation.
//!
//! **Sharded execution.** The engine operates on `&mut [Heap]` — K
//! independent heap shards — with an explicit particle → shard assignment
//! vector. Per-generation propagation runs shard-parallel on the thread
//! pool: each worker holds `&mut` to exactly one shard, so the
//! allocate/copy/mutate hot path needs no locks and no atomics. At
//! resampling, offspring on their ancestor's shard take the O(1) lazy
//! [`Heap::deep_copy`]; offspring assigned across shards take a
//! cross-shard lineage transplant ([`Heap::extract_into`]), one per
//! distinct (ancestor, destination) pair, executed *concurrently* for
//! pairwise-disjoint (src, dst) shard pairs
//! ([`ThreadPool::for_pairs`](crate::pool::ThreadPool::for_pairs)).
//!
//! **Rebalancing.** The assignment starts as the contiguous
//! [`shard_ranges`] partition and is re-planned at every resampling step
//! by the cost-driven rebalancer (see [`super::rebalance`]): a greedy LPT
//! pass over per-particle cost estimates, sticky to the ancestor's shard,
//! migrating only past a configurable imbalance threshold. All RNG
//! streams are keyed by *global* particle index and all weight reductions
//! run in global index order, so the numeric output (`log_evidence`,
//! `posterior_mean`) is bit-identical for every K and every rebalance
//! policy — and K = 1 reproduces the pre-sharding single-heap engine
//! exactly.
//!
//! The alive PF remains coordinator-serial (its retry RNG stream depends
//! on the cumulative attempt count across particles); since sharding
//! would buy it no parallelism while making the O(history) transplant
//! the common case on retries, its population is collapsed onto shard 0.
//! With K > 1 the per-shard `step_population` runs with a serial pool and
//! without the XLA batch artifact (the batched runtime is not
//! shard-aware yet); K = 1 keeps the full batched path.

use super::model::{particle_rng, resample_rng, SmcModel, StepCtx};
use super::rebalance::{
    plan_offspring, CostTracker, RebalancePolicy, OP_COST_S, TRANSPLANT_COST_S,
};
use super::resample::Resampler;
use crate::config::{RunConfig, Task};
use crate::heap::{
    aggregate_metrics, sample_global_peak, shard_of, shard_ranges, Heap, HeapMetrics, Lazy,
    Payload,
};
use crate::pool::ThreadPool;
use crate::stats::{ess, log_sum_exp, normalize_log_weights};
use std::time::Instant;

/// Per-generation metrics snapshot (Figure 7 series), aggregated across
/// shards.
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub t: usize,
    /// Cumulative wall time since filter start (seconds).
    pub elapsed_s: f64,
    /// Heap footprint after this generation (bytes; exact — summed
    /// per-shard gauges refer to the same instant).
    pub live_bytes: usize,
    /// High-water mark so far (bytes). With K > 1 shards this is the sum
    /// of per-shard peaks — a conservative upper bound on the true
    /// simultaneous peak, since shards need not peak at the same moment.
    /// K = 1 — all figure baselines — is exact.
    pub peak_bytes: usize,
    /// Barrier-sampled global peak so far: the maximum over generation
    /// barriers (including the resampling spike) of the *summed* shard
    /// footprint — exact at barrier resolution, never above
    /// `peak_bytes`. The figure to quote for K > 1 runs.
    pub global_peak_bytes: usize,
    pub live_objects: usize,
    pub lazy_copies: usize,
    pub eager_copies: usize,
    pub ess: f64,
}

/// Filter output: evidence estimate, posterior summary, and metrics.
#[derive(Clone, Debug)]
pub struct FilterResult {
    pub log_evidence: f64,
    /// Weighted posterior mean of the model summary at the final
    /// generation (the cross-configuration output check).
    pub posterior_mean: f64,
    pub wall_s: f64,
    /// Peak heap bytes; with K > 1 an upper bound (sum of per-shard
    /// peaks — see [`StepMetrics::peak_bytes`]), exact at K = 1.
    pub peak_bytes: usize,
    /// Exact peak heap bytes: the continuous high-water mark at K = 1,
    /// the barrier-sampled global peak (peak of per-barrier sums) at
    /// K > 1. Always `<= peak_bytes`.
    pub global_peak_bytes: usize,
    /// Migrations: cross-shard transplant operations *executed* while a
    /// rebalancing policy was active (distinct (ancestor, destination)
    /// pairs per resampling step, including any the particle-Gibbs
    /// reference pin forces). Always 0 for policy `off`, whose boundary
    /// crossings are the static partition's inherent transplants — those
    /// are counted by `HeapMetrics::transplants` instead.
    pub migrations: usize,
    pub series: Vec<StepMetrics>,
    /// Alive PF: total propagation attempts (N·T when every particle
    /// survives immediately).
    pub attempts: usize,
}

/// Inference method, per §4.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Method {
    Bootstrap,
    Auxiliary,
    Alive,
}

/// One shard's borrowed slice of contiguous population work: the heap,
/// the shard's contiguous particle chunk, its log-weight chunk, and the
/// global index of the chunk's first particle.
struct ShardTask<'a, S> {
    heap: &'a mut Heap,
    states: &'a mut [Lazy<S>],
    lw: &'a mut [f64],
    base: usize,
}

/// Split (shards, states, lw) into per-shard [`ShardTask`]s following
/// `ranges`. `ranges` must be contiguous from 0 and sum to the slice
/// lengths.
fn make_tasks<'a, S>(
    shards: &'a mut [Heap],
    states: &'a mut [Lazy<S>],
    lw: &'a mut [f64],
    ranges: &[std::ops::Range<usize>],
) -> Vec<ShardTask<'a, S>> {
    let mut tasks = Vec::with_capacity(ranges.len());
    let mut shards = shards;
    let mut states = states;
    let mut lw = lw;
    for r in ranges {
        let (heap, shard_tail) = std::mem::take(&mut shards)
            .split_first_mut()
            .expect("more ranges than shards");
        shards = shard_tail;
        let len = r.end - r.start;
        let (s_chunk, s_tail) = std::mem::take(&mut states).split_at_mut(len);
        states = s_tail;
        let (w_chunk, w_tail) = std::mem::take(&mut lw).split_at_mut(len);
        lw = w_tail;
        tasks.push(ShardTask {
            heap,
            states: s_chunk,
            lw: w_chunk,
            base: r.start,
        });
    }
    tasks
}

#[inline]
fn heap_ops(m: &HeapMetrics) -> usize {
    // The rebalancer's op charge: allocations + actual object copies +
    // memo-chase pulls (the lazy platform's hot-path operations).
    m.total_allocs + m.lazy_copies + m.eager_copies + m.pulls
}

fn step_snapshot(shards: &[Heap], t: usize, start: &Instant, w: &[f64]) -> StepMetrics {
    let agg = aggregate_metrics(shards);
    StepMetrics {
        t,
        elapsed_s: start.elapsed().as_secs_f64(),
        live_bytes: agg.current_bytes(),
        peak_bytes: agg.peak_bytes,
        // K = 1: the continuous high-water mark *is* the global peak (it
        // sees intra-generation transients no barrier sample can), so the
        // series agrees with FilterResult's K = 1 substitution.
        global_peak_bytes: if shards.len() == 1 {
            agg.peak_bytes
        } else {
            agg.global_peak_bytes
        },
        live_objects: agg.live_objects,
        lazy_copies: agg.lazy_copies,
        eager_copies: agg.eager_copies,
        ess: ess(w),
    }
}

/// Draw the initial population, shard-parallel over the contiguous
/// starting partition (per-particle RNG streams make the draw order
/// immaterial).
fn init_population<M: SmcModel + Sync>(
    model: &M,
    shards: &mut [Heap],
    pool: &ThreadPool,
    n: usize,
    seed: u64,
) -> Vec<Lazy<M::State>> {
    let mut states: Vec<Lazy<M::State>> = vec![Lazy::NULL; n];
    let mut scratch = vec![0.0f64; n];
    let ranges = shard_ranges(n, shards.len());
    let mut tasks = make_tasks(shards, &mut states, &mut scratch, &ranges);
    pool.for_shards(&mut tasks, |_, task| {
        for (j, slot) in task.states.iter_mut().enumerate() {
            let mut rng = particle_rng(seed, 0, task.base + j);
            *slot = model.init(task.heap, &mut rng);
        }
    });
    drop(tasks);
    states
}

/// One maximal run of consecutive global particle indices owned by a
/// shard under the current assignment.
struct ShardRun<S> {
    base: usize,
    states: Vec<Lazy<S>>,
    winc: Vec<f64>,
    hints: Vec<f64>,
}

/// One shard's propagation work under an arbitrary assignment.
struct AssignedTask<'a, S> {
    heap: &'a mut Heap,
    runs: Vec<ShardRun<S>>,
    /// Measured generation cost: wall seconds + op charge (out).
    cost: f64,
}

/// Propagate + weight a (prefix of the) population under the current
/// particle → shard assignment, shard-parallel. Weight increments are
/// added into `lw` in place. `assign` must have the same length as
/// `states` (particle Gibbs propagates the prefix that excludes the
/// pinned conditional slot). When `shard_cost` / `hints` are given they
/// receive the measured per-shard generation cost and the model's
/// per-particle cost hints (the rebalancer's inputs). Each shard splits
/// its work into maximal runs of consecutive global indices, so
/// `step_population`'s `base` argument keeps every particle's RNG stream
/// identical regardless of assignment — the seeded equivalence guarantee.
#[allow(clippy::too_many_arguments)]
fn propagate_assigned<M: SmcModel + Sync>(
    model: &M,
    shards: &mut [Heap],
    states: &mut [Lazy<M::State>],
    lw: &mut [f64],
    assign: &[usize],
    t: usize,
    seed: u64,
    observe: bool,
    ctx: &StepCtx,
    mut shard_cost: Option<&mut [f64]>,
    mut hints: Option<&mut [f64]>,
) {
    debug_assert_eq!(states.len(), lw.len());
    debug_assert_eq!(states.len(), assign.len());
    if shards.len() == 1 {
        // Single shard: the pre-sharding path, with the full batched
        // context (XLA artifact + intra-generation numeric parallelism).
        let winc = model.step_population(&mut shards[0], states, t, seed, observe, 0, ctx);
        for (w, d) in lw.iter_mut().zip(winc) {
            *w += d;
        }
        return;
    }
    let k = shards.len();
    let want_hints = hints.is_some();
    // Zero-copy fast path: a monotone assignment is a contiguous
    // partition (always true for policy `off`, and for rebalanced runs
    // until the first migration), so per-shard work is a plain
    // `split_at_mut` of the state/weight slices — no gather/scatter of
    // handles or weights, exactly the pre-rebalancing layout.
    if assign.windows(2).all(|p| p[0] <= p[1]) {
        propagate_contiguous(
            model, shards, states, lw, assign, t, seed, observe, ctx, shard_cost, hints,
        );
        return;
    }
    // Gather each shard's particles as runs of consecutive indices.
    let mut runs_by_shard: Vec<Vec<ShardRun<M::State>>> = (0..k).map(|_| Vec::new()).collect();
    for (i, &s) in assign.iter().enumerate() {
        debug_assert!(s < k, "assignment names shard {s} of {k}");
        match runs_by_shard[s].last_mut() {
            Some(run) if run.base + run.states.len() == i => run.states.push(states[i]),
            _ => runs_by_shard[s].push(ShardRun {
                base: i,
                states: vec![states[i]],
                winc: Vec::new(),
                hints: Vec::new(),
            }),
        }
    }
    let mut tasks: Vec<AssignedTask<'_, M::State>> = shards
        .iter_mut()
        .zip(runs_by_shard)
        .map(|(heap, runs)| AssignedTask {
            heap,
            runs,
            cost: 0.0,
        })
        .collect();
    // Split the worker budget across shards so a shard count below the
    // thread count does not shrink total numeric-phase parallelism
    // (models like RBPF fan their numeric phase out on the given pool;
    // per-particle RNG streams keep results invariant to the chunking).
    let per_shard_threads = (ctx.pool.n_threads() / k).max(1);
    ctx.pool.for_shards(&mut tasks, |_, task| {
        if task.runs.is_empty() {
            return;
        }
        let t0 = Instant::now();
        let ops0 = heap_ops(&task.heap.metrics);
        // Each worker owns one shard outright; the shard's numeric phase
        // gets its slice of the thread budget and runs on the CPU oracle
        // path (the batched XLA runtime is not shard-aware).
        let local = ThreadPool::new(per_shard_threads);
        let shard_ctx = StepCtx {
            pool: &local,
            kalman: None,
        };
        for run in task.runs.iter_mut() {
            run.winc = model.step_population(
                task.heap,
                &mut run.states,
                t,
                seed,
                observe,
                run.base,
                &shard_ctx,
            );
            if want_hints {
                run.hints = run
                    .states
                    .iter_mut()
                    .map(|st| model.cost_hint(task.heap, st))
                    .collect();
            }
        }
        let ops1 = heap_ops(&task.heap.metrics);
        task.cost = t0.elapsed().as_secs_f64() + (ops1 - ops0) as f64 * OP_COST_S;
    });
    // Scatter results back in global index order.
    for (s, task) in tasks.into_iter().enumerate() {
        if let Some(sc) = shard_cost.as_deref_mut() {
            sc[s] = task.cost;
        }
        for run in task.runs {
            let base = run.base;
            for (j, st) in run.states.into_iter().enumerate() {
                states[base + j] = st;
            }
            for (j, w) in run.winc.into_iter().enumerate() {
                lw[base + j] += w;
            }
            if let Some(h) = hints.as_deref_mut() {
                for (j, v) in run.hints.into_iter().enumerate() {
                    h[base + j] = v;
                }
            }
        }
    }
}

/// One shard's chunk of a *contiguous* (monotone-assignment) propagation:
/// the borrowed [`ShardTask`] slices plus the rebalancer's outputs.
struct ContigTask<'a, S> {
    chunk: ShardTask<'a, S>,
    /// Measured generation cost (out).
    cost: f64,
    /// Per-particle cost hints for this chunk (out; empty unless asked).
    hints: Vec<f64>,
}

/// The zero-copy specialization of [`propagate_assigned`] for monotone
/// assignments: derive each shard's contiguous range directly from
/// `assign` and hand out disjoint sub-slice borrows via [`make_tasks`].
#[allow(clippy::too_many_arguments)]
fn propagate_contiguous<M: SmcModel + Sync>(
    model: &M,
    shards: &mut [Heap],
    states: &mut [Lazy<M::State>],
    lw: &mut [f64],
    assign: &[usize],
    t: usize,
    seed: u64,
    observe: bool,
    ctx: &StepCtx,
    mut shard_cost: Option<&mut [f64]>,
    mut hints: Option<&mut [f64]>,
) {
    let k = shards.len();
    let want_hints = hints.is_some();
    let m = assign.len();
    // Per-shard contiguous ranges straight from the monotone assignment
    // (a shard may own an empty range after migrations elsewhere).
    let mut ranges: Vec<std::ops::Range<usize>> = Vec::with_capacity(k);
    let mut start = 0usize;
    for s in 0..k {
        let mut end = start;
        while end < m && assign[end] == s {
            end += 1;
        }
        ranges.push(start..end);
        start = end;
    }
    debug_assert_eq!(start, m, "monotone assignment must cover the prefix");
    let mut tasks: Vec<ContigTask<'_, M::State>> = make_tasks(shards, states, lw, &ranges)
        .into_iter()
        .map(|chunk| ContigTask {
            chunk,
            cost: 0.0,
            hints: Vec::new(),
        })
        .collect();
    let per_shard_threads = (ctx.pool.n_threads() / k).max(1);
    ctx.pool.for_shards(&mut tasks, |_, task| {
        let chunk = &mut task.chunk;
        if chunk.states.is_empty() {
            return;
        }
        let t0 = Instant::now();
        let ops0 = heap_ops(&chunk.heap.metrics);
        let local = ThreadPool::new(per_shard_threads);
        let shard_ctx = StepCtx {
            pool: &local,
            kalman: None,
        };
        let winc = model.step_population(
            chunk.heap, chunk.states, t, seed, observe, chunk.base, &shard_ctx,
        );
        for (w, d) in chunk.lw.iter_mut().zip(winc) {
            *w += d;
        }
        if want_hints {
            task.hints = chunk
                .states
                .iter_mut()
                .map(|st| model.cost_hint(chunk.heap, st))
                .collect();
        }
        let ops1 = heap_ops(&chunk.heap.metrics);
        task.cost = t0.elapsed().as_secs_f64() + (ops1 - ops0) as f64 * OP_COST_S;
    });
    for (s, task) in tasks.into_iter().enumerate() {
        if let Some(sc) = shard_cost.as_deref_mut() {
            sc[s] = task.cost;
        }
        if let Some(h) = hints.as_deref_mut() {
            let base = task.chunk.base;
            for (j, v) in task.hints.into_iter().enumerate() {
                h[base + j] = v;
            }
        }
    }
}

/// A transplant operation for [`ThreadPool::for_pairs`]: (source shard,
/// destination shard, (ancestor index, transplanted handle — filled by
/// the executor)).
type TransplantOp<S> = (usize, usize, (usize, Lazy<S>));

/// Replace the population by the offspring given by `anc`, landing each
/// offspring on the shard named by `new_assign` (one O(1) `deep_copy`
/// per same-shard offspring; one transplant per *distinct* (ancestor,
/// destination-shard) pair, executed concurrently for disjoint (src,
/// dst) pairs), release the parent generation, and sweep memos. Updates
/// `assign` to `new_assign` and returns the number of transplant
/// operations executed.
fn resample_population<S: Payload>(
    shards: &mut [Heap],
    pool: &ThreadPool,
    states: &mut Vec<Lazy<S>>,
    anc: &[usize],
    assign: &mut Vec<usize>,
    new_assign: Vec<usize>,
) -> usize {
    let n = states.len();
    debug_assert_eq!(anc.len(), n);
    debug_assert_eq!(new_assign.len(), n);
    // Transplant plan: one op per distinct (ancestor, destination) pair
    // whose destination differs from the ancestor's home shard. All
    // duplicate offspring of that ancestor on that destination share the
    // single transplanted lineage via O(1) lazy copies. BTreeSet keeps
    // op order deterministic.
    let pair_set: std::collections::BTreeSet<(usize, usize)> = anc
        .iter()
        .zip(&new_assign)
        .filter(|&(&a, &dst)| dst != assign[a])
        .map(|(&a, &dst)| (a, dst))
        .collect();
    let mut ops: Vec<TransplantOp<S>> = pair_set
        .into_iter()
        .map(|(a, dst)| (assign[a], dst, (a, Lazy::NULL)))
        .collect();
    let n_ops = ops.len();
    {
        let states_ref: &[Lazy<S>] = states.as_slice();
        pool.for_pairs(shards, &mut ops, |op, src, dst| {
            let parent = states_ref[op.0];
            op.1 = src.extract_into(&parent, dst);
        });
    }
    let transplanted: std::collections::BTreeMap<(usize, usize), Lazy<S>> = ops
        .into_iter()
        .map(|(_, dst, (a, h))| ((a, dst), h))
        .collect();
    let mut new_states: Vec<Lazy<S>> = Vec::with_capacity(n);
    for (i, &a) in anc.iter().enumerate() {
        let dst = new_assign[i];
        let child = if dst == assign[a] {
            let parent = states[a];
            shards[dst].deep_copy(&parent)
        } else {
            let moved = transplanted[&(a, dst)];
            shards[dst].deep_copy(&moved)
        };
        new_states.push(child);
    }
    // Barrier sample at the resampling spike: parents, transplants, and
    // offspring are all simultaneously live right here.
    sample_global_peak(shards);
    for ((_, dst), h) in transplanted {
        shards[dst].release(h);
    }
    let old = std::mem::replace(states, new_states);
    for (i, s) in old.into_iter().enumerate() {
        shards[assign[i]].release(s);
    }
    *assign = new_assign;
    for h in shards.iter_mut() {
        h.sweep_memos();
    }
    n_ops
}

/// Plan the offspring → shard assignment for this resampling step and
/// execute it: the rebalancer entry point. `pin_last` forces the final
/// slot onto a fixed shard (particle Gibbs keeps the reference
/// trajectory on the conditional slot's shard) — applied *after*
/// planning, so the migration count reflects what actually executed.
/// Returns the executed transplant-op count under an active rebalancing
/// policy, and 0 for policy `off` (whose boundary crossings are the
/// static partition's inherent transplants, counted by
/// `HeapMetrics::transplants`).
#[allow(clippy::too_many_arguments)]
fn plan_and_resample<S: Payload>(
    policy: RebalancePolicy,
    threshold: f64,
    shards: &mut [Heap],
    pool: &ThreadPool,
    states: &mut Vec<Lazy<S>>,
    anc: &[usize],
    assign: &mut Vec<usize>,
    tracker: &mut CostTracker,
    pin_last: Option<usize>,
) -> usize {
    let k = shards.len();
    let plan = {
        // Migration cost model: the ancestor's reachable-subgraph size —
        // the very set `extract_into` would walk — times a per-object
        // transplant cost. Consulted lazily (Budget policy only).
        let migration_cost = |a: usize| {
            shards[assign[a]].reachable_objects(&[states[a].raw()]) as f64 * TRANSPLANT_COST_S
        };
        plan_offspring(
            policy,
            threshold,
            anc,
            assign.as_slice(),
            tracker.costs(),
            k,
            migration_cost,
        )
    };
    let mut new_assign = plan.assign;
    if let Some(s_ref) = pin_last {
        if let Some(last) = new_assign.last_mut() {
            *last = s_ref;
        }
    }
    tracker.inherit(anc);
    let executed = resample_population(shards, pool, states, anc, assign, new_assign);
    if policy == RebalancePolicy::Off {
        0
    } else {
        executed
    }
}

/// Run a particle filter (or forward simulation) for `cfg` over `model`
/// on a single heap — the K = 1 specialization of
/// [`run_filter_shards`].
pub fn run_filter<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    heap: &mut Heap,
    ctx: &StepCtx,
    method: Method,
) -> FilterResult {
    run_filter_shards(model, cfg, std::slice::from_mut(heap), ctx, method)
}

/// Run a particle filter (or forward simulation) over `shards.len()`
/// heap shards. Output is seed-deterministic and identical for every
/// shard count and every rebalance policy.
pub fn run_filter_shards<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    shards: &mut [Heap],
    ctx: &StepCtx,
    method: Method,
) -> FilterResult {
    assert!(!shards.is_empty(), "at least one heap shard");
    // The alive PF is coordinator-serial (its retry RNG stream depends on
    // the cumulative attempt count), so sharding buys no parallelism there
    // — and a sharded layout would make the O(history) cross-shard
    // transplant the common case on retries (each retry draws a uniform
    // ancestor, so (K-1)/K of draws would cross), reintroducing the eager
    // copying cost the lazy platform exists to avoid. Keep its population
    // on shard 0; outputs are K-invariant either way.
    let shards = if method == Method::Alive {
        &mut shards[..1]
    } else {
        shards
    };
    let n = cfg.n_particles;
    let k = shards.len();
    let t_max = cfg.n_steps.min(model.horizon());
    let observe = cfg.task == Task::Inference;
    let resampler = Resampler::Systematic;
    let policy = if k > 1 { cfg.rebalance } else { RebalancePolicy::Off };
    let balancing = policy != RebalancePolicy::Off;
    let start = Instant::now();

    // Initialize: contiguous starting assignment.
    let mut states = init_population(model, shards, ctx.pool, n, cfg.seed);
    let mut assign: Vec<usize> = (0..n).map(|i| shard_of(n, k, i)).collect();
    let mut tracker = CostTracker::new(n);
    let mut shard_cost = vec![0.0f64; k];
    let mut hints = vec![1.0f64; n];
    let mut migrations = 0usize;
    let mut lw = vec![0.0f64; n];
    let mut log_z = 0.0f64;
    let mut series = Vec::new();
    let mut w = Vec::with_capacity(n);
    let mut attempts = 0usize;
    sample_global_peak(shards);

    for t in 1..=t_max {
        // --- Resample (inference only; simulation performs no copies). ---
        if observe {
            normalize_log_weights(&lw, &mut w);
            let cur_ess = ess(&w);
            if cur_ess < cfg.ess_threshold * n as f64 {
                let mut rrng = resample_rng(cfg.seed, t);
                // Auxiliary stage: bias resampling by lookahead scores.
                let ancestors = if method == Method::Auxiliary {
                    let mut aux = vec![0.0f64; n];
                    let mut any = false;
                    for (i, aux_i) in aux.iter_mut().enumerate() {
                        let mut s = states[i];
                        if let Some(la) = model.lookahead(&mut shards[assign[i]], &mut s, t) {
                            *aux_i = la;
                            any = true;
                        }
                        states[i] = s;
                    }
                    if any {
                        let alw: Vec<f64> =
                            lw.iter().zip(&aux).map(|(a, b)| a + b).collect();
                        let mut aw = Vec::new();
                        normalize_log_weights(&alw, &mut aw);
                        let anc = resampler.ancestors(&mut rrng, &aw, n);
                        // First-stage correction: w ∝ 1 / lookahead(a).
                        log_z += log_sum_exp(&alw) - (n as f64).ln();
                        migrations += plan_and_resample(
                            policy,
                            cfg.rebalance_threshold,
                            shards,
                            ctx.pool,
                            &mut states,
                            &anc,
                            &mut assign,
                            &mut tracker,
                            None,
                        );
                        for (i, &a) in anc.iter().enumerate() {
                            lw[i] = -aux[a];
                        }
                        None
                    } else {
                        Some(resampler.ancestors(&mut rrng, &w, n))
                    }
                } else {
                    Some(resampler.ancestors(&mut rrng, &w, n))
                };
                if let Some(anc) = ancestors {
                    log_z += log_sum_exp(&lw) - (n as f64).ln();
                    migrations += plan_and_resample(
                        policy,
                        cfg.rebalance_threshold,
                        shards,
                        ctx.pool,
                        &mut states,
                        &anc,
                        &mut assign,
                        &mut tracker,
                        None,
                    );
                    lw.iter_mut().for_each(|x| *x = 0.0);
                }
            }
        }

        // --- Propagate + weight. ---
        match method {
            Method::Alive if observe => {
                // Alive PF: re-propose each slot until it survives, drawing
                // a fresh ancestor per attempt (Del Moral et al. 2015).
                // Resampling above has already equalized weights. The
                // whole population lives on shard 0 (see the collapse at
                // function entry), so every retry is an O(1) lazy copy.
                debug_assert_eq!(k, 1);
                let heap = &mut shards[0];
                let parents = std::mem::take(&mut states);
                let mut survivors = Vec::with_capacity(n);
                for i in 0..n {
                    let mut attempt = 0usize;
                    loop {
                        let mut rng = particle_rng(
                            cfg.seed,
                            t,
                            i + attempt * n + attempts, // fresh stream per retry
                        );
                        let a = if attempt == 0 {
                            i
                        } else {
                            rng.below(n as u64) as usize
                        };
                        let mut child = heap.deep_copy(&parents[a]);
                        let label = child.label();
                        let winc = heap.with_context(label, |h| {
                            model.step(h, &mut child, t, &mut rng, true)
                        });
                        attempt += 1;
                        if model.alive(winc) {
                            lw[i] += winc;
                            survivors.push(child);
                            break;
                        }
                        heap.release(child);
                        assert!(
                            attempt < 10_000,
                            "alive PF: no surviving particle after 10k attempts at t={t}"
                        );
                    }
                    attempts += attempt;
                }
                states = survivors;
                for p in parents {
                    heap.release(p);
                }
                heap.sweep_memos();
            }
            _ => {
                propagate_assigned(
                    model,
                    shards,
                    &mut states,
                    &mut lw,
                    &assign,
                    t,
                    cfg.seed,
                    observe,
                    ctx,
                    balancing.then_some(&mut shard_cost[..]),
                    balancing.then_some(&mut hints[..]),
                );
                if balancing {
                    tracker.update(&assign, &shard_cost, &hints);
                }
                attempts += n;
            }
        }

        // --- Metrics snapshot (Figure 7). ---
        sample_global_peak(shards);
        normalize_log_weights(&lw, &mut w);
        series.push(step_snapshot(shards, t, &start, &w));
    }

    // Final-generation evidence contribution and posterior summary.
    log_z += log_sum_exp(&lw) - (n as f64).ln();
    normalize_log_weights(&lw, &mut w);
    let mut post = 0.0;
    for i in 0..n {
        let mut s = states[i];
        post += w[i] * model.summary(&mut shards[assign[i]], &mut s);
        states[i] = s;
    }

    let agg = aggregate_metrics(shards);
    let result = FilterResult {
        log_evidence: if observe { log_z } else { f64::NAN },
        posterior_mean: post,
        wall_s: start.elapsed().as_secs_f64(),
        peak_bytes: agg.peak_bytes,
        // K = 1: the continuous high-water mark is the exact global peak.
        global_peak_bytes: if k == 1 {
            agg.peak_bytes
        } else {
            agg.global_peak_bytes
        },
        migrations,
        series,
        attempts,
    };

    for (i, s) in states.into_iter().enumerate() {
        shards[assign[i]].release(s);
    }
    for h in shards.iter_mut() {
        h.sweep_memos();
    }
    result
}

/// Particle Gibbs with reference trajectory (conditional SMC) on a single
/// heap — the K = 1 specialization of [`run_particle_gibbs_shards`].
pub fn run_particle_gibbs<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    heap: &mut Heap,
    ctx: &StepCtx,
) -> Vec<FilterResult> {
    run_particle_gibbs_shards(model, cfg, std::slice::from_mut(heap), ctx)
}

/// Particle Gibbs with reference trajectory (conditional SMC), VBD's
/// method (Wigren et al. 2019, marginalized parameters live inside the
/// state's sufficient-statistic accumulators). Returns per-iteration
/// filter results. The inter-iteration single-particle copy is eager, per
/// the paper's §4 note; the reference trajectory lives on the shard that
/// owns the conditional slot `n - 1` — the rebalancer pins that slot
/// there — and a winner from another shard is transplanted there (the
/// transplant is itself an eager copy).
pub fn run_particle_gibbs_shards<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    shards: &mut [Heap],
    ctx: &StepCtx,
) -> Vec<FilterResult> {
    assert!(!shards.is_empty(), "at least one heap shard");
    let n = cfg.n_particles;
    let k = shards.len();
    let t_max = cfg.n_steps.min(model.horizon());
    let resampler = Resampler::Systematic;
    let policy = if k > 1 { cfg.rebalance } else { RebalancePolicy::Off };
    let balancing = policy != RebalancePolicy::Off;
    let mut results = Vec::new();
    // Shard holding the conditional slot — and the reference trajectory.
    let s_ref = shard_of(n, k, n - 1);
    // Reference trajectory: handles for generations 0..=T (oldest first),
    // all owned by shard `s_ref`.
    let mut reference: Option<Vec<Lazy<M::State>>> = None;
    let mut shard_cost = vec![0.0f64; k];
    let mut hints = vec![1.0f64; n];

    for iter in 0..cfg.pg_iterations {
        let seed = cfg.seed.wrapping_add(iter as u64 * 0x9E37);
        let start = Instant::now();
        let mut states = init_population(model, shards, ctx.pool, n, seed);
        let mut assign: Vec<usize> = (0..n).map(|i| shard_of(n, k, i)).collect();
        // A fresh population every iteration: slot-indexed cost estimates
        // from the previous iteration's particles are garbage here.
        let mut tracker = CostTracker::new(n);
        let mut migrations = 0usize;
        sample_global_peak(shards);
        // Conditional slot n-1 follows the reference when present.
        if let Some(r) = &reference {
            shards[s_ref].release(states[n - 1]);
            states[n - 1] = shards[s_ref].clone_handle(&r[0]);
        }
        let mut lw = vec![0.0f64; n];
        let mut log_z = 0.0;
        let mut w = Vec::new();
        let mut series = Vec::new();

        for t in 1..=t_max {
            // Resample all but the conditional slot.
            normalize_log_weights(&lw, &mut w);
            let mut rrng = resample_rng(seed, t);
            let mut anc = resampler.ancestors(&mut rrng, &w, n);
            if reference.is_some() {
                anc[n - 1] = n - 1;
            }
            log_z += log_sum_exp(&lw) - (n as f64).ln();
            migrations += plan_and_resample(
                policy,
                cfg.rebalance_threshold,
                shards,
                ctx.pool,
                &mut states,
                &anc,
                &mut assign,
                &mut tracker,
                Some(s_ref),
            );
            lw.iter_mut().for_each(|x| *x = 0.0);

            // Propagate free particles; pin + score the conditional one.
            let split = if reference.is_some() { n - 1 } else { n };
            propagate_assigned(
                model,
                shards,
                &mut states[..split],
                &mut lw[..split],
                &assign[..split],
                t,
                seed,
                true,
                ctx,
                balancing.then_some(&mut shard_cost[..]),
                balancing.then_some(&mut hints[..split]),
            );
            if balancing {
                tracker.update(&assign[..split], &shard_cost, &hints[..split]);
            }
            if let Some(r) = &reference {
                shards[s_ref].release(states[n - 1]);
                states[n - 1] = shards[s_ref].clone_handle(&r[t.min(r.len() - 1)]);
                let mut pinned = states[n - 1];
                lw[n - 1] += model.ref_weight(&mut shards[s_ref], &mut pinned, t);
                states[n - 1] = pinned;
            }

            sample_global_peak(shards);
            normalize_log_weights(&lw, &mut w);
            series.push(step_snapshot(shards, t, &start, &w));
        }
        log_z += log_sum_exp(&lw) - (n as f64).ln();

        // Select the next reference trajectory and copy it out EAGERLY
        // (outside the tree pattern — the paper's §4 VBD note). A winner
        // on a foreign shard is transplanted to the reference shard,
        // which is equally eager.
        normalize_log_weights(&lw, &mut w);
        let mut srng = resample_rng(seed, t_max + 1);
        let winner = srng.categorical(&w);
        let s_win = assign[winner];
        let eager_ref = if s_win == s_ref {
            shards[s_ref].deep_copy_eager(&states[winner])
        } else {
            let (src, dst) = pair_mut(shards, s_win, s_ref);
            src.extract_into(&states[winner], dst)
        };
        let mut chain = model.chain(&mut shards[s_ref], &eager_ref);
        shards[s_ref].release(eager_ref);
        chain.reverse(); // oldest first
        if let Some(old) = reference.take() {
            for h in old {
                shards[s_ref].release(h);
            }
        }
        reference = Some(chain);

        let mut post = 0.0;
        for i in 0..n {
            let mut s = states[i];
            post += w[i] * model.summary(&mut shards[assign[i]], &mut s);
            states[i] = s;
        }
        for (i, s) in states.into_iter().enumerate() {
            shards[assign[i]].release(s);
        }
        for h in shards.iter_mut() {
            h.sweep_memos();
        }

        let agg = aggregate_metrics(shards);
        results.push(FilterResult {
            log_evidence: log_z,
            posterior_mean: post,
            wall_s: start.elapsed().as_secs_f64(),
            peak_bytes: agg.peak_bytes,
            global_peak_bytes: if k == 1 {
                agg.peak_bytes
            } else {
                agg.global_peak_bytes
            },
            migrations,
            series,
            attempts: n * t_max,
        });
    }
    if let Some(old) = reference.take() {
        for h in old {
            shards[s_ref].release(h);
        }
    }
    for h in shards.iter_mut() {
        h.sweep_memos();
    }
    results
}

/// Disjoint `&mut` access to two different shards.
fn pair_mut<T>(xs: &mut [T], a: usize, b: usize) -> (&mut T, &mut T) {
    debug_assert_ne!(a, b);
    if a < b {
        let (lo, hi) = xs.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = xs.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}
