//! The population coordinator: particle filters over the lazy heap.
//!
//! Implements the paper's §1 bootstrap filter plus the method variants its
//! evaluation uses — auxiliary PF (PCFG), alive PF (CRBD), and particle
//! Gibbs with a reference trajectory (VBD). Resampling performs one
//! `deep_copy` per offspring (O(1) in lazy modes, O(history) in eager mode
//! — the paper's Figure 7 quadratic/linear time contrast), releases dead
//! lineages, and sweeps memos once per generation.

use super::model::{particle_rng, resample_rng, SmcModel, StepCtx};
use super::resample::Resampler;
use crate::config::{RunConfig, Task};
use crate::heap::{Heap, Lazy};
use crate::stats::{ess, log_sum_exp, normalize_log_weights};
use std::time::Instant;

/// Per-generation metrics snapshot (Figure 7 series).
#[derive(Clone, Debug)]
pub struct StepMetrics {
    pub t: usize,
    /// Cumulative wall time since filter start (seconds).
    pub elapsed_s: f64,
    /// Heap footprint after this generation (bytes).
    pub live_bytes: usize,
    /// High-water mark so far (bytes).
    pub peak_bytes: usize,
    pub live_objects: usize,
    pub lazy_copies: usize,
    pub eager_copies: usize,
    pub ess: f64,
}

/// Filter output: evidence estimate, posterior summary, and metrics.
#[derive(Clone, Debug)]
pub struct FilterResult {
    pub log_evidence: f64,
    /// Weighted posterior mean of the model summary at the final
    /// generation (the cross-configuration output check).
    pub posterior_mean: f64,
    pub wall_s: f64,
    pub peak_bytes: usize,
    pub series: Vec<StepMetrics>,
    /// Alive PF: total propagation attempts (N·T when every particle
    /// survives immediately).
    pub attempts: usize,
}

/// Inference method, per §4.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Method {
    Bootstrap,
    Auxiliary,
    Alive,
}

/// Run a particle filter (or forward simulation) for `cfg` over `model`.
pub fn run_filter<M: SmcModel>(
    model: &M,
    cfg: &RunConfig,
    heap: &mut Heap,
    ctx: &StepCtx,
    method: Method,
) -> FilterResult {
    let n = cfg.n_particles;
    let t_max = cfg.n_steps.min(model.horizon());
    let observe = cfg.task == Task::Inference;
    let resampler = Resampler::Systematic;
    let start = Instant::now();

    // Initialize.
    let mut states: Vec<Lazy<M::State>> = (0..n)
        .map(|i| {
            let mut rng = particle_rng(cfg.seed, 0, i);
            model.init(heap, &mut rng)
        })
        .collect();
    let mut lw = vec![0.0f64; n];
    let mut log_z = 0.0f64;
    let mut series = Vec::new();
    let mut w = Vec::with_capacity(n);
    let mut attempts = 0usize;

    for t in 1..=t_max {
        // --- Resample (inference only; simulation performs no copies). ---
        if observe {
            normalize_log_weights(&lw, &mut w);
            let cur_ess = ess(&w);
            if cur_ess < cfg.ess_threshold * n as f64 {
                let mut rrng = resample_rng(cfg.seed, t);
                // Auxiliary stage: bias resampling by lookahead scores.
                let ancestors = if method == Method::Auxiliary {
                    let mut aux = vec![0.0f64; n];
                    let mut any = false;
                    for (i, s) in states.iter_mut().enumerate() {
                        if let Some(la) = model.lookahead(heap, s, t) {
                            aux[i] = la;
                            any = true;
                        }
                    }
                    if any {
                        let alw: Vec<f64> =
                            lw.iter().zip(&aux).map(|(a, b)| a + b).collect();
                        let mut aw = Vec::new();
                        normalize_log_weights(&alw, &mut aw);
                        let anc = resampler.ancestors(&mut rrng, &aw, n);
                        // First-stage correction: w ∝ 1 / lookahead(a).
                        log_z += log_sum_exp(&alw) - (n as f64).ln();
                        for (i, &a) in anc.iter().enumerate() {
                            let _ = i;
                            let _ = a;
                        }
                        let mut new_states = Vec::with_capacity(n);
                        for &a in &anc {
                            new_states.push(heap.deep_copy(&states[a]));
                        }
                        for s in states.drain(..) {
                            heap.release(s);
                        }
                        states = new_states;
                        for (i, &a) in anc.iter().enumerate() {
                            lw[i] = -aux[a];
                        }
                        heap.sweep_memos();
                        None
                    } else {
                        Some(resampler.ancestors(&mut rrng, &w, n))
                    }
                } else {
                    Some(resampler.ancestors(&mut rrng, &w, n))
                };
                if let Some(anc) = ancestors {
                    log_z += log_sum_exp(&lw) - (n as f64).ln();
                    let mut new_states = Vec::with_capacity(n);
                    for &a in &anc {
                        new_states.push(heap.deep_copy(&states[a]));
                    }
                    for s in states.drain(..) {
                        heap.release(s);
                    }
                    states = new_states;
                    lw.iter_mut().for_each(|x| *x = 0.0);
                    heap.sweep_memos();
                }
            }
        }

        // --- Propagate + weight. ---
        match method {
            Method::Alive if observe => {
                // Alive PF: re-propose each slot until it survives, drawing
                // a fresh ancestor per attempt (Del Moral et al. 2015).
                // Resampling above has already equalized weights.
                let parents = states;
                states = Vec::with_capacity(n);
                for i in 0..n {
                    let mut attempt = 0usize;
                    loop {
                        let mut rng = particle_rng(
                            cfg.seed,
                            t,
                            i + attempt * n + attempts, // fresh stream per retry
                        );
                        let a = if attempt == 0 {
                            i
                        } else {
                            rng.below(n as u64) as usize
                        };
                        let mut child = heap.deep_copy(&parents[a]);
                        let label = child.label();
                        let winc = heap
                            .with_context(label, |h| model.step(h, &mut child, t, &mut rng, true));
                        attempt += 1;
                        if model.alive(winc) {
                            lw[i] += winc;
                            states.push(child);
                            break;
                        }
                        heap.release(child);
                        assert!(
                            attempt < 10_000,
                            "alive PF: no surviving particle after 10k attempts at t={t}"
                        );
                    }
                    attempts += attempt;
                }
                for p in parents {
                    heap.release(p);
                }
                heap.sweep_memos();
            }
            _ => {
                let winc = model.step_population(heap, &mut states, t, cfg.seed, observe, ctx);
                attempts += n;
                for i in 0..n {
                    lw[i] += winc[i];
                }
            }
        }

        // --- Metrics snapshot (Figure 7). ---
        normalize_log_weights(&lw, &mut w);
        series.push(StepMetrics {
            t,
            elapsed_s: start.elapsed().as_secs_f64(),
            live_bytes: heap.metrics.current_bytes(),
            peak_bytes: heap.metrics.peak_bytes,
            live_objects: heap.metrics.live_objects,
            lazy_copies: heap.metrics.lazy_copies,
            eager_copies: heap.metrics.eager_copies,
            ess: ess(&w),
        });
    }

    // Final-generation evidence contribution and posterior summary.
    log_z += log_sum_exp(&lw) - (n as f64).ln();
    normalize_log_weights(&lw, &mut w);
    let mut post = 0.0;
    for (i, s) in states.iter_mut().enumerate() {
        post += w[i] * model.summary(heap, s);
    }

    let result = FilterResult {
        log_evidence: if observe { log_z } else { f64::NAN },
        posterior_mean: post,
        wall_s: start.elapsed().as_secs_f64(),
        peak_bytes: heap.metrics.peak_bytes,
        series,
        attempts,
    };

    for s in states {
        heap.release(s);
    }
    heap.sweep_memos();
    result
}

/// Particle Gibbs with reference trajectory (conditional SMC), VBD's
/// method (Wigren et al. 2019, marginalized parameters live inside the
/// state's sufficient-statistic accumulators). Returns per-iteration
/// filter results. The inter-iteration single-particle copy is eager, per
/// the paper's §4 note.
pub fn run_particle_gibbs<M: SmcModel>(
    model: &M,
    cfg: &RunConfig,
    heap: &mut Heap,
    ctx: &StepCtx,
) -> Vec<FilterResult> {
    let n = cfg.n_particles;
    let t_max = cfg.n_steps.min(model.horizon());
    let resampler = Resampler::Systematic;
    let mut results = Vec::new();
    // Reference trajectory: handles for generations 0..=T (oldest first).
    let mut reference: Option<Vec<Lazy<M::State>>> = None;

    for iter in 0..cfg.pg_iterations {
        let seed = cfg.seed.wrapping_add(iter as u64 * 0x9E37);
        let start = Instant::now();
        let mut states: Vec<Lazy<M::State>> = (0..n)
            .map(|i| {
                let mut rng = particle_rng(seed, 0, i);
                model.init(heap, &mut rng)
            })
            .collect();
        // Conditional slot n-1 follows the reference when present.
        if let Some(r) = &reference {
            heap.release(states[n - 1]);
            states[n - 1] = heap.clone_handle(&r[0]);
        }
        let mut lw = vec![0.0f64; n];
        let mut log_z = 0.0;
        let mut w = Vec::new();
        let mut series = Vec::new();

        for t in 1..=t_max {
            // Resample all but the conditional slot.
            normalize_log_weights(&lw, &mut w);
            let mut rrng = resample_rng(seed, t);
            let mut anc = resampler.ancestors(&mut rrng, &w, n);
            if reference.is_some() {
                anc[n - 1] = n - 1;
            }
            log_z += log_sum_exp(&lw) - (n as f64).ln();
            let mut new_states = Vec::with_capacity(n);
            for &a in &anc {
                new_states.push(heap.deep_copy(&states[a]));
            }
            for s in states.drain(..) {
                heap.release(s);
            }
            states = new_states;
            lw.iter_mut().for_each(|x| *x = 0.0);
            heap.sweep_memos();

            // Propagate free particles; pin + score the conditional one.
            let split = if reference.is_some() { n - 1 } else { n };
            let winc =
                model.step_population(heap, &mut states[..split], t, seed, true, ctx);
            for i in 0..split {
                lw[i] += winc[i];
            }
            if let Some(r) = &reference {
                heap.release(states[n - 1]);
                states[n - 1] = heap.clone_handle(&r[t.min(r.len() - 1)]);
                let mut pinned = states[n - 1];
                lw[n - 1] += model.ref_weight(heap, &mut pinned, t);
                states[n - 1] = pinned;
            }

            normalize_log_weights(&lw, &mut w);
            series.push(StepMetrics {
                t,
                elapsed_s: start.elapsed().as_secs_f64(),
                live_bytes: heap.metrics.current_bytes(),
                peak_bytes: heap.metrics.peak_bytes,
                live_objects: heap.metrics.live_objects,
                lazy_copies: heap.metrics.lazy_copies,
                eager_copies: heap.metrics.eager_copies,
                ess: ess(&w),
            });
        }
        log_z += log_sum_exp(&lw) - (n as f64).ln();

        // Select the next reference trajectory and copy it out EAGERLY
        // (outside the tree pattern — the paper's §4 VBD note).
        normalize_log_weights(&lw, &mut w);
        let mut srng = resample_rng(seed, t_max + 1);
        let k = srng.categorical(&w);
        let eager_ref = heap.deep_copy_eager(&states[k]);
        let mut chain = model.chain(heap, &eager_ref);
        heap.release(eager_ref);
        chain.reverse(); // oldest first
        if let Some(old) = reference.take() {
            for h in old {
                heap.release(h);
            }
        }
        reference = Some(chain);

        let mut post = 0.0;
        for (i, s) in states.iter_mut().enumerate() {
            post += w[i] * model.summary(heap, s);
        }
        for s in states {
            heap.release(s);
        }
        heap.sweep_memos();

        results.push(FilterResult {
            log_evidence: log_z,
            posterior_mean: post,
            wall_s: start.elapsed().as_secs_f64(),
            peak_bytes: heap.metrics.peak_bytes,
            series,
            attempts: n * t_max,
        });
    }
    if let Some(old) = reference.take() {
        for h in old {
            heap.release(h);
        }
    }
    heap.sweep_memos();
    results
}
