//! The population coordinator: particle filters over the sharded lazy heap.
//!
//! Implements the paper's §1 bootstrap filter plus the method variants its
//! evaluation uses — auxiliary PF (PCFG), alive PF (CRBD), and particle
//! Gibbs with a reference trajectory (VBD). Resampling performs one
//! `deep_copy` per offspring (O(1) in lazy modes, O(history) in eager mode
//! — the paper's Figure 7 quadratic/linear time contrast), releases dead
//! lineages, and sweeps memos once per generation.
//!
//! **Sessions.** The generation state machine itself lives in
//! [`super::session::FilterSession`]: one `step()` per generation, with
//! pause/resume and O(1) lazy population forking. The entry points here —
//! [`run_filter_shards`] and [`run_particle_gibbs_shards`] — are thin
//! run-to-completion drivers over a session; this module keeps the
//! propagation executors (assigned / work-stealing / alive rounds) and
//! the resampling machinery the session calls into.
//!
//! **Sharded execution.** The engine operates on `&mut [Heap]` — K
//! independent heap shards — with an explicit particle → shard assignment
//! vector. Per-generation propagation runs shard-parallel on the thread
//! pool: each worker holds `&mut` to exactly one shard, so the
//! allocate/copy/mutate hot path needs no locks and no atomics. At
//! resampling, offspring on their ancestor's shard take the O(1) lazy
//! [`Heap::deep_copy`]; offspring assigned across shards take a
//! cross-shard lineage transplant ([`Heap::extract_into`]), one per
//! distinct (ancestor, destination) pair, executed *concurrently* for
//! pairwise-disjoint (src, dst) shard pairs
//! ([`ThreadPool::for_pairs`](crate::pool::ThreadPool::for_pairs)).
//!
//! **Rebalancing.** The assignment starts as the contiguous
//! [`shard_ranges`] partition and is re-planned at every resampling step
//! by the cost-driven rebalancer (see [`super::rebalance`]): a greedy LPT
//! pass over per-particle cost estimates, sticky to the ancestor's shard,
//! migrating only past a configurable imbalance threshold. All RNG
//! streams are keyed by *global* particle index and all weight reductions
//! run in global index order, so the numeric output (`log_evidence`,
//! `posterior_mean`) is bit-identical for every K and every rebalance
//! policy — and K = 1 reproduces the pre-sharding single-heap engine
//! exactly.
//!
//! **Work stealing.** The rebalancer only moves work at resampling
//! barriers; a long tail *inside* one generation would still idle sibling
//! shards. With `RunConfig::steal` on (the default) and K > 1,
//! propagation runs on the work-stealing executor instead of fixed
//! chunk-per-shard: each worker drains its own per-shard run queue in
//! small chunks, and a worker that finishes parks in a
//! [`StealYard`](crate::pool::StealYard). Busy workers notice, extract
//! tail particles of their queue into a *scratch heap*
//! ([`Heap::extract_into`]) and donate the package; the thief propagates
//! the stolen particles there (RNG streams stay keyed by global particle
//! index) and the results are transplanted back to the home shard at the
//! generation barrier, with the scratch's op counters absorbed into the
//! home metrics. Heap ownership stays one `&mut` per worker throughout —
//! the yard synchronizes only package handoff, never heap operations —
//! and the output is bit-identical with stealing on or off. Donation
//! *selection* is shared-ancestor-aware: among the queue-tail runs a
//! victim may give away, it prefers the runs whose lineage roots are
//! already private (unshared), because donating a lineage still shared
//! with same-shard siblings severs that sharing — the transplant
//! round trip must eagerly duplicate the shared ancestry on both legs.
//! Results land by global index either way, so the choice moves only
//! bytes, never the output.
//!
//! The alive PF (contract v2) runs shard-parallel in *rounds*: per-slot
//! retry RNG streams ([`alive_retry_rng`]) make every slot's attempt
//! sequence independent of the others, so each round draws all pending
//! slots' streams on the coordinator, imports foreign retry ancestors
//! once per distinct (ancestor, destination) pair, and propagates the
//! attempts shard-parallel. Output and total attempt count are identical
//! for every K. (Contract v1 chained all slots through one cumulative
//! attempt counter, which collapsed the population onto shard 0.)
//! Pending slots are retried in batched rounds: once every pending slot
//! has failed its first attempt, each round speculatively draws a
//! *window* of attempts per slot (the per-slot streams make extra draws
//! side-effect-free), cutting the serialized ancestor-import barriers in
//! low-survival regimes; attempts past a slot's first survivor are
//! discarded uncounted, so output and attempt totals are identical to
//! one-attempt rounds for **any** window size. The window adapts to the
//! observed survival rate within the generation (see
//! [`ALIVE_WINDOW_INIT`]): high-survival regimes shrink it toward 1 and
//! waste no speculative propagation, dead zones grow it geometrically up
//! to [`ALIVE_WINDOW_MAX`] to amortize the round barriers.
//!
//! **Batched numeric path.** Propagation dispatches through `step_run`:
//! with `StepCtx::batch` set (the `--batch on` default) a model's
//! [`SmcModel::step_batched`] SoA hook handles each contiguous shard-local
//! run, falling back to the scalar `step_population` when the model
//! declines. The per-shard worker contexts forward the compiled Kalman
//! artifact (`StepCtx::kalman`), so the XLA runtime dispatch is
//! shard-aware: every K uses the artifact (feature `xla`) or the f64 CPU
//! batch oracle, not per-particle fallback. Weight scatter/reduce run
//! through the [`super::batch`] kernels in fixed global-index order, so
//! output is bit-identical for every K × policy × steal × batch setting.

use super::batch;
use super::model::{alive_retry_rng, particle_rng, SmcModel, StepCtx};
use super::rebalance::{
    plan_offspring, CostTracker, RebalancePolicy, HINT_FLOOR, OP_COST_S, TRANSPLANT_COST_S,
};
use crate::config::RunConfig;
use crate::heap::{
    aggregate_metrics, sample_global_peak, shard_of, shard_ranges, trim_shards, Heap, HeapMetrics,
    Lazy, Payload,
};
use crate::pool::{StealYard, ThreadPool};
use crate::rng::Pcg64;
use crate::telemetry::trace::{Phase, PhaseWalls};
use std::sync::Mutex;
use std::time::Instant;

/// Per-generation metrics snapshot (Figure 7 series), aggregated across
/// shards.
#[derive(Clone, Debug)]
pub struct StepMetrics {
    /// Generation index (1-based).
    pub t: usize,
    /// Cumulative wall time since filter start (seconds).
    pub elapsed_s: f64,
    /// Heap footprint after this generation (bytes; exact — summed
    /// per-shard gauges refer to the same instant).
    pub live_bytes: usize,
    /// High-water mark so far (bytes). With K > 1 shards this is the sum
    /// of per-shard peaks — a conservative upper bound on the true
    /// simultaneous peak, since shards need not peak at the same moment.
    /// K = 1 — all figure baselines — is exact.
    pub peak_bytes: usize,
    /// Barrier-sampled global peak so far: the maximum over generation
    /// barriers (including the resampling spike) of the *summed* shard
    /// footprint — exact at barrier resolution, never above
    /// `peak_bytes`. The figure to quote for K > 1 runs.
    pub global_peak_bytes: usize,
    /// Live objects across shards after this generation.
    pub live_objects: usize,
    /// Cumulative lazy (`Copy`) object copies.
    pub lazy_copies: usize,
    /// Cumulative eager object copies.
    pub eager_copies: usize,
    /// Effective sample size of the normalized weights.
    pub ess: f64,
}

/// Filter output: evidence estimate, posterior summary, and metrics.
#[derive(Clone, Debug)]
pub struct FilterResult {
    /// Log marginal-likelihood estimate (NaN for the simulation task).
    pub log_evidence: f64,
    /// Weighted posterior mean of the model summary at the final
    /// generation (the cross-configuration output check).
    pub posterior_mean: f64,
    /// Wall-clock seconds for the whole run.
    pub wall_s: f64,
    /// Peak heap bytes; with K > 1 an upper bound (sum of per-shard
    /// peaks — see [`StepMetrics::peak_bytes`]), exact at K = 1.
    pub peak_bytes: usize,
    /// Exact peak heap bytes: the continuous high-water mark at K = 1,
    /// the barrier-sampled global peak (peak of per-barrier sums) at
    /// K > 1. Always `<= peak_bytes`.
    pub global_peak_bytes: usize,
    /// Transient work-stealing scratch residency: the maximum over
    /// generations of the summed per-scratch-heap peaks (exact per
    /// scratch; bytes that live in no shard's `peak_bytes` between
    /// donation and reclaim). Zero with stealing off, so steal-on vs
    /// steal-off peak comparisons are exact:
    /// `peak_bytes + scratch_peak_bytes` bounds the steal-on footprint.
    pub scratch_peak_bytes: usize,
    /// Migrations: cross-shard transplant operations *executed* while a
    /// rebalancing policy was active (distinct (ancestor, destination)
    /// pairs per resampling step, including any the particle-Gibbs
    /// reference pin forces). Always 0 for policy `off`, whose boundary
    /// crossings are the static partition's inherent transplants — those
    /// are counted by `HeapMetrics::transplants` instead.
    pub migrations: usize,
    /// Particles donated to the work-stealing yard this run (0 with
    /// `steal` off or K = 1). Each counted particle paid the scratch-heap
    /// round trip and was propagated by whichever worker took the batch —
    /// usually, though not necessarily, a non-home worker (a donor that
    /// runs dry can take back its own donation). Like `migrations`, a
    /// pure scheduling statistic: output is bit-identical whatever this
    /// counts.
    pub steals: usize,
    /// Per-generation metrics snapshots (Figure 7).
    pub series: Vec<StepMetrics>,
    /// Alive PF: total propagation attempts (N·T when every particle
    /// survives immediately). Invariant in K under the per-slot retry
    /// stream contract.
    pub attempts: usize,
}

/// Inference method, per §4.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Method {
    /// Bootstrap particle filter (propose from the dynamics).
    Bootstrap,
    /// Auxiliary particle filter (lookahead-biased resampling).
    Auxiliary,
    /// Alive particle filter (retry until a particle survives).
    Alive,
}

/// One shard's borrowed slice of contiguous population work: the heap,
/// the shard's contiguous particle chunk, its log-weight chunk, and the
/// global index of the chunk's first particle.
struct ShardTask<'a, S> {
    heap: &'a mut Heap,
    states: &'a mut [Lazy<S>],
    lw: &'a mut [f64],
    base: usize,
}

/// Split (shards, states, lw) into per-shard [`ShardTask`]s following
/// `ranges`. `ranges` must be contiguous from 0 and sum to the slice
/// lengths.
fn make_tasks<'a, S>(
    shards: &'a mut [Heap],
    states: &'a mut [Lazy<S>],
    lw: &'a mut [f64],
    ranges: &[std::ops::Range<usize>],
) -> Vec<ShardTask<'a, S>> {
    let mut tasks = Vec::with_capacity(ranges.len());
    let mut shards = shards;
    let mut states = states;
    let mut lw = lw;
    for r in ranges {
        let (heap, shard_tail) = std::mem::take(&mut shards)
            .split_first_mut()
            .expect("more ranges than shards");
        shards = shard_tail;
        let len = r.end - r.start;
        let (s_chunk, s_tail) = std::mem::take(&mut states).split_at_mut(len);
        states = s_tail;
        let (w_chunk, w_tail) = std::mem::take(&mut lw).split_at_mut(len);
        lw = w_tail;
        tasks.push(ShardTask {
            heap,
            states: s_chunk,
            lw: w_chunk,
            base: r.start,
        });
    }
    tasks
}

/// Sum of hint weights under the cost model's [`HINT_FLOOR`] clamp — the
/// shared denominator for apportioning one measured cost. (The hint
/// fallback: used only where a single measurement covers several
/// particles, i.e. a thief's stolen batch; everywhere else the engine now
/// measures per particle with metrics scopes.)
fn clamped_hint_sum<'a>(hints: impl IntoIterator<Item = &'a f64>) -> f64 {
    hints.into_iter().map(|h| h.max(HINT_FLOOR)).sum()
}

/// Apportion one measured `cost` over a contiguous run of slots by
/// clamped hint weight, writing per-particle costs into `out[base..]`.
/// No-op when the measurement is unusable.
fn apportion_cost(out: &mut [f64], base: usize, cost: f64, hints: &[f64], hint_sum: f64) {
    if hint_sum <= 0.0 || !cost.is_finite() {
        return;
    }
    for (j, h) in hints.iter().enumerate() {
        out[base + j] = cost * h.max(HINT_FLOOR) / hint_sum;
    }
}

/// One particle's *exact* measured propagation cost: the wall seconds of
/// its scoped region plus the heap-operation charge of the scope's exact
/// [`HeapMetrics`] delta ([`Heap::begin_scope`] / [`Heap::end_scope`]).
/// This is what the rebalancer's [`CostTracker`] now feeds on wherever a
/// scope is active — `cost_hint` apportioning remains only as the
/// fallback for batch-granular measurements (stolen batches).
#[inline]
fn scoped_cost(wall_s: f64, delta: &HeapMetrics) -> f64 {
    wall_s + delta.op_charge() as f64 * OP_COST_S
}

/// The exact-cost propagation core shared by every scoped path (assigned
/// runs, contiguous chunks, steal queues): step `states` one scoped
/// single-particle `step_population` call at a time — bit-identical to
/// the batched call by the per-particle RNG stream contract — and hand
/// `sink` each slot's offset, weight increment, and [`scoped_cost`].
#[allow(clippy::too_many_arguments)]
fn step_scoped<M: SmcModel + Sync>(
    model: &M,
    heap: &mut Heap,
    states: &mut [Lazy<M::State>],
    base: usize,
    t: usize,
    seed: u64,
    observe: bool,
    shard_ctx: &StepCtx,
    mut sink: impl FnMut(usize, f64, f64),
) {
    for j in 0..states.len() {
        let t0 = Instant::now();
        let scope = heap.begin_scope();
        let winc = model.step_population(
            heap,
            &mut states[j..j + 1],
            t,
            seed,
            observe,
            base + j,
            shard_ctx,
        );
        let delta = heap.end_scope(scope);
        sink(j, winc[0], scoped_cost(t0.elapsed().as_secs_f64(), &delta));
    }
}

/// Propagate one contiguous run, preferring the model's batched SoA hook.
/// With `ctx.batch` set, [`SmcModel::step_batched`] gets first refusal on
/// the whole run; a `None` (model has no batched core, or the generation
/// shape doesn't fit it) falls back to the scalar `step_population` loop.
/// The two paths are bit-identical per particle (the hook's contract), so
/// callers never need to know which one ran.
#[allow(clippy::too_many_arguments)]
fn step_run<M: SmcModel + Sync>(
    model: &M,
    heap: &mut Heap,
    states: &mut [Lazy<M::State>],
    t: usize,
    seed: u64,
    observe: bool,
    base: usize,
    ctx: &StepCtx,
) -> Vec<f64> {
    if ctx.batch {
        if let Some(winc) = model.step_batched(heap, states, t, seed, observe, base, ctx) {
            return winc;
        }
    }
    model.step_population(heap, states, t, seed, observe, base, ctx)
}

pub(crate) fn step_snapshot(shards: &[Heap], t: usize, start: &Instant, ess: f64) -> StepMetrics {
    let agg = aggregate_metrics(shards);
    StepMetrics {
        t,
        elapsed_s: start.elapsed().as_secs_f64(),
        live_bytes: agg.current_bytes(),
        peak_bytes: agg.peak_bytes,
        // K = 1: the continuous high-water mark *is* the global peak (it
        // sees intra-generation transients no barrier sample can), so the
        // series agrees with FilterResult's K = 1 substitution.
        global_peak_bytes: if shards.len() == 1 {
            agg.peak_bytes
        } else {
            agg.global_peak_bytes
        },
        live_objects: agg.live_objects,
        lazy_copies: agg.lazy_copies,
        eager_copies: agg.eager_copies,
        ess,
    }
}

/// Draw the initial population, shard-parallel over the contiguous
/// starting partition (per-particle RNG streams make the draw order
/// immaterial).
pub(crate) fn init_population<M: SmcModel + Sync>(
    model: &M,
    shards: &mut [Heap],
    pool: &ThreadPool,
    n: usize,
    seed: u64,
) -> Vec<Lazy<M::State>> {
    let mut states: Vec<Lazy<M::State>> = vec![Lazy::NULL; n];
    let mut scratch = vec![0.0f64; n];
    let ranges = shard_ranges(n, shards.len());
    let mut tasks = make_tasks(shards, &mut states, &mut scratch, &ranges);
    pool.for_shards(&mut tasks, |_, task| {
        for (j, slot) in task.states.iter_mut().enumerate() {
            let mut rng = particle_rng(seed, 0, task.base + j);
            *slot = model.init(task.heap, &mut rng);
        }
    });
    drop(tasks);
    states
}

/// One maximal run of consecutive global particle indices owned by a
/// shard under the current assignment.
struct ShardRun<S> {
    base: usize,
    states: Vec<Lazy<S>>,
    winc: Vec<f64>,
    /// Exact per-particle measured costs (scoped; filled only when the
    /// rebalancer is active).
    costs: Vec<f64>,
}

/// Decompose an assignment into per-shard maximal runs of consecutive
/// global indices, moving the state handles into the runs. Both the
/// assigned and the work-stealing executors use this one decomposition —
/// the steal-on/off bit-identity contract depends on the two paths
/// slicing the population identically (`step_population` receives each
/// run's global base, so RNG streams stay keyed by global index).
fn gather_runs<S>(states: &[Lazy<S>], assign: &[usize], k: usize) -> Vec<Vec<ShardRun<S>>> {
    let mut runs_by_shard: Vec<Vec<ShardRun<S>>> = (0..k).map(|_| Vec::new()).collect();
    for (i, &s) in assign.iter().enumerate() {
        debug_assert!(s < k, "assignment names shard {s} of {k}");
        match runs_by_shard[s].last_mut() {
            Some(run) if run.base + run.states.len() == i => run.states.push(states[i]),
            _ => runs_by_shard[s].push(ShardRun {
                base: i,
                states: vec![states[i]],
                winc: Vec::new(),
                costs: Vec::new(),
            }),
        }
    }
    runs_by_shard
}

/// One shard's propagation work under an arbitrary assignment.
struct AssignedTask<'a, S> {
    heap: &'a mut Heap,
    runs: Vec<ShardRun<S>>,
    /// Worker-clocked wall seconds this shard spent propagating (out).
    wall_s: f64,
}

/// Propagate one run of particles on its shard, appending weight
/// increments to `run.winc`. When `want_costs`, every particle is
/// propagated in its own metrics scope — a single-particle
/// `step_population` call, bit-identical to the batched call by the
/// per-particle RNG stream contract — and the exact measured cost lands
/// in `run.costs`.
#[allow(clippy::too_many_arguments)]
fn propagate_run<M: SmcModel + Sync>(
    model: &M,
    heap: &mut Heap,
    run: &mut ShardRun<M::State>,
    t: usize,
    seed: u64,
    observe: bool,
    shard_ctx: &StepCtx,
    want_costs: bool,
) {
    if want_costs {
        run.costs.reserve(run.states.len());
        let (winc, costs) = (&mut run.winc, &mut run.costs);
        step_scoped(
            model,
            heap,
            &mut run.states,
            run.base,
            t,
            seed,
            observe,
            shard_ctx,
            |_, w, c| {
                winc.push(w);
                costs.push(c);
            },
        );
    } else {
        run.winc = step_run(
            model,
            heap,
            &mut run.states,
            t,
            seed,
            observe,
            run.base,
            shard_ctx,
        );
    }
}

/// Propagate + weight a (prefix of the) population under the current
/// particle → shard assignment, shard-parallel. Weight increments are
/// added into `lw` in place. `assign` must have the same length as
/// `states` (particle Gibbs propagates the prefix that excludes the
/// pinned conditional slot). When `raw_cost` is given it receives the
/// *exact* per-particle measured cost of every propagated slot (scoped
/// wall time + heap-op charge — the rebalancer's input; see
/// [`scoped_cost`]). Each shard splits its work into maximal runs of
/// consecutive global indices, so `step_population`'s `base` argument
/// keeps every particle's RNG stream identical regardless of assignment —
/// the seeded equivalence guarantee. `walls` accumulates per-shard
/// propagate wall time (each worker clocks its own task struct — no
/// shared state — and the coordinator folds after the join; pure
/// measurement, never an input to computation).
#[allow(clippy::too_many_arguments)]
pub(crate) fn propagate_assigned<M: SmcModel + Sync>(
    model: &M,
    shards: &mut [Heap],
    states: &mut [Lazy<M::State>],
    lw: &mut [f64],
    assign: &[usize],
    t: usize,
    seed: u64,
    observe: bool,
    ctx: &StepCtx,
    mut raw_cost: Option<&mut [f64]>,
    walls: &mut PhaseWalls,
) {
    debug_assert_eq!(states.len(), lw.len());
    debug_assert_eq!(states.len(), assign.len());
    if shards.len() == 1 {
        // Single shard: the pre-sharding path, with the full batched
        // context (XLA artifact + intra-generation numeric parallelism).
        // The rebalancer never runs at K = 1, so no costs are measured.
        let t0 = Instant::now();
        let winc = step_run(model, &mut shards[0], states, t, seed, observe, 0, ctx);
        batch::accumulate(lw, &winc);
        walls.add_shard(Phase::Propagate, 0, t0.elapsed().as_secs_f64());
        return;
    }
    let k = shards.len();
    let want_costs = raw_cost.is_some();
    // Zero-copy fast path: a monotone assignment is a contiguous
    // partition (always true for policy `off`, and for rebalanced runs
    // until the first migration), so per-shard work is a plain
    // `split_at_mut` of the state/weight slices — no gather/scatter of
    // handles or weights, exactly the pre-rebalancing layout.
    if assign.windows(2).all(|p| p[0] <= p[1]) {
        propagate_contiguous(
            model, shards, states, lw, assign, t, seed, observe, ctx, raw_cost, walls,
        );
        return;
    }
    // Gather each shard's particles as runs of consecutive indices.
    let runs_by_shard = gather_runs(states, assign, k);
    let mut tasks: Vec<AssignedTask<'_, M::State>> = shards
        .iter_mut()
        .zip(runs_by_shard)
        .map(|(heap, runs)| AssignedTask { heap, runs, wall_s: 0.0 })
        .collect();
    // Split the worker budget across shards so a shard count below the
    // thread count does not shrink total numeric-phase parallelism
    // (models like RBPF fan their numeric phase out on the given pool;
    // per-particle RNG streams keep results invariant to the chunking).
    let per_shard_threads = (ctx.pool.n_threads() / k).max(1);
    let (kalman, use_batch) = (ctx.kalman, ctx.batch);
    ctx.pool.for_shards(&mut tasks, |_, task| {
        if task.runs.is_empty() {
            return;
        }
        let t0 = Instant::now();
        // Each worker owns one shard outright; the shard's numeric phase
        // gets its slice of the thread budget and the shared compiled
        // artifact — the batched runtime dispatch is shard-aware, so
        // every K runs the artifact (or the CPU batch oracle).
        let local = ThreadPool::new(per_shard_threads);
        let shard_ctx = StepCtx {
            pool: &local,
            kalman,
            batch: use_batch,
        };
        for run in task.runs.iter_mut() {
            propagate_run(model, task.heap, run, t, seed, observe, &shard_ctx, want_costs);
        }
        task.wall_s = t0.elapsed().as_secs_f64();
    });
    for (s, task) in tasks.iter().enumerate() {
        walls.add_shard(Phase::Propagate, s, task.wall_s);
    }
    // Scatter results back in global index order.
    for task in tasks {
        for run in task.runs {
            let base = run.base;
            batch::accumulate(&mut lw[base..base + run.winc.len()], &run.winc);
            if let Some(rc) = raw_cost.as_deref_mut() {
                for (j, c) in run.costs.iter().enumerate() {
                    rc[base + j] = *c;
                }
            }
            for (j, st) in run.states.into_iter().enumerate() {
                states[base + j] = st;
            }
        }
    }
}

/// One shard's chunk of a *contiguous* (monotone-assignment) propagation:
/// the borrowed [`ShardTask`] slices plus the rebalancer's outputs.
struct ContigTask<'a, S> {
    chunk: ShardTask<'a, S>,
    /// Exact per-particle measured costs (out; empty unless asked).
    costs: Vec<f64>,
    /// Worker-clocked wall seconds this shard spent propagating (out).
    wall_s: f64,
}

/// The zero-copy specialization of [`propagate_assigned`] for monotone
/// assignments: derive each shard's contiguous range directly from
/// `assign` and hand out disjoint sub-slice borrows via [`make_tasks`].
#[allow(clippy::too_many_arguments)]
fn propagate_contiguous<M: SmcModel + Sync>(
    model: &M,
    shards: &mut [Heap],
    states: &mut [Lazy<M::State>],
    lw: &mut [f64],
    assign: &[usize],
    t: usize,
    seed: u64,
    observe: bool,
    ctx: &StepCtx,
    mut raw_cost: Option<&mut [f64]>,
    walls: &mut PhaseWalls,
) {
    let k = shards.len();
    let want_costs = raw_cost.is_some();
    let m = assign.len();
    // Per-shard contiguous ranges straight from the monotone assignment
    // (a shard may own an empty range after migrations elsewhere).
    let mut ranges: Vec<std::ops::Range<usize>> = Vec::with_capacity(k);
    let mut start = 0usize;
    for s in 0..k {
        let mut end = start;
        while end < m && assign[end] == s {
            end += 1;
        }
        ranges.push(start..end);
        start = end;
    }
    debug_assert_eq!(start, m, "monotone assignment must cover the prefix");
    let mut tasks: Vec<ContigTask<'_, M::State>> = make_tasks(shards, states, lw, &ranges)
        .into_iter()
        .map(|chunk| ContigTask {
            chunk,
            costs: Vec::new(),
            wall_s: 0.0,
        })
        .collect();
    let per_shard_threads = (ctx.pool.n_threads() / k).max(1);
    let (kalman, use_batch) = (ctx.kalman, ctx.batch);
    ctx.pool.for_shards(&mut tasks, |_, task| {
        let chunk = &mut task.chunk;
        if chunk.states.is_empty() {
            return;
        }
        let t0 = Instant::now();
        let local = ThreadPool::new(per_shard_threads);
        let shard_ctx = StepCtx {
            pool: &local,
            kalman,
            batch: use_batch,
        };
        if want_costs {
            // Exact per-particle costs via the shared scoped core.
            task.costs.reserve(chunk.states.len());
            let (lw, costs) = (&mut chunk.lw, &mut task.costs);
            step_scoped(
                model,
                chunk.heap,
                chunk.states,
                chunk.base,
                t,
                seed,
                observe,
                &shard_ctx,
                |j, w, c| {
                    lw[j] += w;
                    costs.push(c);
                },
            );
        } else {
            let winc = step_run(
                model, chunk.heap, chunk.states, t, seed, observe, chunk.base, &shard_ctx,
            );
            batch::accumulate(chunk.lw, &winc);
        }
        task.wall_s = t0.elapsed().as_secs_f64();
    });
    for (s, task) in tasks.iter().enumerate() {
        walls.add_shard(Phase::Propagate, s, task.wall_s);
    }
    if let Some(rc) = raw_cost.as_deref_mut() {
        for task in tasks {
            let base = task.chunk.base;
            for (j, c) in task.costs.into_iter().enumerate() {
                rc[base + j] = c;
            }
        }
    }
}

/// Particles a worker propagates between donation checks under the
/// work-stealing executor. Small enough that a tail worker notices hungry
/// siblings quickly; large enough that the `wanted` check (two relaxed
/// atomic loads) is noise.
const STEAL_CHUNK: usize = 8;

/// Speculative alive-PF attempts drawn per pending slot on the *first*
/// retry round of a generation, before any survival-rate evidence exists.
/// The per-slot retry streams ([`alive_retry_rng`]) make every attempt's
/// randomness independent of how many are drawn, so a round can propagate
/// several attempts per slot and keep only each slot's first survivor —
/// identical output and attempt totals for **any** window size, a
/// fraction of the serialized ancestor-import barriers in low-survival
/// regimes. First attempts (attempt counter 0) still run one per slot: in
/// the common everyone-survives regime speculation would only waste
/// propagation. Later retry rounds adapt the window to the generation's
/// observed retry survival rate: the expected attempts-per-survivor
/// (`ceil(retry attempts / retry survivors)` so far) is exactly the
/// window that makes one more round suffice on average, clamped to
/// [`ALIVE_WINDOW_MAX`]; while no retry has survived yet the window
/// instead doubles geometrically toward the cap.
pub(crate) const ALIVE_WINDOW_INIT: usize = 4;

/// Upper clamp on the adaptive speculative window: bounds wasted
/// overshoot propagation after a slot's first survivor (at most
/// `ALIVE_WINDOW_MAX - 1` discarded attempts per slot per round) and the
/// transient per-round job memory.
pub(crate) const ALIVE_WINDOW_MAX: usize = 32;

/// One shard's work under the work-stealing executor.
struct StealWork<'a, S> {
    shard: usize,
    heap: &'a mut Heap,
    runs: Vec<ShardRun<S>>,
    /// Recycled scratch heaps available for this shard's donations
    /// (chunks, slots, and labels retained from earlier generations).
    spares: Vec<Heap>,
    /// Worker-clocked propagate wall seconds for this shard's own queue,
    /// donation extraction time excluded (out). A worker's thieving time
    /// after its queues run dry is added to its group's first shard.
    wall_s: f64,
    /// Worker-clocked wall seconds spent extracting donations into
    /// scratch heaps — the steal-donate phase (out).
    donate_s: f64,
}

/// A donated package: tail particles extracted into a scratch heap by the
/// victim (who holds the home shard's `&mut`), propagated by whichever
/// worker takes it from the yard.
struct StolenBatch<S: Payload> {
    home: usize,
    /// Global index of `states[0]` (the segment is contiguous).
    base: usize,
    states: Vec<Lazy<S>>,
    heap: Heap,
}

/// A stolen batch the thief finished propagating, awaiting transplant-back.
struct FinishedBatch<S: Payload> {
    home: usize,
    base: usize,
    states: Vec<Lazy<S>>,
    winc: Vec<f64>,
    hints: Vec<f64>,
    /// Thief-measured cost (wall seconds + scratch-heap op charge).
    cost: f64,
    heap: Heap,
}

/// Extract a contiguous tail segment into a scratch heap (a recycled
/// spare when the pool has one, else a fresh bump-only heap) and donate
/// it. The victim performs the extraction under its own `&mut` — the only
/// way particles can leave a shard — and releases the home handles; the
/// segment now lives entirely in the scratch heap.
fn donate_segment<S: Payload>(
    heap: &mut Heap,
    home: usize,
    base: usize,
    seg: Vec<Lazy<S>>,
    yard: &StealYard<StolenBatch<S>>,
    spares: &mut Vec<Heap>,
) {
    debug_assert!(!seg.is_empty());
    let mut scratch = spares.pop().unwrap_or_else(|| heap.scratch());
    let moved: Vec<Lazy<S>> = seg.iter().map(|st| heap.extract_into(st, &mut scratch)).collect();
    for st in seg {
        heap.release(st);
    }
    yard.donate(StolenBatch {
        home,
        base,
        states: moved,
        heap: scratch,
    });
}

/// Fraction of `run`'s particles whose lineage root is still *private*
/// (owning-reference count ≤ 1): a cheap O(run) probe for how little of
/// the run's ancestry a donation would have to sever. A root that was
/// written since the last resampling is unshared, so its spine
/// transplants without duplicating anything a sibling keeps; a root
/// still shared with same-shard siblings means the donation round trip
/// eagerly copies the shared ancestry on both legs.
fn private_fraction<S>(heap: &Heap, run: &ShardRun<S>) -> f64 {
    if run.states.is_empty() {
        return 0.0;
    }
    let private = run
        .states
        .iter()
        .filter(|st| heap.shared_count(st.raw().obj) <= 1)
        .count();
    private as f64 / run.states.len() as f64
}

/// Donate about half of this shard's pending particles. Candidate runs
/// are everything strictly after the worker's cursor, ranked
/// **shared-ancestor-aware**: the run with the highest
/// [`private_fraction`] goes first (ties keep the old farthest-from-the-
/// cursor order), so donations prefer lineages that are already private
/// and cut the eager-copy transplant bill that donating shared ancestry
/// pays. Oversized picks donate their tail split; if the budget outlives
/// the later runs, the current run's own tail is split, always keeping
/// at least one unprocessed particle so the owner cannot be left
/// spinning on an empty run. `r_idx`/`i` locate the worker's cursor;
/// everything at or before it is already processed and never donated.
/// Selection only decides *where* particles propagate — results land by
/// global index — so output is identical for any donation policy.
#[allow(clippy::too_many_arguments)]
fn donate_tail<S: Payload>(
    heap: &mut Heap,
    runs: &mut Vec<ShardRun<S>>,
    r_idx: usize,
    i: usize,
    steal_min: usize,
    shard: usize,
    yard: &StealYard<StolenBatch<S>>,
    spares: &mut Vec<Heap>,
) {
    let here = runs[r_idx].states.len() - i;
    let later: usize = runs[r_idx + 1..].iter().map(|r| r.states.len()).sum();
    let pending = here + later;
    if pending < steal_min {
        return;
    }
    let mut remaining = pending / 2;
    while remaining > 0 && runs.len() - 1 > r_idx {
        // Rank the donatable whole runs by lineage privateness; strict
        // `>` keeps the farthest run on ties (the pre-ranking policy).
        let mut best = runs.len() - 1;
        let mut best_score = private_fraction(heap, &runs[best]);
        for j in (r_idx + 1..runs.len() - 1).rev() {
            let score = private_fraction(heap, &runs[j]);
            if score > best_score {
                best = j;
                best_score = score;
            }
        }
        let len = runs[best].states.len();
        if len <= remaining {
            let run = runs.remove(best);
            debug_assert!(run.winc.is_empty(), "donating a propagated run");
            remaining -= len;
            donate_segment(heap, shard, run.base, run.states, yard, spares);
        } else {
            let run = &mut runs[best];
            let at = len - remaining;
            let seg = run.states.split_off(at);
            donate_segment(heap, shard, run.base + at, seg, yard, spares);
            return;
        }
    }
    if remaining > 0 {
        // Only the current run remains: split its own tail, keeping one
        // particle for the owner.
        let spare = (runs[r_idx].states.len() - i).saturating_sub(1);
        let take = remaining.min(spare);
        if take > 0 {
            let run = &mut runs[r_idx];
            let at = run.states.len() - take;
            let seg = run.states.split_off(at);
            donate_segment(heap, shard, run.base + at, seg, yard, spares);
        }
    }
}

/// Drain one shard's run queue in [`STEAL_CHUNK`]-sized slices, donating
/// tail particles whenever the yard reports hungry workers. With
/// `want_costs`, particles are propagated one scoped call at a time so
/// every kept particle gets an *exact* measured cost in `run.costs`
/// (donation extractions are scheduling overhead and deliberately
/// excluded from any particle's cost). Donation extraction wall time
/// accumulates into `donate_s` so the caller can report the drain's
/// propagate wall net of the steal-donate phase.
#[allow(clippy::too_many_arguments)]
fn drain_own_queue<M: SmcModel + Sync>(
    model: &M,
    shard: usize,
    heap: &mut Heap,
    runs: &mut Vec<ShardRun<M::State>>,
    yard: &StealYard<StolenBatch<M::State>>,
    steal_min: usize,
    t: usize,
    seed: u64,
    observe: bool,
    shard_ctx: &StepCtx,
    want_costs: bool,
    spares: &mut Vec<Heap>,
    donate_s: &mut f64,
) {
    if runs.is_empty() {
        return;
    }
    let mut r_idx = 0;
    // Sticky steal-demand flag: until some worker goes hungry, process in
    // geometrically shrinking half-run slices (amortizing per-call batch
    // overhead back toward the whole-run call); once demand appears —
    // which means the generation is in its tail — drop to [`STEAL_CHUNK`]
    // so donations stay responsive. (Cost scoping forces single-particle
    // slices; the chunking never changes output, only call granularity.)
    let mut hungry = false;
    while r_idx < runs.len() {
        let mut i = 0;
        loop {
            if yard.wanted() {
                hungry = true;
                let d0 = Instant::now();
                donate_tail(heap, runs, r_idx, i, steal_min, shard, yard, spares);
                *donate_s += d0.elapsed().as_secs_f64();
            }
            let len_now = runs[r_idx].states.len();
            if i >= len_now {
                break;
            }
            let rem = len_now - i;
            let len = if want_costs {
                1
            } else if hungry {
                STEAL_CHUNK.min(rem)
            } else {
                (rem.div_ceil(2)).max(STEAL_CHUNK).min(rem)
            };
            let run = &mut runs[r_idx];
            // Per-particle RNG streams (keyed by `run.base + global
            // offset`) make the chunked calls produce exactly the
            // single-call results.
            if want_costs {
                // One particle through the shared scoped core, so the
                // donation poll above still runs between particles.
                let (winc, costs) = (&mut run.winc, &mut run.costs);
                step_scoped(
                    model,
                    heap,
                    &mut run.states[i..i + 1],
                    run.base + i,
                    t,
                    seed,
                    observe,
                    shard_ctx,
                    |_, w, c| {
                        winc.push(w);
                        costs.push(c);
                    },
                );
            } else {
                let winc = step_run(
                    model,
                    heap,
                    &mut run.states[i..i + len],
                    t,
                    seed,
                    observe,
                    run.base + i,
                    shard_ctx,
                );
                run.winc.extend(winc);
            }
            i += len;
        }
        r_idx += 1;
    }
}

/// Propagate + weight the population under the current assignment on the
/// **work-stealing executor** (K > 1): per-shard run queues drained by one
/// worker each, idle workers stealing tail particles from the heaviest
/// remaining queues via scratch-heap donation (see the module docs).
/// Results land exactly where [`propagate_assigned`] would put them —
/// stolen particles are transplanted back to their home shard at the
/// barrier — so `assign` is unchanged and output is bit-identical with
/// stealing on or off. When `raw_cost` is given, it receives per-particle
/// measured costs (NAN where the caller's slice prefix excludes a slot):
/// *exact* scoped measurements for every home-kept particle, and the
/// thief-measured batch cost apportioned by `cost_hint` within each
/// stolen batch (the hint fallback — a batch is one measurement). Each
/// reclaimed scratch heap's own peak is summed into the generation's
/// scratch residency and folded into `scratch_peak_bytes` on shard 0, so
/// steal-on transient bytes are accounted exactly. Reclaimed scratches
/// are recycled into `scratch_pools` (one pool per home shard —
/// `Heap::recycle_scratch` keeps chunks, slots, and labels), so repeat
/// donations reuse storage instead of paying fresh system allocations.
/// Returns the global indices of stolen particles.
#[allow(clippy::too_many_arguments)]
pub(crate) fn propagate_stealing<M: SmcModel + Sync>(
    model: &M,
    shards: &mut [Heap],
    states: &mut [Lazy<M::State>],
    lw: &mut [f64],
    assign: &[usize],
    t: usize,
    seed: u64,
    observe: bool,
    ctx: &StepCtx,
    steal_min: usize,
    mut raw_cost: Option<&mut [f64]>,
    scratch_pools: &mut [Vec<Heap>],
    walls: &mut PhaseWalls,
) -> Vec<usize> {
    let k = shards.len();
    debug_assert!(k > 1, "stealing requires multiple shards");
    debug_assert_eq!(states.len(), lw.len());
    debug_assert_eq!(states.len(), assign.len());
    let want_costs = raw_cost.is_some();
    let steal_min = steal_min.max(2);
    // Gather each shard's particles as maximal runs of consecutive global
    // indices (the same decomposition as `propagate_assigned`).
    let runs_by_shard = gather_runs(states, assign, k);
    // One yard worker per OS worker: group shards contiguously so each
    // group is drained by exactly one worker, which then turns thief.
    let w = ctx.pool.n_threads().min(k).max(1);
    debug_assert_eq!(scratch_pools.len(), k);
    let mut flat: Vec<StealWork<'_, M::State>> = shards
        .iter_mut()
        .zip(runs_by_shard)
        .enumerate()
        .map(|(s, (heap, runs))| StealWork {
            shard: s,
            heap,
            runs,
            spares: std::mem::take(&mut scratch_pools[s]),
            wall_s: 0.0,
            donate_s: 0.0,
        })
        .collect();
    let per = flat.len().div_ceil(w);
    let mut groups: Vec<Vec<StealWork<'_, M::State>>> = Vec::with_capacity(w);
    while !flat.is_empty() {
        let rest = flat.split_off(per.min(flat.len()));
        groups.push(std::mem::replace(&mut flat, rest));
    }
    let n_workers = groups.len();
    let yard: StealYard<StolenBatch<M::State>> = StealYard::new(n_workers);
    let done: Mutex<Vec<FinishedBatch<M::State>>> = Mutex::new(Vec::new());
    let per_worker_threads = (ctx.pool.n_threads() / n_workers).max(1);
    let (kalman, use_batch) = (ctx.kalman, ctx.batch);
    ctx.pool.for_shards(&mut groups, |_, group| {
        // Unwind safety: a panicking worker never parks, so without this
        // guard a model panic here would leave parked siblings waiting
        // for `idle == workers` forever instead of propagating.
        let _abort_on_panic = yard.panic_guard();
        let local = ThreadPool::new(per_worker_threads);
        let shard_ctx = StepCtx {
            pool: &local,
            kalman,
            batch: use_batch,
        };
        for work in group.iter_mut() {
            let t0 = Instant::now();
            let mut donate_s = 0.0;
            drain_own_queue(
                model, work.shard, work.heap, &mut work.runs, &yard, steal_min, t, seed,
                observe, &shard_ctx, want_costs, &mut work.spares, &mut donate_s,
            );
            // The drain's wall net of donation extraction is propagate
            // time; the extraction itself is the steal-donate phase.
            work.wall_s = (t0.elapsed().as_secs_f64() - donate_s).max(0.0);
            work.donate_s = donate_s;
        }
        // Own queues drained: turn thief until the generation completes.
        // Thieved-batch propagation is clocked per batch (park time in
        // the yard is idle, not work) and attributed to the thief
        // worker's first shard — stolen work runs wherever a worker is
        // idle; per-home attribution would misstate who was busy.
        let mut thief_s = 0.0;
        while let Some(b) = yard.take() {
            let StolenBatch {
                home,
                base,
                mut states,
                mut heap,
            } = b;
            let t0 = Instant::now();
            let scope = heap.begin_scope();
            let winc = step_run(model, &mut heap, &mut states, t, seed, observe, base, &shard_ctx);
            let hints: Vec<f64> = if want_costs {
                states.iter_mut().map(|st| model.cost_hint(&mut heap, st)).collect()
            } else {
                Vec::new()
            };
            let delta = heap.end_scope(scope);
            let batch_wall = t0.elapsed().as_secs_f64();
            thief_s += batch_wall;
            let cost = scoped_cost(batch_wall, &delta);
            done.lock().unwrap().push(FinishedBatch {
                home,
                base,
                states,
                winc,
                hints,
                cost,
                heap,
            });
        }
        group[0].wall_s += thief_s;
    });
    // Collect home-side results (and return unused spares to the pools);
    // this also drops the shard borrows.
    let mut home_runs: Vec<Vec<ShardRun<M::State>>> = (0..k).map(|_| Vec::new()).collect();
    for group in groups {
        for mut work in group {
            walls.add_shard(Phase::Propagate, work.shard, work.wall_s);
            walls.add_shard(Phase::StealDonate, work.shard, work.donate_s);
            home_runs[work.shard].extend(work.runs);
            scratch_pools[work.shard].append(&mut work.spares);
        }
    }
    // Transplant stolen results back into their home shards — one
    // reclaimer per home shard, exclusive `&mut`, deterministic batch
    // order — draining and absorbing each scratch heap.
    // (base, home-shard states, winc, hints, thief-measured cost).
    type ReclaimedBatch<S> = (usize, Vec<Lazy<S>>, Vec<f64>, Vec<f64>, f64);
    struct Reclaim<'a, S: Payload> {
        heap: &'a mut Heap,
        batches: Vec<FinishedBatch<S>>,
        back: Vec<ReclaimedBatch<S>>,
        /// Summed peak residency of the scratch heaps this shard
        /// reclaimed (exact per scratch; see `scratch_peak_bytes`).
        scratch_peak_sum: usize,
        /// Drained scratch heaps, recycled for the shard's next
        /// donations.
        recycled: Vec<Heap>,
        /// Worker-clocked wall seconds draining scratches back (out).
        wall_s: f64,
    }
    let mut finished = done.into_inner().unwrap();
    finished.sort_by_key(|b| (b.home, b.base));
    let mut by_home: Vec<Vec<FinishedBatch<M::State>>> = (0..k).map(|_| Vec::new()).collect();
    for b in finished {
        by_home[b.home].push(b);
    }
    let mut reclaims: Vec<Reclaim<'_, M::State>> = shards
        .iter_mut()
        .zip(by_home)
        .map(|(heap, batches)| Reclaim {
            heap,
            batches,
            back: Vec::new(),
            scratch_peak_sum: 0,
            recycled: Vec::new(),
            wall_s: 0.0,
        })
        .collect();
    ctx.pool.for_shards(&mut reclaims, |_, rc| {
        if rc.batches.is_empty() {
            return;
        }
        let t0 = Instant::now();
        for b in std::mem::take(&mut rc.batches) {
            let FinishedBatch {
                base,
                states: stolen,
                winc,
                hints,
                cost,
                heap: mut scratch,
                ..
            } = b;
            let mut back: Vec<Lazy<M::State>> = Vec::with_capacity(stolen.len());
            for st in &stolen {
                back.push(scratch.extract_into(st, rc.heap));
            }
            for st in stolen {
                scratch.release(st);
            }
            scratch.sweep_memos();
            rc.scratch_peak_sum += scratch.metrics.peak_bytes;
            rc.heap.absorb_counters(&scratch);
            scratch.recycle_scratch();
            rc.recycled.push(scratch);
            rc.back.push((base, back, winc, hints, cost));
        }
        rc.wall_s = t0.elapsed().as_secs_f64();
    });
    // Scatter everything in global index order; home-kept particles carry
    // exact scoped costs, stolen batches apportion the thief's batch
    // measurement by hint.
    let mut stolen_idx: Vec<usize> = Vec::new();
    let mut gen_scratch = 0usize;
    for runs in home_runs {
        for run in runs {
            debug_assert_eq!(run.states.len(), run.winc.len());
            let base = run.base;
            batch::accumulate(&mut lw[base..base + run.winc.len()], &run.winc);
            if let Some(rc) = raw_cost.as_deref_mut() {
                debug_assert_eq!(run.costs.len(), run.states.len());
                for (j, c) in run.costs.iter().enumerate() {
                    rc[base + j] = *c;
                }
            }
            for (j, st) in run.states.into_iter().enumerate() {
                states[base + j] = st;
            }
        }
    }
    for (s, mut rc_item) in reclaims.into_iter().enumerate() {
        walls.add_shard(Phase::ScratchReclaim, s, rc_item.wall_s);
        gen_scratch += rc_item.scratch_peak_sum;
        scratch_pools[s].append(&mut rc_item.recycled);
        for (base, back, winc, hints, cost) in rc_item.back {
            let hint_sum = clamped_hint_sum(hints.iter());
            batch::accumulate(&mut lw[base..base + winc.len()], &winc);
            if let Some(rc) = raw_cost.as_deref_mut() {
                apportion_cost(rc, base, cost, &hints, hint_sum);
            }
            for (j, st) in back.into_iter().enumerate() {
                states[base + j] = st;
                stolen_idx.push(base + j);
            }
        }
    }
    // Fold this generation's summed scratch residency into the dedicated
    // gauge (recorded on shard 0, like the barrier peak samples) — the
    // bytes that lived in no shard's `peak_bytes` between donation and
    // reclaim.
    if gen_scratch > 0 {
        shards[0].metrics.note_scratch_peak(gen_scratch);
    }
    stolen_idx.sort_unstable();
    stolen_idx
}

/// A transplant operation for [`ThreadPool::for_pairs`]: (source shard,
/// destination shard, (ancestor index, transplanted handle — filled by
/// the executor)).
type TransplantOp<S> = (usize, usize, (usize, Lazy<S>));

/// Replace the population by the offspring given by `anc`, landing each
/// offspring on the shard named by `new_assign` (one O(1) `deep_copy`
/// per same-shard offspring; one transplant per *distinct* (ancestor,
/// destination-shard) pair, executed concurrently for disjoint (src,
/// dst) pairs), release the parent generation, and sweep memos. Updates
/// `assign` to `new_assign` and returns the number of transplant
/// operations executed.
fn resample_population<S: Payload>(
    shards: &mut [Heap],
    pool: &ThreadPool,
    states: &mut Vec<Lazy<S>>,
    anc: &[usize],
    assign: &mut Vec<usize>,
    new_assign: Vec<usize>,
    walls: &mut PhaseWalls,
) -> usize {
    let t_all = Instant::now();
    let n = states.len();
    debug_assert_eq!(anc.len(), n);
    debug_assert_eq!(new_assign.len(), n);
    // Transplant plan: one op per distinct (ancestor, destination) pair
    // whose destination differs from the ancestor's home shard. All
    // duplicate offspring of that ancestor on that destination share the
    // single transplanted lineage via O(1) lazy copies. BTreeSet keeps
    // op order deterministic.
    let pair_set: std::collections::BTreeSet<(usize, usize)> = anc
        .iter()
        .zip(&new_assign)
        .filter(|&(&a, &dst)| dst != assign[a])
        .map(|(&a, &dst)| (a, dst))
        .collect();
    let mut ops: Vec<TransplantOp<S>> = pair_set
        .into_iter()
        .map(|(a, dst)| (assign[a], dst, (a, Lazy::NULL)))
        .collect();
    let n_ops = ops.len();
    let t_tr = Instant::now();
    {
        let states_ref: &[Lazy<S>] = states.as_slice();
        pool.for_pairs(shards, &mut ops, |op, src, dst| {
            let parent = states_ref[op.0];
            op.1 = src.extract_into(&parent, dst);
        });
    }
    let transplant_s = t_tr.elapsed().as_secs_f64();
    let transplanted: std::collections::BTreeMap<(usize, usize), Lazy<S>> = ops
        .into_iter()
        .map(|(_, dst, (a, h))| ((a, dst), h))
        .collect();
    let mut new_states: Vec<Lazy<S>> = Vec::with_capacity(n);
    for (i, &a) in anc.iter().enumerate() {
        let dst = new_assign[i];
        let child = if dst == assign[a] {
            let parent = states[a];
            shards[dst].deep_copy(&parent)
        } else {
            let moved = transplanted[&(a, dst)];
            shards[dst].deep_copy(&moved)
        };
        new_states.push(child);
    }
    // Barrier sample at the resampling spike: parents, transplants, and
    // offspring are all simultaneously live right here.
    sample_global_peak(shards);
    for ((_, dst), h) in transplanted {
        shards[dst].release(h);
    }
    let old = std::mem::replace(states, new_states);
    for (i, s) in old.into_iter().enumerate() {
        shards[assign[i]].release(s);
    }
    *assign = new_assign;
    for h in shards.iter_mut() {
        h.sweep_memos();
    }
    // Coordinator spans: the cross-shard transplant round versus
    // everything else resampling does (offspring copies, releases,
    // memo sweeps).
    walls.add(Phase::Transplant, transplant_s);
    walls.add(Phase::Resample, t_all.elapsed().as_secs_f64() - transplant_s);
    n_ops
}

/// Plan the offspring → shard assignment for this resampling step and
/// execute it: the rebalancer entry point. `pin_last` forces the final
/// slot onto a fixed shard (particle Gibbs keeps the reference
/// trajectory on the conditional slot's shard) — applied *after*
/// planning, so the migration count reflects what actually executed.
/// Returns the executed transplant-op count under an active rebalancing
/// policy, and 0 for policy `off` (whose boundary crossings are the
/// static partition's inherent transplants, counted by
/// `HeapMetrics::transplants`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_and_resample<S: Payload>(
    policy: RebalancePolicy,
    threshold: f64,
    shards: &mut [Heap],
    pool: &ThreadPool,
    states: &mut Vec<Lazy<S>>,
    anc: &[usize],
    assign: &mut Vec<usize>,
    tracker: &mut CostTracker,
    pin_last: Option<usize>,
    walls: &mut PhaseWalls,
) -> usize {
    let k = shards.len();
    let t_plan = Instant::now();
    let plan = {
        // Migration cost model: the ancestor's reachable-subgraph size —
        // the very set `extract_into` would walk — times a per-object
        // transplant cost. Consulted lazily (Budget policy only).
        let migration_cost = |a: usize| {
            shards[assign[a]].reachable_objects(&[states[a].raw()]) as f64 * TRANSPLANT_COST_S
        };
        plan_offspring(
            policy,
            threshold,
            anc,
            assign.as_slice(),
            tracker.costs(),
            k,
            migration_cost,
        )
    };
    let mut new_assign = plan.assign;
    if let Some(s_ref) = pin_last {
        if let Some(last) = new_assign.last_mut() {
            *last = s_ref;
        }
    }
    walls.add(Phase::RebalancePlan, t_plan.elapsed().as_secs_f64());
    tracker.inherit(anc);
    let executed = resample_population(shards, pool, states, anc, assign, new_assign, walls);
    if policy == RebalancePolicy::Off {
        0
    } else {
        executed
    }
}

/// One alive-PF generation under the per-slot retry-stream contract
/// ([`alive_retry_rng`], contract v2): re-propose each slot until it
/// survives, drawing a fresh uniform ancestor per retry (Del Moral et al.
/// 2015). Runs in *rounds*: the coordinator draws every pending slot's
/// stream (the ancestor redraw is the stream's first draw, so the plan is
/// deterministic and needs no heap access), imports each foreign retry
/// ancestor once per distinct (ancestor, destination-shard) pair —
/// concurrently for disjoint pairs — and the attempts themselves run
/// shard-parallel, one `&mut Heap` per worker. Retry rounds draw an
/// adaptive window of speculative attempts per pending slot — seeded at
/// [`ALIVE_WINDOW_INIT`], re-estimated each round from the generation's
/// observed retry survival rate, capped at [`ALIVE_WINDOW_MAX`]
/// (first-attempt rounds draw one); each slot keeps its first surviving
/// attempt and discards the rest uncounted. Because every slot's
/// attempt sequence depends only on its own streams and the (K-invariant)
/// parent values, the surviving states, weights, and the *total attempt
/// count* are bit-identical for every K. Same-shard retries keep the O(1)
/// lazy `deep_copy`; only cross-shard retry ancestors pay the transplant,
/// and duplicates share one per round.
///
/// Replaces `states` with the survivors (slot → shard assignment is
/// unchanged), adds weight increments into `lw` in slot order, sweeps the
/// shards, and returns the attempts made. Panics (deterministically, on
/// the lowest slot) when a slot exhausts 10k attempts.
///
/// When `raw_cost` is given, every *attempt* (retries included) is
/// propagated in its own metrics scope, and each slot accumulates the
/// exact measured cost of all its attempts this generation — so the
/// rebalancer's [`CostTracker`] learns CRBD-style retry skew from exact
/// per-particle measurements and can migrate the expensive lineages at
/// the next resampling barrier.
#[allow(clippy::too_many_arguments)]
pub(crate) fn alive_generation<M: SmcModel + Sync>(
    model: &M,
    shards: &mut [Heap],
    pool: &ThreadPool,
    states: &mut [Lazy<M::State>],
    lw: &mut [f64],
    assign: &[usize],
    t: usize,
    seed: u64,
    mut raw_cost: Option<&mut [f64]>,
    walls: &mut PhaseWalls,
) -> usize {
    let n = states.len();
    let k = shards.len();
    let want_costs = raw_cost.is_some();
    let mut attempt = vec![0usize; n];
    let mut survivors: Vec<Lazy<M::State>> = vec![Lazy::NULL; n];
    let mut winc_out = vec![0.0f64; n];
    let mut total_attempts = 0usize;
    struct AliveJob<S> {
        slot: usize,
        /// Attempt offset within this round's speculative window (the
        /// slot's attempt counter plus `off` names the retry stream).
        off: usize,
        parent: Lazy<S>,
        rng: Pcg64,
        winc: f64,
        survived: bool,
        child: Lazy<S>,
        /// Exact measured cost of this attempt (scoped; 0 unless asked).
        cost: f64,
    }
    struct AliveTask<'a, S> {
        shard: usize,
        heap: &'a mut Heap,
        jobs: Vec<AliveJob<S>>,
        /// Worker-clocked wall seconds for this shard's attempts (out).
        wall_s: f64,
    }
    // The pending set shrinks in place across rounds, so a long retry
    // tail costs O(pending) per round, not O(n).
    let mut pending: Vec<usize> = (0..n).collect();
    // Adaptive speculative window (retry rounds only): seeded at
    // [`ALIVE_WINDOW_INIT`], then re-estimated from this generation's
    // observed retry survival. Window choice never reaches the output —
    // the per-slot streams and the first-survivor rule make any window
    // produce identical survivors and attempt totals — so adapting it is
    // purely a scheduling decision.
    let mut window = ALIVE_WINDOW_INIT;
    let mut retry_attempts = 0usize;
    let mut retry_survivors = 0usize;
    while !pending.is_empty() {
        // Slots pend together: a slot leaves the set the round it
        // survives, and every still-pending slot consumed the whole
        // window, so pending attempt counters stay uniform — which is
        // what lets one window size serve the round.
        debug_assert!(
            pending.iter().all(|&i| attempt[i] == attempt[pending[0]]),
            "pending attempt counters diverged"
        );
        let first_round = attempt[pending[0]] == 0;
        let window_now = if first_round { 1 } else { window };
        // 1. Per-slot streams: ancestor redraw + the attempt's RNG state,
        //    `window` speculative attempts per pending slot.
        let mut draws: Vec<(usize, usize, usize, Pcg64)> =
            Vec::with_capacity(pending.len() * window_now);
        for &i in &pending {
            for off in 0..window_now {
                let att = attempt[i] + off;
                let mut rng = alive_retry_rng(seed, t, i, att);
                let a = if att == 0 {
                    i
                } else {
                    rng.below(n as u64) as usize
                };
                draws.push((i, off, a, rng));
            }
        }
        // 2. Import foreign retry ancestors: one transplant per distinct
        //    (ancestor, destination) pair (BTreeSet: deterministic op
        //    order), disjoint pairs concurrently.
        let pair_set: std::collections::BTreeSet<(usize, usize)> = draws
            .iter()
            .filter(|(i, _, a, _)| assign[*a] != assign[*i])
            .map(|(i, _, a, _)| (*a, assign[*i]))
            .collect();
        let mut ops: Vec<TransplantOp<M::State>> = pair_set
            .into_iter()
            .map(|(a, dst)| (assign[a], dst, (a, Lazy::NULL)))
            .collect();
        let t_tr = Instant::now();
        {
            let states_ref: &[Lazy<M::State>] = states;
            pool.for_pairs(shards, &mut ops, |op, src, dst| {
                let parent = states_ref[op.0];
                op.1 = src.extract_into(&parent, dst);
            });
        }
        walls.add(Phase::Transplant, t_tr.elapsed().as_secs_f64());
        let imported: std::collections::BTreeMap<(usize, usize), Lazy<M::State>> =
            ops.into_iter().map(|(_, dst, (a, h))| ((a, dst), h)).collect();
        // 3. Shard-parallel attempts.
        let mut jobs_by_shard: Vec<Vec<AliveJob<M::State>>> = (0..k).map(|_| Vec::new()).collect();
        for (i, off, a, rng) in draws {
            let dst = assign[i];
            let parent = if assign[a] == dst {
                states[a]
            } else {
                imported[&(a, dst)]
            };
            jobs_by_shard[dst].push(AliveJob {
                slot: i,
                off,
                parent,
                rng,
                winc: 0.0,
                survived: false,
                child: Lazy::NULL,
                cost: 0.0,
            });
        }
        // Only shards with work get a task (and a worker): a retry tail
        // concentrated on one shard runs inline, without fanning scoped
        // threads over k - 1 idle shards.
        let mut tasks: Vec<AliveTask<'_, M::State>> = shards
            .iter_mut()
            .zip(jobs_by_shard)
            .enumerate()
            .filter(|(_, (_, jobs))| !jobs.is_empty())
            .map(|(s, (heap, jobs))| AliveTask {
                shard: s,
                heap,
                jobs,
                wall_s: 0.0,
            })
            .collect();
        pool.for_shards(&mut tasks, |_, task| {
            let t0 = Instant::now();
            for job in task.jobs.iter_mut() {
                let scope = want_costs.then(|| (Instant::now(), task.heap.begin_scope()));
                let mut child = task.heap.deep_copy(&job.parent);
                let label = child.label();
                let winc = task
                    .heap
                    .with_context(label, |h| model.step(h, &mut child, t, &mut job.rng, true));
                if model.alive(winc) {
                    job.survived = true;
                    job.winc = winc;
                    job.child = child;
                } else {
                    task.heap.release(child);
                }
                if let Some((t0, scope)) = scope {
                    let delta = task.heap.end_scope(scope);
                    job.cost = scoped_cost(t0.elapsed().as_secs_f64(), &delta);
                }
            }
            task.wall_s = t0.elapsed().as_secs_f64();
        });
        for task in tasks.iter() {
            walls.add_shard(Phase::Propagate, task.shard, task.wall_s);
        }
        // 4. Apply results in (slot, attempt) order — deterministic 10k
        //    bailout; every *counted* attempt's exact cost accumulates on
        //    its slot. Per slot, only attempts up to and including the
        //    first survivor count: later speculative attempts in the
        //    window are discarded (surviving children released) without
        //    touching the attempt total, so the totals match one-attempt
        //    rounds exactly.
        let mut round: Vec<AliveJob<M::State>> = Vec::new();
        for task in tasks {
            round.extend(task.jobs);
        }
        round.sort_by_key(|job| (job.slot, job.off));
        let attempts_before = total_attempts;
        let pending_before = pending.len();
        for job in round {
            let i = job.slot;
            if !survivors[i].is_null() {
                // Past this slot's first survivor: speculation overshoot.
                if job.survived {
                    shards[assign[i]].release(job.child);
                }
                continue;
            }
            total_attempts += 1;
            attempt[i] += 1;
            if let Some(rc) = raw_cost.as_deref_mut() {
                if rc[i].is_nan() {
                    rc[i] = job.cost;
                } else {
                    rc[i] += job.cost;
                }
            }
            if job.survived {
                survivors[i] = job.child;
                winc_out[i] = job.winc;
            } else {
                assert!(
                    attempt[i] < 10_000,
                    "alive PF: no surviving particle after 10k attempts at t={t} (slot {i})"
                );
            }
        }
        pending.retain(|&i| survivors[i].is_null());
        // Adapt the next retry round's window to this generation's
        // observed retry survival (first-attempt evidence says nothing
        // about retry survival, so it is excluded). With survivors in
        // hand, the maximum-likelihood attempts-per-survivor is the
        // window that lets the average pending slot finish next round;
        // with none yet, double toward the cap so a dead zone costs
        // O(log) barriers instead of O(attempts).
        if !first_round {
            retry_attempts += total_attempts - attempts_before;
            retry_survivors += pending_before - pending.len();
            window = if retry_survivors == 0 {
                (window * 2).min(ALIVE_WINDOW_MAX)
            } else {
                retry_attempts
                    .div_ceil(retry_survivors)
                    .clamp(1, ALIVE_WINDOW_MAX)
            };
        }
        // Imported parent copies were only needed for this round.
        for ((_, dst), h) in imported {
            shards[dst].release(h);
        }
    }
    // Replace the population: install survivors (same assignment), release
    // parents on their shards, accumulate weights in slot order. This is
    // the alive PF's population-replacement step, so it lands in the
    // resample span.
    let t_rep = Instant::now();
    for i in 0..n {
        lw[i] += winc_out[i];
        let parent = std::mem::replace(&mut states[i], survivors[i]);
        shards[assign[i]].release(parent);
    }
    for h in shards.iter_mut() {
        h.sweep_memos();
    }
    walls.add(Phase::Resample, t_rep.elapsed().as_secs_f64());
    total_attempts
}

/// Run a particle filter (or forward simulation) for `cfg` over `model`
/// on a single heap — the K = 1 specialization of
/// [`run_filter_shards`].
///
/// A small end-to-end run on the linked-list model:
///
/// ```
/// use lazycow::config::{Model, RunConfig, Task};
/// use lazycow::heap::{CopyMode, Heap};
/// use lazycow::models::ListModel;
/// use lazycow::pool::ThreadPool;
/// use lazycow::smc::{run_filter, Method, StepCtx};
///
/// let model = ListModel::synthetic(10, 1);
/// let mut cfg = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
/// cfg.n_particles = 32;
/// cfg.n_steps = 10;
/// let pool = ThreadPool::new(1);
/// let ctx = StepCtx { pool: &pool, kalman: None, batch: true };
/// let mut heap = Heap::new(CopyMode::LazySro);
/// let r = run_filter(&model, &cfg, &mut heap, &ctx, Method::Bootstrap);
/// assert!(r.log_evidence.is_finite());
/// assert_eq!(r.series.len(), 10);
/// assert_eq!(heap.live_objects(), 0, "the filter releases everything");
/// ```
pub fn run_filter<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    heap: &mut Heap,
    ctx: &StepCtx,
    method: Method,
) -> FilterResult {
    run_filter_shards(model, cfg, std::slice::from_mut(heap), ctx, method)
}

/// Run a particle filter (or forward simulation) over `shards.len()`
/// heap shards. Output is seed-deterministic and identical for every
/// shard count and every rebalance policy.
///
/// A thin driver over [`FilterSession`](super::FilterSession): begin,
/// step every generation, finish. The session owns all cross-generation
/// state; this function only fixes the horizon.
pub fn run_filter_shards<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    shards: &mut [Heap],
    ctx: &StepCtx,
    method: Method,
) -> FilterResult {
    let t_max = cfg.n_steps.min(model.horizon());
    let mut session = super::FilterSession::begin(model, cfg, shards, ctx, method);
    for _ in 0..t_max {
        session.step(model, shards, ctx);
    }
    session.finish(model, shards)
}

/// Particle Gibbs with reference trajectory (conditional SMC) on a single
/// heap — the K = 1 specialization of [`run_particle_gibbs_shards`].
pub fn run_particle_gibbs<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    heap: &mut Heap,
    ctx: &StepCtx,
) -> Vec<FilterResult> {
    run_particle_gibbs_shards(model, cfg, std::slice::from_mut(heap), ctx)
}

/// Particle Gibbs with reference trajectory (conditional SMC), VBD's
/// method (Wigren et al. 2019, marginalized parameters live inside the
/// state's sufficient-statistic accumulators). Returns per-iteration
/// filter results. The inter-iteration single-particle copy is eager, per
/// the paper's §4 note; the reference trajectory lives on the shard that
/// owns the conditional slot `n - 1` — the rebalancer pins that slot
/// there — and a winner from another shard is transplanted there (the
/// transplant is itself an eager copy).
pub fn run_particle_gibbs_shards<M: SmcModel + Sync>(
    model: &M,
    cfg: &RunConfig,
    shards: &mut [Heap],
    ctx: &StepCtx,
) -> Vec<FilterResult> {
    assert!(!shards.is_empty(), "at least one heap shard");
    let n = cfg.n_particles;
    let k = shards.len();
    let t_max = cfg.n_steps.min(model.horizon());
    // Shard holding the conditional slot — and the reference trajectory.
    let s_ref = shard_of(n, k, n - 1);
    // Reference trajectory: handles for generations 0..=T (oldest first),
    // all owned by shard `s_ref`.
    let mut reference: Option<Vec<Lazy<M::State>>> = None;
    let mut results = Vec::new();
    if cfg.pg_iterations > 0 {
        // One session drives every iteration: `restart` re-initializes
        // the population under the iteration seed while the recycled
        // scratch pools carry over (the shards — and so the pooled
        // scratches' mode/backend — are fixed across iterations).
        let mut session = super::FilterSession::begin_gibbs(model, cfg, shards, ctx);
        for iter in 0..cfg.pg_iterations {
            if iter > 0 {
                let seed = cfg.seed.wrapping_add(iter as u64 * 0x9E37);
                session.restart(model, shards, ctx, seed);
            }
            // Conditional slot n-1 follows the reference when present.
            if let Some(r) = &reference {
                session.install_reference(shards, r);
            }
            for _ in 0..t_max {
                session.step_gibbs(model, shards, ctx, reference.as_deref());
            }
            let (result, chain) = session.finish_gibbs(model, shards, reference.take());
            reference = Some(chain);
            results.push(result);
        }
    }
    if let Some(old) = reference.take() {
        for h in old {
            shards[s_ref].release(h);
        }
    }
    for h in shards.iter_mut() {
        h.sweep_memos();
    }
    // No evacuation here: the populations are released, so there are no
    // survivors to relocate — trim alone reclaims the emptied chunks.
    // (Per-generation evacuation runs inside the session's barrier when
    // `evacuate_threshold` is set.)
    if let Some(keep) = cfg.decommit_watermark {
        trim_shards(shards, keep);
    }
    results
}

/// Disjoint `&mut` access to two different shards.
pub(crate) fn pair_mut<T>(xs: &mut [T], a: usize, b: usize) -> (&mut T, &mut T) {
    debug_assert_ne!(a, b);
    if a < b {
        let (lo, hi) = xs.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = xs.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}
