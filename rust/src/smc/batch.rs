//! Batched structure-of-arrays numeric kernels for the propagation and
//! weighting hot path.
//!
//! The sharded coordinator already materializes each generation as
//! contiguous global-index runs (one `&mut [Lazy<S>]` slice per shard-local
//! run), so the numeric phase can operate on plain `&[f64]` lanes gathered
//! from those runs: log-weight accumulation, observation log-pdfs, and the
//! per-generation weight reduction all become straight-line loops over
//! contiguous memory that the compiler autovectorizes.
//!
//! **Determinism contract.** Every kernel in this module is elementwise or
//! reduces in a fixed left-to-right order, and the per-lane arithmetic is
//! the *same expression sequence* as the scalar path it replaces
//! ([`normal_lpdf`] / [`poisson_lpmf`] per lane, [`weight_stats`] for the
//! reduction). Batch width and run fragmentation therefore never change a
//! single output bit: splitting a population into arbitrary sub-slices and
//! concatenating the results is bitwise identical to one whole-slice call.
//! That property is what lets `--batch on|off`, every shard count, and
//! every rebalance/steal schedule share one differential oracle (see
//! `tests/differential.rs`).

use crate::rng::{normal_lpdf, poisson_lpmf};
use crate::stats::weight_stats;

/// Lane-wise log-weight accumulate: `lw[i] += inc[i]`.
///
/// The scatter half of the fused accumulate/reduce pair — the coordinator
/// calls this once per contiguous shard-local run with the run's weight
/// increments. Panics if the slices disagree in length.
#[inline]
pub fn accumulate(lw: &mut [f64], inc: &[f64]) {
    assert_eq!(lw.len(), inc.len(), "accumulate: lane length mismatch");
    for (w, d) in lw.iter_mut().zip(inc) {
        *w += d;
    }
}

/// Fused accumulate + normalize + ESS over one population: adds `inc` into
/// `lw` lane-wise, then reduces with [`weight_stats`] (log mean weight +
/// normalized weights + effective sample size in a single pass). Returns
/// `(log mean weight, ess)`.
pub fn accumulate_weight_stats(lw: &mut [f64], inc: &[f64], out: &mut Vec<f64>) -> (f64, f64) {
    accumulate(lw, inc);
    weight_stats(lw, out)
}

/// Batched Gaussian observation log-density: `out[i] = log N(y; means[i],
/// sd²)`. One shared observation scored against a lane of per-particle
/// means — the LGSS/list-model weighting kernel. Each lane evaluates
/// exactly [`normal_lpdf`], so results are bit-identical to the scalar
/// path; the loop-invariant `ln sd` term is hoisted by the compiler, not
/// by algebraic rearrangement.
#[inline]
pub fn gaussian_lpdf(y: f64, means: &[f64], sd: f64, out: &mut [f64]) {
    assert_eq!(means.len(), out.len(), "gaussian_lpdf: lane length mismatch");
    for (o, m) in out.iter_mut().zip(means) {
        *o = normal_lpdf(y, *m, sd);
    }
}

/// Batched Poisson observation log-mass: `out[i] = log Poisson(y; rates[i])`.
/// One shared count observation scored against a lane of per-particle
/// rates. Each lane evaluates exactly [`poisson_lpmf`], so results are
/// bit-identical to the scalar path.
#[inline]
pub fn poisson_lpmf_lanes(y: u64, rates: &[f64], out: &mut [f64]) {
    assert_eq!(rates.len(), out.len(), "poisson_lpmf_lanes: lane length mismatch");
    for (o, r) in out.iter_mut().zip(rates) {
        *o = poisson_lpmf(y, *r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::stats::{ess, normalize_log_weights};

    /// Deterministic pseudo-random lanes for the property tests.
    fn lanes(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.gaussian(0.0, 3.0)).collect()
    }

    /// Sub-slice fragmentations exercised by the width/fragmentation
    /// properties: whole slice, singletons, and uneven runs.
    fn fragmentations(n: usize) -> Vec<Vec<usize>> {
        let mut cuts = vec![vec![n], vec![1; n]];
        let mut uneven = Vec::new();
        let (mut left, mut w) = (n, 1);
        while left > 0 {
            let take = w.min(left);
            uneven.push(take);
            left -= take;
            w = w * 2 + 1;
        }
        cuts.push(uneven);
        cuts
    }

    #[test]
    fn accumulate_matches_scalar_any_fragmentation() {
        for n in [1usize, 7, 64, 255] {
            let base = lanes(n, 11);
            let inc = lanes(n, 22);
            let mut whole = base.clone();
            accumulate(&mut whole, &inc);
            for cut in fragmentations(n) {
                let mut frag = base.clone();
                let mut at = 0;
                for len in cut {
                    accumulate(&mut frag[at..at + len], &inc[at..at + len]);
                    at += len;
                }
                for (a, b) in frag.iter().zip(&whole) {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
                }
                // And against the plainest possible scalar loop.
                for (i, w) in frag.iter().enumerate() {
                    assert_eq!(w.to_bits(), (base[i] + inc[i]).to_bits());
                }
            }
        }
    }

    #[test]
    fn gaussian_lanes_match_scalar_oracle_bitwise() {
        for n in [1usize, 5, 128, 301] {
            let means = lanes(n, 7);
            let mut out = vec![0.0; n];
            gaussian_lpdf(1.25, &means, 0.8f64.sqrt(), &mut out);
            for (i, o) in out.iter().enumerate() {
                let want = normal_lpdf(1.25, means[i], 0.8f64.sqrt());
                assert_eq!(o.to_bits(), want.to_bits(), "lane {i} of {n}");
            }
            // Fragmented evaluation is the same lanes.
            for cut in fragmentations(n) {
                let mut frag = vec![0.0; n];
                let mut at = 0;
                for len in cut {
                    let sub = &mut frag[at..at + len];
                    gaussian_lpdf(1.25, &means[at..at + len], 0.8f64.sqrt(), sub);
                    at += len;
                }
                for (a, b) in frag.iter().zip(&out) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    #[test]
    fn poisson_lanes_match_scalar_oracle_bitwise() {
        let mut rng = Pcg64::new(99);
        for n in [1usize, 9, 200] {
            let rates: Vec<f64> = (0..n).map(|_| rng.below(50) as f64 * 0.3).collect();
            for y in [0u64, 3, 17] {
                let mut out = vec![0.0; n];
                poisson_lpmf_lanes(y, &rates, &mut out);
                for (i, o) in out.iter().enumerate() {
                    assert_eq!(o.to_bits(), poisson_lpmf(y, rates[i]).to_bits(), "lane {i}");
                }
            }
        }
    }

    #[test]
    fn fused_accumulate_reduce_matches_unfused_bitwise() {
        for n in [1usize, 6, 97, 512] {
            let base = lanes(n, 31);
            let inc = lanes(n, 32);
            // Unfused reference: scalar accumulate, then the pre-existing
            // two-pass normalize + ESS.
            let mut lw_ref = base.clone();
            for (w, d) in lw_ref.iter_mut().zip(&inc) {
                *w += d;
            }
            let mut w_ref = Vec::new();
            let lmean_ref = normalize_log_weights(&lw_ref, &mut w_ref);
            let ess_ref = ess(&w_ref);
            // Fused kernel.
            let mut lw = base.clone();
            let mut w = Vec::new();
            let (lmean, e) = accumulate_weight_stats(&mut lw, &inc, &mut w);
            assert_eq!(lmean.to_bits(), lmean_ref.to_bits(), "n={n}");
            assert_eq!(e.to_bits(), ess_ref.to_bits(), "n={n}");
            for (a, b) in w.iter().zip(&w_ref) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}
