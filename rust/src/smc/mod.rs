//! Sequential Monte Carlo: resamplers, the model interface, and the
//! population coordinator (bootstrap / auxiliary / alive particle filters
//! and particle Gibbs) over the (sharded) lazy copy-on-write heap.

pub mod batch;
pub mod filter;
pub mod model;
pub mod rebalance;
pub mod resample;
pub mod session;

pub use filter::{
    run_filter, run_filter_shards, run_particle_gibbs, run_particle_gibbs_shards,
    FilterResult, Method, StepMetrics,
};
pub use model::{alive_retry_rng, particle_rng, resample_rng, SmcModel, StepCtx};
pub use rebalance::{plan_offspring, CostTracker, OffspringPlan, RebalancePolicy};
pub use resample::Resampler;
pub use session::FilterSession;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Model, RunConfig, Task};
    use crate::heap::{CopyMode, Heap, Lazy};
    use crate::lazy_fields;
    use crate::pool::ThreadPool;
    use crate::rng::{normal_lpdf, Pcg64};

    /// A 1-D linear-Gaussian SSM with known closed-form evidence (via a
    /// Kalman filter oracle): x' = a x + N(0, q), y = x + N(0, r).
    struct Lgss {
        a: f64,
        q: f64,
        r: f64,
        obs: Vec<f64>,
    }

    #[derive(Clone)]
    struct LgState {
        x: f64,
        prev: Lazy<LgState>,
    }
    lazy_fields!(LgState: prev);

    impl SmcModel for Lgss {
        type State = LgState;
        fn name(&self) -> &'static str {
            "lgss-test"
        }
        fn horizon(&self) -> usize {
            self.obs.len()
        }
        fn init(&self, heap: &mut Heap, rng: &mut Pcg64) -> Lazy<LgState> {
            let x = rng.gaussian(0.0, 1.0);
            heap.alloc(LgState {
                x,
                prev: Lazy::NULL,
            })
        }
        fn step(
            &self,
            heap: &mut Heap,
            state: &mut Lazy<LgState>,
            t: usize,
            rng: &mut Pcg64,
            observe: bool,
        ) -> f64 {
            let x_prev = heap.read(state, |s| s.x);
            let x = self.a * x_prev + rng.gaussian(0.0, self.q.sqrt());
            let old = *state;
            let new = heap.alloc(LgState { x, prev: old });
            heap.release(old);
            *state = new;
            if observe {
                normal_lpdf(self.obs[t - 1], x, self.r.sqrt())
            } else {
                0.0
            }
        }
        fn summary(&self, heap: &mut Heap, state: &mut Lazy<LgState>) -> f64 {
            heap.read(state, |s| s.x)
        }
        fn chain(&self, heap: &mut Heap, state: &Lazy<LgState>) -> Vec<Lazy<LgState>> {
            let mut out = vec![heap.clone_handle(state)];
            let mut cur = *state;
            loop {
                let prev = heap.read_ptr(&mut cur, |s| s.prev);
                if prev.is_null() {
                    break;
                }
                out.push(heap.clone_handle(&prev));
                cur = prev;
            }
            out
        }
        fn ref_weight(&self, heap: &mut Heap, state: &mut Lazy<LgState>, t: usize) -> f64 {
            let x = heap.read(state, |s| s.x);
            normal_lpdf(self.obs[t - 1], x, self.r.sqrt())
        }
    }

    /// Exact evidence by Kalman filtering.
    fn kalman_evidence(m: &Lgss) -> f64 {
        let (mut mean, mut var) = (0.0f64, 1.0f64);
        let mut lz = 0.0;
        for &y in &m.obs {
            mean *= m.a;
            var = m.a * m.a * var + m.q;
            let s = var + m.r;
            lz += normal_lpdf(y, mean, s.sqrt());
            let k = var / s;
            mean += k * (y - mean);
            var *= 1.0 - k;
        }
        lz
    }

    fn test_model(t: usize) -> Lgss {
        // Simulate observations from the model itself.
        let mut rng = Pcg64::new(777);
        let (a, q, r): (f64, f64, f64) = (0.9, 0.5, 0.8);
        let mut x = rng.gaussian(0.0, 1.0);
        let mut obs = Vec::with_capacity(t);
        for _ in 0..t {
            x = a * x + rng.gaussian(0.0, q.sqrt());
            obs.push(x + rng.gaussian(0.0, r.sqrt()));
        }
        Lgss { a, q, r, obs }
    }

    fn cfg(n: usize, t: usize, mode: CopyMode) -> RunConfig {
        let mut c = RunConfig::for_model(Model::List, Task::Inference, mode);
        c.n_particles = n;
        c.n_steps = t;
        c.seed = 42;
        c
    }

    #[test]
    fn bootstrap_filter_estimates_evidence() {
        let model = test_model(40);
        let exact = kalman_evidence(&model);
        let pool = ThreadPool::new(2);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut heap = Heap::new(CopyMode::LazySro);
        let r = run_filter(&model, &cfg(512, 40, CopyMode::LazySro), &mut heap, &ctx, Method::Bootstrap);
        assert!(
            (r.log_evidence - exact).abs() < 3.0,
            "estimate {} vs exact {exact}",
            r.log_evidence
        );
        assert_eq!(heap.live_objects(), 0, "filter must release everything");
        assert_eq!(r.series.len(), 40);
    }

    #[test]
    fn all_copy_modes_identical_output() {
        // The paper's §4 validation: outputs match across configurations
        // given matched seeds.
        let model = test_model(25);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut outs = Vec::new();
        for mode in CopyMode::ALL {
            let mut heap = Heap::new(mode);
            let r = run_filter(&model, &cfg(128, 25, mode), &mut heap, &ctx, Method::Bootstrap);
            outs.push((r.log_evidence, r.posterior_mean));
            assert_eq!(heap.live_objects(), 0, "{mode:?} leaked");
        }
        for w in outs.windows(2) {
            assert_eq!(w[0].0.to_bits(), w[1].0.to_bits(), "evidence differs: {outs:?}");
            assert_eq!(w[0].1.to_bits(), w[1].1.to_bits(), "posterior differs");
        }
    }

    #[test]
    fn lazy_uses_less_memory_than_eager() {
        let model = test_model(60);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut peaks = Vec::new();
        for mode in [CopyMode::Eager, CopyMode::LazySro] {
            let mut heap = Heap::new(mode);
            let r = run_filter(&model, &cfg(128, 60, mode), &mut heap, &ctx, Method::Bootstrap);
            peaks.push(r.peak_bytes as f64);
        }
        assert!(
            peaks[1] < peaks[0] * 0.7,
            "lazy peak {} not well below eager peak {}",
            peaks[1],
            peaks[0]
        );
    }

    #[test]
    fn simulation_task_performs_no_copies() {
        let model = test_model(30);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut c = cfg(64, 30, CopyMode::LazySro);
        c.task = Task::Simulation;
        let mut heap = Heap::new(CopyMode::LazySro);
        let _ = run_filter(&model, &c, &mut heap, &ctx, Method::Bootstrap);
        assert_eq!(heap.metrics.lazy_copies, 0, "no copies in simulation");
        assert_eq!(heap.metrics.eager_copies, 0);
        assert_eq!(heap.metrics.deep_copies, 0);
    }

    #[test]
    fn alive_filter_counts_attempts() {
        let model = test_model(10);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut heap = Heap::new(CopyMode::LazySro);
        let r = run_filter(&model, &cfg(64, 10, CopyMode::LazySro), &mut heap, &ctx, Method::Alive);
        // Gaussian weights are always finite: exactly one attempt each.
        assert_eq!(r.attempts, 64 * 10);
        assert_eq!(heap.live_objects(), 0);
    }

    #[test]
    fn particle_gibbs_runs_and_improves_nothing_broken() {
        let model = test_model(15);
        let exact = kalman_evidence(&model);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut c = cfg(128, 15, CopyMode::LazySro);
        c.pg_iterations = 3;
        let mut heap = Heap::new(CopyMode::LazySro);
        let rs = run_particle_gibbs(&model, &c, &mut heap, &ctx);
        assert_eq!(rs.len(), 3);
        for r in &rs {
            assert!(
                (r.log_evidence - exact).abs() < 5.0,
                "PG evidence {} vs exact {exact}",
                r.log_evidence
            );
        }
        assert_eq!(heap.live_objects(), 0, "PG must release everything");
        // The inter-iteration reference copies were eager.
        assert!(heap.metrics.eager_copies > 0);
    }
}
