//! The model interface the population coordinator drives.

use crate::heap::{Heap, Lazy, Payload};
use crate::pool::ThreadPool;
use crate::rng::Pcg64;
use crate::runtime::BatchKalman;

/// Shared numeric-phase resources handed to batched steps.
pub struct StepCtx<'a> {
    /// Static-scheduling executor for the parallel numeric phase.
    pub pool: &'a ThreadPool,
    /// Compiled batched-Kalman artifact, when `make artifacts` has run and
    /// the config enables XLA. Models fall back to the CPU oracle path.
    pub kalman: Option<&'a BatchKalman>,
    /// Whether the coordinator may take a model's batched SoA step
    /// ([`SmcModel::step_batched`]). `false` forces the scalar per-particle
    /// path everywhere; output is bit-identical either way (the batched
    /// kernels' determinism contract, gated by `tests/differential.rs`).
    pub batch: bool,
}

/// A population-based probabilistic program.
///
/// State payloads live on the lazy heap and typically chain backwards in
/// time (`prev` pointers), so the population's ancestry is exactly the
/// Figure 2 tree and resampling's `deep_copy` exercises the platform.
pub trait SmcModel {
    /// Per-particle state payload type (lives on the lazy heap).
    type State: Payload;

    /// Short model name (logs and bench labels).
    fn name(&self) -> &'static str;

    /// Number of generations (data length for inference).
    fn horizon(&self) -> usize;

    /// Draw an initial particle (under the coordinator's context).
    fn init(&self, heap: &mut Heap, rng: &mut Pcg64) -> Lazy<Self::State>;

    /// Propagate the particle to generation `t` (mutating through the
    /// handle) and return the log-weight increment. With `observe = false`
    /// (the paper's *simulation* task) the model samples forward without
    /// conditioning and the return value is ignored.
    fn step(
        &self,
        heap: &mut Heap,
        state: &mut Lazy<Self::State>,
        t: usize,
        rng: &mut Pcg64,
        observe: bool,
    ) -> f64;

    /// Batched SoA propagate+weight across (a contiguous slice of) the
    /// population — the opt-in fast path. Models with a tensorizable
    /// numeric core (LGSS, RBPF) return `Some(weight increments)` after
    /// splitting the generation into a serial heap phase and a batched
    /// numeric phase over gathered `&[f64]` lanes (see [`crate::smc::batch`]
    /// and, for RBPF, the shard-aware runtime dispatch through
    /// `ctx.kalman`). The default returns `None`, sending the coordinator
    /// to [`SmcModel::step_population`].
    ///
    /// **Contract:** when `Some` is returned, every slot's weight
    /// increment and post-step heap state must be *bitwise identical* to
    /// what the scalar [`SmcModel::step`] would have produced for that
    /// slot — same RNG streams (`particle_rng(seed, t, base + i)`), same
    /// floating-point expression order per particle. The coordinator
    /// freely mixes batched and scalar stepping across shards, schedules,
    /// and the `--batch` toggle, and the differential harness holds the
    /// outputs bit-equal.
    #[allow(clippy::too_many_arguments)]
    fn step_batched(
        &self,
        _heap: &mut Heap,
        _states: &mut [Lazy<Self::State>],
        _t: usize,
        _seed: u64,
        _observe: bool,
        _base: usize,
        _ctx: &StepCtx,
    ) -> Option<Vec<f64>> {
        None
    }

    /// Batched propagate+weight across (a contiguous slice of) the
    /// population. The default loops [`SmcModel::step`]; the coordinator
    /// calls this whenever [`SmcModel::step_batched`] declines (or batching
    /// is disabled), so it is the scalar reference path the batched hook
    /// must match bitwise.
    ///
    /// `base` is the *global* index of `states[0]` in the population: the
    /// sharded coordinator calls this once per heap shard with that
    /// shard's slice, and slot `i` of the slice must draw from
    /// `particle_rng(seed, t, base + i)` so that every particle's RNG
    /// stream is identical regardless of the shard count (the seeded
    /// K-equivalence guarantee). Single-heap callers pass `base = 0`.
    #[allow(clippy::too_many_arguments)]
    fn step_population(
        &self,
        heap: &mut Heap,
        states: &mut [Lazy<Self::State>],
        t: usize,
        seed: u64,
        observe: bool,
        base: usize,
        _ctx: &StepCtx,
    ) -> Vec<f64> {
        let mut out = Vec::with_capacity(states.len());
        for (i, s) in states.iter_mut().enumerate() {
            let mut rng = particle_rng(seed, t, base + i);
            let label = s.label();
            let lw = heap.with_context(label, |h| self.step(h, s, t, &mut rng, observe));
            out.push(lw);
        }
        out
    }

    /// Auxiliary-particle-filter lookahead score (Pitt & Shephard 1999):
    /// an estimate of the next observation's likelihood used to bias
    /// resampling; `None` disables the auxiliary stage.
    fn lookahead(
        &self,
        _heap: &mut Heap,
        _state: &mut Lazy<Self::State>,
        _t: usize,
    ) -> Option<f64> {
        None
    }

    /// Alive-particle-filter acceptance (Del Moral et al. 2015): whether a
    /// propagated particle survives. Default: finite weight.
    fn alive(&self, lw: f64) -> bool {
        lw > f64::NEG_INFINITY
    }

    /// Relative per-particle propagation-cost hint used by the shard
    /// rebalancer to apportion a shard's measured generation cost among
    /// its particles (larger = more expensive to propagate). Models with
    /// unbounded per-particle structure override this with a cheap size
    /// probe (PCFG: derivation-stack depth; MOT: track count). The
    /// default treats all particles as equal. Never affects filter
    /// output — only where heap work is scheduled.
    fn cost_hint(&self, _heap: &mut Heap, _state: &mut Lazy<Self::State>) -> f64 {
        1.0
    }

    /// A scalar summary of a particle (posterior-mean reporting and the
    /// cross-configuration output equality check).
    fn summary(&self, heap: &mut Heap, state: &mut Lazy<Self::State>) -> f64;

    /// Walk a final particle's state chain backwards, returning owning
    /// handles for generations T..0 (newest first). Used by particle Gibbs
    /// for the reference trajectory. Models without a chain return just
    /// the final state.
    fn chain(&self, heap: &mut Heap, state: &Lazy<Self::State>) -> Vec<Lazy<Self::State>> {
        vec![heap.clone_handle(state)]
    }

    /// Score the reference particle at generation `t` for conditional SMC
    /// (particle Gibbs). Default: unsupported.
    fn ref_weight(&self, _heap: &mut Heap, _state: &mut Lazy<Self::State>, _t: usize) -> f64 {
        unimplemented!("model does not support conditional SMC")
    }

    /// Append one observation (one generation of data) parsed from the
    /// serve protocol's whitespace-separated tokens, growing
    /// [`horizon`](SmcModel::horizon) by exactly one — the incremental
    /// ingest hook `FilterSession`-based servers drive: push the
    /// observation, then [`step`](crate::smc::FilterSession::step) the
    /// session into it.
    ///
    /// **Contract:** validate *every* token before mutating, so a
    /// rejected observation leaves the model untouched (the serve engine
    /// replies with the error and the session stays consistent), and the
    /// appended observation must be byte-for-byte what a batch
    /// construction with the same value would hold — incremental ingest
    /// is bit-identical to the batch run. The error string is shown to
    /// the client verbatim; say what shape was expected.
    ///
    /// The default declines (models are batch-only until they opt in);
    /// every built-in model overrides this.
    fn stream_observation(&mut self, _tokens: &[&str]) -> Result<(), String> {
        Err(format!(
            "model '{}' does not accept streamed observations",
            self.name()
        ))
    }
}

/// Deterministic per-(generation, slot) RNG stream — identical across copy
/// modes so resampling decisions and sampled trajectories match (§4: seeds
/// matched across configurations).
pub fn particle_rng(seed: u64, t: usize, i: usize) -> Pcg64 {
    Pcg64::stream(seed, ((t as u64) << 24) ^ (i as u64))
}

/// Per-generation resampling RNG stream.
pub fn resample_rng(seed: u64, t: usize) -> Pcg64 {
    Pcg64::stream(seed, 0xFFFF_0000_0000_0000 | t as u64)
}

/// Alive-PF per-slot retry stream — the versioned stream contract (v2)
/// that makes the alive PF shard-parallel. Attempt `attempt` of slot `i`
/// at generation `t` draws from a substream independent of every other
/// slot's retries, so slot outcomes (ancestor redraws, propagation
/// randomness, acceptance) do not depend on how attempts interleave
/// across shards — output and the total attempt count are identical for
/// every K. (Contract v1 chained all slots through one cumulative-attempt
/// counter, which pinned the whole population to one coordinator-serial
/// stream.)
///
/// For `attempt > 0` the first draw from the returned stream is the
/// uniform ancestor redraw (`below(n)`); the propagation step consumes
/// the stream from there. The stream id keeps bit 62 set and bits 48..62
/// sparse, disjoint from [`particle_rng`] (`< 2^33`) and [`resample_rng`]
/// (bits 48..63 all set) for every reachable `t`, `i`, and `attempt`
/// (attempts are capped at 10k).
pub fn alive_retry_rng(seed: u64, t: usize, i: usize, attempt: usize) -> Pcg64 {
    // The packing is collision-free only inside these bounds (fields land
    // in disjoint bit ranges); outside them streams would silently alias.
    debug_assert!(i < (1 << 24), "alive stream space supports < 2^24 slots");
    debug_assert!(attempt < (1 << 16), "alive stream space supports < 2^16 attempts");
    debug_assert!(t < (1 << 22), "alive stream space supports < 2^22 generations");
    Pcg64::stream(
        seed,
        (1u64 << 62) ^ ((t as u64) << 40) ^ ((attempt as u64) << 24) ^ (i as u64),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_streams_distinct_and_deterministic() {
        let mut a = particle_rng(1, 3, 5);
        let mut b = particle_rng(1, 3, 5);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = particle_rng(1, 3, 6);
        let mut d = particle_rng(1, 4, 5);
        let x = particle_rng(1, 3, 5).next_u64();
        assert_ne!(x, c.next_u64());
        assert_ne!(x, d.next_u64());
        assert_ne!(x, resample_rng(1, 3).next_u64());
    }

    #[test]
    fn alive_retry_streams_distinct_per_slot_and_attempt() {
        let x = alive_retry_rng(1, 3, 5, 0).next_u64();
        // Deterministic.
        assert_eq!(x, alive_retry_rng(1, 3, 5, 0).next_u64());
        // Distinct across slot, attempt, generation, and from the other
        // stream families.
        assert_ne!(x, alive_retry_rng(1, 3, 6, 0).next_u64());
        assert_ne!(x, alive_retry_rng(1, 3, 5, 1).next_u64());
        assert_ne!(x, alive_retry_rng(1, 4, 5, 0).next_u64());
        assert_ne!(x, particle_rng(1, 3, 5).next_u64());
        assert_ne!(x, resample_rng(1, 3).next_u64());
        // The stream-id spaces are disjoint by construction: alive ids set
        // bit 62 with bits 52..62 clear; particle ids stay below 2^33;
        // resample ids set all of bits 48..63.
        for (t, i, a) in [(1usize, 0usize, 0usize), (3262, 16383, 9999)] {
            let id = (1u64 << 62) ^ ((t as u64) << 40) ^ ((a as u64) << 24) ^ (i as u64);
            assert!(id & (1 << 62) != 0);
            assert_eq!((id >> 52) & 0x3FF, 0, "bits 52..62 clear for t={t}");
        }
    }
}
