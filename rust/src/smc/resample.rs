//! Resampling schemes: ancestor-index generation from particle weights.
//!
//! Multinomial, systematic, stratified, and residual resamplers, all over
//! normalized weights, all deterministic given the generator — the paper
//! matches seeds across configurations so resampling decisions (and hence
//! the ancestry tree of Figure 2) are identical in all three copy modes.

use crate::rng::Pcg64;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Resampler {
    Multinomial,
    Systematic,
    Stratified,
    Residual,
}

impl Resampler {
    pub fn parse(s: &str) -> Option<Resampler> {
        match s.to_ascii_lowercase().as_str() {
            "multinomial" => Some(Resampler::Multinomial),
            "systematic" => Some(Resampler::Systematic),
            "stratified" => Some(Resampler::Stratified),
            "residual" => Some(Resampler::Residual),
            _ => None,
        }
    }

    /// Draw `n` ancestor indices from normalized weights `w`.
    pub fn ancestors(&self, rng: &mut Pcg64, w: &[f64], n: usize) -> Vec<usize> {
        match self {
            Resampler::Multinomial => multinomial(rng, w, n),
            Resampler::Systematic => systematic(rng, w, n),
            Resampler::Stratified => stratified(rng, w, n),
            Resampler::Residual => residual(rng, w, n),
        }
    }
}

/// Multinomial: iid categorical draws (sorted for cache-friendly copying;
/// ancestry statistics are exchangeable).
pub fn multinomial(rng: &mut Pcg64, w: &[f64], n: usize) -> Vec<usize> {
    let mut out: Vec<usize> = (0..n).map(|_| rng.categorical(w)).collect();
    out.sort_unstable();
    out
}

/// Systematic: single uniform offset, minimal variance.
pub fn systematic(rng: &mut Pcg64, w: &[f64], n: usize) -> Vec<usize> {
    let total: f64 = w.iter().sum();
    let step = total / n as f64;
    let mut u = rng.next_f64() * step;
    let mut out = Vec::with_capacity(n);
    let mut acc = 0.0;
    let mut i = 0;
    for _ in 0..n {
        while acc + w[i] < u && i + 1 < w.len() {
            acc += w[i];
            i += 1;
        }
        out.push(i);
        u += step;
    }
    out
}

/// Stratified: one uniform per stratum.
pub fn stratified(rng: &mut Pcg64, w: &[f64], n: usize) -> Vec<usize> {
    let total: f64 = w.iter().sum();
    let mut out = Vec::with_capacity(n);
    let mut acc = 0.0;
    let mut i = 0;
    for k in 0..n {
        let u = (k as f64 + rng.next_f64()) * total / n as f64;
        while acc + w[i] < u && i + 1 < w.len() {
            acc += w[i];
            i += 1;
        }
        out.push(i);
    }
    out
}

/// Residual: deterministic floor(n·wᵢ) copies + multinomial remainder.
pub fn residual(rng: &mut Pcg64, w: &[f64], n: usize) -> Vec<usize> {
    let total: f64 = w.iter().sum();
    let mut out = Vec::with_capacity(n);
    let mut residuals = Vec::with_capacity(w.len());
    for (i, wi) in w.iter().enumerate() {
        let expect = n as f64 * wi / total;
        let k = expect.floor() as usize;
        for _ in 0..k {
            out.push(i);
        }
        residuals.push(expect - k as f64);
    }
    while out.len() < n {
        out.push(rng.categorical(&residuals));
    }
    out.truncate(n);
    out.sort_unstable();
    out
}

/// Offspring counts from an ancestor vector.
pub fn offspring_counts(ancestors: &[usize], n_parents: usize) -> Vec<usize> {
    let mut counts = vec![0usize; n_parents];
    for &a in ancestors {
        counts[a] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [Resampler; 4] = [
        Resampler::Multinomial,
        Resampler::Systematic,
        Resampler::Stratified,
        Resampler::Residual,
    ];

    #[test]
    fn ancestors_are_valid_indices() {
        let mut rng = Pcg64::new(1);
        let w = [0.1, 0.2, 0.3, 0.4];
        for r in ALL {
            let a = r.ancestors(&mut rng, &w, 100);
            assert_eq!(a.len(), 100);
            assert!(a.iter().all(|&i| i < 4), "{r:?}");
        }
    }

    #[test]
    fn proportions_match_weights() {
        let mut rng = Pcg64::new(2);
        let w = [1.0, 3.0, 6.0];
        for r in ALL {
            let a = r.ancestors(&mut rng, &w, 60_000);
            let c = offspring_counts(&a, 3);
            let f2 = c[2] as f64 / 60_000.0;
            assert!((f2 - 0.6).abs() < 0.02, "{r:?}: {f2}");
        }
    }

    #[test]
    fn degenerate_weight_takes_all() {
        let mut rng = Pcg64::new(3);
        let w = [0.0, 1.0, 0.0];
        for r in ALL {
            let a = r.ancestors(&mut rng, &w, 50);
            assert!(a.iter().all(|&i| i == 1), "{r:?}");
        }
    }

    #[test]
    fn systematic_low_variance() {
        // With uniform weights, systematic gives each parent exactly one
        // offspring.
        let mut rng = Pcg64::new(4);
        let w = [0.25; 4];
        let a = systematic(&mut rng, &w, 4);
        let c = offspring_counts(&a, 4);
        assert_eq!(c, vec![1, 1, 1, 1]);
    }

    #[test]
    fn residual_deterministic_part() {
        let mut rng = Pcg64::new(5);
        // Weights 0.5/0.25/0.25 with n=8: floors give 4/2/2 exactly.
        let a = residual(&mut rng, &[0.5, 0.25, 0.25], 8);
        assert_eq!(offspring_counts(&a, 3), vec![4, 2, 2]);
    }

    #[test]
    fn deterministic_given_seed() {
        for r in ALL {
            let w = [0.3, 0.7];
            let a1 = r.ancestors(&mut Pcg64::new(9), &w, 32);
            let a2 = r.ancestors(&mut Pcg64::new(9), &w, 32);
            assert_eq!(a1, a2, "{r:?}");
        }
    }
}
