//! Resampling schemes: ancestor-index generation from particle weights.
//!
//! Multinomial, systematic, stratified, and residual resamplers, all over
//! normalized weights, all deterministic given the generator — the paper
//! matches seeds across configurations so resampling decisions (and hence
//! the ancestry tree of Figure 2) are identical in all three copy modes.

use crate::rng::Pcg64;

/// Resampling scheme: how ancestor indices are drawn from the weights.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Resampler {
    /// Independent draws (highest offspring variance).
    Multinomial,
    /// One uniform offset, N evenly spaced points (lowest variance; the
    /// engine's default, per the paper).
    Systematic,
    /// One uniform draw per stratum.
    Stratified,
    /// Deterministic integer parts + multinomial remainder.
    Residual,
}

impl Resampler {
    /// Parse a resampler name.
    pub fn parse(s: &str) -> Option<Resampler> {
        match s.to_ascii_lowercase().as_str() {
            "multinomial" => Some(Resampler::Multinomial),
            "systematic" => Some(Resampler::Systematic),
            "stratified" => Some(Resampler::Stratified),
            "residual" => Some(Resampler::Residual),
            _ => None,
        }
    }

    /// Draw `n` ancestor indices from (unnormalized) weights `w`.
    ///
    /// Degenerate weight vectors are repaired first (see
    /// [`sanitize_weights`]): non-finite or negative entries are zeroed,
    /// and an all-zero / non-finite total falls back to uniform weights —
    /// so every scheme upholds its contract (exactly `n` ancestors, all
    /// `< w.len()`) instead of panicking or silently biasing toward
    /// index 0. Well-formed inputs are passed through untouched, with no
    /// extra RNG draws, so seeded runs are unaffected.
    pub fn ancestors(&self, rng: &mut Pcg64, w: &[f64], n: usize) -> Vec<usize> {
        assert!(!w.is_empty() || n == 0, "resampling from an empty population");
        if n == 0 {
            return Vec::new();
        }
        let cleaned = sanitize_weights(w);
        let w = cleaned.as_deref().unwrap_or(w);
        match self {
            Resampler::Multinomial => multinomial(rng, w, n),
            Resampler::Systematic => systematic(rng, w, n),
            Resampler::Stratified => stratified(rng, w, n),
            Resampler::Residual => residual(rng, w, n),
        }
    }
}

/// Repair a degenerate weight vector, honoring the input's intent as
/// far as it is expressible:
///
/// - any `+inf` entry dominates every finite one, so infinite entries
///   become the support (uniform among themselves, zero elsewhere);
/// - otherwise NaN and negative entries become zero;
/// - a finite vector whose *sum* overflows to infinity is rescaled by
///   its maximum entry (preserving every relative weight);
/// - only when the total is still zero or non-finite (all particles
///   "impossible") does every particle get equal weight — the only
///   unbiased choice consistent with resampling's contract.
///
/// Returns `None` when `w` is already well-formed (the hot path: no
/// allocation, no change).
pub fn sanitize_weights(w: &[f64]) -> Option<Vec<f64>> {
    let ok = |x: f64| x.is_finite() && x >= 0.0;
    let total: f64 = w.iter().sum();
    if w.iter().all(|&x| ok(x)) && total.is_finite() && total > 0.0 {
        return None;
    }
    let mut v: Vec<f64> = if w.iter().any(|&x| x == f64::INFINITY) {
        // An infinite weight marks a particle infinitely more likely
        // than any finite peer: the infinite set takes everything.
        w.iter()
            .map(|&x| if x == f64::INFINITY { 1.0 } else { 0.0 })
            .collect()
    } else {
        w.iter().map(|&x| if ok(x) { x } else { 0.0 }).collect()
    };
    let total: f64 = v.iter().sum();
    if !total.is_finite() {
        // Finite entries, infinite sum: rescale by the max instead of
        // flattening — relative weights (and hence offspring counts)
        // survive the overflow.
        let max = v.iter().cloned().fold(0.0f64, f64::max);
        if max > 0.0 {
            v.iter_mut().for_each(|x| *x /= max);
        }
    }
    let total: f64 = v.iter().sum();
    if !(total.is_finite() && total > 0.0) {
        v.iter_mut().for_each(|x| *x = 1.0);
    }
    Some(v)
}

/// Multinomial: iid categorical draws (sorted for cache-friendly copying;
/// ancestry statistics are exchangeable).
pub fn multinomial(rng: &mut Pcg64, w: &[f64], n: usize) -> Vec<usize> {
    let mut out: Vec<usize> = (0..n).map(|_| rng.categorical(w)).collect();
    out.sort_unstable();
    out
}

/// Systematic: single uniform offset, minimal variance.
pub fn systematic(rng: &mut Pcg64, w: &[f64], n: usize) -> Vec<usize> {
    let total: f64 = w.iter().sum();
    let step = total / n as f64;
    let mut u = rng.next_f64() * step;
    let mut out = Vec::with_capacity(n);
    let mut acc = 0.0;
    let mut i = 0;
    for _ in 0..n {
        while acc + w[i] < u && i + 1 < w.len() {
            acc += w[i];
            i += 1;
        }
        out.push(i);
        u += step;
    }
    out
}

/// Stratified: one uniform per stratum.
pub fn stratified(rng: &mut Pcg64, w: &[f64], n: usize) -> Vec<usize> {
    let total: f64 = w.iter().sum();
    let mut out = Vec::with_capacity(n);
    let mut acc = 0.0;
    let mut i = 0;
    for k in 0..n {
        let u = (k as f64 + rng.next_f64()) * total / n as f64;
        while acc + w[i] < u && i + 1 < w.len() {
            acc += w[i];
            i += 1;
        }
        out.push(i);
    }
    out
}

/// Residual: deterministic floor(n·wᵢ) copies + multinomial remainder.
pub fn residual(rng: &mut Pcg64, w: &[f64], n: usize) -> Vec<usize> {
    let total: f64 = w.iter().sum();
    let mut out = Vec::with_capacity(n);
    let mut residuals = Vec::with_capacity(w.len());
    for (i, wi) in w.iter().enumerate() {
        let expect = n as f64 * wi / total;
        let k = expect.floor() as usize;
        for _ in 0..k {
            out.push(i);
        }
        residuals.push(expect - k as f64);
    }
    // The residual total is n - Σ floors in exact arithmetic, but float
    // rounding can leave it at zero while floors still undercount n;
    // categorical over an all-zero vector would be undefined, so fall
    // back to the largest original weight for the missing slots.
    let residual_total: f64 = residuals.iter().sum();
    if residual_total > 0.0 {
        while out.len() < n {
            out.push(rng.categorical(&residuals));
        }
    } else if out.len() < n {
        let top = (0..w.len())
            .max_by(|&a, &b| w[a].partial_cmp(&w[b]).unwrap_or(std::cmp::Ordering::Equal))
            .unwrap_or(0);
        out.resize(n, top);
    }
    out.truncate(n);
    out.sort_unstable();
    out
}

/// Offspring counts from an ancestor vector.
pub fn offspring_counts(ancestors: &[usize], n_parents: usize) -> Vec<usize> {
    let mut counts = vec![0usize; n_parents];
    for &a in ancestors {
        counts[a] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [Resampler; 4] = [
        Resampler::Multinomial,
        Resampler::Systematic,
        Resampler::Stratified,
        Resampler::Residual,
    ];

    #[test]
    fn ancestors_are_valid_indices() {
        let mut rng = Pcg64::new(1);
        let w = [0.1, 0.2, 0.3, 0.4];
        for r in ALL {
            let a = r.ancestors(&mut rng, &w, 100);
            assert_eq!(a.len(), 100);
            assert!(a.iter().all(|&i| i < 4), "{r:?}");
        }
    }

    #[test]
    fn proportions_match_weights() {
        let mut rng = Pcg64::new(2);
        let w = [1.0, 3.0, 6.0];
        for r in ALL {
            let a = r.ancestors(&mut rng, &w, 60_000);
            let c = offspring_counts(&a, 3);
            let f2 = c[2] as f64 / 60_000.0;
            assert!((f2 - 0.6).abs() < 0.02, "{r:?}: {f2}");
        }
    }

    #[test]
    fn degenerate_weight_takes_all() {
        let mut rng = Pcg64::new(3);
        let w = [0.0, 1.0, 0.0];
        for r in ALL {
            let a = r.ancestors(&mut rng, &w, 50);
            assert!(a.iter().all(|&i| i == 1), "{r:?}");
        }
    }

    #[test]
    fn systematic_low_variance() {
        // With uniform weights, systematic gives each parent exactly one
        // offspring.
        let mut rng = Pcg64::new(4);
        let w = [0.25; 4];
        let a = systematic(&mut rng, &w, 4);
        let c = offspring_counts(&a, 4);
        assert_eq!(c, vec![1, 1, 1, 1]);
    }

    #[test]
    fn residual_deterministic_part() {
        let mut rng = Pcg64::new(5);
        // Weights 0.5/0.25/0.25 with n=8: floors give 4/2/2 exactly.
        let a = residual(&mut rng, &[0.5, 0.25, 0.25], 8);
        assert_eq!(offspring_counts(&a, 3), vec![4, 2, 2]);
    }

    #[test]
    fn deterministic_given_seed() {
        for r in ALL {
            let w = [0.3, 0.7];
            let a1 = r.ancestors(&mut Pcg64::new(9), &w, 32);
            let a2 = r.ancestors(&mut Pcg64::new(9), &w, 32);
            assert_eq!(a1, a2, "{r:?}");
        }
    }

    /// The resampling contract — exactly `n` ancestors, all in range,
    /// offspring counts summing to `n` — holds for every scheme across
    /// well-formed, skewed, and degenerate weight vectors.
    #[test]
    fn contract_holds_for_all_schemes_and_weights() {
        let cases: Vec<Vec<f64>> = vec![
            vec![0.25, 0.25, 0.25, 0.25],
            vec![1.0, 3.0, 6.0],
            vec![1e-300, 1.0, 1e-300],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 0.0],              // all-zero: uniform fallback
            vec![f64::NAN, 1.0, 2.0],         // NaN entry zeroed
            vec![f64::NAN, f64::NAN],         // all-NaN: uniform fallback
            vec![-1.0, 2.0, -3.0],            // negatives zeroed
            vec![f64::INFINITY, 1.0],         // +inf dominates
            vec![1.0],                        // single parent
        ];
        for (ci, w) in cases.iter().enumerate() {
            for r in ALL {
                for n in [0usize, 1, 7, 64] {
                    let mut rng = Pcg64::new(1000 + ci as u64);
                    let a = r.ancestors(&mut rng, w, n);
                    assert_eq!(a.len(), n, "{r:?} case {ci} n={n}: wrong count");
                    assert!(
                        a.iter().all(|&i| i < w.len()),
                        "{r:?} case {ci} n={n}: ancestor out of range: {a:?}"
                    );
                    let counts = offspring_counts(&a, w.len());
                    assert_eq!(
                        counts.iter().sum::<usize>(),
                        n,
                        "{r:?} case {ci} n={n}: counts must sum to n"
                    );
                }
            }
        }
    }

    /// Zeroed entries (NaN, negative) never receive offspring while a
    /// valid positive weight exists.
    #[test]
    fn repaired_entries_get_no_offspring() {
        for r in ALL {
            let mut rng = Pcg64::new(77);
            let a = r.ancestors(&mut rng, &[f64::NAN, 1.0, -5.0], 40);
            assert!(a.iter().all(|&i| i == 1), "{r:?}: {a:?}");
        }
    }

    /// An infinite weight dominates every finite one — repair must not
    /// invert the bias by zeroing it.
    #[test]
    fn infinite_weight_takes_all() {
        assert_eq!(
            sanitize_weights(&[f64::INFINITY, 1.0]).unwrap(),
            vec![1.0, 0.0]
        );
        assert_eq!(
            sanitize_weights(&[f64::INFINITY, 1e300, f64::INFINITY]).unwrap(),
            vec![1.0, 0.0, 1.0]
        );
        for r in ALL {
            let mut rng = Pcg64::new(88);
            let a = r.ancestors(&mut rng, &[1e-300, f64::INFINITY, 5.0], 40);
            assert!(a.iter().all(|&i| i == 1), "{r:?}: {a:?}");
        }
    }

    /// All-zero weights fall back to uniform resampling: every parent is
    /// reachable and low-variance schemes spread offspring evenly.
    #[test]
    fn all_zero_weights_resample_uniformly() {
        let mut rng = Pcg64::new(5);
        let a = systematic(&mut rng, &[1.0, 1.0, 1.0, 1.0], 4);
        assert_eq!(offspring_counts(&a, 4), vec![1, 1, 1, 1]);
        let mut rng = Pcg64::new(5);
        let a = Resampler::Systematic.ancestors(&mut rng, &[0.0; 4], 4);
        assert_eq!(
            offspring_counts(&a, 4),
            vec![1, 1, 1, 1],
            "uniform fallback must match explicit uniform weights"
        );
    }

    /// Sanitize passes well-formed vectors through untouched (no
    /// allocation, so seeded streams cannot shift).
    #[test]
    fn sanitize_is_identity_on_valid_weights() {
        assert!(sanitize_weights(&[0.2, 0.8]).is_none());
        assert!(sanitize_weights(&[1e-300, 1.0]).is_none());
        let repaired = sanitize_weights(&[f64::NAN, 2.0]).unwrap();
        assert_eq!(repaired, vec![0.0, 2.0]);
        let uniform = sanitize_weights(&[0.0, 0.0]).unwrap();
        assert_eq!(uniform, vec![1.0, 1.0]);
        // An overflowing total rescales by the max, preserving relative
        // weights rather than flattening them.
        let overflow = sanitize_weights(&[f64::MAX, f64::MAX]).unwrap();
        assert_eq!(overflow, vec![1.0, 1.0]);
        let skewed = sanitize_weights(&[f64::MAX, f64::MAX, 1.0]).unwrap();
        assert_eq!(skewed[0], 1.0);
        assert_eq!(skewed[1], 1.0);
        assert!(skewed[2] < 1e-300, "tiny relative weight preserved: {skewed:?}");
    }

    /// A negligible particle keeps negligible offspring counts through
    /// the overflow repair (the repair must not flatten to uniform).
    #[test]
    fn overflow_repair_preserves_offspring_ratios() {
        for r in ALL {
            let mut rng = Pcg64::new(321);
            let a = r.ancestors(&mut rng, &[f64::MAX, f64::MAX, 1.0], 60);
            let counts = offspring_counts(&a, 3);
            assert_eq!(counts[2], 0, "{r:?}: negligible particle got offspring: {counts:?}");
            assert_eq!(counts[0] + counts[1], 60);
        }
    }

    #[test]
    #[should_panic(expected = "empty population")]
    fn empty_weights_with_offspring_panics() {
        let mut rng = Pcg64::new(1);
        let _ = Resampler::Systematic.ancestors(&mut rng, &[], 4);
    }
}
