//! Resumable filter sessions: the step-at-a-time population engine.
//!
//! [`FilterSession`] is the paper's run-to-completion particle filter
//! (Murray 2020, §4) re-cut as an owning state machine, per the natural
//! per-generation decomposition of forward SMC (Paige & Wood 2014): it
//! owns the population handles, the shard assignment vector, the
//! rebalancer's [`CostTracker`], and the RNG seed/time cursor, while the
//! heap shards and the model stay with the caller and are lent to every
//! call. One [`step`](FilterSession::step) call advances exactly one
//! generation — resample → rebalance → propagate → weight → snapshot →
//! decommit — and [`finish`](FilterSession::finish) performs the final
//! evidence/summary reduction and releases the population.
//!
//! **Bit-identity.** A session stepped to completion is bitwise-identical
//! to the monolithic loop it replaced — [`run_filter_shards`] and
//! [`run_particle_gibbs_shards`] are now thin drivers over sessions —
//! across the whole K × policy × steal × batch × allocator matrix. That
//! holds because every random draw is keyed by `(seed, generation,
//! global index)` and every weight reduction runs in global index order,
//! so *when* a generation runs (batch loop or interactive server) cannot
//! reach the output.
//!
//! **Forking.** [`fork`](FilterSession::fork) clones the entire
//! population by lazy deep copy: per particle, one `deep_copy` call that
//! freezes the lineage and hands back a fresh root handle — O(particles)
//! handle/label work, **zero payload allocations** in the tree pattern
//! (asserted by the differential suite via allocator-metric scope
//! deltas). Parent and fork then share frozen ancestry copy-on-write and
//! diverge independently; the parent's subsequent outputs are unchanged
//! by having been forked. This is what makes per-request what-if queries
//! on a long-running population cheap — the O(1)-per-object lazy copy is
//! the platform, the session is the serving surface.
//!
//! **Telemetry.** Each barrier feeds a [`Registry`] owned by the session
//! with deltas of the engine's own counters and of the aggregated
//! [`HeapMetrics`] of the backing shards. Attribution is **exact** even
//! when many sessions share one shard set: every step snapshots the
//! aggregate heap counters at entry and diffs at its own barrier, and
//! [`fork`](FilterSession::fork) attributes its copy work the same way,
//! so work done by other sessions between this session's operations is
//! never charged here (sessions on shared shards execute serially — the
//! `&mut [Heap]` borrow enforces it). The metric *names* are the stable
//! contract — see [`crate::telemetry`].
//!
//! [`run_filter_shards`]: super::run_filter_shards
//! [`run_particle_gibbs_shards`]: super::run_particle_gibbs_shards

use super::filter::{
    alive_generation, init_population, pair_mut, plan_and_resample, propagate_assigned,
    propagate_stealing, step_snapshot, FilterResult, Method, StepMetrics,
};
use super::model::{resample_rng, SmcModel, StepCtx};
use super::rebalance::{CostTracker, RebalancePolicy};
use super::resample::Resampler;
use crate::config::{RunConfig, Task};
use crate::heap::{
    aggregate_metrics, evacuate_shards, sample_global_peak, shard_of, trim_shards, Heap,
    HeapMetrics, Lazy, Payload,
};
use crate::stats::weight_stats;
use crate::telemetry::trace::{Phase, PhaseWalls, TraceLog};
use crate::telemetry::{self, Registry};
use std::time::Instant;

/// A paused particle-filter run: the population and every piece of
/// cross-generation state the coordinator loop used to keep on its
/// stack, now owned by a value that can stop between generations, fork,
/// and resume.
///
/// The session is generic over the model's *state* type only; the model
/// itself (and the heap shards, and the thread-pool context) are
/// borrowed per call, so one long-lived session can serve a model whose
/// observation horizon grows over time — the incremental-ingest shape of
/// the `serve` subcommand.
///
/// Lifecycle: [`begin`](FilterSession::begin) →
/// [`step`](FilterSession::step)\* → ([`fork`](FilterSession::fork)\*) →
/// [`finish`](FilterSession::finish) (or
/// [`abandon`](FilterSession::abandon)). Conditional SMC (particle
/// Gibbs) uses the parallel surface [`begin_gibbs`](FilterSession::begin_gibbs) /
/// [`step_gibbs`](FilterSession::step_gibbs) /
/// [`finish_gibbs`](FilterSession::finish_gibbs) with
/// [`restart`](FilterSession::restart) between iterations.
pub struct FilterSession<S: Payload> {
    cfg: RunConfig,
    method: Method,
    /// Conditional-SMC session (particle Gibbs): resample every
    /// generation, pin the reference slot.
    gibbs: bool,
    observe: bool,
    policy: RebalancePolicy,
    balancing: bool,
    stealing: bool,
    n: usize,
    k: usize,
    /// Shard owning the conditional slot `n - 1` (particle Gibbs).
    s_ref: usize,
    /// Seed for this run segment (per-iteration offset under Gibbs).
    seed: u64,
    /// Next generation to execute (1-based).
    t: usize,
    resampler: Resampler,
    start: Instant,
    states: Vec<Lazy<S>>,
    assign: Vec<usize>,
    lw: Vec<f64>,
    w: Vec<f64>,
    log_z: f64,
    series: Vec<StepMetrics>,
    tracker: CostTracker,
    raw_cost: Vec<f64>,
    scratch_pools: Vec<Vec<Heap>>,
    migrations: usize,
    steals: usize,
    attempts: usize,
    telemetry: Registry,
    // Wall clock of the previous barrier (step-duration histogram).
    // Heap-counter attribution needs no cross-barrier baseline: each
    // step diffs the aggregate against its own entry snapshot.
    last_elapsed: f64,
    /// Per-phase wall accumulator, reset at the top of every step and
    /// flushed at the barrier into the `phase_wall_seconds` histograms
    /// and (when tracing) the trace log. Pure measurement: the clocks
    /// are read on a single code path whether or not a trace sink is
    /// attached, so tracing can never reach the output.
    phase_walls: PhaseWalls,
    /// Structured trace sink (`--trace`): one JSONL span per non-zero
    /// phase wall per barrier. `None` (the default, and always on
    /// forks) records nothing; spans are measured either way.
    trace: Option<TraceLog>,
}

impl<S: Payload> FilterSession<S> {
    /// Open a session over `shards` and initialize the generation-0
    /// population. Mirrors the head of the old monolithic loop exactly:
    /// the wall clock starts before initialization and the global-peak
    /// barrier is sampled right after it.
    pub fn begin<M>(
        model: &M,
        cfg: &RunConfig,
        shards: &mut [Heap],
        ctx: &StepCtx,
        method: Method,
    ) -> Self
    where
        M: SmcModel<State = S> + Sync,
    {
        let mut s = FilterSession::shell(cfg, shards, method, false);
        s.restart(model, shards, ctx, cfg.seed);
        s
    }

    /// Open a conditional-SMC (particle Gibbs) session: resampling runs
    /// every generation and slot `n - 1` is reserved for the reference
    /// trajectory (see [`install_reference`](FilterSession::install_reference)).
    pub fn begin_gibbs<M>(model: &M, cfg: &RunConfig, shards: &mut [Heap], ctx: &StepCtx) -> Self
    where
        M: SmcModel<State = S> + Sync,
    {
        let mut s = FilterSession::shell(cfg, shards, Method::Bootstrap, true);
        s.restart(model, shards, ctx, cfg.seed);
        s
    }

    /// Configuration-only construction shared by the entry points; holds
    /// no population until [`restart`](FilterSession::restart).
    fn shell(cfg: &RunConfig, shards: &[Heap], method: Method, gibbs: bool) -> Self {
        assert!(!shards.is_empty(), "at least one heap shard");
        let n = cfg.n_particles;
        let k = shards.len();
        let observe = gibbs || cfg.task == Task::Inference;
        let policy = if k > 1 { cfg.rebalance } else { RebalancePolicy::Off };
        // Stealing applies to weighted propagation only: the simulation
        // task's contract (Figure 6 — zero copies) must hold by
        // construction, and a donation's scratch round trip is copy
        // traffic. Gibbs sessions always weight.
        let stealing = cfg.steal && k > 1 && observe;
        let mut telemetry = Registry::new();
        // Pre-register the whole stable-name contract so a render before
        // the first barrier already lists every series at zero.
        for name in [
            telemetry::SESSION_STEPS_TOTAL,
            telemetry::SESSION_FORK_TOTAL,
            telemetry::SESSION_RESAMPLES_TOTAL,
            telemetry::SESSION_ATTEMPTS_TOTAL,
            telemetry::SESSION_MIGRATIONS_TOTAL,
            telemetry::SESSION_STEALS_TOTAL,
            telemetry::TRANSPLANTS_TOTAL,
            telemetry::LAZY_COPIES_TOTAL,
            telemetry::EAGER_COPIES_TOTAL,
        ] {
            telemetry.inc(name, 0);
        }
        // Trace sink: opening failures are reported, never fatal — a
        // filter must not die because an observability path is bad.
        let trace = cfg.trace.as_deref().and_then(|path| match TraceLog::open(path, "run") {
            Ok(log) => Some(log),
            Err(e) => {
                eprintln!("# trace: cannot open {path}: {e}");
                None
            }
        });
        FilterSession {
            cfg: cfg.clone(),
            method,
            gibbs,
            observe,
            policy,
            balancing: policy != RebalancePolicy::Off,
            stealing,
            n,
            k,
            s_ref: shard_of(n, k, n - 1),
            seed: cfg.seed,
            t: 1,
            resampler: Resampler::Systematic,
            start: Instant::now(),
            states: Vec::new(),
            assign: Vec::new(),
            lw: Vec::new(),
            w: Vec::with_capacity(n),
            log_z: 0.0,
            series: Vec::new(),
            tracker: CostTracker::new(n),
            raw_cost: vec![f64::NAN; n],
            scratch_pools: (0..k).map(|_| Vec::new()).collect(),
            migrations: 0,
            steals: 0,
            attempts: 0,
            telemetry,
            last_elapsed: 0.0,
            phase_walls: PhaseWalls::new(k),
            trace,
        }
    }

    /// (Re)initialize the population under `seed` and reset the
    /// per-run cursors — the inter-iteration reset of particle Gibbs
    /// (`begin*` call it once with the base seed). The previous
    /// population must already have been consumed by
    /// [`finish_gibbs`](FilterSession::finish_gibbs). Recycled scratch
    /// pools and the telemetry registry survive across restarts: the
    /// former is pure storage reuse, the latter is lifetime history.
    pub fn restart<M>(&mut self, model: &M, shards: &mut [Heap], ctx: &StepCtx, seed: u64)
    where
        M: SmcModel<State = S> + Sync,
    {
        assert!(
            self.states.is_empty(),
            "restart on a live population (finish it first)"
        );
        self.seed = seed;
        self.t = 1;
        self.start = Instant::now();
        self.states = init_population(model, shards, ctx.pool, self.n, seed);
        self.assign = (0..self.n).map(|i| shard_of(self.n, self.k, i)).collect();
        // A fresh population: slot-indexed cost estimates from the
        // previous run's particles are garbage here.
        self.tracker = CostTracker::new(self.n);
        self.lw = vec![0.0; self.n];
        self.log_z = 0.0;
        self.series = Vec::new();
        self.migrations = 0;
        self.steals = 0;
        self.attempts = 0;
        self.last_elapsed = 0.0;
        sample_global_peak(shards);
    }

    /// Pin the conditional slot `n - 1` to the first generation of a
    /// reference trajectory (handles owned by the reference shard,
    /// oldest first) — call between [`restart`](FilterSession::restart)
    /// and the first [`step_gibbs`](FilterSession::step_gibbs) of a
    /// conditional iteration.
    pub fn install_reference(&mut self, shards: &mut [Heap], reference: &[Lazy<S>]) {
        debug_assert!(self.gibbs, "reference pinning is a Gibbs-session operation");
        shards[self.s_ref].release(self.states[self.n - 1]);
        self.states[self.n - 1] = shards[self.s_ref].clone_handle(&reference[0]);
    }

    /// Advance one generation: resample (below the ESS threshold) →
    /// rebalance → propagate → weight → metrics snapshot → decommit
    /// barrier, exactly the body of the old coordinator loop for
    /// generation [`next_generation`](FilterSession::next_generation).
    /// The model's horizon must cover that generation — under
    /// incremental ingest, push the observation first.
    ///
    /// Returns this generation's metrics snapshot (also appended to the
    /// series that [`finish`](FilterSession::finish) returns).
    pub fn step<M>(&mut self, model: &M, shards: &mut [Heap], ctx: &StepCtx) -> StepMetrics
    where
        M: SmcModel<State = S> + Sync,
    {
        debug_assert!(!self.gibbs, "use step_gibbs on a Gibbs session");
        let n = self.n;
        let t = self.t;
        debug_assert!(t <= model.horizon(), "stepping past the model horizon");
        // `--batch off` composes with the caller's context: either side
        // can force the scalar path (bit-identical output).
        let ctx = &StepCtx {
            pool: ctx.pool,
            kalman: ctx.kalman,
            batch: ctx.batch && self.cfg.batch,
        };
        // Exact attribution: everything the shards do between this
        // snapshot and this step's barrier is this step's own work (the
        // exclusive shard borrow serializes sessions).
        let heap_base = aggregate_metrics(shards);
        let attempts_before = self.attempts;
        let migrations_before = self.migrations;
        let steals_before = self.steals;
        let mut resampled = false;
        self.phase_walls.reset(self.k);

        // --- Resample (inference only; simulation performs no copies). ---
        if self.observe {
            // Fused single pass: normalized weights + log mean weight
            // (the evidence increment, reused below) + ESS.
            let t_w = Instant::now();
            let (lmean, cur_ess) = weight_stats(&self.lw, &mut self.w);
            self.phase_walls.add(Phase::Weight, t_w.elapsed().as_secs_f64());
            if cur_ess < self.cfg.ess_threshold * n as f64 {
                resampled = true;
                let mut rrng = resample_rng(self.seed, t);
                // Auxiliary stage: bias resampling by lookahead scores.
                let ancestors = if self.method == Method::Auxiliary {
                    // Lookahead scoring is weighting work: it reads the
                    // model to bias the resampling weights.
                    let t_la = Instant::now();
                    let mut aux = vec![0.0f64; n];
                    let mut any = false;
                    for (i, aux_i) in aux.iter_mut().enumerate() {
                        let mut s = self.states[i];
                        if let Some(la) = model.lookahead(&mut shards[self.assign[i]], &mut s, t)
                        {
                            *aux_i = la;
                            any = true;
                        }
                        self.states[i] = s;
                    }
                    self.phase_walls.add(Phase::Weight, t_la.elapsed().as_secs_f64());
                    if any {
                        let alw: Vec<f64> =
                            self.lw.iter().zip(&aux).map(|(a, b)| a + b).collect();
                        let mut aw = Vec::new();
                        let (alm, _) = weight_stats(&alw, &mut aw);
                        let anc = self.resampler.ancestors(&mut rrng, &aw, n);
                        // First-stage correction: w ∝ 1 / lookahead(a).
                        self.log_z += alm;
                        self.migrations += plan_and_resample(
                            self.policy,
                            self.cfg.rebalance_threshold,
                            shards,
                            ctx.pool,
                            &mut self.states,
                            &anc,
                            &mut self.assign,
                            &mut self.tracker,
                            None,
                            &mut self.phase_walls,
                        );
                        for (i, &a) in anc.iter().enumerate() {
                            self.lw[i] = -aux[a];
                        }
                        None
                    } else {
                        Some(self.resampler.ancestors(&mut rrng, &self.w, n))
                    }
                } else {
                    Some(self.resampler.ancestors(&mut rrng, &self.w, n))
                };
                if let Some(anc) = ancestors {
                    self.log_z += lmean;
                    self.migrations += plan_and_resample(
                        self.policy,
                        self.cfg.rebalance_threshold,
                        shards,
                        ctx.pool,
                        &mut self.states,
                        &anc,
                        &mut self.assign,
                        &mut self.tracker,
                        None,
                        &mut self.phase_walls,
                    );
                    self.lw.iter_mut().for_each(|x| *x = 0.0);
                }
            }
        }

        // --- Propagate + weight. ---
        match self.method {
            Method::Alive if self.observe => {
                // Alive PF (contract v2): per-slot retry streams, rounds
                // of shard-parallel attempts. Resampling above has
                // already equalized weights. With rebalancing active the
                // rounds' measured costs feed the tracker, so
                // retry-heavy lineages migrate at the next barrier.
                if self.balancing {
                    self.raw_cost.iter_mut().for_each(|c| *c = f64::NAN);
                }
                self.attempts += alive_generation(
                    model,
                    shards,
                    ctx.pool,
                    &mut self.states,
                    &mut self.lw,
                    &self.assign,
                    t,
                    self.seed,
                    self.balancing.then_some(&mut self.raw_cost[..]),
                    &mut self.phase_walls,
                );
                if self.balancing {
                    self.tracker.fold(&self.raw_cost);
                }
            }
            _ if self.stealing => {
                if self.balancing {
                    self.raw_cost.iter_mut().for_each(|c| *c = f64::NAN);
                }
                let stolen = propagate_stealing(
                    model,
                    shards,
                    &mut self.states,
                    &mut self.lw,
                    &self.assign,
                    t,
                    self.seed,
                    self.observe,
                    ctx,
                    self.cfg.steal_min,
                    self.balancing.then_some(&mut self.raw_cost[..]),
                    &mut self.scratch_pools,
                    &mut self.phase_walls,
                );
                if self.balancing {
                    for &i in &stolen {
                        self.tracker.note_stolen(i);
                    }
                    self.tracker.fold(&self.raw_cost);
                }
                self.steals += stolen.len();
                self.attempts += n;
            }
            _ => {
                if self.balancing {
                    self.raw_cost.iter_mut().for_each(|c| *c = f64::NAN);
                }
                propagate_assigned(
                    model,
                    shards,
                    &mut self.states,
                    &mut self.lw,
                    &self.assign,
                    t,
                    self.seed,
                    self.observe,
                    ctx,
                    self.balancing.then_some(&mut self.raw_cost[..]),
                    &mut self.phase_walls,
                );
                if self.balancing {
                    self.tracker.fold(&self.raw_cost);
                }
                self.attempts += n;
            }
        }

        self.close_generation(shards, t);
        self.note_barrier(
            shards,
            &heap_base,
            t,
            resampled,
            self.attempts - attempts_before,
            self.migrations - migrations_before,
            self.steals - steals_before,
        );
        self.t = t + 1;
        self.series.last().expect("snapshot just pushed").clone()
    }

    /// Advance one conditional-SMC generation: resample everything but
    /// the pinned slot (every generation — no ESS gate), rebalance with
    /// the reference slot held on its shard, propagate the free
    /// particles, then re-pin and score the conditional one. Pass the
    /// current reference trajectory when conditioning (iterations after
    /// the first).
    pub fn step_gibbs<M>(
        &mut self,
        model: &M,
        shards: &mut [Heap],
        ctx: &StepCtx,
        reference: Option<&[Lazy<S>]>,
    ) -> StepMetrics
    where
        M: SmcModel<State = S> + Sync,
    {
        debug_assert!(self.gibbs, "use step on a non-Gibbs session");
        let n = self.n;
        let t = self.t;
        let ctx = &StepCtx {
            pool: ctx.pool,
            kalman: ctx.kalman,
            batch: ctx.batch && self.cfg.batch,
        };
        let heap_base = aggregate_metrics(shards);
        let attempts_before = self.attempts;
        let migrations_before = self.migrations;
        let steals_before = self.steals;
        self.phase_walls.reset(self.k);

        // Resample all but the conditional slot (fused normalize +
        // evidence increment — PG resamples every generation).
        let t_w = Instant::now();
        let (lmean, _) = weight_stats(&self.lw, &mut self.w);
        self.phase_walls.add(Phase::Weight, t_w.elapsed().as_secs_f64());
        let mut rrng = resample_rng(self.seed, t);
        let mut anc = self.resampler.ancestors(&mut rrng, &self.w, n);
        if reference.is_some() {
            anc[n - 1] = n - 1;
        }
        self.log_z += lmean;
        self.migrations += plan_and_resample(
            self.policy,
            self.cfg.rebalance_threshold,
            shards,
            ctx.pool,
            &mut self.states,
            &anc,
            &mut self.assign,
            &mut self.tracker,
            Some(self.s_ref),
            &mut self.phase_walls,
        );
        self.lw.iter_mut().for_each(|x| *x = 0.0);

        // Propagate free particles; pin + score the conditional one.
        let split = if reference.is_some() { n - 1 } else { n };
        if self.stealing {
            if self.balancing {
                self.raw_cost[..split].iter_mut().for_each(|c| *c = f64::NAN);
            }
            let stolen = propagate_stealing(
                model,
                shards,
                &mut self.states[..split],
                &mut self.lw[..split],
                &self.assign[..split],
                t,
                self.seed,
                true,
                ctx,
                self.cfg.steal_min,
                self.balancing.then_some(&mut self.raw_cost[..split]),
                &mut self.scratch_pools,
                &mut self.phase_walls,
            );
            if self.balancing {
                for &i in &stolen {
                    self.tracker.note_stolen(i);
                }
                self.tracker.fold(&self.raw_cost[..split]);
            }
            self.steals += stolen.len();
        } else {
            if self.balancing {
                self.raw_cost[..split].iter_mut().for_each(|c| *c = f64::NAN);
            }
            propagate_assigned(
                model,
                shards,
                &mut self.states[..split],
                &mut self.lw[..split],
                &self.assign[..split],
                t,
                self.seed,
                true,
                ctx,
                self.balancing.then_some(&mut self.raw_cost[..split]),
                &mut self.phase_walls,
            );
            if self.balancing {
                self.tracker.fold(&self.raw_cost[..split]);
            }
        }
        self.attempts += n;
        if let Some(r) = reference {
            shards[self.s_ref].release(self.states[n - 1]);
            self.states[n - 1] = shards[self.s_ref].clone_handle(&r[t.min(r.len() - 1)]);
            let mut pinned = self.states[n - 1];
            self.lw[n - 1] += model.ref_weight(&mut shards[self.s_ref], &mut pinned, t);
            self.states[n - 1] = pinned;
        }

        self.close_generation(shards, t);
        self.note_barrier(
            shards,
            &heap_base,
            t,
            true,
            self.attempts - attempts_before,
            self.migrations - migrations_before,
            self.steals - steals_before,
        );
        self.t = t + 1;
        self.series.last().expect("snapshot just pushed").clone()
    }

    /// Generation tail shared by both step flavors: global-peak barrier,
    /// metrics snapshot (Figure 7), decommit barrier.
    fn close_generation(&mut self, shards: &mut [Heap], t: usize) {
        sample_global_peak(shards);
        let t_w = Instant::now();
        let (_, snap_ess) = weight_stats(&self.lw, &mut self.w);
        self.phase_walls.add(Phase::Weight, t_w.elapsed().as_secs_f64());
        self.series.push(step_snapshot(shards, t, &self.start, snap_ess));
        // Evacuation barrier: with a threshold configured, placement-move
        // the survivors of sparse chunks into same-class bump space and
        // decommit the emptied chunks. Runs before the trim pass so
        // evacuation-emptied chunks never linger; handles are index-based
        // so output is bit-identical either way.
        if let Some(threshold) = self.cfg.evacuate_threshold {
            let t_evac = Instant::now();
            evacuate_shards(shards, threshold);
            self.phase_walls.add(Phase::Evacuate, t_evac.elapsed().as_secs_f64());
        }
        // Decommit barrier: with a watermark configured, return
        // fully-empty slab chunks past it to the system allocator so
        // long-running (server) populations stay residency-bounded.
        // Runs after the reclaim (parent release + memo sweeps) so a
        // resampling spike's chunks are empty by now; bit-identical
        // output either way.
        if let Some(keep) = self.cfg.decommit_watermark {
            let t_trim = Instant::now();
            trim_shards(shards, keep);
            self.phase_walls.add(Phase::Trim, t_trim.elapsed().as_secs_f64());
        }
    }

    /// Feed the telemetry registry from this barrier's deltas. Heap
    /// counters are cumulative over the shards' lifetime (shards outlive
    /// sessions and are shared across sessions), so the step diffs the
    /// barrier aggregate against `base`, its own entry snapshot —
    /// attribution is exact under session interleaving because nothing
    /// else can touch the shards between the snapshot and the barrier
    /// (the step holds the exclusive borrow throughout). See the
    /// attribution note in [`crate::telemetry`]. The generation's phase
    /// walls flush here too — into the `phase_wall_seconds{phase=..}`
    /// histograms and, when tracing, the JSONL span log, from the *same*
    /// accumulator, so the two always agree.
    #[allow(clippy::too_many_arguments)]
    fn note_barrier(
        &mut self,
        shards: &[Heap],
        base: &HeapMetrics,
        t: usize,
        resampled: bool,
        attempts_d: usize,
        migrations_d: usize,
        steals_d: usize,
    ) {
        let (elapsed, ess, live_bytes, live_objects) = {
            let s = self.series.last().expect("barrier follows a snapshot");
            (s.elapsed_s, s.ess, s.live_bytes, s.live_objects)
        };
        let agg = aggregate_metrics(shards);
        let tele = &mut self.telemetry;
        tele.inc(telemetry::SESSION_STEPS_TOTAL, 1);
        tele.inc(telemetry::SESSION_RESAMPLES_TOTAL, resampled as u64);
        tele.inc(telemetry::SESSION_ATTEMPTS_TOTAL, attempts_d as u64);
        tele.inc(telemetry::SESSION_MIGRATIONS_TOTAL, migrations_d as u64);
        tele.inc(telemetry::SESSION_STEALS_TOTAL, steals_d as u64);
        tele.inc(
            telemetry::TRANSPLANTS_TOTAL,
            agg.transplants.saturating_sub(base.transplants) as u64,
        );
        tele.inc(
            telemetry::LAZY_COPIES_TOTAL,
            agg.lazy_copies.saturating_sub(base.lazy_copies) as u64,
        );
        tele.inc(
            telemetry::EAGER_COPIES_TOTAL,
            agg.eager_copies.saturating_sub(base.eager_copies) as u64,
        );
        tele.set_gauge(telemetry::HEAP_COMMITTED_BYTES, agg.slab_committed_bytes as f64);
        tele.set_gauge(telemetry::HEAP_LIVE_BYTES, live_bytes as f64);
        tele.set_gauge(telemetry::HEAP_LIVE_OBJECTS, live_objects as f64);
        tele.set_gauge(telemetry::ESS_LAST, ess);
        // Allocator health: committed high-water mark, peak-time
        // fragmentation, and decommit traffic (deltas — the trim barrier
        // ran inside this step, so the entry snapshot excludes it).
        tele.set_gauge(
            telemetry::HEAP_COMMITTED_PEAK_BYTES,
            agg.slab_committed_peak_bytes as f64,
        );
        tele.set_gauge(telemetry::HEAP_FRAGMENTATION_RATIO, agg.slab_fragmentation());
        tele.inc(
            telemetry::HEAP_DECOMMITTED_CHUNKS_TOTAL,
            agg.decommitted_chunks.saturating_sub(base.decommitted_chunks) as u64,
        );
        tele.inc(
            telemetry::HEAP_DECOMMITTED_BYTES_TOTAL,
            agg.decommitted_bytes.saturating_sub(base.decommitted_bytes) as u64,
        );
        tele.inc(
            telemetry::HEAP_EVACUATIONS_TOTAL,
            agg.evacuated_objects.saturating_sub(base.evacuated_objects) as u64,
        );
        tele.set_gauge(
            telemetry::HEAP_LOS_BYTES,
            (agg.los_live_bytes + agg.los_free_bytes) as f64,
        );
        tele.observe(
            telemetry::STEP_WALL_SECONDS,
            (elapsed - self.last_elapsed).max(0.0),
        );
        self.last_elapsed = elapsed;
        // Flush the generation's phase walls: one histogram observation
        // per non-zero span, and — when a trace sink is attached — one
        // JSONL line per span from the very same values.
        let walls = &self.phase_walls;
        walls.for_each_span(|phase, _, dur| {
            tele.observe_with(
                telemetry::PHASE_WALL_SECONDS,
                &[("phase", phase.name())],
                dur,
            );
        });
        if let Some(log) = self.trace.as_mut() {
            log.record_walls(t, walls);
        }
    }

    /// Fork the session: lazily deep-copy the whole population and
    /// return an independent session over the *same* shards. Per
    /// particle this is one copy-on-write `deep_copy` — a fresh root
    /// handle over frozen ancestry, O(particles) handle work with **no
    /// eager payload clones** in the tree pattern — so fork cost scales
    /// with the population, not the heap. Parent and fork then diverge
    /// independently: the parent's subsequent outputs are bitwise
    /// unchanged by the fork, and a fork stepped with the same model is
    /// bitwise-identical to the unforked run (all draws are keyed by
    /// `(seed, generation, index)`; freezing never changes values).
    ///
    /// The fork inherits the learned cost estimates, the telemetry
    /// history (`session_fork_total` counts the lineage's forks and is
    /// incremented on both sides), the parent's wall-clock origin, and
    /// the seed/time cursor; scratch pools start empty (pure storage,
    /// never observable in output). The trace sink is **not** inherited:
    /// a what-if fork re-executing generations would duplicate spans in
    /// the parent's log (attach one explicitly with
    /// [`trace_label`](FilterSession::trace_label) semantics via a fresh
    /// session if fork traces are wanted).
    pub fn fork(&mut self, shards: &mut [Heap]) -> FilterSession<S> {
        // Attribute the fork's own copy work (eager modes clone payloads
        // here; lazy modes only touch handles) to the parent — exactly,
        // via the same entry-snapshot scheme the steps use.
        let base = aggregate_metrics(shards);
        let states: Vec<Lazy<S>> = self
            .states
            .iter()
            .enumerate()
            .map(|(i, st)| shards[self.assign[i]].deep_copy(st))
            .collect();
        let agg = aggregate_metrics(shards);
        self.telemetry.inc(telemetry::SESSION_FORK_TOTAL, 1);
        self.telemetry.inc(
            telemetry::LAZY_COPIES_TOTAL,
            agg.lazy_copies.saturating_sub(base.lazy_copies) as u64,
        );
        self.telemetry.inc(
            telemetry::EAGER_COPIES_TOTAL,
            agg.eager_copies.saturating_sub(base.eager_copies) as u64,
        );
        FilterSession {
            cfg: self.cfg.clone(),
            method: self.method,
            gibbs: self.gibbs,
            observe: self.observe,
            policy: self.policy,
            balancing: self.balancing,
            stealing: self.stealing,
            n: self.n,
            k: self.k,
            s_ref: self.s_ref,
            seed: self.seed,
            t: self.t,
            resampler: self.resampler,
            start: self.start,
            states,
            assign: self.assign.clone(),
            lw: self.lw.clone(),
            w: Vec::with_capacity(self.n),
            log_z: self.log_z,
            series: self.series.clone(),
            tracker: self.tracker.clone(),
            raw_cost: vec![f64::NAN; self.n],
            scratch_pools: (0..self.k).map(|_| Vec::new()).collect(),
            migrations: self.migrations,
            steals: self.steals,
            attempts: self.attempts,
            telemetry: self.telemetry.clone(),
            last_elapsed: self.last_elapsed,
            phase_walls: PhaseWalls::new(self.k),
            trace: None,
        }
    }

    /// Final reduction: the last generation's evidence contribution, the
    /// weighted posterior summary, and the aggregate metrics — then
    /// release the population, sweep memos, and run the final decommit.
    /// Identical to the old coordinator's epilogue.
    pub fn finish<M>(mut self, model: &M, shards: &mut [Heap]) -> FilterResult
    where
        M: SmcModel<State = S> + Sync,
    {
        let (final_lmean, _) = weight_stats(&self.lw, &mut self.w);
        self.log_z += final_lmean;
        let mut post = 0.0;
        for i in 0..self.n {
            let mut s = self.states[i];
            post += self.w[i] * model.summary(&mut shards[self.assign[i]], &mut s);
            self.states[i] = s;
        }

        let agg = aggregate_metrics(shards);
        let result = FilterResult {
            log_evidence: if self.observe { self.log_z } else { f64::NAN },
            posterior_mean: post,
            wall_s: self.start.elapsed().as_secs_f64(),
            peak_bytes: agg.peak_bytes,
            // K = 1: the continuous high-water mark is the exact global
            // peak.
            global_peak_bytes: if self.k == 1 {
                agg.peak_bytes
            } else {
                agg.global_peak_bytes
            },
            scratch_peak_bytes: agg.scratch_peak_bytes,
            migrations: self.migrations,
            steals: self.steals,
            series: std::mem::take(&mut self.series),
            attempts: self.attempts,
        };

        self.release_population(shards);
        // Final decommit: the population is gone, so everything beyond
        // the watermark is returnable. (No evacuation here — with no
        // survivors there is nothing to relocate; trim alone reclaims.)
        if let Some(keep) = self.cfg.decommit_watermark {
            trim_shards(shards, keep);
        }
        result
    }

    /// Conditional-SMC epilogue for the iteration just stepped: add the
    /// final evidence increment, draw the winner, copy its trajectory
    /// out **eagerly** (outside the tree pattern — the paper's §4 VBD
    /// note; a winner on a foreign shard is transplanted to the
    /// reference shard, equally eager), release `old_reference`, reduce
    /// the posterior, and release the population. Returns this
    /// iteration's [`FilterResult`] and the next reference trajectory
    /// (oldest first, owned by the reference shard). The session stays
    /// usable: [`restart`](FilterSession::restart) begins the next
    /// iteration.
    pub fn finish_gibbs<M>(
        &mut self,
        model: &M,
        shards: &mut [Heap],
        old_reference: Option<Vec<Lazy<S>>>,
    ) -> (FilterResult, Vec<Lazy<S>>)
    where
        M: SmcModel<State = S> + Sync,
    {
        let n = self.n;
        let t_max = self.t - 1;
        let (final_lmean, _) = weight_stats(&self.lw, &mut self.w);
        self.log_z += final_lmean;
        let mut srng = resample_rng(self.seed, t_max + 1);
        let winner = srng.categorical(&self.w);
        let s_win = self.assign[winner];
        let eager_ref = if s_win == self.s_ref {
            shards[self.s_ref].deep_copy_eager(&self.states[winner])
        } else {
            let (src, dst) = pair_mut(shards, s_win, self.s_ref);
            src.extract_into(&self.states[winner], dst)
        };
        let mut chain = model.chain(&mut shards[self.s_ref], &eager_ref);
        shards[self.s_ref].release(eager_ref);
        chain.reverse(); // oldest first
        if let Some(old) = old_reference {
            for h in old {
                shards[self.s_ref].release(h);
            }
        }

        let mut post = 0.0;
        for i in 0..n {
            let mut s = self.states[i];
            post += self.w[i] * model.summary(&mut shards[self.assign[i]], &mut s);
            self.states[i] = s;
        }
        self.release_population(shards);

        let agg = aggregate_metrics(shards);
        let result = FilterResult {
            log_evidence: self.log_z,
            posterior_mean: post,
            wall_s: self.start.elapsed().as_secs_f64(),
            peak_bytes: agg.peak_bytes,
            global_peak_bytes: if self.k == 1 {
                agg.peak_bytes
            } else {
                agg.global_peak_bytes
            },
            scratch_peak_bytes: agg.scratch_peak_bytes,
            migrations: self.migrations,
            steals: self.steals,
            series: std::mem::take(&mut self.series),
            attempts: n * t_max,
        };
        (result, chain)
    }

    /// Drop the session without producing a result: release the
    /// population, sweep memos, and run the decommit barrier. For
    /// abandoned what-if forks.
    pub fn abandon(mut self, shards: &mut [Heap]) {
        self.release_population(shards);
        // No evacuation: the abandoned population left no survivors to
        // relocate; the trim pass reclaims its emptied chunks.
        if let Some(keep) = self.cfg.decommit_watermark {
            trim_shards(shards, keep);
        }
    }

    /// Release every population handle and sweep the memo tables.
    fn release_population(&mut self, shards: &mut [Heap]) {
        for (i, s) in std::mem::take(&mut self.states).into_iter().enumerate() {
            shards[self.assign[i]].release(s);
        }
        for h in shards.iter_mut() {
            h.sweep_memos();
        }
    }

    /// The running evidence estimate as of the last completed
    /// generation: accumulated resampling increments plus the current
    /// weights' log mean. NaN for simulation-task sessions. Pure — the
    /// exact value [`finish`](FilterSession::finish) would report now.
    pub fn evidence_estimate(&mut self) -> f64 {
        if !self.observe {
            return f64::NAN;
        }
        let (lmean, _) = weight_stats(&self.lw, &mut self.w);
        self.log_z + lmean
    }

    /// The weighted posterior mean of the model summary over the current
    /// population — the mid-run analogue of
    /// [`FilterResult::posterior_mean`].
    pub fn posterior_estimate<M>(&mut self, model: &M, shards: &mut [Heap]) -> f64
    where
        M: SmcModel<State = S> + Sync,
    {
        let _ = weight_stats(&self.lw, &mut self.w);
        let mut post = 0.0;
        for i in 0..self.n {
            let mut s = self.states[i];
            post += self.w[i] * model.summary(&mut shards[self.assign[i]], &mut s);
            self.states[i] = s;
        }
        post
    }

    /// The generation the next [`step`](FilterSession::step) will
    /// execute (1-based).
    pub fn next_generation(&self) -> usize {
        self.t
    }

    /// Population size N.
    pub fn population(&self) -> usize {
        self.n
    }

    /// The latest generation's metrics snapshot, if any generation ran.
    pub fn last_metrics(&self) -> Option<&StepMetrics> {
        self.series.last()
    }

    /// The session's telemetry registry (see [`crate::telemetry`] for
    /// the stable name contract).
    pub fn telemetry(&self) -> &Registry {
        &self.telemetry
    }

    /// Relabel the trace sink's `session` field (the serve engine names
    /// each session's spans after the open-session name; standalone runs
    /// keep the default `"run"`). No-op without a sink.
    pub fn trace_label(&mut self, label: &str) {
        if let Some(log) = self.trace.as_mut() {
            log.set_session(label);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Model, RunConfig, Task};
    use crate::heap::CopyMode;
    use crate::models::ListModel;
    use crate::pool::ThreadPool;
    use crate::smc::run_filter_shards;

    fn cfg(n: usize, t: usize) -> RunConfig {
        let mut c = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
        c.n_particles = n;
        c.n_steps = t;
        c.seed = 1234;
        c
    }

    #[test]
    fn fork_is_lazy_and_both_lineages_exact() {
        let t_max = 12;
        let model = ListModel::synthetic(t_max, 5);
        let c = cfg(32, t_max);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx { pool: &pool, kalman: None, batch: true };

        // Oracle: the plain driver on a fresh heap.
        let mut oracle_heap = [Heap::new(CopyMode::LazySro)];
        let full =
            run_filter_shards(&model, &c, &mut oracle_heap, &ctx, Method::Bootstrap);

        // Session: step halfway, fork, finish both lineages.
        let mut shards = [Heap::new(CopyMode::LazySro)];
        let mut parent = FilterSession::begin(&model, &c, &mut shards, &ctx, Method::Bootstrap);
        for _ in 0..t_max / 2 {
            parent.step(&model, &mut shards, &ctx);
        }
        let scope = shards[0].begin_scope();
        let mut fork = parent.fork(&mut shards);
        let delta = shards[0].end_scope(scope);
        assert_eq!(delta.total_allocs, 0, "fork must not allocate payloads");
        assert_eq!(delta.eager_copies, 0, "fork must not copy eagerly");
        assert_eq!(delta.deep_copies, 32, "one lazy deep copy per particle");

        for _ in t_max / 2..t_max {
            parent.step(&model, &mut shards, &ctx);
            fork.step(&model, &mut shards, &ctx);
        }
        let pr = parent.finish(&model, &mut shards);
        let fr = fork.finish(&model, &mut shards);
        assert_eq!(pr.log_evidence.to_bits(), full.log_evidence.to_bits());
        assert_eq!(pr.posterior_mean.to_bits(), full.posterior_mean.to_bits());
        assert_eq!(fr.log_evidence.to_bits(), full.log_evidence.to_bits());
        assert_eq!(fr.posterior_mean.to_bits(), full.posterior_mean.to_bits());
        assert_eq!(shards[0].live_objects(), 0, "both lineages released");
    }

    #[test]
    fn telemetry_tracks_steps_and_forks() {
        let model = ListModel::synthetic(8, 9);
        let c = cfg(16, 8);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx { pool: &pool, kalman: None, batch: true };
        let mut shards = [Heap::new(CopyMode::LazySro)];
        let mut s = FilterSession::begin(&model, &c, &mut shards, &ctx, Method::Bootstrap);
        assert_eq!(s.telemetry().counter(crate::telemetry::SESSION_STEPS_TOTAL), 0);
        for _ in 0..8 {
            s.step(&model, &mut shards, &ctx);
        }
        let f = s.fork(&mut shards);
        let tele = s.telemetry();
        assert_eq!(tele.counter(crate::telemetry::SESSION_STEPS_TOTAL), 8);
        assert_eq!(tele.counter(crate::telemetry::SESSION_FORK_TOTAL), 1);
        assert_eq!(tele.counter(crate::telemetry::SESSION_ATTEMPTS_TOTAL), 16 * 8);
        assert_eq!(
            tele.histogram(crate::telemetry::STEP_WALL_SECONDS).unwrap().count(),
            8
        );
        assert!(tele.gauge(crate::telemetry::ESS_LAST).is_some());
        let render = tele.render();
        assert!(render.contains("session_steps_total 8"));
        f.abandon(&mut shards);
        s.abandon(&mut shards);
        assert_eq!(shards[0].live_objects(), 0);
    }
}
