//! Parallel execution over index ranges with static scheduling.
//!
//! Substrate replacing OpenMP (the paper parallelizes particle propagation
//! and weighting across threads with static scheduling, one bound per
//! core). [`ThreadPool::for_ranges`] runs `f(start, end)` on contiguous
//! chunks, one per worker, and joins — the numeric phase of each
//! generation. [`ThreadPool::for_shards`] is the scoped executor behind
//! the sharded heap: it hands each worker exclusive `&mut` access to one
//! element of a slice (e.g. one [`Heap`](crate::heap::Heap) shard plus its
//! particle chunk), which is what makes the allocate/copy/mutate hot path
//! run lock-free across cores (see the threading note in [`crate::heap`]).
//!
//! Implementation: scoped threads (`std::thread::scope`) spawned per call.
//! For the per-generation batch sizes of the evaluation models the spawn
//! cost is noise next to the numeric work, and the scope keeps borrows
//! safe without lifetime erasure. All the executors run chunk 0 on the
//! calling thread, so exactly `chunks - 1` threads are spawned per call.
//!
//! [`StealYard`] complements the shard executor with intra-generation work
//! stealing: workers that drain their queue park in the yard, and busy
//! workers donate tail particles (packaged with a scratch heap) to them —
//! see the work-stealing section of `DESIGN.md`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::thread;

/// Static-scheduling parallel executor.
pub struct ThreadPool {
    n_threads: usize,
}

/// Work-stealing coordination for shard workers — the donation half of the
/// intra-generation work-stealing executor (see `smc::filter`).
///
/// The sharded engine gives every worker exclusive `&mut` access to its
/// heap shard, so a thief can never reach into a victim's queue directly:
/// instead, a worker that drains its own queue parks in [`StealYard::take`]
/// and a *victim* — noticing [`StealYard::wanted`] between particles —
/// extracts tail particles of its own queue into a scratch heap and
/// [`StealYard::donate`]s the package. All heap operations stay under the
/// owner's `&mut`; the yard itself synchronizes only the package handoff
/// (one mutex-guarded queue plus two advisory atomics), never the
/// allocate/copy/mutate hot path.
///
/// Termination: `take` returns `None` once every worker is parked and no
/// donation is queued — at that point no future donation can arrive, since
/// donors are by definition not parked.
pub struct StealYard<B> {
    inner: Mutex<YardInner<B>>,
    cv: Condvar,
    workers: usize,
    /// Workers currently parked in [`StealYard::take`] (advisory mirror of
    /// the mutex-guarded count, readable without the lock).
    idle: AtomicUsize,
    /// Donated batches queued but not yet taken (advisory mirror).
    pending: AtomicUsize,
}

struct YardInner<B> {
    queue: VecDeque<B>,
    idle: usize,
    done: bool,
}

/// See [`StealYard::panic_guard`].
pub struct YardPanicGuard<'a, B: Send> {
    yard: &'a StealYard<B>,
}

impl<B: Send> Drop for YardPanicGuard<'_, B> {
    fn drop(&mut self) {
        if thread::panicking() {
            self.yard.abort();
        }
    }
}

impl<B: Send> StealYard<B> {
    /// A yard for `workers` cooperating shard workers. Every worker must
    /// eventually call [`StealYard::take`] (in a loop, until `None`) or the
    /// generation cannot terminate.
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0, "at least one worker");
        StealYard {
            inner: Mutex::new(YardInner {
                queue: VecDeque::new(),
                idle: 0,
                done: false,
            }),
            cv: Condvar::new(),
            workers,
            idle: AtomicUsize::new(0),
            pending: AtomicUsize::new(0),
        }
    }

    /// Victim-side cue: `true` when more workers are parked hungry than
    /// donations are queued. Lock-free (two relaxed loads) so it can run
    /// between every particle; advisory only — a stale answer costs one
    /// extra or one deferred donation, never correctness.
    #[inline]
    pub fn wanted(&self) -> bool {
        self.idle.load(Ordering::Relaxed) > self.pending.load(Ordering::Relaxed)
    }

    /// Queue a donated batch and wake one parked thief.
    pub fn donate(&self, batch: B) {
        let mut g = self.inner.lock().unwrap();
        g.queue.push_back(batch);
        self.pending.fetch_add(1, Ordering::Relaxed);
        self.cv.notify_one();
    }

    /// Unblock every parked worker and mark the generation complete
    /// regardless of outstanding work — the panic-safety hatch. A worker
    /// that panics never parks, so without this the surviving workers
    /// would wait for `idle == workers` forever; call it from a drop
    /// guard ([`StealYard::panic_guard`]) so unwinding wakes the yard.
    pub fn abort(&self) {
        let mut g = self.inner.lock().unwrap();
        g.done = true;
        self.cv.notify_all();
    }

    /// An RAII guard for one worker: if the worker unwinds (panics) while
    /// the guard is live, the yard is aborted so parked siblings return
    /// `None` instead of hanging, letting the scope join and propagate
    /// the panic.
    pub fn panic_guard(&self) -> YardPanicGuard<'_, B> {
        YardPanicGuard { yard: self }
    }

    /// Park until a donated batch arrives (`Some`) or the generation is
    /// complete — every worker parked and the queue empty (`None`).
    pub fn take(&self) -> Option<B> {
        let mut g = self.inner.lock().unwrap();
        g.idle += 1;
        self.idle.fetch_add(1, Ordering::Relaxed);
        loop {
            if let Some(b) = g.queue.pop_front() {
                g.idle -= 1;
                self.idle.fetch_sub(1, Ordering::Relaxed);
                self.pending.fetch_sub(1, Ordering::Relaxed);
                return Some(b);
            }
            if g.done || g.idle == self.workers {
                g.done = true;
                // The worker is leaving for good: drop it from the
                // advisory hungry count so `wanted` goes quiet. (The
                // mutex-guarded count is terminal once `done` is set.)
                self.idle.fetch_sub(1, Ordering::Relaxed);
                self.cv.notify_all();
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }
}

impl ThreadPool {
    /// Create an executor with `n` workers (0 = available parallelism).
    pub fn new(n: usize) -> Self {
        let n_threads = if n == 0 {
            thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            n
        };
        ThreadPool { n_threads }
    }

    /// Number of workers this executor fans out to.
    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Split `0..n` into contiguous chunks (static scheduling, one per
    /// worker) and run `f(start, end)` on each in parallel. Blocks until
    /// all chunks complete.
    pub fn for_ranges<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        let chunks = self.n_threads.min(n);
        if chunks == 1 {
            f(0, n);
            return;
        }
        let per = n.div_ceil(chunks);
        thread::scope(|s| {
            for c in 1..chunks {
                let start = c * per;
                let end = ((c + 1) * per).min(n);
                if start < end {
                    let f = &f;
                    s.spawn(move || f(start, end));
                }
            }
            // Run the first chunk on the calling thread.
            f(0, per.min(n));
        });
    }

    /// `out[i] = f(i)` in parallel over disjoint chunks. Like
    /// [`ThreadPool::for_ranges`], chunk 0 runs on the calling thread and
    /// only `chunks - 1` threads are spawned.
    pub fn map_indexed<T: Send, F>(&self, out: &mut [T], f: F)
    where
        F: Fn(usize) -> T + Send + Sync,
    {
        if out.is_empty() {
            return;
        }
        let chunks = self.n_threads.min(out.len());
        if chunks == 1 {
            for (i, o) in out.iter_mut().enumerate() {
                *o = f(i);
            }
            return;
        }
        let per = out.len().div_ceil(chunks);
        thread::scope(|s| {
            let mut iter = out.chunks_mut(per).enumerate();
            let first = iter.next();
            for (c, chunk) in iter {
                let f = &f;
                s.spawn(move || {
                    for (j, o) in chunk.iter_mut().enumerate() {
                        *o = f(c * per + j);
                    }
                });
            }
            // Run the first chunk on the calling thread.
            if let Some((_, chunk)) = first {
                for (j, o) in chunk.iter_mut().enumerate() {
                    *o = f(j);
                }
            }
        });
    }

    /// Scoped pair executor: run `f(&mut op.2, &mut items[op.0], &mut
    /// items[op.1])` for every op, where each op names a *pair* of
    /// elements (e.g. a (source, destination) heap-shard pair for a
    /// cross-shard transplant). Ops are scheduled into rounds: within a
    /// round all pairs are disjoint, so each op holds exclusive `&mut`
    /// access to both of its elements and the round runs concurrently on
    /// scoped threads (the first op of each round on the calling
    /// thread). The schedule is computed in one O(ops) pass — each op
    /// lands in the round `max(next_free[a], next_free[b])`, booking
    /// both endpoints past it — so scheduling is deterministic in op
    /// order. Panics if an op names `a == b` or an out-of-range index.
    pub fn for_pairs<T, U, F>(&self, items: &mut [T], ops: &mut [(usize, usize, U)], f: F)
    where
        T: Send,
        U: Send,
        F: Fn(&mut U, &mut T, &mut T) + Send + Sync,
    {
        if ops.is_empty() {
            return;
        }
        // Schedule: one pass over the ops, no per-round rescans.
        let mut next_free = vec![0usize; items.len()];
        let mut n_rounds = 0usize;
        let mut round_of = Vec::with_capacity(ops.len());
        for op in ops.iter() {
            let (a, b) = (op.0, op.1);
            assert!(
                a != b && a < items.len() && b < items.len(),
                "for_pairs: bad pair ({a}, {b}) over {} items",
                items.len()
            );
            let r = next_free[a].max(next_free[b]);
            next_free[a] = r + 1;
            next_free[b] = r + 1;
            round_of.push(r);
            n_rounds = n_rounds.max(r + 1);
        }
        let mut rounds: Vec<Vec<usize>> = vec![Vec::new(); n_rounds];
        for (j, &r) in round_of.iter().enumerate() {
            rounds[r].push(j); // members end up in increasing op order
        }
        for round in rounds {
            // Hand out disjoint `&mut` endpoints for this round. Op refs
            // come from a single forward walk of the slice (round members
            // are in increasing index order), item refs from a take-once
            // table of the (few) elements.
            let mut item_refs: Vec<Option<&mut T>> = items.iter_mut().map(Some).collect();
            let mut rest: &mut [(usize, usize, U)] = &mut ops[..];
            let mut consumed = 0usize;
            let mut units: Vec<(&mut U, &mut T, &mut T)> = Vec::with_capacity(round.len());
            for &j in &round {
                let tail = std::mem::take(&mut rest);
                let (_, tail) = tail.split_at_mut(j - consumed);
                let (op, tail) = tail.split_first_mut().expect("op index in range");
                rest = tail;
                consumed = j + 1;
                let (a, b) = (op.0, op.1);
                let ia = item_refs[a].take().expect("item handed out twice in a round");
                let ib = item_refs[b].take().expect("item handed out twice in a round");
                units.push((&mut op.2, ia, ib));
            }
            // Respect the pool's worker budget like the other executors:
            // at most n_threads workers, each running a chunk of the
            // round sequentially, chunk 0 on the calling thread.
            let workers = self.n_threads.min(units.len());
            if workers <= 1 {
                for (u, a, b) in units {
                    f(u, a, b);
                }
                continue;
            }
            let per = units.len().div_ceil(workers);
            thread::scope(|s| {
                let mut iter = units.into_iter();
                let first: Vec<_> = iter.by_ref().take(per).collect();
                loop {
                    let chunk: Vec<_> = iter.by_ref().take(per).collect();
                    if chunk.is_empty() {
                        break;
                    }
                    let f = &f;
                    s.spawn(move || {
                        for (u, a, b) in chunk {
                            f(u, a, b);
                        }
                    });
                }
                for (u, a, b) in first {
                    f(u, a, b);
                }
            });
        }
    }

    /// Scoped shard executor: run `f(index, &mut item)` for every element
    /// of `items`, with each element visited by exactly one worker —
    /// exclusive `&mut` access, no locks. Elements are distributed in
    /// contiguous chunks (static scheduling); chunk 0 runs on the calling
    /// thread. This is how per-generation particle propagation fans out
    /// over `&mut [Heap]` shards.
    pub fn for_shards<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Send + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let workers = self.n_threads.min(n);
        if workers == 1 {
            for (i, it) in items.iter_mut().enumerate() {
                f(i, it);
            }
            return;
        }
        let per = n.div_ceil(workers);
        thread::scope(|s| {
            let mut iter = items.chunks_mut(per).enumerate();
            let first = iter.next();
            for (c, chunk) in iter {
                let f = &f;
                s.spawn(move || {
                    for (j, it) in chunk.iter_mut().enumerate() {
                        f(c * per + j, it);
                    }
                });
            }
            if let Some((_, chunk)) = first {
                for (j, it) in chunk.iter_mut().enumerate() {
                    f(j, it);
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn for_ranges_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits = AtomicUsize::new(0);
        let sum = AtomicUsize::new(0);
        pool.for_ranges(1000, |s, e| {
            for i in s..e {
                hits.fetch_add(1, Ordering::Relaxed);
                sum.fetch_add(i, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn map_indexed_writes_all() {
        let pool = ThreadPool::new(3);
        let mut out = vec![0u64; 513];
        pool.map_indexed(&mut out, |i| (i * i) as u64);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i * i) as u64);
        }
    }

    #[test]
    fn single_thread_runs_inline() {
        let pool = ThreadPool::new(1);
        let mut out = vec![0u32; 10];
        pool.map_indexed(&mut out, |i| i as u32 + 1);
        assert_eq!(out[9], 10);
    }

    #[test]
    fn zero_work_is_fine() {
        let pool = ThreadPool::new(2);
        pool.for_ranges(0, |_, _| panic!("should not run"));
        let mut empty: Vec<u32> = Vec::new();
        pool.map_indexed(&mut empty, |_| 1);
    }

    #[test]
    fn default_parallelism_nonzero() {
        let pool = ThreadPool::new(0);
        assert!(pool.n_threads() >= 1);
    }

    /// Spawn-count assertion: with `chunks` chunks, exactly `chunks - 1`
    /// threads are spawned — chunk 0 runs on the calling thread, for both
    /// `for_ranges` and `map_indexed` (which used to spawn for chunk 0).
    #[test]
    fn chunk_zero_runs_on_calling_thread() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let main_id = thread::current().id();
        let pool = ThreadPool::new(4);

        // map_indexed: 4 items, 4 chunks of 1 — distinct thread per chunk.
        let mut ids = vec![None; 4];
        pool.map_indexed(&mut ids, |_| Some(thread::current().id()));
        assert_eq!(ids[0], Some(main_id), "map_indexed chunk 0 not inline");
        let distinct: HashSet<_> = ids.iter().flatten().collect();
        assert_eq!(distinct.len(), 4, "one worker per chunk");
        let spawned = ids.iter().flatten().filter(|id| **id != main_id).count();
        assert_eq!(spawned, 3, "exactly chunks - 1 threads spawned");

        // for_ranges: same contract.
        let seen: Mutex<Vec<(usize, thread::ThreadId)>> = Mutex::new(Vec::new());
        pool.for_ranges(4, |s, _| {
            seen.lock().unwrap().push((s, thread::current().id()));
        });
        let seen = seen.into_inner().unwrap();
        assert_eq!(seen.len(), 4);
        let zero = seen.iter().find(|(s, _)| *s == 0).unwrap();
        assert_eq!(zero.1, main_id, "for_ranges chunk 0 not inline");
        let distinct: HashSet<_> = seen.iter().map(|(_, id)| id).collect();
        assert_eq!(distinct.len(), 4);
    }

    #[test]
    fn for_shards_exclusive_and_inline_first_chunk() {
        use std::collections::HashSet;
        let main_id = thread::current().id();
        let pool = ThreadPool::new(2);
        // 4 items over 2 workers: chunks of 2; items 0-1 on the caller.
        let mut items: Vec<(usize, u64, Option<thread::ThreadId>)> =
            (0..4).map(|i| (i, 0, None)).collect();
        pool.for_shards(&mut items, |i, it| {
            assert_eq!(it.0, i, "index/item alignment");
            it.1 = (i as u64 + 1) * 10;
            it.2 = Some(thread::current().id());
        });
        for (i, it) in items.iter().enumerate() {
            assert_eq!(it.1, (i as u64 + 1) * 10);
        }
        assert_eq!(items[0].2, Some(main_id));
        assert_eq!(items[1].2, Some(main_id));
        assert_eq!(items[2].2, items[3].2);
        assert_ne!(items[2].2, Some(main_id));
        let distinct: HashSet<_> = items.iter().filter_map(|it| it.2).collect();
        assert_eq!(distinct.len(), 2, "one worker per contiguous chunk");
    }

    #[test]
    fn for_shards_single_worker_and_empty() {
        let pool = ThreadPool::new(1);
        let mut items = vec![0u32; 5];
        pool.for_shards(&mut items, |i, it| *it = i as u32 + 1);
        assert_eq!(items, vec![1, 2, 3, 4, 5]);
        let mut empty: Vec<u32> = Vec::new();
        ThreadPool::new(4).for_shards(&mut empty, |_, _| panic!("no items"));
    }

    #[test]
    fn for_pairs_runs_every_op_with_both_endpoints() {
        let pool = ThreadPool::new(4);
        let mut items = vec![0i64; 6];
        // Ops deliberately collide (0 appears three times) so several
        // rounds are needed; each op moves 1 unit from a to b and records
        // the observed sum in its payload slot.
        let mut ops: Vec<(usize, usize, i64)> = vec![
            (0, 1, 0),
            (2, 3, 0),
            (0, 2, 0),
            (4, 5, 0),
            (0, 5, 0),
        ];
        for it in items.iter_mut() {
            *it = 10;
        }
        pool.for_pairs(&mut items, &mut ops, |slot, a, b| {
            *a -= 1;
            *b += 1;
            *slot = *a + *b;
        });
        // Conservation: total unchanged, 0 lost 3 units.
        assert_eq!(items.iter().sum::<i64>(), 60);
        assert_eq!(items[0], 7);
        assert!(ops.iter().all(|o| o.2 != 0), "every op ran: {ops:?}");
    }

    #[test]
    fn for_pairs_single_thread_and_empty() {
        let pool = ThreadPool::new(1);
        let mut items = vec![1u32, 2, 3];
        let mut ops: Vec<(usize, usize, u32)> = vec![(0, 2, 0), (1, 0, 0)];
        pool.for_pairs(&mut items, &mut ops, |slot, a, b| {
            *slot = *a + *b;
        });
        assert_eq!(ops[0].2, 4);
        assert_eq!(ops[1].2, 3);
        let mut none: Vec<(usize, usize, u32)> = Vec::new();
        pool.for_pairs(&mut items, &mut none, |_, _, _| panic!("no ops"));
    }

    #[test]
    #[should_panic(expected = "bad pair")]
    fn for_pairs_rejects_self_pair() {
        let pool = ThreadPool::new(2);
        let mut items = vec![0u8; 3];
        let mut ops = vec![(1usize, 1usize, ())];
        pool.for_pairs(&mut items, &mut ops, |_, _, _| {});
    }

    #[test]
    fn for_shards_more_items_than_workers() {
        let pool = ThreadPool::new(3);
        let mut items: Vec<usize> = vec![0; 10];
        pool.for_shards(&mut items, |i, it| *it = i * i);
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn steal_yard_single_worker_terminates() {
        let yard: StealYard<u32> = StealYard::new(1);
        assert!(!yard.wanted());
        // The only worker parks with nothing queued: generation complete.
        assert_eq!(yard.take(), None);
        // Idempotent after done.
        assert_eq!(yard.take(), None);
    }

    #[test]
    fn steal_yard_hands_batches_to_thieves() {
        // Worker 0 donates 3 batches then parks; worker 1 starts parked and
        // must receive every batch, then both observe termination.
        let yard: StealYard<u64> = StealYard::new(2);
        let got = Mutex::new(Vec::new());
        thread::scope(|s| {
            let yard = &yard;
            let got = &got;
            s.spawn(move || {
                while let Some(b) = yard.take() {
                    got.lock().unwrap().push(b);
                }
            });
            // Victim: wait until the thief actually parks, donate, finish.
            while !yard.wanted() {
                thread::yield_now();
            }
            for b in [10u64, 20, 30] {
                yard.donate(b);
            }
            while let Some(b) = yard.take() {
                got.lock().unwrap().push(b);
            }
        });
        let mut got = got.into_inner().unwrap();
        got.sort();
        assert_eq!(got, vec![10, 20, 30]);
    }

    #[test]
    fn steal_yard_abort_unblocks_parked_workers() {
        // A panicking worker never parks; abort() is the hatch that lets
        // parked siblings return instead of waiting for idle == workers.
        let yard: StealYard<u8> = StealYard::new(3);
        thread::scope(|s| {
            let yard = &yard;
            let h = s.spawn(move || yard.take());
            while !yard.wanted() {
                thread::yield_now();
            }
            // Simulate the panicking worker's drop guard firing.
            yard.abort();
            assert_eq!(h.join().unwrap(), None);
        });
        // Guard without a panic is inert.
        {
            let _g = yard.panic_guard();
        }
        assert_eq!(yard.take(), None, "aborted yard stays done");
    }

    #[test]
    fn steal_yard_wanted_tracks_parked_thieves() {
        let yard: StealYard<()> = StealYard::new(2);
        assert!(!yard.wanted(), "nobody parked yet");
        thread::scope(|s| {
            let yard = &yard;
            s.spawn(move || {
                // Thief parks; it will be released by the donation below
                // and then by termination.
                while yard.take().is_some() {}
            });
            while !yard.wanted() {
                thread::yield_now();
            }
            yard.donate(());
            // Queued donation satisfies the parked thief: no more wanted
            // until it re-parks. (The thief may re-park quickly, so only
            // check the donation was consumed eventually.)
            while yard.take().is_some() {}
        });
        assert!(!yard.wanted(), "terminated yard wants nothing");
    }
}
