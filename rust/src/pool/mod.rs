//! Parallel execution over index ranges with static scheduling.
//!
//! Substrate replacing OpenMP (the paper parallelizes particle propagation
//! and weighting across threads with static scheduling, one bound per
//! core). [`ThreadPool::for_ranges`] runs `f(start, end)` on contiguous
//! chunks, one per worker, and joins — the numeric phase of each
//! generation. Heap mutation phases remain serialized on the caller (see
//! the threading note in [`crate::heap`]).
//!
//! Implementation: scoped threads (`std::thread::scope`) spawned per call.
//! For the per-generation batch sizes of the evaluation models the spawn
//! cost is noise next to the numeric work, and the scope keeps borrows
//! safe without lifetime erasure.

use std::thread;

/// Static-scheduling parallel executor.
pub struct ThreadPool {
    n_threads: usize,
}

impl ThreadPool {
    /// Create an executor with `n` workers (0 = available parallelism).
    pub fn new(n: usize) -> Self {
        let n_threads = if n == 0 {
            thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            n
        };
        ThreadPool { n_threads }
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Split `0..n` into contiguous chunks (static scheduling, one per
    /// worker) and run `f(start, end)` on each in parallel. Blocks until
    /// all chunks complete.
    pub fn for_ranges<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        let chunks = self.n_threads.min(n);
        if chunks == 1 {
            f(0, n);
            return;
        }
        let per = n.div_ceil(chunks);
        thread::scope(|s| {
            for c in 1..chunks {
                let start = c * per;
                let end = ((c + 1) * per).min(n);
                if start < end {
                    let f = &f;
                    s.spawn(move || f(start, end));
                }
            }
            // Run the first chunk on the calling thread.
            f(0, per.min(n));
        });
    }

    /// `out[i] = f(i)` in parallel over disjoint chunks.
    pub fn map_indexed<T: Send, F>(&self, out: &mut [T], f: F)
    where
        F: Fn(usize) -> T + Send + Sync,
    {
        if out.is_empty() {
            return;
        }
        let chunks = self.n_threads.min(out.len());
        if chunks == 1 {
            for (i, o) in out.iter_mut().enumerate() {
                *o = f(i);
            }
            return;
        }
        let per = out.len().div_ceil(chunks);
        thread::scope(|s| {
            for (c, chunk) in out.chunks_mut(per).enumerate() {
                let f = &f;
                s.spawn(move || {
                    for (j, o) in chunk.iter_mut().enumerate() {
                        *o = f(c * per + j);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn for_ranges_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits = AtomicUsize::new(0);
        let sum = AtomicUsize::new(0);
        pool.for_ranges(1000, |s, e| {
            for i in s..e {
                hits.fetch_add(1, Ordering::Relaxed);
                sum.fetch_add(i, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn map_indexed_writes_all() {
        let pool = ThreadPool::new(3);
        let mut out = vec![0u64; 513];
        pool.map_indexed(&mut out, |i| (i * i) as u64);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i * i) as u64);
        }
    }

    #[test]
    fn single_thread_runs_inline() {
        let pool = ThreadPool::new(1);
        let mut out = vec![0u32; 10];
        pool.map_indexed(&mut out, |i| i as u32 + 1);
        assert_eq!(out[9], 10);
    }

    #[test]
    fn zero_work_is_fine() {
        let pool = ThreadPool::new(2);
        pool.for_ranges(0, |_, _| panic!("should not run"));
        let mut empty: Vec<u32> = Vec::new();
        pool.map_indexed(&mut empty, |_| 1);
    }

    #[test]
    fn default_parallelism_nonzero() {
        let pool = ThreadPool::new(0);
        assert!(pool.n_threads() >= 1);
    }
}
