//! Parallel execution over index ranges with static scheduling.
//!
//! Substrate replacing OpenMP (the paper parallelizes particle propagation
//! and weighting across threads with static scheduling, one bound per
//! core). [`ThreadPool::for_ranges`] runs `f(start, end)` on contiguous
//! chunks, one per worker, and joins — the numeric phase of each
//! generation. [`ThreadPool::for_shards`] is the scoped executor behind
//! the sharded heap: it hands each worker exclusive `&mut` access to one
//! element of a slice (e.g. one [`Heap`](crate::heap::Heap) shard plus its
//! particle chunk), which is what makes the allocate/copy/mutate hot path
//! run lock-free across cores (see the threading note in [`crate::heap`]).
//!
//! Implementation: scoped threads (`std::thread::scope`) spawned per call.
//! For the per-generation batch sizes of the evaluation models the spawn
//! cost is noise next to the numeric work, and the scope keeps borrows
//! safe without lifetime erasure. All three executors run chunk 0 on the
//! calling thread, so exactly `chunks - 1` threads are spawned per call.

use std::thread;

/// Static-scheduling parallel executor.
pub struct ThreadPool {
    n_threads: usize,
}

impl ThreadPool {
    /// Create an executor with `n` workers (0 = available parallelism).
    pub fn new(n: usize) -> Self {
        let n_threads = if n == 0 {
            thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            n
        };
        ThreadPool { n_threads }
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Split `0..n` into contiguous chunks (static scheduling, one per
    /// worker) and run `f(start, end)` on each in parallel. Blocks until
    /// all chunks complete.
    pub fn for_ranges<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        let chunks = self.n_threads.min(n);
        if chunks == 1 {
            f(0, n);
            return;
        }
        let per = n.div_ceil(chunks);
        thread::scope(|s| {
            for c in 1..chunks {
                let start = c * per;
                let end = ((c + 1) * per).min(n);
                if start < end {
                    let f = &f;
                    s.spawn(move || f(start, end));
                }
            }
            // Run the first chunk on the calling thread.
            f(0, per.min(n));
        });
    }

    /// `out[i] = f(i)` in parallel over disjoint chunks. Like
    /// [`ThreadPool::for_ranges`], chunk 0 runs on the calling thread and
    /// only `chunks - 1` threads are spawned.
    pub fn map_indexed<T: Send, F>(&self, out: &mut [T], f: F)
    where
        F: Fn(usize) -> T + Send + Sync,
    {
        if out.is_empty() {
            return;
        }
        let chunks = self.n_threads.min(out.len());
        if chunks == 1 {
            for (i, o) in out.iter_mut().enumerate() {
                *o = f(i);
            }
            return;
        }
        let per = out.len().div_ceil(chunks);
        thread::scope(|s| {
            let mut iter = out.chunks_mut(per).enumerate();
            let first = iter.next();
            for (c, chunk) in iter {
                let f = &f;
                s.spawn(move || {
                    for (j, o) in chunk.iter_mut().enumerate() {
                        *o = f(c * per + j);
                    }
                });
            }
            // Run the first chunk on the calling thread.
            if let Some((_, chunk)) = first {
                for (j, o) in chunk.iter_mut().enumerate() {
                    *o = f(j);
                }
            }
        });
    }

    /// Scoped shard executor: run `f(index, &mut item)` for every element
    /// of `items`, with each element visited by exactly one worker —
    /// exclusive `&mut` access, no locks. Elements are distributed in
    /// contiguous chunks (static scheduling); chunk 0 runs on the calling
    /// thread. This is how per-generation particle propagation fans out
    /// over `&mut [Heap]` shards.
    pub fn for_shards<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Send + Sync,
    {
        let n = items.len();
        if n == 0 {
            return;
        }
        let workers = self.n_threads.min(n);
        if workers == 1 {
            for (i, it) in items.iter_mut().enumerate() {
                f(i, it);
            }
            return;
        }
        let per = n.div_ceil(workers);
        thread::scope(|s| {
            let mut iter = items.chunks_mut(per).enumerate();
            let first = iter.next();
            for (c, chunk) in iter {
                let f = &f;
                s.spawn(move || {
                    for (j, it) in chunk.iter_mut().enumerate() {
                        f(c * per + j, it);
                    }
                });
            }
            if let Some((_, chunk)) = first {
                for (j, it) in chunk.iter_mut().enumerate() {
                    f(j, it);
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn for_ranges_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits = AtomicUsize::new(0);
        let sum = AtomicUsize::new(0);
        pool.for_ranges(1000, |s, e| {
            for i in s..e {
                hits.fetch_add(1, Ordering::Relaxed);
                sum.fetch_add(i, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn map_indexed_writes_all() {
        let pool = ThreadPool::new(3);
        let mut out = vec![0u64; 513];
        pool.map_indexed(&mut out, |i| (i * i) as u64);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i * i) as u64);
        }
    }

    #[test]
    fn single_thread_runs_inline() {
        let pool = ThreadPool::new(1);
        let mut out = vec![0u32; 10];
        pool.map_indexed(&mut out, |i| i as u32 + 1);
        assert_eq!(out[9], 10);
    }

    #[test]
    fn zero_work_is_fine() {
        let pool = ThreadPool::new(2);
        pool.for_ranges(0, |_, _| panic!("should not run"));
        let mut empty: Vec<u32> = Vec::new();
        pool.map_indexed(&mut empty, |_| 1);
    }

    #[test]
    fn default_parallelism_nonzero() {
        let pool = ThreadPool::new(0);
        assert!(pool.n_threads() >= 1);
    }

    /// Spawn-count assertion: with `chunks` chunks, exactly `chunks - 1`
    /// threads are spawned — chunk 0 runs on the calling thread, for both
    /// `for_ranges` and `map_indexed` (which used to spawn for chunk 0).
    #[test]
    fn chunk_zero_runs_on_calling_thread() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let main_id = thread::current().id();
        let pool = ThreadPool::new(4);

        // map_indexed: 4 items, 4 chunks of 1 — distinct thread per chunk.
        let mut ids = vec![None; 4];
        pool.map_indexed(&mut ids, |_| Some(thread::current().id()));
        assert_eq!(ids[0], Some(main_id), "map_indexed chunk 0 not inline");
        let distinct: HashSet<_> = ids.iter().flatten().collect();
        assert_eq!(distinct.len(), 4, "one worker per chunk");
        let spawned = ids.iter().flatten().filter(|id| **id != main_id).count();
        assert_eq!(spawned, 3, "exactly chunks - 1 threads spawned");

        // for_ranges: same contract.
        let seen: Mutex<Vec<(usize, thread::ThreadId)>> = Mutex::new(Vec::new());
        pool.for_ranges(4, |s, _| {
            seen.lock().unwrap().push((s, thread::current().id()));
        });
        let seen = seen.into_inner().unwrap();
        assert_eq!(seen.len(), 4);
        let zero = seen.iter().find(|(s, _)| *s == 0).unwrap();
        assert_eq!(zero.1, main_id, "for_ranges chunk 0 not inline");
        let distinct: HashSet<_> = seen.iter().map(|(_, id)| id).collect();
        assert_eq!(distinct.len(), 4);
    }

    #[test]
    fn for_shards_exclusive_and_inline_first_chunk() {
        use std::collections::HashSet;
        let main_id = thread::current().id();
        let pool = ThreadPool::new(2);
        // 4 items over 2 workers: chunks of 2; items 0-1 on the caller.
        let mut items: Vec<(usize, u64, Option<thread::ThreadId>)> =
            (0..4).map(|i| (i, 0, None)).collect();
        pool.for_shards(&mut items, |i, it| {
            assert_eq!(it.0, i, "index/item alignment");
            it.1 = (i as u64 + 1) * 10;
            it.2 = Some(thread::current().id());
        });
        for (i, it) in items.iter().enumerate() {
            assert_eq!(it.1, (i as u64 + 1) * 10);
        }
        assert_eq!(items[0].2, Some(main_id));
        assert_eq!(items[1].2, Some(main_id));
        assert_eq!(items[2].2, items[3].2);
        assert_ne!(items[2].2, Some(main_id));
        let distinct: HashSet<_> = items.iter().filter_map(|it| it.2).collect();
        assert_eq!(distinct.len(), 2, "one worker per contiguous chunk");
    }

    #[test]
    fn for_shards_single_worker_and_empty() {
        let pool = ThreadPool::new(1);
        let mut items = vec![0u32; 5];
        pool.for_shards(&mut items, |i, it| *it = i as u32 + 1);
        assert_eq!(items, vec![1, 2, 3, 4, 5]);
        let mut empty: Vec<u32> = Vec::new();
        ThreadPool::new(4).for_shards(&mut empty, |_, _| panic!("no items"));
    }

    #[test]
    fn for_shards_more_items_than_workers() {
        let pool = ThreadPool::new(3);
        let mut items: Vec<usize> = vec![0; 10];
        pool.for_shards(&mut items, |i, it| *it = i * i);
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }
}
