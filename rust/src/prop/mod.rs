//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! Provides seeded case sweeps with failure reporting and a lightweight
//! shrinking strategy for integer-vector scripts: on failure, retry with
//! progressively truncated prefixes of the generating choices to report a
//! smaller reproduction seed/length.
//!
//! Usage:
//! ```ignore
//! prop::check(200, |g| {
//!     let xs = g.vec(0..100, |g| g.i64(0..10));
//!     my_invariant(&xs)
//! });
//! ```

use crate::rng::Pcg64;

/// Generation context handed to property closures.
pub struct Gen {
    rng: Pcg64,
    /// Size hint: later cases generate larger structures.
    pub size: usize,
    /// Optional cap on generated script length (used for shrinking).
    pub budget: Option<usize>,
    consumed: usize,
}

impl Gen {
    /// A generator for one case, seeded deterministically.
    pub fn new(seed: u64, size: usize) -> Self {
        Gen {
            rng: Pcg64::new(seed),
            size,
            budget: None,
            consumed: 0,
        }
    }

    /// Has the generation budget been exhausted (shrinking)?
    pub fn spent(&mut self) -> bool {
        self.consumed += 1;
        match self.budget {
            Some(b) => self.consumed > b,
            None => false,
        }
    }

    /// Uniform integer in `lo..=hi`.
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform `usize` in `lo..=hi`.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    /// Uniform `i64` in `lo..=hi`.
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// Pick an element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// Pick an index weighted by `w`.
    pub fn weighted(&mut self, w: &[f64]) -> usize {
        self.rng.categorical(w)
    }

    /// Direct access to the case's RNG (for model-specific draws).
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Outcome of a single property case.
pub enum CaseResult {
    /// Property held.
    Pass,
    /// Property violated, with a description.
    Fail(String),
    /// Case discarded (preconditions unmet).
    Discard,
}

impl From<bool> for CaseResult {
    fn from(ok: bool) -> Self {
        if ok {
            CaseResult::Pass
        } else {
            CaseResult::Fail("property returned false".into())
        }
    }
}

impl From<Result<(), String>> for CaseResult {
    fn from(r: Result<(), String>) -> Self {
        match r {
            Ok(()) => CaseResult::Pass,
            Err(e) => CaseResult::Fail(e),
        }
    }
}

/// Run `cases` seeded cases of a property. Panics with the seed, case
/// index, and (if the property is budget-aware) the smallest failing
/// budget, so failures are reproducible.
pub fn check<R: Into<CaseResult>>(cases: usize, mut prop: impl FnMut(&mut Gen) -> R) {
    check_seeded(0xC0FFEE, cases, &mut prop)
}

/// [`check`] with an explicit base seed (reproduce a reported failure).
pub fn check_seeded<R: Into<CaseResult>>(
    base_seed: u64,
    cases: usize,
    prop: &mut impl FnMut(&mut Gen) -> R,
) {
    let mut discards = 0usize;
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 4 + case * 64 / cases.max(1); // grow sizes over the run
        let mut g = Gen::new(seed, size);
        match prop(&mut g).into() {
            CaseResult::Pass => {}
            CaseResult::Discard => {
                discards += 1;
                assert!(
                    discards < cases * 10,
                    "too many discarded cases ({discards})"
                );
            }
            CaseResult::Fail(msg) => {
                // Shrink: find the smallest budget that still fails.
                let mut best: Option<(usize, String)> = None;
                let mut budget = 1usize;
                while budget < 4096 {
                    let mut g = Gen::new(seed, size);
                    g.budget = Some(budget);
                    if let CaseResult::Fail(m) = prop(&mut g).into() {
                        best = Some((budget, m));
                        break;
                    }
                    budget *= 2;
                }
                match best {
                    Some((b, m)) => panic!(
                        "property failed (seed={seed:#x}, case={case}, size={size}); \
                         shrunk to budget={b}: {m}"
                    ),
                    None => panic!(
                        "property failed (seed={seed:#x}, case={case}, size={size}): {msg}"
                    ),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(50, |g| {
            n += 1;
            let x = g.i64(0, 100);
            x >= 0 && x <= 100
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(50, |g| g.i64(0, 100) < 95);
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1, 10);
        for _ in 0..1000 {
            assert!((3..=7).contains(&g.u64(3, 7)));
            assert!((-5..=5).contains(&g.i64(-5, 5)));
            let f = g.f64(1.0, 2.0);
            assert!((1.0..2.0).contains(&f));
        }
        let xs = [10, 20, 30];
        assert!(xs.contains(g.pick(&xs)));
    }

    #[test]
    fn budget_consumption() {
        let mut g = Gen::new(2, 10);
        g.budget = Some(3);
        assert!(!g.spent());
        assert!(!g.spent());
        assert!(!g.spent());
        assert!(g.spent());
    }
}
