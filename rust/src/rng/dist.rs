//! Distribution samplers and log-densities.
//!
//! Everything the five evaluation models require, implemented against
//! [`Pcg64`](super::Pcg64): gamma (Marsaglia–Tsang 2000), beta, binomial
//! (inversion / BTPE-free split), Poisson (inversion / PTRS), categorical
//! (linear and Walker alias), multinomial, Dirichlet, and the matching
//! log-pdf/pmf functions used for particle weighting.

use super::Pcg64;

/// ln(2π), the Gaussian log-density constant.
pub const LN_2PI: f64 = 1.8378770664093453;

/// ln Γ(x) (Lanczos approximation, |err| < 1e-13 for x > 0).
pub fn ln_gamma(x: f64) -> f64 {
    // Lanczos g=7, n=9 coefficients.
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        pi.ln() - (pi * x).sin().ln() - ln_gamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = COEF[0];
        let t = x + 7.5;
        for (i, c) in COEF.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        0.5 * LN_2PI + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// ln n! via ln Γ.
#[inline]
pub fn ln_factorial(n: u64) -> f64 {
    ln_gamma(n as f64 + 1.0)
}

/// ln C(n, k).
#[inline]
pub fn ln_choose(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

// ----------------------------------------------------------------------
// Log densities (weighting)
// ----------------------------------------------------------------------

/// Normal log-pdf.
#[inline]
pub fn normal_lpdf(x: f64, mean: f64, sd: f64) -> f64 {
    let z = (x - mean) / sd;
    -0.5 * z * z - sd.ln() - 0.5 * LN_2PI
}

/// Gamma(shape k, scale θ) log-pdf.
pub fn gamma_lpdf(x: f64, shape: f64, scale: f64) -> f64 {
    if x <= 0.0 {
        return f64::NEG_INFINITY;
    }
    (shape - 1.0) * x.ln() - x / scale - ln_gamma(shape) - shape * scale.ln()
}

/// Beta(a, b) log-pdf.
pub fn beta_lpdf(x: f64, a: f64, b: f64) -> f64 {
    if !(0.0..=1.0).contains(&x) {
        return f64::NEG_INFINITY;
    }
    (a - 1.0) * x.ln() + (b - 1.0) * (1.0 - x).ln() + ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b)
}

/// Poisson(λ) log-pmf.
pub fn poisson_lpmf(k: u64, lambda: f64) -> f64 {
    if lambda <= 0.0 {
        return if k == 0 { 0.0 } else { f64::NEG_INFINITY };
    }
    k as f64 * lambda.ln() - lambda - ln_factorial(k)
}

/// Binomial(n, p) log-pmf.
pub fn binomial_lpmf(k: u64, n: u64, p: f64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    if p <= 0.0 {
        return if k == 0 { 0.0 } else { f64::NEG_INFINITY };
    }
    if p >= 1.0 {
        return if k == n { 0.0 } else { f64::NEG_INFINITY };
    }
    ln_choose(n, k) + k as f64 * p.ln() + (n - k) as f64 * (1.0 - p).ln()
}

/// Negative-binomial (r, p) log-pmf: the gamma–Poisson marginal used by
/// delayed sampling.
pub fn negbin_lpmf(k: u64, r: f64, p: f64) -> f64 {
    ln_gamma(k as f64 + r) - ln_factorial(k) - ln_gamma(r) + r * p.ln()
        + k as f64 * (1.0 - p).ln()
}

/// Beta-binomial(n, a, b) log-pmf: the beta–binomial marginal used by
/// delayed sampling.
pub fn betabin_lpmf(k: u64, n: u64, a: f64, b: f64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_choose(n, k) + ln_gamma(k as f64 + a) + ln_gamma((n - k) as f64 + b)
        - ln_gamma(n as f64 + a + b)
        + ln_gamma(a + b)
        - ln_gamma(a)
        - ln_gamma(b)
}

// ----------------------------------------------------------------------
// Samplers
// ----------------------------------------------------------------------

impl Pcg64 {
    /// Gamma(shape k, scale θ), Marsaglia–Tsang squeeze for k ≥ 1, with the
    /// boost trick for k < 1.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        debug_assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            let u = self.next_f64_open();
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.next_f64_open();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v * scale;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v * scale;
            }
        }
    }

    /// Beta(a, b) via two gammas.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a, 1.0);
        let y = self.gamma(b, 1.0);
        x / (x + y)
    }

    /// Exponential(rate λ).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.next_f64_open().ln() / rate
    }

    /// Log-normal.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson(λ): inversion for small λ, PTRS-style normal cutover for
    /// large λ (transformed rejection, Hörmann 1993 simplified).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            // Knuth inversion in log space for robustness.
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64_open();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        // Rejection from a discretized normal with a correction loop.
        let sq = lambda.sqrt();
        loop {
            let x = self.gaussian(lambda, sq);
            if x < 0.0 {
                continue;
            }
            let k = x.floor() as u64;
            // Accept with probability pmf(k)/envelope; use ratio test.
            let lp = poisson_lpmf(k, lambda);
            let lq = normal_lpdf(k as f64 + 0.5, lambda, sq);
            if self.next_f64_open().ln() <= lp - lq - 0.1 {
                return k;
            }
        }
    }

    /// Binomial(n, p): inversion for small n·p, otherwise split recursively
    /// via the beta-median trick (BTRD-free, exact).
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        if n == 0 || p <= 0.0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        if n <= 64 {
            // Direct Bernoulli sum.
            let mut k = 0;
            for _ in 0..n {
                if self.next_f64() < p {
                    k += 1;
                }
            }
            return k;
        }
        // Recursive beta split: X ~ Bin(n,p) via the order-statistic
        // decomposition with the median of n uniforms ~ Beta(m, n+1-m).
        let m = n / 2 + 1;
        let x = self.beta(m as f64, (n + 1 - m) as f64);
        if x <= p {
            m + self.binomial(n - m, (p - x) / (1.0 - x))
        } else {
            self.binomial(m - 1, p / x)
        }
    }

    /// Categorical over unnormalized non-negative weights (linear scan).
    pub fn categorical(&mut self, w: &[f64]) -> usize {
        let total: f64 = w.iter().sum();
        debug_assert!(total > 0.0, "categorical with zero total weight");
        let mut u = self.next_f64() * total;
        for (i, wi) in w.iter().enumerate() {
            u -= wi;
            if u <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }

    /// Categorical over *log* weights (log-sum-exp normalized).
    pub fn categorical_log(&mut self, lw: &[f64]) -> usize {
        let m = lw.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let w: Vec<f64> = lw.iter().map(|x| (x - m).exp()).collect();
        self.categorical(&w)
    }

    /// Dirichlet(α).
    pub fn dirichlet(&mut self, alpha: &[f64]) -> Vec<f64> {
        let xs: Vec<f64> = alpha.iter().map(|&a| self.gamma(a, 1.0)).collect();
        let s: f64 = xs.iter().sum();
        xs.into_iter().map(|x| x / s).collect()
    }

    /// Multinomial counts for `n` trials over unnormalized weights.
    pub fn multinomial(&mut self, n: u64, w: &[f64]) -> Vec<u64> {
        let mut counts = vec![0u64; w.len()];
        let mut rest: f64 = w.iter().sum();
        let mut left = n;
        for i in 0..w.len() - 1 {
            if left == 0 || rest <= 0.0 {
                break;
            }
            let p = (w[i] / rest).clamp(0.0, 1.0);
            let k = self.binomial(left, p);
            counts[i] = k;
            left -= k;
            rest -= w[i];
        }
        *counts.last_mut().unwrap() += left;
        counts
    }
}

/// Walker alias table for O(1) categorical sampling (used by the PCFG
/// proposal where the same weight vector is sampled many times).
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasTable {
    /// Build the table from (unnormalized, nonnegative) weights.
    pub fn new(w: &[f64]) -> Self {
        let n = w.len();
        let total: f64 = w.iter().sum();
        assert!(total > 0.0 && n > 0);
        let mut prob: Vec<f64> = w.iter().map(|x| x * n as f64 / total).collect();
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = (0..n).filter(|&i| prob[i] < 1.0).collect();
        let mut large: Vec<usize> = (0..n).filter(|&i| prob[i] >= 1.0).collect();
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            alias[s] = l;
            prob[l] = prob[l] + prob[s] - 1.0;
            if prob[l] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        for i in large.into_iter().chain(small) {
            prob[i] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// Draw a category in O(1).
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let n = self.prob.len();
        let i = rng.below(n as u64) as usize;
        if rng.next_f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(2.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-10);
    }

    #[test]
    fn lpdfs_normalize_roughly() {
        // Riemann check that densities integrate to ~1.
        let dx = 0.001;
        let total: f64 = (1..20_000)
            .map(|i| normal_lpdf(-10.0 + i as f64 * dx, 0.0, 1.0).exp() * dx)
            .sum();
        assert!((total - 1.0).abs() < 1e-3, "normal integrates to {total}");
        let total: f64 = (1..20_000)
            .map(|i| gamma_lpdf(i as f64 * dx, 2.5, 0.7).exp() * dx)
            .sum();
        assert!((total - 1.0).abs() < 1e-2, "gamma integrates to {total}");
        let total: f64 = (0..200).map(|k| poisson_lpmf(k, 12.0).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9, "poisson sums to {total}");
        let total: f64 = (0..=50).map(|k| binomial_lpmf(k, 50, 0.3).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9, "binomial sums to {total}");
        let total: f64 = (0..400).map(|k| negbin_lpmf(k, 5.0, 0.4).exp()).sum();
        assert!((total - 1.0).abs() < 1e-6, "negbin sums to {total}");
        let total: f64 = (0..=30).map(|k| betabin_lpmf(k, 30, 2.0, 5.0).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9, "betabin sums to {total}");
    }

    #[test]
    fn gamma_sampler_moments() {
        let mut r = Pcg64::new(10);
        let (shape, scale) = (3.0, 2.0);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = r.gamma(shape, scale);
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - shape * scale).abs() < 0.05, "mean {mean}");
        assert!((var - shape * scale * scale).abs() < 0.3, "var {var}");
    }

    #[test]
    fn gamma_small_shape() {
        let mut r = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.gamma(0.3, 1.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.3).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn beta_sampler_moments() {
        let mut r = Pcg64::new(12);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.beta(2.0, 6.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn poisson_sampler_small_and_large() {
        let mut r = Pcg64::new(13);
        let n = 50_000;
        for lambda in [0.5, 4.0, 80.0] {
            let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda {lambda}: mean {mean}"
            );
        }
    }

    #[test]
    fn binomial_sampler_small_and_large() {
        let mut r = Pcg64::new(14);
        let n = 30_000;
        for (trials, p) in [(10u64, 0.3), (1000u64, 0.01), (5000u64, 0.6)] {
            let mean: f64 = (0..n).map(|_| r.binomial(trials, p) as f64).sum::<f64>() / n as f64;
            let expect = trials as f64 * p;
            assert!(
                (mean - expect).abs() < expect.max(1.0) * 0.05,
                "Bin({trials},{p}): mean {mean} expect {expect}"
            );
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::new(15);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        for _ in 0..50_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 50_000.0;
        assert!((frac - 0.7).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn categorical_log_matches_linear() {
        let mut r1 = Pcg64::new(16);
        let mut r2 = Pcg64::new(16);
        let w = [0.1, 0.4, 0.5];
        let lw: Vec<f64> = w.iter().map(|x: &f64| x.ln() + 100.0).collect(); // shifted
        for _ in 0..1000 {
            assert_eq!(r1.categorical(&w), r2.categorical_log(&lw));
        }
    }

    #[test]
    fn alias_table_matches_linear_distribution() {
        let mut r = Pcg64::new(17);
        let w = [0.5, 0.1, 0.2, 3.0, 1.2];
        let table = AliasTable::new(&w);
        let total: f64 = w.iter().sum();
        let n = 200_000;
        let mut counts = vec![0usize; w.len()];
        for _ in 0..n {
            counts[table.sample(&mut r)] += 1;
        }
        for (i, c) in counts.iter().enumerate() {
            let frac = *c as f64 / n as f64;
            let expect = w[i] / total;
            assert!((frac - expect).abs() < 0.01, "i={i} frac={frac} expect={expect}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg64::new(18);
        let x = r.dirichlet(&[1.0, 2.0, 3.0]);
        assert!((x.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(x.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn multinomial_conserves_trials() {
        let mut r = Pcg64::new(19);
        let counts = r.multinomial(1000, &[0.2, 0.3, 0.5]);
        assert_eq!(counts.iter().sum::<u64>(), 1000);
        assert!(counts[2] > counts[0]);
    }
}
