//! Pseudo-random number generation and sampling.
//!
//! Substrate module: no rand/distributions crates are available offline, so
//! this implements PCG64 (O'Neill 2014) with SplitMix64 seeding, plus the
//! samplers the five evaluation models need: uniform, normal, log-normal,
//! gamma (Marsaglia–Tsang), beta, binomial, Poisson, categorical,
//! multinomial, and Dirichlet.
//!
//! Streams: [`Pcg64::stream`] derives statistically independent generators
//! from one seed — one per particle/thread, matching the paper's
//! "random number seeds are matched across configurations" methodology
//! (identical seeds → identical resampling decisions in all copy modes).

mod dist;

pub use dist::*;

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// SplitMix64: seed expander (Steele et al. 2014).
#[inline]
pub fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Pcg64 {
    /// Seed a generator. Equal seeds give equal streams.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let a = splitmix64(&mut s) as u128;
        let b = splitmix64(&mut s) as u128;
        let c = splitmix64(&mut s) as u128;
        let d = splitmix64(&mut s) as u128;
        let mut rng = Pcg64 {
            state: (a << 64) | b,
            inc: (((c << 64) | d) << 1) | 1, // stream must be odd
        };
        rng.next_u64();
        rng
    }

    /// Derive the `i`-th independent stream from this generator's seed
    /// lineage (distinct increment → distinct sequence).
    pub fn stream(seed: u64, i: u64) -> Self {
        let mut s = seed ^ (0xA076_1D64_78BD_642F_u64.wrapping_mul(i.wrapping_add(1)));
        let mixed = splitmix64(&mut s);
        Pcg64::new(mixed)
    }

    /// Next raw 64-bit output (PCG XSL-RR 128/64).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random bits into the mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] (safe for log()).
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via the polar (Marsaglia) method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with mean and standard deviation.
    #[inline]
    pub fn gaussian(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn streams_are_distinct() {
        let mut s0 = Pcg64::stream(7, 0);
        let mut s1 = Pcg64::stream(7, 1);
        let x: Vec<u64> = (0..8).map(|_| s0.next_u64()).collect();
        let y: Vec<u64> = (0..8).map(|_| s1.next_u64()).collect();
        assert_ne!(x, y);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Pcg64::new(2);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(3);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Pcg64::new(4);
        let mut xs: Vec<u32> = (0..10).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>());
    }
}
