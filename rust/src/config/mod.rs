//! Run configuration: the experiment matrix of the paper's §4.
//!
//! A [`RunConfig`] names a (problem, task, copy-mode) cell plus its scale
//! parameters. Configs come from CLI flags and/or a TOML-subset file
//! (`key = value` lines with `[section]` headers), CLI taking precedence —
//! the launcher plumbing a deployment-grade framework needs.

use crate::heap::{AllocatorKind, CopyMode};
use crate::smc::rebalance::RebalancePolicy;
use std::collections::BTreeMap;

/// Which §4 problem to run.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Model {
    /// Rao-Blackwellized particle filter (mixed linear/nonlinear SSM).
    Rbpf,
    /// Probabilistic context-free grammar (auxiliary PF, ragged stacks).
    Pcfg,
    /// Vector-borne disease compartment model (particle Gibbs).
    Vbd,
    /// Multi-object tracking (variable track sets).
    Mot,
    /// Constant-rate birth-death phylogenetics (alive PF).
    Crbd,
    /// The Table 1/2 linked-list microbenchmark model.
    List,
}

impl Model {
    /// Parse a model name as accepted by `--model`.
    pub fn parse(s: &str) -> Option<Model> {
        match s.to_ascii_lowercase().as_str() {
            "rbpf" => Some(Model::Rbpf),
            "pcfg" => Some(Model::Pcfg),
            "vbd" => Some(Model::Vbd),
            "mot" => Some(Model::Mot),
            "crbd" => Some(Model::Crbd),
            "list" => Some(Model::List),
            _ => None,
        }
    }

    /// Canonical lowercase name (CLI/bench labels).
    pub fn name(self) -> &'static str {
        match self {
            Model::Rbpf => "rbpf",
            Model::Pcfg => "pcfg",
            Model::Vbd => "vbd",
            Model::Mot => "mot",
            Model::Crbd => "crbd",
            Model::List => "list",
        }
    }

    /// The five evaluation problems of §4 (excludes the microbenchmark).
    pub const EVAL: [Model; 5] = [Model::Rbpf, Model::Pcfg, Model::Vbd, Model::Mot, Model::Crbd];

    /// Paper-scale (N, T_inference, T_simulation) for each problem (§4).
    pub fn paper_scale(self) -> (usize, usize, usize) {
        match self {
            Model::Rbpf => (2048, 500, 500),
            Model::Pcfg => (16384, 3262, 2000),
            Model::Vbd => (4096, 182, 400),
            Model::Mot => (4096, 100, 300),
            Model::Crbd => (5000, 173, 173),
            Model::List => (256, 100, 100),
        }
    }

    /// Reduced default scale so the full Figure 5–7 sweep completes in
    /// minutes on a laptop-class machine (recorded in EXPERIMENTS.md).
    pub fn default_scale(self) -> (usize, usize, usize) {
        match self {
            Model::Rbpf => (256, 150, 150),
            Model::Pcfg => (512, 300, 200),
            Model::Vbd => (256, 120, 200),
            Model::Mot => (192, 60, 120),
            Model::Crbd => (384, 120, 120),
            Model::List => (128, 80, 80),
        }
    }
}

/// Inference vs simulation (the paper's two tasks; simulation performs no
/// copies and isolates lazy-pointer overhead).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Task {
    /// Condition on observations; resample (the copy-heavy task).
    Inference,
    /// Sample forward without conditioning (no copies; Figure 6).
    Simulation,
}

impl Task {
    /// Parse a task name as accepted by `--task`.
    pub fn parse(s: &str) -> Option<Task> {
        match s.to_ascii_lowercase().as_str() {
            "inference" | "infer" => Some(Task::Inference),
            "simulation" | "simulate" | "sim" => Some(Task::Simulation),
            _ => None,
        }
    }

    /// Canonical lowercase name (CLI/bench labels).
    pub fn name(self) -> &'static str {
        match self {
            Task::Inference => "inference",
            Task::Simulation => "simulation",
        }
    }
}

/// A fully-specified run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Which evaluation problem to run.
    pub model: Model,
    /// Inference or simulation.
    pub task: Task,
    /// Copy mode of every heap in the run.
    pub mode: CopyMode,
    /// Number of particles N.
    pub n_particles: usize,
    /// Number of generations T.
    pub n_steps: usize,
    /// PRNG seed (matched across configurations, §4).
    pub seed: u64,
    /// Worker threads for the numeric phase (0 = all cores).
    pub threads: usize,
    /// Heap shards K for parallel particle propagation (0 = match the
    /// worker thread count). Outputs are bit-identical for every K: each
    /// shard-local run takes the batched numeric step over its own SoA
    /// lanes (the compiled Kalman artifact when loaded, the f64 CPU
    /// oracle otherwise — both elementwise per particle, so any split of
    /// the population matches the whole-batch result bitwise). K = 1 is
    /// the serialized single-heap platform.
    pub shards: usize,
    /// Offspring rebalancing policy applied at each resampling step when
    /// K > 1 (outputs are bit-identical for every policy; only the shard
    /// placement of heap work changes). See
    /// [`RebalancePolicy`](crate::smc::rebalance::RebalancePolicy).
    pub rebalance: RebalancePolicy,
    /// Imbalance fraction (of the mean predicted shard load) that must be
    /// exceeded before the rebalancer migrates an offspring off its
    /// ancestor's shard.
    pub rebalance_threshold: f64,
    /// Intra-generation work stealing (K > 1, inference only — the
    /// simulation task keeps its zero-copy contract by construction): a
    /// shard worker that drains its run queue steals tail particles from
    /// the heaviest remaining queue — stolen particles propagate in a
    /// scratch heap and are transplanted back. Outputs are bit-identical
    /// with stealing on or off (RNG streams stay keyed by global particle
    /// index); only where heap work runs changes.
    pub steal: bool,
    /// Minimum pending particles a victim queue must hold before it
    /// donates (about half of) its tail to an idle worker. Guards against
    /// transplant overhead dominating near the end of a generation.
    pub steal_min: usize,
    /// Payload-storage backend for every heap (and scratch heap) of the
    /// run: `slab` (size-class slabs with free-list reuse, the default)
    /// or `system` (one exact-layout system allocation per payload — the
    /// differential baseline). Outputs are bit-identical either way; only
    /// where payload bytes live changes.
    pub allocator: AllocatorKind,
    /// Slab decommit watermark (`--decommit-watermark`): at each
    /// generation barrier, fully-empty slab chunks beyond this many per
    /// size class are returned to the system allocator
    /// ([`Heap::trim`](crate::heap::Heap::trim)), bounding long-run
    /// committed residency. `None` (flag value `off`) disables decommit —
    /// committed bytes then track the high-water mark for the life of
    /// the heap. Outputs are bit-identical either way; only where chunk
    /// memory lives changes. Default: keep
    /// [`DEFAULT_DECOMMIT_WATERMARK`](crate::heap::DEFAULT_DECOMMIT_WATERMARK)
    /// chunks.
    pub decommit_watermark: Option<usize>,
    /// Evacuation sparsity threshold (`--evacuate-threshold`, in
    /// `[0, 1]`): at each generation barrier, slab chunks whose live
    /// payload bytes are at or below this fraction of the chunk are
    /// compacted — survivors placement-moved into same-class bump space,
    /// the emptied chunk decommitted
    /// ([`Heap::evacuate`](crate::heap::Heap::evacuate)). `None` (flag
    /// value `off`, the default) disables evacuation. Outputs are
    /// bit-identical either way; only where payload bytes live changes.
    pub evacuate_threshold: Option<f64>,
    /// ESS-fraction resampling trigger (1.0 = always resample, the paper's
    /// setting for the memory-pattern evaluation).
    pub ess_threshold: f64,
    /// Particle-Gibbs outer iterations (VBD; paper: 3).
    pub pg_iterations: usize,
    /// Use the PJRT-compiled artifacts for batched numeric work when
    /// available (falls back to the CPU oracle path otherwise).
    pub use_xla: bool,
    /// Batched SoA numeric path (`--batch`): when `true` (the default)
    /// the coordinator offers each shard-local run to the model's
    /// [`step_batched`](crate::smc::SmcModel::step_batched) hook; `off`
    /// forces the scalar per-particle reference path. Outputs are
    /// bit-identical either way — the toggle is a differential-testing
    /// and bisection axis, not a semantic switch.
    pub batch: bool,
    /// Emit a per-generation metrics series (Figure 7).
    pub series: bool,
    /// `serve` front-end: TCP listen address (`addr:port`). `None` (the
    /// default) keeps the stdin line protocol. Settable from a config
    /// file (`listen = 127.0.0.1:7878`) or the `--listen` flag.
    pub listen: Option<String>,
    /// `serve` observability: address (`addr:port`) of the Prometheus
    /// `GET /metrics` scrape endpoint (`--metrics-addr`). `None` (the
    /// default) starts no endpoint; the `telemetry` verb still works.
    pub metrics_addr: Option<String>,
    /// Per-phase trace sink (`--trace <path>`, config `trace`): every
    /// generation barrier appends one JSONL span per non-zero phase
    /// wall. `None` (the default) records nothing. The hard contract:
    /// tracing never influences computation — outputs are bit-identical
    /// with the sink on or off (pinned by the differential suite).
    pub trace: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        let (n, t, _) = Model::Rbpf.default_scale();
        RunConfig {
            model: Model::Rbpf,
            task: Task::Inference,
            mode: CopyMode::LazySro,
            n_particles: n,
            n_steps: t,
            seed: 20200401,
            threads: 0,
            shards: 0,
            rebalance: RebalancePolicy::Greedy,
            rebalance_threshold: 0.25,
            steal: true,
            steal_min: 4,
            allocator: AllocatorKind::Slab,
            decommit_watermark: Some(crate::heap::DEFAULT_DECOMMIT_WATERMARK),
            evacuate_threshold: None,
            ess_threshold: 1.0,
            pg_iterations: 3,
            use_xla: true,
            batch: true,
            series: false,
            listen: None,
            metrics_addr: None,
            trace: None,
        }
    }
}

impl RunConfig {
    /// Construct for a given model/task, using its default scale.
    pub fn for_model(model: Model, task: Task, mode: CopyMode) -> Self {
        let (n, t_inf, t_sim) = model.default_scale();
        RunConfig {
            model,
            task,
            mode,
            n_particles: n,
            n_steps: match task {
                Task::Inference => t_inf,
                Task::Simulation => t_sim,
            },
            ..Default::default()
        }
    }

    /// Apply `key = value` overrides (from file or CLI).
    pub fn apply(&mut self, key: &str, value: &str) -> Result<(), String> {
        match key {
            "model" => self.model = Model::parse(value).ok_or(format!("bad model {value}"))?,
            "task" => self.task = Task::parse(value).ok_or(format!("bad task {value}"))?,
            "mode" | "copy" => {
                self.mode = CopyMode::parse(value).ok_or(format!("bad mode {value}"))?
            }
            "particles" | "n" => self.n_particles = value.parse().map_err(|e| format!("{e}"))?,
            "steps" | "t" => self.n_steps = value.parse().map_err(|e| format!("{e}"))?,
            "seed" => self.seed = value.parse().map_err(|e| format!("{e}"))?,
            "threads" => self.threads = value.parse().map_err(|e| format!("{e}"))?,
            "shards" | "k" => self.shards = value.parse().map_err(|e| format!("{e}"))?,
            "rebalance" => {
                self.rebalance = RebalancePolicy::parse(value)
                    .ok_or(format!("bad rebalance policy {value} (off|greedy|budget)"))?
            }
            "rebalance-threshold" | "rebalance_threshold" => {
                self.rebalance_threshold = value.parse().map_err(|e| format!("{e}"))?
            }
            "steal" => {
                self.steal = match value.to_ascii_lowercase().as_str() {
                    "on" | "true" | "1" | "yes" => true,
                    "off" | "false" | "0" | "no" => false,
                    _ => return Err(format!("bad steal value {value} (on|off)")),
                }
            }
            "steal-threshold" | "steal_threshold" | "steal-min" | "steal_min" => {
                self.steal_min = value.parse().map_err(|e| format!("{e}"))?
            }
            "allocator" | "alloc" => {
                self.allocator = AllocatorKind::parse(value)
                    .ok_or(format!("bad allocator {value} (system|slab)"))?
            }
            "decommit-watermark" | "decommit_watermark" => {
                self.decommit_watermark = match value.to_ascii_lowercase().as_str() {
                    "off" | "none" | "never" => None,
                    v => Some(v.parse().map_err(|e| {
                        format!("bad decommit watermark {value} (integer or off): {e}")
                    })?),
                }
            }
            "evacuate-threshold" | "evacuate_threshold" => {
                self.evacuate_threshold = match value.to_ascii_lowercase().as_str() {
                    "off" | "none" | "never" => None,
                    v => {
                        let f: f64 = v.parse().map_err(|e| {
                            format!("bad evacuate threshold {value} (fraction or off): {e}")
                        })?;
                        if !(0.0..=1.0).contains(&f) {
                            return Err(format!(
                                "bad evacuate threshold {value} (must be in [0, 1])"
                            ));
                        }
                        Some(f)
                    }
                }
            }
            "ess" => self.ess_threshold = value.parse().map_err(|e| format!("{e}"))?,
            "pg-iterations" | "pg_iterations" => {
                self.pg_iterations = value.parse().map_err(|e| format!("{e}"))?
            }
            "xla" => self.use_xla = matches!(value, "true" | "1" | "yes"),
            "batch" => {
                self.batch = match value.to_ascii_lowercase().as_str() {
                    "on" | "true" | "1" | "yes" => true,
                    "off" | "false" | "0" | "no" => false,
                    _ => return Err(format!("bad batch value {value} (on|off)")),
                }
            }
            "series" => self.series = matches!(value, "true" | "1" | "yes"),
            "listen" => {
                self.listen = match value {
                    "" | "off" | "none" => None,
                    addr => Some(addr.to_string()),
                }
            }
            "metrics-addr" | "metrics_addr" => {
                self.metrics_addr = match value {
                    "" | "off" | "none" => None,
                    addr => Some(addr.to_string()),
                }
            }
            "trace" => {
                self.trace = match value {
                    "" | "off" | "none" => None,
                    path => Some(path.to_string()),
                }
            }
            _ => return Err(format!("unknown config key {key}")),
        }
        Ok(())
    }

    /// Resolve the shard count against the executor's worker count
    /// (`shards = 0` means "one shard per worker thread").
    pub fn resolved_shards(&self, n_threads: usize) -> usize {
        if self.shards == 0 {
            n_threads.max(1)
        } else {
            self.shards
        }
    }

    /// Human-readable cell label, e.g. `rbpf/inference/lazy-sro N=256 T=150`.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{} N={} T={}",
            self.model.name(),
            self.task.name(),
            self.mode.name(),
            self.n_particles,
            self.n_steps
        )
    }
}

/// Parse a TOML-subset config file: `key = value` lines, `#` comments,
/// `[section]` headers flattened as `section.key`.
pub fn parse_config_text(text: &str) -> Result<BTreeMap<String, String>, String> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(s) = line.strip_prefix('[') {
            let s = s
                .strip_suffix(']')
                .ok_or(format!("line {}: bad section header", lineno + 1))?;
            section = s.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or(format!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let v = v.trim().trim_matches('"').to_string();
        out.insert(key, v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_and_task_parse() {
        assert_eq!(Model::parse("RBPF"), Some(Model::Rbpf));
        assert_eq!(Model::parse("nope"), None);
        assert_eq!(Task::parse("sim"), Some(Task::Simulation));
        for m in Model::EVAL {
            assert_eq!(Model::parse(m.name()), Some(m));
        }
    }

    #[test]
    fn apply_overrides() {
        let mut c = RunConfig::default();
        c.apply("model", "crbd").unwrap();
        c.apply("particles", "64").unwrap();
        c.apply("mode", "eager").unwrap();
        c.apply("series", "true").unwrap();
        c.apply("shards", "4").unwrap();
        assert_eq!(c.model, Model::Crbd);
        assert_eq!(c.n_particles, 64);
        assert_eq!(c.mode, CopyMode::Eager);
        assert!(c.series);
        assert_eq!(c.shards, 4);
        assert_eq!(c.resolved_shards(8), 4);
        c.apply("shards", "0").unwrap();
        assert_eq!(c.resolved_shards(8), 8, "0 = match worker threads");
        c.apply("rebalance", "budget").unwrap();
        assert_eq!(c.rebalance, RebalancePolicy::Budget);
        c.apply("rebalance-threshold", "0.5").unwrap();
        assert!((c.rebalance_threshold - 0.5).abs() < 1e-12);
        assert!(c.steal, "stealing defaults on");
        c.apply("steal", "off").unwrap();
        assert!(!c.steal);
        c.apply("steal", "on").unwrap();
        assert!(c.steal);
        c.apply("steal-threshold", "16").unwrap();
        assert_eq!(c.steal_min, 16);
        c.apply("steal_min", "2").unwrap();
        assert_eq!(c.steal_min, 2);
        assert_eq!(c.allocator, AllocatorKind::Slab, "slab is the default");
        c.apply("allocator", "system").unwrap();
        assert_eq!(c.allocator, AllocatorKind::System);
        c.apply("alloc", "slab").unwrap();
        assert_eq!(c.allocator, AllocatorKind::Slab);
        assert_eq!(
            c.decommit_watermark,
            Some(crate::heap::DEFAULT_DECOMMIT_WATERMARK),
            "decommit defaults on at the keep-2 watermark"
        );
        c.apply("decommit-watermark", "off").unwrap();
        assert_eq!(c.decommit_watermark, None);
        c.apply("decommit_watermark", "5").unwrap();
        assert_eq!(c.decommit_watermark, Some(5));
        assert!(c.apply("decommit-watermark", "many").is_err());
        assert_eq!(c.evacuate_threshold, None, "evacuation defaults off");
        c.apply("evacuate-threshold", "0.5").unwrap();
        assert_eq!(c.evacuate_threshold, Some(0.5));
        c.apply("evacuate_threshold", "off").unwrap();
        assert_eq!(c.evacuate_threshold, None);
        assert!(c.apply("evacuate-threshold", "1.5").is_err());
        assert!(c.apply("evacuate-threshold", "-0.1").is_err());
        assert!(c.apply("evacuate-threshold", "sparse").is_err());
        assert!(c.batch, "batched numeric path defaults on");
        c.apply("batch", "off").unwrap();
        assert!(!c.batch);
        c.apply("batch", "on").unwrap();
        assert!(c.batch);
        assert!(c.apply("batch", "maybe").is_err());
        assert_eq!(c.trace, None, "tracing defaults off");
        c.apply("trace", "/tmp/spans.jsonl").unwrap();
        assert_eq!(c.trace.as_deref(), Some("/tmp/spans.jsonl"));
        c.apply("trace", "off").unwrap();
        assert_eq!(c.trace, None);
        assert_eq!(c.metrics_addr, None, "metrics endpoint defaults off");
        c.apply("metrics-addr", "127.0.0.1:9100").unwrap();
        assert_eq!(c.metrics_addr.as_deref(), Some("127.0.0.1:9100"));
        c.apply("metrics_addr", "none").unwrap();
        assert_eq!(c.metrics_addr, None);
        assert!(c.apply("allocator", "arena").is_err());
        assert!(c.apply("steal", "maybe").is_err());
        assert!(c.apply("rebalance", "bogus").is_err());
        assert!(c.apply("bogus", "1").is_err());
        assert!(c.apply("model", "bogus").is_err());
    }

    #[test]
    fn config_file_parsing() {
        let text = r#"
            # experiment config
            model = "vbd"
            particles = 128
            [bench]
            reps = 5
        "#;
        let map = parse_config_text(text).unwrap();
        assert_eq!(map["model"], "vbd");
        assert_eq!(map["particles"], "128");
        assert_eq!(map["bench.reps"], "5");
        assert!(parse_config_text("[oops").is_err());
        assert!(parse_config_text("novalue").is_err());
    }

    #[test]
    fn paper_scales_match_section4() {
        assert_eq!(Model::Rbpf.paper_scale(), (2048, 500, 500));
        assert_eq!(Model::Pcfg.paper_scale(), (16384, 3262, 2000));
        assert_eq!(Model::Vbd.paper_scale(), (4096, 182, 400));
        assert_eq!(Model::Mot.paper_scale(), (4096, 100, 300));
        assert_eq!(Model::Crbd.paper_scale(), (5000, 173, 173));
    }
}
