//! Per-phase structured tracing of the generation barrier.
//!
//! Every [`FilterSession::step`](crate::smc::FilterSession::step) is a
//! fixed pipeline of phases — propagate, weight, resample, and their
//! scheduling satellites — and diagnosing shard imbalance, steal
//! behaviour, or allocator churn requires knowing where inside that
//! pipeline the wall time went, per shard. This module provides:
//!
//! - [`Phase`]: the closed set of barrier phases, with stable names that
//!   are part of the telemetry contract (they label the
//!   `phase_wall_seconds` histogram and the trace JSONL records);
//! - [`PhaseWalls`]: a per-generation wall recorder. The shard-parallel
//!   phases are measured *inside* the worker tasks — each worker clocks
//!   its own slot, no locks, no atomics — and folded in by the
//!   coordinator at the barrier; coordinator phases are clocked in
//!   place. The engine always measures (two monotonic clock reads per
//!   phase — noise against a propagation phase) and only *recording*
//!   is conditional, so the measured path is identical with tracing on
//!   or off;
//! - [`TraceLog`]: the `--trace <path>` JSONL sink. One record per
//!   nonzero phase span:
//!   `{"session":"a","t":3,"phase":"propagate","shard":0,"dur_s":0.000512}`
//!   (`shard` is omitted on coordinator phases). Records append line-at-
//!   a-time so several sessions of one server may share a sink.
//!
//! **The tracing-never-computes contract:** nothing in this module
//! touches RNG streams, weights, heap state, or scheduling decisions —
//! it reads clocks and writes bytes. Filter outputs are bit-identical
//! with tracing on or off; `tests/differential.rs` pins that axis.

use std::io::Write as _;

/// One phase of the generation barrier. The set is closed and the names
/// are stable: scrapers key `phase_wall_seconds{phase=..}` on them and
/// `tools/trace_report` groups JSONL records by them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Particle propagation (per shard; a stealing worker's thieved
    /// batches count toward the *thief's* wall).
    Propagate,
    /// Weight normalization + ESS (fused reduction, plus the auxiliary
    /// method's lookahead weights when applicable).
    Weight,
    /// Resampling: offspring deep-copies, parent release, memo sweep.
    Resample,
    /// Rebalance planning (cost-model update + LPT offspring placement).
    RebalancePlan,
    /// Cross-shard lineage transplants executed at resampling.
    Transplant,
    /// Work-stealing donation: extracting pending runs into scratch
    /// heaps (per victim shard).
    StealDonate,
    /// Reclaiming scratch heaps at the barrier: transplant-back + counter
    /// absorption + scratch recycle (per home shard).
    ScratchReclaim,
    /// Opportunistic evacuation of sparse slab chunks
    /// (`--evacuate-threshold`).
    Evacuate,
    /// Slab decommit barrier (`--decommit-watermark`).
    Trim,
}

impl Phase {
    /// Every phase, in pipeline order.
    pub const ALL: [Phase; 9] = [
        Phase::Propagate,
        Phase::Weight,
        Phase::Resample,
        Phase::RebalancePlan,
        Phase::Transplant,
        Phase::StealDonate,
        Phase::ScratchReclaim,
        Phase::Evacuate,
        Phase::Trim,
    ];

    /// Stable label value (`phase_wall_seconds{phase="<name>"}` and the
    /// JSONL `"phase"` field).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Propagate => "propagate",
            Phase::Weight => "weight",
            Phase::Resample => "resample",
            Phase::RebalancePlan => "rebalance-plan",
            Phase::Transplant => "transplant",
            Phase::StealDonate => "steal-donate",
            Phase::ScratchReclaim => "scratch-reclaim",
            Phase::Evacuate => "evacuate",
            Phase::Trim => "trim",
        }
    }

    fn index(self) -> usize {
        self as usize
    }

    /// Slot of a shard-parallel phase in the per-shard wall array, if it
    /// is one (propagate / steal-donate / scratch-reclaim).
    fn shard_slot(self) -> Option<usize> {
        match self {
            Phase::Propagate => Some(0),
            Phase::StealDonate => Some(1),
            Phase::ScratchReclaim => Some(2),
            _ => None,
        }
    }
}

/// The shard-parallel phases, in [`PhaseWalls`] slot order.
const SHARD_PHASES: [Phase; 3] = [Phase::Propagate, Phase::StealDonate, Phase::ScratchReclaim];

/// Per-generation phase wall recorder. The coordinator owns it and
/// resets it each step; shard-parallel walls are measured inside the
/// worker tasks (each task clocks itself into its own struct field) and
/// folded in with [`add_shard`](PhaseWalls::add_shard) once the workers
/// have joined, so no synchronization is ever involved.
#[derive(Clone, Debug, Default)]
pub struct PhaseWalls {
    coord: [f64; Phase::ALL.len()],
    shard: Vec<[f64; SHARD_PHASES.len()]>,
}

impl PhaseWalls {
    /// A recorder for `k` shards.
    pub fn new(k: usize) -> Self {
        PhaseWalls {
            coord: [0.0; Phase::ALL.len()],
            shard: vec![[0.0; SHARD_PHASES.len()]; k],
        }
    }

    /// Zero every slot for the next generation, resizing to `k` shards.
    pub fn reset(&mut self, k: usize) {
        self.coord = [0.0; Phase::ALL.len()];
        self.shard.clear();
        self.shard.resize(k, [0.0; SHARD_PHASES.len()]);
    }

    /// Accumulate wall seconds into a coordinator-level phase.
    pub fn add(&mut self, phase: Phase, s: f64) {
        debug_assert!(phase.shard_slot().is_none(), "{} is per-shard", phase.name());
        self.coord[phase.index()] += s.max(0.0);
    }

    /// Accumulate wall seconds into a shard-parallel phase slot.
    pub fn add_shard(&mut self, phase: Phase, shard: usize, s: f64) {
        let slot = phase
            .shard_slot()
            .unwrap_or_else(|| panic!("{} is not a per-shard phase", phase.name()));
        self.shard[shard][slot] += s.max(0.0);
    }

    /// Total recorded wall for one phase (all shards for the parallel
    /// phases).
    pub fn total(&self, phase: Phase) -> f64 {
        match phase.shard_slot() {
            Some(slot) => self.shard.iter().map(|w| w[slot]).sum(),
            None => self.coord[phase.index()],
        }
    }

    /// Visit every nonzero span as `(phase, shard, dur_s)` — shard spans
    /// first (per shard, in phase-slot order), then coordinator spans in
    /// pipeline order. Zero-length spans (phases that did not run this
    /// generation) are elided. The same visit feeds the
    /// `phase_wall_seconds` histogram and the trace sink, so their
    /// totals agree by construction.
    pub fn for_each_span(&self, mut f: impl FnMut(Phase, Option<usize>, f64)) {
        for (s, walls) in self.shard.iter().enumerate() {
            for (slot, phase) in SHARD_PHASES.iter().enumerate() {
                if walls[slot] > 0.0 {
                    f(*phase, Some(s), walls[slot]);
                }
            }
        }
        for phase in Phase::ALL {
            if phase.shard_slot().is_none() && self.coord[phase.index()] > 0.0 {
                f(phase, None, self.coord[phase.index()]);
            }
        }
    }
}

/// A JSONL trace sink (`--trace <path>`, config key `trace`). Each
/// nonzero phase span of each stepped generation appends one record:
///
/// ```json
/// {"session":"run","t":3,"phase":"propagate","shard":0,"dur_s":0.000512}
/// ```
///
/// `shard` is omitted on coordinator-level spans. Lines are appended one
/// `write` at a time, so multiple sessions of one server can share a
/// sink file; `tools/trace_report` summarizes the result. Recording
/// never influences computation — see the module docs.
#[derive(Debug)]
pub struct TraceLog {
    session: String,
    file: std::fs::File,
    buf: String,
}

impl TraceLog {
    /// Open (append/create) the sink at `path`, labeling records with
    /// `session`.
    pub fn open(path: &str, session: &str) -> std::io::Result<TraceLog> {
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(TraceLog {
            session: session.to_string(),
            file,
            buf: String::new(),
        })
    }

    /// Relabel subsequent records (the serve engine names sessions after
    /// the trace sink is opened).
    pub fn set_session(&mut self, session: &str) {
        self.session = session.to_string();
    }

    /// Append one span record for generation `t`. Write errors are
    /// reported once to stderr and otherwise ignored — a full disk must
    /// not kill inference.
    pub fn record(&mut self, t: usize, phase: Phase, shard: Option<usize>, dur_s: f64) {
        use std::fmt::Write as _;
        self.buf.clear();
        let _ = write!(
            self.buf,
            "{{\"session\":\"{}\",\"t\":{},\"phase\":\"{}\"",
            json_escape(&self.session),
            t,
            phase.name()
        );
        if let Some(s) = shard {
            let _ = write!(self.buf, ",\"shard\":{s}");
        }
        let _ = writeln!(self.buf, ",\"dur_s\":{dur_s:.9}}}");
        if let Err(e) = self.file.write_all(self.buf.as_bytes()) {
            eprintln!("# trace write failed: {e} (tracing continues best-effort)");
        }
    }

    /// Record every nonzero span of one generation's [`PhaseWalls`].
    pub fn record_walls(&mut self, t: usize, walls: &PhaseWalls) {
        let mut spans: Vec<(Phase, Option<usize>, f64)> = Vec::new();
        walls.for_each_span(|p, s, d| spans.push((p, s, d)));
        for (p, s, d) in spans {
            self.record(t, p, s, d);
        }
    }
}

fn json_escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                use std::fmt::Write as _;
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            _ => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walls_accumulate_and_elide_zero_spans() {
        let mut w = PhaseWalls::new(2);
        w.add_shard(Phase::Propagate, 0, 0.5);
        w.add_shard(Phase::Propagate, 1, 0.25);
        w.add_shard(Phase::StealDonate, 1, 0.1);
        w.add(Phase::Weight, 0.05);
        w.add(Phase::Weight, 0.05);
        assert_eq!(w.total(Phase::Propagate), 0.75);
        assert_eq!(w.total(Phase::Weight), 0.1);
        assert_eq!(w.total(Phase::Trim), 0.0);
        let mut spans = Vec::new();
        w.for_each_span(|p, s, d| spans.push((p.name(), s, d)));
        assert_eq!(
            spans,
            vec![
                ("propagate", Some(0), 0.5),
                ("propagate", Some(1), 0.25),
                ("steal-donate", Some(1), 0.1),
                ("weight", None, 0.1),
            ]
        );
    }

    #[test]
    fn reset_zeroes_and_resizes() {
        let mut w = PhaseWalls::new(1);
        w.add_shard(Phase::Propagate, 0, 1.0);
        w.reset(3);
        assert_eq!(w.total(Phase::Propagate), 0.0);
        w.add_shard(Phase::ScratchReclaim, 2, 0.2);
        assert_eq!(w.total(Phase::ScratchReclaim), 0.2);
    }

    #[test]
    fn trace_log_writes_schema_lines() {
        let path = std::env::temp_dir().join(format!("lazycow-trace-{}.jsonl", std::process::id()));
        let path_s = path.to_str().unwrap();
        let _ = std::fs::remove_file(&path);
        {
            let mut log = TraceLog::open(path_s, "t\"x").unwrap();
            let mut w = PhaseWalls::new(1);
            w.add_shard(Phase::Propagate, 0, 0.001);
            w.add(Phase::Weight, 0.002);
            log.record_walls(7, &w);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            lines[0],
            "{\"session\":\"t\\\"x\",\"t\":7,\"phase\":\"propagate\",\"shard\":0,\"dur_s\":0.001000000}"
        );
        assert_eq!(
            lines[1],
            "{\"session\":\"t\\\"x\",\"t\":7,\"phase\":\"weight\",\"dur_s\":0.002000000}"
        );
        let _ = std::fs::remove_file(&path);
    }
}
