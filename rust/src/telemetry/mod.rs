//! Stable operational telemetry for long-running filter sessions.
//!
//! A fleet of inference servers is monitored by *name*: dashboards,
//! alerts, and scrapers key on metric identifiers, so those identifiers
//! are a public contract — they never change meaning or disappear inside
//! a major version, and additions are backwards-compatible. The string
//! constants in this module are that contract; everything else (the
//! in-process [`Registry`] representation, the render format's layout)
//! is an implementation detail.
//!
//! The registry is dependency-free and deterministic: metrics render in
//! registration order, counters are monotone `u64`s, gauges are plain
//! `f64`s, and histograms use a fixed logarithmic bucket ladder so two
//! runs of the same workload produce structurally identical output.
//! [`crate::smc::FilterSession`] feeds a registry from
//! [`HeapMetrics`](crate::heap::HeapMetrics) /
//! [`StepMetrics`](crate::smc::StepMetrics) deltas at each generation
//! barrier; nothing here ever influences what the engine computes.
//!
//! Heap-level counters (`transplants_total`, copy counters, residency
//! gauges) aggregate over the *shards backing the session*. Shards are
//! shared between sessions and their forks, and sessions on one
//! `ShardedHeap` execute serially (the exclusive `&mut [Heap]` borrow
//! enforces it), so each step snapshots the aggregate counters at entry
//! and attributes exactly the delta to its own barrier; forks attribute
//! their copy work the same way at fork time. Per-session attribution
//! is therefore **exact under arbitrary interleaving**: another
//! session's activity between this session's operations is never
//! charged here, and the per-session splits sum to the shard totals
//! (work outside any session operation — e.g. copies forced by ad-hoc
//! posterior reads between steps — lands in the shard aggregate only).

/// Generations stepped by this session (counter). One increment per
/// [`step`](crate::smc::FilterSession::step) barrier.
pub const SESSION_STEPS_TOTAL: &str = "session_steps_total";

/// Populations forked off this session lineage (counter). Forks inherit
/// the parent's registry, so a fork's own forks keep accumulating here.
pub const SESSION_FORK_TOTAL: &str = "session_fork_total";

/// Resampling barriers executed (counter). Bootstrap/auxiliary sessions
/// resample only below the ESS threshold; conditional (particle Gibbs)
/// sessions resample every generation.
pub const SESSION_RESAMPLES_TOTAL: &str = "session_resamples_total";

/// Propagation attempts (counter). Equals particles per generation except
/// under the alive method, where retries count too.
pub const SESSION_ATTEMPTS_TOTAL: &str = "session_attempts_total";

/// Rebalancer-executed cross-shard migrations (counter).
pub const SESSION_MIGRATIONS_TOTAL: &str = "session_migrations_total";

/// Particles donated through the work-stealing yard (counter).
pub const SESSION_STEALS_TOTAL: &str = "session_steals_total";

/// Per-generation wall seconds (histogram): time between consecutive
/// step barriers, including resampling and decommit work.
pub const STEP_WALL_SECONDS: &str = "step_wall_seconds";

/// Cross-shard lineage transplants executed on the session's shards
/// (counter; delta-fed from [`HeapMetrics`](crate::heap::HeapMetrics)).
pub const TRANSPLANTS_TOTAL: &str = "transplants_total";

/// O(1) lazy object copies on the session's shards (counter).
pub const LAZY_COPIES_TOTAL: &str = "lazy_copies_total";

/// Eager object copies on the session's shards (counter).
pub const EAGER_COPIES_TOTAL: &str = "eager_copies_total";

/// Slab bytes currently committed across the session's shards (gauge;
/// sampled after the decommit barrier, so it is the figure a
/// residency-bounded server is held to).
pub const HEAP_COMMITTED_BYTES: &str = "heap_committed_bytes";

/// Live heap payload bytes across the session's shards (gauge).
pub const HEAP_LIVE_BYTES: &str = "heap_live_bytes";

/// Live heap objects across the session's shards (gauge).
pub const HEAP_LIVE_OBJECTS: &str = "heap_live_objects";

/// Effective sample size after the latest generation (gauge).
pub const ESS_LAST: &str = "ess_last";

/// Upper bounds (seconds) of the fixed [`Histogram`] bucket ladder:
/// half-decade log steps from 10 µs to 100 s, plus the implicit +Inf
/// overflow bucket. Fixed so that renders are structurally identical
/// across runs and hosts.
pub const HISTOGRAM_BUCKETS_S: [f64; 15] = [
    1e-5, 3.16e-5, 1e-4, 3.16e-4, 1e-3, 3.16e-3, 1e-2, 3.16e-2, 1e-1, 3.16e-1, 1.0, 3.16, 10.0,
    31.6, 100.0,
];

/// A fixed-bucket histogram: cumulative bucket counts over
/// [`HISTOGRAM_BUCKETS_S`] plus count/sum/max, enough for latency
/// quantile estimates without storing samples.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Observations falling at or below each ladder bound (non-cumulative
    /// per-bucket counts; the +Inf overflow lives in `count` minus the
    /// bucket sum).
    buckets: [u64; HISTOGRAM_BUCKETS_S.len()],
    count: u64,
    sum: f64,
    max: f64,
}

impl Histogram {
    fn new() -> Self {
        Histogram {
            buckets: [0; HISTOGRAM_BUCKETS_S.len()],
            count: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    fn observe(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        if let Some(b) = HISTOGRAM_BUCKETS_S.iter().position(|&ub| v <= ub) {
            self.buckets[b] += 1;
        }
        self.count += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of recorded observations (seconds).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Largest recorded observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Per-bucket (non-cumulative) counts aligned with
    /// [`HISTOGRAM_BUCKETS_S`].
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }
}

/// A deterministic, dependency-free metric registry: named counters,
/// gauges, and histograms, rendered in registration order in a
/// Prometheus-style text format.
///
/// `Clone` is deliberate: a forked session clones its parent's registry
/// so the fork's telemetry continues the lineage's history.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: Vec<(&'static str, u64)>,
    gauges: Vec<(&'static str, f64)>,
    histograms: Vec<(&'static str, Histogram)>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Registry::default()
    }

    /// Add `by` to the named counter, registering it at zero on first use.
    pub fn inc(&mut self, name: &'static str, by: u64) {
        match self.counters.iter_mut().find(|(n, _)| *n == name) {
            Some((_, v)) => *v += by,
            None => self.counters.push((name, by)),
        }
    }

    /// Set the named gauge, registering it on first use.
    pub fn set_gauge(&mut self, name: &'static str, v: f64) {
        match self.gauges.iter_mut().find(|(n, _)| *n == name) {
            Some((_, g)) => *g = v,
            None => self.gauges.push((name, v)),
        }
    }

    /// Record one observation into the named histogram, registering it on
    /// first use.
    pub fn observe(&mut self, name: &'static str, v: f64) {
        if let Some((_, h)) = self.histograms.iter_mut().find(|(n, _)| *n == name) {
            h.observe(v);
            return;
        }
        let mut h = Histogram::new();
        h.observe(v);
        self.histograms.push((name, h));
    }

    /// Current value of a counter (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// Current value of a gauge, when set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(n, _)| *n == name).map(|(_, v)| *v)
    }

    /// The named histogram, when any observation has been recorded.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.iter().find(|(n, _)| *n == name).map(|(_, h)| h)
    }

    /// Render every metric in registration order, Prometheus text style:
    /// `name value` lines for counters and gauges, cumulative
    /// `name_bucket{le="..."}` lines plus `_sum`/`_count` for histograms.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, v) in &self.counters {
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in &self.gauges {
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, h) in &self.histograms {
            let mut cum = 0u64;
            for (ub, c) in HISTOGRAM_BUCKETS_S.iter().zip(&h.buckets) {
                cum += c;
                let _ = writeln!(out, "{name}_bucket{{le=\"{ub}\"}} {cum}");
            }
            let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count);
            let _ = writeln!(out, "{name}_sum {}", h.sum);
            let _ = writeln!(out, "{name}_count {}", h.count);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_default_to_zero() {
        let mut r = Registry::new();
        assert_eq!(r.counter(SESSION_STEPS_TOTAL), 0);
        r.inc(SESSION_STEPS_TOTAL, 1);
        r.inc(SESSION_STEPS_TOTAL, 2);
        assert_eq!(r.counter(SESSION_STEPS_TOTAL), 3);
    }

    #[test]
    fn gauges_overwrite() {
        let mut r = Registry::new();
        assert_eq!(r.gauge(HEAP_COMMITTED_BYTES), None);
        r.set_gauge(HEAP_COMMITTED_BYTES, 4096.0);
        r.set_gauge(HEAP_COMMITTED_BYTES, 1024.0);
        assert_eq!(r.gauge(HEAP_COMMITTED_BYTES), Some(1024.0));
    }

    #[test]
    fn histogram_buckets_and_moments() {
        let mut r = Registry::new();
        r.observe(STEP_WALL_SECONDS, 0.5e-3);
        r.observe(STEP_WALL_SECONDS, 2.0);
        r.observe(STEP_WALL_SECONDS, 1e9); // lands in +Inf overflow
        let h = r.histogram(STEP_WALL_SECONDS).unwrap();
        assert_eq!(h.count(), 3);
        assert!(h.max() >= 1e9);
        assert_eq!(h.buckets().iter().sum::<u64>(), 2, "overflow stays out of the ladder");
    }

    #[test]
    fn render_is_deterministic_and_complete() {
        let mut r = Registry::new();
        r.inc(SESSION_STEPS_TOTAL, 5);
        r.set_gauge(ESS_LAST, 31.5);
        r.observe(STEP_WALL_SECONDS, 0.01);
        let a = r.render();
        let b = r.render();
        assert_eq!(a, b);
        assert!(a.contains("session_steps_total 5"));
        assert!(a.contains("ess_last 31.5"));
        assert!(a.contains("step_wall_seconds_count 1"));
        assert!(a.contains("step_wall_seconds_bucket{le=\"+Inf\"} 1"));
    }

    #[test]
    fn clone_preserves_history() {
        let mut r = Registry::new();
        r.inc(SESSION_FORK_TOTAL, 1);
        let mut c = r.clone();
        c.inc(SESSION_FORK_TOTAL, 1);
        assert_eq!(r.counter(SESSION_FORK_TOTAL), 1);
        assert_eq!(c.counter(SESSION_FORK_TOTAL), 2);
    }
}
