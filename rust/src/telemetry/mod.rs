//! Stable operational telemetry for long-running filter sessions.
//!
//! A fleet of inference servers is monitored by *name*: dashboards,
//! alerts, and scrapers key on metric identifiers, so those identifiers
//! are a public contract — they never change meaning or disappear inside
//! a major version, and additions are backwards-compatible. The string
//! constants in this module are that contract; everything else (the
//! in-process [`Registry`] representation, the render format's layout)
//! is an implementation detail.
//!
//! The registry is dependency-free and deterministic: metrics render in
//! registration order, counters are monotone `u64`s, gauges are plain
//! `f64`s, and histograms use a fixed logarithmic bucket ladder so two
//! runs of the same workload produce structurally identical output.
//! Series may carry label sets (`{session="a",model="list"}`); the
//! [`Registry::render`] output is the Prometheus text exposition format
//! 0.0.4 (`# HELP`/`# TYPE` per family, escaped label values, cumulative
//! `_bucket{le=..}` triplets), so a real Prometheus can scrape it —
//! the serve subcommand exposes it at `GET /metrics` (`--metrics-addr`).
//! [`crate::smc::FilterSession`] feeds a registry from
//! [`HeapMetrics`](crate::heap::HeapMetrics) /
//! [`StepMetrics`](crate::smc::StepMetrics) deltas at each generation
//! barrier; nothing here ever influences what the engine computes.
//!
//! Heap-level counters (`transplants_total`, copy counters, residency
//! gauges) aggregate over the *shards backing the session*. Shards are
//! shared between sessions and their forks, and sessions on one
//! `ShardedHeap` execute serially (the exclusive `&mut [Heap]` borrow
//! enforces it), so each step snapshots the aggregate counters at entry
//! and attributes exactly the delta to its own barrier; forks attribute
//! their copy work the same way at fork time. Per-session attribution
//! is therefore **exact under arbitrary interleaving**: another
//! session's activity between this session's operations is never
//! charged here, and the per-session splits sum to the shard totals
//! (work outside any session operation — e.g. copies forced by ad-hoc
//! posterior reads between steps — lands in the shard aggregate only).

pub mod trace;

/// Generations stepped by this session (counter). One increment per
/// [`step`](crate::smc::FilterSession::step) barrier.
pub const SESSION_STEPS_TOTAL: &str = "session_steps_total";

/// Populations forked off this session lineage (counter). Forks inherit
/// the parent's registry, so a fork's own forks keep accumulating here.
pub const SESSION_FORK_TOTAL: &str = "session_fork_total";

/// Resampling barriers executed (counter). Bootstrap/auxiliary sessions
/// resample only below the ESS threshold; conditional (particle Gibbs)
/// sessions resample every generation.
pub const SESSION_RESAMPLES_TOTAL: &str = "session_resamples_total";

/// Propagation attempts (counter). Equals particles per generation except
/// under the alive method, where retries count too.
pub const SESSION_ATTEMPTS_TOTAL: &str = "session_attempts_total";

/// Rebalancer-executed cross-shard migrations (counter).
pub const SESSION_MIGRATIONS_TOTAL: &str = "session_migrations_total";

/// Particles donated through the work-stealing yard (counter).
pub const SESSION_STEALS_TOTAL: &str = "session_steals_total";

/// Per-generation wall seconds (histogram): time between consecutive
/// step barriers, including resampling and decommit work.
pub const STEP_WALL_SECONDS: &str = "step_wall_seconds";

/// Wall seconds per generation-barrier phase (histogram, labeled
/// `{phase="propagate"|"weight"|"resample"|...}` — the
/// [`trace::Phase`] names). Fed from the same clock reads the `--trace`
/// recorder flushes, so trace totals and histogram sums agree.
pub const PHASE_WALL_SECONDS: &str = "phase_wall_seconds";

/// Cross-shard lineage transplants executed on the session's shards
/// (counter; delta-fed from [`HeapMetrics`](crate::heap::HeapMetrics)).
pub const TRANSPLANTS_TOTAL: &str = "transplants_total";

/// O(1) lazy object copies on the session's shards (counter).
pub const LAZY_COPIES_TOTAL: &str = "lazy_copies_total";

/// Eager object copies on the session's shards (counter).
pub const EAGER_COPIES_TOTAL: &str = "eager_copies_total";

/// Slab bytes currently committed across the session's shards (gauge;
/// sampled after the decommit barrier, so it is the figure a
/// residency-bounded server is held to).
pub const HEAP_COMMITTED_BYTES: &str = "heap_committed_bytes";

/// High-water committed slab bytes across the session's shards (gauge).
pub const HEAP_COMMITTED_PEAK_BYTES: &str = "heap_committed_peak_bytes";

/// Slab fragmentation at the committed high-water mark (gauge in
/// `[0, 1]`): 1 − live-block bytes / committed-peak bytes.
pub const HEAP_FRAGMENTATION_RATIO: &str = "heap_fragmentation_ratio";

/// Empty slab chunks returned to the OS at decommit barriers (counter).
pub const HEAP_DECOMMITTED_CHUNKS_TOTAL: &str = "heap_decommitted_chunks_total";

/// Slab bytes returned to the OS at decommit barriers (counter).
pub const HEAP_DECOMMITTED_BYTES_TOTAL: &str = "heap_decommitted_bytes_total";

/// Payloads relocated by evacuation barriers (`--evacuate-threshold`)
/// across the session's shards (counter).
pub const HEAP_EVACUATIONS_TOTAL: &str = "heap_evacuations_total";

/// Large-object-space bytes resident (live + free-listed, headers
/// included) across the session's shards (gauge).
pub const HEAP_LOS_BYTES: &str = "heap_los_bytes";

/// Live heap payload bytes across the session's shards (gauge).
pub const HEAP_LIVE_BYTES: &str = "heap_live_bytes";

/// Live heap objects across the session's shards (gauge).
pub const HEAP_LIVE_OBJECTS: &str = "heap_live_objects";

/// Effective sample size after the latest generation (gauge).
pub const ESS_LAST: &str = "ess_last";

/// Live payload bytes resident on one shard (gauge, labeled
/// `{shard="k"}`; rendered by the serve metrics endpoint).
pub const SHARD_LIVE_BYTES: &str = "shard_live_bytes";

/// Live objects resident on one shard (gauge, labeled `{shard="k"}`).
pub const SHARD_LIVE_OBJECTS: &str = "shard_live_objects";

/// Slab bytes committed on one shard (gauge, labeled `{shard="k"}`).
pub const SHARD_COMMITTED_BYTES: &str = "shard_committed_bytes";

/// TCP connections accepted by the serve front-end (counter).
pub const SERVE_CONNECTIONS_TOTAL: &str = "serve_connections_total";

/// Protocol lines executed by the serve engine (counter, labeled
/// `{verb="obs"|"open"|...}`; blank/comment lines are not counted).
pub const SERVE_REQUESTS_TOTAL: &str = "serve_requests_total";

/// Error replies issued by the serve engine (counter, labeled
/// `{reason="unknown-verb"|"no-session"|...}`).
pub const SERVE_ERRORS_TOTAL: &str = "serve_errors_total";

/// Engine wall seconds per executed protocol line (histogram).
pub const SERVE_REQUEST_SECONDS: &str = "serve_request_seconds";

/// 1 while the server is draining (finishing sessions after
/// `finish-all`/SIGTERM/SIGINT), else 0 (gauge).
pub const SERVE_DRAINING: &str = "serve_draining";

/// One-line help text for every stable metric name (the `# HELP` line of
/// the exposition format). Unknown names get a generic line so renders
/// never fail.
pub fn help_for(name: &str) -> &'static str {
    match name {
        "session_steps_total" => "Generations stepped by this session.",
        "session_fork_total" => "Populations forked off this session lineage.",
        "session_resamples_total" => "Resampling barriers executed.",
        "session_attempts_total" => "Propagation attempts, alive-method retries included.",
        "session_migrations_total" => "Rebalancer-executed cross-shard migrations.",
        "session_steals_total" => "Particles donated through the work-stealing yard.",
        "step_wall_seconds" => "Wall seconds between consecutive step barriers.",
        "phase_wall_seconds" => "Wall seconds per generation-barrier phase.",
        "transplants_total" => "Cross-shard lineage transplants executed.",
        "lazy_copies_total" => "O(1) lazy object copies.",
        "eager_copies_total" => "Eager object copies.",
        "heap_committed_bytes" => "Slab bytes committed across the session's shards.",
        "heap_committed_peak_bytes" => "High-water committed slab bytes.",
        "heap_fragmentation_ratio" => "1 - live/committed-peak slab bytes.",
        "heap_decommitted_chunks_total" => "Empty slab chunks returned to the OS.",
        "heap_decommitted_bytes_total" => "Slab bytes returned to the OS.",
        "heap_evacuations_total" => "Payloads relocated by evacuation barriers.",
        "heap_los_bytes" => "Large-object-space bytes resident (live + free).",
        "heap_live_bytes" => "Live heap payload bytes.",
        "heap_live_objects" => "Live heap objects.",
        "ess_last" => "Effective sample size after the latest generation.",
        "shard_live_bytes" => "Live payload bytes resident on one shard.",
        "shard_live_objects" => "Live objects resident on one shard.",
        "shard_committed_bytes" => "Slab bytes committed on one shard.",
        "serve_connections_total" => "TCP connections accepted by the serve front-end.",
        "serve_requests_total" => "Protocol lines executed, by verb.",
        "serve_errors_total" => "Error replies issued, by reason.",
        "serve_request_seconds" => "Engine wall seconds per executed protocol line.",
        "serve_draining" => "1 while the server is draining, else 0.",
        _ => "lazycow metric.",
    }
}

/// Upper bounds (seconds) of the fixed [`Histogram`] bucket ladder:
/// half-decade log steps from 10 µs to 100 s, plus the implicit +Inf
/// overflow bucket. Fixed so that renders are structurally identical
/// across runs and hosts.
pub const HISTOGRAM_BUCKETS_S: [f64; 15] = [
    1e-5, 3.16e-5, 1e-4, 3.16e-4, 1e-3, 3.16e-3, 1e-2, 3.16e-2, 1e-1, 3.16e-1, 1.0, 3.16, 10.0,
    31.6, 100.0,
];

/// A fixed-bucket histogram: cumulative bucket counts over
/// [`HISTOGRAM_BUCKETS_S`] plus count/sum/max, enough for latency
/// quantile estimates without storing samples.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Observations falling at or below each ladder bound (non-cumulative
    /// per-bucket counts; the +Inf overflow lives in `count` minus the
    /// bucket sum).
    buckets: [u64; HISTOGRAM_BUCKETS_S.len()],
    count: u64,
    sum: f64,
    max: f64,
}

impl Histogram {
    fn new() -> Self {
        Histogram {
            buckets: [0; HISTOGRAM_BUCKETS_S.len()],
            count: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    fn observe(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        if let Some(b) = HISTOGRAM_BUCKETS_S.iter().position(|&ub| v <= ub) {
            self.buckets[b] += 1;
        }
        self.count += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    /// Fold another histogram into this one: buckets and counts add, the
    /// max carries. Counter-monotone — merging never decreases anything.
    fn merge(&mut self, other: &Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of recorded observations (seconds).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Largest recorded observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Per-bucket (non-cumulative) counts aligned with
    /// [`HISTOGRAM_BUCKETS_S`].
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }
}

/// One metric series: a stable family name plus an optional label set.
/// The empty label set is the plain `name value` series.
#[derive(Clone, Debug)]
struct Series<T> {
    name: &'static str,
    labels: Vec<(&'static str, String)>,
    value: T,
}

fn labels_eq(a: &[(&'static str, String)], b: &[(&'static str, &str)]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|((ak, av), (bk, bv))| ak == bk && av == bv)
}

fn own_labels(labels: &[(&'static str, &str)]) -> Vec<(&'static str, String)> {
    labels.iter().map(|(k, v)| (*k, (*v).to_string())).collect()
}

/// Escape a label value per the Prometheus text exposition format:
/// backslash, double quote, and newline are backslash-escaped.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render a label set as `{k="v",...}` (empty string for no labels);
/// `extra` appends one final pre-escaped pair (the histogram `le`).
fn render_labels(labels: &[(&'static str, String)], extra: Option<(&str, &str)>) -> String {
    use std::fmt::Write as _;
    if labels.is_empty() && extra.is_none() {
        return String::new();
    }
    let mut out = String::from("{");
    let mut first = true;
    for (k, v) in labels {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(out, "{k}=\"{}\"", escape_label(v));
    }
    if let Some((k, v)) = extra {
        if !first {
            out.push(',');
        }
        let _ = write!(out, "{k}=\"{v}\"");
    }
    out.push('}');
    out
}

/// A deterministic, dependency-free metric registry: named counters,
/// gauges, and histograms — optionally labeled — rendered in
/// registration order in the Prometheus text exposition format
/// (`# HELP`/`# TYPE` per family, label escaping, cumulative
/// `_bucket`/`_sum`/`_count` triplets).
///
/// `Clone` is deliberate: a forked session clones its parent's registry
/// so the fork's telemetry continues the lineage's history.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: Vec<Series<u64>>,
    gauges: Vec<Series<f64>>,
    histograms: Vec<Series<Histogram>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Registry::default()
    }

    /// Add `by` to the named counter, registering it at zero on first use.
    pub fn inc(&mut self, name: &'static str, by: u64) {
        self.inc_with(name, &[], by);
    }

    /// Add `by` to the named counter series with this label set.
    pub fn inc_with(&mut self, name: &'static str, labels: &[(&'static str, &str)], by: u64) {
        match self
            .counters
            .iter_mut()
            .find(|s| s.name == name && labels_eq(&s.labels, labels))
        {
            Some(s) => s.value += by,
            None => self.counters.push(Series {
                name,
                labels: own_labels(labels),
                value: by,
            }),
        }
    }

    /// Set the named gauge, registering it on first use.
    pub fn set_gauge(&mut self, name: &'static str, v: f64) {
        self.set_gauge_with(name, &[], v);
    }

    /// Set the named gauge series with this label set.
    pub fn set_gauge_with(&mut self, name: &'static str, labels: &[(&'static str, &str)], v: f64) {
        match self
            .gauges
            .iter_mut()
            .find(|s| s.name == name && labels_eq(&s.labels, labels))
        {
            Some(s) => s.value = v,
            None => self.gauges.push(Series {
                name,
                labels: own_labels(labels),
                value: v,
            }),
        }
    }

    /// Record one observation into the named histogram, registering it on
    /// first use.
    pub fn observe(&mut self, name: &'static str, v: f64) {
        self.observe_with(name, &[], v);
    }

    /// Record one observation into the named histogram series with this
    /// label set.
    pub fn observe_with(&mut self, name: &'static str, labels: &[(&'static str, &str)], v: f64) {
        if let Some(s) = self
            .histograms
            .iter_mut()
            .find(|s| s.name == name && labels_eq(&s.labels, labels))
        {
            s.value.observe(v);
            return;
        }
        let mut h = Histogram::new();
        h.observe(v);
        self.histograms.push(Series {
            name,
            labels: own_labels(labels),
            value: h,
        });
    }

    /// Current value of an unlabeled counter (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counter_with(name, &[])
    }

    /// Current value of a labeled counter series (0 when absent).
    pub fn counter_with(&self, name: &str, labels: &[(&'static str, &str)]) -> u64 {
        self.counters
            .iter()
            .find(|s| s.name == name && labels_eq(&s.labels, labels))
            .map(|s| s.value)
            .unwrap_or(0)
    }

    /// Current value of an unlabeled gauge, when set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauge_with(name, &[])
    }

    /// Current value of a labeled gauge series, when set.
    pub fn gauge_with(&self, name: &str, labels: &[(&'static str, &str)]) -> Option<f64> {
        self.gauges
            .iter()
            .find(|s| s.name == name && labels_eq(&s.labels, labels))
            .map(|s| s.value)
    }

    /// The named unlabeled histogram, when any observation exists.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histogram_with(name, &[])
    }

    /// The named labeled histogram series, when any observation exists.
    pub fn histogram_with(
        &self,
        name: &str,
        labels: &[(&'static str, &str)],
    ) -> Option<&Histogram> {
        self.histograms
            .iter()
            .find(|s| s.name == name && labels_eq(&s.labels, labels))
            .map(|s| &s.value)
    }

    /// Fold `other` into this registry series-by-series: counters add,
    /// gauges take `other`'s value, histograms merge bucket-wise. Merging
    /// never decreases a counter (monotonicity is pinned by a test).
    pub fn merge(&mut self, other: &Registry) {
        self.merge_labeled(other, &[]);
    }

    /// Fold `other` into this registry with `extra` labels appended to
    /// every incoming series — how the serve scrape endpoint aggregates
    /// per-session registries under `{session=..,model=..}`.
    pub fn merge_labeled(&mut self, other: &Registry, extra: &[(&'static str, &str)]) {
        let compose = |labels: &[(&'static str, String)]| -> Vec<(&'static str, &str)> {
            let mut all: Vec<(&'static str, &str)> =
                labels.iter().map(|(k, v)| (*k, v.as_str())).collect();
            all.extend(extra.iter().copied());
            all
        };
        for s in &other.counters {
            self.inc_with(s.name, &compose(&s.labels), s.value);
        }
        for s in &other.gauges {
            self.set_gauge_with(s.name, &compose(&s.labels), s.value);
        }
        for s in &other.histograms {
            let labels = compose(&s.labels);
            if let Some(t) = self
                .histograms
                .iter_mut()
                .find(|t| t.name == s.name && labels_eq(&t.labels, &labels))
            {
                t.value.merge(&s.value);
            } else {
                self.histograms.push(Series {
                    name: s.name,
                    labels: own_labels(&labels),
                    value: s.value.clone(),
                });
            }
        }
    }

    /// Render every metric in the Prometheus text exposition format
    /// (0.0.4): one `# HELP`/`# TYPE` header per family in
    /// first-registration order, then its series in registration order —
    /// plain `name value` lines for unlabeled counters and gauges,
    /// `name{k="v"} value` for labeled ones, cumulative
    /// `name_bucket{..,le="..."}` plus `_sum`/`_count` per histogram
    /// series. Byte-deterministic for a given registry state.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut seen: Vec<&'static str> = Vec::new();
        let mut header = |out: &mut String, name: &'static str, kind: &str| {
            if !seen.contains(&name) {
                seen.push(name);
                let _ = writeln!(out, "# HELP {name} {}", help_for(name));
                let _ = writeln!(out, "# TYPE {name} {kind}");
            }
        };
        for name in family_order(&self.counters) {
            header(&mut out, name, "counter");
            for s in self.counters.iter().filter(|s| s.name == name) {
                let _ = writeln!(out, "{name}{} {}", render_labels(&s.labels, None), s.value);
            }
        }
        for name in family_order(&self.gauges) {
            header(&mut out, name, "gauge");
            for s in self.gauges.iter().filter(|s| s.name == name) {
                let _ = writeln!(out, "{name}{} {}", render_labels(&s.labels, None), s.value);
            }
        }
        for name in family_order(&self.histograms) {
            header(&mut out, name, "histogram");
            for s in self.histograms.iter().filter(|s| s.name == name) {
                let h = &s.value;
                let mut cum = 0u64;
                for (ub, c) in HISTOGRAM_BUCKETS_S.iter().zip(&h.buckets) {
                    cum += c;
                    let le = format!("{ub}");
                    let _ = writeln!(
                        out,
                        "{name}_bucket{} {cum}",
                        render_labels(&s.labels, Some(("le", &le)))
                    );
                }
                let _ = writeln!(
                    out,
                    "{name}_bucket{} {}",
                    render_labels(&s.labels, Some(("le", "+Inf"))),
                    h.count
                );
                let _ = writeln!(out, "{name}_sum{} {}", render_labels(&s.labels, None), h.sum);
                let _ = writeln!(
                    out,
                    "{name}_count{} {}",
                    render_labels(&s.labels, None),
                    h.count
                );
            }
        }
        out
    }
}

/// Unique family names in first-registration order.
fn family_order<T>(series: &[Series<T>]) -> Vec<&'static str> {
    let mut names: Vec<&'static str> = Vec::new();
    for s in series {
        if !names.contains(&s.name) {
            names.push(s.name);
        }
    }
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_default_to_zero() {
        let mut r = Registry::new();
        assert_eq!(r.counter(SESSION_STEPS_TOTAL), 0);
        r.inc(SESSION_STEPS_TOTAL, 1);
        r.inc(SESSION_STEPS_TOTAL, 2);
        assert_eq!(r.counter(SESSION_STEPS_TOTAL), 3);
    }

    #[test]
    fn gauges_overwrite() {
        let mut r = Registry::new();
        assert_eq!(r.gauge(HEAP_COMMITTED_BYTES), None);
        r.set_gauge(HEAP_COMMITTED_BYTES, 4096.0);
        r.set_gauge(HEAP_COMMITTED_BYTES, 1024.0);
        assert_eq!(r.gauge(HEAP_COMMITTED_BYTES), Some(1024.0));
    }

    #[test]
    fn histogram_buckets_and_moments() {
        let mut r = Registry::new();
        r.observe(STEP_WALL_SECONDS, 0.5e-3);
        r.observe(STEP_WALL_SECONDS, 2.0);
        r.observe(STEP_WALL_SECONDS, 1e9); // lands in +Inf overflow
        let h = r.histogram(STEP_WALL_SECONDS).unwrap();
        assert_eq!(h.count(), 3);
        assert!(h.max() >= 1e9);
        assert_eq!(h.buckets().iter().sum::<u64>(), 2, "overflow stays out of the ladder");
    }

    #[test]
    fn histogram_bucket_edges_land_in_their_le_bucket() {
        // An observation exactly on a ladder bound belongs to that
        // bucket (`le` is inclusive), and the cumulative render counts it
        // there and in every wider bucket.
        let mut r = Registry::new();
        r.observe(STEP_WALL_SECONDS, 1e-3); // exactly bucket index 4
        r.observe(STEP_WALL_SECONDS, 1e-3 + 1e-9); // just over: index 5
        let h = r.histogram(STEP_WALL_SECONDS).unwrap();
        assert_eq!(h.buckets()[4], 1, "edge observation is inclusive");
        assert_eq!(h.buckets()[5], 1);
        let text = r.render();
        assert!(text.contains("step_wall_seconds_bucket{le=\"0.001\"} 1"));
        assert!(text.contains("step_wall_seconds_bucket{le=\"0.00316\"} 2"));
        assert!(text.contains("step_wall_seconds_bucket{le=\"+Inf\"} 2"));
    }

    #[test]
    fn render_is_deterministic_and_complete() {
        let mut r = Registry::new();
        r.inc(SESSION_STEPS_TOTAL, 5);
        r.set_gauge(ESS_LAST, 31.5);
        r.observe(STEP_WALL_SECONDS, 0.01);
        let a = r.render();
        let b = r.render();
        assert_eq!(a, b);
        assert!(a.contains("session_steps_total 5"));
        assert!(a.contains("ess_last 31.5"));
        assert!(a.contains("step_wall_seconds_count 1"));
        assert!(a.contains("step_wall_seconds_bucket{le=\"+Inf\"} 1"));
    }

    #[test]
    fn render_emits_exposition_headers_once_per_family() {
        let mut r = Registry::new();
        r.inc_with(SERVE_REQUESTS_TOTAL, &[("verb", "obs")], 2);
        r.inc_with(SERVE_REQUESTS_TOTAL, &[("verb", "open")], 1);
        r.set_gauge(SERVE_DRAINING, 0.0);
        r.observe(SERVE_REQUEST_SECONDS, 0.002);
        let text = r.render();
        assert_eq!(text.matches("# HELP serve_requests_total").count(), 1);
        assert_eq!(text.matches("# TYPE serve_requests_total counter").count(), 1);
        assert!(text.contains("serve_requests_total{verb=\"obs\"} 2"));
        assert!(text.contains("serve_requests_total{verb=\"open\"} 1"));
        assert!(text.contains("# TYPE serve_draining gauge"));
        assert!(text.contains("# TYPE serve_request_seconds histogram"));
        // HELP precedes TYPE precedes the series.
        let help = text.find("# HELP serve_requests_total").unwrap();
        let ty = text.find("# TYPE serve_requests_total").unwrap();
        let series = text.find("serve_requests_total{").unwrap();
        assert!(help < ty && ty < series);
    }

    #[test]
    fn labeled_renders_are_byte_identical_across_runs() {
        let build = || {
            let mut r = Registry::new();
            r.inc_with(SESSION_STEPS_TOTAL, &[("session", "a"), ("model", "list")], 3);
            r.inc_with(SESSION_STEPS_TOTAL, &[("session", "b"), ("model", "rbpf")], 7);
            r.set_gauge_with(SHARD_LIVE_BYTES, &[("shard", "0")], 128.0);
            r.set_gauge_with(SHARD_LIVE_BYTES, &[("shard", "1")], 256.0);
            r.observe_with(PHASE_WALL_SECONDS, &[("phase", "propagate")], 0.02);
            r.render()
        };
        let a = build();
        let b = build();
        assert_eq!(a, b, "same registrations must render byte-identically");
        assert!(a.contains("session_steps_total{session=\"a\",model=\"list\"} 3"));
        assert!(a.contains("shard_live_bytes{shard=\"1\"} 256"));
        assert!(a.contains("phase_wall_seconds_bucket{phase=\"propagate\",le=\"+Inf\"} 1"));
        assert!(a.contains("phase_wall_seconds_sum{phase=\"propagate\"} 0.02"));
    }

    #[test]
    fn label_values_are_escaped() {
        let mut r = Registry::new();
        r.inc_with(SERVE_ERRORS_TOTAL, &[("reason", "a\"b\\c\nd")], 1);
        let text = r.render();
        assert!(text.contains("serve_errors_total{reason=\"a\\\"b\\\\c\\nd\"} 1"));
    }

    #[test]
    fn merge_is_counter_monotone_and_histogram_additive() {
        let mut a = Registry::new();
        a.inc(SESSION_STEPS_TOTAL, 5);
        a.observe(STEP_WALL_SECONDS, 0.01);
        a.set_gauge(ESS_LAST, 10.0);
        let mut b = Registry::new();
        b.inc(SESSION_STEPS_TOTAL, 2);
        b.inc(SESSION_FORK_TOTAL, 1);
        b.observe(STEP_WALL_SECONDS, 1.0);
        b.set_gauge(ESS_LAST, 20.0);
        let before = a.counter(SESSION_STEPS_TOTAL);
        a.merge(&b);
        assert!(a.counter(SESSION_STEPS_TOTAL) >= before, "merge never decreases a counter");
        assert_eq!(a.counter(SESSION_STEPS_TOTAL), 7);
        assert_eq!(a.counter(SESSION_FORK_TOTAL), 1);
        assert_eq!(a.gauge(ESS_LAST), Some(20.0), "gauges take the incoming value");
        let h = a.histogram(STEP_WALL_SECONDS).unwrap();
        assert_eq!(h.count(), 2);
        assert!((h.sum() - 1.01).abs() < 1e-12);
        // Merging under extra labels lands in a distinct labeled series.
        let mut scrape = Registry::new();
        scrape.merge_labeled(&b, &[("session", "s1"), ("model", "list")]);
        assert_eq!(
            scrape.counter_with(SESSION_STEPS_TOTAL, &[("session", "s1"), ("model", "list")]),
            2
        );
        assert_eq!(scrape.counter(SESSION_STEPS_TOTAL), 0);
    }

    #[test]
    fn clone_preserves_history() {
        let mut r = Registry::new();
        r.inc(SESSION_FORK_TOTAL, 1);
        let mut c = r.clone();
        c.inc(SESSION_FORK_TOTAL, 1);
        assert_eq!(r.counter(SESSION_FORK_TOTAL), 1);
        assert_eq!(c.counter(SESSION_FORK_TOTAL), 2);
    }
}
