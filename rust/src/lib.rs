//! lazycow — lazy object copy-on-write platform for population-based
//! probabilistic programming.
//!
//! A from-scratch reproduction of Murray (2020), "Lazy object copy as a
//! platform for population-based probabilistic programming", as a
//! three-layer Rust + JAX + Pallas stack. See DESIGN.md for the system
//! inventory and EXPERIMENTS.md for the paper-vs-measured record.

pub mod bench;
pub mod cli;
pub mod config;
pub mod graph;
pub mod heap;
pub mod linalg;
pub mod models;
pub mod pool;
pub mod ppl;
pub mod prop;
pub mod rng;
pub mod smc;
pub mod runtime;
pub mod stats;
