//! lazycow — lazy object copy-on-write platform for population-based
//! probabilistic programming.
//!
//! A from-scratch reproduction of Murray (2020), "Lazy object copy as a
//! platform for population-based probabilistic programming"
//! (arXiv:2001.05293), grown into a sharded, work-stealing,
//! slab-allocated SMC platform. See `DESIGN.md` (this directory) for the
//! full system inventory; this page is the architecture tour.
//!
//! # Architecture tour: graph → heap → alloc → smc → models
//!
//! - [`graph`] holds the paper's §2 *formal semantics*: the labeled
//!   multigraph model of lazy copies, an executable small-step oracle,
//!   and fuzz tests that pin the production heap against it. Read this
//!   first to understand *what* the platform promises.
//! - [`heap`] is the production platform: objects in generation-tagged
//!   slots, lazy pointers ([`heap::Lazy`]) as (object, label) id pairs,
//!   and the paper's operations — `Pull`, `Get`, `Copy`, `Freeze`,
//!   `Finish`, and the O(1) [`deep_copy`](heap::Heap::deep_copy) — plus
//!   the [`ShardedHeap`](heap::ShardedHeap): K independent heaps with
//!   cross-shard lineage transplant for lock-free parallel propagation.
//! - [`heap::alloc`] owns every byte the heap allocates: a size-class
//!   slab allocator for payloads *and* (via a raw-bytes path) for memo
//!   tables and label storage, with free-list reuse tuned to resampling
//!   churn and a watermark decommit pass
//!   ([`Heap::trim`](heap::Heap::trim)) bounding long-run residency.
//! - [`smc`] is the population coordinator: bootstrap / auxiliary /
//!   alive particle filters and particle Gibbs over the (sharded) heap,
//!   with cost-driven rebalancing ([`smc::rebalance`]),
//!   intra-generation work stealing, and a batched SoA numeric path
//!   ([`smc::batch`] plus the [`smc::SmcModel::step_batched`] hook,
//!   gated by `--batch`). Outputs are bit-identical across every
//!   scheduling, storage, and numeric-path configuration. The engine is
//!   a resumable state machine — [`smc::FilterSession`] steps one
//!   generation at a time, forks whole populations in O(particles) via
//!   the lazy copy, and feeds [`telemetry`]; the `run_*` entry points
//!   are thin drivers over it.
//! - [`models`] are the paper's §4 evaluation problems (RBPF, PCFG, VBD,
//!   MOT, CRBD, plus the linked-list microbenchmark), each implementing
//!   [`smc::SmcModel`] — including the streaming-ingest hook
//!   ([`smc::SmcModel::stream_observation`]) that makes every model
//!   servable.
//! - [`serve`] is the serving surface (§5): many named sessions over
//!   one shared sharded heap, driven by a line protocol over stdin or
//!   TCP ([`serve::ServeEngine`] / [`serve::serve_tcp`]), with
//!   structured `err` replies, a graceful drain, and a Prometheus
//!   `/metrics` scrape endpoint ([`serve::MetricsHub`],
//!   `--metrics-addr`) — per session, replies stay bit-identical to the
//!   batch run however sessions interleave.
//!
//! Supporting substrate: [`pool`] (scoped static-scheduling executors
//! and the work-stealing yard), [`rng`] (counter-keyed PCG streams —
//! the determinism backbone), [`stats`] / [`linalg`] (weight math),
//! [`ppl`] (delayed-sampling building blocks), [`prop`]
//! (property-test harness), [`runtime`] (optional PJRT-compiled
//! kernels), [`telemetry`] (stable-name labeled metrics rendered in the
//! Prometheus exposition format, plus the [`telemetry::trace`] per-phase
//! span recorder behind `--trace` — the observability contract of the
//! `serve` subcommand), [`config`] / [`cli`] / [`bench`] (the launcher).
//!
//! # A taste of the API
//!
//! ```
//! use lazycow::heap::{CopyMode, Heap, Lazy};
//! use lazycow::lazy_fields;
//!
//! #[derive(Clone)]
//! struct Node {
//!     value: i64,
//!     next: Lazy<Node>,
//! }
//! lazy_fields!(Node: next);
//!
//! let mut heap = Heap::new(CopyMode::LazySro);
//! let a = heap.alloc(Node { value: 1, next: Lazy::NULL });
//! // O(1) deep copy; nothing is copied until written through.
//! let mut b = heap.deep_copy(&a);
//! heap.mutate_root(&mut b, |n| n.value = 2);
//! assert_eq!(heap.read(&mut b.clone(), |n| n.value), 2);
//! assert_eq!(heap.read(&mut a.clone(), |n| n.value), 1, "original intact");
//! heap.release(a);
//! heap.release(b);
//! ```

#![warn(missing_docs)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod graph;
pub mod heap;
pub mod linalg;
pub mod models;
pub mod pool;
pub mod ppl;
pub mod prop;
pub mod rng;
pub mod serve;
pub mod smc;
pub mod runtime;
pub mod stats;
pub mod telemetry;
