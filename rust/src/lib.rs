//! lazycow — lazy object copy-on-write platform for population-based
//! probabilistic programming.
//!
//! A from-scratch reproduction of Murray (2020), "Lazy object copy as a
//! platform for population-based probabilistic programming", as a
//! three-layer Rust + JAX + Pallas stack, extended with a sharded heap
//! ([`heap::ShardedHeap`]) that runs particle propagation shard-parallel
//! with cross-shard lineage transplant at resampling. See `DESIGN.md`
//! (this directory) for the system inventory, the shard/transplant
//! architecture, and the threading model.

pub mod bench;
pub mod cli;
pub mod config;
pub mod graph;
pub mod heap;
pub mod linalg;
pub mod models;
pub mod pool;
pub mod ppl;
pub mod prop;
pub mod rng;
pub mod smc;
pub mod runtime;
pub mod stats;
