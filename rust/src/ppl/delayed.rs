//! Scalar conjugate-pair delayed-sampling nodes.
//!
//! Each node is either `Marginalized` (posterior hyper-parameters) or
//! `Realized` (a concrete value). `observe_*` updates the hyper-parameters
//! and returns the marginal log-likelihood (the particle weight
//! contribution); `realize` draws a value and pins it.

use crate::rng::{
    betabin_lpmf, gamma_lpdf, negbin_lpmf, normal_lpdf, Pcg64,
};

/// 1-D Gaussian with unknown mean (known observation variance):
/// μ ~ N(m, v); y | μ ~ N(μ, s²).
#[derive(Clone, Debug, PartialEq)]
pub enum GaussianNode {
    /// Posterior N(mean, var) carried analytically.
    Marginalized {
        /// Posterior mean.
        mean: f64,
        /// Posterior variance.
        var: f64,
    },
    /// Collapsed to a sampled value.
    Realized(f64),
}

impl GaussianNode {
    /// A marginalized node with prior N(mean, var).
    pub fn new(mean: f64, var: f64) -> Self {
        GaussianNode::Marginalized { mean, var }
    }

    /// Observe y ~ N(μ, obs_var): conjugate update; returns the marginal
    /// log-likelihood log N(y; m, v + obs_var).
    pub fn observe(&mut self, y: f64, obs_var: f64) -> f64 {
        match self {
            GaussianNode::Marginalized { mean, var } => {
                let s = *var + obs_var;
                let ll = normal_lpdf(y, *mean, s.sqrt());
                let k = *var / s;
                *mean += k * (y - *mean);
                *var *= 1.0 - k;
                ll
            }
            GaussianNode::Realized(mu) => normal_lpdf(y, *mu, obs_var.sqrt()),
        }
    }

    /// Random-walk prediction: μ' = a·μ + b + N(0, q).
    pub fn predict(&mut self, a: f64, b: f64, q: f64) {
        if let GaussianNode::Marginalized { mean, var } = self {
            *mean = a * *mean + b;
            *var = a * a * *var + q;
        }
    }

    /// Draw a value and pin it.
    pub fn realize(&mut self, rng: &mut Pcg64) -> f64 {
        match self {
            GaussianNode::Marginalized { mean, var } => {
                let x = rng.gaussian(*mean, var.sqrt());
                *self = GaussianNode::Realized(x);
                x
            }
            GaussianNode::Realized(x) => *x,
        }
    }

    /// Posterior mean (or the realized value).
    pub fn mean(&self) -> f64 {
        match self {
            GaussianNode::Marginalized { mean, .. } => *mean,
            GaussianNode::Realized(x) => *x,
        }
    }
}

/// Gamma–Poisson: λ ~ Gamma(shape k, rate β); y | λ ~ Poisson(c·λ).
#[derive(Clone, Debug, PartialEq)]
pub enum GammaPoissonNode {
    /// Posterior Gamma(shape, rate) carried analytically.
    Marginalized {
        /// Posterior shape k.
        shape: f64,
        /// Posterior rate β.
        rate: f64,
    },
    /// Collapsed to a sampled rate.
    Realized(f64),
}

impl GammaPoissonNode {
    /// A marginalized node with prior Gamma(shape, rate).
    pub fn new(shape: f64, rate: f64) -> Self {
        GammaPoissonNode::Marginalized { shape, rate }
    }

    /// Observe y ~ Poisson(c·λ): returns the negative-binomial marginal
    /// log-pmf; posterior Gamma(k + y, β + c).
    pub fn observe(&mut self, y: u64, c: f64) -> f64 {
        match self {
            GammaPoissonNode::Marginalized { shape, rate } => {
                let p = *rate / (*rate + c);
                let ll = negbin_lpmf(y, *shape, p);
                *shape += y as f64;
                *rate += c;
                ll
            }
            GammaPoissonNode::Realized(lam) => crate::rng::poisson_lpmf(y, c * *lam),
        }
    }

    /// Draw a rate and pin it.
    pub fn realize(&mut self, rng: &mut Pcg64) -> f64 {
        match self {
            GammaPoissonNode::Marginalized { shape, rate } => {
                let x = rng.gamma(*shape, 1.0 / *rate);
                *self = GammaPoissonNode::Realized(x);
                x
            }
            GammaPoissonNode::Realized(x) => *x,
        }
    }

    /// Posterior mean k/β (or the realized value).
    pub fn mean(&self) -> f64 {
        match self {
            GammaPoissonNode::Marginalized { shape, rate } => shape / rate,
            GammaPoissonNode::Realized(x) => *x,
        }
    }

    /// Log-density of a concrete rate value under the current marginal
    /// (used by particle Gibbs acceptance diagnostics).
    pub fn lpdf(&self, x: f64) -> f64 {
        match self {
            GammaPoissonNode::Marginalized { shape, rate } => gamma_lpdf(x, *shape, 1.0 / *rate),
            GammaPoissonNode::Realized(v) => {
                if (x - v).abs() < 1e-12 {
                    0.0
                } else {
                    f64::NEG_INFINITY
                }
            }
        }
    }
}

/// Beta–Binomial: p ~ Beta(a, b); y | p ~ Binomial(n, p).
#[derive(Clone, Debug, PartialEq)]
pub enum BetaBinomialNode {
    /// Posterior Beta(a, b) carried analytically.
    Marginalized {
        /// Posterior α.
        a: f64,
        /// Posterior β.
        b: f64,
    },
    /// Collapsed to a sampled probability.
    Realized(f64),
}

impl BetaBinomialNode {
    /// A marginalized node with prior Beta(a, b).
    pub fn new(a: f64, b: f64) -> Self {
        BetaBinomialNode::Marginalized { a, b }
    }

    /// Observe y successes of n trials: beta-binomial marginal; posterior
    /// Beta(a + y, b + n − y).
    pub fn observe(&mut self, y: u64, n: u64) -> f64 {
        match self {
            BetaBinomialNode::Marginalized { a, b } => {
                let ll = betabin_lpmf(y, n, *a, *b);
                *a += y as f64;
                *b += (n - y) as f64;
                ll
            }
            BetaBinomialNode::Realized(p) => crate::rng::binomial_lpmf(y, n, *p),
        }
    }

    /// Draw a probability and pin it.
    pub fn realize(&mut self, rng: &mut Pcg64) -> f64 {
        match self {
            BetaBinomialNode::Marginalized { a, b } => {
                let x = rng.beta(*a, *b);
                *self = BetaBinomialNode::Realized(x);
                x
            }
            BetaBinomialNode::Realized(x) => *x,
        }
    }

    /// Posterior mean a/(a+b) (or the realized value).
    pub fn mean(&self) -> f64 {
        match self {
            BetaBinomialNode::Marginalized { a, b } => a / (a + b),
            BetaBinomialNode::Realized(x) => *x,
        }
    }
}

/// Beta–Bernoulli (convenience wrapper used by PCFG rule probabilities).
#[derive(Clone, Debug, PartialEq)]
pub struct BetaBernoulli(pub BetaBinomialNode);

impl BetaBernoulli {
    /// A marginalized node with prior Beta(a, b).
    pub fn new(a: f64, b: f64) -> Self {
        BetaBernoulli(BetaBinomialNode::new(a, b))
    }

    /// Observe one Bernoulli outcome; returns the marginal log-pmf.
    pub fn observe(&mut self, y: bool) -> f64 {
        self.0.observe(y as u64, 1)
    }

    /// Draw an outcome from the posterior predictive and observe it.
    pub fn sample_and_observe(&mut self, rng: &mut Pcg64) -> (bool, f64) {
        let p = self.0.mean();
        let y = rng.next_f64() < p;
        let ll = self.observe(y);
        (y, ll)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn gaussian_conjugate_update_matches_closed_form() {
        // Prior N(0, 1), observe y = 2 with obs var 1: posterior N(1, 0.5).
        let mut node = GaussianNode::new(0.0, 1.0);
        let ll = node.observe(2.0, 1.0);
        assert!((ll - normal_lpdf(2.0, 0.0, 2f64.sqrt())).abs() < 1e-12);
        match node {
            GaussianNode::Marginalized { mean, var } => {
                assert!((mean - 1.0).abs() < 1e-12);
                assert!((var - 0.5).abs() < 1e-12);
            }
            _ => panic!("still marginalized"),
        }
    }

    #[test]
    fn gaussian_sequential_equals_batch() {
        // Two sequential observations must equal the joint likelihood.
        let mut node = GaussianNode::new(0.5, 2.0);
        let l1 = node.observe(1.0, 0.7);
        let l2 = node.observe(-0.3, 0.7);
        // Joint: y1 ~ N(m, v+s), y2 | y1 ~ N(m', v'+s) — chain rule already
        // used; verify against a fine-grid numeric marginal instead.
        let mut num = 0.0;
        let d = 0.001;
        let mut mu = -20.0;
        while mu < 20.0 {
            let prior = normal_lpdf(mu, 0.5, 2f64.sqrt()).exp();
            let lik = normal_lpdf(1.0, mu, 0.7f64.sqrt()).exp()
                * normal_lpdf(-0.3, mu, 0.7f64.sqrt()).exp();
            num += prior * lik * d;
            mu += d;
        }
        assert!((l1 + l2 - num.ln()).abs() < 1e-4, "{} vs {}", l1 + l2, num.ln());
    }

    #[test]
    fn gaussian_predict_then_realize() {
        let mut node = GaussianNode::new(1.0, 0.5);
        node.predict(2.0, 0.1, 0.3);
        assert!((node.mean() - 2.1).abs() < 1e-12);
        let mut rng = Pcg64::new(5);
        let x = node.realize(&mut rng);
        assert_eq!(node.realize(&mut rng), x, "realized value is pinned");
    }

    #[test]
    fn gamma_poisson_posterior_and_marginal() {
        let mut node = GammaPoissonNode::new(2.0, 1.0);
        let ll = node.observe(3, 1.0);
        assert!((ll - negbin_lpmf(3, 2.0, 0.5)).abs() < 1e-12);
        match node {
            GammaPoissonNode::Marginalized { shape, rate } => {
                assert_eq!(shape, 5.0);
                assert_eq!(rate, 2.0);
            }
            _ => panic!(),
        }
        // Sequential observes sum to the joint marginal (numeric check).
        let mut node = GammaPoissonNode::new(1.5, 2.0);
        let tot = node.observe(1, 1.0) + node.observe(4, 1.0);
        let mut num = 0.0;
        let d = 0.001;
        let mut lam = d;
        while lam < 50.0 {
            let prior = gamma_lpdf(lam, 1.5, 0.5).exp();
            let lik = crate::rng::poisson_lpmf(1, lam).exp() * crate::rng::poisson_lpmf(4, lam).exp();
            num += prior * lik * d;
            lam += d;
        }
        assert!((tot - num.ln()).abs() < 1e-3, "{} vs {}", tot, num.ln());
    }

    #[test]
    fn beta_binomial_posterior() {
        let mut node = BetaBinomialNode::new(1.0, 1.0);
        let ll = node.observe(7, 10);
        assert!((ll - betabin_lpmf(7, 10, 1.0, 1.0)).abs() < 1e-12);
        assert!((node.mean() - 8.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn beta_bernoulli_sequence() {
        let mut rng = Pcg64::new(9);
        let mut node = BetaBernoulli::new(2.0, 2.0);
        let mut total = 0.0;
        for _ in 0..50 {
            let (_, ll) = node.sample_and_observe(&mut rng);
            total += ll;
            assert!(ll < 0.0);
        }
        assert!(total.is_finite());
    }

    #[test]
    fn realized_nodes_score_directly() {
        let mut node = GaussianNode::Realized(1.5);
        let ll = node.observe(1.0, 0.25);
        assert!((ll - normal_lpdf(1.0, 1.5, 0.5)).abs() < 1e-12);
        let mut gp = GammaPoissonNode::Realized(2.0);
        let ll = gp.observe(2, 1.5);
        assert!((ll - crate::rng::poisson_lpmf(2, 3.0)).abs() < 1e-12);
    }
}
