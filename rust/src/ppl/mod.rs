//! PPL runtime: delayed sampling (automatic Rao–Blackwellization).
//!
//! Murray et al. (2018): a random variable is kept *marginalized* (its
//! posterior parameters carried analytically) for as long as conjugacy
//! permits; observations update the parameters and contribute the
//! *marginal* likelihood; sampling (realization) collapses it to a value.
//! These nodes live inside particle state payloads on the lazy heap, so
//! their in-place parameter updates are exactly the mutation pattern the
//! copy-on-write platform exists to support.

pub mod delayed;
pub mod kalman;

pub use delayed::{BetaBernoulli, BetaBinomialNode, GammaPoissonNode, GaussianNode};
pub use kalman::KalmanState;
