//! Multivariate Kalman filtering: the marginalized linear substate of the
//! Rao–Blackwellized particle filter (Lindsten & Schön 2010).
//!
//! Per particle: z ~ N(m, P) with linear-Gaussian dynamics
//!   z' = A z + b + N(0, Q),   y = C z + N(0, R).
//! `predict` and `update` carry (m, P) analytically; `update` returns the
//! marginal log-likelihood used as the particle weight.
//!
//! This is the CPU oracle for (and fallback of) the L1 Pallas kernel
//! `python/compile/kernels/kalman.py`, which performs the same algebra
//! batched over the particle dimension; the pytest suite and the Rust
//! runtime round-trip tests assert agreement.

use crate::linalg::{mvn_lpdf, Mat};

/// Gaussian belief over a linear substate.
#[derive(Clone, Debug, PartialEq)]
pub struct KalmanState {
    /// Belief mean m.
    pub mean: Vec<f64>,
    /// Belief covariance P.
    pub cov: Mat,
}

impl KalmanState {
    /// A belief N(mean, cov); the covariance must be square and match.
    pub fn new(mean: Vec<f64>, cov: Mat) -> Self {
        assert_eq!(mean.len(), cov.rows);
        assert_eq!(cov.rows, cov.cols);
        KalmanState { mean, cov }
    }

    /// Substate dimension.
    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Time update: m ← A m + b, P ← A P Aᵀ + Q.
    pub fn predict(&mut self, a: &Mat, b: &[f64], q: &Mat) {
        let m = a.matmul(&Mat::col_vec(&self.mean));
        for i in 0..self.mean.len() {
            self.mean[i] = m.at(i, 0) + b[i];
        }
        self.cov = a.matmul(&self.cov).matmul(&a.t()).add(q);
    }

    /// Measurement update with y = C z + N(0, R); returns the marginal
    /// log-likelihood log N(y; C m, C P Cᵀ + R).
    pub fn update(&mut self, c: &Mat, r: &Mat, y: &[f64]) -> f64 {
        let d = y.len();
        // Innovation.
        let cm = c.matmul(&Mat::col_vec(&self.mean));
        let innov: Vec<f64> = (0..d).map(|i| y[i] - cm.at(i, 0)).collect();
        // S = C P Cᵀ + R.
        let pct = self.cov.matmul(&c.t());
        let s = c.matmul(&pct).add(r);
        let predicted: Vec<f64> = (0..d).map(|i| cm.at(i, 0)).collect();
        let ll = mvn_lpdf(y, &predicted, &s);
        // K = P Cᵀ S⁻¹ (via SPD solve per column of (P Cᵀ)ᵀ).
        let s_inv = s.inv_spd().expect("innovation covariance not SPD");
        let k = pct.matmul(&s_inv);
        // m ← m + K innov; P ← P − K S Kᵀ.
        let kv = k.matmul(&Mat::col_vec(&innov));
        for i in 0..self.mean.len() {
            self.mean[i] += kv.at(i, 0);
        }
        let ksk = k.matmul(&s).matmul(&k.t());
        self.cov = self.cov.sub(&ksk);
        ll
    }

    /// Sample a concrete substate (used when a model collapses the
    /// Rao–Blackwellization, e.g. at trajectory extraction).
    pub fn sample(&self, rng: &mut crate::rng::Pcg64) -> Vec<f64> {
        let l = self.cov.cholesky().expect("covariance not SPD");
        let n = self.dim();
        let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut out = self.mean.clone();
        for i in 0..n {
            for j in 0..=i {
                out[i] += l.at(i, j) * z[j];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::normal_lpdf;

    #[test]
    fn scalar_kalman_matches_gaussian_node() {
        // 1-D Kalman must agree with the scalar delayed-sampling node.
        let mut ks = KalmanState::new(vec![0.0], Mat::from_rows(&[&[1.0]]));
        let mut gn = crate::ppl::GaussianNode::new(0.0, 1.0);
        let c = Mat::eye(1);
        let r = Mat::from_rows(&[&[1.0]]);
        let l1 = ks.update(&c, &r, &[2.0]);
        let l2 = gn.observe(2.0, 1.0);
        assert!((l1 - l2).abs() < 1e-12);
        assert!((ks.mean[0] - gn.mean()).abs() < 1e-12);

        // And predict agrees.
        ks.predict(&Mat::from_rows(&[&[0.9]]), &[0.1], &Mat::from_rows(&[&[0.2]]));
        gn.predict(0.9, 0.1, 0.2);
        assert!((ks.mean[0] - gn.mean()).abs() < 1e-12);
    }

    #[test]
    fn update_reduces_uncertainty() {
        let mut ks = KalmanState::new(vec![0.0, 0.0], Mat::eye(2).scale(4.0));
        let c = Mat::from_rows(&[&[1.0, 0.0]]);
        let r = Mat::from_rows(&[&[0.5]]);
        let tr_before = ks.cov.at(0, 0) + ks.cov.at(1, 1);
        let ll = ks.update(&c, &r, &[1.0]);
        let tr_after = ks.cov.at(0, 0) + ks.cov.at(1, 1);
        assert!(tr_after < tr_before);
        assert!(ll < 0.0);
        // Observed dimension moved toward the observation.
        assert!(ks.mean[0] > 0.5 && ks.mean[0] < 1.0);
        // Unobserved dimension untouched (no correlation).
        assert_eq!(ks.mean[1], 0.0);
    }

    #[test]
    fn loglik_matches_direct_formula_1d() {
        let mut ks = KalmanState::new(vec![0.3], Mat::from_rows(&[&[2.0]]));
        let ll = ks.update(&Mat::eye(1), &Mat::from_rows(&[&[0.5]]), &[1.1]);
        assert!((ll - normal_lpdf(1.1, 0.3, 2.5f64.sqrt())).abs() < 1e-12);
    }

    #[test]
    fn filtering_a_known_sequence() {
        // Track a 2-D constant-velocity target; the filter must lock on.
        let dt = 1.0;
        let a = Mat::from_rows(&[&[1.0, dt], &[0.0, 1.0]]);
        let q = Mat::from_rows(&[&[0.01, 0.0], &[0.0, 0.01]]);
        let c = Mat::from_rows(&[&[1.0, 0.0]]);
        let r = Mat::from_rows(&[&[0.1]]);
        let mut ks = KalmanState::new(vec![0.0, 0.0], Mat::eye(2).scale(10.0));
        // True: position = 2t, velocity 2.
        for t in 1..=30 {
            ks.predict(&a, &[0.0, 0.0], &q);
            ks.update(&c, &r, &[2.0 * t as f64]);
        }
        assert!((ks.mean[1] - 2.0).abs() < 0.1, "velocity {}", ks.mean[1]);
    }

    #[test]
    fn sample_has_right_moments() {
        let ks = KalmanState::new(vec![1.0, -1.0], Mat::from_rows(&[&[0.5, 0.2], &[0.2, 0.3]]));
        let mut rng = crate::rng::Pcg64::new(3);
        let n = 20000;
        let mut m = [0.0, 0.0];
        for _ in 0..n {
            let x = ks.sample(&mut rng);
            m[0] += x[0];
            m[1] += x[1];
        }
        assert!((m[0] / n as f64 - 1.0).abs() < 0.02);
        assert!((m[1] / n as f64 + 1.0).abs() < 0.02);
    }
}
