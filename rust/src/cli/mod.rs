//! Declarative command-line flag parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! subcommands, and generated `--help` text.

use std::collections::BTreeMap;

/// One declared flag: name, help text, optional default, and whether it
/// is boolean (present = true, no value consumed).
#[derive(Clone, Debug)]
pub struct FlagSpec {
    /// Flag name without the leading `--`.
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Default value applied when the flag is absent (`None` for bools).
    pub default: Option<String>,
    /// Boolean flag: presence alone sets it to `"true"`.
    pub is_bool: bool,
}

/// Parsed arguments: subcommand + flag map.
#[derive(Debug, Default)]
pub struct Args {
    /// The positional subcommand, if one was given.
    pub command: Option<String>,
    values: BTreeMap<String, String>,
}

impl Args {
    /// Value of a flag (default-filled), if set.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Value of a flag, or `default` when absent.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Flag value parsed as `usize` (None if absent or unparsable).
    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    /// Flag value parsed as `u64` (None if absent or unparsable).
    pub fn get_u64(&self, name: &str) -> Option<u64> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    /// Flag value parsed as `f64` (None if absent or unparsable).
    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    /// Boolean flag state (`true`/`1`/`yes` count as set).
    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }
}

/// A command-line interface definition.
pub struct Cli {
    /// Program name shown in help.
    pub program: &'static str,
    /// One-line program description.
    pub about: &'static str,
    /// Declared subcommands as (name, help) pairs.
    pub commands: Vec<(&'static str, &'static str)>,
    /// Declared flags.
    pub flags: Vec<FlagSpec>,
}

/// Why parsing an argument vector failed.
#[derive(Debug, PartialEq)]
pub enum CliError {
    /// A `--flag` that was never declared.
    UnknownFlag(String),
    /// A non-boolean flag at the end of the argument list.
    MissingValue(String),
    /// `--help`/`-h` was given; print the help text and exit.
    HelpRequested,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(s) => write!(f, "unknown flag --{s}"),
            CliError::MissingValue(s) => write!(f, "flag --{s} requires a value"),
            CliError::HelpRequested => write!(f, "help requested"),
        }
    }
}

impl Cli {
    /// An interface with no commands or flags yet (builder style).
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Cli {
            program,
            about,
            commands: Vec::new(),
            flags: Vec::new(),
        }
    }

    /// Declare a subcommand.
    pub fn command(mut self, name: &'static str, help: &'static str) -> Self {
        self.commands.push((name, help));
        self
    }

    /// Declare a value flag with a default.
    pub fn flag(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a boolean flag (presence = true).
    pub fn bool_flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: None,
            is_bool: true,
        });
        self
    }

    /// Generated `--help` text: usage, commands, and flags with defaults.
    pub fn help_text(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.program, self.about);
        let _ = writeln!(s, "USAGE: {} <command> [--flag value ...]\n", self.program);
        if !self.commands.is_empty() {
            let _ = writeln!(s, "COMMANDS:");
            for (name, help) in &self.commands {
                let _ = writeln!(s, "  {name:<14} {help}");
            }
            let _ = writeln!(s);
        }
        let _ = writeln!(s, "FLAGS:");
        for f in &self.flags {
            let d = f
                .default
                .as_ref()
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            let _ = writeln!(s, "  --{:<14} {}{}", f.name, f.help, d);
        }
        s
    }

    /// Parse an argument vector (without argv[0]).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for f in &self.flags {
            if let Some(d) = &f.default {
                args.values.insert(f.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::HelpRequested);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(name.clone()))?;
                let value = if let Some(v) = inline {
                    v
                } else if spec.is_bool {
                    "true".to_string()
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| CliError::MissingValue(name.clone()))?
                };
                args.values.insert(name, value);
            } else if args.command.is_none() {
                args.command = Some(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("lazycow", "test")
            .command("run", "run a model")
            .flag("model", "rbpf", "model name")
            .flag("particles", "128", "N")
            .bool_flag("verbose", "chatty")
    }

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = cli()
            .parse(&v(&["run", "--model", "vbd", "--particles=256", "--verbose"]))
            .unwrap();
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("model"), Some("vbd"));
        assert_eq!(a.get_usize("particles"), Some(256));
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(&v(&["run"])).unwrap();
        assert_eq!(a.get("model"), Some("rbpf"));
        assert_eq!(a.get_usize("particles"), Some(128));
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn errors() {
        assert_eq!(
            cli().parse(&v(&["--nope", "x"])),
            Err(CliError::UnknownFlag("nope".into()))
        );
        assert_eq!(
            cli().parse(&v(&["--model"])),
            Err(CliError::MissingValue("model".into()))
        );
        assert_eq!(cli().parse(&v(&["--help"])), Err(CliError::HelpRequested));
    }

    #[test]
    fn help_text_lists_everything() {
        let h = cli().help_text();
        assert!(h.contains("--model"));
        assert!(h.contains("run"));
        assert!(h.contains("default: rbpf"));
    }
}

impl PartialEq for Args {
    fn eq(&self, other: &Self) -> bool {
        self.command == other.command && self.values == other.values
    }
}
