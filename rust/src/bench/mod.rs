//! Benchmark harness (criterion is unavailable offline — and the paper
//! reports medians + interquartile ranges over repetitions, which this
//! harness produces directly).
//!
//! [`run_cell`] runs a closure for a number of repetitions, measuring wall
//! time and the process peak RSS delta, and emits aligned tables and TSV
//! for downstream plotting.

use crate::stats::median_iqr;
use std::time::Instant;

/// One measured repetition.
#[derive(Clone, Debug)]
pub struct Sample {
    /// Wall seconds of the repetition.
    pub seconds: f64,
    /// Peak heap footprint reported by the workload (bytes), if any.
    pub peak_bytes: Option<f64>,
}

/// Aggregated result of a benchmark cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Cell label (e.g. `rbpf/lazy-sro`).
    pub name: String,
    /// Repetitions measured.
    pub reps: usize,
    /// Median wall seconds.
    pub time_median: f64,
    /// First-quartile wall seconds.
    pub time_q1: f64,
    /// Third-quartile wall seconds.
    pub time_q3: f64,
    /// Median peak bytes (when every rep reported one).
    pub mem_median: Option<f64>,
    /// First-quartile peak bytes.
    pub mem_q1: Option<f64>,
    /// Third-quartile peak bytes.
    pub mem_q3: Option<f64>,
}

impl CellResult {
    /// Aggregate raw samples into medians and quartiles.
    pub fn from_samples(name: &str, samples: &[Sample]) -> Self {
        let times: Vec<f64> = samples.iter().map(|s| s.seconds).collect();
        let (tm, t1, t3) = median_iqr(&times);
        let mems: Vec<f64> = samples.iter().filter_map(|s| s.peak_bytes).collect();
        let (mm, m1, m3) = if mems.len() == samples.len() && !mems.is_empty() {
            let (a, b, c) = median_iqr(&mems);
            (Some(a), Some(b), Some(c))
        } else {
            (None, None, None)
        };
        CellResult {
            name: name.to_string(),
            reps: samples.len(),
            time_median: tm,
            time_q1: t1,
            time_q3: t3,
            mem_median: mm,
            mem_q1: m1,
            mem_q3: m3,
        }
    }

    /// Header row matching [`CellResult::tsv_row`].
    pub fn tsv_header() -> &'static str {
        "cell\treps\ttime_median_s\ttime_q1_s\ttime_q3_s\tmem_median_b\tmem_q1_b\tmem_q3_b"
    }

    /// One TSV row for downstream plotting.
    pub fn tsv_row(&self) -> String {
        format!(
            "{}\t{}\t{:.6}\t{:.6}\t{:.6}\t{}\t{}\t{}",
            self.name,
            self.reps,
            self.time_median,
            self.time_q1,
            self.time_q3,
            self.mem_median.map(|v| format!("{v:.0}")).unwrap_or_default(),
            self.mem_q1.map(|v| format!("{v:.0}")).unwrap_or_default(),
            self.mem_q3.map(|v| format!("{v:.0}")).unwrap_or_default(),
        )
    }

    /// Human-readable aligned row for terminal output.
    pub fn pretty_row(&self) -> String {
        let mem = match (self.mem_median, self.mem_q1, self.mem_q3) {
            (Some(m), Some(a), Some(b)) => format!(
                "{:>10} [{:>10}, {:>10}]",
                human_bytes(m),
                human_bytes(a),
                human_bytes(b)
            ),
            _ => "         -".to_string(),
        };
        format!(
            "{:<36} {:>9.3}s [{:>8.3}s, {:>8.3}s]   {}",
            self.name, self.time_median, self.time_q1, self.time_q3, mem
        )
    }
}

/// Format bytes with binary units.
pub fn human_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0}{}", UNITS[u])
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

/// Run `reps` repetitions of `work`, which returns an optional peak-bytes
/// figure for the repetition (the heap's own high-water mark, matched to
/// the paper's peak-memory plots).
pub fn run_cell(name: &str, reps: usize, mut work: impl FnMut(usize) -> Option<f64>) -> CellResult {
    let mut samples = Vec::with_capacity(reps);
    for rep in 0..reps {
        let start = Instant::now();
        let peak = work(rep);
        samples.push(Sample {
            seconds: start.elapsed().as_secs_f64(),
            peak_bytes: peak,
        });
    }
    CellResult::from_samples(name, &samples)
}

/// Current process max RSS in bytes (Linux: /proc/self/status VmHWM), as a
/// whole-process cross-check of the heap's own accounting.
pub fn max_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_cell_collects_reps() {
        let mut calls = 0;
        let cell = run_cell("demo", 5, |rep| {
            calls += 1;
            Some((rep as f64 + 1.0) * 1000.0)
        });
        assert_eq!(calls, 5);
        assert_eq!(cell.reps, 5);
        assert_eq!(cell.mem_median, Some(3000.0));
        assert!(cell.time_median >= 0.0);
        assert!(cell.time_q1 <= cell.time_q3);
    }

    #[test]
    fn tsv_and_pretty_rows() {
        let cell = run_cell("x", 3, |_| Some(2048.0));
        let tsv = cell.tsv_row();
        assert!(tsv.starts_with("x\t3\t"));
        assert!(CellResult::tsv_header().contains("time_median_s"));
        assert!(cell.pretty_row().contains("x"));
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512.0), "512B");
        assert_eq!(human_bytes(2048.0), "2.00KiB");
        assert_eq!(human_bytes(3.0 * 1024.0 * 1024.0), "3.00MiB");
    }

    #[test]
    fn rss_readable_on_linux() {
        // Smoke: should parse on this platform.
        assert!(max_rss_bytes().unwrap_or(0) > 0);
    }

    #[test]
    fn missing_mem_leaves_none() {
        let cell = run_cell("nomem", 3, |_| None);
        assert!(cell.mem_median.is_none());
        assert!(cell.pretty_row().contains("-"));
    }
}
