//! Small dense linear algebra for the CPU-side Kalman paths.
//!
//! Column-major `Mat` with the handful of operations the Rao–Blackwellized
//! filters need: multiply, transpose, Cholesky, triangular solves, SPD
//! inverse, quadratic forms, and the multivariate normal log-density.
//! These serve as the oracle against the XLA-compiled batched kernels and
//! as the fallback when artifacts are absent.

/// Column-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Column-major storage (`data[j * rows + i]` is element (i, j)).
    pub data: Vec<f64>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            *m.at_mut(i, i) = 1.0;
        }
        m
    }

    /// Build from row slices (all the same length).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows[0].len();
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            for (j, v) in row.iter().enumerate() {
                *m.at_mut(i, j) = *v;
            }
        }
        m
    }

    /// Column vector (n x 1).
    pub fn col_vec(v: &[f64]) -> Self {
        Mat {
            rows: v.len(),
            cols: 1,
            data: v.to_vec(),
        }
    }

    #[inline]
    /// Element (i, j).
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.rows + i]
    }

    #[inline]
    /// Mutable element (i, j).
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.data[j * self.rows + i]
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                *out.at_mut(j, i) = self.at(i, j);
            }
        }
        out
    }

    /// Matrix product `self · other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for j in 0..other.cols {
            for k in 0..self.cols {
                let b = other.at(k, j);
                if b == 0.0 {
                    continue;
                }
                for i in 0..self.rows {
                    *out.at_mut(i, j) += self.at(i, k) * b;
                }
            }
        }
        out
    }

    /// Elementwise sum.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        out
    }

    /// Elementwise difference.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
        out
    }

    /// Scalar multiple.
    pub fn scale(&self, s: f64) -> Mat {
        let mut out = self.clone();
        for a in out.data.iter_mut() {
            *a *= s;
        }
        out
    }

    /// Cholesky factor L (lower) of an SPD matrix: self = L Lᵀ.
    pub fn cholesky(&self) -> Option<Mat> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut l = Mat::zeros(n, n);
        for j in 0..n {
            let mut d = self.at(j, j);
            for k in 0..j {
                d -= l.at(j, k) * l.at(j, k);
            }
            if d <= 0.0 {
                return None;
            }
            let d = d.sqrt();
            *l.at_mut(j, j) = d;
            for i in (j + 1)..n {
                let mut v = self.at(i, j);
                for k in 0..j {
                    v -= l.at(i, k) * l.at(j, k);
                }
                *l.at_mut(i, j) = v / d;
            }
        }
        Some(l)
    }

    /// Solve L x = b (forward substitution), L lower-triangular.
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.rows;
        assert_eq!(b.len(), n);
        let mut x = b.to_vec();
        for i in 0..n {
            for k in 0..i {
                x[i] -= self.at(i, k) * x[k];
            }
            x[i] /= self.at(i, i);
        }
        x
    }

    /// Solve Lᵀ x = b (back substitution), L lower-triangular.
    pub fn solve_lower_t(&self, b: &[f64]) -> Vec<f64> {
        let n = self.rows;
        let mut x = b.to_vec();
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                x[i] -= self.at(k, i) * x[k];
            }
            x[i] /= self.at(i, i);
        }
        x
    }

    /// SPD solve: self · x = b via Cholesky.
    pub fn solve_spd(&self, b: &[f64]) -> Option<Vec<f64>> {
        let l = self.cholesky()?;
        Some(l.solve_lower_t(&l.solve_lower(b)))
    }

    /// SPD inverse via Cholesky (column-by-column solves).
    pub fn inv_spd(&self) -> Option<Mat> {
        let n = self.rows;
        let l = self.cholesky()?;
        let mut out = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e.iter_mut().for_each(|x| *x = 0.0);
            e[j] = 1.0;
            let col = l.solve_lower_t(&l.solve_lower(&e));
            for i in 0..n {
                *out.at_mut(i, j) = col[i];
            }
        }
        Some(out)
    }

    /// log|det| of an SPD matrix via Cholesky.
    pub fn ln_det_spd(&self) -> Option<f64> {
        let l = self.cholesky()?;
        let mut s = 0.0;
        for i in 0..self.rows {
            s += l.at(i, i).ln();
        }
        Some(2.0 * s)
    }
}

/// Multivariate normal log-density log N(x; mean, cov).
pub fn mvn_lpdf(x: &[f64], mean: &[f64], cov: &Mat) -> f64 {
    let n = x.len();
    let l = cov.cholesky().expect("covariance not SPD");
    let diff: Vec<f64> = x.iter().zip(mean).map(|(a, b)| a - b).collect();
    let z = l.solve_lower(&diff);
    let maha: f64 = z.iter().map(|v| v * v).sum();
    let ln_det: f64 = 2.0 * (0..n).map(|i| l.at(i, i).ln()).sum::<f64>();
    -0.5 * (maha + ln_det + n as f64 * crate::rng::LN_2PI)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }

    #[test]
    fn matmul_and_transpose() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.at(0, 0), 19.0);
        assert_eq!(c.at(0, 1), 22.0);
        assert_eq!(c.at(1, 0), 43.0);
        assert_eq!(c.at(1, 1), 50.0);
        let at = a.t();
        assert_eq!(at.at(0, 1), 3.0);
    }

    #[test]
    fn cholesky_round_trip() {
        let a = Mat::from_rows(&[&[4.0, 2.0, 0.5], &[2.0, 5.0, 1.0], &[0.5, 1.0, 3.0]]);
        let l = a.cholesky().unwrap();
        let re = l.matmul(&l.t());
        for i in 0..3 {
            for j in 0..3 {
                assert_close(re.at(i, j), a.at(i, j), 1e-12);
            }
        }
        // Non-SPD rejected.
        let bad = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]);
        assert!(bad.cholesky().is_none());
    }

    #[test]
    fn spd_solve_and_inverse() {
        let a = Mat::from_rows(&[&[4.0, 1.0], &[1.0, 3.0]]);
        let b = [1.0, 2.0];
        let x = a.solve_spd(&b).unwrap();
        // A x should be b.
        let ax = a.matmul(&Mat::col_vec(&x));
        assert_close(ax.at(0, 0), 1.0, 1e-12);
        assert_close(ax.at(1, 0), 2.0, 1e-12);
        let inv = a.inv_spd().unwrap();
        let id = a.matmul(&inv);
        assert_close(id.at(0, 0), 1.0, 1e-12);
        assert_close(id.at(0, 1), 0.0, 1e-12);
    }

    #[test]
    fn ln_det() {
        let a = Mat::from_rows(&[&[2.0, 0.0], &[0.0, 8.0]]);
        assert_close(a.ln_det_spd().unwrap(), (16f64).ln(), 1e-12);
    }

    #[test]
    fn mvn_lpdf_matches_univariate() {
        let cov = Mat::from_rows(&[&[2.25]]);
        let got = mvn_lpdf(&[1.3], &[0.8], &cov);
        let want = crate::rng::normal_lpdf(1.3, 0.8, 1.5);
        assert_close(got, want, 1e-12);
    }

    #[test]
    fn mvn_lpdf_integrates() {
        // 2-D Riemann check on a correlated Gaussian.
        let cov = Mat::from_rows(&[&[1.0, 0.4], &[0.4, 0.8]]);
        let mean = [0.2, -0.3];
        let d = 0.1;
        let mut total = 0.0;
        let mut x = -6.0;
        while x < 6.0 {
            let mut y = -6.0;
            while y < 6.0 {
                total += mvn_lpdf(&[x, y], &mean, &cov).exp() * d * d;
                y += d;
            }
            x += d;
        }
        assert_close(total, 1.0, 1e-2);
    }
}
