//! The linked-list microbenchmark model: a 1-D linear-Gaussian SSM whose
//! particle state is a cons list (the paper's Table 1/2 `Node` class, with
//! a float payload). Used by the quickstart example, the ancestry-tree
//! bound bench (Jacob et al. 2015 / Figure 2), and as the simplest
//! end-to-end exercise of the platform.

use crate::heap::{Heap, Lazy};
use crate::lazy_fields;
use crate::rng::{normal_lpdf, Pcg64};
use crate::smc::SmcModel;

/// One generation of a particle's history: a cons cell of the chain.
#[derive(Clone)]
pub struct ListState {
    /// Latent state value at this generation.
    pub x: f64,
    /// Previous generation (null at t = 0).
    pub prev: Lazy<ListState>,
}
lazy_fields!(ListState: prev);

/// The 1-D linear-Gaussian SSM: x' = a·x + N(0, q), y = x + N(0, r).
pub struct ListModel {
    /// Dynamics coefficient a.
    pub a: f64,
    /// Process-noise variance q.
    pub q: f64,
    /// Observation-noise variance r.
    pub r: f64,
    /// Observations, one per generation.
    pub obs: Vec<f64>,
}

impl ListModel {
    /// Simulate `t_max` observations from the model itself.
    pub fn synthetic(t_max: usize, seed: u64) -> Self {
        let (a, q, r) = (0.9f64, 0.5f64, 0.8f64);
        let mut rng = Pcg64::stream(seed, 0x7157);
        let mut x = rng.gaussian(0.0, 1.0);
        let mut obs = Vec::with_capacity(t_max);
        for _ in 0..t_max {
            x = a * x + rng.gaussian(0.0, q.sqrt());
            obs.push(x + rng.gaussian(0.0, r.sqrt()));
        }
        ListModel { a, q, r, obs }
    }

    /// Exact evidence by Kalman filtering (test oracle).
    pub fn exact_evidence(&self) -> f64 {
        let (mut mean, mut var) = (0.0f64, 1.0f64);
        let mut lz = 0.0;
        for &y in &self.obs {
            mean *= self.a;
            var = self.a * self.a * var + self.q;
            let s = var + self.r;
            lz += normal_lpdf(y, mean, s.sqrt());
            let k = var / s;
            mean += k * (y - mean);
            var *= 1.0 - k;
        }
        lz
    }
}

impl SmcModel for ListModel {
    type State = ListState;

    fn name(&self) -> &'static str {
        "list"
    }

    fn horizon(&self) -> usize {
        self.obs.len()
    }

    fn init(&self, heap: &mut Heap, rng: &mut Pcg64) -> Lazy<ListState> {
        let x = rng.gaussian(0.0, 1.0);
        heap.alloc(ListState {
            x,
            prev: Lazy::NULL,
        })
    }

    fn step(
        &self,
        heap: &mut Heap,
        state: &mut Lazy<ListState>,
        t: usize,
        rng: &mut Pcg64,
        observe: bool,
    ) -> f64 {
        let x_prev = heap.read(state, |s| s.x);
        let x = self.a * x_prev + rng.gaussian(0.0, self.q.sqrt());
        let old = *state;
        let new = heap.alloc(ListState { x, prev: old });
        heap.release(old);
        *state = new;
        if observe {
            normal_lpdf(self.obs[t - 1], x, self.r.sqrt())
        } else {
            0.0
        }
    }

    fn summary(&self, heap: &mut Heap, state: &mut Lazy<ListState>) -> f64 {
        heap.read(state, |s| s.x)
    }

    fn chain(&self, heap: &mut Heap, state: &Lazy<ListState>) -> Vec<Lazy<ListState>> {
        let mut out = vec![heap.clone_handle(state)];
        let mut cur = *state;
        loop {
            let prev = heap.read_ptr(&mut cur, |s| s.prev);
            if prev.is_null() {
                break;
            }
            out.push(heap.clone_handle(&prev));
            cur = prev;
        }
        out
    }

    fn ref_weight(&self, heap: &mut Heap, state: &mut Lazy<ListState>, t: usize) -> f64 {
        let x = heap.read(state, |s| s.x);
        normal_lpdf(self.obs[t - 1], x, self.r.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Model, RunConfig, Task};
    use crate::heap::CopyMode;
    use crate::pool::ThreadPool;
    use crate::smc::{run_filter, Method, StepCtx};

    #[test]
    fn evidence_close_to_exact() {
        let model = ListModel::synthetic(50, 1);
        let exact = model.exact_evidence();
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
        };
        let mut c = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
        c.n_particles = 1024;
        c.n_steps = 50;
        let mut heap = crate::heap::Heap::new(CopyMode::LazySro);
        let r = run_filter(&model, &c, &mut heap, &ctx, Method::Bootstrap);
        assert!(
            (r.log_evidence - exact).abs() < 2.0,
            "{} vs {exact}",
            r.log_evidence
        );
    }
}
