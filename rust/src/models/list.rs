//! The linked-list microbenchmark model: a 1-D linear-Gaussian SSM whose
//! particle state is a cons list (the paper's Table 1/2 `Node` class, with
//! a float payload). Used by the quickstart example, the ancestry-tree
//! bound bench (Jacob et al. 2015 / Figure 2), and as the simplest
//! end-to-end exercise of the platform.

use crate::heap::{Heap, Lazy};
use crate::lazy_fields;
use crate::rng::{normal_lpdf, Pcg64};
use crate::smc::{batch, particle_rng, SmcModel, StepCtx};

/// One generation of a particle's history: a cons cell of the chain.
#[derive(Clone)]
pub struct ListState {
    /// Latent state value at this generation.
    pub x: f64,
    /// Previous generation (null at t = 0).
    pub prev: Lazy<ListState>,
}
lazy_fields!(ListState: prev);

/// The 1-D linear-Gaussian SSM: x' = a·x + N(0, q), y = x + N(0, r).
///
/// `Clone` supports what-if serving: a speculative branch clones the
/// model, appends hypothetical observations, and steps a forked session
/// against the clone without disturbing the live observation stream.
#[derive(Clone)]
pub struct ListModel {
    /// Dynamics coefficient a.
    pub a: f64,
    /// Process-noise variance q.
    pub q: f64,
    /// Observation-noise variance r.
    pub r: f64,
    /// Observations, one per generation.
    pub obs: Vec<f64>,
}

impl ListModel {
    /// Simulate `t_max` observations from the model itself.
    pub fn synthetic(t_max: usize, seed: u64) -> Self {
        let (a, q, r) = (0.9f64, 0.5f64, 0.8f64);
        let mut rng = Pcg64::stream(seed, 0x7157);
        let mut x = rng.gaussian(0.0, 1.0);
        let mut obs = Vec::with_capacity(t_max);
        for _ in 0..t_max {
            x = a * x + rng.gaussian(0.0, q.sqrt());
            obs.push(x + rng.gaussian(0.0, r.sqrt()));
        }
        ListModel { a, q, r, obs }
    }

    /// A model with the synthetic dynamics (a, q, r) = (0.9, 0.5, 0.8)
    /// and **no observations yet** — the incremental-ingest starting
    /// point for the `serve` subcommand, fed via
    /// [`push_obs`](ListModel::push_obs).
    pub fn streaming() -> Self {
        ListModel {
            a: 0.9,
            q: 0.5,
            r: 0.8,
            obs: Vec::new(),
        }
    }

    /// Append one observation, extending the model horizon by one
    /// generation. A [`FilterSession`](crate::smc::FilterSession) over
    /// this model can then step that generation.
    pub fn push_obs(&mut self, y: f64) {
        self.obs.push(y);
    }

    /// Exact evidence by Kalman filtering (test oracle).
    pub fn exact_evidence(&self) -> f64 {
        let (mut mean, mut var) = (0.0f64, 1.0f64);
        let mut lz = 0.0;
        for &y in &self.obs {
            mean *= self.a;
            var = self.a * self.a * var + self.q;
            let s = var + self.r;
            lz += normal_lpdf(y, mean, s.sqrt());
            let k = var / s;
            mean += k * (y - mean);
            var *= 1.0 - k;
        }
        lz
    }
}

impl SmcModel for ListModel {
    type State = ListState;

    fn name(&self) -> &'static str {
        "list"
    }

    fn horizon(&self) -> usize {
        self.obs.len()
    }

    fn init(&self, heap: &mut Heap, rng: &mut Pcg64) -> Lazy<ListState> {
        let x = rng.gaussian(0.0, 1.0);
        heap.alloc(ListState {
            x,
            prev: Lazy::NULL,
        })
    }

    fn step(
        &self,
        heap: &mut Heap,
        state: &mut Lazy<ListState>,
        t: usize,
        rng: &mut Pcg64,
        observe: bool,
    ) -> f64 {
        let x_prev = heap.read(state, |s| s.x);
        let x = self.a * x_prev + rng.gaussian(0.0, self.q.sqrt());
        let old = *state;
        let new = heap.alloc(ListState { x, prev: old });
        heap.release(old);
        *state = new;
        if observe {
            normal_lpdf(self.obs[t - 1], x, self.r.sqrt())
        } else {
            0.0
        }
    }

    /// Batched generation over SoA lanes ([`crate::smc::batch`]): serial
    /// heap reads → per-lane propagation + batched Gaussian log-pdf →
    /// serial chain extension. Covers both tasks (simulation draws no
    /// extra randomness), bit-identical to the scalar [`SmcModel::step`].
    #[allow(clippy::too_many_arguments)]
    fn step_batched(
        &self,
        heap: &mut Heap,
        states: &mut [Lazy<ListState>],
        t: usize,
        seed: u64,
        observe: bool,
        base: usize,
        _ctx: &StepCtx,
    ) -> Option<Vec<f64>> {
        let n = states.len();
        // Phase 1 (serial, heap): gather the previous latent values.
        let mut xs = vec![0.0f64; n];
        for (i, s) in states.iter_mut().enumerate() {
            xs[i] = heap.read(s, |st| st.x);
        }
        // Phase 2 (lanes, no heap): propagate, then weight in one batched
        // log-pdf sweep. Same RNG stream and expression order per lane as
        // the scalar step.
        for (i, x) in xs.iter_mut().enumerate() {
            let mut rng = particle_rng(seed, t, base + i);
            *x = self.a * *x + rng.gaussian(0.0, self.q.sqrt());
        }
        let mut lw = vec![0.0f64; n];
        if observe {
            batch::gaussian_lpdf(self.obs[t - 1], &xs, self.r.sqrt(), &mut lw);
        }
        // Phase 3 (serial, heap): extend the chains under each particle's
        // copy context, exactly as the scalar path does.
        for (i, s) in states.iter_mut().enumerate() {
            let old = *s;
            let label = s.label();
            let new = heap.with_context(label, |h| h.alloc(ListState { x: xs[i], prev: old }));
            heap.release(old);
            *s = new;
        }
        Some(lw)
    }

    fn summary(&self, heap: &mut Heap, state: &mut Lazy<ListState>) -> f64 {
        heap.read(state, |s| s.x)
    }

    fn chain(&self, heap: &mut Heap, state: &Lazy<ListState>) -> Vec<Lazy<ListState>> {
        let mut out = vec![heap.clone_handle(state)];
        let mut cur = *state;
        loop {
            let prev = heap.read_ptr(&mut cur, |s| s.prev);
            if prev.is_null() {
                break;
            }
            out.push(heap.clone_handle(&prev));
            cur = prev;
        }
        out
    }

    fn ref_weight(&self, heap: &mut Heap, state: &mut Lazy<ListState>, t: usize) -> f64 {
        let x = heap.read(state, |s| s.x);
        normal_lpdf(self.obs[t - 1], x, self.r.sqrt())
    }

    /// One observation per generation: a single finite float `y`.
    fn stream_observation(&mut self, tokens: &[&str]) -> Result<(), String> {
        let [tok] = tokens else {
            return Err(format!(
                "list expects exactly one observation value per generation, got {} tokens",
                tokens.len()
            ));
        };
        let y: f64 = tok
            .parse()
            .map_err(|_| format!("list observation '{tok}' is not a number"))?;
        if !y.is_finite() {
            return Err(format!("list observation '{tok}' must be finite"));
        }
        self.push_obs(y);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Model, RunConfig, Task};
    use crate::heap::CopyMode;
    use crate::pool::ThreadPool;
    use crate::smc::{run_filter, Method, StepCtx};

    #[test]
    fn evidence_close_to_exact() {
        let model = ListModel::synthetic(50, 1);
        let exact = model.exact_evidence();
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut c = RunConfig::for_model(Model::List, Task::Inference, CopyMode::LazySro);
        c.n_particles = 1024;
        c.n_steps = 50;
        let mut heap = crate::heap::Heap::new(CopyMode::LazySro);
        let r = run_filter(&model, &c, &mut heap, &ctx, Method::Bootstrap);
        assert!(
            (r.log_evidence - exact).abs() < 2.0,
            "{} vs {exact}",
            r.log_evidence
        );
    }

    #[test]
    fn batched_step_equals_sequential_step_bitwise() {
        // The SoA hook must match the scalar step bit-for-bit — weights
        // and post-step states — for both tasks.
        let model = ListModel::synthetic(6, 9);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        for observe in [true, false] {
            let mut heap_a = crate::heap::Heap::new(CopyMode::LazySro);
            let mut heap_b = crate::heap::Heap::new(CopyMode::LazySro);
            let n = 13;
            let mut sa: Vec<_> = (0..n)
                .map(|i| model.init(&mut heap_a, &mut particle_rng(3, 0, i)))
                .collect();
            let mut sb: Vec<_> = (0..n)
                .map(|i| model.init(&mut heap_b, &mut particle_rng(3, 0, i)))
                .collect();
            for t in 1..=6 {
                let wa = model
                    .step_batched(&mut heap_a, &mut sa, t, 3, observe, 0, &ctx)
                    .expect("list model always batches");
                for (i, s) in sb.iter_mut().enumerate() {
                    let mut rng = particle_rng(3, t, i);
                    let wb = model.step(&mut heap_b, s, t, &mut rng, observe);
                    assert_eq!(wa[i].to_bits(), wb.to_bits(), "t={t} i={i} observe={observe}");
                    let xa = heap_a.read(&mut sa[i], |st| st.x);
                    let xb = heap_b.read(s, |st| st.x);
                    assert_eq!(xa.to_bits(), xb.to_bits(), "t={t} i={i} state");
                }
            }
            for s in sa {
                heap_a.release(s);
            }
            for s in sb {
                heap_b.release(s);
            }
        }
    }
}
