//! CRBD: constant-rate birth–death phylogenetics with an **alive particle
//! filter** (Del Moral et al. 2015) and delayed sampling (Kudlicka et al.
//! 2019).
//!
//! The observed, fixed ultrametric phylogeny is processed as a sequence of
//! branching events (T = #events). Per event, each particle (i) scores the
//! observed speciation with the **marginalized** birth rate λ (gamma prior
//! carried as a gamma–Poisson sufficient-statistic accumulator — exposure
//! updates every event, the in-place mutation pattern), and (ii) simulates
//! hidden side-speciations whose subtrees must go extinct before the
//! present; a surviving hidden subtree kills the particle (weight −∞),
//! which the alive PF handles by re-proposing until N survivors exist.
//!
//! Paper scale: N = 5000, T = 173, cetacean phylogeny (Steeman et al.
//! 2009, 87 extant species). Substitution: a synthetic ultrametric
//! birth–death tree with 87 tips generated once from a fixed seed — same
//! event count and shape class; the platform behaviour depends on the
//! event sequence structure, not which species are at the tips.

use crate::heap::{Heap, Lazy};
use crate::lazy_fields;
use crate::ppl::GammaPoissonNode;
use crate::rng::Pcg64;
use crate::smc::SmcModel;

/// Death (extinction) rate, fixed (λ is inferred).
const MU: f64 = 0.25;

/// One branching event of the observed tree.
#[derive(Clone, Copy, Debug)]
pub struct TreeEvent {
    /// Time since the previous event.
    pub dt: f64,
    /// Number of extant lineages during the interval.
    pub lineages: u32,
    /// Time remaining from this event to the present.
    pub remaining: f64,
}

/// A particle's inference state over the tree's event sequence.
#[derive(Clone)]
pub struct CrbdState {
    /// Marginalized birth rate: λ ~ Gamma, speciations ~ Poisson(λ·E).
    pub lambda: GammaPoissonNode,
    /// Branching events processed so far.
    pub events_done: u32,
    /// Previous event's state (the history chain).
    pub prev: Lazy<CrbdState>,
}
lazy_fields!(CrbdState: prev);

/// The constant-rate birth-death model over an observed tree.
///
/// `Clone` supports what-if serving: speculative branches clone the
/// model and append hypothetical branching events without disturbing
/// the live event sequence.
#[derive(Clone)]
pub struct Crbd {
    /// The observed tree's branching events, oldest first.
    pub events: Vec<TreeEvent>,
}

impl Crbd {
    /// A model with **no branching events yet** — the incremental-ingest
    /// starting point for the `serve` subcommand (events arrive via
    /// [`stream_observation`](SmcModel::stream_observation)).
    pub fn streaming() -> Self {
        Crbd { events: Vec::new() }
    }

    /// Generate a synthetic ultrametric tree with `tips` extant species:
    /// the branching-event sequence of a birth–death process conditioned
    /// on survival, approximated by exponential inter-event times at rate
    /// λ₀·k for k current lineages.
    pub fn synthetic(tips: usize, seed: u64) -> Self {
        let lambda0 = 0.8;
        let mut rng = Pcg64::stream(seed, 0xC12BD);
        let mut raw: Vec<(f64, u32)> = Vec::with_capacity(tips.saturating_sub(1));
        for k in 2..=tips as u32 {
            let dt = rng.exponential(lambda0 * k as f64);
            raw.push((dt, k - 1));
        }
        let total: f64 = raw.iter().map(|(dt, _)| dt).sum();
        let mut elapsed = 0.0;
        let events = raw
            .into_iter()
            .map(|(dt, lineages)| {
                elapsed += dt;
                TreeEvent {
                    dt,
                    lineages,
                    remaining: total - elapsed,
                }
            })
            .collect();
        Crbd { events }
    }

    /// Extinction probability of a hidden subtree born with `remaining`
    /// time to the present, under birth rate `lam` and death rate MU
    /// (standard CRBD formula).
    fn extinct_prob(lam: f64, remaining: f64) -> f64 {
        if (lam - MU).abs() < 1e-9 {
            let x = lam * remaining;
            return (x / (1.0 + x)).clamp(0.0, 1.0);
        }
        let e = (-(lam - MU) * remaining).exp();
        (MU * (1.0 - e) / (lam - MU * e)).clamp(0.0, 1.0)
    }
}

impl SmcModel for Crbd {
    type State = CrbdState;

    fn name(&self) -> &'static str {
        "crbd"
    }

    fn horizon(&self) -> usize {
        self.events.len()
    }

    fn init(&self, heap: &mut Heap, _rng: &mut Pcg64) -> Lazy<CrbdState> {
        heap.alloc(CrbdState {
            lambda: GammaPoissonNode::new(2.0, 2.0), // prior mean 1.0
            events_done: 0,
            prev: Lazy::NULL,
        })
    }

    fn step(
        &self,
        heap: &mut Heap,
        state: &mut Lazy<CrbdState>,
        t: usize,
        rng: &mut Pcg64,
        observe: bool,
    ) -> f64 {
        let ev = self.events[t - 1];
        let mut s = heap.read(state, |s| s.clone());
        let exposure = ev.dt * ev.lineages as f64;
        // Observed speciation at the end of the interval: one event in
        // `exposure` lineage-time (gamma–Poisson marginal).
        let mut ll = s.lambda.observe(1, exposure.max(1e-9));
        if observe {
            // Hidden speciations along the interval whose subtrees must be
            // extinct today. Posterior-predictive count, then survival
            // thinning — any survivor contradicts the observed tree.
            let lam_hat = s.lambda.mean();
            let m = rng.poisson(lam_hat * exposure);
            let p_ext = Self::extinct_prob(lam_hat, ev.remaining.max(1e-9));
            for _ in 0..m {
                if rng.next_f64() > p_ext {
                    ll = f64::NEG_INFINITY; // subtree survives: impossible
                    break;
                }
            }
        }
        s.events_done += 1;
        let old = *state;
        s.prev = old;
        let new = heap.alloc(s);
        heap.release(old);
        *state = new;
        if observe {
            ll
        } else {
            0.0
        }
    }

    fn summary(&self, heap: &mut Heap, state: &mut Lazy<CrbdState>) -> f64 {
        heap.read(state, |s| s.lambda.mean())
    }

    /// Per-particle cost skew: the dominant step cost is simulating hidden
    /// side-speciations, whose expected count is the posterior-predictive
    /// rate λ̂ times the interval exposure — so particles carrying a high
    /// inferred birth rate are proportionally more expensive. A cheap O(1)
    /// read of the marginal mean; the offset keeps hints positive.
    fn cost_hint(&self, heap: &mut Heap, state: &mut Lazy<CrbdState>) -> f64 {
        1.0 + heap.read(state, |s| s.lambda.mean())
    }

    /// One branching event per generation: `dt lineages remaining`
    /// (interval length > 0, extant lineage count ≥ 1, time to the
    /// present ≥ 0). Validation matters doubly here: the alive PF
    /// re-proposes until a particle survives, so an event no particle
    /// can survive would spin the retry loop into its bailout — reject
    /// malformed shapes at the door.
    fn stream_observation(&mut self, tokens: &[&str]) -> Result<(), String> {
        let [t_dt, t_lin, t_rem] = tokens else {
            return Err(format!(
                "crbd expects three values per event (dt lineages remaining), got {} tokens",
                tokens.len()
            ));
        };
        let dt: f64 = t_dt
            .parse()
            .map_err(|_| format!("crbd dt '{t_dt}' is not a number"))?;
        let lineages: u32 = t_lin
            .parse()
            .map_err(|_| format!("crbd lineages '{t_lin}' is not a positive integer"))?;
        let remaining: f64 = t_rem
            .parse()
            .map_err(|_| format!("crbd remaining '{t_rem}' is not a number"))?;
        if !dt.is_finite() || dt <= 0.0 {
            return Err(format!("crbd dt must be finite and > 0, got {dt}"));
        }
        if lineages == 0 {
            return Err("crbd lineages must be >= 1".to_string());
        }
        if !remaining.is_finite() || remaining < 0.0 {
            return Err(format!("crbd remaining must be finite and >= 0, got {remaining}"));
        }
        self.events.push(TreeEvent { dt, lineages, remaining });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Model, RunConfig, Task};
    use crate::heap::{CopyMode, Heap};
    use crate::pool::ThreadPool;
    use crate::smc::{run_filter, Method, StepCtx};

    #[test]
    fn synthetic_tree_shape() {
        let tree = Crbd::synthetic(87, 1);
        assert_eq!(tree.events.len(), 86, "87 tips -> 86 branching events");
        assert!(tree.events.iter().all(|e| e.dt > 0.0));
        assert!(tree.events.last().unwrap().remaining.abs() < 1e-9);
        assert_eq!(tree.events[0].lineages, 1);
        // Reproducible.
        assert_eq!(
            Crbd::synthetic(87, 1).events.len(),
            Crbd::synthetic(87, 1).events.len()
        );
    }

    #[test]
    fn extinction_probability_bounds() {
        for lam in [0.1, 0.25, 0.8, 2.0] {
            for tau in [0.01, 1.0, 50.0] {
                let p = Crbd::extinct_prob(lam, tau);
                assert!((0.0..=1.0).contains(&p), "lam={lam} tau={tau}: {p}");
            }
        }
        // Long horizons with high birth rate: survival likely.
        assert!(Crbd::extinct_prob(2.0, 100.0) < 0.5);
        // Short horizons: extinction unlikely... and death dominates birth:
        assert!(Crbd::extinct_prob(0.01, 100.0) > 0.9);
    }

    #[test]
    fn alive_filter_retries_and_cleans_up() {
        let model = Crbd::synthetic(30, 2);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut out = Vec::new();
        for mode in CopyMode::ALL {
            let mut c = RunConfig::for_model(Model::Crbd, Task::Inference, mode);
            c.n_particles = 64;
            c.n_steps = model.horizon();
            c.seed = 3;
            let mut heap = Heap::new(mode);
            let r = run_filter(&model, &c, &mut heap, &ctx, Method::Alive);
            assert!(r.log_evidence.is_finite());
            assert!(
                r.attempts >= 64 * model.horizon(),
                "attempt count includes retries"
            );
            out.push((r.log_evidence, r.attempts));
            assert_eq!(heap.live_objects(), 0, "{mode:?} leaked");
        }
        assert_eq!(out[0], out[1]);
        assert_eq!(out[1], out[2]);
    }

    #[test]
    fn posterior_lambda_is_plausible() {
        // The generating rate is 0.8; the posterior mean of λ should land
        // in a sane band around it.
        let model = Crbd::synthetic(87, 7);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut c = RunConfig::for_model(Model::Crbd, Task::Inference, CopyMode::LazySro);
        c.n_particles = 128;
        c.n_steps = model.horizon();
        let mut heap = Heap::new(CopyMode::LazySro);
        let r = run_filter(&model, &c, &mut heap, &ctx, Method::Alive);
        assert!(
            (0.3..2.0).contains(&r.posterior_mean),
            "posterior mean λ = {}",
            r.posterior_mean
        );
    }
}
