//! VBD: vector-borne disease model (dengue-like SEIR/SEI), inferred with
//! marginalized particle Gibbs (Wigren et al. 2019).
//!
//! Discrete-time stochastic compartmental model with binomial transfers:
//! humans S→E→I→R, mosquitoes S→E→I (with turnover). The per-case
//! reporting rate is marginalized by a gamma–Poisson sufficient-statistic
//! accumulator (delayed sampling) that *mutates every generation* — the
//! in-place-update pattern the lazy platform serves. Between particle
//! Gibbs iterations a single particle is deep-copied **eagerly** (the
//! paper's §4 note), handled by the coordinator.
//!
//! Paper scale: N = 4096, T = 182, 3 PG iterations, dengue data from
//! Micronesia (Funk et al. 2016). Substitution: a synthetic outbreak trace
//! generated from the model with fixed "true" parameters — same shape
//! (weekly case counts, a single epidemic wave); the memory behaviour the
//! paper measures depends on the trace length and structure, not values.

use crate::heap::{Heap, Lazy};
use crate::lazy_fields;
use crate::ppl::GammaPoissonNode;
use crate::rng::Pcg64;
use crate::smc::SmcModel;

/// Fixed epidemiological parameters (weekly rates).
#[derive(Clone, Debug)]
pub struct VbdParams {
    /// Human population size.
    pub n_h: u64,
    /// Mosquito population size.
    pub n_m: u64,
    /// Human-to-mosquito transmission rate.
    pub beta_hm: f64,
    /// Mosquito-to-human transmission rate.
    pub beta_mh: f64,
    /// Human incubation probability per week.
    pub p_inc_h: f64,
    /// Human recovery probability per week.
    pub p_rec_h: f64,
    /// Mosquito incubation probability per week.
    pub p_inc_m: f64,
    /// Mosquito death probability per week.
    pub p_death_m: f64,
}

impl Default for VbdParams {
    fn default() -> Self {
        VbdParams {
            n_h: 10_000,
            n_m: 30_000,
            beta_hm: 1.2,
            beta_mh: 0.6,
            p_inc_h: 0.6,
            p_rec_h: 0.5,
            p_inc_m: 0.7,
            p_death_m: 0.25,
        }
    }
}

/// One week's SEIR/SEI compartment counts (humans and mosquitos).
#[derive(Clone)]
pub struct VbdState {
    /// Susceptible humans.
    pub sh: u64,
    /// Exposed humans.
    pub eh: u64,
    /// Infectious humans.
    pub ih: u64,
    /// Recovered humans.
    pub rh: u64,
    /// Susceptible mosquitos.
    pub sm: u64,
    /// Exposed mosquitos.
    pub em: u64,
    /// Infectious mosquitos.
    pub im: u64,
    /// New human infections this week (the observed quantity's base).
    pub new_ih: u64,
    /// Marginalized reporting rate: ρ ~ Gamma, y ~ Poisson(ρ · new_ih).
    pub rho: GammaPoissonNode,
    /// Observation log-likelihood recorded at step time (used to score the
    /// pinned reference particle in conditional SMC).
    pub obs_ll: f64,
    /// Previous week's state (the history chain).
    pub prev: Lazy<VbdState>,
}
lazy_fields!(VbdState: prev);

/// The VBD model: weekly case counts with a marginalized reporting rate.
///
/// `Clone` supports what-if serving: speculative branches clone the
/// model and append hypothetical case counts without disturbing the
/// live trace.
#[derive(Clone)]
pub struct Vbd {
    /// Fixed epidemiological parameters.
    pub params: VbdParams,
    /// Observed weekly case counts.
    pub obs: Vec<u64>,
}

fn transfer(rng: &mut Pcg64, n: u64, rate: f64) -> u64 {
    let p = 1.0 - (-rate).exp();
    rng.binomial(n, p.clamp(0.0, 1.0))
}

impl Vbd {
    fn initial(params: &VbdParams) -> VbdState {
        VbdState {
            sh: params.n_h - 10,
            eh: 5,
            ih: 5,
            rh: 0,
            sm: params.n_m - 100,
            em: 50,
            im: 50,
            new_ih: 0,
            rho: GammaPoissonNode::new(2.0, 4.0), // prior mean 0.5 reporting
            obs_ll: 0.0,
            prev: Lazy::NULL,
        }
    }

    /// One week of dynamics; returns the number of new human infections.
    fn dynamics(p: &VbdParams, s: &mut VbdState, rng: &mut Pcg64) -> u64 {
        let foi_h = p.beta_hm * s.im as f64 / p.n_m as f64;
        let new_eh = transfer(rng, s.sh, foi_h);
        let new_ih = transfer(rng, s.eh, p.p_inc_h);
        let new_rh = transfer(rng, s.ih, p.p_rec_h);
        let foi_m = p.beta_mh * s.ih as f64 / p.n_h as f64;
        let new_em = transfer(rng, s.sm, foi_m);
        let new_im = transfer(rng, s.em, p.p_inc_m);
        // Mosquito turnover: deaths replaced by susceptible births.
        let dead_em = transfer(rng, s.em, p.p_death_m);
        let dead_im = transfer(rng, s.im, p.p_death_m);
        s.sh -= new_eh;
        s.eh = s.eh + new_eh - new_ih;
        s.ih = s.ih + new_ih - new_rh;
        s.rh += new_rh;
        s.sm = s.sm + dead_em + dead_im - new_em;
        s.em = s.em + new_em - new_im - dead_em.min(s.em + new_em - new_im);
        s.im = s.im + new_im - dead_im;
        s.new_ih = new_ih;
        new_ih
    }

    /// Default parameters and **no case counts yet** — the
    /// incremental-ingest starting point for the `serve` subcommand
    /// (weekly counts arrive via
    /// [`stream_observation`](SmcModel::stream_observation)).
    pub fn streaming() -> Self {
        Vbd {
            params: VbdParams::default(),
            obs: Vec::new(),
        }
    }

    /// Generate a synthetic weekly case-count trace (one outbreak wave).
    pub fn synthetic(t_max: usize, seed: u64) -> Self {
        let params = VbdParams::default();
        let mut rng = Pcg64::stream(seed, 0xB0DD);
        let mut s = Self::initial(&params);
        let true_rho = 0.4;
        let mut obs = Vec::with_capacity(t_max);
        for _ in 0..t_max {
            let new_ih = Self::dynamics(&params, &mut s, &mut rng);
            obs.push(rng.poisson(true_rho * new_ih as f64));
        }
        Vbd { params, obs }
    }
}

impl SmcModel for Vbd {
    type State = VbdState;

    fn name(&self) -> &'static str {
        "vbd"
    }

    fn horizon(&self) -> usize {
        self.obs.len()
    }

    fn init(&self, heap: &mut Heap, _rng: &mut Pcg64) -> Lazy<VbdState> {
        heap.alloc(Self::initial(&self.params))
    }

    fn step(
        &self,
        heap: &mut Heap,
        state: &mut Lazy<VbdState>,
        t: usize,
        rng: &mut Pcg64,
        observe: bool,
    ) -> f64 {
        let mut s = heap.read(state, |s| s.clone());
        let new_ih = Self::dynamics(&self.params, &mut s, rng);
        let ll = if observe {
            s.rho.observe(self.obs[t - 1], new_ih.max(1) as f64)
        } else {
            // Simulation: sample a pseudo-observation from the predictive.
            let rho = match s.rho {
                GammaPoissonNode::Marginalized { shape, rate } => shape / rate,
                GammaPoissonNode::Realized(v) => v,
            };
            let _ = rng.poisson(rho * new_ih as f64);
            0.0
        };
        s.obs_ll = ll;
        let old = *state;
        s.prev = old;
        let new = heap.alloc(s);
        heap.release(old);
        *state = new;
        if observe {
            ll
        } else {
            0.0
        }
    }

    fn summary(&self, heap: &mut Heap, state: &mut Lazy<VbdState>) -> f64 {
        heap.read(state, |s| s.ih as f64 + s.rho.mean())
    }

    fn chain(&self, heap: &mut Heap, state: &Lazy<VbdState>) -> Vec<Lazy<VbdState>> {
        let mut out = vec![heap.clone_handle(state)];
        let mut cur = *state;
        loop {
            let prev = heap.read_ptr(&mut cur, |s| s.prev);
            if prev.is_null() {
                break;
            }
            out.push(heap.clone_handle(&prev));
            cur = prev;
        }
        out
    }

    fn ref_weight(&self, heap: &mut Heap, state: &mut Lazy<VbdState>, _t: usize) -> f64 {
        heap.read(state, |s| s.obs_ll)
    }

    /// One observation per generation: a non-negative integer weekly
    /// case count.
    fn stream_observation(&mut self, tokens: &[&str]) -> Result<(), String> {
        let [tok] = tokens else {
            return Err(format!(
                "vbd expects exactly one case count per generation, got {} tokens",
                tokens.len()
            ));
        };
        let y: u64 = tok
            .parse()
            .map_err(|_| format!("vbd case count '{tok}' is not a non-negative integer"))?;
        self.obs.push(y);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Model, RunConfig, Task};
    use crate::heap::{CopyMode, Heap};
    use crate::pool::ThreadPool;
    use crate::smc::{run_filter, run_particle_gibbs, Method, StepCtx};

    #[test]
    fn synthetic_trace_has_an_outbreak() {
        let m = Vbd::synthetic(120, 1);
        assert_eq!(m.obs.len(), 120);
        let peak = *m.obs.iter().max().unwrap();
        assert!(peak > 10, "expected an epidemic wave, peak {peak}");
        // Reproducible.
        assert_eq!(m.obs, Vbd::synthetic(120, 1).obs);
    }

    #[test]
    fn population_is_conserved() {
        let params = VbdParams::default();
        let mut s = Vbd::initial(&params);
        let mut rng = Pcg64::new(3);
        for _ in 0..200 {
            Vbd::dynamics(&params, &mut s, &mut rng);
            assert_eq!(s.sh + s.eh + s.ih + s.rh, params.n_h, "humans conserved");
        }
    }

    #[test]
    fn bootstrap_filter_runs_all_modes_identically() {
        let model = Vbd::synthetic(30, 2);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut out = Vec::new();
        for mode in CopyMode::ALL {
            let mut c = RunConfig::for_model(Model::Vbd, Task::Inference, mode);
            c.n_particles = 64;
            c.n_steps = 30;
            c.seed = 5;
            let mut heap = Heap::new(mode);
            let r = run_filter(&model, &c, &mut heap, &ctx, Method::Bootstrap);
            out.push(r.log_evidence);
            assert_eq!(heap.live_objects(), 0);
        }
        assert_eq!(out[0].to_bits(), out[1].to_bits());
        assert_eq!(out[1].to_bits(), out[2].to_bits());
    }

    #[test]
    fn particle_gibbs_with_eager_reference_copy() {
        let model = Vbd::synthetic(20, 3);
        let pool = ThreadPool::new(1);
        let ctx = StepCtx {
            pool: &pool,
            kalman: None,
            batch: true,
        };
        let mut c = RunConfig::for_model(Model::Vbd, Task::Inference, CopyMode::LazySro);
        c.n_particles = 48;
        c.n_steps = 20;
        c.pg_iterations = 3;
        let mut heap = Heap::new(CopyMode::LazySro);
        let rs = run_particle_gibbs(&model, &c, &mut heap, &ctx);
        assert_eq!(rs.len(), 3);
        assert!(rs.iter().all(|r| r.log_evidence.is_finite()));
        assert!(
            heap.metrics.eager_copies > 0,
            "reference copy must be eager (paper §4)"
        );
        assert_eq!(heap.live_objects(), 0);
    }
}
